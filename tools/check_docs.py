#!/usr/bin/env python3
"""Docs gate: link-check + cross-refs + doctest, pure stdlib.

The repo's documentation is layered -- README.md (the feature tour),
docs/ARCHITECTURE.md (the layer map, with doctested examples),
docs/OPERATIONS.md (every env var / CI gate / baseline workflow), and
ROADMAP.md -- and CI keeps it honest the same way it keeps the
benchmarks honest:

* every **relative markdown link** in a checked doc must resolve to a
  file that exists in the repo (scheme links -- http/https/mailto --
  and pure anchors are skipped; ``#fragment`` suffixes are stripped);
* the README must **cross-reference** both docs pages (the docs layer
  is only useful if it is discoverable from the front door);
* no checked doc may reference a **non-shipping path** (``/root/...``
  build-environment paths do not exist for repo users; this is the
  regression class that left a dead related-repo path in ROADMAP.md
  for four PRs);
* the fenced examples in docs/ARCHITECTURE.md run as **doctests**
  (needs ``PYTHONPATH=src`` and jax installed; everything above is
  stdlib-only).

Usage::

    PYTHONPATH=src python tools/check_docs.py            # the CI gate
    python tools/check_docs.py --no-doctest              # links only

Exits nonzero with one line per finding.
"""

from __future__ import annotations

import argparse
import os
import re
import sys
from typing import List

DOC_FILES = (
    "README.md",
    "ROADMAP.md",
    "docs/ARCHITECTURE.md",
    "docs/OPERATIONS.md",
)

# The front door must point at the docs layer.
REQUIRED_REFS = {
    "README.md": ("docs/ARCHITECTURE.md", "docs/OPERATIONS.md"),
}

DOCTEST_FILES = ("docs/ARCHITECTURE.md",)

# [text](target) -- target up to the first ')' or whitespace.  Good
# enough for this repo's docs; nested parens in URLs are not used.
_LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
_SCHEME_RE = re.compile(r"^[a-zA-Z][a-zA-Z0-9+.-]*:")

# Build-environment absolute paths that do not ship with the repo.
_NON_SHIPPING_RE = re.compile(r"/root/(?:related|repo)\b")


def repo_root() -> str:
    return os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def iter_links(text: str):
    """(line_number, raw_target) for every markdown link in ``text``."""
    for i, line in enumerate(text.splitlines(), 1):
        for m in _LINK_RE.finditer(line):
            yield i, m.group(1)


def check_links(root: str, docs=DOC_FILES) -> List[str]:
    """Dead relative links + missing required cross-references."""
    errors = []
    for doc in docs:
        path = os.path.join(root, doc)
        if not os.path.isfile(path):
            errors.append(f"{doc}: checked doc is missing")
            continue
        with open(path, encoding="utf-8") as fh:
            text = fh.read()
        seen = set()
        for lineno, target in iter_links(text):
            if _SCHEME_RE.match(target) or target.startswith("#"):
                continue
            rel = target.split("#", 1)[0]
            if not rel:
                continue
            seen.add(os.path.normpath(
                os.path.join(os.path.dirname(doc), rel)))
            resolved = os.path.normpath(
                os.path.join(root, os.path.dirname(doc), rel))
            if not os.path.exists(resolved):
                errors.append(f"{doc}:{lineno}: dead link -> {target}")
        for required in REQUIRED_REFS.get(doc, ()):
            if os.path.normpath(required) not in seen:
                errors.append(f"{doc}: missing required link to {required}")
    return errors


def check_shipping_paths(root: str, docs=DOC_FILES) -> List[str]:
    """Docs must not reference paths that only exist at build time."""
    errors = []
    for doc in docs:
        path = os.path.join(root, doc)
        if not os.path.isfile(path):
            continue
        with open(path, encoding="utf-8") as fh:
            for i, line in enumerate(fh, 1):
                m = _NON_SHIPPING_RE.search(line)
                if m:
                    errors.append(f"{doc}:{i}: non-shipping path "
                                  f"{m.group(0)!r} referenced in docs")
    return errors


def run_doctests(root: str, docs=DOCTEST_FILES) -> List[str]:
    """doctest.testfile over the example-bearing docs."""
    import doctest
    errors = []
    for doc in docs:
        path = os.path.join(root, doc)
        if not os.path.isfile(path):
            errors.append(f"{doc}: doctest target is missing")
            continue
        failures, attempted = doctest.testfile(path, module_relative=False)
        if failures:
            errors.append(f"{doc}: {failures}/{attempted} doctest "
                          f"examples failed (rerun: python -m doctest "
                          f"{doc} -v)")
        elif attempted == 0:
            errors.append(f"{doc}: no doctest examples found (the "
                          f"worked-examples section is load-bearing)")
    return errors


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--no-doctest", action="store_true",
                    help="skip the doctest pass (no jax / PYTHONPATH "
                         "needed; links and paths are still checked)")
    args = ap.parse_args(argv)

    root = repo_root()
    errors = check_links(root) + check_shipping_paths(root)
    if not args.no_doctest:
        errors += run_doctests(root)
    for e in errors:
        print(f"DOCS: {e}")
    if errors:
        print(f"docs gate: {len(errors)} finding(s)")
        return 1
    print("docs gate: clean")
    return 0


if __name__ == "__main__":
    sys.exit(main())
