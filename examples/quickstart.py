"""Quickstart: the DynIMS control loop in ~40 lines.

A 125 GB node runs a compute job with a memory burst while an in-memory
store (here: a byte cache standing in for Alluxio / a dataset cache /
a KV pool) opportunistically uses the slack.  The controller keeps
utilization at the 95% threshold, evicting within one 100 ms interval.

    PYTHONPATH=src python examples/quickstart.py
"""

from repro.core import (ControlPlane, GiB, ShardCache, SimulatedMonitor,
                        StoreRegistry)
from repro.core.cluster_sim import paper_controller_params


class Blob:
    def __init__(self, nbytes):
        self.nbytes = nbytes


def main():
    # the opportunistic tenant: starts with all 60 GB of RAMdisk
    cache = ShardCache(capacity=60 * GiB)
    for shard in range(60):
        cache.put(shard, Blob(1 * GiB))
    registry = StoreRegistry()
    registry.register(cache, max_bytes=60 * GiB)

    # the priority tenant: 20 GB baseline with a burst to 95 GB
    compute = [20 * GiB] * 10 + [95 * GiB] * 15 + [20 * GiB] * 25

    plane = ControlPlane(paper_controller_params())   # Table I
    plane.attach("node0",
                 SimulatedMonitor("node0", total=125 * GiB, usage=compute,
                                  storage_used_fn=cache.used),
                 registry)

    print(f"{'interval':>8} {'compute':>9} {'cache cap':>10} "
          f"{'cache used':>10} {'util':>6}")
    for i in range(len(compute)):
        plane.tick()
        util = (compute[i] + cache.used()) / (125 * GiB)
        print(f"{i:8d} {compute[i]/GiB:8.0f}G {cache.capacity()/GiB:9.1f}G "
              f"{cache.used()/GiB:9.1f}G {util:6.1%}")
    print(f"\nevictions: {cache.stats.evictions}, "
          f"bytes evicted: {cache.stats.bytes_evicted/GiB:.0f} GiB "
          f"-- and capacity recovered to "
          f"{cache.capacity()/GiB:.0f} GiB after the burst")


if __name__ == "__main__":
    main()
