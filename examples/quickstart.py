"""Quickstart: the DynIMS control loop in ~40 lines.

A 125 GB node runs a compute job with a memory burst while an in-memory
store (here: a byte cache standing in for Alluxio / a dataset cache /
a KV pool) opportunistically uses the slack.  The whole pipeline is
declared once -- a ``PlaneSpec`` naming the node, its monitor, and its
store -- and the ``MemoryPlane`` keeps utilization at the 95% threshold,
evicting within one 100 ms interval.

    PYTHONPATH=src python examples/quickstart.py
"""

from repro.core import (GiB, MemoryPlane, NodeSpec, PlaneSpec, ShardCache,
                        SimulatedMonitor, StoreSpec)
from repro.core.cluster_sim import paper_controller_params


class Blob:
    def __init__(self, nbytes):
        self.nbytes = nbytes


def main():
    # the opportunistic tenant: starts with all 60 GB of RAMdisk
    cache = ShardCache(capacity=60 * GiB)
    for shard in range(60):
        cache.put(shard, Blob(1 * GiB))

    # the priority tenant: 20 GB baseline with a burst to 95 GB
    compute = [20 * GiB] * 10 + [95 * GiB] * 15 + [20 * GiB] * 25

    # declare the plane: Table I law + one node (monitor + one store)
    plane = MemoryPlane(PlaneSpec(
        params=paper_controller_params(),
        nodes=(NodeSpec(
            "node0",
            monitor=SimulatedMonitor("node0", total=125 * GiB,
                                     usage=compute,
                                     storage_used_fn=cache.used),
            stores=(StoreSpec(cache, max_bytes=60 * GiB),)),),
    ))

    print(f"{'interval':>8} {'compute':>9} {'cache cap':>10} "
          f"{'cache used':>10} {'util':>6}")
    for i in range(len(compute)):
        plane.tick()
        util = (compute[i] + cache.used()) / (125 * GiB)
        print(f"{i:8d} {compute[i]/GiB:8.0f}G {cache.capacity()/GiB:9.1f}G "
              f"{cache.used()/GiB:9.1f}G {util:6.1%}")
    print(f"\nevictions: {cache.stats.evictions}, "
          f"bytes evicted: {cache.stats.bytes_evicted/GiB:.0f} GiB "
          f"-- and capacity recovered to "
          f"{cache.capacity()/GiB:.0f} GiB after the burst")
    last = plane.actions(node="node0", limit=1)[0]
    print(f"last action: u {last.u_prev/GiB:.1f}G -> {last.u_next/GiB:.1f}G "
          f"at {last.utilization:.0%} utilization "
          f"({len(plane.actions())} retained, bounded history)")


if __name__ == "__main__":
    main()
