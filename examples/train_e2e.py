"""End-to-end training driver: a ~100M-parameter model, few hundred steps.

    PYTHONPATH=src python examples/train_e2e.py --steps 300

Runs the full stack on CPU: synthetic tokenized corpus -> DynIMS-managed
shard cache -> microbatched AdamW train step -> checkpoints -> restart
check.  The default config is xlstm-125m reduced in depth only (125M ->
~94M params) so a few hundred steps fit CPU budgets; --full-125m uses
the exact assigned config.
"""

import argparse
import dataclasses
import os
import tempfile
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.configs.dynims import host_cache_params
from repro.core import GiB, MemoryPlane, PlaneSpec
from repro.data import DataPipeline, PipelineConfig, ShardStore, write_corpus
from repro.models import Model, count_params
from repro.train import Trainer, TrainerConfig, TrainStepConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch-size", type=int, default=4)
    ap.add_argument("--seq-len", type=int, default=96)
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--full-size", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if not args.full_size:
        # ~100M-parameter variant of the same family
        cfg = dataclasses.replace(
            cfg, name=cfg.name + "-100m", n_layers=6, d_model=512,
            n_heads=8, n_kv_heads=4, head_dim=64, d_ff=2048,
            vocab_size=50304)
    model = Model(cfg, remat="full", attn_impl="dense")
    params = model.init(jax.random.key(0))
    n = count_params(model.schema())
    print(f"arch={cfg.name} params={n/1e6:.1f}M")

    tmp = tempfile.mkdtemp(prefix="repro-e2e-")
    corpus = os.path.join(tmp, "corpus")
    write_corpus(corpus, n_shards=16, tokens_per_shard=65536,
                 vocab_size=cfg.vocab_size)
    plane = MemoryPlane(PlaneSpec(params=host_cache_params(32 * GiB)))
    pipe = DataPipeline(
        ShardStore(corpus),
        PipelineConfig(batch_size=args.batch_size, seq_len=args.seq_len,
                       cache_bytes=64 << 20),
        plane=plane)
    trainer = Trainer(
        model, pipe,
        TrainStepConfig(microbatches=2, peak_lr=6e-4,
                        warmup_steps=max(args.steps // 20, 1),
                        total_steps=args.steps),
        TrainerConfig(steps=args.steps, checkpoint_every=args.steps // 2,
                      checkpoint_dir=os.path.join(tmp, "ckpt"),
                      log_every=max(args.steps // 20, 1)),
        plane=plane)
    t0 = time.time()
    trainer.fit(params)
    dt = time.time() - t0
    first, last = trainer.metrics_log[0], trainer.metrics_log[-1]
    print(f"\n{args.steps} steps in {dt:.0f}s "
          f"({args.steps * args.batch_size * args.seq_len / dt:.0f} tok/s)")
    print(f"loss: {first['loss']:.3f} -> {last['loss']:.3f}")
    print(f"dataset-cache hit ratio: {pipe.hit_ratio:.1%} "
          f"(DynIMS-managed)")
    assert last["loss"] < first["loss"], "training must reduce loss"
    pipe.close()


if __name__ == "__main__":
    main()
