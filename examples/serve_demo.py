"""Serving demo: continuous batching with a DynIMS-managed KV pool.

A small llama-family model serves a queue of requests while a
``MemoryPlane`` arbitrates the device-memory budget between the compute
tenant (a simulated co-located job with a mid-run burst) and the KV
block pool.  When the burst drives utilization past the threshold the
controller shrinks the pool within one interval, sequences are
preempted and transparently requeued, and service completes after the
controller re-grants capacity -- the paper's eviction/recovery
behaviour, end-to-end on the serving path.

    PYTHONPATH=src python examples/serve_demo.py
"""

import jax
import numpy as np

from repro.configs import get_config
from repro.configs.dynims import hbm_pool_params
from repro.core import (KVBlockPool, MemoryPlane, PlaneSpec,
                        SimulatedMonitor)
from repro.models import Model
from repro.serving import ServingConfig, ServingEngine


def main():
    cfg = get_config("llama3.2-1b-smoke")
    model = Model(cfg, remat="none")
    params = model.init(jax.random.key(0))
    sc = ServingConfig(max_batch=3, max_len=96, block_tokens=8)

    # Size the contended "HBM" so the pool is half of it: a compute
    # burst to ~0.9*M forces the controller to reclaim pool capacity.
    kv_bytes = (sc.block_tokens * 2 * cfg.n_kv_heads * cfg.head_dim * 2
                * cfg.n_layers)
    n_blocks = sc.max_batch * (sc.max_len // sc.block_tokens)
    hbm = 2.0 * n_blocks * kv_bytes
    pool = KVBlockPool("kv-pool", n_blocks, kv_bytes)

    # the co-located compute tenant: quiet, a burst over ticks 12-24, quiet
    def compute_usage(i):
        return 0.90 * hbm if 12 <= i < 24 else 0.05 * hbm

    plane = MemoryPlane(PlaneSpec(params=hbm_pool_params(hbm)))
    engine = ServingEngine(
        model, params, sc, pool=pool, plane=plane, node="serve0",
        monitor=SimulatedMonitor("serve0", total=hbm, usage=compute_usage,
                                 storage_used_fn=pool.used))
    rng = np.random.default_rng(0)
    for i in range(8):
        engine.submit(rng.integers(0, cfg.vocab_size, 10), 12)
    print(f"submitted 8 requests; pool = {pool.total_blocks} blocks, "
          f"plane manages {hbm/2**20:.1f} MiB of device memory")

    for step in range(12):
        engine.step()
    print("quiet phase:", engine.stats())

    print("\n-- co-located burst: the controller reclaims pool blocks --")
    for step in range(12):
        engine.step()
    print("during burst:", engine.stats())

    print("\n-- burst over: the controller re-grants within intervals --")
    finished = engine.run_until_drained()
    st = engine.stats()
    print("drained:", st)
    assert len(finished) == 8
    print(f"\nall 8 requests completed; {st['preemptions']} preemption(s) "
          "were absorbed transparently (progress preserved)")
    for a in plane.actions(node="serve0", limit=3):
        print(f"  action: u {a.u_prev/2**20:6.1f}M -> {a.u_next/2**20:6.1f}M"
              f"  (util {a.utilization:.0%})")


if __name__ == "__main__":
    main()
