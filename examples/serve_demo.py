"""Serving demo: continuous batching with a DynIMS-managed KV pool.

A small llama-family model serves a queue of requests; mid-run the KV
pool is squeezed (simulating a device-memory burst from a co-located
job), sequences are preempted and transparently requeued, and service
completes after the pool recovers -- the paper's eviction/recovery
behaviour on the serving path.

    PYTHONPATH=src python examples/serve_demo.py
"""

import jax
import numpy as np

from repro.configs import get_config
from repro.models import Model
from repro.serving import ServingConfig, ServingEngine


def main():
    cfg = get_config("llama3.2-1b-smoke")
    model = Model(cfg, remat="none")
    params = model.init(jax.random.key(0))
    engine = ServingEngine(model, params,
                           ServingConfig(max_batch=3, max_len=96,
                                         block_tokens=8))
    rng = np.random.default_rng(0)
    for i in range(8):
        engine.submit(rng.integers(0, cfg.vocab_size, 10), 12)
    print(f"submitted 8 requests; pool = {engine.pool.total_blocks} blocks")

    for step in range(12):
        engine.step()
    print("mid-run:", engine.stats())

    print("\n-- memory burst: KV pool shrunk to 3 blocks --")
    engine.pool.set_capacity(engine.pool.block_bytes * 3)
    for step in range(6):
        engine.step()
    print("during burst:", engine.stats())

    print("\n-- burst over: pool restored --")
    engine.pool.set_capacity(engine.pool.total_blocks
                             * engine.pool.block_bytes)
    finished = engine.run_until_drained()
    st = engine.stats()
    print("drained:", st)
    assert len(finished) == 8
    print(f"\nall 8 requests completed; {st['preemptions']} preemption(s) "
          "were absorbed transparently (progress preserved)")


if __name__ == "__main__":
    main()
