"""ScenarioLab end to end: sweep a gain grid, deploy the winner.

Tunes the DynIMS gains for one named scenario -- thousands of closed
loops (gain grid x fleet x horizon) compiled into one scanned/vmapped
device-resident program -- prints the leaderboard against the paper's
Table I defaults, then attaches the tuned ``ControllerParams`` to a
live ``MemoryPlane`` and replays a burst through it.

    PYTHONPATH=src python examples/tune_gains.py [scenario] [--budget N]
    PYTHONPATH=src python examples/tune_gains.py --method halving ...
    PYTHONPATH=src python examples/tune_gains.py --all   # retune presets
    PYTHONPATH=src python examples/tune_gains.py \
        --portfolio swap-storm bursty-serving   # worst-case tuning
    PYTHONPATH=src python examples/tune_gains.py \
        spark-iterative-cache --objective runtime   # CacheLoop: tune for
                                                    # modeled app runtime
    PYTHONPATH=src python examples/tune_gains.py --check-presets
        # preset-drift gate: regenerate every LAB_TUNED preset on its
        # tuning grid and exit 1 with a diff if configs/dynims.py is
        # stale relative to the tuning code (CI runs this)
    PYTHONPATH=src python examples/tune_gains.py --engine pallas ...
        # any of the above on PR 9's fused PallasSweep engine; presets
        # must regenerate identically on either engine
"""

import argparse
import sys

from repro.configs.dynims import (LAB_TUNED, LAB_TUNED_OBJECTIVES,
                                  tuned_scenarios)
from repro.core import (GiB, MemoryPlane, NodeSpec, PlaneSpec, ShardCache,
                        SimulatedMonitor, StoreSpec)
from repro.lab import (OBJECTIVES, get_scenario, list_scenarios, tune_gains,
                       tune_portfolio)


def tune_one(name: str, budget: int, method: str = "grid",
             objective: str = "default", engine: str = "xla"):
    spec = get_scenario(name)
    print(f"== {name}: {spec.description or spec.family}")
    print(f"   fleet={spec.n_nodes} nodes x {spec.n_intervals} intervals, "
          f"~{budget}+1 gain candidates, method={method}, "
          f"objective={objective}, engine={engine}")
    result = tune_gains(name, budget=budget, method=method,
                        objective=objective, engine=engine)
    if result.rounds:
        sched = " -> ".join(f"{r['n_candidates']}@T={r['horizon']}"
                            for r in result.rounds)
        print(f"   halving schedule: {sched}")
    print(result.summary())
    print()
    return result


def deploy(result) -> None:
    """Drive one burst through a MemoryPlane running the tuned gains."""
    p = result.params
    cache = ShardCache(capacity=p.u_max)
    for shard in range(int(p.u_max / GiB)):
        cache.put(shard, type("Blob", (), {"nbytes": 1 * GiB})())
    compute = [30 * GiB] * 6 + [95 * GiB] * 10 + [30 * GiB] * 14
    plane = MemoryPlane(PlaneSpec(
        params=p,
        nodes=(NodeSpec(
            "node0",
            monitor=SimulatedMonitor("node0", total=p.total_memory,
                                     usage=compute,
                                     storage_used_fn=cache.used),
            stores=(StoreSpec(cache, max_bytes=p.u_max),)),),
    ))
    print("deploying tuned gains on a MemoryPlane (30G base, 95G burst):")
    for i in range(len(compute)):
        a = plane.tick()[0]
        print(f"  t={i * p.interval_s:5.2f}s  util={a.utilization:5.2f}"
              f"  grant={a.u_next / GiB:6.1f} GiB"
              f"  store={cache.used() / GiB:6.1f} GiB")


_GAIN_FIELDS = ("r0", "lam", "lam_grant", "u_min", "u_max", "deadband",
                "feedforward")


def check_presets(budget: int, engine: str = "xla") -> int:
    """Preset-drift gate: are the checked-in LAB_TUNED presets what the
    tuning code produces today?

    Regenerates every preset on the default grid at ``budget`` (the
    grid the presets were derived from) under its recorded objective
    and diffs the winner against ``configs/dynims.py``.  A nonzero
    exit means the presets are stale -- rerun ``--all`` and commit the
    new values (with the finding that changed them).  ``engine=
    "pallas"`` must reproduce the same presets byte for byte (the
    grid's final ranking is computed host-side either way).
    """
    stale = []
    for name in tuned_scenarios():
        objective = LAB_TUNED_OBJECTIVES.get(name, "default")
        result = tune_gains(name, budget=budget, objective=objective,
                            engine=engine)
        preset = LAB_TUNED[name]
        diffs = [(f, getattr(preset, f), getattr(result.params, f))
                 for f in _GAIN_FIELDS
                 if getattr(preset, f) != getattr(result.params, f)]
        print(f"{name} [{objective}]: "
              f"{'STALE' if diffs else 'ok'} "
              f"(regenerated score {result.score:.3f})")
        for field, have, want in diffs:
            print(f"   {field}: preset {have!r} != regenerated {want!r}")
        if diffs:
            stale.append(name)
    if stale:
        print(f"\npreset drift in {len(stale)} scenario(s): "
              f"{', '.join(stale)}")
        print("regenerate with: python examples/tune_gains.py --all "
              f"--budget {budget}")
        return 1
    print(f"\nall {len(tuned_scenarios())} LAB_TUNED presets regenerate "
          "identically")
    return 0


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("scenario", nargs="?", default="bursty-serving",
                    choices=list_scenarios())
    # 100 -> the default grid the checked-in LAB_TUNED presets came
    # from (a paper-law 9x9 lam x r0 plane + the three beyond-paper law
    # variants); --all with the default budget reproduces them exactly.
    ap.add_argument("--budget", type=int, default=100)
    ap.add_argument("--method", default="grid",
                    choices=("grid", "random", "halving"))
    ap.add_argument("--objective", default="default",
                    choices=sorted(OBJECTIVES),
                    help="'runtime' optimizes CacheLoop's modeled app "
                         "runtime (cache-enabled scenarios)")
    ap.add_argument("--all", action="store_true",
                    help="retune every checked-in preset scenario")
    ap.add_argument("--check-presets", action="store_true",
                    help="preset-drift gate: regenerate every LAB_TUNED "
                         "preset and exit 1 with a diff if configs/"
                         "dynims.py is stale (CI runs this)")
    ap.add_argument("--portfolio", nargs="+", metavar="SCENARIO",
                    help="worst-case tune one gain set across these "
                         "scenarios instead of single-scenario tuning")
    ap.add_argument("--engine", default="xla", choices=("xla", "pallas"),
                    help="sweep engine: the default XLA scan or PR 9's "
                         "fused PallasSweep kernel")
    args = ap.parse_args()

    if args.check_presets:
        sys.exit(check_presets(args.budget, args.engine))
    if args.portfolio:
        result = tune_portfolio(args.portfolio, budget=args.budget,
                                aggregate="worst", objective=args.objective,
                                engine=args.engine)
        print(f"== portfolio (worst-case over {', '.join(args.portfolio)})")
        for name, s in result.scenario_scores.items():
            print(f"   {name}: winner scores {s:.3f}")
        print(f"   tuned (r0={result.params.r0:.4f}, "
              f"lam={result.params.lam:.4f}) aggregate={result.score:.3f} "
              f"baseline={result.baseline_score:.3f} "
              f"(+{result.improvement:.3f})")
        return
    if args.all:
        for name in tuned_scenarios():
            objective = LAB_TUNED_OBJECTIVES.get(name, "default")
            r = tune_one(name, args.budget, args.method, objective,
                         args.engine)
            knobs = [f"r0={r.params.r0:.4f}", f"lam={r.params.lam:.4f}"]
            if r.params.lam_grant is not None:
                knobs.append(f"lam_grant={r.params.lam_grant:.4f}")
            if r.params.deadband:
                knobs.append(f"deadband={r.params.deadband:.4f}")
            if r.params.feedforward:
                knobs.append(f"feedforward={r.params.feedforward:.4f}")
            print(f"   preset: LAB_TUNED[{name!r}] = PAPER_TABLE_I.replace("
                  f"{', '.join(knobs)})\n")
        return
    result = tune_one(args.scenario, args.budget, args.method,
                      args.objective, args.engine)
    deploy(result)


if __name__ == "__main__":
    main()
