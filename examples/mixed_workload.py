"""The paper's headline experiment (Sec. IV): mixed HPCC + Spark K-means
on 5 compute nodes, four memory configurations, DynIMS vs static.

    PYTHONPATH=src python examples/mixed_workload.py

Prints the Fig. 5/7/8 numbers: speedups, hit ratios, and the burst
shrink-and-recover timeline.
"""

import numpy as np

from repro.core.cluster_sim import run_paper_experiment

NAMES = {
    1: "Spark(45GB), no cache      (static)",
    2: "Spark(20GB)/Alluxio(25GB)  (static)",
    3: "Spark(20GB)/DynIMS(60GB)   (dynamic)",
    4: "Spark(20GB)/Alluxio(60GB)  (no HPCC; upper bound)",
}


def main():
    print("simulating 4 configurations x (HPCC + K-means 320 GiB)...")
    res = run_paper_experiment()
    print(f"\n{'configuration':45s} {'runtime':>9} {'hit':>6} {'disk':>8}")
    for c in (1, 2, 3, 4):
        r = res[c]
        print(f"{NAMES[c]:45s} {r.app_runtime_s:8.0f}s "
              f"{r.hit_ratio:5.1%} {r.disk_reads_gib:6.0f}GiB")
    d = res
    print(f"\nDynIMS speedup vs config 1: "
          f"{d[1].app_runtime_s/d[3].app_runtime_s:.1f}x  (paper: 5.1x)")
    print(f"DynIMS speedup vs config 2: "
          f"{d[2].app_runtime_s/d[3].app_runtime_s:.1f}x  (paper: 3.8x)")
    print(f"DynIMS vs upper bound:      "
          f"{d[3].app_runtime_s/d[4].app_runtime_s:.2f}x  (paper: comparable)")

    r = d[3]
    print("\nFig. 7 -- storage capacity timeline under the HPCC bursts:")
    t = r.t_s
    for frac in np.linspace(0, 0.999, 12):
        i = int(frac * (len(t) - 1))
        bar = "#" * int(r.cap_gib[i] / 2)
        print(f"  t={t[i]:6.0f}s cap={r.cap_gib[i]:5.1f}G "
              f"exec={r.exec_gib[i]:5.1f}G |{bar}")
    print("\nFig. 8 -- K-means iteration times (DynIMS):",
          [f"{x:.0f}" for x in r.iteration_times_s])


if __name__ == "__main__":
    main()
