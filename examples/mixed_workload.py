"""The paper's Sec. IV mix driven through FleetPlane: an HPCC-style
compute tenant and a Spark-style storage tenant arbitrated over one
5-node / 125 GB fleet, per-tenant budgets re-granted every epoch.

    PYTHONPATH=src python examples/mixed_workload.py

Prints per-tenant budgets and fleet utilization per arbitration epoch
(the two-level analogue of the Fig. 7 capacity timeline), then the
classic four-configuration comparison (Figs. 5/7/8) from the cluster
simulator for reference.
"""

import numpy as np

from repro.core.cluster_sim import run_paper_experiment
from repro.core.control import ControllerParams
from repro.core.monitor import SimulatedMonitor
from repro.core.plane import NodeSpec, PlaneSpec
from repro.core.traces import GiB, hpcc_trace
from repro.fleet import FleetPlane, FleetSpec, TenantSpec

N_NODES = 5
M = 125.0 * GiB
INTERVAL_S = 0.1
EPOCH_INTERVALS = 20            # re-arbitrate every 2 s
N_EPOCHS = 12

NAMES = {
    1: "Spark(45GB), no cache      (static)",
    2: "Spark(20GB)/Alluxio(25GB)  (static)",
    3: "Spark(20GB)/DynIMS(60GB)   (dynamic)",
    4: "Spark(20GB)/Alluxio(60GB)  (no HPCC; upper bound)",
}


def build_fleet() -> FleetSpec:
    """Sec. IV as two tenants: bursty HPCC compute + steady Spark."""
    horizon = N_EPOCHS * EPOCH_INTERVALS
    hpcc = hpcc_trace(horizon * INTERVAL_S, INTERVAL_S, seed=0)
    hpcc = np.tile(hpcc, -(-horizon // len(hpcc)))[:horizon]
    rng = np.random.default_rng(1)
    spark = (30.0 + 2.0 * rng.standard_normal(horizon)).clip(20.0)

    def nodes(tag, trace_gib):
        return tuple(
            NodeSpec(f"node{i}", monitor=SimulatedMonitor(
                f"node{i}", total=M,
                usage=lambda t, tr=trace_gib, i=i:
                    float(tr[min(t, len(tr) - 1)]) * GiB
                    * (0.9 + 0.05 * i)))
            for i in range(N_NODES))

    return FleetSpec(
        tenants=(
            TenantSpec("hpcc", PlaneSpec(
                params=ControllerParams(total_memory=M, u_max=60 * GiB,
                                        interval_s=INTERVAL_S),
                nodes=nodes("hpcc", hpcc / GiB)),
                weight=3.0, priority=1, floor_gib=10.0),
            TenantSpec("spark", PlaneSpec(
                params=ControllerParams(total_memory=M, u_max=60 * GiB,
                                        interval_s=INTERVAL_S),
                nodes=nodes("spark", spark)),
                weight=1.0, priority=0, floor_gib=22.0),
        ),
        policy="proportional", epoch_intervals=EPOCH_INTERVALS,
        fleet_memory_gib=M / GiB)


def drive_fleet() -> None:
    fleet = FleetPlane(build_fleet())
    b0 = fleet.budgets()
    print("FleetPlane: HPCC + Spark over "
          f"{N_NODES} nodes x {M / GiB:.0f} GB, "
          f"{fleet.spec.policy} policy, epoch = "
          f"{EPOCH_INTERVALS * INTERVAL_S:.0f}s")
    print(f"\n{'epoch':>5} {'hpcc':>9} {'spark':>9} {'sum':>9} "
          f"{'fleet util':>11}")
    print(f"{'init':>5} {b0['hpcc'] / GiB:8.1f}G {b0['spark'] / GiB:8.1f}G "
          f"{sum(b0.values()) / GiB:8.1f}G {'':>11}")
    for _ in range(N_EPOCHS):
        for _ in range(EPOCH_INTERVALS):
            fleet.tick()
        b = fleet.budgets()
        util = fleet.fleet_utilization()
        print(f"{fleet.epoch:5d} {b['hpcc'] / GiB:8.1f}G "
              f"{b['spark'] / GiB:8.1f}G {sum(b.values()) / GiB:8.1f}G "
              f"{util:10.1%}")
    total = sum(fleet.budgets().values())
    print(f"\nbudget conservation held: sum = {total / GiB:.1f}G "
          f"<= M = {M / GiB:.0f}G")


def paper_comparison() -> None:
    print("\nsimulating 4 configurations x (HPCC + K-means 320 GiB)...")
    res = run_paper_experiment()
    print(f"\n{'configuration':45s} {'runtime':>9} {'hit':>6} {'disk':>8}")
    for c in (1, 2, 3, 4):
        r = res[c]
        print(f"{NAMES[c]:45s} {r.app_runtime_s:8.0f}s "
              f"{r.hit_ratio:5.1%} {r.disk_reads_gib:6.0f}GiB")
    d = res
    print(f"\nDynIMS speedup vs config 1: "
          f"{d[1].app_runtime_s / d[3].app_runtime_s:.1f}x  (paper: 5.1x)")
    print(f"DynIMS speedup vs config 2: "
          f"{d[2].app_runtime_s / d[3].app_runtime_s:.1f}x  (paper: 3.8x)")
    print(f"DynIMS vs upper bound:      "
          f"{d[3].app_runtime_s / d[4].app_runtime_s:.2f}x  "
          "(paper: comparable)")

    r = d[3]
    print("\nFig. 7 -- storage capacity timeline under the HPCC bursts:")
    t = r.t_s
    for frac in np.linspace(0, 0.999, 12):
        i = int(frac * (len(t) - 1))
        bar = "#" * int(r.cap_gib[i] / 2)
        print(f"  t={t[i]:6.0f}s cap={r.cap_gib[i]:5.1f}G "
              f"exec={r.exec_gib[i]:5.1f}G |{bar}")
    print("\nFig. 8 -- K-means iteration times (DynIMS):",
          [f"{x:.0f}" for x in r.iteration_times_s])


def main():
    drive_fleet()
    paper_comparison()


if __name__ == "__main__":
    main()
