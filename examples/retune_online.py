"""ReplayLoop end to end: capture -> replay -> re-tune -> hot-swap.

A ``MemoryPlane`` runs the swap-storm workload on paper Table I gains
while recording its own telemetry; the capture becomes a ``"replay"``
scenario, ``retune_online`` searches gains on it (successive halving
over the sweep engine) in the background *while the plane keeps
ticking*, and the winner is hot-swapped into the live plane at an
interval boundary.  The script then audits the swap through the
epoch-stamped action history: every node took exactly one action per
control interval -- nothing dropped, nothing duplicated -- and the
epochs are monotone.

    PYTHONPATH=src python examples/retune_online.py [--smoke]
    PYTHONPATH=src python examples/retune_online.py --out-dir artifacts

Exit status is nonzero if any ReplayLoop guarantee fails, so CI can
gate on it (the ``retune-smoke`` job); ``--out-dir`` writes the
captured ``.npz`` and the tuned params as artifacts.
"""

import argparse
import dataclasses
import json
import os
import sys
import time

import numpy as np

from repro.configs.dynims import PAPER_TABLE_I
from repro.core import MemoryPlane, PlaneSpec, SimulatedMonitor
from repro.core.store import StoreRegistry
from repro.lab import (GainSet, ScenarioSpec, get_scenario, retune_online,
                       run_sweep)

# Fleet p99 utilization: |replayed - observed| tolerance.  The plane
# runs float32 fused updates against the sweep's float32 scan; the
# streaming quantile adds ~5e-4 worst case.
P99_TOL = 0.02


def build_recording_plane(demand: np.ndarray, node_memory: np.ndarray,
                          params, capture_intervals: int) -> MemoryPlane:
    """A plane driving the scenario demand through saturated stores.

    Each monitor reports ``demand + grant`` (the storage tenant keeps
    its grant full -- the sweep engine's saturated-store model), so the
    capture's demand column is exactly the scenario demand and the
    closed loop the plane runs is the closed loop a replay sweeps.
    """
    plane = MemoryPlane(PlaneSpec(params=params, backend="array",
                                  record=capture_intervals))
    t = demand.shape[1]
    for i in range(demand.shape[0]):
        name = f"node{i}"
        plane.attach(
            name,
            SimulatedMonitor(
                name, total=float(node_memory[i]),
                # loop the workload so the plane can tick forever
                usage=lambda k, row=demand[i]: float(row[k % t]),
                storage_used_fn=lambda nm=name: plane.capacity(nm)),
            registry=StoreRegistry(),
            u0=params.u_max)
    return plane


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run: 8 nodes, short horizon, small grid")
    ap.add_argument("--out-dir", default=None,
                    help="write capture.npz + tuned_params.json here")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    n_nodes, horizon, budget = (8, 240, 16) if args.smoke else (32, 600, 48)
    spec = get_scenario("swap-storm").replace(n_nodes=n_nodes,
                                              n_intervals=horizon)
    demand = spec.build_demand(seed=args.seed)
    node_memory = spec.build_node_memory(seed=args.seed)
    baseline = PAPER_TABLE_I
    post_ticks = max(horizon // 4, 32)
    plane = build_recording_plane(demand, node_memory, baseline,
                                  capture_intervals=horizon)
    # The no-drop audit counts the actions tick() hands back, so it is
    # exact however many intervals phase 3 ends up running (the
    # retained ActionHistory stays at its default bound).
    audit = []
    n_ticks = [0]

    def tick() -> None:
        audit.extend(plane.tick())
        n_ticks[0] += 1

    print(f"== phase 1: run swap-storm on Table I gains, recording "
          f"({n_nodes} nodes x {horizon} intervals)")
    for _ in range(horizon):
        tick()
    capture = plane.capture()
    observed_p99 = capture.utilization_p99()
    print(f"   captured {capture.n_nodes} x {capture.n_intervals}, "
          f"observed p99 utilization {observed_p99:.4f}")

    print("== phase 2: replay fidelity -- the captured trace swept at the "
          "deployed gains must reproduce the observed loop")
    replay = ScenarioSpec.from_capture(capture, name="swap-storm-replay")
    fidelity = run_sweep(replay, GainSet.from_params(baseline),
                         seed=args.seed)
    replayed_p99 = float(fidelity.stats.p99_utilization[0])
    p99_err = abs(replayed_p99 - observed_p99)
    print(f"   replayed p99 {replayed_p99:.4f} (|err| {p99_err:.4f}, "
          f"tol {P99_TOL})")

    print(f"== phase 3: retune_online (halving, budget {budget}) while the "
          "plane keeps ticking")
    handle = retune_online(plane, name="swap-storm-replay", method="halving",
                           budget=budget, seed=args.seed, block=False)
    while not handle.done:
        tick()                       # live traffic during the search
        time.sleep(0.01)             # leave the CPU to the tuning sweep
    result = handle.result()
    print("  ", result.summary())

    print("== phase 4: serve more intervals under the new epoch, then "
          "audit the action history")
    for _ in range(post_ticks):
        tick()

    ticks = n_ticks[0]
    failures = []
    if not result.tune.score >= result.tune.baseline_score:
        failures.append("tuned score fell below the deployed baseline")
    if not result.swapped:
        failures.append("retune round did not hot-swap (no improvement "
                        "found on the replayed workload)")
    elif plane.epoch != result.epoch or plane.params != result.params:
        failures.append("plane is not running the swapped params")
    for i in range(n_nodes):
        actions = [a for a in audit if a.node == f"node{i}"]
        epochs = [a.epoch for a in actions]
        if len(actions) != ticks:
            failures.append(f"node{i}: {len(actions)} actions for {ticks} "
                            "ticks (dropped or duplicated interval)")
        if any(b < a for a, b in zip(epochs, epochs[1:])):
            failures.append(f"node{i}: epochs not monotone")
        if result.swapped and (0 not in epochs or result.epoch not in epochs):
            failures.append(f"node{i}: history does not span the swap")
    if p99_err > P99_TOL:
        failures.append(f"replay p99 off by {p99_err:.4f} > {P99_TOL}")

    if args.out_dir:
        os.makedirs(args.out_dir, exist_ok=True)
        capture.save(os.path.join(args.out_dir, "capture.npz"))
        with open(os.path.join(args.out_dir, "tuned_params.json"), "w") as fh:
            json.dump({
                "scenario": result.scenario.name,
                "swapped": result.swapped,
                "epoch": result.epoch,
                "score": result.tune.score,
                "baseline_score": result.tune.baseline_score,
                "observed_p99": observed_p99,
                "replayed_p99": replayed_p99,
                "old_params": dataclasses.asdict(result.old_params),
                "tuned_params": dataclasses.asdict(result.params),
            }, fh, indent=2)
        print(f"   artifacts in {args.out_dir}/")

    if failures:
        print("FAILED:")
        for f in failures:
            print("  -", f)
        return 1
    print(f"OK: ReplayLoop round-trip held every guarantee "
          f"({ticks} intervals, epoch {plane.epoch})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
