"""ChaosPlane drill: every fault family thrown at live planes.

Phase 1 runs a recording ``MemoryPlane`` (array backend) through a
seed-deterministic :class:`~repro.runtime.chaos.ChaosSpec` covering the
full fault catalog -- sensor dropout/freeze/NaN/Inf/negative, slow
samples, node crash+rejoin, actuation raise/timeout/partial-apply, and
a ``retune-kill`` that murders the supervised online-retune round --
then audits the degradation contract:

* no grant ever exceeds ``u_max`` (or goes below ``u_min``), faulted
  telemetry or not;
* every published control action is finite -- NaN/Inf telemetry never
  reaches the law;
* per-node action epochs stay monotone through the storm;
* crashed nodes quarantine (fail-static pin) and rejoin within the
  hysteresis window once the chaos lifts;
* the supervised retune round restarts after being killed and still
  lands (or cleanly reports dead);
* the bounded FaultLog tells the whole story (written as an artifact).

Phase 2 nests the same storm one level up: a ``FleetPlane`` whose
"victim" tenant loses every node.  The victim must be quarantined at
the next arbitration epoch and squeezed to its floor (fail-static at
fleet level), the sum of live budgets must conserve at *every* tick,
and the victim must rejoin and win budget back after recovery.

    PYTHONPATH=src python examples/chaos_drill.py [--smoke] [--seed 0]
    PYTHONPATH=src python examples/chaos_drill.py --out-dir artifacts

Exit status is nonzero if any degradation guarantee fails, so CI can
gate on it (the ``chaos-smoke`` job); ``--out-dir`` writes the fault
logs and injected-fault counts as ``faultlog.json``.
"""

import argparse
import dataclasses
import json
import math
import os
import sys
import time

from repro.configs.dynims import PAPER_TABLE_I
from repro.core import (GiB, HealthPolicy, MemoryPlane, NodeHealth,
                        PlaneSpec, SimulatedMonitor, StoreRegistry)
from repro.core.control import ControllerParams
from repro.core.plane import NodeSpec
from repro.fleet import FleetPlane, FleetSpec, TenantSpec
from repro.lab import retune_online
from repro.runtime import ChaosSpec, FaultSpec, inject

M = 125.0 * GiB
EPS = 1.0          # byte-scale tolerance on grant bounds


def build_plane(n_nodes: int, params, policy: HealthPolicy,
                record: int) -> MemoryPlane:
    """A recording plane with gently varying synthetic demand."""
    plane = MemoryPlane(PlaneSpec(params=params, backend="array",
                                  health=policy, record=record))
    for i in range(n_nodes):
        name = f"node{i}"
        plane.attach(
            name,
            SimulatedMonitor(
                name, total=M,
                usage=lambda k, ph=i: (70.0 + 20.0 * math.sin(
                    0.15 * k + 0.7 * ph)) * GiB,
                storage_used_fn=lambda nm=name: plane.capacity(nm)),
            registry=StoreRegistry(),
            u0=params.u_max)
    return plane


def chaos_schedule(n_nodes: int, start: int, span: int) -> ChaosSpec:
    """Every fault family, spread across the fleet inside one window."""
    node = lambda i: (f"node{i % n_nodes}",)
    half = span // 2
    return ChaosSpec(faults=(
        FaultSpec("dropout", nodes=node(0), start=start, duration=span,
                  probability=0.5),
        FaultSpec("freeze", nodes=node(1), start=start, duration=half),
        FaultSpec("slow-sample", nodes=node(1), start=start + half,
                  duration=4, magnitude=0.001),
        FaultSpec("nan", nodes=node(2), start=start, duration=half),
        FaultSpec("inf", nodes=node(2), start=start + half, duration=4),
        FaultSpec("negative", nodes=node(3), start=start, duration=6),
        FaultSpec("crash", nodes=node(4), start=start, duration=span),
        FaultSpec("actuate-raise", nodes=node(5), start=start,
                  duration=half),
        FaultSpec("actuate-timeout", nodes=node(5), start=start + half,
                  duration=3, magnitude=0.0),
        FaultSpec("actuate-partial", nodes=node(3), start=start + 8,
                  duration=6, magnitude=0.5),
        FaultSpec("retune-kill", start=start, duration=span),
    ), seed=0)


def audit_actions(audit, n_nodes, failures, leg):
    for i in range(n_nodes):
        acts = [a for a in audit if a.node == f"node{i}"]
        for a in acts:
            if not (math.isfinite(a.u_next) and math.isfinite(a.u_prev)):
                failures.append(f"{leg}: node{i} published a non-finite "
                                f"action (u_next={a.u_next})")
                break
        epochs = [a.epoch for a in acts]
        if any(b < a for a, b in zip(epochs, epochs[1:])):
            failures.append(f"{leg}: node{i} epochs not monotone")


def phase_memory_plane(args, failures):
    n_nodes = 6 if args.smoke else 16
    pre, span, recover = (8, 40, 40) if args.smoke else (20, 80, 60)
    params = PAPER_TABLE_I.replace(interval_s=0.01)
    policy = HealthPolicy(stale_budget=3, rejoin_intervals=4,
                          actuation_retries=3, retry_backoff_cap=8,
                          fault_log=2048, seed=args.seed)
    plane = build_plane(n_nodes, params, policy, record=pre + span + recover)
    spec = chaos_schedule(n_nodes, start=pre, span=span)
    audit = []
    saw_quarantine = False

    print(f"== phase 1: MemoryPlane under the full fault catalog "
          f"({n_nodes} nodes, {len(spec.faults)} fault specs, "
          f"window [{pre}, {pre + span}))")
    handle = None
    with inject(plane, spec) as chaos:
        for t in range(pre + span):
            actions = plane.tick()
            audit.extend(actions)
            for a in actions:
                if a.u_next > params.u_max + EPS or a.u_next > M + EPS:
                    failures.append(
                        f"plane: grant {a.u_next / GiB:.1f} GiB on "
                        f"{a.node} exceeds the cap at tick {t}")
            if t == pre + 2:
                # Supervised retune starts inside the retune-kill
                # window: the first attempt dies by construction.
                handle = retune_online(
                    plane, name="chaos-replay", method="random", budget=4,
                    seed=args.seed, block=False, swap=False,
                    restarts=8, restart_backoff_s=0.05)
            if plane.health().quarantined():
                saw_quarantine = True
        report = plane.health()
        print(f"   under chaos: {report.summary()}")
        print(f"   injected: {chaos.counts()}")
        if not saw_quarantine:
            failures.append("plane: crash fault never drove a node to "
                            "QUARANTINED")
    # Chaos reverted: the plane must heal within the hysteresis window
    # plus the actuation shield's worst-case backoff tail (a long
    # failure streak leaves up to ~2*cap skipped apply calls pending).
    deadline = (policy.stale_budget + policy.rejoin_intervals
                + 2 * policy.retry_backoff_cap + 4)
    for t in range(recover):
        audit.extend(plane.tick())
        report = plane.health()
        if not report.degraded():
            break
    healed_in = t + 1
    if report.degraded():
        failures.append(f"plane: still degraded {recover} ticks after the "
                        f"chaos lifted: {report.summary()}")
    elif healed_in > deadline:
        failures.append(f"plane: rejoin took {healed_in} ticks, "
                        f"hysteresis allows {deadline}")
    else:
        print(f"   recovered in {healed_in} ticks "
              f"(hysteresis allows {deadline})")
    audit_actions(audit, n_nodes, failures, "plane")

    # The retune supervisor must have restarted past the injected kill.
    while handle is not None and not handle.done:
        plane.tick()
        time.sleep(0.01)
    if handle is not None:
        if handle.restarts < 1:
            failures.append("retune: supervisor never restarted despite "
                            "the retune-kill fault")
        try:
            handle.result()
            print(f"   retune survived: {handle.attempts} attempts, "
                  f"{handle.restarts} restarts")
        except Exception as exc:
            failures.append(f"retune: dead after {handle.attempts} "
                            f"attempts: {exc}")
    counts = plane.fault_log.counts()
    for expected in ("sample-error", "telemetry-invalid", "quarantine",
                     "rejoin", "actuation-error", "retune-restart"):
        if counts.get(expected, 0) < 1:
            failures.append(f"plane: fault log missing {expected!r} "
                            f"events (got {sorted(counts)})")
    return plane, chaos, counts


def phase_fleet_plane(args, failures):
    n_nodes = 2
    epoch_intervals = 4
    pre, span, recover = (8, 24, 32) if args.smoke else (12, 40, 48)
    params = ControllerParams(total_memory=M, u_max=60.0 * GiB,
                              interval_s=0.01)
    policy = HealthPolicy(stale_budget=2, rejoin_intervals=3,
                          fault_log=1024, seed=args.seed)

    def tenant(name, usage_gib, **kw):
        nodes = tuple(
            NodeSpec(f"{name}-n{i}", monitor=SimulatedMonitor(
                f"{name}-n{i}", total=M,
                usage=lambda t, g=usage_gib: g * GiB))
            for i in range(n_nodes))
        return TenantSpec(name, PlaneSpec(params=params, nodes=nodes,
                                          health=policy), **kw)

    spec = FleetSpec(tenants=(
        tenant("victim", 40.0, weight=2.0, floor_gib=8.0),
        tenant("bystander", 30.0, weight=1.0, floor_gib=8.0),
    ), epoch_intervals=epoch_intervals)
    fleet = FleetPlane(spec)
    floor = max(8.0 * GiB, 1 << 20)
    chaos = ChaosSpec(faults=(
        FaultSpec("crash",
                  nodes=tuple(f"victim-n{i}" for i in range(n_nodes)),
                  start=pre, duration=span),
    ), seed=args.seed)

    print(f"== phase 2: FleetPlane with tenant 'victim' fully crashed "
          f"for ticks [{pre}, {pre + span})")
    victim_floored = False
    with fleet, inject(fleet.plane("victim"), chaos):
        for t in range(pre + span):
            fleet.tick()
            budgets = fleet.budgets()
            if sum(budgets.values()) > M + EPS:
                failures.append(f"fleet: budgets sum "
                                f"{sum(budgets.values()) / GiB:.1f} GiB > "
                                f"{M / GiB:.0f} GiB at tick {t}")
            if ("victim" in fleet.quarantined_tenants()
                    and budgets["victim"] <= floor + EPS):
                victim_floored = True
        if not victim_floored:
            failures.append("fleet: quarantined victim was never squeezed "
                            "to its floor")
        print(f"   mid-chaos budgets: "
              f"{ {k: round(v / GiB, 1) for k, v in fleet.budgets().items()} } "
              f"quarantined={fleet.quarantined_tenants()}")
        # Chaos lifts inside the context: the victim's nested plane must
        # rejoin and the next epochs must grow its budget back.
        for t in range(recover):
            fleet.tick()
        if fleet.quarantined_tenants():
            failures.append(f"fleet: {fleet.quarantined_tenants()} still "
                            f"quarantined {recover} ticks after recovery")
        if fleet.budgets()["victim"] <= floor + EPS:
            failures.append("fleet: victim budget never recovered above "
                            "its floor after rejoin")
        counts = fleet.fault_log.counts()
        for expected in ("tenant-quarantine", "tenant-rejoin"):
            if counts.get(expected, 0) < 1:
                failures.append(f"fleet: fault log missing {expected!r}")
        print(f"   post-recovery budgets: "
              f"{ {k: round(v / GiB, 1) for k, v in fleet.budgets().items()} }")
    return fleet, counts


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run: fewer nodes, shorter windows")
    ap.add_argument("--out-dir", default=None,
                    help="write faultlog.json here")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    failures = []
    plane, chaos, plane_counts = phase_memory_plane(args, failures)
    fleet, fleet_counts = phase_fleet_plane(args, failures)

    if args.out_dir:
        os.makedirs(args.out_dir, exist_ok=True)
        path = os.path.join(args.out_dir, "faultlog.json")
        with open(path, "w") as fh:
            json.dump({
                "seed": args.seed,
                "injected": chaos.counts(),
                "plane_fault_counts": plane_counts,
                "plane_events": [dataclasses.asdict(e)
                                 for e in plane.fault_log.snapshot()],
                "fleet_fault_counts": fleet_counts,
                "fleet_events": [dataclasses.asdict(e)
                                 for e in fleet.fault_log.snapshot()],
                "failures": failures,
            }, fh, indent=2)
        print(f"   artifact: {path}")

    if failures:
        print("FAILED:")
        for f in failures:
            print("  -", f)
        return 1
    print("OK: every degradation guarantee held under the full fault "
          "catalog (plane + fleet)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
