"""MetricAggregator (core/stream.py): window aggregates, slope, bus wiring."""

import numpy as np
import pytest

from repro.core.bus import MessageBus
from repro.core.monitor import MemorySample, SimulatedMonitor
from repro.core.stream import (AGG_TOPIC, AggregatedMetrics, MetricAggregator,
                               RAW_TOPIC)

GiB = float(2**30)


def sample(used, node="n0", i=0, total=125 * GiB, storage=0.0, swap=0.0):
    return MemorySample(node=node, timestamp=i * 0.1, used=used, total=total,
                        storage_used=storage, swap_used=swap)


def test_single_sample_aggregates():
    agg = MetricAggregator(window=4)
    a = agg.update(sample(10 * GiB))
    assert a.used_latest == a.used_mean == a.used_max == 10 * GiB
    assert a.used_ewma == 10 * GiB          # EWMA seeds at first sample
    assert a.slope_per_interval == 0.0      # no slope from one point
    assert a.n_samples == 1
    assert a.utilization == pytest.approx(10 / 125)


def test_window_mean_max_and_eviction():
    agg = MetricAggregator(window=3)
    for i, used in enumerate([10.0, 20.0, 30.0, 40.0]):
        a = agg.update(sample(used, i=i))
    # window holds the last 3: [20, 30, 40]
    assert a.used_latest == 40.0
    assert a.used_mean == pytest.approx(30.0)
    assert a.used_max == 40.0
    assert a.n_samples == 3


def test_ewma_recursion():
    alpha = 0.25
    agg = MetricAggregator(window=8, ewma_alpha=alpha)
    values = [10.0, 50.0, 30.0]
    expected = values[0]
    for i, used in enumerate(values):
        a = agg.update(sample(used, i=i))
        expected = alpha * used + (1 - alpha) * expected if i else values[0]
    assert a.used_ewma == pytest.approx(expected)


def test_slope_least_squares():
    agg = MetricAggregator(window=8)
    # exact ramp: slope == step
    for i in range(5):
        a = agg.update(sample(100.0 + 7.0 * i, i=i))
    assert a.slope_per_interval == pytest.approx(7.0)
    # flat tail pulls the fitted slope below the ramp's
    for i in range(5, 10):
        a = agg.update(sample(128.0, i=i))
    assert 0.0 <= a.slope_per_interval < 7.0
    # least squares on a noisy-but-linear window stays close
    rng = np.random.default_rng(0)
    agg2 = MetricAggregator(window=8)
    for i in range(8):
        a2 = agg2.update(sample(5.0 * i + float(rng.normal(0, 1e-3)), i=i))
    assert a2.slope_per_interval == pytest.approx(5.0, abs=1e-2)


def test_per_node_isolation():
    agg = MetricAggregator(window=4)
    agg.update(sample(10.0, node="a"))
    b = agg.update(sample(99.0, node="b"))
    a = agg.update(sample(20.0, node="a", i=1))
    assert a.used_mean == pytest.approx(15.0)
    assert b.used_mean == pytest.approx(99.0)
    assert agg.latest("a").used == 20.0
    assert agg.latest("b").used == 99.0
    assert agg.latest("missing") is None


def test_bus_raw_to_agg_pipeline():
    bus = MessageBus()
    MetricAggregator(window=4, bus=bus)
    got = []
    bus.subscribe(AGG_TOPIC, got.append)
    mon = SimulatedMonitor("n0", total=125 * GiB,
                           usage=[10 * GiB, 20 * GiB])
    bus.publish(RAW_TOPIC, mon.sample())
    bus.publish(RAW_TOPIC, mon.sample())
    assert len(got) == 2
    assert isinstance(got[-1], AggregatedMetrics)
    assert got[-1].node == "n0"
    assert got[-1].used_latest == 20 * GiB
    assert got[-1].used_max == 20 * GiB
    assert got[-1].n_samples == 2


def test_window_validation():
    with pytest.raises(ValueError):
        MetricAggregator(window=0)
