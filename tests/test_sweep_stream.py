"""Device-resident sweep engine: streaming quantile accuracy, chunk and
device invariance, successive halving, portfolio tuning.

The oracle here is an independent float64 numpy reimplementation of the
closed loop -- the engine's streamed statistics must match a dense
history it never materializes.
"""

import os
import subprocess
import sys

import numpy as np
import pytest

from repro.core.cluster_sim import paper_controller_params
from repro.lab import (FleetStats, GainSet, QUANT_BINS, QUANT_RANGE,
                       get_scenario, grid_gains, halving_tune,
                       quantile_from_codes, run_sweep, sweep_demand,
                       tune_gains, tune_portfolio, utilization_codes)

# Worst-case error of the streaming p99: 12-level bisection bracket
# (2^-13 of the QUANT_RANGE span) plus half a bin.  The satellite
# acceptance bound is 0.005; the implementation is ~10x tighter.
P99_TOL = 0.005


def oracle_utils(demand, m, params, occupancy=1.0):
    """Dense (T, N) utilization history from a float64 reference loop."""
    demand = np.asarray(demand, np.float64)
    m = np.broadcast_to(np.asarray(m, np.float64), (demand.shape[0],))
    n, t = demand.shape
    u = np.full(n, params.u_max, np.float64)
    v_prev = None
    utils = np.empty((t, n))
    for i in range(t):
        v = demand[:, i] + occupancy * u
        v_eff = v.copy()
        if params.feedforward > 0.0 and v_prev is not None:
            v_eff = v + params.feedforward * (v - v_prev)
        r = v_eff / m
        err = r - params.r0
        lam = np.where(
            err < 0,
            params.lam if params.lam_grant is None else params.lam_grant,
            params.lam)
        u_next = u - lam * v_eff * err / params.r0
        if params.deadband > 0.0:
            u_next = np.where(np.abs(err) <= params.deadband, u, u_next)
        u = np.clip(u_next, params.u_min, params.u_max)
        utils[i] = v / m
        v_prev = v
    return utils


SCENARIO_SHRINKS = {
    "bursty-serving": dict(n_nodes=48, n_intervals=300),
    "hetero-fleet": dict(n_nodes=48, n_intervals=250),
    "swap-storm": dict(n_nodes=32, n_intervals=300),
}


@pytest.mark.parametrize("name", sorted(SCENARIO_SHRINKS))
def test_streaming_quantile_accuracy_vs_numpy(name):
    """Engine p99 within 0.005 of np.quantile over the dense history,
    across bursty / heterogeneous / swap-pressure demand shapes."""
    spec = get_scenario(name).replace(**SCENARIO_SHRINKS[name])
    p = paper_controller_params()
    demand = spec.build_demand(seed=4)
    m = spec.build_node_memory(seed=4)
    stats = sweep_demand(demand, GainSet.from_params(p), node_memory=m,
                         interval_s=spec.interval_s,
                         occupancy=spec.occupancy)
    ref = oracle_utils(demand, m, p, occupancy=spec.occupancy)
    assert abs(float(stats.p99_utilization[0])
               - np.quantile(ref, 0.99)) <= P99_TOL
    # the streamed companions stay pinned to the dense history too
    np.testing.assert_allclose(float(stats.mean_utilization[0]),
                               ref.mean(), rtol=1e-4)
    np.testing.assert_allclose(float(stats.max_utilization[0]),
                               ref.max(), rtol=1e-4)


def test_quantile_from_codes_unit():
    """The fixed-bin bisection against np.quantile on known samples."""
    import jax.numpy as jnp
    rng = np.random.default_rng(0)
    lo, hi = QUANT_RANGE
    for sample in (rng.uniform(0.2, 1.4, 20_000),               # smooth
                   np.concatenate([rng.normal(0.6, 0.05, 15_000),
                                   rng.normal(1.2, 0.02, 5_000)]),  # bimodal
                   np.full(8_192, 0.9731)):                     # point mass
        sample = np.clip(sample, lo, hi - 1e-6).astype(np.float32)
        sample = sample[:sample.size - sample.size % 64]
        codes = utilization_codes(jnp.asarray(sample.reshape(64, -1)))
        for q in (0.5, 0.99):
            got = float(quantile_from_codes(codes, q, sample.size))
            assert abs(got - np.quantile(sample, q)) <= P99_TOL, q


def test_device_resident_chunking_invariance():
    """Chunk size (auto or explicit, padded or exact) is invisible."""
    p = paper_controller_params()
    gains = grid_gains(p, lam=(0.3, 0.7, 1.1), r0=(0.9, 0.94, 0.97))
    spec = get_scenario("bursty-serving").replace(n_nodes=32,
                                                  n_intervals=200)
    runs = [run_sweep(spec, gains, seed=2, chunk=c)
            for c in (None, 2, 5, 16)]
    for other in runs[1:]:
        for f in FleetStats._fields:
            np.testing.assert_array_equal(
                getattr(runs[0].stats, f), getattr(other.stats, f),
                err_msg=f)


MULTIDEVICE_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import numpy as np, jax
from repro.core.cluster_sim import paper_controller_params
from repro.core.traces import fleet_demand_traces
from repro.lab import FleetStats, get_scenario, grid_gains, sweep_demand
p = paper_controller_params()
demand = fleet_demand_traces(64, 300, p.interval_s, seed=3)
gains = grid_gains(p, lam=(0.3, 0.6, 0.9, 1.2), r0=(0.9, 0.93, 0.95))
assert len(jax.local_devices()) == 4
cache = get_scenario("cache-churn").cache
for kw in ({}, {"cache": cache}):       # saturated store AND CacheLoop
    multi = sweep_demand(demand, gains, node_memory=p.total_memory,
                         interval_s=p.interval_s, **kw)  # auto-detect: 4
    single = sweep_demand(demand, gains, node_memory=p.total_memory,
                          interval_s=p.interval_s, devices=1, **kw)
    for f in FleetStats._fields:
        assert np.array_equal(getattr(multi, f), getattr(single, f)), (kw, f)
print("MULTIDEVICE_PARITY_OK")
"""


@pytest.mark.slow
def test_sharded_sweep_matches_single_device():
    """Gain-axis shard_map over 4 forced host devices is bit-identical
    to the single-device path, with and without cache state in the
    carry."""
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    proc = subprocess.run([sys.executable, "-c", MULTIDEVICE_SCRIPT],
                          capture_output=True, text=True, env=env,
                          timeout=600)
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "MULTIDEVICE_PARITY_OK" in proc.stdout


# ---------------------------------------------------------------------------
# Successive halving + portfolio tuning
# ---------------------------------------------------------------------------

def test_halving_reaches_grid_best_on_swap_storm():
    grid = tune_gains("swap-storm", method="grid", budget=64, seed=0)
    halv = tune_gains("swap-storm", method="halving", budget=64, seed=0)
    assert halv.score >= grid.score - 1e-9
    assert halv.params == grid.params
    assert halv.score >= halv.baseline_score
    # round schedule: shrinking candidates over growing horizons
    horizons = [r["horizon"] for r in halv.rounds]
    cands = [r["n_candidates"] for r in halv.rounds]
    assert horizons == sorted(horizons) and horizons[-1] == 1000
    assert cands[0] > cands[-1]
    # the cheap rounds simulate a fraction of the grid's node-intervals
    # (the widened default grid may exceed the nominal budget)
    grid_work = 1000 * grid.sweep.n_configs
    halv_work = sum(r["horizon"] * r["n_candidates"] for r in halv.rounds)
    assert halv_work <= grid_work / 3


def test_halving_prefix_rounds_validate_args():
    with pytest.raises(ValueError):
        halving_tune("swap-storm", rounds=(0.0, 1.0))
    with pytest.raises(ValueError):
        run_sweep("swap-storm",
                  grid_gains(lam=(0.5,), r0=(0.95,)), horizon=10**9)


def test_portfolio_tuning_worst_case():
    scenarios = ["swap-storm", "bursty-serving"]
    small = [get_scenario(s).replace(n_nodes=24, n_intervals=200)
             for s in scenarios]
    result = tune_portfolio(small, budget=16, aggregate="worst", seed=1)
    assert result.score >= result.baseline_score
    assert set(result.scenario_scores) == {s.name for s in small}
    # worst-case aggregate: the reported score is the winner's minimum
    assert result.score == pytest.approx(
        min(result.scenario_scores.values()), rel=1e-6)
    mean_r = tune_portfolio(small, budget=16, aggregate="mean", seed=1)
    assert mean_r.score >= result.score - 1e-9   # mean >= min pointwise
    with pytest.raises(ValueError):
        tune_portfolio([], budget=4)
    with pytest.raises(ValueError):
        tune_portfolio(small, aggregate="median")
