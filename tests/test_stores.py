"""Managed stores: ShardCache, KVBlockPool, StoreRegistry, eviction."""

import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis "
                    "(pip install -r requirements-dev.txt)")
import hypothesis.strategies as st
from hypothesis import given, settings

from repro.core import (KVBlockPool, LFUPolicy, LRUPolicy, ShardCache,
                        StoreRegistry, make_policy)


class Blob:
    def __init__(self, nbytes):
        self.nbytes = nbytes


def test_cache_basic_hit_miss():
    c = ShardCache(capacity=100)
    assert c.get(1) is None
    assert c.put(1, Blob(40))
    assert c.get(1) is not None
    assert c.stats.hits == 1 and c.stats.misses == 1


def test_cache_eviction_at_capacity():
    c = ShardCache(capacity=100, policy="lru")
    c.put(1, Blob(40))
    c.put(2, Blob(40))
    c.put(3, Blob(40))                 # evicts 1 (LRU)
    assert 1 not in c and 2 in c and 3 in c
    assert c.used() <= c.capacity()


def test_set_capacity_evicts_immediately():
    c = ShardCache(capacity=120, policy="lru")
    for i in range(3):
        c.put(i, Blob(40))
    report = c.set_capacity(50)
    assert c.used() <= 50
    assert len(report.evicted_keys) == 2
    assert report.evicted_bytes == 80


def test_lfu_keeps_frequent():
    c = ShardCache(capacity=80, policy="lfu")
    c.put(1, Blob(40))
    c.put(2, Blob(40))
    for _ in range(5):
        c.get(1)
    c.put(3, Blob(40))                 # victim must be 2 (freq 1)
    assert 1 in c and 2 not in c


def test_lfu_mru_tiebreak_scan_resistance():
    p = LFUPolicy(tie="mru")
    for k in range(4):
        p.on_insert(k)
    assert p.victim() == 3             # newest among freq-1
    p_classic = LFUPolicy(tie="lru")
    for k in range(4):
        p_classic.on_insert(k)
    assert p_classic.victim() == 0


def test_admission_stabilizes_cyclic_scan():
    """The paper's static-25GB config sustains ~cache/partition hit
    ratio on repeated scans; plain insert-always LFU would thrash to 0%."""
    c = ShardCache(capacity=25, policy="lfu", admission=True,
                   sizeof=lambda v: 1.0)
    for it in range(4):
        for k in range(64):
            if c.get(k) is None:
                c.put(k, object())
    # steady state: first 25 keys resident
    assert c.stats.hit_ratio > 0.25


def test_oversized_object_rejected():
    c = ShardCache(capacity=10)
    assert not c.put(1, Blob(50))
    assert c.stats.rejected == 1


@given(st.lists(st.tuples(st.integers(0, 20), st.integers(1, 30)),
                min_size=1, max_size=200))
@settings(max_examples=50, deadline=None)
def test_capacity_invariant_under_any_workload(ops):
    """used() <= capacity() after every operation, any access pattern."""
    c = ShardCache(capacity=100, policy="lfu")
    for key, size in ops:
        if c.get(key) is None:
            c.put(key, Blob(size))
        assert c.used() <= c.capacity()
        assert c.used() == sum(c._sizes.values())


# ---------------------------------------------------------------------------
# KVBlockPool
# ---------------------------------------------------------------------------

def test_pool_alloc_free():
    p = KVBlockPool("kv", num_blocks=8, block_bytes=100)
    blocks = [p.alloc_block("a") for _ in range(3)]
    assert all(b is not None for b in blocks)
    assert p.num_free_blocks() == 5
    assert p.block_table("a") == blocks
    assert p.free_seq("a") == 3
    assert p.num_free_blocks() == 8


def test_pool_budget_rejects():
    p = KVBlockPool("kv", num_blocks=4, block_bytes=100)
    for _ in range(4):
        assert p.alloc_block("a") is not None
    assert p.alloc_block("b") is None
    assert p.stats.rejected == 1


def test_pool_shrink_preempts_largest_first():
    p = KVBlockPool("kv", num_blocks=8, block_bytes=100)
    for _ in range(5):
        p.alloc_block("big")
    for _ in range(2):
        p.alloc_block("small")
    report = p.set_capacity(300)       # 3 usable blocks
    assert "big" in report.evicted_keys
    assert p.drain_preempted() == ["big"]
    assert p.block_table("small")      # survivor intact


def test_pool_capacity_roundtrip():
    p = KVBlockPool("kv", num_blocks=8, block_bytes=100)
    p.set_capacity(200)
    assert p.num_free_blocks() == 2
    p.set_capacity(1e9)                # clamped to total
    assert p.num_free_blocks() == 8


# ---------------------------------------------------------------------------
# StoreRegistry priority waterfall
# ---------------------------------------------------------------------------

def test_registry_waterfall():
    hi = ShardCache("hi", capacity=0, priority=10)
    lo = ShardCache("lo", capacity=0, priority=1)
    reg = StoreRegistry()
    reg.register(lo, max_bytes=100)
    reg.register(hi, max_bytes=50)
    reg.apply_capacity(120)
    assert hi.capacity() == 50         # high priority filled first
    assert lo.capacity() == 70
    reg.apply_capacity(30)
    assert hi.capacity() == 30 and lo.capacity() == 0
