"""ChaosPlane: fault injection, the health state machine, fail-static
degradation, retune supervision, and fleet-level quarantine.

The invariants a dynamic controller must keep when its own sensors and
actuators fail (the dual of the paper's claim that late telemetry is a
swap storm): no grant beyond the caps, no action from non-finite
telemetry, epoch-monotone histories, bounded quarantine entry and
bounded rejoin, and -- one level up -- a FleetPlane that conserves
budgets and squeezes a dark tenant to its floor.
"""

import math
import threading
import time

import pytest

from repro.core import (ControllerParams, GiB, HealthPolicy, MemoryPlane,
                        MemorySample, MonitorFault, NodeHealth, NodeSpec,
                        PlaneSpec, ShardCache, SimulatedMonitor, StoreSpec,
                        StoreRegistry, validate_sample)
from repro.core.cluster_sim import paper_controller_params
from repro.core.plane import FaultLog, FaultEvent
from repro.fleet import FleetPlane, FleetSpec, TenantSpec
from repro.lab import retune_online
from repro.runtime import (ChaosError, ChaosSpec, FAULT_KINDS, FaultSpec,
                           HeartbeatMonitor, inject)

M = 125.0 * GiB
BACKENDS = ("scalar", "array")


def _params(**kw):
    kw.setdefault("total_memory", M)
    kw.setdefault("u_max", 60.0 * GiB)
    kw.setdefault("u_min", 5.0 * GiB)
    return ControllerParams(**kw)


def _plane(backend, n_nodes=4, policy=None, usage=None, **spec_kw):
    params = _params()
    usage = usage or (lambda k: 80.0 * GiB)
    plane = MemoryPlane(PlaneSpec(
        params=params, backend=backend,
        health=policy or HealthPolicy(stale_budget=2, rejoin_intervals=3),
        nodes=tuple(
            NodeSpec(f"n{i}",
                     monitor=SimulatedMonitor(f"n{i}", total=M, usage=usage),
                     registry=StoreRegistry(), u0=30.0 * GiB)
            for i in range(n_nodes)),
        **spec_kw))
    return plane, params


# ---------------------------------------------------------------------------
# Spec validation + deterministic scheduling
# ---------------------------------------------------------------------------

def test_fault_spec_validation():
    with pytest.raises(ValueError, match="unknown fault kind"):
        FaultSpec("gremlin")
    with pytest.raises(ValueError):
        FaultSpec("nan", start=-1)
    with pytest.raises(ValueError):
        FaultSpec("nan", duration=0)
    with pytest.raises(ValueError):
        FaultSpec("nan", probability=0.0)
    with pytest.raises(ValueError):
        FaultSpec("nan", probability=1.5)
    with pytest.raises(TypeError):
        ChaosSpec(faults=("nan",))
    f = FaultSpec("slow-sample", nodes=["a", "b"])
    assert f.nodes == ("a", "b")
    assert f.effective_magnitude() > 0.0          # kind default


def test_chaos_schedule_is_deterministic_and_windowed():
    spec = ChaosSpec(faults=(
        FaultSpec("nan", nodes=("n0",), start=5, duration=10,
                  probability=0.4),
    ), seed=7)
    fires = [spec.fires(0, "n0", t) for t in range(30)]
    assert fires == [spec.fires(0, "n0", t) for t in range(30)]  # pure
    assert not any(fires[:5]) and not any(fires[15:])            # window
    assert any(fires[5:15])
    assert not spec.fires(0, "n1", 7)                            # node filter
    # a different seed reshuffles the probabilistic schedule
    other = ChaosSpec(faults=spec.faults, seed=8)
    assert fires != [other.fires(0, "n0", t) for t in range(30)]


def test_validate_sample_catches_garbage():
    good = MemorySample("n", 0.0, 10.0, 100.0)
    assert validate_sample(good) is None
    bad = [
        MemorySample("n", 0.0, float("nan"), 100.0),
        MemorySample("n", 0.0, float("inf"), 100.0),
        MemorySample("n", 0.0, -5.0, 100.0),
        MemorySample("n", 0.0, 10.0, 0.0),
        MemorySample("n", 0.0, 10.0, 100.0, storage_used=-1.0),
    ]
    assert all(validate_sample(s) is not None for s in bad)


def test_simulated_monitor_fault_modes_are_seeded():
    def make(seed):
        return SimulatedMonitor("n0", total=100.0,
                                usage=lambda i: 50.0 + i,
                                faults={"dropout": 0.3, "nan": 0.2},
                                fault_seed=seed)

    def run(mon, n=40):
        out = []
        for _ in range(n):
            try:
                u = mon.sample().used
                out.append("nan" if math.isnan(u) else u)
            except MonitorFault:
                out.append("drop")
        return out

    a, b = run(make(3)), run(make(3))
    assert a == b                                  # deterministic replay
    assert a != run(make(4))                       # seed changes schedule
    assert "drop" in a and "nan" in a
    with pytest.raises(ValueError, match="unknown fault kinds"):
        SimulatedMonitor("n", total=1.0, usage=lambda i: 1.0,
                         faults={"gremlin": 0.5})


def test_simulated_monitor_freeze_returns_last_good():
    mon = SimulatedMonitor("n0", total=100.0, usage=lambda i: float(i),
                           faults={"freeze": 1.0}, fault_seed=0)
    first = mon.sample()          # nothing cached yet -> fresh sample
    frozen = [mon.sample() for _ in range(3)]
    assert all(s.used == first.used for s in frozen)


# ---------------------------------------------------------------------------
# The health state machine under injected faults (both backends)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", BACKENDS)
def test_invariants_hold_under_full_catalog(backend):
    """Grant caps, finite actions, and epoch monotonicity survive every
    telemetry/actuation fault family at once."""
    plane, params = _plane(backend, n_nodes=5)
    spec = ChaosSpec(faults=(
        FaultSpec("dropout", nodes=("n0",), start=3, duration=10,
                  probability=0.5),
        FaultSpec("freeze", nodes=("n1",), start=3, duration=8),
        FaultSpec("nan", nodes=("n2",), start=3, duration=8),
        FaultSpec("negative", nodes=("n2",), start=11, duration=4),
        FaultSpec("crash", nodes=("n3",), start=5, duration=15),
        FaultSpec("actuate-raise", nodes=("n4",), start=3, duration=8),
        FaultSpec("actuate-partial", nodes=("n4",), start=12, duration=4),
    ), seed=1)
    audit = []
    with inject(plane, spec) as chaos:
        for _ in range(30):
            audit.extend(plane.tick())
    for _ in range(30):
        audit.extend(plane.tick())
    assert chaos.counts()                       # something actually fired
    for a in audit:
        assert math.isfinite(a.u_next) and math.isfinite(a.u_prev)
        assert a.u_next <= params.u_max + 1.0
        assert a.u_next >= params.u_min - 1.0
        assert a.u_next <= M
    for i in range(5):
        epochs = [a.epoch for a in audit if a.node == f"n{i}"]
        assert all(y >= x for x, y in zip(epochs, epochs[1:]))
    report = plane.health()
    assert not report.degraded(), report.summary()
    assert report.fault_counts.get("quarantine", 0) >= 1
    assert report.fault_counts.get("rejoin", 0) >= 1


@pytest.mark.parametrize("backend", BACKENDS)
def test_quarantine_entry_and_rejoin_are_bounded(backend):
    """A crashed node quarantines after exactly ``stale_budget`` failed
    intervals and rejoins after ``rejoin_intervals`` good ones."""
    policy = HealthPolicy(stale_budget=3, rejoin_intervals=4)
    plane, _ = _plane(backend, n_nodes=2, policy=policy)
    for _ in range(5):
        plane.tick()                                   # warm last-good
    crash = ChaosSpec(faults=(FaultSpec("crash", nodes=("n0",)),), seed=0)
    handle = inject(plane, crash)
    states = []
    for _ in range(10):
        plane.tick()
        states.append(plane.health().nodes["n0"].state)
    # holdover until the stale_budget-th consecutive bad interval
    # trips quarantine -- entry is bounded, not instant
    assert states[policy.stale_budget - 2] is not NodeHealth.QUARANTINED
    assert states[policy.stale_budget - 1] is NodeHealth.QUARANTINED
    assert states[-1] is NodeHealth.QUARANTINED
    handle.revert()
    rejoin_at = None
    for t in range(policy.rejoin_intervals + 3):
        plane.tick()
        if plane.health().nodes["n0"].state is NodeHealth.HEALTHY:
            rejoin_at = t
            break
    assert rejoin_at is not None, "node never rejoined after chaos lifted"
    assert rejoin_at + 1 >= policy.rejoin_intervals    # hysteresis respected


@pytest.mark.parametrize("backend", BACKENDS)
def test_quarantined_node_is_pinned_fail_static(backend):
    """While quarantined, the node's stores sit at the fail-static
    grant (u_min by default) and the law leaves it alone."""
    policy = HealthPolicy(stale_budget=2, rejoin_intervals=3)
    cache = ShardCache(capacity=30.0 * GiB)
    params = _params()
    plane = MemoryPlane(PlaneSpec(
        params=params, backend=backend, health=policy,
        nodes=(NodeSpec(
            "n0",
            monitor=SimulatedMonitor("n0", total=M,
                                     usage=lambda k: 80.0 * GiB,
                                     storage_used_fn=cache.used),
            stores=(StoreSpec(cache, max_bytes=60.0 * GiB),),
            u0=30.0 * GiB),)))
    for _ in range(3):
        plane.tick()
    with inject(plane, ChaosSpec(
            faults=(FaultSpec("dropout", nodes=("n0",)),), seed=0)):
        for _ in range(policy.stale_budget + 4):
            acted = plane.tick()
        info = plane.health().nodes["n0"]
        assert info.state is NodeHealth.QUARANTINED
        assert info.pin_grant == policy.fail_static_grant(
            params.u_min, params.u_max) == params.u_min
        assert cache.capacity() == pytest.approx(info.pin_grant)
        assert acted == []                   # law not running on n0


@pytest.mark.parametrize("backend", BACKENDS)
def test_nan_telemetry_never_reaches_the_law(backend):
    """Non-finite samples are replaced by last-good holdover; the grant
    trajectory stays finite and inside the caps throughout."""
    plane, params = _plane(backend, n_nodes=1)
    for _ in range(3):
        plane.tick()
    u_before = plane.capacity("n0")
    with inject(plane, ChaosSpec(
            faults=(FaultSpec("nan", nodes=("n0",), duration=2),), seed=0)):
        acts = plane.tick() + plane.tick()
    # holdover keeps the loop running on the last-good observation
    assert acts, "stale holdover should keep the law running"
    for a in acts:
        assert math.isfinite(a.u_next)
        assert params.u_min <= a.u_next <= params.u_max
    assert math.isfinite(plane.capacity("n0"))
    assert plane.health().fault_counts["telemetry-invalid"] == 2
    assert u_before == pytest.approx(plane.capacity("n0"), rel=0.5)


def test_actuation_retry_backoff_and_recovery():
    """A wedged store degrades to bounded backoff (no unbounded retry
    storm) and recovers on the first successful apply."""
    policy = HealthPolicy(actuation_retries=2, retry_backoff_cap=4)
    plane, _ = _plane("scalar", n_nodes=1, policy=policy)
    for _ in range(2):
        plane.tick()
    with inject(plane, ChaosSpec(
            faults=(FaultSpec("actuate-raise", nodes=("n0",),
                              duration=6),), seed=0)):
        for _ in range(6):
            plane.tick()
        info = plane.health().nodes["n0"]
        assert info.actuation_degraded      # retries exhausted -> flagged
        assert info.actuation_failures >= policy.actuation_retries
        counts = plane.fault_log.counts()
        # backoff skips apply calls: strictly fewer errors than ticks
        assert counts["actuation-error"] < 6
        assert counts.get("actuation-degraded", 0) == 1
    for _ in range(2 * policy.retry_backoff_cap + 2):
        plane.tick()
    info = plane.health().nodes["n0"]
    assert not info.actuation_degraded and info.actuation_failures == 0
    assert plane.fault_log.counts().get("actuation-recovered", 0) == 1


def test_chaos_revert_restores_the_plane():
    plane, _ = _plane("scalar", n_nodes=2)
    mon0 = plane._monitors["n0"]
    inner0 = plane._registries["n0"]._inner
    tick0 = plane.tick
    handle = inject(plane, ChaosSpec(
        faults=(FaultSpec("crash",), FaultSpec("retune-kill")), seed=0))
    assert plane._monitors["n0"] is not mon0
    assert plane._registries["n0"]._inner is not inner0
    handle.revert()
    handle.revert()                          # idempotent
    assert plane._monitors["n0"] is mon0
    assert plane._registries["n0"]._inner is inner0
    assert plane.tick == tick0
    assert plane.tick()                      # clean plane ticks normally


def test_fault_log_is_bounded():
    log = FaultLog(maxlen=4)
    for i in range(10):
        log.append(FaultEvent(kind="k", node="n", tick=i, timestamp=0.0))
    assert len(log) == 4
    assert [e.tick for e in log.snapshot()] == [6, 7, 8, 9]
    assert log.counts() == {"k": 10}         # counts survive eviction


def test_tick_deadline_watchdog():
    policy = HealthPolicy(tick_deadline_s=1e-9)
    plane, _ = _plane("scalar", n_nodes=1, policy=policy)
    plane.tick()
    report = plane.health()
    assert report.deadline_misses == 1
    assert report.fault_counts.get("tick-deadline", 0) == 1


# ---------------------------------------------------------------------------
# Retune supervision
# ---------------------------------------------------------------------------

def _recording_plane(ticks=30):
    plane, _ = _plane(
        "array", n_nodes=3, record=ticks,
        usage=lambda k: (60.0 + 30.0 * math.sin(0.3 * k)) * GiB)
    for _ in range(ticks):
        plane.tick()
    return plane


def test_retune_supervisor_restarts_after_kill():
    plane = _recording_plane()
    real_capture = plane.capture
    boom = [2]                               # first two rounds die

    def flaky_capture(*a, **kw):
        if boom[0] > 0:
            boom[0] -= 1
            raise ChaosError("injected retune kill")
        return real_capture(*a, **kw)

    plane.capture = flaky_capture
    handle = retune_online(plane, method="random", budget=4, seed=0,
                           block=False, swap=False, restarts=4,
                           restart_backoff_s=0.01)
    result = handle.result(timeout=300)
    assert handle.attempts == 3 and handle.restarts == 2
    assert result.tune.score >= result.tune.baseline_score
    counts = plane.fault_log.counts()
    assert counts.get("retune-restart", 0) == 2
    assert "retune-dead" not in counts


def test_retune_supervisor_gives_up_and_reports_dead():
    plane = _recording_plane(ticks=10)
    plane.capture = lambda *a, **kw: (_ for _ in ()).throw(
        ChaosError("wedged"))
    handle = retune_online(plane, block=False, restarts=2,
                           restart_backoff_s=0.01)
    with pytest.raises(ChaosError):
        handle.result(timeout=60)
    assert handle.attempts == 3 and handle.restarts == 2
    assert plane.fault_log.counts().get("retune-dead", 0) == 1


def test_retune_unsupervised_keeps_legacy_eager_capture():
    plane, _ = _plane("scalar", n_nodes=1)     # not recording
    with pytest.raises(ValueError, match="not recording"):
        retune_online(plane, block=False)      # raises in the caller


# ---------------------------------------------------------------------------
# FleetPlane: quarantined tenants and rollback
# ---------------------------------------------------------------------------

def _fleet(n_nodes=2, epoch_intervals=3):
    params = _params(interval_s=0.01)
    policy = HealthPolicy(stale_budget=2, rejoin_intervals=2)

    def tenant(name, usage_gib, **kw):
        nodes = tuple(
            NodeSpec(f"{name}-n{i}", monitor=SimulatedMonitor(
                f"{name}-n{i}", total=M,
                usage=lambda t, g=usage_gib: g * GiB))
            for i in range(n_nodes))
        return TenantSpec(name, PlaneSpec(params=params, nodes=nodes,
                                          health=policy), **kw)

    return FleetPlane(FleetSpec(tenants=(
        tenant("victim", 40.0, weight=2.0, floor_gib=8.0),
        tenant("bystander", 30.0, weight=1.0, floor_gib=8.0),
    ), epoch_intervals=epoch_intervals))


def test_fleet_quarantined_tenant_gets_floor_and_rejoins():
    fleet = _fleet()
    floor = 8.0 * GiB
    with fleet:
        for _ in range(6):
            fleet.tick()
        pre = fleet.budgets()
        assert pre["victim"] > floor * 1.5       # bidding normally
        handle = inject(fleet.plane("victim"), ChaosSpec(
            faults=(FaultSpec("crash", nodes=("victim-n0",
                                              "victim-n1")),), seed=0))
        floored = False
        for _ in range(12):
            fleet.tick()
            b = fleet.budgets()
            assert sum(b.values()) <= M + 1.0    # conservation, every tick
            if ("victim" in fleet.quarantined_tenants()
                    and b["victim"] <= floor + 1.0):
                floored = True
        assert floored, "dark tenant never squeezed to its floor"
        assert fleet.budgets()["bystander"] > floor  # bystander unharmed
        vic = fleet._tenants["victim"]
        assert vic.last_telemetry is not None        # pre-chaos telemetry
        assert vic.last_telemetry.usage_bytes > 0.0  # kept for operators
        handle.revert()
        for _ in range(14):
            fleet.tick()
            assert sum(fleet.budgets().values()) <= M + 1.0
        assert fleet.quarantined_tenants() == []
        assert fleet.budgets()["victim"] > floor * 1.5   # budget regrown
        counts = fleet.fault_log.counts()
        assert counts.get("tenant-quarantine", 0) >= 1
        assert counts.get("tenant-rejoin", 0) >= 1


def test_fleet_rebalance_rolls_back_on_partial_swap_failure():
    fleet = _fleet()
    with fleet:
        for _ in range(6):
            fleet.tick()
        before = fleet.budgets()
        grant_before = fleet.last_grant()
        # Wedge one tenant's swap: the next rebalance must unwind.
        bystander = fleet._tenants["bystander"].plane
        real_swap = bystander.swap_params
        bystander.swap_params = lambda *a, **kw: (_ for _ in ()).throw(
            RuntimeError("wedged swap"))
        telemetry = fleet._snapshot_telemetry()
        grant = fleet.rebalance(telemetry)
        after = fleet.budgets()
        assert after == before                       # fully unwound
        assert sum(after.values()) <= M + 1.0
        assert fleet.last_grant() == grant_before    # failed grant unpublished
        assert grant == grant_before
        assert fleet.fault_log.counts().get("rebalance-rollback", 0) == 1
        bystander.swap_params = real_swap
        fleet.tick()                                 # fleet still ticks


# ---------------------------------------------------------------------------
# HeartbeatMonitor race hardening
# ---------------------------------------------------------------------------

def test_heartbeat_callbacks_fire_outside_the_lock():
    hb = HeartbeatMonitor(interval_s=0.01, timeout_intervals=1)
    hb.register("w0")
    seen = []
    # A callback that re-enters the monitor would deadlock if fired
    # under the lock.
    hb.on_failure(lambda w: seen.append(("fail", w, hb.failed_workers())))
    hb.on_recovery(lambda w: seen.append(("rec", w, hb.healthy_workers())))
    assert hb.check(now=time.monotonic() + 1.0) == ["w0"]
    hb.heartbeat("w0")
    assert ("fail", "w0", ["w0"]) in seen
    assert ("rec", "w0", ["w0"]) in seen


def test_heartbeat_concurrent_registration_and_check():
    hb = HeartbeatMonitor(interval_s=0.001, timeout_intervals=1)
    for i in range(16):
        hb.register(f"w{i}")
    errors = []
    stop = threading.Event()

    def churn():
        try:
            while not stop.is_set():
                hb.on_failure(lambda w: None)
                hb.on_recovery(lambda w: None)
                hb.heartbeat("w0")
        except Exception as exc:                     # pragma: no cover
            errors.append(exc)

    threads = [threading.Thread(target=churn) for _ in range(4)]
    for t in threads:
        t.start()
    deadline = time.monotonic() + 0.5
    try:
        while time.monotonic() < deadline:
            hb.check(now=time.monotonic() + 1.0)
            for i in range(16):
                hb.heartbeat(f"w{i}")
    finally:
        stop.set()
        for t in threads:
            t.join()
    assert not errors
    assert set(hb.healthy_workers()) == {f"w{i}" for i in range(16)}
