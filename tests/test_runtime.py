"""Runtime: heartbeats/failures, stragglers, elastic re-meshing."""

import pytest

from repro.runtime import (ElasticMeshPlanner, HeartbeatMonitor,
                           StragglerDetector)


def test_heartbeat_failure_and_recovery():
    mon = HeartbeatMonitor(interval_s=1.0, timeout_intervals=3)
    failed, recovered = [], []
    mon.on_failure(failed.append)
    mon.on_recovery(recovered.append)
    mon.heartbeat("w0", now=0.0)
    mon.heartbeat("w1", now=0.0)
    assert mon.check(now=2.0) == []          # within timeout
    mon.heartbeat("w1", now=2.5)             # w1 stays alive, w0 silent
    assert set(mon.check(now=4.0)) == {"w0"}
    assert failed == ["w0"]
    assert mon.failed_workers() == ["w0"]
    assert mon.check(now=4.5) == []          # not re-reported
    mon.heartbeat("w0", now=5.0)             # rejoin
    assert recovered == ["w0"]
    assert sorted(mon.healthy_workers()) == ["w0", "w1"]


def test_straggler_squeeze_then_evict():
    squeezed, evicted = [], []
    det = StragglerDetector(window=8, threshold=1.5, grace=3,
                            squeeze_cb=lambda w, f: squeezed.append((w, f)),
                            evict_cb=evicted.append)
    for i in range(8):
        for w in ("w0", "w1", "w2", "w3"):
            det.record(w, 1.0)
        det.record("slow", 3.0)
    r1 = det.check()
    assert [r.worker for r in r1] == ["slow"]
    assert r1[0].action == "squeeze"
    det.check()
    r3 = det.check()
    assert r3[0].action == "evict"
    assert evicted == ["slow"]
    assert len(squeezed) == 2
    assert all(0 < f < 1 for _, f in squeezed)


def test_straggler_recovers_resets_strikes():
    det = StragglerDetector(window=8, threshold=1.5, grace=3)
    for _ in range(8):
        for w in ("a", "b", "c"):
            det.record(w, 1.0)
        det.record("d", 2.0)
    det.check()
    for _ in range(8):                 # d recovers
        for w in ("a", "b", "c", "d"):
            det.record(w, 1.0)
    assert det.check() == []
    assert det._strikes["d"] == 0


def test_elastic_planner_prefers_keeping_tp():
    pl = ElasticMeshPlanner(model_axis=16)
    full = pl.plan(256)
    assert full.shape == (16, 16) and full.dropped == 0
    degraded = pl.replan_after_failures(256, 16)
    assert degraded.shape == (15, 16)
    assert degraded.dropped == 0
    odd = pl.plan(250)
    assert odd.shape == (15, 16) and odd.dropped == 10


def test_elastic_planner_degrades_tp_last_resort():
    pl = ElasticMeshPlanner(model_axis=16)
    tiny = pl.plan(12)
    assert tiny.shape[1] == 8 and tiny.shape[0] == 1
    with pytest.raises(RuntimeError):
        pl.plan(0)


def test_mesh_plan_materializes_on_cpu():
    import jax
    pl = ElasticMeshPlanner(model_axis=1, axis_names=("data", "model"))
    plan = pl.plan(1)
    mesh = plan.make(jax.devices())
    assert mesh.devices.shape == (1, 1)
