"""Control-law unit + property tests (paper Eq. 1, Table I)."""

import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis "
                    "(pip install -r requirements-dev.txt)")
import hypothesis.strategies as st
from hypothesis import given, settings

from repro.core import (ControllerParams, GiB, closed_loop_eigenvalue,
                        control_step, fixed_point_capacity, is_stable,
                        settling_time, simulate_saturated_loop,
                        vectorized_step)
from repro.core.cluster_sim import paper_controller_params


def test_table_one_parameters():
    p = paper_controller_params()
    assert p.total_memory == 125 * GiB
    assert p.r0 == 0.95 and p.lam == 0.5
    assert p.u_min == 0 and p.u_max == 60 * GiB
    assert p.interval_s == 0.1
    assert p.is_paper_faithful


def test_eq1_matches_paper_formula():
    p = paper_controller_params()
    u, v = 40 * GiB, 120 * GiB
    r = v / p.total_memory
    expected = u - p.lam * v * (r - p.r0) / p.r0
    assert control_step(u, v, p) == pytest.approx(expected, rel=1e-12)


def test_clamping():
    p = paper_controller_params()
    assert control_step(59 * GiB, 40 * GiB, p) == p.u_max   # grant clamped
    assert control_step(1 * GiB, 200 * GiB, p) == p.u_min   # reclaim clamped


def test_pressure_shrinks_slack_grows():
    p = paper_controller_params()
    u = 30 * GiB
    assert control_step(u, 124 * GiB, p) < u     # r > r0 -> shrink
    assert control_step(u, 80 * GiB, p) > u      # r < r0 -> grow


@given(lam=st.floats(0.01, 1.99))
@settings(max_examples=40, deadline=None)
def test_stability_region(lam):
    p = paper_controller_params(lam=lam)
    assert is_stable(p)
    assert closed_loop_eigenvalue(p) == pytest.approx(1 - lam)
    demand = np.full(600, 60.0 * GiB)
    trace = simulate_saturated_loop(p, demand, u0=p.u_max)
    target = fixed_point_capacity(p, 60.0 * GiB)
    t = settling_time(trace, target, tol_frac=0.05)
    assert t is not None, "stable loop must settle"


@given(lam=st.floats(2.05, 4.0))
@settings(max_examples=15, deadline=None)
def test_instability_beyond_two(lam):
    p = paper_controller_params(lam=lam)
    assert not is_stable(p)


@given(lam=st.floats(0.05, 0.8))
@settings(max_examples=25, deadline=None)
def test_monotone_no_overshoot_for_lam_below_one(lam):
    """Small lam: approach is monotone (paper picks 0.5).  The linearized
    no-overshoot bound is lam <= 1; the true loop's gain grows with
    distance from the fixed point (delta ~ lam*v*(r-r0)), so from a
    u_max start monotonicity empirically needs lam <~ 0.85."""
    p = paper_controller_params(lam=lam)
    demand = np.full(400, 70.0 * GiB)
    trace = simulate_saturated_loop(p, demand, u0=p.u_max)
    target = fixed_point_capacity(p, 70.0 * GiB)
    diffs = np.diff(trace)
    assert (diffs <= 1e-6).all(), "capacity must fall monotonically"
    assert trace[-1] >= target - 1e6


@given(
    u=st.floats(0, 60 * GiB),
    v=st.floats(1 * GiB, 130 * GiB),
)
@settings(max_examples=100, deadline=None)
def test_output_always_in_range(u, v):
    p = paper_controller_params()
    out = control_step(u, v, p)
    assert p.u_min <= out <= p.u_max


@given(
    u=st.lists(st.floats(0, 60 * GiB), min_size=1, max_size=32),
    d=st.floats(10 * GiB, 90 * GiB),
)
@settings(max_examples=30, deadline=None)
def test_vectorized_matches_scalar(u, d):
    p = paper_controller_params()
    us = np.asarray(u)
    vs = us + d                               # saturated store usage
    vec = np.asarray(vectorized_step(
        us, vs, total_memory=p.total_memory, r0=p.r0, lam=p.lam,
        u_min=p.u_min, u_max=p.u_max))
    ref = np.asarray([control_step(ui, vi, p) for ui, vi in zip(us, vs)])
    np.testing.assert_allclose(vec, ref, rtol=1e-5)


def test_settling_under_ten_intervals_at_paper_lambda():
    """lambda=0.5 reaches the 2% band in < 1 s (10 intervals) -- the
    responsiveness claim behind the paper's 100 ms interval choice."""
    p = paper_controller_params()
    demand = np.full(100, 75.0 * GiB)
    trace = simulate_saturated_loop(p, demand, u0=p.u_max)
    target = fixed_point_capacity(p, 75.0 * GiB)
    assert settling_time(trace, target) <= 10


def test_feedforward_reduces_burst_overshoot():
    """Beyond-paper slope feedforward must cut peak utilization during a
    steep ramp (this is §Perf controller-hillclimb hypothesis H1)."""
    from repro.core.traces import hpcc_trace
    demand = hpcc_trace(60.0, 0.1, seed=3)
    p0 = paper_controller_params()
    p1 = paper_controller_params(feedforward=1.0)

    def peak_util(p):
        u = p.u_max
        v_prev = None
        peak = 0.0
        for d in demand:
            v = d + u
            peak = max(peak, v / p.total_memory)
            u_next = control_step(u, v, p, v_prev=v_prev)
            v_prev = v
            u = u_next
        return peak

    assert peak_util(p1) <= peak_util(p0)
