"""CacheLoop: cache dynamics in the scanned sweep.

Three oracles pin the model:

* the **discrete-event simulator** (``core.cluster_sim``) -- the
  analytic hit curve must land within 0.02 of the per-key LFU cache on
  the cyclic-scan parity configuration;
* a **float64 numpy reimplementation** of the same analytic dynamics --
  the float32 streamed accumulators must match a dense reference they
  never materialize;
* the **pre-CacheLoop fast path** -- a degenerate cache spec (instant
  refill, unbounded working set, warm start) must reproduce the
  saturated-store loop bit for bit, and ``cache=None`` must keep every
  new field at its neutral value.
"""

import numpy as np
import pytest

from repro.configs.dynims import PAPER_TABLE_I
from repro.core.cluster_sim import (make_cache_parity_config,
                                    paper_controller_params, simulate)
from repro.core.eviction import POLICY_MODELS, PolicyModel, policy_model
from repro.core.traces import GiB, hpl_slowdown
from repro.lab import (CacheSpec, FleetStats, GainSet, ScenarioSpec,
                       default_score, get_scenario, grid_gains,
                       hpl_slowdown_curve, paper_law_mask,
                       plan_specialization, resolve_objective, run_sweep,
                       runtime_score, sweep_demand, tune_gains)
from repro.lab.tune import _default_candidates

STABILITY_FIELDS = FleetStats._fields[:10]
CACHE_FIELDS = ("hit_ratio", "evicted_bytes", "app_runtime", "app_slowdown")


def small(name, **kw):
    return get_scenario(name).replace(**kw)


# ---------------------------------------------------------------------------
# Cache-off: neutral fields, unchanged fast path
# ---------------------------------------------------------------------------

def test_cache_off_fields_are_neutral():
    spec = small("bursty-serving", n_nodes=16, n_intervals=150)
    r = run_sweep(spec, GainSet.from_params(PAPER_TABLE_I), seed=0)
    assert float(r.stats.hit_ratio[0]) == 1.0
    assert float(r.stats.evicted_bytes[0]) == 0.0
    ideal = spec.n_intervals * spec.interval_s
    assert float(r.stats.app_runtime[0]) == pytest.approx(ideal)
    assert float(r.stats.app_slowdown[0]) == 1.0
    # the runtime term of default_score is exactly zero, and the pure
    # runtime objective degenerates to a constant
    np.testing.assert_allclose(r.scores(runtime_score), -1.0)


def test_degenerate_cache_matches_fast_path_bitwise():
    """A cache that always mirrors the grant (warm start, unbounded
    working set, instant refill) IS the saturated store: every
    stability metric must be bit-identical to the cache=None path."""
    p = paper_controller_params()
    demand = np.asarray(get_scenario("bursty-serving").replace(
        n_nodes=24, n_intervals=200).build_demand(seed=3))
    gains = grid_gains(p, lam=(0.3, 0.9, 1.4), r0=(0.9, 0.95))
    degenerate = CacheSpec(policy="lfu", reuse_skew=0.0,
                           working_set_frac=1e6, access_gibps=1e6,
                           refill_gibps=1e6, miss_penalty_s_per_gib=0.0,
                           evict_penalty_s_per_gib=0.0, warm_frac=1.0)
    off = sweep_demand(demand, gains, node_memory=p.total_memory,
                       interval_s=p.interval_s)
    on = sweep_demand(demand, gains, node_memory=p.total_memory,
                      interval_s=p.interval_s, cache=degenerate)
    for f in STABILITY_FIELDS:
        np.testing.assert_array_equal(getattr(off, f), getattr(on, f),
                                      err_msg=f)


# ---------------------------------------------------------------------------
# Discrete-event oracle parity (the acceptance gate)
# ---------------------------------------------------------------------------

def _parity_spec(cfg, n_intervals: int = 1600) -> ScenarioSpec:
    """The cyclic-scan spec whose analytic cache must match ``cfg``."""
    w_gib = cfg.app.dataset_gib / cfg.n_compute     # per-node partition
    # access rate sized so total model accesses equal the oracle's
    # total block reads (iterations x partition per node)
    access = cfg.app.iterations * w_gib / (n_intervals * cfg.interval_s)
    return ScenarioSpec(
        name="cache-parity", family="constant", n_nodes=cfg.n_compute,
        n_intervals=n_intervals, base_gib=0.0,
        offset_gib=cfg.spark_exec_gib + cfg.os_base_gib,
        amp_range=(1.0, 1.0), phase_shift=False,
        node_memory_gib=cfg.node_memory_gib,
        cache=CacheSpec(policy="lfu", reuse_skew=0.0,
                        working_set_frac=w_gib / cfg.node_memory_gib,
                        access_gibps=access, refill_gibps=access,
                        miss_penalty_s_per_gib=0.4))


def _pinned_gains(cfg):
    """Pin the grant at the oracle's static capacity."""
    return GainSet.from_params(paper_controller_params(
        u_min=cfg.static_cache_gib * GiB, u_max=cfg.static_cache_gib * GiB))


def test_hit_ratio_matches_discrete_event_oracle():
    """The analytic cache model reproduces cluster_sim's per-key LFU
    hit ratio within 0.02 on the cyclic-scan parity configuration."""
    cfg = make_cache_parity_config()
    oracle = simulate(cfg)
    assert oracle.peak_utilization < 0.9      # pure cache dynamics, no
    # pressure coupling in the comparison

    r = run_sweep(_parity_spec(cfg), _pinned_gains(cfg), seed=0)
    assert abs(float(r.stats.hit_ratio[0]) - oracle.hit_ratio) <= 0.02
    # the miss-penalty model lands in the oracle's runtime ballpark
    assert float(r.stats.app_runtime[0]) == pytest.approx(
        oracle.app_runtime_s, rel=0.15)
    # capacity pinned -> the controller never forces an eviction
    assert float(r.stats.evicted_bytes[0]) == 0.0


def test_cold_start_first_pass_matches_discrete_event_oracle():
    """Warmup-aware cold scan: with few iterations the compulsory-miss
    first pass dominates the run, so a model that applies the
    steady-state hit curve from t=0 overshoots.  The cold-scan term
    must track the discrete-event cold start, where pass 1 of the
    cyclic scan gets zero hits."""
    cfg = make_cache_parity_config(iterations=4)
    oracle = simulate(cfg)
    r = run_sweep(_parity_spec(cfg, n_intervals=800), _pinned_gains(cfg),
                  seed=0)
    model = float(r.stats.hit_ratio[0])
    assert abs(model - oracle.hit_ratio) <= 0.03
    # closed form of the cyclic scan: only passes 2..k hit, each
    # serving cache_gib of the partition locally
    w_gib = cfg.app.dataset_gib / cfg.n_compute
    k = cfg.app.iterations
    expect = (k - 1) / k * cfg.static_cache_gib / w_gib
    assert model == pytest.approx(expect, abs=0.03)


def test_warm_start_skips_compulsory_misses():
    """warm_frac seeds the resident set: a fully warm cache whose
    working set fits the grant pays no compulsory miss, while the same
    horizon cold-started is still inside its first pass and misses
    almost everything."""
    base = CacheSpec(policy="lfu", reuse_skew=0.0, working_set_frac=0.2,
                     access_gibps=1.0, refill_gibps=1.0)
    spec = ScenarioSpec(
        name="warmup", family="constant", n_nodes=4, n_intervals=200,
        base_gib=0.0, offset_gib=20.0, amp_range=(1.0, 1.0),
        phase_shift=False, cache=base)
    # w = 25 GiB, grant pinned at 30 GiB >= w; 200 intervals scan
    # 20 GiB < w, so the whole horizon sits in the first pass
    pinned = GainSet.from_params(paper_controller_params(
        u_min=30 * GiB, u_max=30 * GiB))
    cold = run_sweep(spec, pinned, seed=0)
    warm = run_sweep(spec.replace(cache=base.replace(warm_frac=1.0)),
                     pinned, seed=0)
    assert float(warm.stats.hit_ratio[0]) == pytest.approx(1.0, abs=1e-5)
    assert float(cold.stats.hit_ratio[0]) == pytest.approx(0.0, abs=0.05)
    assert float(warm.stats.app_runtime[0]) < float(cold.stats.app_runtime[0])


# ---------------------------------------------------------------------------
# float64 numpy oracle for the streamed accumulators
# ---------------------------------------------------------------------------

def cache_oracle(demand, m, params, cache, interval_s):
    """Dense float64 reference of the CacheLoop dynamics."""
    demand = np.asarray(demand, np.float64)
    n, t = demand.shape
    m = np.broadcast_to(np.asarray(m, np.float64), (n,))
    conc = policy_model(cache.policy).concentration
    hit_exp = 1.0 - cache.reuse_skew
    w = cache.working_set_frac * m
    access = cache.access_gibps * interval_s            # GiB / interval
    refill = cache.refill_gibps * GiB * interval_s      # bytes / interval
    u = np.full(n, params.u_max)
    resident = cache.warm_frac * np.minimum(u, w)
    wf0 = resident / w                      # warm prefix of the working set
    v_prev = demand[:, 0] + resident
    hits = 0.0
    evicted = 0.0
    app = np.zeros(n)
    util_sum = 0.0
    for i in range(t):
        v = demand[:, i] + resident
        v_eff = v + params.feedforward * (v - v_prev)
        r_eff = v_eff / m
        err = r_eff - params.r0
        lam = np.where(
            err < 0,
            params.lam if params.lam_grant is None else params.lam_grant,
            params.lam)
        u_next = u - lam * v_eff * err / params.r0
        if params.deadband > 0.0:
            u_next = np.where(np.abs(err) <= params.deadband, u, u_next)
        u_next = np.clip(u_next, params.u_min, params.u_max)
        r = v / m
        util_sum += r.sum()
        res_ev = np.minimum(resident, u_next)
        ev_g = (resident - res_ev) / GiB
        f = np.minimum(res_ev / w, 1.0)
        hit = conc * f ** hit_exp + (1.0 - conc) * f
        # warmup-aware cold scan (first pass pays compulsory misses)
        cold = i * access * GiB < w
        wf = np.minimum(wf0, f)
        hit = np.where(cold, wf + cache.reuse_skew * (hit - wf), hit)
        miss_g = (1.0 - hit) * access
        resident = np.minimum(np.minimum(u_next, w),
                              res_ev + np.minimum(miss_g * GiB, refill))
        slow = np.array([hpl_slowdown(x) for x in r])
        app += (interval_s * slow + miss_g * cache.miss_penalty_s_per_gib
                + ev_g * cache.evict_penalty_s_per_gib)
        hits += (hit * access).sum()
        evicted += ev_g.sum()
        v_prev, u = v, u_next
    return {
        "hit_ratio": hits / (n * t * access),
        "evicted_bytes": evicted * GiB,
        "app_runtime": app.max(),
        "mean_utilization": util_sum / (n * t),
    }


@pytest.mark.parametrize("params_kw", [
    {},                                                     # paper law
    dict(lam=1.1, r0=0.92),
    dict(lam_grant=0.3, deadband=0.004, feedforward=0.5),   # fallback path
])
def test_streamed_cache_stats_match_numpy_oracle(params_kw):
    spec = small("cache-churn", n_nodes=24, n_intervals=300)
    p = paper_controller_params(**params_kw)
    demand = spec.build_demand(seed=6)
    m = spec.build_node_memory(seed=6)
    stats = sweep_demand(demand, GainSet.from_params(p), node_memory=m,
                         interval_s=spec.interval_s, cache=spec.cache)
    ref = cache_oracle(demand, m, p, spec.cache, spec.interval_s)
    for key, rtol in (("hit_ratio", 1e-4), ("evicted_bytes", 1e-3),
                      ("app_runtime", 1e-3), ("mean_utilization", 1e-4)):
        np.testing.assert_allclose(
            float(getattr(stats, key)[0]), ref[key], rtol=rtol,
            atol=1e-6, err_msg=key)


# ---------------------------------------------------------------------------
# Eviction / refill flux and the hit-curve knobs
# ---------------------------------------------------------------------------

def test_shrinking_grant_produces_eviction_flux():
    """Demand bursts force the controller to reclaim below the resident
    set: evicted_bytes must be positive, and a slower refill pipe must
    cost hit ratio."""
    spec = small("cache-churn", n_nodes=16, n_intervals=400)
    gains = GainSet.from_params(paper_controller_params(lam=1.2))
    r = run_sweep(spec, gains, seed=1)
    assert float(r.stats.evicted_bytes[0]) > 0.0
    assert float(r.stats.app_slowdown[0]) > 1.0
    slow_refill = spec.replace(cache=spec.cache.replace(refill_gibps=0.05))
    r2 = run_sweep(slow_refill, gains, seed=1)
    assert float(r2.stats.hit_ratio[0]) < float(r.stats.hit_ratio[0])


def test_policy_and_skew_shape_the_hit_curve():
    spec = small("spark-iterative-cache", n_nodes=16, n_intervals=300)
    gains = GainSet.from_params(PAPER_TABLE_I)

    def hit(cache):
        r = run_sweep(spec.replace(cache=cache), gains, seed=2)
        return float(r.stats.hit_ratio[0])

    base = spec.cache
    # frequency-concentrating policies exploit skewed reuse better
    assert hit(base.replace(policy="lfu")) > hit(base.replace(policy="lru"))
    assert hit(base.replace(policy="lru")) > hit(base.replace(policy="fifo"))
    # at alpha=0 (uniform / cyclic reuse) every policy collapses to h=f
    flat = base.replace(reuse_skew=0.0)
    assert hit(flat.replace(policy="lfu")) == pytest.approx(
        hit(flat.replace(policy="fifo")), rel=1e-6)
    # more skew -> more of the working set's heat fits the grant
    assert hit(base.replace(reuse_skew=0.9)) > hit(
        base.replace(reuse_skew=0.1))


def test_policy_models_registry():
    assert set(POLICY_MODELS) == {"lfu", "lru", "fifo", "adaptive"}
    assert policy_model("lfu").concentration == 1.0
    assert policy_model("lfu").concentration > \
        policy_model("lru").concentration > \
        policy_model("fifo").concentration
    with pytest.raises(ValueError):
        policy_model("belady")
    with pytest.raises(ValueError):
        PolicyModel(concentration=1.5)


def test_hpl_slowdown_curve_matches_scalar_reference():
    grid = np.linspace(0.0, 1.4, 141)
    ref = np.array([hpl_slowdown(u) for u in grid])
    np.testing.assert_allclose(np.asarray(hpl_slowdown_curve(grid)), ref,
                               rtol=1e-5)


def test_cache_spec_validation():
    with pytest.raises(ValueError):
        CacheSpec(policy="belady")
    with pytest.raises(ValueError):
        CacheSpec(reuse_skew=1.0)
    with pytest.raises(ValueError):
        CacheSpec(working_set_frac=0.0)
    with pytest.raises(ValueError):
        CacheSpec(warm_frac=1.5)
    with pytest.raises(ValueError):
        ScenarioSpec(name="bad", occupancy=0.5, cache=CacheSpec())
    with pytest.raises(ValueError):
        sweep_demand(np.ones((2, 4)), GainSet.from_params(PAPER_TABLE_I),
                     node_memory=PAPER_TABLE_I.total_memory, occupancy=0.5,
                     cache=CacheSpec())


# ---------------------------------------------------------------------------
# Chunking invariance with cache state in the carry
# ---------------------------------------------------------------------------

def test_cache_sweep_chunking_invariant():
    spec = small("cache-churn", n_nodes=16, n_intervals=200)
    gains = grid_gains(paper_controller_params(),
                       lam=(0.4, 0.9, 1.3), r0=(0.9, 0.94, 0.97))
    runs = [run_sweep(spec, gains, seed=4, chunk=c)
            for c in (None, 2, 5, 16)]
    for other in runs[1:]:
        for f in FleetStats._fields:
            np.testing.assert_array_equal(
                getattr(runs[0].stats, f), getattr(other.stats, f),
                err_msg=f)


# ---------------------------------------------------------------------------
# Specialization planning and the widened default grids
# ---------------------------------------------------------------------------

def test_specialized_path_left_only_when_knobs_active():
    p = paper_controller_params()
    paper = grid_gains(p, lam=(0.3, 0.9), r0=(0.9, 0.95))
    assert plan_specialization(paper).paper_law
    assert paper_law_mask(paper).all()
    for knob in (dict(lam_grant=(0.25,)), dict(deadband=(0.005,)),
                 dict(feedforward=(0.5,))):
        variant = grid_gains(p, lam=(0.5,), r0=(0.95,), **knob)
        assert not paper_law_mask(variant).any(), knob
        assert not plan_specialization(variant).paper_law
    # zero-valued knobs do NOT leave the fast path
    stealth = grid_gains(p, lam=(0.5,), r0=(0.95,), deadband=(0.0,),
                         feedforward=(0.0,))
    assert plan_specialization(stealth).paper_law


def test_default_grid_searches_beyond_paper_knobs():
    g = _default_candidates("grid", 64, PAPER_TABLE_I, seed=0)
    mask = paper_law_mask(g)
    assert mask.any() and not mask.all()
    assert (g.lam_grant != g.lam).any()
    assert (g.deadband > 0).any()
    assert (g.feedforward > 0).any()
    # most of the budget stays on the specialized fast path
    assert mask.mean() > 0.5


def test_mixed_law_sweep_partitions_and_matches_subsets():
    """A mixed paper/beyond-paper gain set must score identically to
    running each law class separately (partitioned dispatch)."""
    p = paper_controller_params()
    demand = np.asarray(small("bursty-serving", n_nodes=16,
                              n_intervals=200).build_demand(seed=5))
    g = _default_candidates("grid", 32, p, seed=0)
    mask = paper_law_mask(g)
    mixed = sweep_demand(demand, g, node_memory=p.total_memory,
                         interval_s=p.interval_s)
    fast = sweep_demand(demand, g.take(np.flatnonzero(mask)),
                        node_memory=p.total_memory, interval_s=p.interval_s)
    slow = sweep_demand(demand, g.take(np.flatnonzero(~mask)),
                        node_memory=p.total_memory, interval_s=p.interval_s)
    for f in FleetStats._fields:
        np.testing.assert_array_equal(
            getattr(mixed, f)[mask], getattr(fast, f), err_msg=f)
        np.testing.assert_array_equal(
            getattr(mixed, f)[~mask], getattr(slow, f), err_msg=f)


# ---------------------------------------------------------------------------
# Runtime objective through the tuners
# ---------------------------------------------------------------------------

def test_runtime_objective_tunes_modeled_runtime():
    spec = small("cache-churn", n_nodes=16, n_intervals=300)
    result = tune_gains(spec, budget=16, score_fn="runtime", seed=0)
    assert result.score >= result.baseline_score
    best = result.best_stats()
    base = tune_gains(spec, gains=GainSet.from_params(PAPER_TABLE_I),
                      score_fn="runtime", seed=0)
    assert best["app_runtime"] <= base.best_stats()["app_runtime"] + 1e-6
    # default_score now prices the slowdown too (nonzero runtime term)
    s = run_sweep(spec, GainSet.from_params(result.params), seed=0)
    assert float(default_score(s.stats)[0]) != float(
        default_score(s.stats._replace(
            app_slowdown=np.ones_like(np.asarray(s.stats.app_slowdown))))[0])


def test_resolve_objective_names_and_errors():
    assert resolve_objective("default") is default_score
    assert resolve_objective("runtime") is runtime_score
    assert resolve_objective(default_score) is default_score
    with pytest.raises(ValueError):
        resolve_objective("latency")
