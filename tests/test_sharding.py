"""Sharding policy units + a small real-device dry run.

The full 512-device dry-run is `python -m repro.launch.dryrun --all`
(results under results/dryrun/); here we test the policy logic and,
in a subprocess with 8 forced host devices, one real lower+compile of
each cell kind on a small mesh to keep the machinery honest in CI.
"""

import json
import os
import subprocess
import sys

import pytest

from repro.configs import ARCH_IDS, get_config


def test_sharding_report_divisibility():
    r = get_config("mistral-large-123b").sharding_report(16, 16)
    assert r["attn_tp"] is True
    assert "expanded" in r["attn_note"]
    assert r["mlp_tp"] and r["vocab_tp"] and r["d_model_fsdp"]

    r = get_config("qwen2-1.5b").sharding_report(16, 16)
    assert r["attn_tp"] is False          # 12 heads % 16 != 0
    assert r["mlp_tp"] is True

    r = get_config("whisper-large-v3").sharding_report(16, 16)
    assert r["attn_tp"] is False          # 20 heads % 16 != 0

    r = get_config("qwen2-moe-a2.7b").sharding_report(16, 16)
    assert r["experts_padded"] == 4       # 60 -> 64
    assert r["attn_tp"] is True           # 16 heads, 16 kv


def test_every_arch_has_a_report():
    for a in ARCH_IDS:
        r = get_config(a).sharding_report(16, 16)
        assert r["mesh"] == {"data": 16, "model": 16}


SUBPROCESS_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json, sys
import jax
from repro.launch.cells import CellSettings, build_cell
from repro.launch.mesh import activate_mesh, make_mesh
from repro.roofline.analysis import analyze_compiled

mesh = make_mesh((4, 2), ("data", "model"))
out = {}
for arch, shape in [("llama3.2-1b-smoke", "train_4k"),
                    ("llama3.2-1b-smoke", "prefill_32k"),
                    ("llama3.2-1b-smoke", "decode_32k")]:
    import repro.configs.base as B
    import dataclasses
    # shrink the benchmark shapes to smoke scale but keep the kinds
    shp = B.SHAPES[shape]
    small = dataclasses.replace(shp, seq_len=64, global_batch=8)
    B_SHAPES = dict(B.SHAPES); B.SHAPES[shape] = small
    try:
        with activate_mesh(mesh):
            fn, inputs, desc = build_cell(arch, shape, mesh,
                                          settings=CellSettings(microbatches=2 if shp.kind == "train" else 1,
                                                                attn_impl="dense"))
            compiled = jax.jit(fn).lower(*inputs).compile()
        r = analyze_compiled(compiled, desc, 8)
        out[shape] = {"flops": r["hlo_flops_per_chip"],
                      "dominant": r["roofline"]["dominant"]}
    finally:
        B.SHAPES.update(B_SHAPES)
print(json.dumps(out))
"""


@pytest.mark.slow
def test_small_mesh_dryrun_all_kinds():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    proc = subprocess.run([sys.executable, "-c", SUBPROCESS_SCRIPT],
                          capture_output=True, text=True, env=env,
                          timeout=600)
    assert proc.returncode == 0, proc.stderr[-3000:]
    out = json.loads(proc.stdout.strip().splitlines()[-1])
    assert set(out) == {"train_4k", "prefill_32k", "decode_32k"}
    assert all(v["flops"] > 0 for v in out.values())


def test_dryrun_artifacts_if_present():
    """When the full sweep has run, sanity-check its artifacts."""
    d = "results/dryrun"
    if not os.path.isdir(d) or not os.listdir(d):
        pytest.skip("full dry-run not executed in this environment")
    files = [f for f in os.listdir(d) if f.endswith(".json")]
    assert len(files) >= 33
    for f in files[:10]:
        r = json.load(open(os.path.join(d, f)))
        assert r["hlo_flops_per_chip"] > 0
        assert r["roofline"]["dominant"] in ("compute", "memory",
                                             "collective")
