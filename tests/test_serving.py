"""Serving engine: continuous batching, preemption, mixed progress."""

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import Model
from repro.models import decode as D
from repro.serving import Request, ServingConfig, ServingEngine


@pytest.fixture(scope="module")
def small_model():
    cfg = get_config("llama3.2-1b-smoke")
    m = Model(cfg, remat="none", attn_impl="dense")
    return cfg, m, m.init(jax.random.key(0))


def make_engine(small_model, **kw):
    cfg, m, params = small_model
    sc = ServingConfig(max_batch=kw.pop("max_batch", 3),
                       max_len=kw.pop("max_len", 64),
                       block_tokens=kw.pop("block_tokens", 8), **kw)
    return cfg, ServingEngine(m, params, sc)


def test_engine_drains_all_requests(small_model):
    cfg, eng = make_engine(small_model)
    rng = np.random.default_rng(0)
    rids = [eng.submit(rng.integers(0, cfg.vocab_size, 7), 5)
            for _ in range(7)]
    fin = eng.run_until_drained(max_steps=2000)
    assert sorted(fin) == sorted(rids)
    assert all(len(r.output) == 5 for r in fin.values())


def test_mixed_progress_equals_isolated(small_model):
    """A request served alongside others (staggered admission, different
    positions per slot) must produce the same tokens as served alone --
    the per-sequence position machinery end-to-end."""
    cfg, m, params = small_model
    rng = np.random.default_rng(1)
    prompts = [rng.integers(0, cfg.vocab_size, n) for n in (5, 9, 3)]

    def serve(prompt_list):
        eng = ServingEngine(m, params,
                            ServingConfig(max_batch=3, max_len=64,
                                          block_tokens=8,
                                          cache_dtype="float32"))
        rids = [eng.submit(p, 6) for p in prompt_list]
        fin = eng.run_until_drained(max_steps=2000)
        return [fin[r].output for r in rids]

    together = serve(prompts)
    alone = [serve([p])[0] for p in prompts]
    assert together == alone


def test_preemption_requeues_and_finishes(small_model):
    cfg, eng = make_engine(small_model)
    rng = np.random.default_rng(2)
    for _ in range(6):
        eng.submit(rng.integers(0, cfg.vocab_size, 12), 10)
    for _ in range(8):
        eng.step()
    eng.pool.set_capacity(eng.pool.block_bytes * 3)
    for _ in range(4):
        eng.step()
    eng.pool.set_capacity(eng.pool.block_bytes * eng.pool.total_blocks)
    fin = eng.run_until_drained(max_steps=5000)
    st = eng.stats()
    assert len(fin) == 6
    assert st["preemptions"] >= 1
    assert all(len(r.output) == 10 for r in fin.values())


def test_preempted_output_preserved(small_model):
    """Preemption keeps generated tokens: on re-admission the sequence
    continues, it does not restart generation."""
    cfg, m, params = small_model
    eng = ServingEngine(m, params,
                        ServingConfig(max_batch=1, max_len=64,
                                      block_tokens=4,
                                      cache_dtype="float32"))
    rng = np.random.default_rng(3)
    prompt = rng.integers(0, cfg.vocab_size, 6)
    rid = eng.submit(prompt, 8)
    for _ in range(9):
        eng.step()
    req = eng.slots[0].request
    tokens_before = list(req.output)
    assert tokens_before
    eng.pool.set_capacity(0)                     # hard burst
    eng.step()
    assert eng.queue and eng.queue[0].rid == rid
    eng.pool.set_capacity(eng.pool.block_bytes * eng.pool.total_blocks)
    fin = eng.run_until_drained(max_steps=4000)
    assert fin[rid].output[:len(tokens_before)] == tokens_before
    assert len(fin[rid].output) == 8
    assert fin[rid].preemptions >= 1


def test_admission_respects_pool_budget(small_model):
    cfg, m, params = small_model
    eng = ServingEngine(m, params,
                        ServingConfig(max_batch=3, max_len=64,
                                      block_tokens=8))
    eng.pool.set_capacity(eng.pool.block_bytes * 2)   # room for 1 request
    rng = np.random.default_rng(4)
    for _ in range(3):
        eng.submit(rng.integers(0, cfg.vocab_size, 8), 4)
    eng.step()
    assert sum(not s.free for s in eng.slots) == 1
    assert len(eng.queue) == 2
