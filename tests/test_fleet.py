"""FleetPlane: arbiter invariants, nested-plane composition, fused
sweep parity, and torn-budget audits.

The arbiter invariants (conservation, floor respect, starvation
freedom) are checked three ways: directly on the float64 reference,
on the batched jax path against that reference, and end-to-end on the
fused sweep's streamed :class:`FleetExtras`.
"""

import os
import subprocess
import sys
import threading
import time

import numpy as np
import pytest

from repro.core.cluster_sim import paper_controller_params
from repro.core.control import ControllerParams
from repro.core.monitor import SimulatedMonitor
from repro.core.plane import NodeSpec, PlaneSpec
from repro.core.traces import GiB
from repro.fleet import (FleetArbiter, FleetExtras, FleetPlane,
                         FleetScenario, FleetSpec, FleetTenant,
                         MIN_TENANT_BUDGET, POLICIES, TenantMonitor,
                         TenantSpec, TenantTelemetry, arbitrate,
                         arbitrate_reference, fleet_reference,
                         fleet_sweep_demand, get_fleet_scenario,
                         list_fleet_scenarios, run_fleet_sweep)
from repro.lab import FleetStats, get_scenario, grid_gains
from repro.lab.scenarios import ScenarioSpec
from repro.runtime.churn import FAILED_DEMAND, churn_demand

M = 125.0 * GiB


def _params(**kw):
    kw.setdefault("total_memory", M)
    kw.setdefault("u_max", 60.0 * GiB)
    kw.setdefault("interval_s", 0.01)
    return ControllerParams(**kw)


def _tenant_spec(name, usage_gib, n_nodes=2, **kw):
    nodes = tuple(
        NodeSpec(f"{name}-n{i}", monitor=SimulatedMonitor(
            f"{name}-n{i}", total=M, usage=lambda t, g=usage_gib: g * GiB))
        for i in range(n_nodes))
    return TenantSpec(name, PlaneSpec(params=_params(), nodes=nodes), **kw)


def _three_tenants(**fleet_kw):
    return FleetSpec(
        tenants=(
            _tenant_spec("heavy", 45.0, weight=3.0, priority=2,
                         floor_gib=10.0),
            _tenant_spec("steady", 25.0, weight=1.5, priority=1,
                         floor_gib=8.0),
            _tenant_spec("light", 8.0, weight=1.0, priority=0),
        ),
        **fleet_kw)


# ---------------------------------------------------------------------------
# Specs
# ---------------------------------------------------------------------------

def test_spec_validation():
    plane = _tenant_spec("a", 10.0).plane
    with pytest.raises(ValueError):
        TenantSpec("", plane)
    with pytest.raises(ValueError):
        TenantSpec("a", plane, weight=0.0)
    with pytest.raises(ValueError):
        TenantSpec("a", plane, floor_gib=-1.0)
    with pytest.raises(ValueError):
        FleetSpec(tenants=())
    with pytest.raises(ValueError):                      # duplicate names
        FleetSpec(tenants=(TenantSpec("a", plane), TenantSpec("a", plane)))
    with pytest.raises(ValueError):
        FleetSpec(tenants=(TenantSpec("a", plane),), policy="lottery")
    with pytest.raises(ValueError):                      # floors > memory
        FleetSpec(tenants=(TenantSpec("a", plane, floor_gib=100.0),
                           TenantSpec("b", plane, floor_gib=50.0)),
                  fleet_memory_gib=125.0)
    spec = _three_tenants()
    assert spec.names == ("heavy", "steady", "light")
    assert spec.priority_order() == (0, 1, 2)
    assert len(spec) == 3
    # priority ties break in declaration order
    flat = spec.replace(tenants=tuple(
        t.replace(priority=0) for t in spec.tenants))
    assert flat.priority_order() == (0, 1, 2)


def test_nested_plane_rejects_per_node_params():
    base = _tenant_spec("a", 10.0)
    pinned = base.plane.nodes[0].replace(
        params=_params(total_memory=64 * GiB))
    bad = base.replace(plane=base.plane.replace(
        nodes=(pinned,) + base.plane.nodes[1:]))
    with pytest.raises(ValueError, match="per-node params"):
        FleetPlane(FleetSpec(tenants=(bad,)))


# ---------------------------------------------------------------------------
# Arbiter policies: invariants + scalar/batched parity
# ---------------------------------------------------------------------------

def _random_problem(rng, k=4, n=6):
    desired = rng.uniform(0.0, 80.0, (k, n)) * GiB
    m = rng.uniform(64.0, 160.0, n) * GiB
    weights = rng.uniform(0.5, 4.0, k)
    floors = rng.uniform(0.0, 12.0, k) * GiB
    return desired, m, weights, floors


@pytest.mark.parametrize("policy", POLICIES)
def test_arbitrate_reference_invariants(policy):
    """Conservation, floor respect, demand boundedness -- every node,
    every policy, jittered node memories."""
    rng = np.random.default_rng(7)
    for trial in range(20):
        desired, m, weights, floors = _random_problem(rng)
        k = desired.shape[0]
        alloc = arbitrate_reference(
            desired, m, weights=weights, floors=floors,
            priority_order=tuple(range(k)), policy=policy,
            rr_offset=trial % k)
        assert (alloc >= 0).all()
        # conservation: sum over tenants never exceeds the node
        assert (alloc.sum(0) <= m * (1 + 1e-9)).all(), trial
        # floor respect: every tenant holds its (admissible) floor
        f = np.maximum(floors[:, None], MIN_TENANT_BUDGET)
        f_eff = f * np.minimum(1.0, m / np.maximum(f.sum(0), 1.0))
        assert (alloc >= f_eff * (1 - 1e-9)).all(), trial
        # demand boundedness: nobody gets more than it asked (or floor)
        assert (alloc <= np.maximum(desired, f_eff) + 1.0).all(), trial


@pytest.mark.parametrize("policy", POLICIES)
def test_arbitrate_matches_reference(policy):
    """Batched (tenants x nodes) jax path pinned to the float64 oracle."""
    rng = np.random.default_rng(3)
    for trial in range(5):
        desired, m, weights, floors = _random_problem(rng, k=5, n=4)
        k = desired.shape[0]
        order = tuple(rng.permutation(k))
        kw = dict(weights=weights, floors=floors, priority_order=order,
                  policy=policy, rr_offset=trial)
        ref = arbitrate_reference(desired, m, **kw)
        got = np.asarray(arbitrate(desired.astype(np.float32),
                                   m.astype(np.float32), **kw))
        np.testing.assert_allclose(got, ref, rtol=1e-4, atol=1024.0)


def test_priority_starves_only_without_floor():
    """Strict priority drains the pool top-down: a floorless last-place
    tenant is starved under scarcity, a floor protects it."""
    desired = np.full((3, 1), 80.0) * GiB
    m = np.array([100.0 * GiB])
    kw = dict(weights=np.ones(3), priority_order=(0, 1, 2),
              policy="priority")
    starved = arbitrate_reference(desired, m, floors=np.zeros(3), **kw)
    assert starved[0, 0] == pytest.approx(80.0 * GiB)
    assert starved[2, 0] <= MIN_TENANT_BUDGET  # floorless: starved
    floored = arbitrate_reference(desired, m,
                                  floors=np.array([0, 0, 15.0 * GiB]), **kw)
    assert floored[2, 0] >= 15.0 * GiB * (1 - 1e-9)


def test_round_robin_rotation_is_starvation_free():
    """Over K consecutive epochs every tenant heads the chain once, so
    each gets the full pool at least once even with zero floors."""
    k = 3
    desired = np.full((k, 1), 90.0) * GiB
    m = np.array([100.0 * GiB])
    best = np.zeros(k)
    for off in range(k):
        alloc = arbitrate_reference(
            desired, m, weights=np.ones(k), floors=np.zeros(k),
            priority_order=tuple(range(k)), policy="round_robin",
            rr_offset=off)
        best = np.maximum(best, alloc[:, 0])
    assert (best >= 90.0 * GiB * (1 - 1e-9)).all()


def test_proportional_waterfill_redistributes():
    """A satisfied tenant's leftover share re-divides among the hungry
    (max-min), and grants follow weights when everyone is hungry."""
    m = np.array([100.0 * GiB])
    alloc = arbitrate_reference(
        np.array([[10.0], [200.0], [200.0]]) * GiB, m,
        weights=np.array([2.0, 1.0, 1.0]), floors=np.zeros(3),
        priority_order=(0, 1, 2), policy="proportional")
    assert alloc[0, 0] == pytest.approx(10.0 * GiB)       # capped at desire
    assert alloc[1, 0] == pytest.approx(45.0 * GiB, rel=1e-6)
    assert alloc[2, 0] == pytest.approx(45.0 * GiB, rel=1e-6)
    hungry = arbitrate_reference(
        np.full((2, 1), 500.0) * GiB, m,
        weights=np.array([3.0, 1.0]), floors=np.zeros(2),
        priority_order=(0, 1), policy="proportional")
    # rel 1e-4: both tenants hold the 1 MiB minimum before weighting
    assert hungry[0, 0] / hungry[1, 0] == pytest.approx(3.0, rel=1e-4)


def test_fleet_arbiter_runtime():
    spec = _three_tenants(policy="round_robin")
    arb = FleetArbiter(spec)
    b0 = arb.initial_budgets(M)
    assert sum(b0.values()) == pytest.approx(M, rel=1e-9)
    assert b0["heavy"] > b0["light"]                      # weight share
    tele = {n: TenantTelemetry(usage_bytes=20.0 * GiB, budget_bytes=b)
            for n, b in b0.items()}
    g1 = arb.allocate(tele, M)
    g2 = arb.allocate(tele, M)
    assert (g1.epoch, g2.epoch) == (1, 2)
    assert arb.last_grant() is g2
    assert g2.total() <= M * (1 + 1e-9)
    # missing telemetry bids the floor, not garbage
    g3 = arb.allocate({}, M)
    assert g3.budgets["light"] <= MIN_TENANT_BUDGET * (1 + 1e-9)
    # telemetry derived quantities
    t = TenantTelemetry(usage_bytes=30.0, budget_bytes=40.0, hit_ratio=0.5)
    assert t.pressure == pytest.approx(0.75)
    assert t.slack_bytes == pytest.approx(10.0)
    assert t.desired_bytes(r0=1.0) == pytest.approx(45.0)  # miss headroom


# ---------------------------------------------------------------------------
# Live FleetPlane
# ---------------------------------------------------------------------------

def test_fleet_plane_end_to_end():
    """3 tenants x 5 epochs: budgets track demand, conservation holds
    at every epoch, nested actions are epoch-stamped."""
    spec = _three_tenants(epoch_intervals=4)
    with FleetPlane(spec) as fp:
        seen = []
        for _ in range(20):
            actions = fp.tick()
            assert set(actions) == {"heavy", "steady", "light"}
            b = fp.budgets()
            assert sum(b.values()) <= M * (1 + 1e-9)
            seen.append(b)
        assert fp.epoch == 5
        final = fp.budgets()
        # budgets track demand: heavy (45G usage) outranks light (8G)
        assert final["heavy"] > final["steady"] > final["light"]
        # nested monitors observe the grant, not the node
        mon = fp.plane("light").spec.nodes[0].monitor
        assert isinstance(mon, TenantMonitor)
        assert mon.sample().total == pytest.approx(final["light"])
        # every rebalance rode the epoch-stamped swap machinery
        acts = fp.plane("heavy").tick()
        assert acts and acts[0].epoch == 5
        assert fp.last_grant().epoch == 5
        assert 0.0 < fp.fleet_utilization() < 1.0


def test_torn_budget_audit_under_concurrent_ticks():
    """A ticking fleet + a budget-sampling auditor: the instantaneous
    budget sum stays conserving through every mid-rebalance window
    (shrink-first commit order), and no tick ever observes a tenant
    interval under a torn budget (actions within one tick share one
    params epoch per tenant)."""
    spec = _three_tenants(epoch_intervals=2)
    violations = []
    stop = threading.Event()

    def audit(fp):
        while not stop.is_set():
            total = sum(fp.budgets().values())
            if total > M * (1 + 1e-9):
                violations.append(total)

    with FleetPlane(spec) as fp:
        auditor = threading.Thread(target=audit, args=(fp,))
        auditor.start()
        try:
            for _ in range(30):
                actions = fp.tick()
                for name, acts in actions.items():
                    epochs = {a.epoch for a in acts}
                    assert len(epochs) <= 1, (name, epochs)
        finally:
            stop.set()
            auditor.join()
    assert not violations
    assert fp.epoch == 15


# ---------------------------------------------------------------------------
# Fused fleet sweep vs the scalar oracle
# ---------------------------------------------------------------------------

def _small_problem(k=3, n=6, t=120, seed=0):
    rng = np.random.default_rng(seed)
    base = rng.uniform(10.0, 45.0, (k, 1, 1))
    wave = 1.0 + 0.4 * np.sin(
        np.linspace(0, 6 * np.pi, t) + rng.uniform(0, np.pi, (k, n, 1)))
    demand = (base * wave * (0.9 + 0.2 * rng.random((k, n, 1)))) * GiB
    weights = np.array([3.0, 1.5, 1.0])[:k]
    floors = np.array([10.0, 8.0, 0.0])[:k] * GiB
    return demand.astype(np.float64), weights, floors


def _gains(n=2):
    p = paper_controller_params()
    return grid_gains(p, lam=np.linspace(0.3, 0.9, n),
                      r0=np.linspace(0.9, 0.96, n))


@pytest.mark.parametrize("policy", POLICIES)
def test_fleet_sweep_matches_reference(policy):
    """The fused (tenants x nodes) jitted scan is pinned to the scalar
    float64 oracle across all policies -- stats and streamed extras."""
    demand, weights, floors = _small_problem()
    gains = _gains()
    kw = dict(node_memory=M, weights=weights, floors=floors,
              policy=policy, priority_order=(2, 0, 1),
              epoch_intervals=30, interval_s=0.1)
    stats, extras = fleet_sweep_demand(demand, gains, **kw)
    ref_stats, ref_extras = fleet_reference(demand, gains, **kw)
    for f in FleetStats._fields:
        got, want = np.asarray(getattr(stats, f)), getattr(ref_stats, f)
        # p99 rides the streaming-quantile bracket plus order-statistic
        # sensitivity to f32-vs-f64 closed-loop drift on a small sample
        atol = 1e-2 if f == "p99_utilization" else 1e-4
        np.testing.assert_allclose(got, want, rtol=2e-4, atol=atol,
                                   err_msg=f)
    for f in FleetExtras._fields:
        np.testing.assert_allclose(
            np.asarray(getattr(extras, f)), getattr(ref_extras, f),
            rtol=2e-4, atol=1e-3, err_msg=f)


@pytest.mark.parametrize("policy", POLICIES)
def test_fleet_sweep_extras_invariants(policy):
    """The streamed worst-case slacks certify the arbitration
    invariants held at every (epoch, node) the sweep performed."""
    demand, weights, floors = _small_problem(seed=5)
    stats, extras = fleet_sweep_demand(
        demand, _gains(), node_memory=M, weights=weights, floors=floors,
        policy=policy, epoch_intervals=20, interval_s=0.1)
    ex = FleetExtras(*(np.asarray(f) for f in extras))
    # conservation: sum_k B[k] <= M everywhere (1e-3 GiB ~ f32 rounding)
    assert (ex.conservation_slack_gib >= -1e-3).all()
    # floors held everywhere
    assert (ex.floor_slack_gib >= -1e-3).all()
    assert (ex.tenant_budget_min_gib <= ex.tenant_budget_mean_gib
            + 1e-6).all()
    # starvation-freedom: floors (or rotation) keep every tenant alive
    if policy != "priority":
        assert (ex.tenant_budget_min_gib > 0.0).all()
    assert np.isfinite(np.asarray(stats.mean_utilization)).all()


def test_fleet_sweep_chunk_invariance():
    """Gain-chunking is invisible, exactly as in the lab engine."""
    demand, weights, floors = _small_problem(k=2, n=4, t=60, seed=2)
    gains = _gains(3)
    kw = dict(node_memory=M, weights=weights[:2], floors=floors[:2],
              epoch_intervals=20, interval_s=0.1)
    base = fleet_sweep_demand(demand, gains, **kw)
    for chunk in (2, 9):
        other = fleet_sweep_demand(demand, gains, chunk=chunk, **kw)
        for got, want, f in zip(other[0] + other[1], base[0] + base[1],
                                FleetStats._fields + FleetExtras._fields):
            np.testing.assert_array_equal(np.asarray(got),
                                          np.asarray(want), err_msg=f)


def test_fleet_sweep_single_device_node_shards_fallback():
    """Requesting node sharding on one device falls back bit-exactly to
    the unsharded program."""
    demand, weights, floors = _small_problem(k=2, n=4, t=60, seed=3)
    kw = dict(node_memory=M, weights=weights[:2], floors=floors[:2],
              epoch_intervals=20, interval_s=0.1, devices=1)
    plain = fleet_sweep_demand(demand, _gains(), node_shards=1, **kw)
    sharded = fleet_sweep_demand(demand, _gains(), node_shards=4, **kw)
    for got, want, f in zip(sharded[0] + sharded[1], plain[0] + plain[1],
                            FleetStats._fields + FleetExtras._fields):
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want),
                                      err_msg=f)


def test_fleet_sweep_validates_args():
    demand, weights, floors = _small_problem(k=2, n=4, t=60)
    kw = dict(node_memory=M, weights=weights[:2], floors=floors[:2])
    with pytest.raises(ValueError):                       # ragged epochs
        fleet_sweep_demand(demand, _gains(), epoch_intervals=7, **kw)
    with pytest.raises(ValueError):                       # bad order
        fleet_sweep_demand(demand, _gains(), epoch_intervals=20,
                           priority_order=(0, 0), **kw)
    with pytest.raises(ValueError):
        fleet_sweep_demand(demand[0], _gains(), epoch_intervals=20, **kw)
    with pytest.raises(ValueError):
        fleet_sweep_demand(demand, _gains(), epoch_intervals=20,
                           node_memory=M, weights=weights,
                           floors=np.zeros(3))            # (3,) vs k=2


# ---------------------------------------------------------------------------
# Scenario composition + runtime churn
# ---------------------------------------------------------------------------

def test_registered_fleet_scenarios():
    names = list_fleet_scenarios()
    assert {"hpcc-spark", "tenant-churn"} <= set(names)
    fs = get_fleet_scenario("tenant-churn")
    assert fs.n_tenants == 3 and fs.n_nodes == 24
    d = fs.build_demand(seed=0)
    assert d.shape == (3, 24, 480) and (d >= 0).all()
    # tenants decorrelate under one seed but stay deterministic
    assert np.array_equal(d, fs.build_demand(seed=0))
    # composition validation
    with pytest.raises(ValueError):                       # shape mismatch
        FleetScenario("bad", tenants=(
            FleetTenant("a", "runtime-churn"),
            FleetTenant("b", "paper-c3-dynims60")))
    with pytest.raises(ValueError):                       # ragged epochs
        FleetScenario("bad", tenants=(FleetTenant("a", "runtime-churn"),),
                      epoch_intervals=7)
    with pytest.raises(KeyError):
        get_fleet_scenario("no-such-fleet")


def test_runtime_churn_scenario():
    """The fault machinery actually drives the registered trace:
    stragglers get squeezed then evicted, heartbeat failures collapse
    demand to the OS baseline and recover."""
    demand, events = churn_demand(n_nodes=12, n_intervals=240, seed=1)
    assert demand.shape == (12, 240)
    assert events["squeeze"] and events["evict"]
    assert events["fail"] and events["recover"]
    assert min(events["evict"]) > min(events["squeeze"])  # escalation
    # a failed node's demand collapses toward the OS baseline
    t_fail = events["fail"][0]
    col = demand[:, t_fail]
    assert col.min() <= FAILED_DEMAND * demand[:, 0].max() * 1.5
    # deterministic in the seed
    d2, e2 = churn_demand(n_nodes=12, n_intervals=240, seed=1)
    assert np.array_equal(demand, d2) and events == e2
    # and the lab registry serves the replay spec
    spec = get_scenario("runtime-churn")
    assert spec.family == "replay"
    assert spec.build_demand(seed=0).shape == (24, 480)


def test_run_fleet_sweep_tenant_churn():
    fs = get_fleet_scenario("tenant-churn")
    stats, extras = run_fleet_sweep(fs, _gains(), seed=0)
    assert np.asarray(stats.mean_utilization).shape == (4,)
    assert (np.asarray(extras.conservation_slack_gib) >= -1e-3).all()
    assert (np.asarray(extras.floor_slack_gib) >= -1e-3).all()


def test_cell_tenant_deployment():
    """launch/cells wraps a benchmark cell's plane as a fleet tenant
    with kind-derived priority and parameter-derived weight."""
    from repro.launch.cells import DEFAULT_CELL_PRIORITY, cell_tenant
    plane = _tenant_spec("cell", 10.0).plane
    t = cell_tenant("hymba-1.5b", "decode_32k", plane=plane,
                    floor_gib=4.0)
    assert t.name == "hymba-1.5b:decode_32k"
    assert t.priority == DEFAULT_CELL_PRIORITY["decode"] == 2
    assert t.weight > 0 and t.floor_gib == 4.0
    train = cell_tenant("hymba-1.5b", "train_4k", plane=plane)
    assert train.priority == DEFAULT_CELL_PRIORITY["train"] == 0
    # the tenant composes into an arbitrable fleet
    spec = FleetSpec(tenants=(t.replace(name="serve"),
                              train.replace(name="train")))
    assert FleetArbiter(spec).initial_budgets(M)["serve"] > 0


# ---------------------------------------------------------------------------
# 2-D (gains x nodes) device mesh
# ---------------------------------------------------------------------------

MESH2D_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import numpy as np, jax
from repro.core.cluster_sim import paper_controller_params
from repro.core.traces import GiB, fleet_demand_traces
from repro.lab import FleetStats, grid_gains, sweep_demand
from repro.fleet import FleetExtras, fleet_sweep_demand
assert len(jax.local_devices()) == 4
p = paper_controller_params()
gains = grid_gains(p, lam=(0.3, 0.6, 0.9, 1.2), r0=(0.9, 0.95))

# lab engine on the (gains x nodes) mesh vs single device
demand = fleet_demand_traces(32, 200, p.interval_s, seed=3)
single = sweep_demand(demand, gains, node_memory=p.total_memory,
                      interval_s=p.interval_s, devices=1)
for ns in (2, 4):          # 2x2 and 1x4 meshes
    multi = sweep_demand(demand, gains, node_memory=p.total_memory,
                         interval_s=p.interval_s, node_shards=ns)
    for f in FleetStats._fields:
        np.testing.assert_allclose(
            np.asarray(getattr(multi, f)), np.asarray(getattr(single, f)),
            rtol=2e-4, atol=2e-3, err_msg=("lab", ns, f))

# fleet engine: the composed two-level loop on the same meshes
rng = np.random.default_rng(0)
fdem = rng.uniform(10.0, 45.0, (3, 16, 120)) * GiB
kw = dict(node_memory=p.total_memory, weights=np.array([3.0, 1.5, 1.0]),
          floors=np.array([10.0, 8.0, 0.0]) * GiB, epoch_intervals=30,
          interval_s=p.interval_s)
fs, fe = fleet_sweep_demand(fdem, gains, devices=1, **kw)
for ns in (2, 4):
    ms, me = fleet_sweep_demand(fdem, gains, node_shards=ns, **kw)
    for f in FleetStats._fields:
        np.testing.assert_allclose(
            np.asarray(getattr(ms, f)), np.asarray(getattr(fs, f)),
            rtol=2e-4, atol=2e-3, err_msg=("fleet", ns, f))
    for f in FleetExtras._fields:
        np.testing.assert_allclose(
            np.asarray(getattr(me, f)), np.asarray(getattr(fe, f)),
            rtol=2e-4, atol=2e-3, err_msg=("fleet-extras", ns, f))
print("MESH2D_PARITY_OK")
"""


@pytest.mark.slow
def test_2d_mesh_matches_single_device():
    """(gains x nodes) shard_map over 4 forced host devices agrees with
    the single-device program for both the lab and fleet engines (the
    single-device fallback itself is bit-exact; cross-device psum
    reassociation allows small float drift)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    proc = subprocess.run([sys.executable, "-c", MESH2D_SCRIPT],
                          capture_output=True, text=True, env=env,
                          timeout=900)
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "MESH2D_PARITY_OK" in proc.stdout
