"""Shared fixtures: the PlaneCheck runtime-sanitizer hooks.

With ``PLANECHECK_SANITIZERS=1`` in the environment (the CI
fast-suites job sets it), ``repro.lab.sweep`` dispatches its chunk
loop under ``jax.transfer_guard("disallow")`` and the session-end gate
below asserts the sweep hot path compiled exactly once per
(chunk, horizon, nodes, specialization) shape.  Locally both are
no-ops unless the variable is exported.
"""

import pytest

from repro.analysis import runtime as pc_runtime


@pytest.fixture
def planecheck_sanitizers(monkeypatch):
    """Force-enable the runtime sanitizers for one test."""
    monkeypatch.setenv("PLANECHECK_SANITIZERS", "1")
    return pc_runtime


@pytest.fixture(scope="session", autouse=True)
def _recompile_gate():
    """Whole-run recompile gate over the sweep hot path.

    Scoped to ``lab.sweep.chunk``: its executable cache is keyed by
    (devices, specialization, cache) + input shapes, so within one
    process every counter key must trace exactly once.  (The
    ``plane.fused_step`` counter is *not* gated here -- tests build
    many planes, and each ``make_fused_step`` call legitimately
    compiles its own instance at the same fleet size.)
    """
    yield
    if pc_runtime.sanitizers_enabled():
        excess = pc_runtime.excess_traces("lab.sweep.chunk")
        assert not excess, (
            "sweep hot path retraced (same shape compiled more than "
            f"once): {excess}")
