"""Shared fixtures + hooks: the PlaneCheck runtime-sanitizer gates.

With ``PLANECHECK_SANITIZERS=1`` in the environment (the CI
fast-suites job sets it), ``repro.lab.sweep`` dispatches its chunk
loop under ``jax.transfer_guard("disallow")`` and the session-level
hooks below assert the sweep hot path compiled exactly once per
counter key -- (chunk, horizon, nodes) shape plus the specialization
digest of its executable cache entry.  Locally both are no-ops unless
the variable is exported.

The gate reports through ``pytest_terminal_summary`` and fails the
run via ``pytest_sessionfinish`` -- not from a fixture teardown, which
would surface as an ERROR on whichever test happened to run last and
bury the actual cause.
"""

import pytest

from repro.analysis import runtime as pc_runtime

# Only the sweep hot path is gated -- both engines: the XLA chunk
# loop ("lab.sweep.chunk") and the PallasSweep dispatch
# ("lab.sweep.pallas").  The ``plane.fused_step`` counter is *not*:
# tests build many planes, and each ``make_fused_step`` call
# legitimately compiles its own instance at the same fleet size.
_GATED_PREFIX = "lab.sweep."


@pytest.fixture
def planecheck_sanitizers(monkeypatch):
    """Force-enable the runtime sanitizers for one test."""
    monkeypatch.setenv("PLANECHECK_SANITIZERS", "1")
    return pc_runtime


def _gate_excess():
    if not pc_runtime.sanitizers_enabled():
        return {}
    return pc_runtime.excess_traces(_GATED_PREFIX)


def pytest_terminal_summary(terminalreporter, exitstatus, config):
    excess = _gate_excess()
    if not excess:
        return
    terminalreporter.section("PlaneCheck recompile gate", sep="=", red=True)
    terminalreporter.write_line(
        "sweep hot path retraced -- the same executable-cache key "
        "compiled more than once this session:")
    for key, n in sorted(excess.items()):
        terminalreporter.write_line(f"  {key}: {n} traces")
    terminalreporter.write_line(
        "Each key is (shape dims + specialization digest); a count > 1 "
        "means a retrace leak (shape drift, non-hashable static arg, or "
        "a counter key coarser than the jit cache key).")


def pytest_sessionfinish(session, exitstatus):
    if _gate_excess():
        session.exitstatus = 1
