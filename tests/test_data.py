"""Data pipeline: determinism, caching, prefetch, corpus store."""

import numpy as np
import pytest

from repro.data import DataPipeline, PipelineConfig, ShardStore, write_corpus


@pytest.fixture()
def store(tmp_path):
    path = str(tmp_path / "corpus")
    write_corpus(path, n_shards=6, tokens_per_shard=2048, vocab_size=101,
                 seed=3)
    return ShardStore(path)


def test_corpus_deterministic(tmp_path, store):
    path2 = str(tmp_path / "corpus2")
    write_corpus(path2, n_shards=6, tokens_per_shard=2048, vocab_size=101,
                 seed=3)
    s2 = ShardStore(path2)
    np.testing.assert_array_equal(store.read(2), s2.read(2))


def test_batches_deterministic_by_step(store):
    cfg = PipelineConfig(batch_size=4, seq_len=32, seed=9,
                         prefetch_depth=0, dynims=False)
    p1 = DataPipeline(store, cfg)
    p2 = DataPipeline(store, cfg)
    b1 = p1.batch(17)
    b2 = p2.batch(17)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    # restart safety: computing step 17 after 0..16 == computing it cold
    p3 = DataPipeline(store, cfg)
    for s in range(17):
        p3.batch(s)
    b3 = p3.batch(17)
    np.testing.assert_array_equal(b1["tokens"], b3["tokens"])
    p1.close(), p2.close(), p3.close()


def test_labels_are_shifted_tokens(store):
    cfg = PipelineConfig(batch_size=2, seq_len=16, prefetch_depth=0,
                         dynims=False)
    p = DataPipeline(store, cfg)
    plan = p._plan(0)
    b = p.batch(0)
    sid, off = plan[0]
    shard = store.read(int(sid))
    np.testing.assert_array_equal(b["tokens"][0], shard[off:off + 16])
    np.testing.assert_array_equal(b["labels"][0],
                                  shard[off + 1:off + 17])
    p.close()


def test_cache_reduces_store_reads(store):
    cfg = PipelineConfig(batch_size=8, seq_len=32, cache_bytes=1 << 20,
                         prefetch_depth=0, dynims=False)
    p = DataPipeline(store, cfg)
    for s in range(20):
        p.batch(s)
    assert store.reads <= 6                  # every shard read at most once
    assert p.hit_ratio > 0.5
    p.close()


def test_cache_shrink_forces_rereads(store):
    cfg = PipelineConfig(batch_size=8, seq_len=32, cache_bytes=1 << 20,
                         prefetch_depth=0, dynims=False)
    p = DataPipeline(store, cfg)
    for s in range(5):
        p.batch(s)
    reads_before = store.reads
    p.cache.set_capacity(0)                  # burst: drop everything
    p.cache.set_capacity(1 << 20)
    for s in range(5, 10):
        p.batch(s)
    assert store.reads > reads_before        # had to refetch
    p.close()
