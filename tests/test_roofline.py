"""Roofline machinery: the HLO cost model against known programs."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.roofline import PEAK_FLOPS, parse_collectives, roofline_terms
from repro.roofline.analysis import model_flops
from repro.roofline.hlo_cost import hlo_cost

UNIT = 2 * 1024 ** 3          # one 1024^3 matmul


def _chain(nl, remat):
    def body(x, w):
        return jnp.tanh(jnp.dot(x, w)), None

    def f(x, ws):
        g = jax.checkpoint(body) if remat else body
        x, _ = jax.lax.scan(g, x, ws)
        return x.sum()
    return f


@pytest.mark.parametrize("nl,remat,expect", [
    (4, False, 12), (4, True, 16), (8, False, 24), (8, True, 32)])
def test_hlo_cost_counts_loop_trips(nl, remat, expect):
    """fwd (N) + bwd (2N) [+ remat recompute (N)] matmuls, with the scan
    trip count applied -- the thing backend cost_analysis gets wrong."""
    x = jax.ShapeDtypeStruct((1024, 1024), jnp.float32)
    ws = jax.ShapeDtypeStruct((nl, 1024, 1024), jnp.float32)
    c = jax.jit(jax.value_and_grad(_chain(nl, remat),
                                   argnums=(0, 1))).lower(x, ws).compile()
    r = hlo_cost(c.as_text())
    assert r["flops"] == pytest.approx(expect * UNIT, rel=1e-6)


def test_backend_cost_analysis_is_wrong_on_loops():
    """Documents WHY hlo_cost exists: the backend reports loop-invariant
    flops (if this ever starts passing trip counts, simplify!)."""
    x = jax.ShapeDtypeStruct((1024, 1024), jnp.float32)
    ws = jax.ShapeDtypeStruct((8, 1024, 1024), jnp.float32)
    c = jax.jit(jax.value_and_grad(_chain(8, False),
                                   argnums=(0, 1))).lower(x, ws).compile()
    analysis = c.cost_analysis()
    if isinstance(analysis, list):       # jax <= 0.4.x: one dict per device
        analysis = analysis[0]
    backend = analysis["flops"]
    ours = hlo_cost(c.as_text())["flops"]
    assert ours >= 3 * backend


def test_remat_reduces_bytes():
    x = jax.ShapeDtypeStruct((1024, 1024), jnp.float32)
    ws = jax.ShapeDtypeStruct((8, 1024, 1024), jnp.float32)
    plain = hlo_cost(jax.jit(jax.value_and_grad(
        _chain(8, False), argnums=(0, 1))).lower(x, ws).compile().as_text())
    remat = hlo_cost(jax.jit(jax.value_and_grad(
        _chain(8, True), argnums=(0, 1))).lower(x, ws).compile().as_text())
    assert remat["bytes"] < plain["bytes"]


def test_roofline_terms_and_dominance():
    t = roofline_terms(hlo_flops_per_chip=197e12,       # exactly 1 s
                       hlo_bytes_per_chip=819e9 / 2,    # 0.5 s
                       collective_bytes_per_chip=50e9 / 4)
    assert t["compute_s"] == pytest.approx(1.0)
    assert t["memory_s"] == pytest.approx(0.5)
    assert t["collective_s"] == pytest.approx(0.25)
    assert t["dominant"] == "compute"
    assert t["bound_s"] == pytest.approx(1.0)


def test_model_flops_conventions():
    assert model_flops(10, 0, 100, "train") == 6 * 10 * 100
    assert model_flops(10, 0, 100, "prefill") == 2 * 10 * 100
    assert model_flops(100, 25, 10, "train") == 6 * 25 * 10   # MoE active


def test_parse_collectives_finds_psum():
    mesh = jax.make_mesh((1,), ("x",))

    def f(a):
        return jax.lax.psum(a, "x")

    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    fn = jax.jit(shard_map(f, mesh=mesh, in_specs=P("x"), out_specs=P()))
    c = fn.lower(jax.ShapeDtypeStruct((16, 64), jnp.float32)).compile()
    out = parse_collectives(c.as_text())
    # single-device meshes may elide the collective; accept either but
    # the parser must not crash and must return the schema
    assert set(out) >= {"total_bytes", "per_kind_bytes", "n_ops"}
