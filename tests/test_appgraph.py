"""AppGraph: DAG co-simulation in the scanned sweep.

Three oracles pin the makespan stream, mirroring the CacheLoop test
strategy:

* :func:`repro.lab.appgraph.reference_makespan` -- a float64 numpy
  replay of the exact interval-quantized queue/barrier update, for
  carry-level parity;
* :func:`repro.core.cluster_sim.simulate_app_graph` -- the independent
  sub-interval discrete-event oracle (float64 scalar law + event-split
  queues), for model-level parity;
* the **pre-AppGraph fast path** -- ``app_graph=None`` keeps
  ``makespan`` at the neutral horizon, and a zero-demand graph leaves
  every stability field bit-identical (the queue rides along without
  perturbing the control loop).

Plus the acceptance demos: the ``spark-dag`` scenario's >= 2x emergent
makespan gap (no ``RUNTIME_WEIGHT`` involved) and the ``limplock``
scenario's fleet-wide inflation from one slow node.
"""

import numpy as np
import pytest

from repro.configs.dynims import PAPER_TABLE_I
from repro.core.cluster_sim import (paper_controller_params,
                                    simulate_app_graph)
from repro.core.traces import GiB
from repro.lab import (AppGraphSpec, FleetStats, GainSet, ScenarioSpec,
                       StageSpec, compile_graph, get_scenario, grid_gains,
                       makespan_score, reference_makespan, resolve_objective,
                       run_sweep, sweep_demand, topo_order, tune_gains)
from repro.lab._compat import reset_warnings
from repro.runtime import limplock_nodes

STABILITY_FIELDS = FleetStats._fields[:10]

M = 125.0 * GiB


def static_gains(grant_gib: float = 25.0) -> GainSet:
    """The paper's static Table-I baseline: grant pinned, law inert."""
    return GainSet.from_params(paper_controller_params(
        lam=0.0, u_min=grant_gib * GiB, u_max=grant_gib * GiB))


# ---------------------------------------------------------------------------
# Spec validation and graph compilation
# ---------------------------------------------------------------------------

def test_stage_and_graph_validation():
    with pytest.raises(ValueError):
        StageSpec(name="")
    with pytest.raises(ValueError):
        StageSpec(name="m", tasks=-1)
    with pytest.raises(ValueError):
        StageSpec(name="m", task_gib=0.0)
    with pytest.raises(ValueError):
        StageSpec(name="m", demand_gib=-1.0)
    with pytest.raises(ValueError):
        AppGraphSpec(stages=())
    with pytest.raises(ValueError):
        AppGraphSpec(stages=(StageSpec(name="a"), StageSpec(name="a")))
    with pytest.raises(ValueError):
        AppGraphSpec(stages=(StageSpec(name="a"),), iterations=0)
    with pytest.raises(ValueError):
        AppGraphSpec(stages=(StageSpec(name="a"),), compute_gibps=0.0)
    with pytest.raises(ValueError):
        AppGraphSpec(stages=(StageSpec(name="a"),), slow_factor=0.5)
    with pytest.raises(ValueError):
        AppGraphSpec(stages=(StageSpec(name="a"),), slow_nodes=(-1,))


def test_topo_order_and_cycle_detection():
    a = StageSpec(name="a")
    b = StageSpec(name="b", deps=("a",))
    c = StageSpec(name="c", deps=("a", "b"))
    assert topo_order((c, b, a)) == [2, 1, 0]
    # no edges: declaration order is the implicit chain
    assert topo_order((a, StageSpec(name="z"))) == [0, 1]
    with pytest.raises(ValueError, match="unknown"):
        topo_order((StageSpec(name="a", deps=("ghost",)),))
    with pytest.raises(ValueError, match="itself"):
        topo_order((StageSpec(name="a", deps=("a",)),))
    with pytest.raises(ValueError, match="cycle"):
        topo_order((StageSpec(name="a", deps=("b",)),
                    StageSpec(name="b", deps=("a",))))


def test_compile_graph_round_robin_and_skew():
    g = AppGraphSpec(
        stages=(StageSpec(name="map", tasks=5, task_gib=2.0, barrier=False,
                          demand_gib=1.5),
                StageSpec(name="red", tasks=0, task_gib=4.0,
                          deps=("map",))),
        iterations=2, slow_nodes=(1,), slow_factor=3.0)
    cg = compile_graph(g, 3)
    assert cg.n_rows == 4
    assert cg.work_gib.shape == (5, 3)           # sentinel row appended
    # 5 tasks over 3 nodes -> 2/2/1; node 1 carries the 3x skew
    np.testing.assert_allclose(cg.work_gib[0], [4.0, 12.0, 2.0])
    np.testing.assert_allclose(cg.work_gib[1], [4.0, 12.0, 4.0])
    np.testing.assert_allclose(cg.work_gib[4], 0.0)      # sentinel
    np.testing.assert_allclose(cg.demand_bytes[:4] / GiB,
                               [1.5, 0.0, 1.5, 0.0])
    np.testing.assert_allclose(cg.barrier[:5], [0.0, 1.0, 0.0, 1.0, 0.0])
    assert cg.names == ("map@0", "red@0", "map@1", "red@1")
    assert g.n_stage_rows == 4
    assert g.total_work_gib(3) == pytest.approx(cg.work_gib.sum())
    with pytest.raises(ValueError, match="out of range"):
        compile_graph(g, 1)
    with pytest.raises(ValueError, match="out of range"):
        ScenarioSpec(name="bad", n_nodes=1, app_graph=g)


# ---------------------------------------------------------------------------
# Graph-off: neutral makespan, untouched fast path
# ---------------------------------------------------------------------------

def test_graph_off_makespan_is_neutral_horizon():
    spec = get_scenario("bursty-serving").replace(n_nodes=8, n_intervals=200)
    r = run_sweep(spec, GainSet.from_params(PAPER_TABLE_I), seed=0)
    ideal = spec.n_intervals * spec.interval_s
    assert float(r.stats.makespan[0]) == pytest.approx(ideal)
    # neutral makespan still scores: the objective degenerates to a
    # constant, never an error
    np.testing.assert_allclose(r.scores(makespan_score), -ideal, rtol=1e-6)


def test_zero_demand_graph_keeps_stability_fields_bitwise():
    """A graph that holds no memory is invisible to the control loop:
    the queue rides the scan without perturbing a single stability
    bit (the AppGraph analogue of CacheLoop's degenerate-spec test)."""
    p = paper_controller_params()
    demand = np.asarray(get_scenario("bursty-serving").replace(
        n_nodes=12, n_intervals=200).build_demand(seed=3))
    gains = grid_gains(p, lam=(0.3, 0.9), r0=(0.9, 0.95))
    ghost = AppGraphSpec(
        stages=(StageSpec(name="map", task_gib=3.0, barrier=False),
                StageSpec(name="red", task_gib=2.0, deps=("map",))),
        iterations=2)
    off = sweep_demand(demand, gains, node_memory=p.total_memory,
                       interval_s=p.interval_s)
    on = sweep_demand(demand, gains, node_memory=p.total_memory,
                      interval_s=p.interval_s, app_graph=ghost)
    for f in STABILITY_FIELDS:
        np.testing.assert_array_equal(getattr(off, f), getattr(on, f),
                                      err_msg=f)
    # ... but the makespan is live, not the neutral horizon
    assert not np.allclose(on.makespan, off.makespan)


def test_stage_demand_feeds_back_into_observed_pressure():
    """An active stage's held memory must be visible to the controller:
    the same trace with a demand-holding graph runs hotter."""
    p = paper_controller_params()
    demand = np.asarray(get_scenario("bursty-serving").replace(
        n_nodes=8, n_intervals=200).build_demand(seed=1))
    heavy = AppGraphSpec(
        stages=(StageSpec(name="shuffle", task_gib=1e6, demand_gib=20.0),))
    off = sweep_demand(demand, GainSet.from_params(p),
                       node_memory=p.total_memory, interval_s=p.interval_s)
    on = sweep_demand(demand, GainSet.from_params(p),
                      node_memory=p.total_memory, interval_s=p.interval_s,
                      app_graph=heavy)
    assert float(on.mean_utilization[0]) > float(off.mean_utilization[0])


# ---------------------------------------------------------------------------
# float64 carry replay (reference_makespan)
# ---------------------------------------------------------------------------

def test_reference_makespan_matches_streamed_carry():
    # limplock's row sizes are exact multiples of the per-interval
    # advance, so every row boundary is a float knife edge: f32 and
    # f64 may legitimately disagree by one interval per row.  The
    # misaligned graph below pins the carry tightly; here 1% brackets
    # the documented boundary slip.
    spec = get_scenario("limplock")
    demand = np.asarray(spec.build_demand(seed=0))
    n, t = demand.shape
    stats = sweep_demand(demand, static_gains(), node_memory=M,
                         interval_s=spec.interval_s,
                         app_graph=spec.app_graph)
    grant = np.full((n, t), 25.0 * GiB)
    ref = reference_makespan(spec.app_graph, demand, M, grant,
                             interval_s=spec.interval_s)
    assert float(stats.makespan[0]) == pytest.approx(ref["makespan_s"],
                                                     rel=0.01)
    assert ref["t_done"] > 0
    # every barrier row cleared, in order
    assert (np.diff(ref["stage_finish_t"]) > 0).all()


def test_reference_makespan_parity_off_knife_edge():
    """With row sizes that do NOT align to interval boundaries and a
    bursty trace exercising the pressure curve, the f32 carry must
    track the f64 replay to within one interval per stage row."""
    graph = AppGraphSpec(
        stages=(StageSpec(name="map", tasks=9, task_gib=1.7,
                          barrier=False, demand_gib=3.0),
                StageSpec(name="shuffle", task_gib=5.3, demand_gib=9.0,
                          deps=("map",)),
                StageSpec(name="reduce", tasks=5, task_gib=2.9,
                          deps=("shuffle",), demand_gib=1.0)),
        iterations=3, compute_gibps=1.7, slow_nodes=(2,), slow_factor=2.3)
    spec = get_scenario("bursty-serving").replace(
        n_nodes=6, n_intervals=900, app_graph=graph)
    demand = np.asarray(spec.build_demand(seed=5))
    stats = sweep_demand(demand, static_gains(30.0), node_memory=M,
                         interval_s=spec.interval_s, app_graph=graph)
    grant = np.full(demand.shape, 30.0 * GiB)
    ref = reference_makespan(graph, demand, M, grant,
                             interval_s=spec.interval_s)
    slack = (graph.n_stage_rows + 1) * spec.interval_s
    assert abs(float(stats.makespan[0]) - ref["makespan_s"]) <= slack


def test_reference_makespan_extrapolates_truncated_horizon():
    spec = get_scenario("limplock")
    demand = np.asarray(spec.build_demand(seed=0))[:, :300]
    grant = np.full(demand.shape, 25.0 * GiB)
    ref = reference_makespan(spec.app_graph, demand, M, grant,
                             interval_s=spec.interval_s)
    horizon = demand.shape[1] * spec.interval_s
    assert ref["t_done"] == -1
    assert ref["makespan_s"] > horizon
    stats = sweep_demand(demand, static_gains(), node_memory=M,
                         interval_s=spec.interval_s,
                         app_graph=spec.app_graph)
    # same knife-edge boundary slip as above: the f32 carry may credit
    # one interval of work more/less per row crossed before truncation
    assert float(stats.makespan[0]) == pytest.approx(ref["makespan_s"],
                                                     rel=0.01)


# ---------------------------------------------------------------------------
# Discrete-event oracle parity (the acceptance gate)
# ---------------------------------------------------------------------------

def test_limplock_oracle_is_exact():
    """Constant demand below the pressure knee: the makespan is pure
    arithmetic.  One 4x node at 2 GiB/s drains its 32 GiB row in 16 s;
    six barrier rows -> 96 s, and both engines must agree exactly."""
    spec = get_scenario("limplock")
    demand = np.asarray(spec.build_demand(seed=0))
    o = simulate_app_graph(spec.app_graph, demand, node_memory=M,
                           interval_s=spec.interval_s, params=None,
                           static_grant=25.0 * GiB)
    assert o["finished"]
    np.testing.assert_allclose(o["stage_finish_s"],
                               [16.0, 32.0, 48.0, 64.0, 80.0, 96.0])
    stats = sweep_demand(demand, static_gains(), node_memory=M,
                         interval_s=spec.interval_s,
                         app_graph=spec.app_graph)
    assert float(stats.makespan[0]) == pytest.approx(96.0, abs=0.2)
    assert o["makespan_s"] == pytest.approx(96.0, rel=1e-9)


@pytest.mark.parametrize("dynamic", [False, True],
                         ids=["static-25g", "dynamic-table1"])
def test_spark_dag_within_15pct_of_discrete_event_oracle(dynamic):
    spec = get_scenario("spark-dag")
    demand = np.asarray(spec.build_demand(seed=0))
    gains = (GainSet.from_params(PAPER_TABLE_I) if dynamic
             else static_gains())
    stats = sweep_demand(demand, gains, node_memory=M,
                         interval_s=spec.interval_s, cache=spec.cache,
                         app_graph=spec.app_graph)
    o = simulate_app_graph(spec.app_graph, demand, node_memory=M,
                           interval_s=spec.interval_s,
                           params=PAPER_TABLE_I if dynamic else None,
                           static_grant=25.0 * GiB, cache=spec.cache)
    assert float(stats.makespan[0]) == pytest.approx(o["makespan_s"],
                                                     rel=0.15)


# ---------------------------------------------------------------------------
# The paper's headline, emergent: >= 2x makespan gap on spark-dag
# ---------------------------------------------------------------------------

def test_spark_dag_dynamic_beats_static_2x_emergent():
    """Dynamic Table-I gains vs. the static 25G baseline on the
    spark-dag scenario: >= 2x end-to-end makespan, measured purely as
    the DAG's drain time -- ``makespan_score`` carries no
    ``RUNTIME_WEIGHT``; no penalty-model term is involved."""
    spec = get_scenario("spark-dag")
    demand = np.asarray(spec.build_demand(seed=0))
    kw = dict(node_memory=M, interval_s=spec.interval_s, cache=spec.cache,
              app_graph=spec.app_graph)
    static = sweep_demand(demand, static_gains(), **kw)
    dynamic = sweep_demand(demand, GainSet.from_params(PAPER_TABLE_I), **kw)
    ratio = float(static.makespan[0]) / float(dynamic.makespan[0])
    assert ratio >= 2.0, f"emergent speedup only {ratio:.2f}x"
    # and the objective orders them the same way, weight-free
    assert float(makespan_score(dynamic)[0]) > float(
        makespan_score(static)[0])


def test_limplock_one_slow_node_inflates_fleet_makespan():
    spec = get_scenario("limplock")
    healthy = spec.app_graph.replace(slow_nodes=(), slow_factor=1.0)
    r_slow = run_sweep(spec, static_gains(), seed=0)
    r_ok = run_sweep(spec.replace(app_graph=healthy), static_gains(), seed=0)
    ratio = float(r_slow.stats.makespan[0]) / float(r_ok.stats.makespan[0])
    # barrier coupling: ONE 4x node makes the whole fleet 4x slower
    assert ratio == pytest.approx(4.0, rel=0.05)
    # the offline detector fingers exactly that node from per-node
    # drain times
    cg = compile_graph(spec.app_graph, spec.n_nodes)
    per_node_s = cg.work_gib.sum(axis=0) / spec.app_graph.compute_gibps
    assert limplock_nodes(per_node_s) == [0]
    assert limplock_nodes(per_node_s[1:]) == []


# ---------------------------------------------------------------------------
# Engine invariances
# ---------------------------------------------------------------------------

def test_appgraph_sweep_chunking_invariant():
    spec = get_scenario("spark-dag").replace(n_nodes=8, n_intervals=300)
    gains = grid_gains(paper_controller_params(),
                       lam=(0.4, 0.9, 1.3), r0=(0.9, 0.95))
    runs = [run_sweep(spec, gains, seed=4, chunk=c) for c in (None, 2, 5)]
    for other in runs[1:]:
        for f in FleetStats._fields:
            np.testing.assert_array_equal(
                getattr(runs[0].stats, f), getattr(other.stats, f),
                err_msg=f)


def test_pallas_engine_falls_back_with_warning():
    spec = get_scenario("limplock").replace(n_intervals=300)
    demand = np.asarray(spec.build_demand(seed=0))
    xla = sweep_demand(demand, static_gains(), node_memory=M,
                       interval_s=spec.interval_s, app_graph=spec.app_graph)
    reset_warnings()
    with pytest.warns(RuntimeWarning, match="falling back"):
        pal = sweep_demand(demand, static_gains(), node_memory=M,
                           interval_s=spec.interval_s,
                           app_graph=spec.app_graph, engine="pallas")
    for f in FleetStats._fields:
        np.testing.assert_array_equal(getattr(xla, f), getattr(pal, f),
                                      err_msg=f)


def test_makespan_objective_registered_and_tunable():
    assert resolve_objective("makespan") is makespan_score
    spec = get_scenario("spark-dag").replace(n_nodes=8, n_intervals=400)
    result = tune_gains(spec, budget=8, objective="makespan", seed=0)
    assert result.score >= result.baseline_score
    # score is literally the negated makespan -- no weights anywhere
    r = run_sweep(spec, GainSet.from_params(result.params), seed=0)
    np.testing.assert_allclose(r.scores(makespan_score),
                               -np.asarray(r.stats.makespan), rtol=1e-6)
