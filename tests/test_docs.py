"""Docs gate unit suite: tools/check_docs.py.

The docs job runs the gate script directly; these tests pin its
behaviour — dead-link detection, scheme/anchor skipping, the required
README → docs/ cross-references, the non-shipping-path rule (the
regression class that left a dead related-repo path in ROADMAP.md),
and the doctest pass — plus the gate's verdict on the repo's actual
docs, so `pytest` alone catches a docs regression without the CI job.
"""

import importlib.util
import os
import sys

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _load_check_docs():
    spec = importlib.util.spec_from_file_location(
        "check_docs", os.path.join(ROOT, "tools", "check_docs.py"))
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


cd = _load_check_docs()


# ---------------------------------------------------------------------------
# The repo's own docs must pass the gate
# ---------------------------------------------------------------------------

def test_repo_docs_links_are_clean():
    assert cd.check_links(ROOT) == []


def test_repo_docs_reference_no_build_environment_paths():
    assert cd.check_shipping_paths(ROOT) == []


def test_architecture_doctests_pass():
    assert cd.run_doctests(ROOT) == []


def test_gate_main_is_clean_end_to_end(capsys):
    assert cd.main([]) == 0
    assert "clean" in capsys.readouterr().out


# ---------------------------------------------------------------------------
# Detection behaviour, on synthetic docs
# ---------------------------------------------------------------------------

def _write(tmp_path, rel, text):
    p = tmp_path / rel
    p.parent.mkdir(parents=True, exist_ok=True)
    p.write_text(text)
    return p


def test_dead_relative_link_is_flagged(tmp_path):
    _write(tmp_path, "GUIDE.md", "see [missing](nope/gone.md)\n")
    errs = cd.check_links(str(tmp_path), docs=("GUIDE.md",))
    assert len(errs) == 1
    assert "dead link" in errs[0] and "nope/gone.md" in errs[0]


def test_scheme_anchor_and_fragment_links_are_skipped(tmp_path):
    _write(tmp_path, "docs/OTHER.md", "content\n")
    _write(tmp_path, "docs/GUIDE.md",
           "[web](https://example.com/x) [mail](mailto:a@b.c)\n"
           "[anchor](#section) [frag](OTHER.md#part)\n")
    assert cd.check_links(str(tmp_path), docs=("docs/GUIDE.md",)) == []


def test_links_resolve_relative_to_the_doc_not_the_root(tmp_path):
    _write(tmp_path, "README.md", "r\n")
    _write(tmp_path, "docs/GUIDE.md", "[up](../README.md)\n")
    assert cd.check_links(str(tmp_path), docs=("docs/GUIDE.md",)) == []


def test_required_readme_crossrefs_are_enforced(tmp_path):
    _write(tmp_path, "README.md", "no links here\n")
    errs = cd.check_links(str(tmp_path), docs=("README.md",))
    missing = sorted(e for e in errs if "missing required" in e)
    assert len(missing) == 2
    assert any("ARCHITECTURE" in e for e in missing)
    assert any("OPERATIONS" in e for e in missing)


def test_missing_checked_doc_is_itself_a_finding(tmp_path):
    errs = cd.check_links(str(tmp_path), docs=("GONE.md",))
    assert errs == ["GONE.md: checked doc is missing"]


def test_non_shipping_path_is_flagged(tmp_path):
    _write(tmp_path, "GUIDE.md",
           "fine line\nsee `/root/related/some_repo/` for idiom\n")
    errs = cd.check_shipping_paths(str(tmp_path), docs=("GUIDE.md",))
    assert len(errs) == 1 and "GUIDE.md:2" in errs[0]


def test_doctest_runner_catches_a_failing_example(tmp_path):
    _write(tmp_path, "docs/BAD.md",
           "```python\n>>> 1 + 1\n3\n\n```\n")
    errs = cd.run_doctests(str(tmp_path), docs=("docs/BAD.md",))
    assert len(errs) == 1 and "1/1" in errs[0]


def test_doctest_runner_rejects_example_free_docs(tmp_path):
    _write(tmp_path, "docs/EMPTY.md", "prose only\n")
    errs = cd.run_doctests(str(tmp_path), docs=("docs/EMPTY.md",))
    assert len(errs) == 1 and "no doctest examples" in errs[0]


def test_gate_exits_nonzero_on_findings(tmp_path, capsys, monkeypatch):
    _write(tmp_path, "README.md", "[dead](gone.md)\n")
    _write(tmp_path, "ROADMAP.md", "ok\n")
    _write(tmp_path, "docs/ARCHITECTURE.md", "```python\n>>> 2\n2\n\n```\n")
    _write(tmp_path, "docs/OPERATIONS.md", "ok\n")
    monkeypatch.setattr(cd, "repo_root", lambda: str(tmp_path))
    assert cd.main([]) == 1
    out = capsys.readouterr().out
    assert "dead link" in out and "finding" in out
