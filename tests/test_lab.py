"""ScenarioLab tests: registry, sweep-engine parity, scoring, tuning."""

import numpy as np
import pytest

from repro.configs.dynims import PAPER_TABLE_I, tuned_params, tuned_scenarios
from repro.core import GiB, MemoryPlane
from repro.core.cluster_sim import paper_controller_params, simulate_fleet
from repro.core.traces import fleet_demand_traces
from repro.lab import (FleetStats, GainSet, ScenarioSpec, compute_fleet_stats,
                       default_score, get_scenario, grid_gains,
                       list_scenarios, random_gains, register_scenario,
                       run_sweep, stats_to_dict, sweep_demand, tune_gains)


# ---------------------------------------------------------------------------
# Scenario registry
# ---------------------------------------------------------------------------

def test_registry_ships_paper_and_stress_scenarios():
    names = list_scenarios()
    assert len(names) >= 8
    for c in (1, 2, 3, 4):
        assert any(n.startswith(f"paper-c{c}") for n in names)
    for stress in ("bursty-serving", "hetero-fleet", "swap-storm",
                   "phase-replay"):
        assert stress in names


def test_scenarios_compile_to_dense_demand():
    for name in list_scenarios():
        spec = get_scenario(name)
        demand = spec.build_demand(seed=0)
        assert demand.shape == (spec.n_nodes, spec.n_intervals), name
        assert np.isfinite(demand).all() and (demand >= 0).all(), name
        m = spec.build_node_memory(seed=0)
        assert m.shape == (spec.n_nodes,) and (m > 0).all(), name


def test_scenario_determinism_and_seed_sensitivity():
    spec = get_scenario("bursty-serving")
    np.testing.assert_array_equal(spec.build_demand(seed=5),
                                  spec.build_demand(seed=5))
    assert not np.array_equal(spec.build_demand(seed=5),
                              spec.build_demand(seed=6))


def test_scenario_knobs():
    hetero = get_scenario("hetero-fleet")
    m = hetero.build_node_memory(seed=0)
    assert m.std() > 0, "memory_jitter must spread per-node budgets"
    churn = get_scenario("failover-churn")
    demand = churn.build_demand(seed=0)
    # some nodes collapse to the failure remnant at some point
    assert (demand.min(axis=1) < 0.2 * demand.max(axis=1)).any()
    with pytest.raises(ValueError):
        ScenarioSpec(name="bad", family="nope")
    with pytest.raises(KeyError):
        get_scenario("no-such-scenario")


def test_register_scenario_no_silent_overwrite():
    spec = ScenarioSpec(name="tmp-test-scenario", n_nodes=2, n_intervals=8)
    register_scenario(spec, overwrite=True)
    with pytest.raises(ValueError):
        register_scenario(spec)
    assert get_scenario("tmp-test-scenario") is spec


# ---------------------------------------------------------------------------
# Sweep engine: parity with the Python-loop fleet sim
# ---------------------------------------------------------------------------

PARITY_KEYS = ("mean_utilization", "p99_utilization", "max_utilization",
               "mean_capacity_gib", "capacity_std_gib",
               "frac_intervals_over_r0", "max_over_r0")

# The device-resident engine estimates p99 with the streaming fixed-bin
# quantile (12-level bisection over 65536 bins): worst-case bracket
# error is QUANT_RANGE span * 2^-13 ~= 2.4e-4 plus half a bin, so p99
# gets its own parity tolerance; every other metric stays exact to
# float32 ulps.
P99_ATOL = 5e-4


def assert_engine_parity(lab, ref):
    for k in PARITY_KEYS:
        atol = P99_ATOL if k == "p99_utilization" else 1e-5
        np.testing.assert_allclose(lab[k], ref[k], rtol=1e-4, atol=atol,
                                   err_msg=k)


def test_sweep_parity_with_python_fleet_sim():
    """A 1-gain, paper-config sweep reproduces simulate_fleet's stability
    metrics within float32 tolerance."""
    ref = simulate_fleet(n_nodes=128, n_intervals=400, seed=2,
                         engine="python")
    lab = simulate_fleet(n_nodes=128, n_intervals=400, seed=2, engine="lab")
    assert_engine_parity(lab, ref)


def test_engine_parity_beyond_paper_knobs():
    """Both engines must run the same law for asymmetric/deadband/
    feedforward params, not just the paper-faithful defaults."""
    p = paper_controller_params(lam_grant=0.2, deadband=0.005,
                                feedforward=0.5)
    ref = simulate_fleet(48, 200, seed=5, params=p, engine="python")
    lab = simulate_fleet(48, 200, seed=5, params=p, engine="lab")
    assert_engine_parity(lab, ref)


def test_sweep_demand_matches_direct_gainset_call():
    p = paper_controller_params()
    demand = fleet_demand_traces(32, 200, p.interval_s, seed=7)
    stats = sweep_demand(demand, GainSet.from_params(p),
                         node_memory=p.total_memory, interval_s=p.interval_s)
    ref = simulate_fleet(n_nodes=32, n_intervals=200, seed=7,
                         engine="python")
    assert stats.mean_utilization.shape == (1,)
    np.testing.assert_allclose(float(stats.p99_utilization[0]),
                               ref["p99_utilization"], rtol=1e-4,
                               atol=P99_ATOL)


def test_sweep_chunking_invariant():
    """Chunk size is an implementation detail: stats must not change."""
    p = paper_controller_params()
    gains = grid_gains(p, lam=(0.3, 0.6, 0.9), r0=(0.92, 0.95, 0.97))
    a = run_sweep("swap-storm", gains, seed=1, chunk=2)
    b = run_sweep("swap-storm", gains, seed=1, chunk=16)
    for f in FleetStats._fields:
        np.testing.assert_allclose(getattr(a.stats, f), getattr(b.stats, f),
                                   rtol=1e-6, err_msg=f)


def test_gain_set_construction_and_roundtrip():
    p = paper_controller_params(lam=0.7, r0=0.93, lam_grant=0.2,
                                deadband=0.01, feedforward=0.5)
    g = GainSet.from_params(p)
    assert len(g) == 1
    assert g.params_at(0, PAPER_TABLE_I) == PAPER_TABLE_I.replace(
        lam=0.7, r0=0.93, lam_grant=0.2, deadband=0.01, feedforward=0.5)
    sym = GainSet.from_params(paper_controller_params())
    assert sym.params_at(0, PAPER_TABLE_I).lam_grant is None
    grid = grid_gains(lam=(0.2, 0.5), r0=(0.9, 0.95), lam_grant=(None, 0.1))
    assert len(grid) == 8
    rnd = random_gains(17, seed=3)
    assert len(rnd) == 17
    assert (rnd.lam > 0).all() and (rnd.lam < 2).all()
    with pytest.raises(ValueError):
        GainSet(r0=np.ones(2), lam=np.ones(3), lam_grant=np.ones(2),
                u_min=np.zeros(2), u_max=np.ones(2))


def test_sweep_honours_deadband_and_feedforward():
    """The loop a tune run scores is the loop the tuned params deploy:
    the beyond-paper knobs must change sweep output."""
    p = paper_controller_params()
    demand = fleet_demand_traces(16, 200, p.interval_s, seed=9)
    frozen = sweep_demand(
        demand, GainSet.from_params(p.replace(deadband=10.0)),
        node_memory=p.total_memory, interval_s=p.interval_s)
    # |r - r0| <= 10 always holds, so the law never moves u off u_max
    assert float(frozen.mean_capacity_gib[0]) == pytest.approx(
        p.u_max / GiB, rel=1e-6)
    assert float(frozen.capacity_std_gib[0]) == pytest.approx(0.0, abs=1e-6)
    base = sweep_demand(demand, GainSet.from_params(p),
                        node_memory=p.total_memory, interval_s=p.interval_s)
    ff = sweep_demand(demand, GainSet.from_params(p.replace(feedforward=1.0)),
                      node_memory=p.total_memory, interval_s=p.interval_s)
    assert float(ff.mean_capacity_gib[0]) != float(base.mean_capacity_gib[0])
    # slope feedforward acts ahead of ramps: it must not hurt overshoot
    assert float(ff.max_over_r0[0]) <= float(base.max_over_r0[0]) + 1e-6


# ---------------------------------------------------------------------------
# Scoring
# ---------------------------------------------------------------------------

def test_fleet_stats_on_known_history():
    # 2 intervals x 2 nodes, hand-checkable
    utils = np.array([[0.5, 0.9], [1.1, 0.96]], np.float32)
    caps = np.array([[10.0, 20.0], [30.0, 40.0]], np.float32) * GiB
    s = compute_fleet_stats(utils, caps, r0=0.95, interval_s=0.1)
    d = stats_to_dict(s)
    assert d["max_utilization"] == pytest.approx(1.1)
    assert d["frac_intervals_over_r0"] == pytest.approx(0.5)   # 1.1, 0.96
    assert d["pressure_violation_rate"] == pytest.approx(0.25)
    assert d["max_over_r0"] == pytest.approx(0.15, abs=1e-6)
    assert d["mean_capacity_gib"] == pytest.approx(25.0)
    assert d["granted_volume_gib_s"] == pytest.approx(5.0)  # (15+35)*0.1
    assert d["settle_intervals"] == 2.0      # last interval still over band
    calm = compute_fleet_stats(np.full((4, 2), 0.5, np.float32), caps=caps.repeat(2, 0),
                               r0=0.95, interval_s=0.1)
    assert stats_to_dict(calm)["settle_intervals"] == 0.0


def test_default_score_prefers_safe_high_grant():
    caps_hi = np.full((4, 2), 50.0, np.float32) * GiB
    caps_lo = np.full((4, 2), 20.0, np.float32) * GiB
    safe_hi = compute_fleet_stats(np.full((4, 2), 0.9, np.float32), caps_hi,
                                  r0=0.95, interval_s=0.1)
    safe_lo = compute_fleet_stats(np.full((4, 2), 0.9, np.float32), caps_lo,
                                  r0=0.95, interval_s=0.1)
    swapping = compute_fleet_stats(np.full((4, 2), 1.05, np.float32), caps_hi,
                                   r0=0.95, interval_s=0.1)
    assert float(default_score(safe_hi)) > float(default_score(safe_lo))
    assert float(default_score(safe_hi)) > float(default_score(swapping))


# ---------------------------------------------------------------------------
# Tuning
# ---------------------------------------------------------------------------

def test_tuned_gains_beat_paper_defaults_on_stress_scenario():
    """>= 64-point sweep returns gains that beat Table I off-testbed."""
    result = tune_gains("swap-storm", budget=64, seed=0)
    assert result.sweep.n_configs >= 64
    assert result.score > result.baseline_score
    assert result.params != result.baseline_params
    # the tuned params are deployable as-is
    assert 0 < result.params.lam < 2 and 0 < result.params.r0 <= 1


def test_tune_never_below_baseline_and_random_method():
    result = tune_gains("paper-c3-dynims60", method="random", budget=16,
                        seed=1)
    assert result.score >= result.baseline_score
    assert result.sweep.n_configs == 17      # budget + appended baseline


def test_tuned_presets_exposed_through_configs_and_plane():
    assert set(tuned_scenarios()) >= {"bursty-serving", "swap-storm",
                                      "hetero-fleet"}
    for name in tuned_scenarios():
        p = tuned_params(name)
        assert p != PAPER_TABLE_I, name
        assert 0 < p.lam < 2
    assert tuned_params("paper-c3-dynims60") == PAPER_TABLE_I
    assert tuned_params("swap-storm", u_max=30 * GiB).u_max == 30 * GiB
    with pytest.raises(KeyError):
        tuned_params("unknown-scenario")

    plane = MemoryPlane.for_scenario("bursty-serving")
    assert plane.spec.params == tuned_params("bursty-serving")
    assert plane.nodes() == []


# ---------------------------------------------------------------------------
# Batched trace generation (core/traces.py)
# ---------------------------------------------------------------------------

def test_fleet_demand_traces_shape_and_determinism():
    d = fleet_demand_traces(16, 300, 0.1, seed=4)
    assert d.shape == (16, 300)
    np.testing.assert_array_equal(d, fleet_demand_traces(16, 300, 0.1,
                                                         seed=4))
    flat = fleet_demand_traces(4, 100, 0.1, seed=4, amp_range=(1.0, 1.0),
                               phase_shift=False)
    np.testing.assert_array_equal(flat[0], flat[3])


def test_fleet_demand_traces_tiles_short_base():
    base = np.arange(10, dtype=np.float64)
    d = fleet_demand_traces(2, 25, 0.1, seed=0, base=base,
                            amp_range=(1.0, 1.0), phase_shift=False)
    assert d.shape == (2, 25)
    np.testing.assert_array_equal(d[0, :10], d[0, 10:20])
