"""Faithful-reproduction gate: the cluster simulator must land inside
the paper's reported bands (Sec. IV, Figs 5-8).

These are the EXPERIMENTS.md §Paper-validation numbers; benchmarks/
fig*.py produce the full figures from the same simulator.
"""

import numpy as np
import pytest

from repro.core.cluster_sim import (make_paper_config, run_paper_experiment,
                                    simulate, simulate_fleet)
from repro.core.traces import GiB, IterativeAppSpec, hpcc_trace, hpl_slowdown


@pytest.fixture(scope="module")
def paper_results():
    return run_paper_experiment()


def test_headline_speedups(paper_results):
    d = paper_results
    s1 = d[1].app_runtime_s / d[3].app_runtime_s
    s2 = d[2].app_runtime_s / d[3].app_runtime_s
    # paper: 5.1x over Spark(45GB), 3.8x over Spark(20)/Alluxio(25)
    assert 4.3 <= s1 <= 6.2, s1
    assert 3.0 <= s2 <= 4.6, s2


def test_near_upper_bound(paper_results):
    """paper: 'comparable performance with their reference upper bound'."""
    d = paper_results
    assert d[3].app_runtime_s / d[4].app_runtime_s <= 1.35


def test_hit_ratios(paper_results):
    d = paper_results
    # paper: 'up to 75%' dynamic vs 'at most 31%' static
    assert 0.70 <= d[3].hit_ratio <= 0.90
    assert 0.25 <= d[2].hit_ratio <= 0.42
    assert d[3].hit_ratio > d[2].hit_ratio + 0.3


def test_config1_vs_config2_ratio(paper_results):
    """paper Sec IV.B: RDD-cached Spark is ~1.3x slower than Alluxio."""
    d = paper_results
    ratio = d[1].app_runtime_s / d[2].app_runtime_s
    assert 1.15 <= ratio <= 1.6, ratio


def test_fig7_burst_shrink_recover(paper_results):
    """Storage capacity dips during the HPCC burst, then recovers."""
    r = paper_results[3]
    cap = r.cap_gib
    assert cap[0] == pytest.approx(60, abs=1)
    assert cap.min() < 30                      # shrunk during burst
    # recovered to u_max by the end (HPCC finished)
    assert cap[-1] > 55
    # memory pressure stayed controlled: utilization ~<= r0 + transient
    assert r.peak_utilization < 1.04


def test_fig8_iterations_recover(paper_results):
    """Early iterations degrade toward static speed, later ones recover
    to the upper bound (paper Fig. 8)."""
    dyn = paper_results[3].iteration_times_s
    ub = paper_results[4].iteration_times_s
    # late iterations within 25% of the no-contention upper bound
    assert np.mean(dyn[-3:]) <= np.mean(ub[-3:]) * 1.25
    # early iterations visibly degraded
    assert max(dyn[:3]) > 2.0 * np.mean(dyn[-3:])


def test_fig6_problem_size_scaling():
    """paper Fig. 6: static configs degrade sharply as the dataset
    outgrows the cache; DynIMS scales much more gently."""
    sizes = [80.0, 240.0, 400.0]
    dyn, static = [], []
    for gib in sizes:
        app = IterativeAppSpec(dataset_gib=gib, iterations=4)
        dyn.append(simulate(make_paper_config(3, app=app)).app_runtime_s)
        static.append(simulate(make_paper_config(2, app=app)).app_runtime_s)
    # both monotone in problem size
    assert dyn == sorted(dyn) and static == sorted(static)
    # static blows up far faster than dynims
    assert static[-1] / static[0] > 2.0 * dyn[-1] / dyn[0]


def test_fig1_trace_statistics():
    """HPCC trace matches Fig. 1: peak ~75 GB, >=40 GB unused most of
    the time on a 125 GB node."""
    tr = hpcc_trace(600.0, 0.1, seed=0) / GiB
    assert 73.0 <= tr.max() <= 76.0
    # "at least 40 GB memory is unused during most of running time":
    # unused = 125 - 45 (Spark exec + reserved) - hpcc >= 40  <=>
    # hpcc <= 40 GiB for most intervals
    assert float((tr <= 40.0).mean()) > 0.55


def test_fig2_pressure_curve():
    """HPL slowdown: flat to ~92%, collapsing near 100%, swap fatal."""
    assert hpl_slowdown(0.5) == 1.0
    assert hpl_slowdown(0.90) == 1.0
    assert 1.0 < hpl_slowdown(0.96) < 2.0
    assert hpl_slowdown(0.999) > 3.0
    assert hpl_slowdown(1.0, swap_frac=0.01) > 40.0
    # monotone
    grid = np.linspace(0.5, 1.1, 61)
    vals = [hpl_slowdown(u) for u in grid]
    assert all(b >= a - 1e-9 for a, b in zip(vals, vals[1:]))


def test_lambda_sweep_stability():
    """Empirical counterpart of the paper's 0 < lambda <= 2 sweep."""
    from repro.core.cluster_sim import paper_controller_params
    from repro.core import simulate_saturated_loop, fixed_point_capacity
    demand = np.full(400, 70.0) * GiB
    for lam, stable in [(0.25, True), (0.5, True), (1.0, True),
                        (1.9, True), (2.5, False)]:
        p = paper_controller_params(lam=lam)
        tr = simulate_saturated_loop(p, demand, u0=p.u_max)
        target = fixed_point_capacity(p, 70.0 * GiB)
        settled = abs(tr[-1] - target) < 0.05 * target
        assert settled == stable, (lam, tr[-5:] / GiB)


def test_fleet_scale_stability():
    """4096 node controllers, fused vectorized updates: the fleet holds
    utilization at/below r0 except brief ramp transients."""
    m = simulate_fleet(n_nodes=4096, n_intervals=400, seed=1)
    assert m["p99_utilization"] <= 1.0
    assert m["frac_intervals_over_r0"] < 0.08
    assert m["mean_utilization"] < 0.95
