"""Checkpointing: atomicity, manifest checks, retention, async staging."""

import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (CheckpointManager, latest_step,
                              restore_pytree, save_pytree)


def tree(seed=0):
    rng = np.random.default_rng(seed)
    return {"a": jnp.asarray(rng.normal(0, 1, (4, 8)), jnp.float32),
            "b": {"c": jnp.asarray(rng.integers(0, 9, (3,)), jnp.int32)},
            "step": 7}


def test_roundtrip(tmp_path):
    t = tree()
    save_pytree(t, str(tmp_path), 5)
    out = restore_pytree(tree(seed=1), str(tmp_path), 5)
    np.testing.assert_array_equal(np.asarray(out["a"]), np.asarray(t["a"]))
    np.testing.assert_array_equal(np.asarray(out["b"]["c"]),
                                  np.asarray(t["b"]["c"]))


def test_incomplete_checkpoint_invisible(tmp_path):
    t = tree()
    path = save_pytree(t, str(tmp_path), 5)
    os.remove(os.path.join(path, "_COMPLETE"))
    assert latest_step(str(tmp_path)) is None
    with pytest.raises(FileNotFoundError):
        restore_pytree(t, str(tmp_path), 5)


def test_shape_mismatch_rejected(tmp_path):
    save_pytree(tree(), str(tmp_path), 1)
    bad = tree()
    bad["a"] = jnp.zeros((2, 2))
    with pytest.raises(ValueError):
        restore_pytree(bad, str(tmp_path), 1)


def test_latest_step_picks_newest_complete(tmp_path):
    for s in (1, 3, 7):
        save_pytree(tree(), str(tmp_path), s)
    assert latest_step(str(tmp_path)) == 7
    shutil.rmtree(os.path.join(str(tmp_path), "step-000000007"))
    assert latest_step(str(tmp_path)) == 3


def test_manager_retention(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        mgr.save(tree(), s)
    steps = sorted(n for n in os.listdir(str(tmp_path))
                   if n.startswith("step-"))
    assert len(steps) == 2
    assert latest_step(str(tmp_path)) == 4


def test_manager_async_save_and_flush(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_save=True)
    mgr.save(tree(), 9)
    mgr.wait()
    restored, step = mgr.restore_latest(tree(seed=2))
    assert step == 9
    np.testing.assert_array_equal(np.asarray(restored["a"]),
                                  np.asarray(tree()["a"]))


def test_manager_staging_buffer_pressure(tmp_path):
    """Shrinking the staging store forces the pending save to flush --
    the DynIMS coupling for checkpoint staging."""
    mgr = CheckpointManager(str(tmp_path), async_save=True)
    mgr.save(tree(), 3)
    mgr.set_capacity(0.0)             # burst: no staging allowed
    assert mgr.used() == 0.0
    assert latest_step(str(tmp_path)) == 3
