"""PallasSweep: the fused engine pinned to the XLA path.

Four pins hold the PR-9 engine in place:

* **cross-engine parity** -- ``engine="pallas"`` must reproduce
  ``engine="xla"`` stat for stat on the registry scenarios (bit-level
  on the saturated-store path; the cache path differs only through
  ``_fast_pow`` on the hit curve, bounded well under the 1e-4 budget);
* **lowering parity** -- the production CPU scan and the true
  ``pallas_call`` interpret-mode kernel share ``_fused_step``, so they
  must agree bit for bit, deterministically across runs;
* **in-scan halving identity** -- the device-side successive-halving
  program must select the same survivors and return the same tuned
  params as the host-loop ``halving_tune`` it replaces;
* **API surface** -- ``engine=`` is uniform across the sweep and tune
  entry points, old spellings warn exactly once through the ``_compat``
  shims, and unknown engines fail fast.
"""

import warnings

import numpy as np
import pytest

import repro.lab as lab
from repro.core.cluster_sim import paper_controller_params
from repro.core.traces import GiB
from repro.fleet import fleet_sweep_demand
from repro.lab import (FleetStats, GainSet, get_scenario, grid_gains,
                       halving_tune, run_sweep, sweep_demand, tune_gains)
from repro.lab._compat import reset_warnings
from repro.lab.pallas_sweep import (halving_schedule, halving_sweep,
                                    pallas_sweep_demand)

P = paper_controller_params()

# The one stat whose pallas spelling is _fast_pow (exp2/log2) instead
# of XLA's pow lowering; everything else must match bit for bit on the
# cache path too.
FAST_POW_FIELDS = ("hit_ratio", "app_runtime", "app_slowdown")


def _scenario(name, n_nodes, n_intervals, cache=True, seed=3):
    spec = get_scenario(name).replace(n_nodes=n_nodes,
                                      n_intervals=n_intervals)
    if not cache:
        spec = spec.replace(cache=None)
    return (spec.build_demand(seed=seed), spec.build_node_memory(seed=seed),
            spec.cache)


def _gains(n_lam=3, n_r0=2):
    return grid_gains(P, lam=np.linspace(0.2, 1.7, n_lam),
                      r0=np.linspace(0.88, 0.97, n_r0))


def _stats_dict(stats):
    return {k: np.asarray(v, np.float64) for k, v in stats._asdict().items()}


def _assert_stats_close(a, b, rtol_default=1e-4, rtol_p99=5e-4,
                        loose=()):
    da, db = _stats_dict(a), _stats_dict(b)
    assert set(da) == set(db)
    for name in da:
        rtol = rtol_p99 if name == "p99_utilization" else rtol_default
        if name in loose:
            rtol = max(rtol, 5e-2)
        np.testing.assert_allclose(
            da[name], db[name], rtol=rtol, atol=1e-12,
            err_msg=f"engine mismatch on {name}")


# ---------------------------------------------------------------------------
# Cross-engine parity
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", ["bursty-serving", "hetero-fleet",
                                  "swap-storm"])
def test_engine_parity_saturated_store(name):
    """Non-cache scenarios: the fused step is the XLA step bit for bit."""
    demand, m, _ = _scenario(name, n_nodes=16, n_intervals=120, cache=False)
    gains = _gains()
    kw = dict(node_memory=m, interval_s=P.interval_s)
    ref = sweep_demand(demand, gains, engine="xla", **kw)
    got = sweep_demand(demand, gains, engine="pallas", **kw)
    da, db = _stats_dict(ref), _stats_dict(got)
    for field in FleetStats._fields:
        np.testing.assert_array_equal(
            da[field], db[field],
            err_msg=f"{name}: {field} not bit-identical across engines")


def test_engine_parity_cacheloop():
    """CacheLoop scenario: only the _fast_pow spelling may differ."""
    demand, m, cache = _scenario("spark-iterative-cache", 12, 150)
    assert cache is not None
    gains = _gains()
    kw = dict(node_memory=m, interval_s=P.interval_s, cache=cache)
    ref = sweep_demand(demand, gains, engine="xla", **kw)
    got = sweep_demand(demand, gains, engine="pallas", **kw)
    da, db = _stats_dict(ref), _stats_dict(got)
    for field in FleetStats._fields:
        if field in FAST_POW_FIELDS:
            np.testing.assert_allclose(
                da[field], db[field], rtol=1e-4,
                err_msg=f"cache path: {field} outside the parity budget")
        else:
            np.testing.assert_array_equal(
                da[field], db[field],
                err_msg=f"cache path: {field} not bit-identical")


def test_run_sweep_engine_kwarg_roundtrip():
    """run_sweep(engine=...) carries parity through the result object."""
    spec = get_scenario("swap-storm").replace(n_nodes=12, n_intervals=100)
    a = run_sweep(spec, _gains(2, 2), engine="xla", seed=5)
    b = run_sweep(spec, _gains(2, 2), engine="pallas", seed=5)
    np.testing.assert_array_equal(a.scores(), b.scores())
    assert a.best() == b.best()


# ---------------------------------------------------------------------------
# Lowering parity + determinism
# ---------------------------------------------------------------------------

def test_scan_matches_interpret_kernel():
    """The production scan and the pallas_call interpret kernel share
    one jaxpr; both lowerings must agree bit for bit."""
    demand, m, cache = _scenario("spark-iterative-cache", 8, 48, seed=1)
    gains = _gains(2, 2)
    kw = dict(node_memory=m, interval_s=P.interval_s, cache=cache)
    a = pallas_sweep_demand(demand, gains, **kw)
    b = pallas_sweep_demand(demand, gains, force_interpret=True, **kw)
    da, db = _stats_dict(a), _stats_dict(b)
    for field in FleetStats._fields:
        np.testing.assert_array_equal(
            da[field], db[field],
            err_msg=f"scan vs interpret: {field} diverged")


def test_interpret_mode_deterministic():
    demand, m, _ = _scenario("bursty-serving", 8, 48, cache=False, seed=2)
    gains = _gains(2, 2)
    kw = dict(node_memory=m, interval_s=P.interval_s, force_interpret=True)
    a = pallas_sweep_demand(demand, gains, **kw)
    b = pallas_sweep_demand(demand, gains, **kw)
    for field in FleetStats._fields:
        np.testing.assert_array_equal(np.asarray(getattr(a, field)),
                                      np.asarray(getattr(b, field)))


def test_chunk_invariance():
    """Lane-chunked dispatch must not change any stat."""
    demand, m, _ = _scenario("hetero-fleet", 12, 80, cache=False)
    gains = _gains(3, 3)
    kw = dict(node_memory=m, interval_s=P.interval_s)
    whole = pallas_sweep_demand(demand, gains, **kw)
    chunked = pallas_sweep_demand(demand, gains, chunk=8, **kw)
    for field in FleetStats._fields:
        np.testing.assert_array_equal(np.asarray(getattr(whole, field)),
                                      np.asarray(getattr(chunked, field)))


def test_horizon_and_bf16():
    """horizon= truncates identically to a sliced trace; bf16 demand
    storage stays within loose tolerance of the f32 reference."""
    demand, m, _ = _scenario("swap-storm", 12, 120, cache=False)
    gains = _gains(2, 2)
    kw = dict(node_memory=m, interval_s=P.interval_s)
    a = sweep_demand(demand, gains, engine="pallas", horizon=64, **kw)
    b = sweep_demand(demand[:, :64], gains, engine="pallas", **kw)
    for field in FleetStats._fields:
        np.testing.assert_array_equal(np.asarray(getattr(a, field)),
                                      np.asarray(getattr(b, field)))
    lo = pallas_sweep_demand(demand, gains, precision="bf16", **kw)
    _assert_stats_close(
        sweep_demand(demand, gains, engine="pallas", **kw), lo,
        rtol_default=5e-2, rtol_p99=5e-2,
        loose=FleetStats._fields)


# ---------------------------------------------------------------------------
# In-scan halving
# ---------------------------------------------------------------------------

def _random_gains(n, seed=7):
    rng = np.random.default_rng(seed)
    return GainSet(
        r0=rng.uniform(0.85, 0.98, n).astype(np.float32),
        lam=rng.uniform(0.2, 1.8, n).astype(np.float32),
        lam_grant=np.full(n, 0.5, np.float32),
        u_min=np.full(n, float(8 * GiB), np.float32),
        u_max=np.full(n, float(125 * GiB), np.float32),
        deadband=np.zeros(n, np.float32),
        feedforward=np.zeros(n, np.float32))


def test_halving_schedule_matches_host_arithmetic():
    horizons, keeps = halving_schedule(160, 24, (0.125, 0.5, 1.0), 0.25, 4)
    assert horizons == [20, 80, 160]
    assert keeps == [6, 4]
    horizons, keeps = halving_schedule(100, 8, (0.5, 1.0), 0.5, 2)
    assert horizons == [50, 100]
    assert keeps == [4]


def test_in_scan_halving_matches_host_tuner():
    """engine="pallas" halving_tune = the host loop: same survivors,
    same tuned params, same baseline score."""
    spec = get_scenario("swap-storm").replace(n_nodes=16, n_intervals=160)
    gains = _random_gains(24)
    a = halving_tune(spec, gains=gains, seed=5, engine="xla")
    b = halving_tune(spec, gains=gains, seed=5, engine="pallas")
    assert a.params == b.params
    assert np.isclose(a.score, b.score)
    assert np.isclose(a.baseline_score, b.baseline_score)
    assert [r["horizon"] for r in a.rounds] == \
        [r["horizon"] for r in b.rounds]
    assert [r["n_candidates"] for r in a.rounds] == \
        [r["n_candidates"] for r in b.rounds]


def test_halving_sweep_single_dispatch_masks_dead_lanes():
    """The in-scan program returns final-round stats for survivors plus
    the baseline lane, and survivor indices point into the candidates."""
    demand, m, cache = _scenario("spark-iterative-cache", 10, 96, seed=4)
    gains = _random_gains(12, seed=9)
    base = GainSet.from_params(P)
    hs = halving_sweep(demand, gains, base, node_memory=m,
                       interval_s=P.interval_s, cache=cache)
    n_final = len(hs.scores)
    assert n_final == len(hs.survivor_idx) + 1      # + baseline lane
    assert np.all(hs.survivor_idx >= 0)
    assert np.all(hs.survivor_idx < 12)
    assert len(set(hs.survivor_idx.tolist())) == len(hs.survivor_idx)
    assert np.asarray(hs.stats.mean_utilization).shape == (n_final,)
    assert hs.rounds[-1]["elapsed_s"] > 0.0
    # Survivors' final stats equal a plain full-horizon sweep of the
    # same lanes: masking dead lanes must not perturb live ones.
    survivors = gains.take(hs.survivor_idx).concat(base)
    ref = pallas_sweep_demand(demand, survivors, node_memory=m,
                              interval_s=P.interval_s, cache=cache)
    np.testing.assert_array_equal(
        np.asarray(ref.mean_utilization),
        np.asarray(hs.stats.mean_utilization))


# ---------------------------------------------------------------------------
# API surface: engine=, shims, fallbacks
# ---------------------------------------------------------------------------

def test_unknown_engine_raises():
    demand, m, _ = _scenario("swap-storm", 8, 40, cache=False)
    with pytest.raises(ValueError, match="engine"):
        sweep_demand(demand, _gains(2, 2), node_memory=m,
                     interval_s=P.interval_s, engine="tpu")
    spec = get_scenario("swap-storm").replace(n_nodes=8, n_intervals=40)
    with pytest.raises(ValueError, match="engine"):
        tune_gains(spec, budget=4, engine="mosaic")


def test_fleet_pallas_falls_back_to_xla_with_warning():
    rng = np.random.default_rng(0)
    k, n, t = 2, 6, 60
    demand = (rng.uniform(10.0, 30.0, (k, n, t)) * GiB)
    kw = dict(node_memory=float(125 * GiB),
              weights=np.array([2.0, 1.0]),
              floors=np.array([8.0, 0.0]) * GiB,
              epoch_intervals=30, interval_s=0.1)
    gains = _gains(2, 2)
    reset_warnings()
    with pytest.warns(RuntimeWarning, match="falling back"):
        got, _ = fleet_sweep_demand(demand, gains, engine="pallas", **kw)
    ref, _ = fleet_sweep_demand(demand, gains, engine="xla", **kw)
    for field in FleetStats._fields:
        np.testing.assert_array_equal(np.asarray(getattr(got, field)),
                                      np.asarray(getattr(ref, field)))


def test_score_fn_kwarg_warns_once_and_routes():
    spec = get_scenario("swap-storm").replace(n_nodes=8, n_intervals=40)
    reset_warnings()
    with pytest.warns(DeprecationWarning, match="score_fn"):
        old = tune_gains(spec, budget=4, score_fn="runtime")
    with warnings.catch_warnings():
        warnings.simplefilter("error")          # warn-once: second is clean
        again = tune_gains(spec, budget=4, score_fn="runtime")
    new = tune_gains(spec, budget=4, objective="runtime")
    assert old.params == new.params == again.params
    assert np.isclose(old.score, new.score)


def test_renamed_module_attrs_warn_through_shims():
    import repro.lab.sweep as sweep_mod
    import repro.lab.tune as tune_mod
    reset_warnings()
    with pytest.warns(DeprecationWarning, match="XLA_DEFAULT_CHUNK"):
        assert lab.DEFAULT_CHUNK == lab.XLA_DEFAULT_CHUNK
    reset_warnings()
    with pytest.warns(DeprecationWarning, match="XLA_DEFAULT_CHUNK"):
        assert sweep_mod.DEFAULT_CHUNK == sweep_mod.XLA_DEFAULT_CHUNK
    reset_warnings()
    with pytest.warns(DeprecationWarning, match="Objective"):
        assert tune_mod.ScoreFn is tune_mod.Objective
    with pytest.raises(AttributeError):
        lab.NOT_A_REAL_NAME
