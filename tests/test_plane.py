"""Monitoring/control plane: bus, aggregator, MemoryPlane end-to-end,
scalar vs array backend parity, lifecycle, and the legacy shim."""

import time

import numpy as np
import pytest

from repro.core import (AGG_TOPIC, RAW_TOPIC, ControlPlane, ControllerParams,
                        GiB, MemoryPlane, MemorySample, MessageBus,
                        MetricAggregator, NodeSpec, PlaneSpec, ShardCache,
                        Signal, SimulatedMonitor, StoreRegistry, StoreSpec)
from repro.core.cluster_sim import paper_controller_params


class Blob:
    def __init__(self, nbytes):
        self.nbytes = nbytes


def test_bus_pubsub_and_poll():
    bus = MessageBus()
    seen = []
    unsub = bus.subscribe("t", seen.append)
    bus.publish("t", 1)
    bus.publish("t", 2)
    assert seen == [1, 2]
    assert bus.poll("t", group="g1") == [1, 2]
    assert bus.poll("t", group="g1") == []
    bus.publish("t", 3)
    assert bus.poll("t", group="g1") == [3]
    unsub()
    bus.publish("t", 4)
    assert seen == [1, 2, 3] or seen == [1, 2]  # unsubscribed


def test_bus_isolates_subscriber_exceptions():
    bus = MessageBus()
    bus.subscribe("t", lambda m: 1 / 0)
    bus.publish("t", "x")              # must not raise
    assert len(bus.errors) == 1


def test_sample_json_roundtrip():
    s = MemorySample(node="n0", timestamp=1.5, used=10.0, total=100.0,
                     storage_used=4.0)
    assert MemorySample.from_json(s.to_json()) == s


def test_aggregator_window_and_slope():
    agg = MetricAggregator(window=4)
    out = None
    for i, used in enumerate([10, 20, 30, 40]):
        out = agg.update(MemorySample("n", float(i), used, 100.0))
    assert out.used_latest == 40
    assert out.used_mean == 25
    assert out.used_max == 40
    assert abs(out.slope_per_interval - 10.0) < 1e-9


def test_control_plane_closed_loop_burst():
    """Full pipeline: burst -> cache shrinks within intervals; burst
    clears -> cache regrows (paper Fig. 7 behaviour)."""
    p = paper_controller_params()
    plane = ControlPlane(p)
    cache = ShardCache(capacity=60 * GiB, sizeof=lambda v: v.nbytes)
    for i in range(60):
        cache.put(i, Blob(1 * GiB))
    reg = StoreRegistry()
    reg.register(cache, max_bytes=60 * GiB)

    usage = ([20 * GiB] * 10) + ([95 * GiB] * 20) + ([20 * GiB] * 40)
    mon = SimulatedMonitor("n0", total=125 * GiB, usage=usage,
                           storage_used_fn=cache.used)
    plane.attach("n0", mon, reg, u0=60 * GiB)

    caps = []
    for _ in range(len(usage)):
        plane.tick()
        caps.append(cache.capacity() / GiB)
    # burst (compute 95 GiB): u* = 0.95*125 - 95 = 23.75 GiB
    assert min(caps[10:30]) < 30
    # recovery: back to u_max
    assert caps[-1] > 55
    # actual evictions happened and usage tracked capacity
    assert cache.used() <= cache.capacity()
    assert cache.stats.evictions >= 25


def test_control_actions_published():
    p = paper_controller_params()
    plane = ControlPlane(p)
    cache = ShardCache(capacity=0, sizeof=lambda v: 1.0)
    reg = StoreRegistry()
    reg.register(cache, max_bytes=60 * GiB)
    mon = SimulatedMonitor("n0", total=125 * GiB, usage=[50 * GiB] * 5)
    plane.attach("n0", mon, reg)
    for _ in range(5):
        plane.tick()
    from repro.core import CONTROL_TOPIC
    actions = plane.bus.poll(CONTROL_TOPIC, group="test")
    assert len(actions) == 5
    assert all(a.node == "n0" for a in actions)


# ---------------------------------------------------------------------------
# MemoryPlane: declarative API, backends, lifecycle
# ---------------------------------------------------------------------------

def test_signal_enum_coercion():
    assert Signal.coerce("latest") is Signal.LATEST
    assert Signal.coerce(Signal.EWMA) is Signal.EWMA
    with pytest.raises(ValueError):
        Signal.coerce("p99")
    with pytest.raises(ValueError):
        PlaneSpec(params=paper_controller_params(), signal="bogus")


def test_plane_spec_rejects_unknown_backend():
    with pytest.raises(ValueError):
        PlaneSpec(params=paper_controller_params(), backend="quantum")


def test_memory_plane_array_backend_closed_loop():
    """The fused array backend drives a real cache through the paper's
    burst/shrink/recover scenario, same as the scalar reference."""
    cache = ShardCache(capacity=60 * GiB, sizeof=lambda v: v.nbytes)
    for i in range(60):
        cache.put(i, Blob(1 * GiB))
    usage = ([20 * GiB] * 10) + ([95 * GiB] * 20) + ([20 * GiB] * 40)
    plane = MemoryPlane(PlaneSpec(
        params=paper_controller_params(),
        backend="array",
        nodes=(NodeSpec(
            "n0",
            monitor=SimulatedMonitor("n0", total=125 * GiB, usage=usage,
                                     storage_used_fn=cache.used),
            stores=(StoreSpec(cache, max_bytes=60 * GiB),),
            u0=60 * GiB),),
    ))
    caps = []
    for _ in range(len(usage)):
        actions = plane.tick()
        assert len(actions) == 1
        caps.append(cache.capacity() / GiB)
    assert min(caps[10:30]) < 30          # shrunk during the burst
    assert caps[-1] > 55                  # recovered to u_max
    assert cache.used() <= cache.capacity()
    assert cache.stats.evictions >= 25
    assert plane.capacity("n0") == pytest.approx(caps[-1] * GiB, rel=1e-6)


def _heterogeneous_fleet(backend, base, M, u_min, u_max, u0, demand):
    """One plane with per-node capacity overrides and trace monitors."""
    n = len(M)
    nodes = tuple(
        NodeSpec(
            f"n{i}",
            monitor=SimulatedMonitor(f"n{i}", total=M[i], usage=demand[i]),
            registry=StoreRegistry(),
            u0=u0[i],
            params=base.replace(total_memory=M[i], u_min=u_min[i],
                                u_max=u_max[i]))
        for i in range(n))
    return MemoryPlane(PlaneSpec(params=base, backend=backend, nodes=nodes))


@pytest.mark.parametrize("variant", ["paper", "extended"])
def test_array_scalar_parity_256_heterogeneous_nodes(variant):
    """Acceptance: ArrayController matches the scalar reference within
    1e-4 relative tolerance across a mixed fleet (mixed M, u_min/u_max,
    feedforward/deadband both off and on)."""
    rng = np.random.default_rng(42)
    n, t = 256, 30
    M = rng.uniform(64, 256, n) * GiB
    u_max = rng.uniform(20, 60, n) * GiB
    u_min = rng.uniform(0, 5, n) * GiB
    u0 = rng.uniform(u_min, u_max)
    base = ControllerParams(total_memory=125 * GiB)
    if variant == "paper":
        demand = rng.uniform(0.5, 1.05, (n, t)) * M[:, None]
    else:
        base = base.replace(feedforward=0.5, deadband=0.015, lam_grant=0.25)
        # piecewise-constant demand on a coarse utilization grid keeps
        # float32-vs-float64 rounding away from the deadband boundary
        offsets = np.array([-0.25, -0.10, -0.04, 0.02, 0.06, 0.12])
        levels = rng.choice(offsets, size=(n, t // 5 + 1))
        demand = (base.r0 + np.repeat(levels, 5, axis=1)[:, :t]) * M[:, None]
    planes = {b: _heterogeneous_fleet(b, base, M, u_min, u_max, u0, demand)
              for b in ("scalar", "array")}
    for _ in range(t):
        for plane in planes.values():
            plane.tick()
    ref = np.array([planes["scalar"].capacity(f"n{i}") for i in range(n)])
    got = np.array([planes["array"].capacity(f"n{i}") for i in range(n)])
    np.testing.assert_allclose(got, ref, rtol=1e-4, atol=1e4)


def test_memory_plane_lifecycle_restart():
    """attach -> tick -> start -> stop -> re-start: the plane is
    restartable and keeps collecting actions."""
    params = paper_controller_params(interval_s=0.01)
    plane = MemoryPlane(PlaneSpec(params=params, backend="array"))
    plane.attach("n0",
                 SimulatedMonitor("n0", total=125 * GiB,
                                  usage=lambda i: 80 * GiB),
                 registry=StoreRegistry(), u0=30 * GiB)
    assert plane.nodes() == ["n0"]
    assert len(plane.tick()) == 1
    assert not plane.running

    plane.start()
    assert plane.running
    time.sleep(0.15)
    plane.stop()
    assert not plane.running
    n1 = len(plane.actions())
    assert n1 > 1

    plane.start()                      # restart after stop
    time.sleep(0.15)
    plane.stop()
    assert len(plane.actions()) > n1

    with plane:                        # context-manager lifecycle
        assert plane.running
        time.sleep(0.05)
    assert not plane.running


def test_action_history_is_bounded():
    plane = MemoryPlane(PlaneSpec(
        params=paper_controller_params(), backend="array", history=8,
        nodes=(NodeSpec("n0",
                        monitor=SimulatedMonitor(
                            "n0", total=125 * GiB,
                            usage=lambda i: 100 * GiB),
                        registry=StoreRegistry(), u0=30 * GiB),)))
    for _ in range(40):
        plane.tick()
    assert len(plane.actions()) == 8
    assert len(plane.actions(limit=3)) == 3
    # scalar backend honors the same bound
    shim = ControlPlane(paper_controller_params(), max_history=8)
    shim.attach("n0", SimulatedMonitor("n0", total=125 * GiB,
                                       usage=lambda i: 100 * GiB),
                StoreRegistry(), u0=30 * GiB)
    for _ in range(40):
        shim.tick()
    assert len(shim.controller.actions) == 8


def test_squeeze_clamps_without_moving_control_state():
    cache = ShardCache(capacity=40 * GiB, sizeof=lambda v: v.nbytes)
    for i in range(40):
        cache.put(i, Blob(1 * GiB))
    plane = MemoryPlane(PlaneSpec(
        params=paper_controller_params(), backend="array",
        nodes=(NodeSpec("n0",
                        monitor=SimulatedMonitor(
                            "n0", total=125 * GiB,
                            usage=lambda i: 40 * GiB,
                            storage_used_fn=cache.used),
                        stores=(StoreSpec(cache, 60 * GiB),),
                        u0=40 * GiB),)))
    assert plane.squeeze("n0", 0.25)
    assert cache.capacity() == pytest.approx(10 * GiB)
    assert plane.capacity("n0") == pytest.approx(40 * GiB)   # u untouched
    plane.tick()                       # law re-grants from slack
    assert cache.capacity() > 10 * GiB
    assert not plane.squeeze("ghost", 0.5)


def test_per_node_gain_override_rejected_on_array_backend():
    from repro.core import ArrayController
    base = paper_controller_params()
    ac = ArrayController(base)
    with pytest.raises(ValueError):
        ac.attach_node("n0", StoreRegistry(), u0=0.0,
                       params=base.replace(lam=1.5))
    ac.attach_node("n1", StoreRegistry(), u0=0.0,
                   params=base.replace(u_max=10 * GiB))   # capacities ok


def test_control_plane_shim_is_deprecated_memory_plane():
    with pytest.warns(DeprecationWarning):
        shim = ControlPlane(paper_controller_params())
    assert isinstance(shim, MemoryPlane)
    from repro.core.controller import ControlPlane as legacy_path
    assert legacy_path is ControlPlane


def test_scalar_tick_returns_full_fleet_despite_small_history():
    """tick() must return every node's action even when the retained
    history bound is smaller than the fleet (both backends)."""
    for backend in ("scalar", "array"):
        plane = MemoryPlane(PlaneSpec(
            params=paper_controller_params(), backend=backend, history=4,
            nodes=tuple(
                NodeSpec(f"n{i}",
                         monitor=SimulatedMonitor(
                             f"n{i}", total=125 * GiB,
                             usage=lambda t: 90 * GiB),
                         registry=StoreRegistry(), u0=30 * GiB)
                for i in range(12))))
        actions = plane.tick()
        assert len(actions) == 12, backend
        assert len(plane.actions()) == 4          # retained log stays bounded


def test_attach_rejects_registry_and_stores_together():
    plane = MemoryPlane(PlaneSpec(params=paper_controller_params()))
    cache = ShardCache(capacity=1 * GiB)
    with pytest.raises(ValueError):
        plane.attach("n0",
                     SimulatedMonitor("n0", total=125 * GiB,
                                      usage=lambda i: 50 * GiB),
                     registry=StoreRegistry(),
                     stores=(StoreSpec(cache, 1 * GiB),))


def test_idle_engine_still_ticks_plane():
    """A fully-idle (e.g. fully-preempted) serving engine must keep
    ticking its plane or a reclaimed pool can never be re-granted."""
    import repro.serving.engine as E

    class _Plane:
        ticks = 0
        def attach(self, *a, **k):
            return StoreRegistry()
        def tick(self):
            self.ticks += 1
            return []

    eng = E.ServingEngine.__new__(E.ServingEngine)
    eng.steps = 0
    eng.plane = _Plane()
    eng.queue = []
    eng.finished = {}
    eng.slots = [E._Slot()]
    eng.pool = type("P", (), {"drain_preempted": staticmethod(lambda: []),
                              "num_free_blocks": staticmethod(lambda: 0)})()
    eng.cfg = E.ServingConfig(max_batch=1)
    eng.step()
    assert eng.plane.ticks == 1
