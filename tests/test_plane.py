"""Monitoring plane: bus, aggregator, controller end-to-end."""

import numpy as np

from repro.core import (AGG_TOPIC, RAW_TOPIC, ControlPlane, GiB,
                        MemorySample, MessageBus, MetricAggregator,
                        ShardCache, SimulatedMonitor, StoreRegistry)
from repro.core.cluster_sim import paper_controller_params


class Blob:
    def __init__(self, nbytes):
        self.nbytes = nbytes


def test_bus_pubsub_and_poll():
    bus = MessageBus()
    seen = []
    unsub = bus.subscribe("t", seen.append)
    bus.publish("t", 1)
    bus.publish("t", 2)
    assert seen == [1, 2]
    assert bus.poll("t", group="g1") == [1, 2]
    assert bus.poll("t", group="g1") == []
    bus.publish("t", 3)
    assert bus.poll("t", group="g1") == [3]
    unsub()
    bus.publish("t", 4)
    assert seen == [1, 2, 3] or seen == [1, 2]  # unsubscribed


def test_bus_isolates_subscriber_exceptions():
    bus = MessageBus()
    bus.subscribe("t", lambda m: 1 / 0)
    bus.publish("t", "x")              # must not raise
    assert len(bus.errors) == 1


def test_sample_json_roundtrip():
    s = MemorySample(node="n0", timestamp=1.5, used=10.0, total=100.0,
                     storage_used=4.0)
    assert MemorySample.from_json(s.to_json()) == s


def test_aggregator_window_and_slope():
    agg = MetricAggregator(window=4)
    out = None
    for i, used in enumerate([10, 20, 30, 40]):
        out = agg.update(MemorySample("n", float(i), used, 100.0))
    assert out.used_latest == 40
    assert out.used_mean == 25
    assert out.used_max == 40
    assert abs(out.slope_per_interval - 10.0) < 1e-9


def test_control_plane_closed_loop_burst():
    """Full pipeline: burst -> cache shrinks within intervals; burst
    clears -> cache regrows (paper Fig. 7 behaviour)."""
    p = paper_controller_params()
    plane = ControlPlane(p)
    cache = ShardCache(capacity=60 * GiB, sizeof=lambda v: v.nbytes)
    for i in range(60):
        cache.put(i, Blob(1 * GiB))
    reg = StoreRegistry()
    reg.register(cache, max_bytes=60 * GiB)

    usage = ([20 * GiB] * 10) + ([95 * GiB] * 20) + ([20 * GiB] * 40)
    mon = SimulatedMonitor("n0", total=125 * GiB, usage=usage,
                           storage_used_fn=cache.used)
    plane.attach("n0", mon, reg, u0=60 * GiB)

    caps = []
    for _ in range(len(usage)):
        plane.tick()
        caps.append(cache.capacity() / GiB)
    # burst (compute 95 GiB): u* = 0.95*125 - 95 = 23.75 GiB
    assert min(caps[10:30]) < 30
    # recovery: back to u_max
    assert caps[-1] > 55
    # actual evictions happened and usage tracked capacity
    assert cache.used() <= cache.capacity()
    assert cache.stats.evictions >= 25


def test_control_actions_published():
    p = paper_controller_params()
    plane = ControlPlane(p)
    cache = ShardCache(capacity=0, sizeof=lambda v: 1.0)
    reg = StoreRegistry()
    reg.register(cache, max_bytes=60 * GiB)
    mon = SimulatedMonitor("n0", total=125 * GiB, usage=[50 * GiB] * 5)
    plane.attach("n0", mon, reg)
    for _ in range(5):
        plane.tick()
    from repro.core import CONTROL_TOPIC
    actions = plane.bus.poll(CONTROL_TOPIC, group="test")
    assert len(actions) == 5
    assert all(a.node == "n0" for a in actions)
