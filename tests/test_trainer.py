"""Trainer integration: fit, checkpoint/restart exactness, DynIMS tick."""

import os

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.dynims import host_cache_params
from repro.core import GiB
from repro.core.controller import ControlPlane
from repro.data import DataPipeline, PipelineConfig, ShardStore, write_corpus
from repro.models import Model
from repro.train import Trainer, TrainerConfig, TrainStepConfig


@pytest.fixture(scope="module")
def setup(tmp_path_factory):
    tmp = tmp_path_factory.mktemp("trainer")
    corpus = str(tmp / "corpus")
    write_corpus(corpus, n_shards=8, tokens_per_shard=4096, vocab_size=503)
    cfg = get_config("llama3.2-1b-smoke")
    model = Model(cfg, remat="full", attn_impl="dense")
    params = model.init(jax.random.key(0))
    return tmp, corpus, cfg, model, params


def make_trainer(tmp, corpus, model, steps, ckpt_dir, plane=None,
                 schedule_steps=None):
    pipe = DataPipeline(
        ShardStore(corpus),
        PipelineConfig(batch_size=4, seq_len=32, cache_bytes=1 << 20,
                       prefetch_depth=0, dynims=plane is not None),
        plane=plane)
    return pipe, Trainer(
        model, pipe,
        TrainStepConfig(microbatches=2, warmup_steps=2,
                        total_steps=schedule_steps or steps),
        TrainerConfig(steps=steps, checkpoint_every=4,
                      checkpoint_dir=ckpt_dir, log_every=2),
        plane=plane)


def test_loss_decreases(setup):
    tmp, corpus, cfg, model, params = setup
    pipe, tr = make_trainer(tmp, corpus, model, 14, str(tmp / "ck1"))
    tr.fit(params)
    losses = [r["loss"] for r in tr.metrics_log]
    assert losses[-1] < losses[0]
    pipe.close()


def test_restart_is_exact(setup):
    """Straight-through training and crash+resume must produce the SAME
    final parameters (deterministic pipeline + checkpointed state)."""
    tmp, corpus, cfg, model, params = setup

    pipe1, tr1 = make_trainer(tmp, corpus, model, 8, str(tmp / "ckA"))
    pA, _ = tr1.fit(params)
    pipe1.close()

    # crash after 4 steps (checkpoint_every=4), then resume to 8; the
    # interrupted run keeps the SAME schedule horizon (8)
    pipe2, tr2 = make_trainer(tmp, corpus, model, 4, str(tmp / "ckB"),
                              schedule_steps=8)
    tr2.fit(params)
    pipe2.close()
    pipe3, tr3 = make_trainer(tmp, corpus, model, 8, str(tmp / "ckB"))
    pB, _ = tr3.resume(model.init(jax.random.key(42)))  # junk init
    pipe3.close()

    for a, b in zip(jax.tree.leaves(pA), jax.tree.leaves(pB)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-6, rtol=1e-5)


def test_dynims_plane_ticks_during_training(setup):
    tmp, corpus, cfg, model, params = setup
    plane = ControlPlane(host_cache_params(64 * GiB))
    pipe, tr = make_trainer(tmp, corpus, model, 6, str(tmp / "ck2"),
                            plane=plane)
    tr.fit(params)
    assert len(plane.controller.actions) >= 6
    assert pipe.hit_ratio >= 0.0
    pipe.close()


def test_straggler_squeeze_shrinks_cache(setup):
    tmp, corpus, cfg, model, params = setup
    plane = ControlPlane(host_cache_params(64 * GiB))
    pipe, tr = make_trainer(tmp, corpus, model, 4, str(tmp / "ck3"),
                            plane=plane)
    cap0 = pipe.cache.capacity()
    tr._squeeze_worker("localhost", 0.5)
    assert pipe.cache.capacity() <= cap0 * 0.5 + 1
    pipe.close()
