"""PlaneCheck: per-rule fires/doesn't-fire pairs, lock regressions,
mutation gates, and the end-to-end zero-new-findings invariant."""

import os
import textwrap

import numpy as np
import pytest

from repro.analysis import Baseline, RULES, analyze_locks, analyze_traced, run
from repro.analysis.__main__ import main as planecheck_main
from repro.analysis.runtime import (dispatch_guard, excess_traces,
                                    record_trace, reset_trace_counts,
                                    trace_counts)

REPO = os.path.abspath(os.path.join(os.path.dirname(__file__), os.pardir))
BASELINE = os.path.join(REPO, "PLANECHECK_BASELINE.json")


def traced_rules(tmp_path, code):
    p = tmp_path / "snippet.py"
    p.write_text(textwrap.dedent(code))
    return [f.rule for f in analyze_traced([str(p)], root=str(tmp_path))]


def lock_rules(tmp_path, code):
    p = tmp_path / "snippet.py"
    p.write_text(textwrap.dedent(code))
    return [f.rule for f in analyze_locks([str(p)], root=str(tmp_path))]


# ---------------------------------------------------------------------------
# TraceLint rule pairs
# ---------------------------------------------------------------------------

def test_t001_host_sync_fires(tmp_path):
    rules = traced_rules(tmp_path, """
        import jax

        @jax.jit
        def f(x):
            return x.item()
        """)
    assert "PC-T001" in rules


def test_t001_untraced_function_does_not_fire(tmp_path):
    rules = traced_rules(tmp_path, """
        def host_helper(x):
            return x.item()
        """)
    assert rules == []


def test_t002_float_cast_fires(tmp_path):
    rules = traced_rules(tmp_path, """
        import jax

        @jax.jit
        def f(x):
            return float(x) + 1.0
        """)
    assert "PC-T002" in rules


def test_t002_shape_metadata_does_not_fire(tmp_path):
    rules = traced_rules(tmp_path, """
        import jax

        @jax.jit
        def f(x):
            return x * float(x.shape[0])
        """)
    assert rules == []


def test_t002_static_kwonly_arg_does_not_fire(tmp_path):
    # keyword-only args follow the repo convention: static under jit
    rules = traced_rules(tmp_path, """
        import jax
        import functools

        def f(x, *, scale):
            return x * float(scale)

        g = jax.jit(f, static_argnames=("scale",))
        """)
    assert rules == []


def test_t003_branch_on_traced_fires(tmp_path):
    rules = traced_rules(tmp_path, """
        import jax

        @jax.jit
        def f(x):
            if x > 0:
                return x
            return -x
        """)
    assert "PC-T003" in rules


def test_t003_is_none_and_key_membership_do_not_fire(tmp_path):
    rules = traced_rules(tmp_path, """
        import jax

        @jax.jit
        def f(params, y):
            if y is None:
                y = params["a"]
            if "b" in params:
                y = y + params["b"]
            return y
        """)
    assert rules == []


def test_t004_numpy_on_traced_fires(tmp_path):
    rules = traced_rules(tmp_path, """
        import jax
        import numpy as np

        @jax.jit
        def f(x):
            return np.sum(x)
        """)
    assert "PC-T004" in rules


def test_t004_numpy_on_constants_does_not_fire(tmp_path):
    rules = traced_rules(tmp_path, """
        import jax
        import numpy as np

        @jax.jit
        def f(x):
            return x * np.float32(3.0)
        """)
    assert rules == []


def test_t005_f64_promotion_fires(tmp_path):
    rules = traced_rules(tmp_path, """
        import jax
        import jax.numpy as jnp

        @jax.jit
        def f(x):
            return jnp.asarray(x, jnp.float64)
        """)
    assert "PC-T005" in rules


def test_t005_f32_does_not_fire(tmp_path):
    rules = traced_rules(tmp_path, """
        import jax
        import jax.numpy as jnp

        @jax.jit
        def f(x):
            return jnp.asarray(x, jnp.float32)
        """)
    assert rules == []


def test_t006_sort_and_traced_scatter_fire(tmp_path):
    rules = traced_rules(tmp_path, """
        import jax
        import jax.numpy as jnp

        @jax.jit
        def f(x, idx):
            y = jnp.sort(x)
            return y.at[idx].set(0.0)
        """)
    assert rules.count("PC-T006") == 2


def test_t006_static_index_scatter_does_not_fire(tmp_path):
    rules = traced_rules(tmp_path, """
        import jax

        @jax.jit
        def f(x):
            return x.at[0].set(0.0)
        """)
    assert rules == []


def test_t007_jit_in_loop_fires(tmp_path):
    rules = traced_rules(tmp_path, """
        import jax

        def build(n):
            out = []
            for i in range(n):
                out.append(jax.jit(lambda x: x + i))
            return out
        """)
    assert "PC-T007" in rules


def test_t007_hoisted_jit_does_not_fire(tmp_path):
    rules = traced_rules(tmp_path, """
        import jax

        def build(n):
            step = jax.jit(lambda x: x + 1)
            return [step for _ in range(n)]
        """)
    assert rules == []


def test_taint_flows_through_scan_and_partial(tmp_path):
    # the lab/sweep idiom: partial-bound statics + lax.scan body
    rules = traced_rules(tmp_path, """
        import functools
        import jax

        def kernel(demand, gains, *, paper_law):
            def step(carry, d):
                bad = d.item()          # host sync on the scanned value
                return carry, bad
            return jax.lax.scan(step, gains, demand)

        fn = functools.partial(kernel, paper_law=True)
        compiled = jax.jit(fn)
        """)
    assert "PC-T001" in rules


def test_planecheck_ignore_pragma_suppresses(tmp_path):
    rules = traced_rules(tmp_path, """
        import jax

        @jax.jit
        def f(x):
            return float(x)  # planecheck: ignore[PC-T002]
        """)
    assert rules == []


# ---------------------------------------------------------------------------
# LockLint rule pairs
# ---------------------------------------------------------------------------

INVERSION = """
    import threading

    class C:
        def __init__(self):
            self.a = threading.Lock()
            self.b = threading.Lock()

        def m1(self):
            with self.a:
                with self.b:
                    pass

        def m2(self):
            with self.b:
                with self.a:
                    pass
    """


def test_l001_inversion_fires(tmp_path):
    assert "PC-L001" in lock_rules(tmp_path, INVERSION)


def test_l001_consistent_order_does_not_fire(tmp_path):
    rules = lock_rules(tmp_path, """
        import threading

        class C:
            def __init__(self):
                self.a = threading.Lock()
                self.b = threading.Lock()

            def m1(self):
                with self.a:
                    with self.b:
                        pass

            def m2(self):
                with self.a:
                    with self.b:
                        pass
        """)
    assert rules == []


def test_l001_cross_method_inversion_through_call(tmp_path):
    # m2 holds b and calls m1, which acquires a; m3 orders a before b
    rules = lock_rules(tmp_path, """
        import threading

        class C:
            def __init__(self):
                self.a = threading.Lock()
                self.b = threading.Lock()

            def locked_a(self):
                with self.a:
                    pass

            def m2(self):
                with self.b:
                    self.locked_a()

            def m3(self):
                with self.a:
                    with self.b:
                        pass
        """)
    assert "PC-L001" in rules


GUARDED = """
    import threading

    class C:
        def __init__(self):
            self._lock = threading.Lock()
            self._items = []   # guarded-by: _lock

        def {body}
    """


def test_l002_unlocked_mutation_fires(tmp_path):
    code = GUARDED.format(body="bad(self):\n            "
                               "self._items.append(1)")
    assert "PC-L002" in lock_rules(tmp_path, code)


def test_l002_locked_mutation_does_not_fire(tmp_path):
    code = GUARDED.format(body="good(self):\n            "
                               "with self._lock:\n                "
                               "self._items.append(1)")
    assert lock_rules(tmp_path, code) == []


def test_l002_holds_pragma_trusted(tmp_path):
    code = GUARDED.format(body="helper(self):  # locklint: holds _lock\n"
                               "            self._items.append(1)")
    assert lock_rules(tmp_path, code) == []


def test_l002_documentation_only_guard_not_enforced(tmp_path):
    # guard names that are not lock attrs (e.g. join(_thread)) document
    # a synchronization contract the analyzer cannot check
    rules = lock_rules(tmp_path, """
        import threading

        class H:
            def __init__(self, thread):
                self._thread = thread
                self._box = {}   # guarded-by: join(_thread)

            def late_write(self):
                self._box["k"] = 1
        """)
    assert rules == []


def test_l003_blocking_under_lock_fires(tmp_path):
    rules = lock_rules(tmp_path, """
        import threading
        import time

        class C:
            def __init__(self):
                self._lock = threading.Lock()

            def bad(self):
                with self._lock:
                    time.sleep(1.0)
        """)
    assert "PC-L003" in rules


def test_l003_blocking_outside_lock_does_not_fire(tmp_path):
    rules = lock_rules(tmp_path, """
        import threading
        import time

        class C:
            def __init__(self):
                self._lock = threading.Lock()

            def good(self):
                time.sleep(1.0)
                with self._lock:
                    pass
        """)
    assert rules == []


def test_l003_transitive_blocking_through_callee(tmp_path):
    rules = lock_rules(tmp_path, """
        import threading

        class C:
            def __init__(self):
                self._lock = threading.Lock()

            def slow_flush(self):
                with open("/tmp/x", "w") as fh:
                    fh.write("x")

            def bad(self):
                with self._lock:
                    self.slow_flush()
        """)
    assert "PC-L003" in rules


# ---------------------------------------------------------------------------
# Baseline semantics
# ---------------------------------------------------------------------------

def test_baseline_requires_justification():
    b = Baseline([{"rule": "PC-T001", "file": "f.py", "symbol": "g",
                   "justification": ""}])
    assert b.validate()


def test_baseline_matches_on_symbol_not_line(tmp_path):
    p = tmp_path / "snippet.py"
    p.write_text(textwrap.dedent("""
        import jax

        @jax.jit
        def f(x):
            return x.item()
        """))
    findings = analyze_traced([str(p)], root=str(tmp_path))
    assert findings
    b = Baseline([{"rule": f.rule, "file": f.file, "symbol": f.symbol,
                   "justification": "test"} for f in findings])
    assert all(b.covers(f) for f in findings)
    assert b.stale() == []


def test_rule_catalog_covers_both_families():
    assert {r[:4] for r in RULES} == {"PC-T", "PC-L"}
    assert len(RULES) == 10


# ---------------------------------------------------------------------------
# Mutation gates (acceptance criteria)
# ---------------------------------------------------------------------------

def test_gate_fails_on_injected_item_in_scan(tmp_path):
    src = open(os.path.join(REPO, "src/repro/lab/sweep.py")).read()
    needle = "        law = (u_next,) if paper_law else (u_next, v)"
    assert needle in src
    mutated = tmp_path / "sweep_mut.py"
    mutated.write_text(src.replace(
        needle, "        _bad = r.item()\n" + needle, 1))
    findings = analyze_traced([str(mutated)], root=str(tmp_path))
    assert any(f.rule == "PC-T001" and "step" in f.symbol
               for f in findings)
    rc = planecheck_main([str(mutated), "--check", "--baseline", BASELINE])
    assert rc == 1


def test_gate_fails_on_injected_lock_inversion(tmp_path):
    src = open(os.path.join(REPO, "src/repro/core/plane.py")).read()
    needle = "    def record(self, capacity"
    assert needle in src
    inj = ("    def _inverted(self):\n"
           "        with self._lock:\n"
           "            with self._tick_lock:\n"
           "                pass\n\n")
    mutated = tmp_path / "plane_mut.py"
    mutated.write_text(src.replace(needle, inj + needle, 1))
    findings = analyze_locks([str(mutated)], root=str(tmp_path))
    assert any(f.rule == "PC-L001" and "_tick_lock" in f.symbol
               for f in findings)
    rc = planecheck_main([str(mutated), "--check", "--baseline", BASELINE])
    assert rc == 1


# ---------------------------------------------------------------------------
# End-to-end over src/
# ---------------------------------------------------------------------------

def test_src_tree_has_zero_nonbaselined_findings(monkeypatch):
    monkeypatch.chdir(REPO)
    baseline = Baseline.load(BASELINE)
    assert baseline.validate() == []
    assert len(baseline.entries) <= 10
    findings, new = run(["src"], baseline)
    assert new == [], "\n".join(f.format() for f in new)
    assert baseline.stale() == []


def test_src_tree_has_no_lock_inversions(monkeypatch):
    monkeypatch.chdir(REPO)
    assert [f for f in analyze_locks(["src"])
            if f.rule == "PC-L001"] == []


def test_cli_check_exits_zero_on_tree(monkeypatch):
    monkeypatch.chdir(REPO)
    assert planecheck_main(["src", "--check", "--baseline", BASELINE]) == 0


# ---------------------------------------------------------------------------
# Runtime sanitizers
# ---------------------------------------------------------------------------

def test_record_trace_counts_and_excess(planecheck_sanitizers):
    reset_trace_counts()
    record_trace("unit.test", shape=4)
    record_trace("unit.test", shape=4)
    record_trace("unit.test", shape=8)
    counts = trace_counts("unit.test")
    assert counts == {"unit.test{shape=4}": 2, "unit.test{shape=8}": 1}
    assert excess_traces("unit.test") == {"unit.test{shape=4}": 2}
    reset_trace_counts()
    assert trace_counts("unit.test") == {}


def test_record_trace_noop_when_disabled(monkeypatch):
    # the counter dict must not grow in a production process (one key
    # per fleet size from plane.fused_step would accumulate forever)
    monkeypatch.delenv("PLANECHECK_SANITIZERS", raising=False)
    reset_trace_counts()
    record_trace("unit.disabled", shape=4)
    assert trace_counts("unit.disabled") == {}


def test_dispatch_guard_noop_when_disabled(monkeypatch):
    jnp = pytest.importorskip("jax.numpy")
    monkeypatch.delenv("PLANECHECK_SANITIZERS", raising=False)
    with dispatch_guard():
        assert float(jnp.sum(jnp.asarray(np.ones(4, np.float32)))) == 4.0


def test_dispatch_guard_blocks_implicit_transfers(planecheck_sanitizers):
    jnp = pytest.importorskip("jax.numpy")
    host = np.ones(8, np.float32)
    with dispatch_guard():
        # implicit host->device conversion of a numpy operand is
        # exactly the per-chunk regression class the guard exists for
        with pytest.raises(Exception, match="[Tt]ransfer"):
            jnp.sum(host).block_until_ready()


def test_sweep_compiles_once_per_shape(planecheck_sanitizers):
    pytest.importorskip("jax")
    from repro.core.cluster_sim import paper_controller_params
    from repro.core.traces import fleet_demand_traces
    from repro.lab import GainSet, sweep_demand

    p = paper_controller_params()
    # a shape unique to this test so parallel-file runs cannot collide
    demand = fleet_demand_traces(3, 37, p.interval_s, seed=11)
    gains = GainSet.from_params(p)
    reset_trace_counts()
    for _ in range(2):
        sweep_demand(demand, gains, node_memory=p.total_memory,
                     interval_s=p.interval_s)
    key = [k for k in trace_counts("lab.sweep.chunk") if "horizon=37" in k]
    assert key and trace_counts("lab.sweep.chunk")[key[0]] == 1
    assert excess_traces("lab.sweep.chunk") == {}


def test_fused_step_compiles_once_per_fleet_shape(planecheck_sanitizers):
    jnp = pytest.importorskip("jax.numpy")
    from repro.core.control import ControllerParams
    from repro.core.plane import make_fused_step

    params = ControllerParams(total_memory=64.0, r0=0.7, lam=0.4)
    fused = make_fused_step(params)
    n = 5
    args = (jnp.zeros(n), jnp.zeros(n), jnp.zeros(n),
            jnp.zeros(n, bool), jnp.ones(n, bool), jnp.full(n, 64.0),
            jnp.zeros(n), jnp.full(n, 64.0))
    reset_trace_counts()
    fused(*args)
    fused(*args)
    assert trace_counts("plane.fused_step") == \
        {"plane.fused_step{nodes=5}": 1}
