"""Optimizer substrate: AdamW math, clipping, schedules, compression."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis "
                    "(pip install -r requirements-dev.txt)")
import hypothesis.strategies as st
from hypothesis import given, settings

from repro.optim import (adamw_init, adamw_update, compress_decompress,
                         compression_init, int8_dequantize, int8_quantize,
                         linear_warmup_cosine)
from repro.train.step import clip_by_global_norm, global_norm


def test_adamw_matches_reference_math():
    p = {"w": jnp.asarray([[1.0, -2.0], [0.5, 3.0]])}
    g = {"w": jnp.asarray([[0.1, 0.2], [-0.3, 0.4]])}
    state = adamw_init(p)
    lr, b1, b2, eps, wd = 0.1, 0.9, 0.95, 1e-8, 0.1
    p2, state2 = adamw_update(g, state, p, lr=lr, b1=b1, b2=b2,
                              weight_decay=wd)
    m = (1 - b1) * np.asarray(g["w"])
    v = (1 - b2) * np.asarray(g["w"]) ** 2
    mhat = m / (1 - b1)
    vhat = v / (1 - b2)
    expect = np.asarray(p["w"]) - lr * (
        mhat / (np.sqrt(vhat) + eps) + wd * np.asarray(p["w"]))
    np.testing.assert_allclose(np.asarray(p2["w"]), expect, rtol=1e-5)
    assert int(state2.step) == 1


def test_adamw_no_decay_on_1d_params():
    p = {"scale": jnp.ones((8,))}
    g = {"scale": jnp.zeros((8,))}
    state = adamw_init(p)
    p2, _ = adamw_update(g, state, p, lr=0.1, weight_decay=0.5)
    np.testing.assert_allclose(np.asarray(p2["scale"]), np.ones(8))


def test_clip_by_global_norm():
    tree = {"a": jnp.full((4,), 3.0), "b": jnp.full((4,), 4.0)}
    norm = float(global_norm(tree))
    assert norm == pytest.approx(10.0)
    clipped, reported = clip_by_global_norm(tree, 1.0)
    assert float(reported) == pytest.approx(10.0)
    assert float(global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)
    # no-op below the bound
    same, _ = clip_by_global_norm(tree, 100.0)
    np.testing.assert_allclose(np.asarray(same["a"]), 3.0)


def test_schedule_shape():
    kw = dict(peak_lr=1.0, warmup_steps=10, total_steps=100)
    assert float(linear_warmup_cosine(0, **kw)) == pytest.approx(0.1)
    assert float(linear_warmup_cosine(9, **kw)) == pytest.approx(1.0)
    assert float(linear_warmup_cosine(10, **kw)) <= 1.0
    end = float(linear_warmup_cosine(99, **kw))
    assert 0.09 < end < 0.15          # final_frac=0.1


@given(st.lists(st.floats(-100, 100), min_size=1, max_size=64))
@settings(max_examples=50, deadline=None)
def test_int8_roundtrip_bounded_error(vals):
    x = jnp.asarray(vals, jnp.float32)
    q, scale = int8_quantize(x)
    deq = int8_dequantize(q, scale)
    # max error is half a quantization step
    assert float(jnp.abs(x - deq).max()) <= float(scale) * 0.5 + 1e-6


def test_error_feedback_invariant():
    """deq_t + residual_{t+1} == grad_t + residual_t exactly: no signal
    is ever lost, only delayed (the EF convergence argument)."""
    g = {"w": jnp.asarray(np.random.default_rng(0).normal(0, 1, (64,)),
                          jnp.float32)}
    state = compression_init(g)
    total_in, total_out = np.zeros(64), np.zeros(64)
    for t in range(20):
        gt = jax.tree.map(lambda x: x * (t + 1) / 10.0, g)
        deq, state = compress_decompress(gt, state)
        total_in += np.asarray(gt["w"])
        total_out += np.asarray(deq["w"])
    # cumulative transmitted == cumulative true gradient minus the last
    # residual still in flight
    np.testing.assert_allclose(total_out + np.asarray(state.residual["w"]),
                               total_in, rtol=1e-5, atol=1e-5)


def test_compressed_training_still_converges():
    """A toy regression must reach near the uncompressed loss."""
    rng = np.random.default_rng(0)
    X = jnp.asarray(rng.normal(0, 1, (128, 8)), jnp.float32)
    true_w = jnp.asarray(rng.normal(0, 1, (8,)), jnp.float32)
    y = X @ true_w

    def run(compress):
        p = {"w": jnp.zeros((8,))}
        state = adamw_init(p)
        comp = compression_init(p)
        for _ in range(300):
            loss, g = jax.value_and_grad(
                lambda p: jnp.mean((X @ p["w"] - y) ** 2))(p)
            if compress:
                g, comp = compress_decompress(g, comp)
            p, state = adamw_update(g, state, p, lr=0.05, weight_decay=0.0)
        return float(jnp.mean((X @ p["w"] - y) ** 2))

    assert run(True) < 1e-2
    assert run(True) < run(False) * 50 + 1e-3
