"""Per-arch smoke tests (deliverable f) + model-level invariants.

Every assigned architecture instantiates a REDUCED same-family config
and runs forward + one train step on CPU, asserting output shapes and
finiteness.  The full configs are exercised only via the dry-run.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, SHAPES
from repro.models import Model, count_params
from repro.models import decode as D
from repro.train.step import TrainStepConfig, build_train_step, \
    init_train_state

RNG = np.random.default_rng(0)


def make_batch(cfg, b=2, s=16):
    batch = {"tokens": jnp.asarray(
        RNG.integers(0, cfg.vocab_size, (b, s)), jnp.int32)}
    if cfg.family == "audio":
        batch["frames"] = jnp.asarray(
            RNG.normal(0, 1, (b, cfg.vision_tokens, cfg.d_model)),
            jnp.float32)
    if cfg.family == "vlm":
        batch["images"] = jnp.asarray(
            RNG.normal(0, 1, (b, cfg.vision_tokens, cfg.d_model)),
            jnp.float32)
    # no "labels" key: loss exercises the shifted-tokens fallback path
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward(arch):
    cfg = get_config(arch + "-smoke")
    m = Model(cfg, remat="none", attn_impl="dense")
    params = m.init(jax.random.key(0))
    batch = make_batch(cfg)
    logits, aux = m.forward(params, batch)
    assert logits.shape == (2, 16, cfg.padded_vocab)
    assert bool(jnp.isfinite(logits).all())
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_train_step(arch):
    cfg = get_config(arch + "-smoke")
    m = Model(cfg, remat="full", attn_impl="dense")
    params = m.init(jax.random.key(0))
    tcfg = TrainStepConfig(microbatches=1, warmup_steps=1, total_steps=4)
    step = jax.jit(build_train_step(m, tcfg))
    state = init_train_state(params, tcfg)
    batch = make_batch(cfg)
    p2, state, metrics = step(params, state, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert bool(jnp.isfinite(metrics["grad_norm"]))
    # parameters actually moved
    moved = any(
        float(jnp.abs(a - b).max()) > 0
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)))
    assert moved


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_matches_forward(arch):
    """KV-cache/recurrent decode must reproduce the parallel forward.

    f32 caches isolate logic from cache quantization.  MoE gets a wider
    band: routing is discontinuous, so ~1e-3 numeric noise can flip a
    near-tied expert on a token (measured: bf16 caches flip experts;
    f32 caches agree to ~1e-6 -- see test body assertion).
    """
    cfg = get_config(arch + "-smoke")
    m = Model(cfg, remat="none", attn_impl="dense")
    params = m.init(jax.random.key(1))
    B, S = 2, 12
    batch = make_batch(cfg, B, S)
    tokens = batch["tokens"]
    logits_fwd, _ = m.forward(params, batch)

    state = D.init_state(m, B, 32, cache_dtype="float32")
    state = D._attach_cross_context(m, params, state, batch)
    outs = []
    for t in range(S):
        lg, state = D.decode_step(m, params, state, tokens[:, t:t + 1])
        outs.append(lg[:, 0])
    logits_dec = jnp.stack(outs, axis=1)
    rel = float(jnp.abs(logits_fwd - logits_dec).max()) / (
        float(jnp.abs(logits_fwd).max()) + 1e-9)
    assert rel < 5e-3, rel


def test_shape_skip_policy():
    """long_500k runs only for sub-quadratic archs (DESIGN §5)."""
    long = SHAPES["long_500k"]
    runs = {a for a in ARCH_IDS if get_config(a).supports_shape(long)}
    assert runs == {"gemma3-1b", "xlstm-125m", "hymba-1.5b"}
    for a in ARCH_IDS:     # everything supports the other three shapes
        cfg = get_config(a)
        for s in ("train_4k", "prefill_32k", "decode_32k"):
            assert cfg.supports_shape(SHAPES[s])


def test_published_param_counts():
    """Analytic parameter counts must be in the right ballpark for the
    flagship sizes (sanity against the configs being mis-entered)."""
    expect = {
        "dbrx-132b": (120e9, 140e9),
        "mistral-large-123b": (115e9, 130e9),
        "llama3.2-1b": (1.0e9, 1.6e9),
        "qwen2-1.5b": (1.2e9, 2.0e9),
        "gemma3-1b": (0.9e9, 1.6e9),
        "qwen2-moe-a2.7b": (13e9, 16e9),
        "llama-3.2-vision-11b": (8e9, 12e9),
        "hymba-1.5b": (1.2e9, 2.2e9),
        "whisper-large-v3": (1.4e9, 2.1e9),
        "xlstm-125m": (0.10e9, 0.22e9),
    }
    for a, (lo, hi) in expect.items():
        n = get_config(a).n_params()
        assert lo <= n <= hi, f"{a}: {n:.3e} outside [{lo:.1e},{hi:.1e}]"


def test_schema_params_match_analytic_count():
    """Schema-derived parameter count tracks the analytic formula."""
    for a in ("llama3.2-1b", "mistral-large-123b", "dbrx-132b"):
        cfg = get_config(a)
        m = Model(cfg)
        n_schema = count_params(m.schema())
        n_formula = cfg.n_params()
        assert abs(n_schema - n_formula) / n_formula < 0.06, a


def test_gemma_window_schedule_structure():
    cfg = get_config("gemma3-1b")
    m = Model(cfg)
    sch = m.schema()["layers"]
    assert "groups" in sch and "tail" in sch
    # 26 layers = 4 groups x (5 local + 1 global) + 2 tail
    gk = jax.tree.leaves(sch["groups"]["glob"]["attn"]["wq"],
                         is_leaf=lambda x: hasattr(x, "shape"))
    assert sch["groups"]["locals"]["attn"]["wq"].shape[0] == 4  # n_groups
    assert sch["groups"]["locals"]["attn"]["wq"].shape[1] == 5
    assert sch["tail"]["attn"]["wq"].shape[0] == 2


def test_sliding_window_masks_differ():
    """Local vs global layers must actually attend differently."""
    cfg = get_config("gemma3-1b-smoke")
    m = Model(cfg, remat="none", attn_impl="dense")
    params = m.init(jax.random.key(0))
    b = make_batch(cfg, 2, 32)
    # degenerate check: shrinking the window changes the output
    import dataclasses
    cfg2 = dataclasses.replace(cfg, sliding_window=2)
    m2 = Model(cfg2, remat="none", attn_impl="dense")
    l1, _ = m.forward(params, b)
    l2, _ = m2.forward(params, b)
    assert float(jnp.abs(l1 - l2).max()) > 1e-4


def test_moe_capacity_drops_tokens():
    from repro.models.moe import moe_apply
    import dataclasses
    cfg = dataclasses.replace(get_config("dbrx-132b-smoke"),
                              capacity_factor=0.25)
    m = Model(cfg, remat="none")
    params = m.init(jax.random.key(0))
    lp = jax.tree.map(lambda t: t[0], params["layers"]["flat"])
    x = jnp.asarray(RNG.normal(0, 1, (2, 32, cfg.d_model)), jnp.float32)
    out_drop, _ = moe_apply(lp["moe"], x, cfg)
    cfg2 = dataclasses.replace(cfg, capacity_factor=8.0)
    out_full, _ = moe_apply(lp["moe"], x, cfg2)
    # capacity drops change outputs (some tokens got no expert)
    assert float(jnp.abs(out_drop - out_full).max()) > 1e-4
    assert bool(jnp.isfinite(out_drop).all())


def test_moe_expert_padding_unroutable():
    """qwen2-moe pads 60 -> 64 experts; dummies must never be selected."""
    from repro.models.moe import padded_experts
    cfg = get_config("qwen2-moe-a2.7b")
    assert padded_experts(cfg) == 64
    smoke = get_config("qwen2-moe-a2.7b-smoke")
    m = Model(smoke, remat="none")
    params = m.init(jax.random.key(0))
    lp = jax.tree.map(lambda t: t[0], params["layers"]["flat"])
    # smoke config has 4 experts (< EP hint), no padding; force padding
    router = lp["moe"]["router"]
    logits = jnp.asarray(RNG.normal(0, 1, (8, router.shape[0])),
                         jnp.float32) @ router
    assert bool(jnp.isfinite(logits).all())


def test_mlstm_chunked_matches_sequential():
    """The chunkwise-parallel mLSTM must equal the step recurrence."""
    from repro.models import ssm
    cfg = get_config("xlstm-125m-smoke")
    from repro.models.params import Axes, init_params
    sch = ssm.mlstm_schema(cfg, Axes(fsdp=None, tp=None, batch=(None,)))
    params = init_params(sch, jax.random.key(0), jnp.float32)
    x = jnp.asarray(RNG.normal(0, 1, (2, 20, cfg.d_model)), jnp.float32)
    full = ssm.mlstm_apply(params, x, cfg, chunk=8)
    state = {k: jnp.asarray(np.zeros(v), jnp.float32) if k != "m" else
             jnp.full(v, -1e30, jnp.float32)
             for k, v in ssm.mlstm_state_shapes(cfg, 2).items()}
    outs = []
    for t in range(20):
        o, state = ssm.mlstm_decode_step(params, x[:, t:t + 1], state, cfg)
        outs.append(o[:, 0])
    seq = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(full), np.asarray(seq),
                               atol=2e-4, rtol=2e-3)


def test_mamba_chunked_matches_sequential():
    from repro.models import ssm
    cfg = get_config("hymba-1.5b-smoke")
    from repro.models.params import Axes, init_params
    sch = ssm.mamba_schema(cfg, Axes(fsdp=None, tp=None, batch=(None,)))
    params = init_params(sch, jax.random.key(0), jnp.float32)
    x = jnp.asarray(RNG.normal(0, 1, (2, 24, cfg.d_model)), jnp.float32)
    full = ssm.mamba_apply(params, x, cfg, chunk=8)
    hshape, cshape = ssm.mamba_state_shape(cfg, 2)
    state = jnp.zeros(hshape, jnp.float32)
    conv = jnp.zeros(cshape, jnp.float32)
    outs = []
    for t in range(24):
        o, state, conv = ssm.mamba_decode_step(params, x[:, t:t + 1],
                                               state, conv, cfg)
        outs.append(o[:, 0])
    seq = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(full), np.asarray(seq),
                               atol=2e-4, rtol=2e-3)
