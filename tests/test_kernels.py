"""Pallas kernel sweeps: shapes x dtypes vs pure-jnp oracles.

Kernels run in interpret mode on CPU -- the kernel BODY (blocking,
masking, online-softmax carry, scratch handling) is what is validated.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.decode_attention.ops import (decode_attention_op,
                                                decode_attention_ref)
from repro.kernels.flash_attention.ops import (attention_ref,
                                               flash_attention_op)
from repro.kernels.ssm_scan.ops import ssm_scan_op, ssm_scan_ref

RNG = np.random.default_rng(7)


def rand(shape, dtype):
    return jnp.asarray(RNG.normal(0, 1, shape), dtype)


FLASH_CASES = [
    # (b, sq, skv, h, kv, hd, causal, window, bq, bk)
    (2, 256, 256, 4, 2, 64, True, 0, 64, 64),
    (1, 128, 128, 4, 4, 32, True, 0, 128, 128),
    (2, 128, 256, 4, 1, 64, False, 0, 64, 64),     # cross-attn shape
    (1, 256, 256, 8, 2, 64, True, 64, 64, 64),     # sliding window
    (1, 512, 512, 2, 2, 128, True, 0, 128, 128),   # hw-aligned hd
    (2, 192, 192, 4, 2, 64, True, 48, 64, 64),     # window % block != 0
]


@pytest.mark.parametrize("case", FLASH_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_matches_ref(case, dtype):
    b, sq, skv, h, kv, hd, causal, window, bq, bk = case
    q = rand((b, sq, h, hd), dtype)
    k = rand((b, skv, kv, hd), dtype)
    v = rand((b, skv, kv, hd), dtype)
    out = flash_attention_op(q, k, v, causal=causal, window=window,
                             block_q=bq, block_k=bk)
    ref = attention_ref(q.astype(jnp.float32), k.astype(jnp.float32),
                        v.astype(jnp.float32), causal=causal,
                        window=window)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32), np.asarray(ref),
                               atol=tol, rtol=tol)


def test_flash_block_skipping_equivalence():
    """Causal block skipping must not change results vs full blocks."""
    q = rand((1, 256, 4, 64), jnp.float32)
    k = rand((1, 256, 4, 64), jnp.float32)
    v = rand((1, 256, 4, 64), jnp.float32)
    a = flash_attention_op(q, k, v, causal=True, block_q=64, block_k=64)
    b = flash_attention_op(q, k, v, causal=True, block_q=128, block_k=128)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               atol=1e-5, rtol=1e-5)


DECODE_CASES = [
    (4, 512, 8, 2, 64, 0, 128),
    (2, 1024, 4, 4, 32, 0, 256),
    (3, 512, 8, 4, 64, 200, 128),
    (1, 256, 2, 1, 128, 0, 64),
]


@pytest.mark.parametrize("case", DECODE_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention_matches_ref(case, dtype):
    b, s, h, kv, hd, window, bk = case
    q = rand((b, h, hd), dtype)
    kc = rand((b, s, kv, hd), dtype)
    vc = rand((b, s, kv, hd), dtype)
    lo = window + 1 if window else 1
    lens = jnp.asarray(RNG.integers(lo, s, (b,)), jnp.int32)
    out = decode_attention_op(q, kc, vc, lens, window=window, block_k=bk)
    ref = decode_attention_ref(q.astype(jnp.float32),
                               kc.astype(jnp.float32),
                               vc.astype(jnp.float32), lens, window=window)
    tol = 3e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32), np.asarray(ref),
                               atol=tol, rtol=tol)


def test_decode_attention_never_reads_past_length():
    """Poisoned cache beyond lengths must not affect the output."""
    b, s, h, kv, hd = 2, 256, 4, 2, 64
    q = rand((b, h, hd), jnp.float32)
    kc = rand((b, s, kv, hd), jnp.float32)
    vc = rand((b, s, kv, hd), jnp.float32)
    lens = jnp.asarray([100, 17], jnp.int32)
    out1 = decode_attention_op(q, kc, vc, lens, block_k=64)
    poison = jnp.where(
        (jnp.arange(s) >= lens[:, None])[..., None, None], 1e9, 0.0)
    out2 = decode_attention_op(q, kc + poison, vc + poison, lens,
                               block_k=64)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2),
                               atol=1e-5)


SSM_CASES = [
    (2, 256, 128, 16, 64, 128),
    (1, 128, 256, 8, 32, 64),
    (3, 64, 128, 4, 64, 128),
]


@pytest.mark.parametrize("case", SSM_CASES)
def test_ssm_scan_matches_ref(case):
    b, s, c, n, chunk, bc = case
    decay = jnp.asarray(RNG.uniform(0.3, 1.0, (b, s, c, n)), jnp.float32)
    drive = jnp.asarray(RNG.normal(0, 0.2, (b, s, c, n)), jnp.float32)
    h0 = jnp.asarray(RNG.normal(0, 1.0, (b, c, n)), jnp.float32)
    out = ssm_scan_op(decay, drive, h0, chunk=chunk, block_c=bc)
    ref = ssm_scan_ref(decay, drive, h0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=1e-5, rtol=1e-5)


def test_ssm_scan_carries_state_across_chunks():
    """A long scan chunked at 16 must equal an unchunked reference --
    the VMEM carry is the thing under test."""
    b, s, c, n = 1, 128, 128, 8
    decay = jnp.full((b, s, c, n), 0.99, jnp.float32)
    drive = jnp.ones((b, s, c, n), jnp.float32) * 0.01
    h0 = jnp.ones((b, c, n), jnp.float32)
    out = ssm_scan_op(decay, drive, h0, chunk=16, block_c=128)
    ref = ssm_scan_ref(decay, drive, h0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=1e-5, rtol=1e-5)
