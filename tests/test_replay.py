"""ReplayLoop: trace capture, replay scenarios, online re-tune, hot-swap.

Three guarantees under test:

* **capture fidelity** -- a trace captured from a live ``MemoryPlane``
  and replayed through the sweep engine reproduces the observed closed
  loop (p99 utilization within the float32 + streaming-quantile
  tolerance), and survives an ``.npz`` round-trip bit for bit;
* **hot-swap safety** -- ``swap_params`` lands at an interval boundary
  even under a concurrently ticking plane: per node, exactly one action
  per tick, epochs monotone, no torn parameters;
* **the closed loop closes** -- ``retune_online`` tunes on the
  captured workload, never returns a score below the deployed gains,
  and the plane actually runs the winner afterwards.
"""

import threading
import time

import numpy as np
import pytest

from repro.configs.dynims import PAPER_TABLE_I
from repro.core import (ArrayController, CapturedTrace, GiB, MemoryPlane,
                        MemorySample, PlaneSpec, SimulatedMonitor,
                        TraceRecorder)
from repro.core.cluster_sim import paper_controller_params
from repro.core.controller import ControlAction
from repro.core.store import StoreRegistry
from repro.lab import (GainSet, ReplayTrace, ScenarioSpec, get_scenario,
                       retune_online, run_sweep)

P = paper_controller_params()


def _sample(node, t, used, total=125 * GiB, storage=0.0):
    return MemorySample(node=node, timestamp=t, used=used, total=total,
                        storage_used=storage)


def _action(node, u_next, epoch=0):
    return ControlAction(node=node, timestamp=0.0, u_prev=0.0,
                         u_next=u_next, utilization=0.5, epoch=epoch)


def _fake_capture(n=4, t=120, seed=0):
    rng = np.random.default_rng(seed)
    return CapturedTrace(
        nodes=tuple(f"n{i}" for i in range(n)),
        interval_s=0.1,
        demand=rng.uniform(20, 80, (n, t)) * GiB,
        utilization=rng.uniform(0.5, 1.0, (n, t)),
        grant=np.full((n, t), 60 * GiB),
        residency=np.zeros((n, t)),
        total_memory=np.full(n, 125 * GiB))


def _saturated_plane(demand, node_memory, params, record, backend="array"):
    """Monitors report demand + grant: the sweep's saturated store."""
    plane = MemoryPlane(PlaneSpec(params=params, backend=backend,
                                  record=record))
    t = demand.shape[1]
    for i in range(demand.shape[0]):
        name = f"node{i}"
        plane.attach(
            name,
            SimulatedMonitor(
                name, total=float(node_memory[i]),
                usage=lambda k, row=demand[i]: float(row[k % t]),
                storage_used_fn=lambda nm=name: plane.capacity(nm)),
            registry=StoreRegistry(), u0=params.u_max)
    return plane


# ---------------------------------------------------------------------------
# TraceRecorder / CapturedTrace
# ---------------------------------------------------------------------------

def test_recorder_ring_is_bounded():
    rec = TraceRecorder(capacity=8)
    for t in range(30):
        rec.record({"n0": _sample("n0", t * 0.1, (30 + t) * GiB)},
                   [_action("n0", 50 * GiB)])
    assert len(rec) == 8
    cap = rec.snapshot(interval_s=0.1)
    assert cap.n_intervals == 8
    # ring retains the *last* 8 intervals
    np.testing.assert_allclose(cap.demand[0] / GiB, np.arange(52, 60))
    rec.clear()
    assert len(rec) == 0
    with pytest.raises(ValueError):
        rec.snapshot()
    with pytest.raises(ValueError):
        TraceRecorder(capacity=0)


def test_recorder_fills_node_gaps():
    """A node missing from some intervals (late join, skipped sample)
    is forward/backward-filled so the arrays stay rectangular."""
    rec = TraceRecorder(capacity=16)
    for t in range(6):
        tick = {"a": _sample("a", t * 0.1, (10 + t) * GiB)}
        if t >= 2:                                  # "b" joins late
            tick["b"] = _sample("b", t * 0.1, (40 + t) * GiB)
        if t == 4:                                  # "a" skips one
            del tick["a"]
        rec.record(tick, [_action(n, 50 * GiB) for n in tick])
    cap = rec.snapshot()
    assert cap.nodes == ("a", "b")
    a, b = cap.demand / GiB
    np.testing.assert_allclose(a, [10, 11, 12, 13, 13, 15])  # ffill at t=4
    np.testing.assert_allclose(b, [42, 42, 42, 43, 44, 45])  # bfill head
    assert np.isfinite(cap.grant).all()


def test_capture_npz_roundtrip(tmp_path):
    cap = _fake_capture()
    path = tmp_path / "capture.npz"
    cap.save(path)
    back = CapturedTrace.load(path)
    assert back.nodes == cap.nodes
    assert back.interval_s == cap.interval_s
    for f in ("demand", "utilization", "grant", "residency", "total_memory"):
        np.testing.assert_array_equal(getattr(back, f), getattr(cap, f),
                                      err_msg=f)


def test_plane_capture_requires_recording():
    plane = MemoryPlane(PlaneSpec(params=P))
    with pytest.raises(ValueError):
        plane.capture()
    plane.record(capacity=4)
    plane.attach("n0", SimulatedMonitor("n0", total=125 * GiB,
                                        usage=lambda i: 60 * GiB),
                 registry=StoreRegistry(), u0=30 * GiB)
    plane.tick()
    assert plane.capture().n_intervals == 1


# ---------------------------------------------------------------------------
# Replay scenarios
# ---------------------------------------------------------------------------

def test_replay_spec_same_shape_is_exact():
    cap = _fake_capture()
    spec = ScenarioSpec.from_capture(cap, name="exact")
    assert spec.family == "replay"
    np.testing.assert_array_equal(spec.build_demand(seed=3), cap.demand)
    np.testing.assert_array_equal(spec.build_node_memory(seed=3),
                                  cap.total_memory)
    # a spec stays a value: hashable and replaceable
    assert hash(spec) == hash(spec.replace())
    assert spec.replace(n_nodes=8) != spec


def test_replay_interpolates_and_tiles():
    cap = _fake_capture(n=3, t=50)
    spec = ScenarioSpec.from_capture(cap, n_nodes=10, n_intervals=200)
    d = spec.build_demand(seed=0)
    assert d.shape == (10, 200)
    # captured nodes replay their (interpolated) trace: endpoints exact
    np.testing.assert_allclose(d[:3, 0], cap.demand[:, 0])
    np.testing.assert_allclose(d[:3, -1], cap.demand[:, -1])
    # clones are deterministic per seed and stay in the captured range
    np.testing.assert_array_equal(d, spec.build_demand(seed=0))
    assert not np.array_equal(spec.build_demand(seed=1)[3:], d[3:])
    m = spec.build_node_memory(seed=0)
    assert m.shape == (10,)
    np.testing.assert_array_equal(m[:3], cap.total_memory)


def test_replay_trace_payload_validation():
    with pytest.raises(ValueError):
        ScenarioSpec(name="bad", family="replay")          # no payload
    tr = ReplayTrace(np.ones((2, 4)) * GiB, 125 * GiB)
    with pytest.raises(ValueError):
        ScenarioSpec(name="bad", family="hpcc", replay=tr)  # wrong family
    with pytest.raises(AttributeError):
        tr.interval_s = 0.2                                # immutable
    assert tr == ReplayTrace(np.ones((2, 4)) * GiB, 125 * GiB)


def test_from_capture_fits_cache_from_residency():
    cap = _fake_capture()
    # no residency observed -> saturated store, and an explicit request
    # to fit one must fail loudly
    assert ScenarioSpec.from_capture(cap).cache is None
    with pytest.raises(ValueError):
        ScenarioSpec.from_capture(cap, fit_cache=True)
    res = np.minimum(np.cumsum(np.full(cap.demand.shape, 0.25 * GiB),
                               axis=1), 40 * GiB)
    warm = CapturedTrace(nodes=cap.nodes, interval_s=cap.interval_s,
                         demand=cap.demand, utilization=cap.utilization,
                         grant=cap.grant, residency=res,
                         total_memory=cap.total_memory)
    cache = ScenarioSpec.from_capture(warm).cache
    assert cache is not None
    # residency ceiling: 0.25 GiB x 120 intervals = 30 GiB (under the
    # 40 GiB cap) on 125 GiB nodes
    assert cache.working_set_frac == pytest.approx(30 / 125, rel=0.01)
    # refill flux: 0.25 GiB per 0.1 s interval = 2.5 GiB/s
    assert cache.refill_gibps == pytest.approx(2.5, rel=0.05)
    # and the fit is overridable
    assert ScenarioSpec.from_capture(warm, fit_cache=False).cache is None
    # residency that exactly tracks the grant IS the saturated store:
    # the auto heuristic must not re-simulate warmup that never happened
    saturated = CapturedTrace(nodes=cap.nodes, interval_s=cap.interval_s,
                              demand=cap.demand,
                              utilization=cap.utilization, grant=cap.grant,
                              residency=cap.grant.copy(),
                              total_memory=cap.total_memory)
    assert ScenarioSpec.from_capture(saturated).cache is None


def test_replay_roundtrip_p99_fidelity():
    """Acceptance: the captured trace replayed through the sweep
    reproduces the live plane's closed loop -- observed p99 within the
    f32 + streaming-quantile tolerance."""
    spec = get_scenario("swap-storm").replace(n_nodes=6, n_intervals=150)
    demand = spec.build_demand(seed=0)
    m = spec.build_node_memory(seed=0)
    plane = _saturated_plane(demand, m, PAPER_TABLE_I, record=150)
    for _ in range(150):
        plane.tick()
    cap = plane.capture()
    replay = ScenarioSpec.from_capture(cap, name="fidelity")
    r = run_sweep(replay, GainSet.from_params(PAPER_TABLE_I), seed=0)
    assert abs(float(r.stats.p99_utilization[0])
               - cap.utilization_p99()) <= 0.02
    assert abs(float(r.stats.mean_utilization[0])
               - float(cap.utilization.mean())) <= 0.01


# ---------------------------------------------------------------------------
# Hot-swap safety
# ---------------------------------------------------------------------------

def test_array_swap_updates_defaults_keeps_overrides():
    ctrl = ArrayController(P)
    ctrl.attach_node("plain", StoreRegistry(), u0=30 * GiB)
    ctrl.attach_node("pinned", StoreRegistry(), u0=5 * GiB,
                     params=P.replace(u_max=10 * GiB))
    new = P.replace(lam=1.5, u_max=50 * GiB)
    assert ctrl.swap_params(new) == 1
    assert ctrl.epoch == 1
    assert ctrl.params.lam == 1.5
    assert ctrl._u_max[ctrl._index["plain"]] == 50 * GiB
    assert ctrl._u_max[ctrl._index["pinned"]] == 10 * GiB   # kept


@pytest.mark.parametrize("backend", ["scalar", "array"])
def test_swap_mid_run_changes_the_law(backend):
    """A plane hot-swapped to a tighter threshold must reclaim further;
    control state carries over (no restart transient to u_max)."""
    demand = np.full((1, 8), 80 * GiB)      # saturated: v = 80G + grant
    plane = _saturated_plane(demand, np.array([125 * GiB]), P,
                             record=0, backend=backend)
    for _ in range(40):
        a = plane.tick()[0]
    settled = plane.capacity("node0")
    assert a.epoch == 0
    # u* = r0*M - d: 38.75G at the paper threshold
    assert settled == pytest.approx(0.95 * 125 * GiB - 80 * GiB, rel=0.05)
    # r0: 0.95 -> 0.80 moves the fixed point down to 20G
    epoch = plane.swap_params(P.replace(r0=0.80))
    assert epoch == 1 and plane.epoch == 1
    for _ in range(40):
        a = plane.tick()[0]
    assert a.epoch == 1
    assert plane.capacity("node0") == pytest.approx(
        0.80 * 125 * GiB - 80 * GiB, rel=0.05)


def test_concurrent_ticks_during_swap_are_never_torn():
    """Acceptance: tick() racing retune-style swaps -- every interval
    runs wholly under one epoch, one action per node per tick, epochs
    monotone, capacities always finite."""
    n_nodes, n_ticks = 4, 160
    plane = MemoryPlane(PlaneSpec(params=P, backend="array"))
    rng_demand = np.random.default_rng(0).uniform(40, 110, (n_nodes, 64))
    for i in range(n_nodes):
        plane.attach(f"n{i}",
                     SimulatedMonitor(
                         f"n{i}", total=125 * GiB,
                         usage=lambda k, row=rng_demand[i]:
                             float(row[k % 64] * GiB)),
                     registry=StoreRegistry(), u0=60 * GiB)
    audit = []

    def run():
        for _ in range(n_ticks):
            audit.extend(plane.tick())

    ticker = threading.Thread(target=run)
    ticker.start()
    variants = [P.replace(lam=l) for l in (1.0, 1.5, 0.25, 0.8)]
    for v in variants:
        time.sleep(0.02)
        plane.swap_params(v)
    ticker.join()
    assert plane.epoch == len(variants)
    per_tick = {}
    for k, a in enumerate(audit):
        per_tick.setdefault(k // n_nodes, []).append(a)
        assert np.isfinite(a.u_next)
    for i in range(n_nodes):
        actions = [a for a in audit if a.node == f"n{i}"]
        assert len(actions) == n_ticks            # nothing dropped/duplicated
        epochs = [a.epoch for a in actions]
        assert all(b >= a for a, b in zip(epochs, epochs[1:]))
    # swaps land at interval boundaries: one epoch per whole interval
    for k, acts in per_tick.items():
        assert len({a.epoch for a in acts}) == 1, f"torn interval {k}"


# ---------------------------------------------------------------------------
# retune_online: the loop closes
# ---------------------------------------------------------------------------

def test_retune_online_swaps_the_replay_winner():
    spec = get_scenario("swap-storm").replace(n_nodes=5, n_intervals=120)
    demand = spec.build_demand(seed=0)
    m = spec.build_node_memory(seed=0)
    plane = _saturated_plane(demand, m, PAPER_TABLE_I, record=120)
    for _ in range(120):
        plane.tick()
    result = retune_online(plane, name="retune-test", method="halving",
                           budget=12, seed=0, block=True)
    assert result.tune.score >= result.tune.baseline_score
    assert result.old_params == PAPER_TABLE_I
    assert result.swapped and result.epoch == 1
    assert plane.params == result.params != PAPER_TABLE_I
    assert plane.tick()[0].epoch == 1
    assert "hot-swapped" in result.summary()


def test_retune_online_respects_min_improvement():
    """An unreachable improvement bar must leave the deployed params
    alone (and the non-blocking handle must deliver the same result)."""
    spec = get_scenario("swap-storm").replace(n_nodes=4, n_intervals=80)
    demand = spec.build_demand(seed=1)
    m = spec.build_node_memory(seed=1)
    plane = _saturated_plane(demand, m, PAPER_TABLE_I, record=80)
    for _ in range(80):
        plane.tick()
    handle = retune_online(plane, budget=8, seed=1, block=False,
                           min_improvement=float("inf"))
    result = handle.result(timeout=300)
    assert handle.done
    assert not result.swapped and result.epoch is None
    assert plane.params == PAPER_TABLE_I and plane.epoch == 0
