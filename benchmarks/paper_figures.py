"""Benchmarks reproducing the paper's tables/figures from the simulator.

One function per figure; each returns (rows, derived) where rows are
CSV-ready and derived is the headline number validated against the
paper's claim.
"""

from __future__ import annotations

import time
from typing import Dict, List, Tuple

import numpy as np

from repro.core.cluster_sim import (make_paper_config, run_paper_experiment,
                                    simulate, simulate_fleet,
                                    paper_controller_params)
from repro.core.traces import GiB, IterativeAppSpec, hpcc_trace, hpl_slowdown
from repro.core import (fixed_point_capacity, simulate_saturated_loop,
                        settling_time)

# the four Spark apps of Fig. 5 (differ in compute intensity)
APPS = {
    "kmeans": IterativeAppSpec("kmeans", compute_s_per_gib=0.55),
    "logistic_regression": IterativeAppSpec("logistic", compute_s_per_gib=0.40),
    "linear_regression": IterativeAppSpec("linear", compute_s_per_gib=0.33),
    "svm": IterativeAppSpec("svm", compute_s_per_gib=0.48),
}


def fig1_memory_pattern() -> Tuple[List[dict], str]:
    t0 = time.perf_counter()
    tr = hpcc_trace(600.0, 0.1, seed=0) / GiB
    us = (time.perf_counter() - t0) * 1e6
    rows = [{"name": "fig1_hpcc_trace", "us_per_call": us,
             "derived": f"peak={tr.max():.1f}GiB;"
                        f"frac<=40GiB={float((tr <= 40).mean()):.2f}"}]
    return rows, f"peak {tr.max():.1f} GiB (paper: ~75)"


def fig2_pressure_curve() -> Tuple[List[dict], str]:
    t0 = time.perf_counter()
    pts = {u: hpl_slowdown(u) for u in (0.5, 0.9, 0.95, 0.98, 1.0)}
    us = (time.perf_counter() - t0) * 1e6 / len(pts)
    rows = [{"name": "fig2_hpl_slowdown", "us_per_call": us,
             "derived": ";".join(f"u{int(k*100)}={v:.2f}x"
                                 for k, v in pts.items())}]
    return rows, "collapse near 100% (paper Fig. 2)"


def fig5_applications() -> Tuple[List[dict], str]:
    rows = []
    best_s1 = best_s2 = 0.0
    for name, app in APPS.items():
        t0 = time.perf_counter()
        res = run_paper_experiment(app=app)
        us = (time.perf_counter() - t0) * 1e6
        s1 = res[1].app_runtime_s / res[3].app_runtime_s
        s2 = res[2].app_runtime_s / res[3].app_runtime_s
        best_s1, best_s2 = max(best_s1, s1), max(best_s2, s2)
        rows.append({
            "name": f"fig5_{name}", "us_per_call": us,
            "derived": (f"speedup_vs_spark45={s1:.2f}x;"
                        f"speedup_vs_static25={s2:.2f}x;"
                        f"hit={res[3].hit_ratio:.2f}")})
    return rows, (f"max speedups {best_s1:.1f}x / {best_s2:.1f}x "
                  "(paper: 5.1x / 3.8x)")


def fig6_problem_sizes() -> Tuple[List[dict], str]:
    rows = []
    for gib in (80, 160, 240, 320, 400):
        app = IterativeAppSpec(dataset_gib=float(gib), iterations=4)
        t0 = time.perf_counter()
        dyn = simulate(make_paper_config(3, app=app)).app_runtime_s
        sta = simulate(make_paper_config(2, app=app)).app_runtime_s
        us = (time.perf_counter() - t0) * 1e6
        rows.append({"name": f"fig6_size{gib}", "us_per_call": us,
                     "derived": f"dynims={dyn:.0f}s;static25={sta:.0f}s;"
                                f"ratio={sta/dyn:.2f}"})
    return rows, "static degrades from 160GiB (paper Fig. 6)"


def fig7_stability() -> Tuple[List[dict], str]:
    t0 = time.perf_counter()
    r = simulate(make_paper_config(3))
    us = (time.perf_counter() - t0) * 1e6
    rows = [{"name": "fig7_burst_timeline", "us_per_call": us,
             "derived": (f"cap_min={r.cap_gib.min():.1f}GiB;"
                         f"cap_final={r.cap_gib[-1]:.1f}GiB;"
                         f"peak_util={r.peak_utilization:.3f}")}]
    return rows, "shrink-and-recover, utilization bounded (paper Fig. 7)"


def fig8_iterations() -> Tuple[List[dict], str]:
    t0 = time.perf_counter()
    dyn = simulate(make_paper_config(3)).iteration_times_s
    ub = simulate(make_paper_config(4)).iteration_times_s
    us = (time.perf_counter() - t0) * 1e6
    rows = [{"name": "fig8_iteration_recovery", "us_per_call": us,
             "derived": (f"iters_early={np.mean(dyn[:3]):.0f}s;"
                         f"iters_late={np.mean(dyn[-3:]):.0f}s;"
                         f"upper={np.mean(ub[-3:]):.0f}s")}]
    return rows, "early iters degraded, late iters at upper bound"


def lambda_sweep() -> Tuple[List[dict], str]:
    rows = []
    demand = np.full(400, 70.0) * GiB
    for lam in (0.1, 0.25, 0.5, 1.0, 1.5, 1.9, 2.5):
        p = paper_controller_params(lam=lam)
        t0 = time.perf_counter()
        tr = simulate_saturated_loop(p, demand, u0=p.u_max)
        us = (time.perf_counter() - t0) * 1e6
        target = fixed_point_capacity(p, 70.0 * GiB)
        t = settling_time(tr, target, tol_frac=0.02)
        rows.append({"name": f"lambda_{lam}", "us_per_call": us,
                     "derived": f"settle={t};stable={t is not None}"})
    return rows, "stable for 0<lam<2, fastest near 0.5-1 (paper Sec. III.B)"


def controller_latency() -> Tuple[List[dict], str]:
    """Control-plane cost: the paper reports <10% of one core for 4
    nodes; we measure per-decision latency scalar + vectorized-fleet."""
    from repro.core import control_step
    import jax
    import jax.numpy as jnp
    from repro.core import vectorized_step

    p = paper_controller_params()
    t0 = time.perf_counter()
    n = 20000
    u = 40 * GiB
    for i in range(n):
        u = control_step(u, 100 * GiB, p)
    scalar_us = (time.perf_counter() - t0) * 1e6 / n

    nodes = 4096
    us_arr = jnp.full((nodes,), 40 * GiB)
    vs_arr = jnp.full((nodes,), 100 * GiB)
    step = jax.jit(lambda u, v: vectorized_step(
        u, v, total_memory=p.total_memory, r0=p.r0, lam=p.lam,
        u_min=p.u_min, u_max=p.u_max))
    step(us_arr, vs_arr).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(100):
        us_arr = step(us_arr, vs_arr)
    us_arr.block_until_ready()
    fleet_us = (time.perf_counter() - t0) * 1e6 / 100
    rows = [
        {"name": "controller_scalar", "us_per_call": scalar_us,
         "derived": f"{1e6/scalar_us:.0f} decisions/s/core"},
        {"name": "controller_fleet4096", "us_per_call": fleet_us,
         "derived": f"{fleet_us/nodes*1000:.1f} ns/node/interval"},
    ]
    budget = 100_000  # 100 ms interval in us
    return rows, (f"fleet tick for 4096 nodes = {fleet_us:.0f} us "
                  f"({100*fleet_us/budget:.2f}% of the 100 ms interval)")


def fleet_scale() -> Tuple[List[dict], str]:
    t0 = time.perf_counter()
    m = simulate_fleet(n_nodes=4096, n_intervals=300, seed=0)
    us = (time.perf_counter() - t0) * 1e6
    rows = [{"name": "fleet_4096nodes", "us_per_call": us,
             "derived": (f"p99util={m['p99_utilization']:.3f};"
                         f"over_r0={m['frac_intervals_over_r0']:.3f}")}]
    return rows, "4096-node closed loop stable"
