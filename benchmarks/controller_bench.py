"""Controller-backend benchmark: scalar per-node loop vs ArrayController.

Times one control interval's *decision stage* at fleet sizes 64 / 1024 /
4096 nodes, two ways:

* ``law_scalar_ms`` -- the per-node Python loop the legacy controller
  dispatch ran: one float64 ``control_step`` call per node.
* ``law_array_ms``  -- the ArrayController's fused jitted update: one
  XLA dispatch for the whole fleet (``make_fused_step``).

plus, for context, the full ``MemoryPlane.tick`` (monitor sampling +
bus + aggregation + decide + actuate) for both backends, which shares
the per-node Python observation path and therefore dilutes the ratio.

Writes ``BENCH_controller.json`` next to the repo root and prints a
table.  Usage:

    PYTHONPATH=src python benchmarks/controller_bench.py [--out PATH]
"""

from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np

FLEET_SIZES = (64, 1024, 4096)
REPEATS = 30


def _bench(fn, repeats: int = REPEATS) -> float:
    """Median wall-time of ``fn()`` in milliseconds."""
    fn()                                   # warmup (jit compile, caches)
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        times.append((time.perf_counter() - t0) * 1e3)
    return float(np.median(times))


def bench_fleet(n_nodes: int, seed: int = 0) -> dict:
    import jax.numpy as jnp

    from repro.core import (ControllerParams, GiB, MemoryPlane, NodeSpec,
                            PlaneSpec, SimulatedMonitor, StoreRegistry,
                            control_step, make_fused_step)

    rng = np.random.default_rng(seed)
    params = ControllerParams(total_memory=125.0 * GiB)
    u = rng.uniform(0.0, 60.0, n_nodes) * GiB
    v = rng.uniform(60.0, 125.0, n_nodes) * GiB

    # -- decision stage: per-node Python loop (legacy dispatch shape) -----
    def law_scalar():
        return [control_step(ui, vi, params) for ui, vi in zip(u, v)]

    # -- decision stage: one fused jitted update for the fleet ------------
    fused = make_fused_step(params)
    u32 = jnp.asarray(u, jnp.float32)
    v32 = jnp.asarray(v, jnp.float32)
    ones = jnp.ones(n_nodes, bool)
    m32 = jnp.full(n_nodes, params.total_memory, jnp.float32)
    lo = jnp.full(n_nodes, params.u_min, jnp.float32)
    hi = jnp.full(n_nodes, params.u_max, jnp.float32)

    def law_array():
        return fused(u32, v32, v32, ones, ones, m32, lo, hi).block_until_ready()

    law_scalar_ms = _bench(law_scalar)
    law_array_ms = _bench(law_array)

    # -- full plane tick per backend (shared monitor/bus/agg overhead) ----
    def build_plane(backend: str) -> MemoryPlane:
        demand = rng.uniform(60.0, 125.0, n_nodes) * GiB
        return MemoryPlane(PlaneSpec(
            params=params, backend=backend,
            nodes=tuple(
                NodeSpec(f"n{i}",
                         monitor=SimulatedMonitor(
                             f"n{i}", total=params.total_memory,
                             usage=lambda _t, d=demand[i]: d),
                         registry=StoreRegistry(), u0=u[i])
                for i in range(n_nodes))))

    tick_scalar_ms = _bench(build_plane("scalar").tick, repeats=5)
    tick_array_ms = _bench(build_plane("array").tick, repeats=5)

    # -- health-layer overhead: the same array tick with ChaosPlane
    # faults landing on ~10% of samples, exercising validation,
    # holdover, and the quarantine state machine every interval -------
    from repro.runtime import ChaosSpec, FaultSpec, inject
    chaos_plane = build_plane("array")
    inject(chaos_plane, ChaosSpec(faults=(
        FaultSpec("dropout", probability=0.05),
        FaultSpec("nan", probability=0.05),
    ), seed=seed))
    tick_chaos_ms = _bench(chaos_plane.tick, repeats=5)

    return {
        "n_nodes": n_nodes,
        "law_scalar_ms": law_scalar_ms,
        "law_array_ms": law_array_ms,
        "law_speedup": law_scalar_ms / law_array_ms,
        "tick_scalar_ms": tick_scalar_ms,
        "tick_array_ms": tick_array_ms,
        "tick_speedup": tick_scalar_ms / tick_array_ms,
        "tick_chaos_ms": tick_chaos_ms,
        "health_overhead": tick_chaos_ms / tick_array_ms,
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    default_out = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "BENCH_controller.json")
    ap.add_argument("--out", default=default_out)
    ap.add_argument("--sizes", type=int, nargs="*", default=list(FLEET_SIZES))
    args = ap.parse_args()

    results = [bench_fleet(n) for n in args.sizes]
    with open(args.out, "w") as fh:
        json.dump({"interval_decision_stage": results}, fh, indent=2)

    print(f"{'nodes':>6} {'law scalar':>11} {'law array':>10} {'speedup':>8} "
          f"{'tick scalar':>12} {'tick array':>11} {'tick+chaos':>11}")
    for r in results:
        print(f"{r['n_nodes']:6d} {r['law_scalar_ms']:9.3f}ms "
              f"{r['law_array_ms']:8.3f}ms {r['law_speedup']:7.1f}x "
              f"{r['tick_scalar_ms']:10.2f}ms {r['tick_array_ms']:9.2f}ms "
              f"{r['tick_chaos_ms']:9.2f}ms")
    print(f"\nwrote {args.out}")


if __name__ == "__main__":
    main()
