"""ScenarioLab sweep-engine benchmarks.

Times the fleet-scale closed loop (phase-shifted HPCC demand, paper
Table I gains) across engines and knobs:

* ``python_loop``  -- ``simulate_fleet(engine="python")``: one fused
  jitted step per interval, re-entering Python T times.
* ``lab_scan``     -- ``simulate_fleet(engine="lab")``: the whole
  horizon as one jitted ``lax.scan`` (single dispatch).
* ``lab_sweep_G``  -- the device-resident engine amortized over a
  G-point gain grid: histories never leave the device (streamed stats
  + fixed-bin quantile bisection), O(G) bytes per chunk to the host.
* ``lab_sweep_cache_G`` -- the same sweep with CacheLoop enabled
  (resident set, hit curve, evict/refill flux, modeled app runtime in
  the scan carry): the cache-dynamics overhead over the saturated
  store.
* ``pallas_sweep_G`` / ``pallas_sweep_cache_G`` -- PR 9's fused
  PallasSweep engine (``engine="pallas"``) on the same grid.
* ``pallas_halving_cache_512`` -- in-scan successive halving over 512
  cache-on candidates in ONE dispatch.  Its throughput is the
  **grid-equivalent effective rate**: G*T*N updates a grid tuner would
  have run, divided by the halving wall time (the kernel masks
  dominated lanes dead at T/8 and T/2, executing ~27% of the
  lane-steps).  ``--engine both`` gates this row at >= 10x the
  same-run ``lab_sweep_cache_G`` throughput -- the PR-9 acceptance
  claim, measured on the same machine in the same process.

The figure of merit is **node*interval*config closed-loop updates per
second**.  Writes two artifacts at the repo root:

* ``BENCH_lab.json``   -- headline ``sweep_throughput`` rows plus a
  ``smoke_reference`` section (the small shape CI re-measures).
* ``BENCH_sweep.json`` -- ``chunked_throughput`` (chunk-size sweep on
  the device-resident path), ``device_scaling`` (gain axis
  ``shard_map``'d over forced host devices), ``time_to_best`` (grid vs
  successive-halving time-to-best-gain on swap-storm), and
  ``smoke_reference_pallas`` (the PallasSweep smoke rows CI gates).

Usage:

    PYTHONPATH=src python benchmarks/lab_bench.py [--nodes 4096]
    PYTHONPATH=src python benchmarks/lab_bench.py --smoke --engine both \
        --check-baseline BENCH_lab.json \
        --check-pallas-baseline BENCH_sweep.json   # CI regression gates

The smoke run times the small reference shape only (no artifacts
unless ``--out``/``--sweep-out`` is given) and, with
``--check-baseline``, fails if the sweep speedup over the same-run
``python_loop`` row regresses more than ``--max-regress`` (default
20%) against the checked-in ``smoke_reference`` -- normalizing by the
python-loop row keeps the gate honest across machine speeds.
``--check-pallas-baseline`` applies the same ratio-of-ratios gate to
the PallasSweep rows against ``smoke_reference_pallas``.
"""

from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

import numpy as np

REPEATS = 3
SMOKE_SHAPE = dict(n_nodes=256, n_intervals=300, n_configs=16)


def _best(fn) -> float:
    """Best-of-N wall time, after a warmup call that pays compilation."""
    fn()
    times = []
    for _ in range(REPEATS):
        t0 = time.perf_counter()
        fn()
        times.append(time.perf_counter() - t0)
    return min(times)


def _row(name: str, n_nodes: int, n_intervals: int, configs: int,
         elapsed: float, **extra) -> dict:
    work = n_nodes * n_intervals * configs
    return {"engine": name, "n_nodes": n_nodes, "n_intervals": n_intervals,
            "n_configs": configs, "elapsed_s": elapsed,
            "throughput_upd_per_s": work / elapsed, **extra}


def _bench_gains(n_configs: int):
    """The benchmark's canonical ~n_configs (lam x r0) grid."""
    from repro.core.cluster_sim import paper_controller_params
    from repro.lab import grid_gains
    k = max(int(np.sqrt(n_configs)), 2)
    return grid_gains(paper_controller_params(),
                      lam=np.linspace(0.1, 1.8, k),
                      r0=np.linspace(0.88, 0.98, k))


def bench_engines(n_nodes: int, n_intervals: int, n_configs: int,
                  seed: int = 0) -> list:
    """The headline engine comparison at one (nodes, intervals) shape."""
    from repro.core.cluster_sim import paper_controller_params, simulate_fleet
    from repro.core.traces import fleet_demand_traces
    from repro.lab import sweep_demand

    p = paper_controller_params()
    rows = [
        _row("python_loop", n_nodes, n_intervals, 1,
             _best(lambda: simulate_fleet(n_nodes, n_intervals, seed=seed,
                                          engine="python"))),
        _row("lab_scan", n_nodes, n_intervals, 1,
             _best(lambda: simulate_fleet(n_nodes, n_intervals, seed=seed,
                                          engine="lab"))),
    ]
    # The sweep amortizes demand compilation across the grid: time only
    # the engine, as a tuner (which builds demand once) experiences it.
    demand = fleet_demand_traces(n_nodes, n_intervals, p.interval_s,
                                 seed=seed)
    gains = _bench_gains(n_configs)
    rows.append(_row(
        f"lab_sweep_{len(gains)}", n_nodes, n_intervals, len(gains),
        _best(lambda: sweep_demand(demand, gains, node_memory=p.total_memory,
                                   interval_s=p.interval_s))))
    # CacheLoop overhead: same grid with cache dynamics in the carry.
    from repro.lab import get_scenario
    cache = get_scenario("spark-iterative-cache").cache
    rows.append(_row(
        f"lab_sweep_cache_{len(gains)}", n_nodes, n_intervals, len(gains),
        _best(lambda: sweep_demand(demand, gains, node_memory=p.total_memory,
                                   interval_s=p.interval_s, cache=cache))))
    base = rows[0]["throughput_upd_per_s"]
    for r in rows:
        r["speedup_vs_python_loop"] = r["throughput_upd_per_s"] / base
    return rows


HALVING_CANDIDATES = 512
TENX_FLOOR = 10.0


def _halving_gains(n: int):
    """An n-point (lam x r0) grid (the smallest k x k grid covering n,
    sliced to exactly n lanes)."""
    k = int(np.ceil(np.sqrt(n)))
    return _bench_gains(k * k).take(np.arange(n))


def bench_pallas(n_nodes: int, n_intervals: int, n_configs: int,
                 xla_rows: list, seed: int = 0) -> list:
    """PallasSweep rows at the same shape as :func:`bench_engines`.

    ``xla_rows`` is the same-run output of :func:`bench_engines`: each
    pallas row's ``speedup_vs_xla`` divides by the matching same-run
    XLA row (sweep vs sweep, cache vs cache, halving vs the cache
    sweep it replaces), so both the baseline gate and the >= 10x claim
    are same-process, same-machine comparisons.
    """
    from repro.core.cluster_sim import paper_controller_params
    from repro.core.traces import fleet_demand_traces
    from repro.lab import GainSet, get_scenario, sweep_demand
    from repro.lab.pallas_sweep import halving_sweep

    p = paper_controller_params()
    demand = fleet_demand_traces(n_nodes, n_intervals, p.interval_s,
                                 seed=seed)
    gains = _bench_gains(n_configs)
    cache = get_scenario("spark-iterative-cache").cache
    kw = dict(node_memory=p.total_memory, interval_s=p.interval_s)
    rows = [
        _row(f"pallas_sweep_{len(gains)}", n_nodes, n_intervals, len(gains),
             _best(lambda: sweep_demand(demand, gains, engine="pallas",
                                        **kw))),
        _row(f"pallas_sweep_cache_{len(gains)}", n_nodes, n_intervals,
             len(gains),
             _best(lambda: sweep_demand(demand, gains, engine="pallas",
                                        cache=cache, **kw))),
    ]
    # In-scan halving: one dispatch tunes HALVING_CANDIDATES cache-on
    # lanes.  throughput_upd_per_s is the grid-equivalent effective
    # rate (G*T*N over the halving wall time); lane_steps_frac records
    # how much of that grid the masked kernel actually executed.
    big = _halving_gains(HALVING_CANDIDATES)
    base = GainSet.from_params(p)
    el = _best(lambda: halving_sweep(demand, big, base, cache=cache, **kw))
    from repro.lab.pallas_sweep import TILE_GAINS, halving_schedule
    horizons, keeps = halving_schedule(
        n_intervals, len(big), (0.125, 0.5, 1.0), 0.25, 4)
    pad = lambda n: -(-n // TILE_GAINS) * TILE_GAINS
    counts = [len(big) + 1] + [k + 1 for k in keeps]
    lane_steps = sum(pad(c) * (h - h0) for c, h, h0 in
                     zip(counts, horizons, [0] + horizons[:-1]))
    halving_row = _row(
        f"pallas_halving_cache_{len(big)}", n_nodes, n_intervals,
        len(big), el,
        effective="grid-equivalent",
        lane_steps_frac=lane_steps / (len(big) * n_intervals))
    rows.append(halving_row)
    # Normalize by the same-run XLA rows, not python_loop: both sides
    # are compute-bound scans of the same math, so the ratio is stable
    # across machines (python_loop is dispatch-bound and skews 2-3x
    # between hosts, which would poison a checked-in baseline).
    xla = {r["engine"]: r for r in xla_rows}
    ref_of = {
        f"pallas_sweep_{len(gains)}": f"lab_sweep_{len(gains)}",
        f"pallas_sweep_cache_{len(gains)}": f"lab_sweep_cache_{len(gains)}",
        halving_row["engine"]: f"lab_sweep_cache_{len(gains)}",
    }
    for r in rows:
        ref = xla.get(ref_of[r["engine"]])
        if ref:
            r["speedup_vs_xla"] = (r["throughput_upd_per_s"]
                                   / ref["throughput_upd_per_s"])
    if "speedup_vs_xla" in halving_row:
        halving_row["cache_on_speedup_vs_xla"] = \
            halving_row["speedup_vs_xla"]
    return rows


def check_tenx_gate(pallas_rows: list) -> int:
    """The PR-9 acceptance claim as a hard CI gate: the in-scan halving
    row's grid-equivalent rate >= 10x the same-run XLA cache-on sweep."""
    row = next((r for r in pallas_rows
                if r["engine"].startswith("pallas_halving_cache")), None)
    if row is None or "cache_on_speedup_vs_xla" not in row:
        print("# 10x gate: no halving row to check")
        return 1
    ratio = row["cache_on_speedup_vs_xla"]
    ok = ratio >= TENX_FLOOR
    print(f"# 10x gate: in-scan halving effective rate is {ratio:.1f}x the "
          f"same-run XLA cache-on sweep (floor {TENX_FLOOR:.0f}x) -> "
          f"{'OK' if ok else 'FAIL'}")
    return 0 if ok else 1


def bench_chunks(n_nodes: int, n_intervals: int, n_configs: int,
                 seed: int = 0) -> list:
    """Device-resident throughput vs gain-chunk width (incl. auto)."""
    from repro.core.cluster_sim import paper_controller_params
    from repro.core.traces import fleet_demand_traces
    from repro.lab import sweep_demand

    p = paper_controller_params()
    demand = fleet_demand_traces(n_nodes, n_intervals, p.interval_s,
                                 seed=seed)
    gains = _bench_gains(n_configs)
    rows = []
    for chunk in (8, 32, 64, None):
        el = _best(lambda: sweep_demand(
            demand, gains, node_memory=p.total_memory,
            interval_s=p.interval_s, chunk=chunk))
        rows.append(_row(f"chunk_{'auto' if chunk is None else chunk}",
                         n_nodes, n_intervals, len(gains), el))
    return rows


_SCALING_SNIPPET = r"""
import os, json, time, sys
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=%d"
import numpy as np
from repro.core.cluster_sim import paper_controller_params
from repro.core.traces import fleet_demand_traces
from repro.lab import grid_gains, sweep_demand
n_nodes, n_intervals, n_configs, ndev = %d, %d, %d, %d
p = paper_controller_params()
demand = fleet_demand_traces(n_nodes, n_intervals, p.interval_s, seed=0)
k = max(int(np.sqrt(n_configs)), 2)
gains = grid_gains(p, lam=np.linspace(0.1, 1.8, k),
                   r0=np.linspace(0.88, 0.98, k))
run = lambda: sweep_demand(demand, gains, node_memory=p.total_memory,
                           interval_s=p.interval_s, devices=ndev)
run()
best = 1e9
for _ in range(3):
    t0 = time.perf_counter()
    run()
    best = min(best, time.perf_counter() - t0)
print(json.dumps({"elapsed_s": best, "n_configs": len(gains)}))
"""


def bench_device_scaling(n_nodes: int, n_intervals: int, n_configs: int,
                         device_counts=(1, 2)) -> list:
    """Gain-axis shard_map scaling over forced host devices.

    Each count runs in a subprocess because XLA fixes the host device
    count at first jax init.
    """
    rows = []
    for ndev in device_counts:
        code = _SCALING_SNIPPET % (ndev, n_nodes, n_intervals, n_configs,
                                   ndev)
        env = dict(os.environ)
        env["PYTHONPATH"] = env.get("PYTHONPATH") or "src"
        proc = subprocess.run([sys.executable, "-c", code],
                              capture_output=True, text=True, env=env,
                              timeout=1800)
        if proc.returncode != 0:
            print(f"# device_scaling ndev={ndev} failed:\n"
                  f"{proc.stderr[-1500:]}", file=sys.stderr)
            continue
        out = json.loads(proc.stdout.strip().splitlines()[-1])
        rows.append(_row(f"devices_{ndev}", n_nodes, n_intervals,
                         out["n_configs"], out["elapsed_s"]))
    if rows:
        base = rows[0]["throughput_upd_per_s"]
        for r in rows:
            r["scaling_vs_1_device"] = r["throughput_upd_per_s"] / base
    return rows


def bench_time_to_best(scenario: str = "swap-storm", budget: int = 64,
                       seed: int = 0) -> list:
    """Grid vs successive halving: wall-clock to the best gain point.

    Times the warm (executables compiled) search, the steady state a
    retuning deployment lives in; `compile_s` reports the one-time
    cost.
    """
    from repro.lab import tune_gains

    rows = []
    for method in ("grid", "halving"):
        run = lambda: tune_gains(scenario, method=method, budget=budget,
                                 seed=seed)
        t0 = time.perf_counter()
        result = run()
        cold = time.perf_counter() - t0
        warm = _best(run)
        rows.append({
            "method": method, "scenario": scenario, "budget": budget,
            "best_score": result.score,
            "best_r0": result.params.r0, "best_lam": result.params.lam,
            "wall_s_warm": warm, "compile_s": cold - warm,
        })
    g, h = rows
    h["wall_vs_grid"] = h["wall_s_warm"] / g["wall_s_warm"]
    h["reaches_grid_best"] = bool(h["best_score"] >= g["best_score"] - 1e-9)
    return rows


def check_baseline(smoke_rows: list, baseline_path: str,
                   max_regress: float, section: str = "smoke_reference",
                   prefix: str = "lab_sweep",
                   ratio_key: str = "speedup_vs_python_loop") -> int:
    """Compare the smoke sweep speedups against the checked-in ones.

    Every ``{prefix}*`` row present in both runs is gated (the
    cache-off sweep AND the CacheLoop sweep), each normalized by its
    own run's ``python_loop`` row so runner speed cancels.  The pallas
    gate reuses this ratio-of-ratios with ``section=
    "smoke_reference_pallas"``/``prefix="pallas"`` and the
    cross-engine ``speedup_vs_xla`` ratio (compute-bound on both
    sides, so it cancels machine skew that the dispatch-bound
    python_loop row does not).
    """
    with open(baseline_path) as fh:
        doc = json.load(fh)
    ref_rows = doc.get(section) or []
    ref = {r["engine"]: r for r in ref_rows}
    now = {r["engine"]: r for r in smoke_rows}
    names = [n for n in now if n.startswith(prefix) and n in ref]
    if not names:
        print(f"# no comparable {section} sweep row in "
              f"{baseline_path}; nothing to check")
        return 0
    failed = False
    for name in names:
        ref_ratio = ref[name][ratio_key]
        now_ratio = now[name][ratio_key]
        floor = ref_ratio * (1.0 - max_regress)
        ok = now_ratio >= floor
        failed |= not ok
        print(f"# {name} {ratio_key}: now {now_ratio:.2f}x, "
              f"baseline {ref_ratio:.2f}x, floor {floor:.2f}x -> "
              f"{'OK' if ok else 'REGRESSION'}")
    return 1 if failed else 0


def print_rows(title: str, rows: list) -> None:
    if not rows:
        return
    print(f"\n# {title}")
    cols = []
    for r in rows:
        cols.extend(k for k in r if k not in cols)
    print("  ".join(c.rjust(max(len(c), 12)) for c in cols))
    for r in rows:
        cells = []
        for c in cols:
            v = r.get(c)
            s = f"{v:.4g}" if isinstance(v, float) else ("" if v is None
                                                         else str(v))
            cells.append(s.rjust(max(len(c), 12)))
        print("  ".join(cells))


def main() -> int:
    ap = argparse.ArgumentParser()
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    ap.add_argument("--out", default=None,
                    help="BENCH_lab.json path (default: repo root; "
                         "omitted in --smoke unless given)")
    ap.add_argument("--sweep-out", default=None,
                    help="BENCH_sweep.json path (same default rules)")
    ap.add_argument("--nodes", type=int, default=4096)
    ap.add_argument("--intervals", type=int, default=1000)
    ap.add_argument("--configs", type=int, default=64)
    ap.add_argument("--smoke", action="store_true",
                    help="small-shape engine rows only; fast enough "
                         "for a CI job")
    ap.add_argument("--check-baseline", default=None, metavar="PATH",
                    help="compare smoke speedups against this checked-in "
                         "artifact; non-zero exit on regression")
    ap.add_argument("--check-pallas-baseline", default=None, metavar="PATH",
                    help="ratio-of-ratios gate for the pallas rows against "
                         "this artifact's smoke_reference_pallas section")
    ap.add_argument("--max-regress", type=float, default=0.2)
    ap.add_argument("--engine", choices=("xla", "pallas", "both"),
                    default="xla",
                    help="which sweep engines to bench; pallas/both adds "
                         "the PallasSweep rows and the 10x halving gate")
    args = ap.parse_args()

    from repro.analysis.runtime import (excess_traces, reset_trace_counts,
                                        sanitizers_enabled, trace_counts)

    if args.smoke:
        # record_trace only counts with the sanitizers on; enable them
        # before the first dispatch -- an executable compiled before
        # that sits in the jit cache and would never be counted, so the
        # recompile gate below would vacuously pass.
        os.environ.setdefault("PLANECHECK_SANITIZERS", "1")
    reset_trace_counts()
    smoke_rows = bench_engines(**SMOKE_SHAPE)
    print_rows("smoke shape "
               f"({SMOKE_SHAPE['n_nodes']}x{SMOKE_SHAPE['n_intervals']})",
               smoke_rows)
    pallas_rows = []
    if args.engine in ("pallas", "both"):
        pallas_rows = bench_pallas(xla_rows=smoke_rows, **SMOKE_SHAPE)
        print_rows("PallasSweep smoke rows", pallas_rows)

    if args.smoke:
        status = 0
        # PR 3's time-to-best claim as a checked invariant: every
        # (chunk, horizon) shape the smoke rows dispatched must map to
        # exactly one compiled executable (PlaneCheck recompile
        # counter).  The "lab.sweep." prefix covers both engines'
        # dispatch keys (chunk loop + pallas specializations).
        if sanitizers_enabled():
            counts = trace_counts("lab.sweep.")
            excess = excess_traces("lab.sweep.")
            print(f"\nrecompile counter: "
                  f"{counts or '(no jitted sweeps ran)'}")
            if excess:
                print(f"FAIL: sweep hot path retraced: {excess}")
                return 1
        else:
            # setdefault above respects an explicit opt-out; say so
            # instead of printing a vacuously-empty counter.
            print("\nrecompile gate skipped (PLANECHECK_SANITIZERS "
                  "explicitly disabled)")
        if pallas_rows:
            status |= check_tenx_gate(pallas_rows)
        if args.out:
            doc = {"smoke_reference": smoke_rows}
            if pallas_rows:
                doc["smoke_reference_pallas"] = pallas_rows
            with open(args.out, "w") as fh:
                json.dump(doc, fh, indent=2)
            print(f"\nwrote {args.out}")
        if args.check_baseline:
            status |= check_baseline(smoke_rows, args.check_baseline,
                                     args.max_regress)
        if args.check_pallas_baseline and pallas_rows:
            status |= check_baseline(
                pallas_rows, args.check_pallas_baseline, args.max_regress,
                section="smoke_reference_pallas", prefix="pallas",
                ratio_key="speedup_vs_xla")
        return status

    rows = bench_engines(args.nodes, args.intervals, args.configs)
    chunk_rows = bench_chunks(args.nodes, args.intervals, args.configs)
    scaling_rows = bench_device_scaling(args.nodes, args.intervals,
                                        args.configs)
    ttb_rows = bench_time_to_best()

    print_rows(f"engines ({args.nodes}x{args.intervals})", rows)
    print_rows("chunked device-resident throughput", chunk_rows)
    print_rows("device scaling (forced host devices)", scaling_rows)
    print_rows("time-to-best-gain (swap-storm, 64+1 candidates)", ttb_rows)

    out = args.out or os.path.join(root, "BENCH_lab.json")
    with open(out, "w") as fh:
        json.dump({"sweep_throughput": rows,
                   "smoke_reference": smoke_rows}, fh, indent=2)
    sweep_out = args.sweep_out or os.path.join(root, "BENCH_sweep.json")
    sweep_doc = {"chunked_throughput": chunk_rows,
                 "device_scaling": scaling_rows,
                 "time_to_best": ttb_rows}
    if pallas_rows:
        sweep_doc["smoke_reference_pallas"] = pallas_rows
    with open(sweep_out, "w") as fh:
        json.dump(sweep_doc, fh, indent=2)
    print(f"\nwrote {out}\nwrote {sweep_out}")
    status = check_tenx_gate(pallas_rows) if pallas_rows else 0
    if args.check_baseline:
        status |= check_baseline(smoke_rows, args.check_baseline,
                                 args.max_regress)
    return status


if __name__ == "__main__":
    sys.exit(main())
