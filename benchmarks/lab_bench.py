"""ScenarioLab sweep-engine benchmark vs the Python-loop fleet sim.

Times the same fleet-scale closed loop (phase-shifted HPCC demand,
paper Table I gains) three ways:

* ``python_loop``  -- ``simulate_fleet(engine="python")``: one fused
  jitted step per interval, re-entering Python T times.
* ``lab_scan``     -- ``simulate_fleet(engine="lab")``: the whole
  horizon as one jitted ``lax.scan`` (single dispatch).
* ``lab_sweep_G``  -- the lab engine amortized over a G-point gain
  grid ``vmap``'d through the same scan.

The figure of merit is **node*interval*config closed-loop updates per
second**.  Writes ``BENCH_lab.json`` at the repo root and prints a
table.  Usage:

    PYTHONPATH=src python benchmarks/lab_bench.py [--nodes 4096]
"""

from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np

REPEATS = 3


def _best(fn) -> float:
    """Best-of-N wall time, after a warmup call that pays compilation."""
    fn()
    times = []
    for _ in range(REPEATS):
        t0 = time.perf_counter()
        fn()
        times.append(time.perf_counter() - t0)
    return min(times)


def bench(n_nodes: int, n_intervals: int, n_configs: int,
          seed: int = 0) -> list:
    from repro.core.cluster_sim import paper_controller_params, simulate_fleet
    from repro.core.traces import fleet_demand_traces
    from repro.lab import GainSet, grid_gains, sweep_demand

    p = paper_controller_params()
    rows = []

    def timed(name, configs, fn):
        elapsed = _best(fn)
        work = n_nodes * n_intervals * configs
        rows.append({
            "engine": name,
            "n_nodes": n_nodes,
            "n_intervals": n_intervals,
            "n_configs": configs,
            "elapsed_s": elapsed,
            "throughput_upd_per_s": work / elapsed,
        })

    timed("python_loop", 1,
          lambda: simulate_fleet(n_nodes, n_intervals, seed=seed,
                                 engine="python"))
    timed("lab_scan", 1,
          lambda: simulate_fleet(n_nodes, n_intervals, seed=seed,
                                 engine="lab"))

    # The sweep amortizes demand compilation across the grid: time only
    # the engine, as a tuner (which builds demand once) experiences it.
    demand = fleet_demand_traces(n_nodes, n_intervals, p.interval_s,
                                 seed=seed)
    k = max(int(np.sqrt(n_configs)), 2)
    gains = grid_gains(p, lam=np.linspace(0.1, 1.8, k),
                       r0=np.linspace(0.88, 0.98, k))
    timed(f"lab_sweep_{len(gains)}", len(gains),
          lambda: sweep_demand(demand, gains, node_memory=p.total_memory,
                               interval_s=p.interval_s))
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    default_out = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "BENCH_lab.json")
    ap.add_argument("--out", default=default_out)
    ap.add_argument("--nodes", type=int, default=4096)
    ap.add_argument("--intervals", type=int, default=1000)
    ap.add_argument("--configs", type=int, default=64)
    args = ap.parse_args()

    rows = bench(args.nodes, args.intervals, args.configs)
    base = rows[0]["throughput_upd_per_s"]
    for r in rows:
        r["speedup_vs_python_loop"] = r["throughput_upd_per_s"] / base
    with open(args.out, "w") as fh:
        json.dump({"sweep_throughput": rows}, fh, indent=2)

    print(f"{'engine':>14} {'configs':>7} {'elapsed':>9} "
          f"{'node*intv*cfg/s':>16} {'speedup':>8}")
    for r in rows:
        print(f"{r['engine']:>14} {r['n_configs']:7d} "
              f"{r['elapsed_s']:8.3f}s {r['throughput_upd_per_s']:16.3e} "
              f"{r['speedup_vs_python_loop']:7.1f}x")
    print(f"\nwrote {args.out}")


if __name__ == "__main__":
    main()
