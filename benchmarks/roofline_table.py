"""§Roofline table generator from dry-run artifacts.

Reads results/dryrun/*.json (written by `python -m repro.launch.dryrun`)
and emits the per-(arch x shape x mesh) three-term table, dominant
bottleneck, useful-FLOPs ratio and the MFU bound, as markdown + CSV.
"""

from __future__ import annotations

import glob
import json
import os
from typing import List, Tuple

HEADER = ("| arch | shape | mesh | compute_s | memory_s | collective_s | "
          "dominant | useful_flops | MFU_bound |")
SEP = "|" + "---|" * 9


def load_rows(path: str = "results/dryrun") -> List[dict]:
    rows = []
    for f in sorted(glob.glob(os.path.join(path, "*.json"))):
        r = json.load(open(f))
        t = r["roofline"]
        rows.append({
            "arch": r["arch"], "shape": r["shape"],
            "mesh": "2x16x16" if r.get("multi_pod") else "16x16",
            "compute_s": t["compute_s"], "memory_s": t["memory_s"],
            "collective_s": t["collective_s"], "dominant": t["dominant"],
            "useful_flops": r["useful_flops_ratio"],
            "mfu_bound": r["model_flops_utilization_bound"],
            "file": os.path.basename(f),
        })
    return rows


def markdown(rows: List[dict]) -> str:
    out = [HEADER, SEP]
    for r in rows:
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
            f"{r['compute_s']:.4f} | {r['memory_s']:.4f} | "
            f"{r['collective_s']:.4f} | {r['dominant']} | "
            f"{r['useful_flops']:.2f} | {r['mfu_bound']:.3f} |")
    return "\n".join(out)


def roofline_summary() -> Tuple[List[dict], str]:
    rows = load_rows()
    if not rows:
        return ([{"name": "roofline_table", "us_per_call": 0,
                  "derived": "no dry-run artifacts"}],
                "run `python -m repro.launch.dryrun --all` first")
    bench_rows = [{
        "name": f"roofline_{r['arch']}_{r['shape']}_{r['mesh']}",
        "us_per_call": r["compute_s"] * 1e6,  # compute-term in us
        "derived": (f"dom={r['dominant']};mem_s={r['memory_s']:.4f};"
                    f"coll_s={r['collective_s']:.4f};"
                    f"mfu_bound={r['mfu_bound']:.3f}")
    } for r in rows]
    n_dom = {}
    for r in rows:
        n_dom[r["dominant"]] = n_dom.get(r["dominant"], 0) + 1
    return bench_rows, f"{len(rows)} cells; dominance: {n_dom}"


if __name__ == "__main__":
    print(markdown(load_rows()))
