"""FleetPlane sweep-engine benchmarks.

Times the fused two-level closed loop -- per-tenant Eq. 1 inside
epoch-arbitrated budgets -- against the scalar float64 oracle, and
maps the fused path's throughput over the (tenants x nodes) plane:

* ``fleet_reference``   -- :func:`repro.fleet.fleet_reference`: dense
  numpy per-gain loops, arbitration per epoch, exact semantics.
* ``fleet_sweep_G``     -- :func:`repro.fleet.fleet_sweep_demand`: the
  whole (gains x tenants x nodes x intervals) grid as jitted nested
  scans with fused one-hot arbitration, histories never leaving the
  device.
* ``scaling_KxN``       -- fused-path rows over a tenants x nodes
  grid at fixed total work, showing where the batched arbitration
  unroll (O(K^2) per epoch) starts to bite.

The figure of merit is **tenant*node*interval*config closed-loop
updates per second**.  Writes ``BENCH_fleet.json`` at the repo root
with the headline + scaling rows plus a ``smoke_reference`` section
the CI bench-smoke job re-measures.

Usage:

    PYTHONPATH=src python benchmarks/fleet_bench.py
    PYTHONPATH=src python benchmarks/fleet_bench.py --smoke \
        --check-baseline BENCH_fleet.json   # CI regression gate

The smoke run times the small reference shape only and, with
``--check-baseline``, fails if the fused sweep's speedup over the
same-run ``fleet_reference`` row regresses more than ``--max-regress``
(default 20%) against the checked-in ``smoke_reference`` -- the
ratio-of-ratios normalization keeps the gate honest across machines.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

import numpy as np

REPEATS = 3
SMOKE_SHAPE = dict(n_tenants=3, n_nodes=64, n_intervals=240, n_configs=9)
SCALING_GRID = ((2, 256), (4, 256), (8, 256), (4, 1024), (8, 1024))


def _best(fn) -> float:
    """Best-of-N wall time, after a warmup call that pays compilation."""
    fn()
    times = []
    for _ in range(REPEATS):
        t0 = time.perf_counter()
        fn()
        times.append(time.perf_counter() - t0)
    return min(times)


def _row(name: str, n_tenants: int, n_nodes: int, n_intervals: int,
         configs: int, elapsed: float, **extra) -> dict:
    work = n_tenants * n_nodes * n_intervals * configs
    return {"engine": name, "n_tenants": n_tenants, "n_nodes": n_nodes,
            "n_intervals": n_intervals, "n_configs": configs,
            "elapsed_s": elapsed, "throughput_upd_per_s": work / elapsed,
            **extra}


def _problem(n_tenants: int, n_nodes: int, n_intervals: int, seed: int = 0):
    """Decorrelated per-tenant demand plus Table-I-ish fleet shape."""
    from repro.core.traces import GiB, fleet_demand_traces

    demand = np.stack([
        fleet_demand_traces(n_nodes, n_intervals, 0.1, seed=seed + k * 7919)
        for k in range(n_tenants)])
    weights = np.linspace(3.0, 1.0, n_tenants)
    floors = np.zeros(n_tenants)
    floors[-1] = 8.0 * GiB
    return demand, weights, floors


def _bench_gains(n_configs: int):
    from repro.core.cluster_sim import paper_controller_params
    from repro.lab import grid_gains
    k = max(int(np.sqrt(n_configs)), 2)
    return grid_gains(paper_controller_params(),
                      lam=np.linspace(0.1, 1.8, k),
                      r0=np.linspace(0.88, 0.98, k))


def bench_engines(n_tenants: int, n_nodes: int, n_intervals: int,
                  n_configs: int, seed: int = 0) -> list:
    """Reference vs fused at one (tenants, nodes, intervals) shape."""
    from repro.core.traces import GiB
    from repro.fleet import fleet_reference, fleet_sweep_demand

    demand, weights, floors = _problem(n_tenants, n_nodes, n_intervals,
                                       seed)
    gains = _bench_gains(n_configs)
    kw = dict(node_memory=125.0 * GiB, weights=weights, floors=floors,
              epoch_intervals=max(n_intervals // 10, 1), interval_s=0.1)
    rows = [
        _row("fleet_reference", n_tenants, n_nodes, n_intervals,
             len(gains),
             _best(lambda: fleet_reference(demand, gains, **kw))),
        _row(f"fleet_sweep_{len(gains)}", n_tenants, n_nodes, n_intervals,
             len(gains),
             _best(lambda: fleet_sweep_demand(demand, gains, **kw))),
    ]
    base = rows[0]["throughput_upd_per_s"]
    for r in rows:
        r["speedup_vs_reference"] = r["throughput_upd_per_s"] / base
    return rows


def bench_scaling(n_intervals: int, n_configs: int, seed: int = 0) -> list:
    """Fused-path throughput over the (tenants x nodes) plane."""
    from repro.core.traces import GiB
    from repro.fleet import fleet_sweep_demand

    gains = _bench_gains(n_configs)
    rows = []
    for n_tenants, n_nodes in SCALING_GRID:
        demand, weights, floors = _problem(n_tenants, n_nodes,
                                           n_intervals, seed)
        kw = dict(node_memory=125.0 * GiB, weights=weights, floors=floors,
                  epoch_intervals=max(n_intervals // 10, 1),
                  interval_s=0.1)
        el = _best(lambda: fleet_sweep_demand(demand, gains, **kw))
        rows.append(_row(f"scaling_{n_tenants}x{n_nodes}", n_tenants,
                         n_nodes, n_intervals, len(gains), el))
    base = rows[0]["throughput_upd_per_s"]
    for r in rows:
        r["throughput_vs_first"] = r["throughput_upd_per_s"] / base
    return rows


def check_baseline(smoke_rows: list, baseline_path: str,
                   max_regress: float) -> int:
    """Gate the fused sweep's speedup over the same-run reference row
    against the checked-in ``smoke_reference`` (ratio of ratios)."""
    with open(baseline_path) as fh:
        doc = json.load(fh)
    ref = {r["engine"]: r for r in doc.get("smoke_reference") or []}
    now = {r["engine"]: r for r in smoke_rows}
    names = [n for n in now if n.startswith("fleet_sweep") and n in ref]
    if not names:
        print(f"# no comparable smoke_reference sweep row in "
              f"{baseline_path}; nothing to check")
        return 0
    failed = False
    for name in names:
        ref_ratio = ref[name]["speedup_vs_reference"]
        now_ratio = now[name]["speedup_vs_reference"]
        floor = ref_ratio * (1.0 - max_regress)
        ok = now_ratio >= floor
        failed |= not ok
        print(f"# {name} speedup vs fleet_reference: now {now_ratio:.2f}x, "
              f"baseline {ref_ratio:.2f}x, floor {floor:.2f}x -> "
              f"{'OK' if ok else 'REGRESSION'}")
    return 1 if failed else 0


def print_rows(title: str, rows: list) -> None:
    if not rows:
        return
    print(f"\n# {title}")
    cols = []
    for r in rows:
        cols.extend(k for k in r if k not in cols)
    print("  ".join(c.rjust(max(len(c), 12)) for c in cols))
    for r in rows:
        cells = []
        for c in cols:
            v = r.get(c)
            s = f"{v:.4g}" if isinstance(v, float) else ("" if v is None
                                                         else str(v))
            cells.append(s.rjust(max(len(c), 12)))
        print("  ".join(cells))


def main() -> int:
    ap = argparse.ArgumentParser()
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    ap.add_argument("--out", default=None,
                    help="BENCH_fleet.json path (default: repo root; "
                         "omitted in --smoke unless given)")
    ap.add_argument("--intervals", type=int, default=500)
    ap.add_argument("--configs", type=int, default=16)
    ap.add_argument("--smoke", action="store_true",
                    help="small-shape rows only; fast enough for CI")
    ap.add_argument("--check-baseline", default=None, metavar="PATH",
                    help="compare smoke speedups against this checked-in "
                         "artifact; non-zero exit on regression")
    ap.add_argument("--max-regress", type=float, default=0.2)
    args = ap.parse_args()

    if args.smoke:
        # count retraces from the first dispatch (see lab_bench.py)
        os.environ.setdefault("PLANECHECK_SANITIZERS", "1")
    from repro.analysis.runtime import (excess_traces, reset_trace_counts,
                                        sanitizers_enabled, trace_counts)

    reset_trace_counts()
    smoke_rows = bench_engines(**SMOKE_SHAPE)
    print_rows("smoke shape ({n_tenants}x{n_nodes}x{n_intervals})"
               .format(**SMOKE_SHAPE), smoke_rows)

    if args.smoke:
        if sanitizers_enabled():
            counts = trace_counts("fleet.sweep.chunk")
            excess = excess_traces("fleet.sweep.chunk")
            print(f"\nrecompile counter: "
                  f"{counts or '(no jitted sweeps ran)'}")
            if excess:
                print(f"FAIL: fleet sweep hot path retraced: {excess}")
                return 1
        else:
            print("\nrecompile gate skipped (PLANECHECK_SANITIZERS "
                  "explicitly disabled)")
        if args.out:
            with open(args.out, "w") as fh:
                json.dump({"smoke_reference": smoke_rows}, fh, indent=2)
            print(f"\nwrote {args.out}")
        if args.check_baseline:
            return check_baseline(smoke_rows, args.check_baseline,
                                  args.max_regress)
        return 0

    rows = bench_engines(SMOKE_SHAPE["n_tenants"], SMOKE_SHAPE["n_nodes"],
                         args.intervals, args.configs)
    scaling_rows = bench_scaling(args.intervals, args.configs)
    print_rows(f"engines (x{args.intervals} intervals)", rows)
    print_rows("tenants x nodes scaling (fused path)", scaling_rows)

    out = args.out or os.path.join(root, "BENCH_fleet.json")
    with open(out, "w") as fh:
        json.dump({"sweep_throughput": rows,
                   "tenant_node_scaling": scaling_rows,
                   "smoke_reference": smoke_rows}, fh, indent=2)
    print(f"\nwrote {out}")
    if args.check_baseline:
        return check_baseline(smoke_rows, args.check_baseline,
                              args.max_regress)
    return 0


if __name__ == "__main__":
    sys.exit(main())
