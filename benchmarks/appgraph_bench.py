"""AppGraph makespan benchmarks: the paper's "up to 5X", emergent.

Unlike the throughput benches (``lab_bench.py``, ``fleet_bench.py``)
whose gates are timing ratios, the headline numbers here are
**deterministic model outputs** -- end-to-end DAG makespans from the
scanned sweep -- so CI compares them directly:

* ``makespan_gap``    -- the ``spark-dag`` scenario under the static
  25G Table-I baseline vs the dynamic Table-I controller.  The gate is
  the paper's claim made emergent: the dynamic controller must finish
  the DAG >= ``--min-gap`` (default 2x) faster, with **no** penalty
  weight involved, and both makespans must match the checked-in
  artifact within ``--drift`` (a model change must regenerate the
  baseline deliberately).
* ``limplock``        -- the ``limplock`` scenario with and without
  its one 4x-degraded node: barrier coupling must inflate the *fleet*
  makespan ~4x (gated to [3.5, 4.5]).
* ``smoke_reference`` -- timing rows (informational, not gated): the
  AppGraph carry's overhead over the identical sweep with
  ``app_graph=None`` on a reduced spark-dag shape.

Writes ``BENCH_appgraph.json`` at the repo root; ``--smoke`` runs the
same deterministic gates plus the timing rows fast enough for CI.

Usage:

    PYTHONPATH=src python benchmarks/appgraph_bench.py
    PYTHONPATH=src python benchmarks/appgraph_bench.py --smoke \
        --check-baseline BENCH_appgraph.json     # CI gate
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

import numpy as np

REPEATS = 3
HARD_LIMPLOCK_BAND = (3.5, 4.5)


def _best(fn) -> float:
    fn()
    times = []
    for _ in range(REPEATS):
        t0 = time.perf_counter()
        fn()
        times.append(time.perf_counter() - t0)
    return min(times)


def _static_gains(grant_gib: float = 25.0):
    """The paper's static Table-I baseline: grant pinned, law inert."""
    from repro.core.cluster_sim import paper_controller_params
    from repro.core.traces import GiB
    from repro.lab import GainSet
    return GainSet.from_params(paper_controller_params(
        lam=0.0, u_min=grant_gib * GiB, u_max=grant_gib * GiB))


def measure_makespan_gap(seed: int = 0) -> list:
    """spark-dag: static 25G vs dynamic Table-I, emergent makespans."""
    from repro.configs.dynims import PAPER_TABLE_I
    from repro.core.traces import GiB
    from repro.lab import GainSet, get_scenario, sweep_demand

    spec = get_scenario("spark-dag")
    demand = np.asarray(spec.build_demand(seed=seed))
    kw = dict(node_memory=125.0 * GiB, interval_s=spec.interval_s,
              cache=spec.cache, app_graph=spec.app_graph)
    static = float(sweep_demand(demand, _static_gains(), **kw).makespan[0])
    dynamic = float(sweep_demand(
        demand, GainSet.from_params(PAPER_TABLE_I), **kw).makespan[0])
    return [
        {"config": "static-25g", "scenario": "spark-dag", "seed": seed,
         "makespan_s": static, "speedup_vs_static": 1.0},
        {"config": "dynamic-table1", "scenario": "spark-dag", "seed": seed,
         "makespan_s": dynamic, "speedup_vs_static": static / dynamic},
    ]


def measure_limplock(seed: int = 0) -> list:
    """limplock: fleet makespan with/without the one 4x-degraded node."""
    from repro.lab import get_scenario, run_sweep

    spec = get_scenario("limplock")
    healthy = spec.replace(app_graph=spec.app_graph.replace(
        slow_nodes=(), slow_factor=1.0))
    ok = float(run_sweep(healthy, _static_gains(), seed=seed)
               .stats.makespan[0])
    slow = float(run_sweep(spec, _static_gains(), seed=seed)
                 .stats.makespan[0])
    return [
        {"config": "healthy", "scenario": "limplock", "seed": seed,
         "makespan_s": ok, "inflation_vs_healthy": 1.0},
        {"config": "one-4x-node", "scenario": "limplock", "seed": seed,
         "makespan_s": slow, "inflation_vs_healthy": slow / ok},
    ]


def measure_overhead(seed: int = 0) -> list:
    """Timing rows: the AppGraph carry vs app_graph=None, same sweep."""
    from repro.core.cluster_sim import paper_controller_params
    from repro.core.traces import GiB
    from repro.lab import get_scenario, grid_gains, sweep_demand

    spec = get_scenario("spark-dag").replace(n_nodes=8, n_intervals=600)
    demand = np.asarray(spec.build_demand(seed=seed))
    gains = grid_gains(paper_controller_params(),
                       lam=np.linspace(0.2, 1.6, 3),
                       r0=np.linspace(0.9, 0.97, 3))
    kw = dict(node_memory=125.0 * GiB, interval_s=spec.interval_s,
              cache=spec.cache)
    t_plain = _best(lambda: sweep_demand(demand, gains, **kw))
    t_graph = _best(lambda: sweep_demand(demand, gains,
                                         app_graph=spec.app_graph, **kw))
    work = len(gains) * demand.shape[0] * demand.shape[1]
    rows = [
        {"engine": "sweep_plain", "n_nodes": 8, "n_intervals": 600,
         "n_configs": len(gains), "elapsed_s": t_plain,
         "throughput_upd_per_s": work / t_plain},
        {"engine": "sweep_appgraph", "n_nodes": 8, "n_intervals": 600,
         "n_configs": len(gains), "elapsed_s": t_graph,
         "throughput_upd_per_s": work / t_graph,
         "overhead_vs_plain": t_graph / t_plain},
    ]
    return rows


def check_gates(gap_rows: list, limp_rows: list, baseline_path: str,
                min_gap: float, drift: float) -> int:
    """The deterministic CI gates; nonzero on any failure."""
    failed = False

    speedup = gap_rows[1]["speedup_vs_static"]
    ok = speedup >= min_gap
    failed |= not ok
    print(f"# emergent makespan gap (spark-dag): {speedup:.2f}x, "
          f"floor {min_gap:.1f}x -> {'OK' if ok else 'FAIL'}")

    lo, hi = HARD_LIMPLOCK_BAND
    infl = limp_rows[1]["inflation_vs_healthy"]
    ok = lo <= infl <= hi
    failed |= not ok
    print(f"# limplock fleet inflation: {infl:.2f}x, band "
          f"[{lo}, {hi}] -> {'OK' if ok else 'FAIL'}")

    if baseline_path:
        with open(baseline_path) as fh:
            doc = json.load(fh)
        for section, rows in (("makespan_gap", gap_rows),
                              ("limplock", limp_rows)):
            ref = {r["config"]: r for r in doc.get(section) or []}
            for r in rows:
                base = ref.get(r["config"])
                if base is None:
                    print(f"# {section}/{r['config']}: no baseline row; "
                          f"skipped")
                    continue
                rel = abs(r["makespan_s"] - base["makespan_s"]) \
                    / base["makespan_s"]
                ok = rel <= drift
                failed |= not ok
                verdict = "OK" if ok else ("DRIFT -- regenerate the "
                                           "artifact if the model "
                                           "change is deliberate")
                print(f"# {section}/{r['config']}: makespan "
                      f"{r['makespan_s']:.2f}s vs baseline "
                      f"{base['makespan_s']:.2f}s (drift {rel:.1%}, "
                      f"tol {drift:.0%}) -> {verdict}")
    return 1 if failed else 0


def print_rows(title: str, rows: list) -> None:
    if not rows:
        return
    print(f"\n# {title}")
    cols = []
    for r in rows:
        cols.extend(k for k in r if k not in cols)
    print("  ".join(c.rjust(max(len(c), 12)) for c in cols))
    for r in rows:
        cells = []
        for c in cols:
            v = r.get(c)
            s = f"{v:.4g}" if isinstance(v, float) else ("" if v is None
                                                         else str(v))
            cells.append(s.rjust(max(len(c), 12)))
        print("  ".join(cells))


def main() -> int:
    ap = argparse.ArgumentParser()
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    ap.add_argument("--out", default=None,
                    help="BENCH_appgraph.json path (default: repo root; "
                         "omitted in --smoke unless given)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--smoke", action="store_true",
                    help="same deterministic gates, CI-fast")
    ap.add_argument("--check-baseline", default=None, metavar="PATH",
                    help="gate the makespans against this checked-in "
                         "artifact; nonzero exit on failure")
    ap.add_argument("--min-gap", type=float, default=2.0,
                    help="hard floor on the emergent dynamic-vs-static "
                         "makespan speedup")
    ap.add_argument("--drift", type=float, default=0.05,
                    help="relative tolerance vs the checked-in makespans")
    args = ap.parse_args()

    gap_rows = measure_makespan_gap(seed=args.seed)
    limp_rows = measure_limplock(seed=args.seed)
    overhead_rows = measure_overhead(seed=args.seed)
    print_rows("spark-dag emergent makespan gap", gap_rows)
    print_rows("limplock barrier coupling", limp_rows)
    print_rows("AppGraph carry overhead (timing, informational)",
               overhead_rows)

    out = args.out or (None if args.smoke
                       else os.path.join(root, "BENCH_appgraph.json"))
    if out:
        with open(out, "w") as fh:
            json.dump({"makespan_gap": gap_rows, "limplock": limp_rows,
                       "smoke_reference": overhead_rows}, fh, indent=2)
        print(f"\nwrote {out}")
    return check_gates(gap_rows, limp_rows, args.check_baseline,
                       args.min_gap, args.drift)


if __name__ == "__main__":
    sys.exit(main())
