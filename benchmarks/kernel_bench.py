"""Kernel micro-benchmarks: Pallas (interpret) vs pure-jnp oracle.

On CPU the interpret-mode wall time is NOT the perf signal (TPU is the
target); the derived column carries the correctness deltas and the
VMEM working-set sizes the BlockSpecs claim, which is what the roofline
hillclimb reasons about.
"""

from __future__ import annotations

import time
from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import (decode_attention_op, flash_attention_op,
                           ssm_scan_op)
from repro.kernels.decode_attention.ref import decode_attention_ref
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.ssm_scan.ref import ssm_scan_ref

RNG = np.random.default_rng(0)


def _time(fn, *args, n=3):
    fn(*args)                                   # compile/warm
    t0 = time.perf_counter()
    for _ in range(n):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) * 1e6 / n, out


def flash_bench() -> Tuple[List[dict], str]:
    b, s, h, kv, hd = 1, 512, 4, 2, 64
    q = jnp.asarray(RNG.normal(0, 1, (b, s, h, hd)), jnp.float32)
    k = jnp.asarray(RNG.normal(0, 1, (b, s, kv, hd)), jnp.float32)
    v = jnp.asarray(RNG.normal(0, 1, (b, s, kv, hd)), jnp.float32)
    us_k, out = _time(lambda *a: flash_attention_op(
        *a, causal=True, block_q=128, block_k=128), q, k, v, n=1)
    us_r, ref = _time(lambda *a: attention_ref(*a, causal=True), q, k, v)
    err = float(jnp.abs(out - ref).max())
    vmem = (128 * hd + 128 * hd * 2 + 128 * hd + 128 * 2) * 4
    rows = [{"name": "flash_attention_512", "us_per_call": us_k,
             "derived": f"err={err:.1e};ref_us={us_r:.0f};"
                        f"vmem_tile={vmem/1024:.0f}KiB"}]
    return rows, f"flash kernel allclose {err:.1e}"


def decode_bench() -> Tuple[List[dict], str]:
    b, s, h, kv, hd = 4, 2048, 8, 2, 64
    q = jnp.asarray(RNG.normal(0, 1, (b, h, hd)), jnp.float32)
    kc = jnp.asarray(RNG.normal(0, 1, (b, s, kv, hd)), jnp.float32)
    vc = jnp.asarray(RNG.normal(0, 1, (b, s, kv, hd)), jnp.float32)
    lens = jnp.asarray(RNG.integers(1, s, (b,)), jnp.int32)
    us_k, out = _time(lambda *a: decode_attention_op(
        *a, block_k=256), q, kc, vc, lens, n=1)
    us_r, ref = _time(decode_attention_ref, q, kc, vc, lens)
    err = float(jnp.abs(out - ref).max())
    rows = [{"name": "decode_attention_2k", "us_per_call": us_k,
             "derived": f"err={err:.1e};ref_us={us_r:.0f}"}]
    return rows, f"decode kernel allclose {err:.1e}"


def ssm_bench() -> Tuple[List[dict], str]:
    b, s, c, n = 1, 512, 128, 16
    decay = jnp.asarray(RNG.uniform(0.5, 1, (b, s, c, n)), jnp.float32)
    drive = jnp.asarray(RNG.normal(0, 0.1, (b, s, c, n)), jnp.float32)
    h0 = jnp.zeros((b, c, n), jnp.float32)
    us_k, out = _time(lambda *a: ssm_scan_op(*a, chunk=64), decay, drive,
                      h0, n=1)
    us_r, ref = _time(ssm_scan_ref, decay, drive, h0)
    err = float(jnp.abs(out - ref).max())
    rows = [{"name": "ssm_scan_512", "us_per_call": us_k,
             "derived": f"err={err:.1e};ref_us={us_r:.0f}"}]
    return rows, f"ssm kernel allclose {err:.1e}"
