"""Benchmark harness: one entry per paper table/figure + roofline.

Usage: PYTHONPATH=src python -m benchmarks.run [--only PAT]
       PYTHONPATH=src python -m benchmarks.run --artifacts-only

Prints `name,us_per_call,derived` CSV plus per-figure headlines, then a
summary of every checked-in ``BENCH_*.json`` artifact (written by
``controller_bench.py``, ``lab_bench.py``, ...) so one invocation shows
the repo's full performance picture.
"""

from __future__ import annotations

import argparse
import glob
import json
import os
import sys


def aggregate_artifacts(root: str) -> None:
    """One summary table per ``BENCH_*.json`` table found under root.

    Artifacts are ``{section_name: [row_dict, ...], ...}``; every
    list-of-dicts value renders as an aligned table keyed by the union
    of its row fields, so new benchmarks join the summary by just
    writing a ``BENCH_<name>.json``.
    """
    paths = sorted(glob.glob(os.path.join(root, "BENCH_*.json")))
    if not paths:
        print("# no BENCH_*.json artifacts found")
        return
    print("\n# ---- checked-in benchmark artifacts ----")
    for path in paths:
        try:
            with open(path) as fh:
                doc = json.load(fh)
        except (OSError, ValueError) as e:
            print(f"# {os.path.basename(path)}: unreadable ({e!r})")
            continue
        for section, rows in sorted(doc.items()):
            if not (isinstance(rows, list)
                    and all(isinstance(r, dict) for r in rows) and rows):
                continue
            cols = []
            for r in rows:
                cols.extend(k for k in r if k not in cols)
            widths = {c: max(len(c), *(len(_fmt(r.get(c))) for r in rows))
                      for c in cols}
            print(f"\n## {os.path.basename(path)} :: {section}")
            print("  ".join(c.rjust(widths[c]) for c in cols))
            for r in rows:
                print("  ".join(_fmt(r.get(c)).rjust(widths[c])
                                for c in cols))


def _fmt(v) -> str:
    if isinstance(v, float):
        return f"{v:.4g}"
    return "" if v is None else str(v)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="")
    ap.add_argument("--artifacts-only", action="store_true",
                    help="skip the live micro-benches; just summarize "
                         "BENCH_*.json artifacts")
    args = ap.parse_args()

    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    if args.artifacts_only:
        aggregate_artifacts(root)
        return

    from . import kernel_bench, paper_figures
    from .roofline_table import roofline_summary

    benches = [
        ("fig1", paper_figures.fig1_memory_pattern),
        ("fig2", paper_figures.fig2_pressure_curve),
        ("fig5", paper_figures.fig5_applications),
        ("fig6", paper_figures.fig6_problem_sizes),
        ("fig7", paper_figures.fig7_stability),
        ("fig8", paper_figures.fig8_iterations),
        ("lambda", paper_figures.lambda_sweep),
        ("latency", paper_figures.controller_latency),
        ("fleet", paper_figures.fleet_scale),
        ("kern_flash", kernel_bench.flash_bench),
        ("kern_decode", kernel_bench.decode_bench),
        ("kern_ssm", kernel_bench.ssm_bench),
        ("roofline", roofline_summary),
    ]
    print("name,us_per_call,derived")
    headlines = []
    for key, fn in benches:
        if args.only and args.only not in key:
            continue
        try:
            rows, headline = fn()
        except Exception as e:      # a bench failure must not hide others
            print(f"{key},0,ERROR:{e!r}")
            headlines.append((key, f"ERROR {e!r}"))
            continue
        for r in rows:
            print(f"{r['name']},{r['us_per_call']:.1f},{r['derived']}")
        headlines.append((key, headline))
    print()
    for k, h in headlines:
        print(f"# {k}: {h}")
    aggregate_artifacts(root)


if __name__ == "__main__":
    main()
