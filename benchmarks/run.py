"""Benchmark harness: one entry per paper table/figure + roofline.

Usage: PYTHONPATH=src python -m benchmarks.run [--skip-roofline]
Prints `name,us_per_call,derived` CSV plus per-figure headlines.
"""

from __future__ import annotations

import argparse
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="")
    args = ap.parse_args()

    from . import kernel_bench, paper_figures
    from .roofline_table import roofline_summary

    benches = [
        ("fig1", paper_figures.fig1_memory_pattern),
        ("fig2", paper_figures.fig2_pressure_curve),
        ("fig5", paper_figures.fig5_applications),
        ("fig6", paper_figures.fig6_problem_sizes),
        ("fig7", paper_figures.fig7_stability),
        ("fig8", paper_figures.fig8_iterations),
        ("lambda", paper_figures.lambda_sweep),
        ("latency", paper_figures.controller_latency),
        ("fleet", paper_figures.fleet_scale),
        ("kern_flash", kernel_bench.flash_bench),
        ("kern_decode", kernel_bench.decode_bench),
        ("kern_ssm", kernel_bench.ssm_bench),
        ("roofline", roofline_summary),
    ]
    print("name,us_per_call,derived")
    headlines = []
    for key, fn in benches:
        if args.only and args.only not in key:
            continue
        try:
            rows, headline = fn()
        except Exception as e:      # a bench failure must not hide others
            print(f"{key},0,ERROR:{e!r}")
            headlines.append((key, f"ERROR {e!r}"))
            continue
        for r in rows:
            print(f"{r['name']},{r['us_per_call']:.1f},{r['derived']}")
        headlines.append((key, headline))
    print()
    for k, h in headlines:
        print(f"# {k}: {h}")


if __name__ == "__main__":
    main()
