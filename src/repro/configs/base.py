"""Architecture + run-shape configuration schema.

Every assigned architecture is an :class:`ArchConfig`; every benchmark
shape is an :class:`InputShape`.  Configs are frozen dataclasses so they
hash (usable as jit static args) and are fully serializable.

The divisibility policy of DESIGN.md §4 lives here
(:meth:`ArchConfig.sharding_report`): a tensor dimension is sharded on a
mesh axis only when divisible, otherwise replicated on that axis and the
decision is recorded, so the dry-run log shows exactly which layout each
architecture got.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Dict, Optional, Tuple


@dataclass(frozen=True)
class InputShape:
    """One benchmark cell's input geometry."""

    name: str
    seq_len: int
    global_batch: int
    kind: str                    # "train" | "prefill" | "decode"

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


TRAIN_4K = InputShape("train_4k", 4_096, 256, "train")
PREFILL_32K = InputShape("prefill_32k", 32_768, 32, "prefill")
DECODE_32K = InputShape("decode_32k", 32_768, 128, "decode")
LONG_500K = InputShape("long_500k", 524_288, 1, "decode")

SHAPES: Dict[str, InputShape] = {
    s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
}


@dataclass(frozen=True)
class ArchConfig:
    """One architecture, exactly as published (see configs/<id>.py)."""

    name: str
    family: str                       # dense|moe|ssm|hybrid|audio|vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int

    head_dim: int = 0                 # 0 -> d_model // n_heads
    # ---- attention ------------------------------------------------------
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    sliding_window: int = 0           # 0 = full attention
    global_every: int = 0             # gemma3: 1 global per N layers (N=6)
    attn_logit_softcap: float = 0.0
    # ---- MoE --------------------------------------------------------------
    n_experts: int = 0
    experts_per_token: int = 0
    n_shared_experts: int = 0
    d_ff_expert: int = 0              # per-expert hidden (0 -> d_ff)
    router_aux_coef: float = 0.01
    capacity_factor: float = 1.25
    # ---- encoder-decoder ---------------------------------------------------
    is_encoder_decoder: bool = False
    n_encoder_layers: int = 0
    # ---- vlm ----------------------------------------------------------------
    cross_attn_group: int = 0         # 1 cross layer per N self layers
    vision_tokens: int = 0
    # ---- ssm / hybrid ---------------------------------------------------------
    block_pattern: Tuple[str, ...] = ()   # e.g. ("mlstm","slstm")
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    # ---- misc ------------------------------------------------------------
    norm: str = "rmsnorm"             # rmsnorm|layernorm
    act: str = "silu"                 # silu|gelu
    mlp_gated: bool = True            # SwiGLU-style (False: plain 2-layer)
    tie_embeddings: bool = False
    vocab_pad_to: int = 256
    source: str = ""
    notes: str = ""

    # ---- derived -----------------------------------------------------------
    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)
        if self.n_experts and not self.d_ff_expert:
            object.__setattr__(self, "d_ff_expert", self.d_ff)

    @property
    def padded_vocab(self) -> int:
        p = self.vocab_pad_to
        return (self.vocab_size + p - 1) // p * p

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for long_500k (SSM / hybrid / sliding-window dominant)."""
        return self.family in ("ssm", "hybrid") or (
            self.sliding_window > 0 and self.global_every > 0)

    def supports_shape(self, shape: InputShape) -> bool:
        if shape.name == "long_500k":
            return self.sub_quadratic
        return True

    # ---- parameter counts (for roofline MODEL_FLOPS) -------------------------
    def n_params(self) -> int:
        return _count_params(self, active_only=False)

    def n_active_params(self) -> int:
        return _count_params(self, active_only=True)

    # ---- smoke-test reduction ---------------------------------------------
    def reduced(self) -> "ArchConfig":
        """Tiny same-family config for CPU smoke tests."""
        heads = min(self.n_heads, 4)
        kv = max(1, min(self.n_kv_heads, heads))
        while heads % kv:
            kv -= 1
        n_layers = min(self.n_layers, 4)
        if self.cross_attn_group:
            n_layers = max(self.cross_attn_group + 1, 2)
            n_layers = 2 * self.cross_attn_group  # 2 groups
        if self.block_pattern:
            n_layers = max(len(self.block_pattern), 2)
        return dataclasses.replace(
            self,
            name=self.name + "-smoke",
            n_layers=n_layers,
            n_encoder_layers=min(self.n_encoder_layers, 2),
            d_model=64,
            n_heads=heads,
            n_kv_heads=kv,
            head_dim=64 // heads,
            d_ff=128,
            d_ff_expert=128 if self.n_experts else 0,
            vocab_size=503,
            vocab_pad_to=64,
            n_experts=min(self.n_experts, 4),
            experts_per_token=min(self.experts_per_token, 2),
            n_shared_experts=min(self.n_shared_experts, 1),
            # no capacity drops at smoke scale so decode == forward exactly;
            # drop behaviour is unit-tested separately
            capacity_factor=8.0 if self.n_experts else self.capacity_factor,
            sliding_window=min(self.sliding_window, 16) if self.sliding_window else 0,
            # keep the local:global group structure exercised at 4 layers
            global_every=2 if self.global_every else 0,
            vision_tokens=min(self.vision_tokens, 8) if self.vision_tokens else 0,
            ssm_state=min(self.ssm_state, 8) if self.ssm_state else 0,
        )

    # ---- sharding report (DESIGN.md §4 divisibility policy) -------------------
    def sharding_report(self, data: int, model: int) -> Dict[str, object]:
        """Which dims shard on a (data, model) mesh, and why not if not."""
        heads_tp = self.n_heads % model == 0
        kv_eff = self.n_kv_heads
        kv_note = "native"
        if heads_tp and self.n_kv_heads < model:
            if model % self.n_kv_heads == 0:
                kv_eff = model
                kv_note = f"expanded {self.n_kv_heads}->{model} (Megatron KV replication)"
            else:
                heads_tp = False
                kv_note = f"kv={self.n_kv_heads} not expandable to {model}"
        elif heads_tp and self.n_kv_heads >= model:
            if self.n_kv_heads % model:
                heads_tp = False
                kv_note = f"kv={self.n_kv_heads} % model={model} != 0"
        ff = self.d_ff_expert if self.is_moe else self.d_ff
        report = {
            "arch": self.name,
            "mesh": {"data": data, "model": model},
            "attn_tp": heads_tp,
            "attn_note": kv_note if heads_tp else (
                f"attention replicated over model axis "
                f"(heads={self.n_heads} % {model} != 0; {kv_note})"),
            "kv_heads_effective": kv_eff if heads_tp else self.n_kv_heads,
            "mlp_tp": ff % model == 0 if ff else False,
            "vocab_tp": self.padded_vocab % model == 0,
            "d_model_fsdp": self.d_model % data == 0,
            "experts_padded": 0,
        }
        if self.is_moe:
            e = self.n_experts
            pad = (model - e % model) % model if e % model else 0
            report["experts_padded"] = pad
            report["expert_parallel"] = True
            report["moe_note"] = (
                f"{e} experts padded +{pad} to {e + pad} for EP={model}"
                if pad else f"{e} experts, EP={model}")
        return report


def _count_params(c: ArchConfig, active_only: bool) -> int:
    d, hd = c.d_model, c.head_dim
    kv = c.n_kv_heads
    attn = d * c.n_heads * hd + 2 * d * kv * hd + c.n_heads * hd * d
    if c.qkv_bias:
        attn += (c.n_heads + 2 * kv) * hd
    if c.mlp_gated:
        dense_mlp = 3 * d * c.d_ff
    else:
        dense_mlp = 2 * d * c.d_ff
    per_layer = attn + 2 * d                     # + norms
    total = 0
    n_self = c.n_layers
    if c.family == "ssm":
        # mLSTM/sLSTM blocks: qkv-ish projections + gates + ff block
        inner = c.ssm_expand * d
        mlstm = 3 * d * inner + 3 * inner + inner * d + 2 * d * c_ff_or(c, 4 * d)
        total = c.n_layers * (mlstm + 2 * d)
        emb = c.padded_vocab * d * (1 if c.tie_embeddings else 2)
        return total + emb + d
    if c.is_moe:
        e_ff = c.d_ff_expert
        router = d * c.n_experts
        n_e = c.experts_per_token if active_only else c.n_experts
        moe_mlp = router + n_e * 3 * d * e_ff \
            + c.n_shared_experts * 3 * d * e_ff
        total += n_self * (per_layer + moe_mlp)
    elif c.family == "hybrid":
        inner = c.ssm_expand * d
        ssm = 2 * d * inner + inner * (c.ssm_state * 2 + 1) + inner * d
        total += n_self * (per_layer + ssm + dense_mlp)
    else:
        total += n_self * (per_layer + dense_mlp)
    if c.cross_attn_group:
        n_cross = c.n_layers // c.cross_attn_group
        cross = d * c.n_heads * hd + 2 * d * kv * hd + c.n_heads * hd * d
        total += n_cross * (cross + dense_mlp + 2 * d)
    if c.is_encoder_decoder:
        enc = c.n_encoder_layers * (per_layer + dense_mlp)
        cross = c.n_layers * (attn + d)       # decoder cross-attention
        total += enc + cross
    emb = c.padded_vocab * d * (1 if c.tie_embeddings else 2)
    return total + emb + d


def c_ff_or(c: ArchConfig, default: int) -> int:
    return c.d_ff if c.d_ff else default
