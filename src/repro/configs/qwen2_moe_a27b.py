"""qwen2-moe-a2.7b: 60 routed experts top-4 + 4 shared experts.

[hf:Qwen/Qwen1.5-MoE-A2.7B; hf]  24L d_model=2048 16H (GQA kv=16)
d_ff=1408 (per expert) vocab=151936.
"""

from .base import ArchConfig

ARCH = ArchConfig(
    name="qwen2-moe-a2.7b",
    family="moe",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,
    vocab_size=151_936,
    head_dim=128,
    qkv_bias=True,
    n_experts=60,
    experts_per_token=4,
    n_shared_experts=4,
    d_ff_expert=1408,
    rope_theta=1_000_000.0,
    source="hf:Qwen/Qwen1.5-MoE-A2.7B",
    notes="60 routed experts padded to 64 for EP=16 (DESIGN §4); "
          "4 shared experts run densely with a sigmoid gate.",
)
