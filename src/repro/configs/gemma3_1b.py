"""gemma3-1b: 5:1 local:global sliding-window schedule, 262k vocab.

[hf:google/gemma-3-1b-pt; unverified]  26L d_model=1152 4H (GQA kv=1)
d_ff=6912 vocab=262144, window 512, 1 global layer per 6.
"""

from .base import ArchConfig

ARCH = ArchConfig(
    name="gemma3-1b",
    family="dense",
    n_layers=26,
    d_model=1152,
    n_heads=4,
    n_kv_heads=1,
    d_ff=6912,
    vocab_size=262_144,
    head_dim=256,
    sliding_window=512,
    global_every=6,
    rope_theta=1_000_000.0,
    act="gelu",
    tie_embeddings=True,
    source="hf:google/gemma-3-1b-pt",
    notes="Window schedule is structural: scan over groups of 5 local + "
          "1 global (+2-layer local tail for 26 = 4*6+2). Runs "
          "long_500k (sliding-window dominant). 4 heads -> attention "
          "replicated over model axis.",
)
