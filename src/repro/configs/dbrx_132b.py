"""dbrx-132b: 16-expert top-4 fine-grained MoE.

[hf:databricks/dbrx-base; unverified]  40L d_model=6144 48H (GQA kv=8)
d_ff=10752 (per expert) vocab=100352, MoE 16e top-4.
"""

from .base import ArchConfig

ARCH = ArchConfig(
    name="dbrx-132b",
    family="moe",
    n_layers=40,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=10752,
    vocab_size=100352,
    head_dim=128,
    n_experts=16,
    experts_per_token=4,
    d_ff_expert=10752,
    rope_theta=500_000.0,
    act="silu",
    source="hf:databricks/dbrx-base",
    notes="16 experts top-4; expert dim == model-axis size -> EP=16, "
          "one expert per model shard, canonical all-to-all.",
)
