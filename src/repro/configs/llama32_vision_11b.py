"""llama-3.2-vision-11b: decoder with gated cross-attention image layers.

[hf:meta-llama/Llama-3.2-11B-Vision; unverified]  40L d_model=4096 32H
(GQA kv=8) d_ff=14336 vocab=128256; 1 cross-attn layer per 5 (8 total).
Vision frontend is a stub: ``input_specs`` supplies precomputed patch
embeddings (B, vision_tokens, d_model).
"""

from .base import ArchConfig

ARCH = ArchConfig(
    name="llama-3.2-vision-11b",
    family="vlm",
    n_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=128_256,
    head_dim=128,
    cross_attn_group=5,
    vision_tokens=1600,
    rope_theta=500_000.0,
    source="hf:meta-llama/Llama-3.2-11B-Vision",
    notes="Nested scan: 8 groups of (4 self + 1 gated-cross). Cross-attn "
          "KV (image tokens) is a second, static KV class in the "
          "serving pool.",
)
