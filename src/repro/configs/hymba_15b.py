"""hymba-1.5b: parallel attention + Mamba heads in every layer.

[arXiv:2411.13676; hf]  32L d_model=1600 25H (GQA kv=5) d_ff=5504
vocab=32001, ssm_state=16.
"""

from .base import ArchConfig

ARCH = ArchConfig(
    name="hymba-1.5b",
    family="hybrid",
    n_layers=32,
    d_model=1600,
    n_heads=25,
    n_kv_heads=5,
    d_ff=5504,
    vocab_size=32_001,
    head_dim=64,
    sliding_window=1024,
    global_every=16,
    ssm_state=16,
    ssm_expand=2,
    ssm_conv=4,
    rope_theta=10_000.0,
    source="arXiv:2411.13676",
    notes="Attention and Mamba run in parallel per layer, fused by mean "
          "of RMS-normalized branch outputs (paper's mean fusion). "
          "Published pattern has 3 global-attn layers (first/middle/"
          "last); structural approximation here: 1 global per 16 "
          "(layers 15, 31). 25 heads % 16 != 0 -> attention replicated "
          "over model; Mamba shards d_inner=3200 over model. Runs "
          "long_500k (hybrid).",
)
