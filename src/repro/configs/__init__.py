"""Config registry: ``--arch <id>`` resolution for all assigned archs."""

from __future__ import annotations

import importlib
from typing import Dict, List

from .base import (ArchConfig, InputShape, SHAPES, TRAIN_4K, PREFILL_32K,
                   DECODE_32K, LONG_500K)

_MODULES = {
    "dbrx-132b": "dbrx_132b",
    "qwen2-moe-a2.7b": "qwen2_moe_a27b",
    "whisper-large-v3": "whisper_large_v3",
    "qwen2-1.5b": "qwen2_15b",
    "gemma3-1b": "gemma3_1b",
    "mistral-large-123b": "mistral_large_123b",
    "llama3.2-1b": "llama32_1b",
    "xlstm-125m": "xlstm_125m",
    "llama-3.2-vision-11b": "llama32_vision_11b",
    "hymba-1.5b": "hymba_15b",
}

ARCH_IDS: List[str] = list(_MODULES)


def get_config(name: str) -> ArchConfig:
    smoke = name.endswith("-smoke")
    base = name[: -len("-smoke")] if smoke else name
    if base not in _MODULES:
        raise KeyError(
            f"unknown arch {name!r}; available: {ARCH_IDS}")
    mod = importlib.import_module(f".{_MODULES[base]}", __package__)
    cfg: ArchConfig = mod.ARCH
    return cfg.reduced() if smoke else cfg


def all_configs() -> Dict[str, ArchConfig]:
    return {a: get_config(a) for a in ARCH_IDS}


def get_shape(name: str) -> InputShape:
    if name not in SHAPES:
        raise KeyError(f"unknown shape {name!r}; available: {list(SHAPES)}")
    return SHAPES[name]


def cells(include_skips: bool = False):
    """All (arch, shape) benchmark cells; skips filtered per DESIGN §5."""
    out = []
    for a in ARCH_IDS:
        cfg = get_config(a)
        for s in SHAPES.values():
            if include_skips or cfg.supports_shape(s):
                out.append((a, s.name))
    return out


__all__ = ["ARCH_IDS", "ArchConfig", "InputShape", "SHAPES", "TRAIN_4K",
           "PREFILL_32K", "DECODE_32K", "LONG_500K", "all_configs",
           "cells", "get_config", "get_shape"]
