"""mistral-large-123b: the largest dense assignment.

[hf:mistralai/Mistral-Large-Instruct-2407; unverified]  88L d_model=12288
96H (GQA kv=8) d_ff=28672 vocab=32768.
"""

from .base import ArchConfig

ARCH = ArchConfig(
    name="mistral-large-123b",
    family="dense",
    n_layers=88,
    d_model=12288,
    n_heads=96,
    n_kv_heads=8,
    d_ff=28672,
    vocab_size=32_768,
    head_dim=128,
    rope_theta=1_000_000.0,
    source="hf:mistralai/Mistral-Large-Instruct-2407",
    notes="FSDP(data) x TP(model) essential: 123B params = ~246 GB bf16 "
          "-> ~1 GB/chip on 256 chips. KV heads (8) replicated over "
          "model axis (Megatron pattern).",
)
