"""DynIMS controller parameters (paper Table I) + framework tier defaults.

Table I: M=125 GB, r0=0.95, lambda=0.5, U_min=0, U_max=60 GB, T=100 ms.

The framework reuses the same law for its own memory tiers; defaults for
those tiers live here so every trainer/server instantiates identically.
"""

from __future__ import annotations

from typing import Dict, List

from ..core.control import ControllerParams, GiB

# The paper's exact Table I configuration.
PAPER_TABLE_I = ControllerParams(
    total_memory=125.0 * GiB,
    r0=0.95,
    lam=0.5,
    u_min=0.0,
    u_max=60.0 * GiB,
    interval_s=0.1,
)


# ScenarioLab-tuned gains per named scenario (see ``repro.lab``): the
# argmax of the default widened grid (a paper-law 9x9 lam x r0 plane
# plus the beyond-paper law variants: asymmetric grant, deadband,
# feedforward) at budget=100, seed 0.  Stability scenarios tune under
# ``lab.score.default_score``; the CacheLoop scenarios tune under
# ``lab.score.runtime_score`` (modeled app runtime, the paper's
# headline metric) -- see LAB_TUNED_OBJECTIVES.  Regenerate with
# ``examples/tune_gains.py --all``.  Two lab findings: reclaim speed
# buys more than Table I's smoothness under recurring bursts (gains
# ~3x the paper's 0.5), and on three of four stress scenarios the
# *asymmetric* law wins -- reclaim near-critically (lam=1.6) but grant
# gently (lam_grant=0.25), which burns less headroom re-granting into
# the next burst.
LAB_TUNED: Dict[str, ControllerParams] = {
    # KV-admission waves: reclaim hard, re-grant softly between waves.
    "bursty-serving": PAPER_TABLE_I.replace(r0=0.935, lam=1.6,
                                            lam_grant=0.25),
    # Demand bursts past M: concede headroom (low r0), asymmetric law.
    "swap-storm": PAPER_TABLE_I.replace(r0=0.90, lam=1.6, lam_grant=0.25),
    # Mixed hardware: tight threshold, fast reclaim, gentle grant.
    "hetero-fleet": PAPER_TABLE_I.replace(r0=0.97, lam=1.6, lam_grant=0.25),
    # Crash/restart churn: grant aggressively into freed memory.
    "failover-churn": PAPER_TABLE_I.replace(r0=0.98, lam=0.95),
    # CacheLoop (runtime objective): with the warmup-aware cold scan
    # charging compulsory misses for the first pass, re-warming an
    # evicted set is priced honestly -- so like cache-churn this
    # workload now prefers slope feedforward (reclaim *ahead* of the
    # HPCC burst) over a bare near-critical gain.
    "spark-iterative-cache": PAPER_TABLE_I.replace(r0=0.935, lam=1.6,
                                                   feedforward=0.5),
    # CacheLoop with a slow refill pipe: slope feedforward reclaims
    # ahead of the burst, halving the evict-reload churn.
    "cache-churn": PAPER_TABLE_I.replace(r0=0.90, lam=1.6, feedforward=0.5),
}

# Which tuning objective produced each preset (tune_gains score_fn).
LAB_TUNED_OBJECTIVES: Dict[str, str] = {
    "spark-iterative-cache": "runtime",
    "cache-churn": "runtime",
}


# The registry names of the paper's Sec. IV.A scenarios (repro.lab
# registers them; kept literal here so configs does not import the lab).
PAPER_SCENARIOS = ("paper-c1-spark45", "paper-c2-static25",
                   "paper-c3-dynims60", "paper-c4-nohpcc")


def tuned_params(scenario: str, **overrides) -> ControllerParams:
    """The checked-in ScenarioLab preset for a named scenario.

    The paper's own scenarios resolve to Table I itself; unknown names
    (including misspelled ``paper-*`` ones) raise with the choices.
    """
    if scenario in PAPER_SCENARIOS:
        base = PAPER_TABLE_I
    else:
        try:
            base = LAB_TUNED[scenario]
        except KeyError:
            known = ", ".join(sorted(LAB_TUNED) + list(PAPER_SCENARIOS))
            raise KeyError(
                f"no tuned preset for {scenario!r} (have: {known}); run "
                "repro.lab.tune_gains to derive one") from None
    return base.replace(**overrides) if overrides else base


def tuned_scenarios() -> List[str]:
    return sorted(LAB_TUNED)


def host_cache_params(total_host_ram: float, *, u_max_frac: float = 0.5,
                      **overrides) -> ControllerParams:
    """Dataset shard cache on a TPU worker host (paper roles preserved)."""
    kw = dict(total_memory=total_host_ram, r0=0.95, lam=0.5, u_min=0.0,
              u_max=u_max_frac * total_host_ram, interval_s=0.1)
    kw.update(overrides)
    return ControllerParams(**kw)


def hbm_pool_params(hbm_bytes: float = 16 * GiB, *,
                    u_max_frac: float = 0.85, **overrides) -> ControllerParams:
    """Serving KV-block pool in HBM: tighter r0 (OOM is fatal on device),
    faster reclaim than grant (beyond-paper asymmetric gains)."""
    kw = dict(total_memory=hbm_bytes, r0=0.92, lam=0.8, lam_grant=0.3,
              u_min=0.0, u_max=u_max_frac * hbm_bytes, interval_s=0.05)
    kw.update(overrides)
    return ControllerParams(**kw)
