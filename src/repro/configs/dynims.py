"""DynIMS controller parameters (paper Table I) + framework tier defaults.

Table I: M=125 GB, r0=0.95, lambda=0.5, U_min=0, U_max=60 GB, T=100 ms.

The framework reuses the same law for its own memory tiers; defaults for
those tiers live here so every trainer/server instantiates identically.
"""

from __future__ import annotations

from ..core.control import ControllerParams, GiB

# The paper's exact Table I configuration.
PAPER_TABLE_I = ControllerParams(
    total_memory=125.0 * GiB,
    r0=0.95,
    lam=0.5,
    u_min=0.0,
    u_max=60.0 * GiB,
    interval_s=0.1,
)


def host_cache_params(total_host_ram: float, *, u_max_frac: float = 0.5,
                      **overrides) -> ControllerParams:
    """Dataset shard cache on a TPU worker host (paper roles preserved)."""
    kw = dict(total_memory=total_host_ram, r0=0.95, lam=0.5, u_min=0.0,
              u_max=u_max_frac * total_host_ram, interval_s=0.1)
    kw.update(overrides)
    return ControllerParams(**kw)


def hbm_pool_params(hbm_bytes: float = 16 * GiB, *,
                    u_max_frac: float = 0.85, **overrides) -> ControllerParams:
    """Serving KV-block pool in HBM: tighter r0 (OOM is fatal on device),
    faster reclaim than grant (beyond-paper asymmetric gains)."""
    kw = dict(total_memory=hbm_bytes, r0=0.92, lam=0.8, lam_grant=0.3,
              u_min=0.0, u_max=u_max_frac * hbm_bytes, interval_s=0.05)
    kw.update(overrides)
    return ControllerParams(**kw)
