"""DynIMS controller parameters (paper Table I) + framework tier defaults.

Table I: M=125 GB, r0=0.95, lambda=0.5, U_min=0, U_max=60 GB, T=100 ms.

The framework reuses the same law for its own memory tiers; defaults for
those tiers live here so every trainer/server instantiates identically.
"""

from __future__ import annotations

from typing import Dict, List

from ..core.control import ControllerParams, GiB

# The paper's exact Table I configuration.
PAPER_TABLE_I = ControllerParams(
    total_memory=125.0 * GiB,
    r0=0.95,
    lam=0.5,
    u_min=0.0,
    u_max=60.0 * GiB,
    interval_s=0.1,
)


# ScenarioLab-tuned gains per named scenario (see ``repro.lab``): the
# argmax of a 10x10 lam x r0 grid sweep under ``lab.score.default_score``
# at seed 0.  Regenerate with ``examples/tune_gains.py --all``.  The
# common shape -- gains well above the paper's 0.5 -- is the lab's first
# finding: under recurring bursts, reclaim speed buys more than the
# smoothness Table I optimizes for.
LAB_TUNED: Dict[str, ControllerParams] = {
    # KV-admission waves: track bursts tightly with a near-critical gain.
    "bursty-serving": PAPER_TABLE_I.replace(r0=0.9578, lam=1.8),
    # Demand bursts past M: concede headroom (low r0), reclaim fast.
    "swap-storm": PAPER_TABLE_I.replace(r0=0.8911, lam=1.0444),
    # Mixed hardware: paper r0 but ~3x the paper gain.
    "hetero-fleet": PAPER_TABLE_I.replace(r0=0.9578, lam=1.4222),
    # Crash/restart churn: grant aggressively into freed memory.
    "failover-churn": PAPER_TABLE_I.replace(r0=0.98, lam=1.0444),
}


# The registry names of the paper's Sec. IV.A scenarios (repro.lab
# registers them; kept literal here so configs does not import the lab).
PAPER_SCENARIOS = ("paper-c1-spark45", "paper-c2-static25",
                   "paper-c3-dynims60", "paper-c4-nohpcc")


def tuned_params(scenario: str, **overrides) -> ControllerParams:
    """The checked-in ScenarioLab preset for a named scenario.

    The paper's own scenarios resolve to Table I itself; unknown names
    (including misspelled ``paper-*`` ones) raise with the choices.
    """
    if scenario in PAPER_SCENARIOS:
        base = PAPER_TABLE_I
    else:
        try:
            base = LAB_TUNED[scenario]
        except KeyError:
            known = ", ".join(sorted(LAB_TUNED) + list(PAPER_SCENARIOS))
            raise KeyError(
                f"no tuned preset for {scenario!r} (have: {known}); run "
                "repro.lab.tune_gains to derive one") from None
    return base.replace(**overrides) if overrides else base


def tuned_scenarios() -> List[str]:
    return sorted(LAB_TUNED)


def host_cache_params(total_host_ram: float, *, u_max_frac: float = 0.5,
                      **overrides) -> ControllerParams:
    """Dataset shard cache on a TPU worker host (paper roles preserved)."""
    kw = dict(total_memory=total_host_ram, r0=0.95, lam=0.5, u_min=0.0,
              u_max=u_max_frac * total_host_ram, interval_s=0.1)
    kw.update(overrides)
    return ControllerParams(**kw)


def hbm_pool_params(hbm_bytes: float = 16 * GiB, *,
                    u_max_frac: float = 0.85, **overrides) -> ControllerParams:
    """Serving KV-block pool in HBM: tighter r0 (OOM is fatal on device),
    faster reclaim than grant (beyond-paper asymmetric gains)."""
    kw = dict(total_memory=hbm_bytes, r0=0.92, lam=0.8, lam_grant=0.3,
              u_min=0.0, u_max=u_max_frac * hbm_bytes, interval_s=0.05)
    kw.update(overrides)
    return ControllerParams(**kw)
