"""xlstm-125m: alternating sLSTM + mLSTM blocks (attention-free).

[arXiv:2405.04517; unverified]  12L d_model=768 4H d_ff=0 vocab=50304.
"""

from .base import ArchConfig

ARCH = ArchConfig(
    name="xlstm-125m",
    family="ssm",
    n_layers=12,
    d_model=768,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab_size=50_304,
    head_dim=192,
    block_pattern=("mlstm", "slstm"),
    ssm_expand=2,
    tie_embeddings=True,
    source="arXiv:2405.04517",
    notes="mLSTM runs in the chunkwise-parallel linear-attention form "
          "(MXU-friendly); sLSTM is sequential by design (lax.scan over "
          "time). Attention-free -> runs long_500k with O(1) state; the "
          "serving KV pool is inapplicable (DESIGN §5) -- DynIMS manages "
          "the (tiny) recurrent-state pool instead.",
)
