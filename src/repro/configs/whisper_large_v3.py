"""whisper-large-v3: encoder-decoder audio backbone (conv frontend = stub).

[arXiv:2212.04356; unverified]  32L(enc)+32L(dec) d_model=1280 20H
(kv=20) d_ff=5120 vocab=51866.  ``input_specs`` supplies precomputed
frame embeddings (the conv frontend stub per the assignment).
"""

from .base import ArchConfig

ARCH = ArchConfig(
    name="whisper-large-v3",
    family="audio",
    n_layers=32,
    n_encoder_layers=32,
    is_encoder_decoder=True,
    d_model=1280,
    n_heads=20,
    n_kv_heads=20,
    d_ff=5120,
    vocab_size=51_866,
    head_dim=64,
    norm="layernorm",
    act="gelu",
    mlp_gated=False,
    # encoder frames: whisper's 1500 (30 s) padded to 1536 so the cross
    # cache's seq dim shards over the 16-way model axis; decode masks by
    # the true enc_len, so padding is never attended.
    vision_tokens=1536,

    source="arXiv:2212.04356",
    notes="RoPE replaces Whisper's learned/sinusoidal positions (TPU "
          "adaptation; noted in DESIGN). 20 heads % 16 != 0 -> attention "
          "replicated over model axis, MLP stays TP. Decode shapes "
          "beyond the deployed 448-token decoder exercise the backbone "
          "as a framework capability.",
)
