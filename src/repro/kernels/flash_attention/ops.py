"""Jit'd public wrapper for the flash-attention kernel.

On TPU backends this compiles the Pallas kernel; on CPU (this container)
it runs the same kernel body in interpret mode, so correctness of the
blocking/masking/carry logic is validated even without hardware.
"""

from __future__ import annotations

import functools

import jax

from .kernel import flash_attention
from .ref import attention_ref


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_k"))
def flash_attention_op(q, k, v, *, causal: bool = True, window: int = 0,
                       block_q: int = 128, block_k: int = 128):
    return flash_attention(q, k, v, causal=causal, window=window,
                           block_q=block_q, block_k=block_k,
                           interpret=_on_cpu())


__all__ = ["flash_attention_op", "attention_ref"]
