"""Flash attention as a Pallas TPU kernel (GQA, causal, sliding window).

TPU adaptation of the CUDA flash-attention blocking: the (block_q x
block_k) tiles are sized for VMEM and the MXU's 128-lane geometry, the
online-softmax carry lives in VMEM scratch across the sequential
``kv`` grid dimension, and fully-masked tiles are skipped *before* their
matmuls issue (``@pl.when`` on block-level causal/window bounds), which
on a sequential TPU grid is real skipped work, not a predicated no-op.

Grid: (batch*heads, q_blocks, kv_blocks) with semantics
("parallel", "parallel", "arbitrary") -- the kv axis must run in order
because the scratch carry accumulates along it.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  scale: float, causal: bool, window: int,
                  block_q: int, block_k: int, n_kv_blocks: int):
    iq = pl.program_id(1)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = iq * block_q
    k_start = ik * block_k

    # Block-level skip: the whole tile is masked out iff it lies entirely
    # above the causal diagonal or entirely left of the window's reach.
    live = True
    if causal:
        live = k_start <= q_start + block_q - 1
    if window:
        live = jnp.logical_and(
            live, k_start + block_k - 1 > q_start - window)

    @pl.when(live)
    def _compute():
        q = q_ref[0, :, 0, :].astype(jnp.float32)          # (bq, hd)
        k = k_ref[0, :, 0, :].astype(jnp.float32)          # (bk, hd)
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        s = s * scale                                       # (bq, bk)
        q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32,
                                                   (block_q, block_k), 0)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32,
                                                   (block_q, block_k), 1)
        mask = jnp.ones((block_q, block_k), bool)
        if causal:
            mask &= k_pos <= q_pos
        if window:
            mask &= k_pos > q_pos - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + p.sum(axis=1)
        acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ik == n_kv_blocks - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, :, 0, :] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int = 0,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = False) -> jax.Array:
    """q: (B,Sq,H,hd); k/v: (B,Skv,KV,hd) -> (B,Sq,H,hd)."""
    b, sq, h, hd = q.shape
    skv, kvh = k.shape[1], k.shape[2]
    assert h % kvh == 0, "GQA requires n_heads % n_kv_heads == 0"
    g = h // kvh
    block_q = min(block_q, sq)
    block_k = min(block_k, skv)
    assert sq % block_q == 0 and skv % block_k == 0, \
        "sequence lengths must divide block sizes (pad upstream)"
    n_q = sq // block_q
    n_k = skv // block_k
    grid = (b * h, n_q, n_k)

    kernel = functools.partial(
        _flash_kernel, scale=1.0 / (hd ** 0.5), causal=causal,
        window=window, block_q=block_q, block_k=block_k, n_kv_blocks=n_k)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, 1, hd),
                         lambda bh, iq, ik: (bh // h, iq, bh % h, 0)),
            pl.BlockSpec((1, block_k, 1, hd),
                         lambda bh, iq, ik: (bh // h, ik, (bh % h) // g, 0)),
            pl.BlockSpec((1, block_k, 1, hd),
                         lambda bh, iq, ik: (bh // h, ik, (bh % h) // g, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, 1, hd),
                               lambda bh, iq, ik: (bh // h, iq, bh % h, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),        # running max
            pltpu.VMEM((block_q,), jnp.float32),        # running sum
            pltpu.VMEM((block_q, hd), jnp.float32),     # running acc
        ],
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v)
