"""Pure-jnp oracle for the flash-attention kernel (GQA + causal + SWA)."""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = True, window: int = 0,
                  softcap: float = 0.0) -> jax.Array:
    """q: (B,Sq,H,hd); k/v: (B,Skv,KV,hd) with H % KV == 0 -> (B,Sq,H,hd).

    Materialized-logits reference in float32.  The kernel must match this
    to ~1e-3 in float32 (its online-softmax recurrence reassociates sums).
    """
    b, sq, h, hd = q.shape
    skv, kvh = k.shape[1], k.shape[2]
    g = h // kvh
    qg = q.reshape(b, sq, kvh, g, hd).astype(jnp.float32)
    logits = jnp.einsum("bqkgd,bskd->bkgqs", qg, k.astype(jnp.float32))
    logits = logits / (hd ** 0.5)
    if softcap:
        logits = softcap * jnp.tanh(logits / softcap)
    q_pos = jnp.arange(sq)
    k_pos = jnp.arange(skv)
    mask = jnp.ones((sq, skv), bool)
    if causal:
        mask &= k_pos[None, :] <= q_pos[:, None]
    if window:
        mask &= k_pos[None, :] > q_pos[:, None] - window
    logits = jnp.where(mask[None, None, None], logits, NEG_INF)
    w = jax.nn.softmax(logits, axis=-1)
    o = jnp.einsum("bkgqs,bskd->bqkgd", w, v.astype(jnp.float32))
    return o.reshape(b, sq, h, hd)
