"""Jit'd public wrapper for the selective-scan kernel."""

from __future__ import annotations

import functools

import jax

from .kernel import ssm_scan
from .ref import ssm_scan_ref


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


@functools.partial(jax.jit, static_argnames=("chunk", "block_c"))
def ssm_scan_op(decay, drive, h0, *, chunk: int = 64, block_c: int = 128):
    return ssm_scan(decay, drive, h0, chunk=chunk, block_c=block_c,
                    interpret=_on_cpu())


__all__ = ["ssm_scan_op", "ssm_scan_ref"]
