"""Pure-jnp oracle for the chunked selective-scan (S6) kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def ssm_scan_ref(decay: jax.Array, drive: jax.Array,
                 h0: jax.Array) -> jax.Array:
    """decay/drive: (B,S,C,N); h0: (B,C,N) -> hidden states (B,S,C,N).

    h_t = decay_t * h_{t-1} + drive_t, channel-diagonal (C independent
    channels, N state dims per channel).  Sequential-in-time reference.
    """
    def step(h, xs):
        a, b_ = xs
        h = a * h + b_
        return h, h

    _, hs = jax.lax.scan(step, h0.astype(jnp.float32),
                         (decay.swapaxes(0, 1).astype(jnp.float32),
                          drive.swapaxes(0, 1).astype(jnp.float32)))
    return hs.swapaxes(0, 1)
