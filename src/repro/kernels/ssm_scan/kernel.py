"""Chunked selective scan (Mamba S6) as a Pallas TPU kernel.

TPU adaptation of the CUDA selective-scan: instead of one warp-level
scan per channel, the sequence is tiled into chunks walked by the
sequential grid axis; each program holds a (channel-block x state) carry
in VMEM scratch and runs the within-chunk recurrence as an unrolled
vector loop over the chunk -- channels are the vector lanes (the VPU's
8x128 geometry), time is the sequential axis.  State never leaves VMEM
between chunks of the same channel block.

Grid: (batch, channel_blocks, seq_chunks), semantics
("parallel", "parallel", "arbitrary").
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssm_kernel(decay_ref, drive_ref, h0_ref, out_ref, h_ref, *,
                chunk: int):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        h_ref[...] = h0_ref[0].astype(jnp.float32)          # (bc, n)

    h = h_ref[...]
    # Unrolled time loop within the chunk; channel block x state dims
    # stay vectorized.  ``chunk`` is a compile-time constant.
    for t in range(chunk):
        a = decay_ref[0, t].astype(jnp.float32)             # (bc, n)
        b_ = drive_ref[0, t].astype(jnp.float32)
        h = a * h + b_
        out_ref[0, t] = h.astype(out_ref.dtype)
    h_ref[...] = h


def ssm_scan(decay: jax.Array, drive: jax.Array, h0: jax.Array, *,
             chunk: int = 64, block_c: int = 128,
             interpret: bool = False) -> jax.Array:
    """decay/drive: (B,S,C,N); h0: (B,C,N) -> (B,S,C,N) hidden states."""
    b, s, c, n = decay.shape
    chunk = min(chunk, s)
    block_c = min(block_c, c)
    assert s % chunk == 0 and c % block_c == 0
    n_chunks = s // chunk
    n_cblocks = c // block_c
    grid = (b, n_cblocks, n_chunks)

    kernel = functools.partial(_ssm_kernel, chunk=chunk)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, block_c, n),
                         lambda ib, icb, ic: (ib, ic, icb, 0)),
            pl.BlockSpec((1, chunk, block_c, n),
                         lambda ib, icb, ic: (ib, ic, icb, 0)),
            pl.BlockSpec((1, block_c, n), lambda ib, icb, ic: (ib, icb, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, block_c, n),
                               lambda ib, icb, ic: (ib, ic, icb, 0)),
        out_shape=jax.ShapeDtypeStruct(decay.shape, jnp.float32),
        scratch_shapes=[pltpu.VMEM((block_c, n), jnp.float32)],
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(decay, drive, h0)
