"""Pure-jnp oracle for single-token decode attention over a KV cache."""

from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def decode_attention_ref(q: jax.Array, k_cache: jax.Array,
                         v_cache: jax.Array, lengths: jax.Array, *,
                         window: int = 0) -> jax.Array:
    """q: (B,H,hd); caches: (B,S,KV,hd); lengths: (B,) int32.

    Attends to positions [0, len_b) per sequence -> (B,H,hd), float32.
    """
    b, h, hd = q.shape
    s, kvh = k_cache.shape[1], k_cache.shape[2]
    g = h // kvh
    qg = q.reshape(b, kvh, g, hd).astype(jnp.float32)
    logits = jnp.einsum("bkgd,bskd->bkgs", qg,
                        k_cache.astype(jnp.float32)) / (hd ** 0.5)
    k_pos = jnp.arange(s)
    valid = k_pos[None] < lengths[:, None]                   # (B,S)
    if window:
        valid &= k_pos[None] >= lengths[:, None] - window
    logits = jnp.where(valid[:, None, None], logits, NEG_INF)
    w = jax.nn.softmax(logits, axis=-1)
    o = jnp.einsum("bkgs,bskd->bkgd", w, v_cache.astype(jnp.float32))
    return o.reshape(b, h, hd)
