"""Jit'd public wrapper for the decode-attention kernel."""

from __future__ import annotations

import functools

import jax

from .kernel import decode_attention
from .ref import decode_attention_ref


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


@functools.partial(jax.jit, static_argnames=("window", "block_k"))
def decode_attention_op(q, k_cache, v_cache, lengths, *, window: int = 0,
                        block_k: int = 256):
    return decode_attention(q, k_cache, v_cache, lengths, window=window,
                            block_k=block_k, interpret=_on_cpu())


__all__ = ["decode_attention_op", "decode_attention_ref"]
