"""Single-token decode attention as a Pallas TPU kernel.

Serving hot path: one query token per sequence against a long KV cache.
The cache's sequence axis is tiled into ``block_k`` chunks walked by the
sequential grid axis with an online-softmax carry in VMEM scratch (same
recurrence as the flash kernel, degenerate q-block of one token per
(batch, head) program).  Per-sequence lengths arrive via scalar prefetch
(SMEM) so block-level skipping -- tiles entirely past ``len_b`` issue no
matmul -- is decided before the tile loads stream.

This kernel is what the DynIMS-managed KV pool feeds: the pool hands out
whole cache pages, the engine materializes the (B,S,KV,hd) view, the
kernel never reads past ``lengths``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref,
                   m_ref, l_ref, acc_ref, *,
                   scale: float, window: int, block_k: int,
                   n_kv_blocks: int, n_heads: int):
    bh = pl.program_id(0)
    ik = pl.program_id(1)
    b = bh // n_heads
    seq_len = len_ref[b]

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    k_start = ik * block_k
    live = k_start < seq_len
    if window:
        live = jnp.logical_and(live, k_start + block_k > seq_len - window)

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0, :].astype(jnp.float32)               # (hd,)
        k = k_ref[0, :, 0, :].astype(jnp.float32)            # (bk, hd)
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        s = jnp.dot(k, q, preferred_element_type=jnp.float32) * scale
        k_pos = k_start + jax.lax.iota(jnp.int32, block_k)
        valid = k_pos < seq_len
        if window:
            valid &= k_pos >= seq_len - window
        s = jnp.where(valid, s, NEG_INF)                     # (bk,)
        m_prev = m_ref[0]
        m_new = jnp.maximum(m_prev, s.max())
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_ref[0] = l_ref[0] * corr + p.sum()
        acc_ref[0, :] = acc_ref[0, :] * corr + jnp.dot(
            p, v, preferred_element_type=jnp.float32)
        m_ref[0] = m_new

    @pl.when(ik == n_kv_blocks - 1)
    def _finalize():
        l = jnp.maximum(l_ref[0], 1e-30)
        o_ref[0, 0, :] = (acc_ref[0, :] / l).astype(o_ref.dtype)


def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     lengths: jax.Array, *, window: int = 0,
                     block_k: int = 256, interpret: bool = False
                     ) -> jax.Array:
    """q: (B,H,hd); caches: (B,S,KV,hd); lengths: (B,) -> (B,H,hd)."""
    b, h, hd = q.shape
    s, kvh = k_cache.shape[1], k_cache.shape[2]
    assert h % kvh == 0
    g = h // kvh
    block_k = min(block_k, s)
    assert s % block_k == 0, "cache length must divide block_k"
    n_k = s // block_k
    grid = (b * h, n_k)

    kernel = functools.partial(
        _decode_kernel, scale=1.0 / (hd ** 0.5), window=window,
        block_k=block_k, n_kv_blocks=n_k, n_heads=h)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, hd), lambda bh, ik, lens: (bh // h, bh % h, 0)),
            pl.BlockSpec((1, block_k, 1, hd),
                         lambda bh, ik, lens: (bh // h, ik, (bh % h) // g, 0)),
            pl.BlockSpec((1, block_k, 1, hd),
                         lambda bh, ik, lens: (bh // h, ik, (bh % h) // g, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, hd),
                               lambda bh, ik, lens: (bh // h, bh % h, 0)),
        scratch_shapes=[
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1, hd), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(lengths.astype(jnp.int32), q, k_cache, v_cache)
