"""Pallas TPU kernels for the framework's compute hot spots.

The paper (DynIMS) has no kernel-level contribution -- these kernels are
framework substrate for the serving/training paths the DynIMS-managed
memory tiers feed (DESIGN.md §2):

* flash_attention  -- 2D-tiled online-softmax attention (prefill/train)
* decode_attention -- one-token-vs-cache attention with scalar-prefetch
                      lengths (serving hot path over the KV pool)
* ssm_scan         -- chunked selective scan (Mamba channels on the VPU)

Each kernel ships kernel.py (pl.pallas_call + BlockSpec), ops.py (jit'd
wrapper, interpret-mode on CPU), ref.py (pure-jnp oracle).  Tests sweep
shapes/dtypes and assert allclose against the oracle.
"""

from .decode_attention.ops import decode_attention_op
from .flash_attention.ops import flash_attention_op
from .ssm_scan.ops import ssm_scan_op

__all__ = ["decode_attention_op", "flash_attention_op", "ssm_scan_op"]
