"""Three-term roofline from a compiled dry-run artifact.

    compute    = HLO_FLOPs      / (chips * peak_FLOP/s)
    memory     = HLO_bytes      / (chips * HBM_bw)
    collective = collective_B   / (chips * link_bw)

``compiled.cost_analysis()`` on an SPMD module reports the *per-device*
program (one partition's flops/bytes), so per-chip terms divide by the
chip rate only; we normalize both conventions explicitly and record
which was used.  MODEL_FLOPS is the analytic useful work (6·N·D train,
2·N·D inference, N_active for MoE); its ratio against HLO_FLOPs exposes
remat recompute and dispatch overhead.
"""

from __future__ import annotations

from typing import Dict, Optional

from .constants import HBM_BW, ICI_BW, PEAK_FLOPS
from .hlo import parse_collectives


def model_flops(n_params: int, n_active: int, tokens: int,
                kind: str) -> float:
    n = n_active or n_params
    if kind == "train":
        return 6.0 * n * tokens
    return 2.0 * n * tokens          # prefill / decode forward-only


def roofline_terms(*, hlo_flops_per_chip: float, hlo_bytes_per_chip: float,
                   collective_bytes_per_chip: float,
                   peak_flops: float = PEAK_FLOPS, hbm_bw: float = HBM_BW,
                   ici_bw: float = ICI_BW) -> Dict[str, float]:
    compute = hlo_flops_per_chip / peak_flops
    memory = hlo_bytes_per_chip / hbm_bw
    collective = collective_bytes_per_chip / ici_bw
    terms = {"compute_s": compute, "memory_s": memory,
             "collective_s": collective}
    dominant = max(terms, key=terms.get)
    bound = max(compute, memory, collective)
    total = max(bound, 1e-30)
    return {
        **terms,
        "dominant": dominant.replace("_s", ""),
        "bound_s": bound,
        "compute_fraction_of_roofline": compute / total,
    }


def analyze_compiled(compiled, desc: dict, n_chips: int,
                     hlo_text: Optional[str] = None) -> dict:
    """Extract the full §Roofline row for one compiled cell.

    Primary accounting is the trip-count-aware HLO cost model
    (roofline/hlo_cost.py); the backend's ``cost_analysis()`` is kept in
    the artifact for reference but is known to count ``while`` bodies
    once on CPU (validated in tests/test_roofline.py).
    """
    backend_cost = {}
    try:
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0] if ca else {}
        backend_cost = {k: float(v) for k, v in dict(ca or {}).items()
                        if isinstance(v, (int, float))}
    except Exception as e:             # pragma: no cover
        backend_cost = {"error": str(e)}

    text = hlo_text if hlo_text is not None else compiled.as_text()
    from .hlo_cost import hlo_cost
    model_cost = hlo_cost(text)
    flops = float(model_cost["flops"])
    nbytes = float(model_cost["bytes"])
    coll = {
        "total_bytes": float(model_cost["collective_bytes"]),
        "per_kind_bytes": model_cost["per_kind_bytes"],
        "flat_parse": parse_collectives(text),    # no loop multipliers
    }
    bytes_by_op = model_cost.get("bytes_by_op", {})

    mem = {}
    try:
        ma = compiled.memory_analysis()
        for k in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "generated_code_size_in_bytes",
                  "alias_size_in_bytes"):
            v = getattr(ma, k, None)
            if v is not None:
                mem[k] = int(v)
    except Exception as e:             # pragma: no cover
        mem = {"error": str(e)}

    # cost_analysis on an SPMD module is per-device; collective bytes
    # parsed from the per-device HLO likewise.
    terms = roofline_terms(
        hlo_flops_per_chip=flops,
        hlo_bytes_per_chip=nbytes,
        collective_bytes_per_chip=coll["total_bytes"],
    )
    mf = model_flops(desc["n_params"], desc.get("n_active_params", 0),
                     desc["tokens"], desc["kind"])
    mf_per_chip = mf / n_chips
    return {
        **desc,
        "n_chips": n_chips,
        "hlo_flops_per_chip": flops,
        "hlo_bytes_per_chip": nbytes,
        "bytes_by_op": bytes_by_op,
        "backend_cost_analysis": backend_cost,
        "collectives": coll,
        "memory_analysis": mem,
        "roofline": terms,
        "model_flops_total": mf,
        "model_flops_per_chip": mf_per_chip,
        "useful_flops_ratio": (mf_per_chip / flops) if flops else 0.0,
        "step_time_bound_s": terms["bound_s"],
        "model_flops_utilization_bound": (
            mf_per_chip / PEAK_FLOPS / terms["bound_s"]
            if terms["bound_s"] > 0 else 0.0),
    }


def format_row(r: dict) -> str:
    t = r["roofline"]
    return (f"| {r['arch']} | {r['shape']} | {t['compute_s']:.4f} | "
            f"{t['memory_s']:.4f} | {t['collective_s']:.4f} | "
            f"{t['dominant']} | {r['useful_flops_ratio']:.2f} | "
            f"{r['model_flops_utilization_bound']:.3f} |")
