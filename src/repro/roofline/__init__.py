"""Roofline analysis over compiled dry-run artifacts."""

from .analysis import analyze_compiled, roofline_terms
from .constants import HBM_BW, ICI_BW, PEAK_FLOPS
from .hlo import parse_collectives

__all__ = ["HBM_BW", "ICI_BW", "PEAK_FLOPS", "analyze_compiled",
           "parse_collectives", "roofline_terms"]
