"""Trip-count-aware cost model over compiled HLO text.

XLA:CPU's ``compiled.cost_analysis()`` counts a ``while`` body ONCE
regardless of trip count (measured: an 8-layer scanned train step
reports exactly one matmul of FLOPs), which voids it for scan-over-
layers programs -- i.e. for every model here.  This module re-derives
program cost from the compiled HLO text with loop multipliers:

* computations are parsed into instruction lists with a per-computation
  symbol table (operand shapes resolved through named instructions),
* ``while`` instructions multiply their body cost by the trip count
  recovered from the condition computation's comparison constant
  (JAX scans lower to ``lt(i, N)``),
* ``fusion``/``call``/conditional branches recurse with multiplier 1,
* FLOPs: dot/convolution, 2 * output_elements * contraction_size
  (element-wise transcendentals ignored -- MXU work dominates),
* bytes: for every non-trivial top-level instruction, operand + result
  bytes; fusions count only their boundary (that is what reaches HBM),
* collectives: operand bytes per kind, loop-multiplied.

Validated against hand-counted matmul programs (tests/test_roofline.py).
"""

from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "token": 0,
}

_SHAPE_RE = re.compile(r"([a-z]\d*[a-z]*\d*(?:e\dm\d\w*)?)\[([\d,]*)\]")
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\((.*?)\)\s*->")
_INSTR_RE = re.compile(r"^\s+(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.*)$")
_OPND_RE = re.compile(r"%([\w\.\-]+)")
_CALLS_RE = re.compile(r"(?:calls|to_apply)=%?([\w\.\-]+)")
_WHILE_RE = re.compile(r"condition=%?([\w\.\-]+),\s*body=%?([\w\.\-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_BATCH_RE = re.compile(r"lhs_batch_dims=\{([\d,]*)\}")

SKIP_BYTES_OPS = {"parameter", "constant", "bitcast", "get-tuple-element",
                  "tuple", "after-all", "iota", "partition-id",
                  "replica-id", "while", "conditional", "copy-start",
                  "copy-done", "reshape", "transpose"}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")


def _parse_shapes(text: str) -> List[Tuple[str, Tuple[int, ...]]]:
    out = []
    for dt, dims in _SHAPE_RE.findall(text):
        shape = tuple(int(d) for d in dims.split(",") if d)
        out.append((dt, shape))
    return out


def _nbytes(dt: str, shape: Tuple[int, ...]) -> int:
    n = 1
    for d in shape:
        n *= d
    return n * _DTYPE_BYTES.get(dt, 4)


@dataclass
class Instr:
    name: str
    opcode: str
    line: str
    result_shapes: List[Tuple[str, Tuple[int, ...]]]
    operands: List[str]

    @property
    def result_bytes(self) -> int:
        return sum(_nbytes(dt, sh) for dt, sh in self.result_shapes)


@dataclass
class Computation:
    name: str
    instrs: List[Instr] = field(default_factory=list)
    symbols: Dict[str, List[Tuple[str, Tuple[int, ...]]]] = \
        field(default_factory=dict)
    params: List[str] = field(default_factory=list)


def _opcode_of(rhs: str) -> str:
    # rhs looks like: "f32[1,2]{1,0} opcode(...)" or "(f32[..],..) op(...)"
    m = re.search(r"\)\s*([a-z][\w\-]*)\(", rhs)    # after tuple result
    if m:
        return m.group(1)
    m = re.search(r"\}?\s([a-z][\w\-]*)\(", rhs)
    if m:
        return m.group(1)
    return "unknown"


def parse_module(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    current: Optional[Computation] = None
    entry_name = None
    for raw in text.splitlines():
        line = raw.rstrip()
        if current is None:
            m = _COMP_HDR.match(line.strip())
            if m and line.rstrip().endswith("{"):
                current = Computation(m.group(1))
                if line.strip().startswith("ENTRY"):
                    entry_name = m.group(1)
                # parameters: "name: f32[1,2]" pairs
                for pname, ptext in re.findall(
                        r"([\w\.\-]+):\s*"
                        r"([a-z]\d*[a-z]*\d*(?:e\dm\d\w*)?"
                        r"\[[\d,]*\](?:\{[^}]*\})?)",
                        m.group(2)):
                    shapes = _parse_shapes(ptext)
                    if shapes:
                        current.symbols[pname] = shapes
                        current.params.append(pname)
            continue
        if line.startswith("}"):
            comps[current.name] = current
            current = None
            continue
        m = _INSTR_RE.match(line)
        if not m:
            continue
        name, rhs = m.groups()
        opcode = _opcode_of(rhs)
        # result shapes: everything before the opcode token
        op_idx = rhs.find(f"{opcode}(")
        result_shapes = _parse_shapes(rhs[:op_idx] if op_idx > 0 else rhs)
        # operands: %names inside the first paren group after opcode
        paren = rhs[op_idx:] if op_idx >= 0 else rhs
        depth = 0
        end = 0
        for i, ch in enumerate(paren):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        operands = _OPND_RE.findall(paren[:end + 1])
        inst = Instr(name, opcode, rhs, result_shapes, operands)
        current.instrs.append(inst)
        current.symbols[name] = result_shapes
    if entry_name is not None:
        comps["__entry__"] = comps[entry_name]
    return comps


def _trip_count(cond: Computation) -> int:
    consts = [int(x) for i in cond.instrs
              for x in _CONST_RE.findall(i.line)]
    return max(consts) if consts else 1


def _dot_flops(inst: Instr, comp: Computation) -> float:
    out_elems = 0
    for dt, sh in inst.result_shapes:
        n = 1
        for d in sh:
            n *= d
        out_elems += n
    m = _CONTRACT_RE.search(inst.line)
    contract = 1
    if m and inst.operands:
        lhs = comp.symbols.get(inst.operands[0])
        if lhs:
            _, lshape = lhs[0]
            for ax in (int(a) for a in m.group(1).split(",") if a):
                if ax < len(lshape):
                    contract *= lshape[ax]
    return 2.0 * out_elems * contract


@dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    collective_bytes: float = 0.0
    per_kind: Dict[str, float] = field(default_factory=dict)
    bytes_by_op: Dict[str, float] = field(default_factory=dict)

    def add(self, other: "Cost", mult: float = 1.0) -> None:
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        self.collective_bytes += other.collective_bytes * mult
        for k, v in other.per_kind.items():
            self.per_kind[k] = self.per_kind.get(k, 0.0) + v * mult
        for k, v in other.bytes_by_op.items():
            self.bytes_by_op[k] = self.bytes_by_op.get(k, 0.0) + v * mult


def _operand_bytes(inst: Instr, comp: Computation) -> int:
    total = 0
    for op in inst.operands:
        shapes = comp.symbols.get(op)
        if shapes:
            total += sum(_nbytes(dt, sh) for dt, sh in shapes)
    return total


def _sliced_param_bytes(inner: Computation, pos: int,
                        full_bytes: int) -> int:
    """Effective read size of a fusion operand: if the corresponding
    inner parameter is consumed ONLY through (dynamic-)slice ops, the
    fusion reads just the slices -- the scan-over-layers pattern feeds
    the full (L, ...) stack into each iteration's fusion but touches one
    layer.  Counting the full operand overstates HBM traffic ~L times."""
    if pos >= len(inner.params):
        return full_bytes
    pname = inner.params[pos]
    sliced = 0
    for inst in inner.instrs:
        if pname not in inst.operands:
            continue
        if inst.opcode in ("dynamic-slice", "slice"):
            sliced += inst.result_bytes
        elif inst.opcode == "dynamic-update-slice":
            # in-place update: write = update slice, read = none extra
            if inst.operands and inst.operands[0] == pname:
                upd = inst.operands[1] if len(inst.operands) > 1 else None
                if upd and upd in inner.symbols:
                    sliced += sum(_nbytes(dt, sh)
                                  for dt, sh in inner.symbols[upd])
                continue
            return full_bytes
        elif inst.opcode in ("bitcast", "get-tuple-element", "parameter"):
            continue
        else:
            return full_bytes
    return min(sliced, full_bytes) if sliced else full_bytes


def _instr_bytes(inst: Instr, comp: Computation,
                 comps: Dict[str, Computation]) -> float:
    """HBM traffic of one top-level instruction (operands + result),
    with slice-aware handling of the scan access patterns."""
    op = inst.opcode
    if op in ("dynamic-slice", "slice"):
        return 2.0 * inst.result_bytes               # read slice, write slice
    if op == "dynamic-update-slice":
        upd = 0
        if len(inst.operands) > 1:
            shapes = comp.symbols.get(inst.operands[1])
            if shapes:
                upd = sum(_nbytes(dt, sh) for dt, sh in shapes)
        return 2.0 * upd                              # in-place slice write
    calls = _CALLS_RE.search(inst.line)
    if op == "fusion" and calls and calls.group(1) in comps:
        inner = comps[calls.group(1)]
        total = float(inst.result_bytes)
        # Output fusions updating an aliased buffer: if the root (looking
        # through convert/bitcast/copy wrappers -- XLA:CPU inserts f32
        # round-trips TPU would not) is a DUS, the true write is the
        # update slice; the accumulator operand it targets aliases in
        # place, so its read side is free as well.
        aliased_param = None
        root = _resolve(inner, inner.instrs[-1] if inner.instrs else None)
        if root is not None and root.opcode == "dynamic-update-slice":
            if len(root.operands) > 1:
                shapes = inner.symbols.get(root.operands[1])
                if shapes:
                    total = float(sum(_nbytes(dt, sh)
                                      for dt, sh in shapes))
            tgt = _resolve(inner, _def_of(inner, root.operands[0])) \
                if root.operands else None
            if tgt is not None and tgt.opcode == "parameter":
                aliased_param = tgt.name
        for pos, opnd in enumerate(inst.operands):
            shapes = comp.symbols.get(opnd)
            if not shapes:
                continue
            if pos < len(inner.params) and \
                    inner.params[pos] == aliased_param:
                continue                      # in-place accumulator
            full = sum(_nbytes(dt, sh) for dt, sh in shapes)
            total += _sliced_param_bytes(inner, pos, full)
        return total
    return float(inst.result_bytes + _operand_bytes(inst, comp))


_TRANSPARENT = ("convert", "bitcast", "copy", "reshape")


def _def_of(comp: Computation, name: str):
    for inst in comp.instrs:
        if inst.name == name:
            return inst
    if name in comp.params:
        return Instr(name, "parameter", "", comp.symbols.get(name, []), [])
    return None


def _resolve(comp: Computation, inst):
    """Walk back through convert/bitcast/copy chains to the real op."""
    seen = 0
    while inst is not None and inst.opcode in _TRANSPARENT and \
            inst.operands and seen < 8:
        inst = _def_of(comp, inst.operands[0])
        seen += 1
    return inst


def _comp_cost(comp: Computation, comps: Dict[str, Computation],
               memo: Dict[str, Cost], top_level: bool) -> Cost:
    if comp.name in memo:
        return memo[comp.name]
    cost = Cost()
    for inst in comp.instrs:
        if inst.opcode == "while":
            m = _WHILE_RE.search(inst.line)
            if m:
                trips = _trip_count(comps[m.group(1)])
                body = _comp_cost(comps[m.group(2)], comps, memo, top_level)
                cost.add(body, trips)
            continue
        calls = _CALLS_RE.search(inst.line)
        if inst.opcode in ("fusion", "call") and calls:
            inner = _comp_cost(comps[calls.group(1)], comps, memo,
                               top_level=False)
            # fusions: count only MXU work from inside; memory traffic is
            # the fusion boundary (operands + result), added below.
            cost.flops += inner.flops
            cost.collective_bytes += inner.collective_bytes
            for k, v in inner.per_kind.items():
                cost.per_kind[k] = cost.per_kind.get(k, 0.0) + v
        elif inst.opcode in ("conditional",):
            for cname in _OPND_RE.findall(
                    inst.line[inst.line.find("branch"):] or ""):
                if cname in comps:
                    cost.add(_comp_cost(comps[cname], comps, memo, False))
        if inst.opcode in ("dot", "convolution"):
            cost.flops += _dot_flops(inst, comp)
        kind = inst.opcode.replace("-start", "")
        if kind in COLLECTIVES:
            b = float(_operand_bytes(inst, comp))
            cost.collective_bytes += b
            cost.per_kind[kind] = cost.per_kind.get(kind, 0.0) + b
        if inst.opcode not in SKIP_BYTES_OPS and not inst.opcode.endswith(
                "-done"):
            b = _instr_bytes(inst, comp, comps)
            cost.bytes += b
            cost.bytes_by_op[inst.opcode] = \
                cost.bytes_by_op.get(inst.opcode, 0.0) + b
    memo[comp.name] = cost
    return cost


def hlo_cost(text: str) -> Dict[str, float]:
    """-> {'flops', 'bytes', 'collective_bytes', 'per_kind_bytes'}."""
    comps = parse_module(text)
    entry = comps.get("__entry__")
    if entry is None:
        raise ValueError("no ENTRY computation found")
    memo: Dict[str, Cost] = {}
    # memoization note: a computation reached both from top level and
    # inside a fusion is rare in optimized HLO; accept the approximation.
    cost = _comp_cost(entry, comps, memo, top_level=True)
    return {
        "flops": cost.flops,
        "bytes": cost.bytes,
        "collective_bytes": cost.collective_bytes,
        "per_kind_bytes": dict(cost.per_kind),
        "bytes_by_op": dict(cost.bytes_by_op),
    }
