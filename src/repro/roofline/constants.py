"""TPU v5e-like hardware constants (per chip)."""

PEAK_FLOPS = 197e12       # bf16 FLOP/s
HBM_BW = 819e9            # bytes/s
ICI_BW = 50e9             # bytes/s per link (task-specified)

CHIP = {
    "peak_flops": PEAK_FLOPS,
    "hbm_bw": HBM_BW,
    "ici_bw": ICI_BW,
    "hbm_bytes": 16 * 2**30,
}
