"""Collective-byte accounting from post-SPMD compiled HLO text.

``compiled.as_text()`` (after partitioning/optimization) names every
collective explicitly; we sum the *operand* bytes of each -- the payload
a chip must move -- bucketed by op kind.  ``lowered.as_text()`` is
pre-SPMD (sharding annotations, no collectives), so the compiled module
is the right artifact.
"""

from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict, List, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter",
                  "all-to-all", "collective-permute")

_SHAPE_RE = re.compile(r"\b([a-z]\d*[a-z]*\d*(?:e\dm\d\w*)?)\[([\d,]*)\]")
_INSTR_RE = re.compile(
    r"=\s*(?:\([^)]*\)\s*)?"                      # optional tuple result
    r"((?:bf16|f16|f32|f64|s\d+|u\d+|pred|c\d+|f8\w+)\[[^=]*?)?"
    r"\b(all-gather|all-reduce|reduce-scatter|all-to-all|"
    r"collective-permute)(?:-start|-done)?\(", )


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            if d:
                n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def parse_collectives(hlo_text: str) -> Dict[str, object]:
    """Sum operand bytes per collective kind from compiled HLO text."""
    per_kind: Dict[str, float] = defaultdict(float)
    per_kind_count: Dict[str, int] = defaultdict(int)
    ops: List[Tuple[str, float]] = []
    for line in hlo_text.splitlines():
        m = None
        for kind in COLLECTIVE_OPS:
            token = f" {kind}(" if f" {kind}(" in line else (
                f"{kind}-start(" if f"{kind}-start(" in line else None)
            if token is not None and "=" in line:
                m = kind
                break
        if m is None:
            continue
        # operand shapes are inside the call parens; result shape(s)
        # precede the op name.  Take shapes after the op token.
        idx = line.index(m)
        operands = line[idx:]
        shapes = _SHAPE_RE.findall(operands)
        nbytes = float(sum(_shape_bytes(dt, dims) for dt, dims in shapes))
        if nbytes == 0:
            continue
        per_kind[m] += nbytes
        per_kind_count[m] += 1
        ops.append((m, nbytes))
    return {
        "total_bytes": float(sum(per_kind.values())),
        "per_kind_bytes": dict(per_kind),
        "per_kind_count": dict(per_kind_count),
        "n_ops": len(ops),
        "largest": sorted(ops, key=lambda t: -t[1])[:10],
    }
