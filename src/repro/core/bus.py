"""In-process messaging bus (the paper's Kafka analogue).

Topic-based pub/sub with the same role Kafka plays in DynIMS: decouple
monitoring agents, the stream processor, and the memory controller.  Two
consumption styles, matching Kafka's consumer groups:

* callback subscription (``subscribe``) -- push, used by the aggregator,
* bounded per-topic retention + cursors (``poll``) -- pull, used by tests
  and by slow consumers.

Thread-safe; publishing never blocks on slow subscribers (exceptions in a
callback are recorded, not propagated -- a monitoring plane must not take
down the data plane).
"""

from __future__ import annotations

import threading
from collections import defaultdict, deque
from typing import Any, Callable, Dict, List, Tuple


class MessageBus:
    def __init__(self, retention: int = 4096):
        self._lock = threading.RLock()
        self._retention = retention
        self._log: Dict[str, deque] = defaultdict(   # guarded-by: _lock
            lambda: deque(maxlen=retention))
        self._offsets: Dict[str, int] = defaultdict(int)  # guarded-by: _lock
        self._subs: Dict[str, List[Callable[[Any], None]]] = \
            defaultdict(list)                        # guarded-by: _lock
        self._cursors: Dict[Tuple[str, str], int] = {}  # guarded-by: _lock
        self.errors: List[Tuple[str, Exception]] = []   # guarded-by: _lock

    # -- producer side ---------------------------------------------------
    def publish(self, topic: str, message: Any) -> None:
        with self._lock:
            self._log[topic].append(message)
            self._offsets[topic] += 1
            subs = list(self._subs[topic])
        for fn in subs:
            try:
                fn(message)
            except Exception as exc:  # monitoring must not crash data plane
                with self._lock:
                    self.errors.append((topic, exc))

    # -- push consumers ----------------------------------------------------
    def subscribe(self, topic: str, fn: Callable[[Any], None]) -> Callable[[], None]:
        """Register a callback; returns an unsubscribe handle."""
        with self._lock:
            self._subs[topic].append(fn)

        def unsubscribe() -> None:
            with self._lock:
                try:
                    self._subs[topic].remove(fn)
                except ValueError:
                    pass
        return unsubscribe

    # -- pull consumers ----------------------------------------------------
    def poll(self, topic: str, group: str = "default", max_items: int = 256) -> List[Any]:
        """Return messages this consumer group has not seen yet."""
        with self._lock:
            log = self._log[topic]
            total = self._offsets[topic]
            first_retained = total - len(log)
            cursor = self._cursors.get((topic, group), 0)
            cursor = max(cursor, first_retained)
            start = cursor - first_retained
            out = list(log)[start:start + max_items]
            self._cursors[(topic, group)] = cursor + len(out)
            return out

    def depth(self, topic: str) -> int:
        with self._lock:
            return len(self._log[topic])
