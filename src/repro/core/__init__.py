"""DynIMS core: the paper's contribution as a composable library.

Layout mirrors the paper's four building blocks (Fig. 3) plus the
storage actuation they drive and the simulator that reproduces the
evaluation:

* :mod:`.monitor`    -- monitoring agents (collectd analogue)
* :mod:`.bus`        -- messaging bus (Kafka analogue)
* :mod:`.stream`     -- stream aggregation (Flink analogue)
* :mod:`.control`    -- the Eq. 1 feedback law + stability analysis
* :mod:`.controller` -- the memory controller service (Vert.x analogue)
* :mod:`.plane`      -- **MemoryPlane**, the declarative control-plane
  API every consumer builds on (PlaneSpec -> MemoryPlane facade)
* :mod:`.eviction`   -- LFU/LRU/FIFO/adaptive eviction policies
* :mod:`.store`      -- managed stores: ShardCache, KVBlockPool
* :mod:`.traces`     -- HPCC/HPL workload models (paper Figs 1-2)
* :mod:`.cluster_sim`-- discrete-event reproduction of Sec. IV

The control plane has two interchangeable backends behind one facade:
the scalar reference controller (:class:`DynIMSController`, float64
per-node Eq. 1, paper-faithful) and the batched
:class:`ArrayController` (all nodes packed into arrays, one fused
jitted ``vectorized_step`` per interval -- the 1000+-node path).
Consumers pick via ``PlaneSpec(backend=...)``; a parity test pins the
backends together.  The legacy imperative :class:`ControlPlane` remains
as a deprecation shim over the scalar backend.
"""

from .bus import MessageBus
from .control import (ControllerParams, Signal, closed_loop_eigenvalue,
                      control_step, fixed_point_capacity, is_stable,
                      settling_time, simulate_saturated_loop,
                      vectorized_step)
from .controller import (ActionHistory, CONTROL_TOPIC, ControlAction,
                         DynIMSController)
from .eviction import (AdaptivePolicy, FIFOPolicy, LFUPolicy, LRUPolicy,
                       make_policy)
from .monitor import (DeviceMemoryMonitor, HostMemoryMonitor, MemorySample,
                      MonitorFault, SimulatedMonitor)
from .plane import (ArrayController, CapturedTrace, ControlPlane,
                    DEFAULT_TRACE_CAPACITY, FaultEvent, FaultLog,
                    HealthPolicy, HealthReport, MemoryPlane, NodeHealth,
                    NodeHealthInfo, NodeSpec, PlaneSpec, StoreSpec,
                    TraceRecorder, make_fused_step, validate_sample)
from .store import (EvictionReport, KVBlockPool, ManagedStore, ShardCache,
                    StoreRegistry, StoreStats)
from .stream import AGG_TOPIC, RAW_TOPIC, AggregatedMetrics, MetricAggregator
from .traces import (GiB, IterativeAppSpec, Phase, TierSpec, hpcc_trace,
                     hpl_slowdown)

__all__ = [
    "AGG_TOPIC", "ActionHistory", "AdaptivePolicy", "AggregatedMetrics",
    "ArrayController", "CONTROL_TOPIC", "CapturedTrace", "ControlAction",
    "ControlPlane", "ControllerParams", "DEFAULT_TRACE_CAPACITY",
    "DeviceMemoryMonitor", "DynIMSController", "TraceRecorder",
    "EvictionReport", "FIFOPolicy", "FaultEvent", "FaultLog", "GiB",
    "HealthPolicy", "HealthReport", "HostMemoryMonitor",
    "IterativeAppSpec", "KVBlockPool", "LFUPolicy", "LRUPolicy",
    "ManagedStore", "MemoryPlane", "MemorySample", "MessageBus",
    "MetricAggregator", "MonitorFault", "NodeHealth", "NodeHealthInfo",
    "NodeSpec", "Phase", "PlaneSpec", "RAW_TOPIC",
    "ShardCache", "Signal", "SimulatedMonitor", "StoreRegistry",
    "StoreSpec", "StoreStats", "TierSpec", "closed_loop_eigenvalue",
    "control_step", "fixed_point_capacity", "hpcc_trace", "hpl_slowdown",
    "is_stable", "make_fused_step", "make_policy", "settling_time",
    "simulate_saturated_loop", "validate_sample", "vectorized_step",
]
