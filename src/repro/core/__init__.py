"""DynIMS core: the paper's contribution as a composable library.

Layout mirrors the paper's four building blocks (Fig. 3) plus the
storage actuation they drive and the simulator that reproduces the
evaluation:

* :mod:`.monitor`    -- monitoring agents (collectd analogue)
* :mod:`.bus`        -- messaging bus (Kafka analogue)
* :mod:`.stream`     -- stream aggregation (Flink analogue)
* :mod:`.control`    -- the Eq. 1 feedback law + stability analysis
* :mod:`.controller` -- the memory controller service (Vert.x analogue)
* :mod:`.eviction`   -- LFU/LRU/FIFO/adaptive eviction policies
* :mod:`.store`      -- managed stores: ShardCache, KVBlockPool
* :mod:`.traces`     -- HPCC/HPL workload models (paper Figs 1-2)
* :mod:`.cluster_sim`-- discrete-event reproduction of Sec. IV
"""

from .bus import MessageBus
from .control import (ControllerParams, closed_loop_eigenvalue, control_step,
                      fixed_point_capacity, is_stable, settling_time,
                      simulate_saturated_loop, vectorized_step)
from .controller import (CONTROL_TOPIC, ControlAction, ControlPlane,
                         DynIMSController)
from .eviction import (AdaptivePolicy, FIFOPolicy, LFUPolicy, LRUPolicy,
                       make_policy)
from .monitor import (DeviceMemoryMonitor, HostMemoryMonitor, MemorySample,
                      SimulatedMonitor)
from .store import (EvictionReport, KVBlockPool, ManagedStore, ShardCache,
                    StoreRegistry, StoreStats)
from .stream import AGG_TOPIC, RAW_TOPIC, AggregatedMetrics, MetricAggregator
from .traces import (GiB, IterativeAppSpec, Phase, TierSpec, hpcc_trace,
                     hpl_slowdown)

__all__ = [
    "AGG_TOPIC", "AdaptivePolicy", "AggregatedMetrics", "CONTROL_TOPIC",
    "ControlAction", "ControlPlane", "ControllerParams",
    "DeviceMemoryMonitor", "DynIMSController", "EvictionReport",
    "FIFOPolicy", "GiB", "HostMemoryMonitor", "IterativeAppSpec",
    "KVBlockPool", "LFUPolicy", "LRUPolicy", "ManagedStore", "MemorySample",
    "MessageBus", "MetricAggregator", "Phase", "RAW_TOPIC", "ShardCache",
    "SimulatedMonitor", "StoreRegistry", "StoreStats", "TierSpec",
    "closed_loop_eigenvalue", "control_step", "fixed_point_capacity",
    "hpcc_trace", "hpl_slowdown", "is_stable", "make_policy",
    "settling_time", "simulate_saturated_loop", "vectorized_step",
]
