"""Memory monitoring agents (the paper's collectd analogue).

Each agent samples one node's memory state and emits a ``MemorySample``;
``to_json``/``from_json`` mirror the paper's JSON-over-Kafka metric
encoding so samples can travel the :mod:`repro.core.bus` unchanged.

Three agents:

* :class:`HostMemoryMonitor` -- the real thing, reads ``/proc/meminfo``
  (psutil fallback).  On a TPU worker this is the host-RAM view that
  governs the dataset shard cache.
* :class:`DeviceMemoryMonitor` -- per-accelerator HBM view via
  ``device.memory_stats()`` (present on TPU/GPU backends; returns None
  fields on CPU).  Governs the serving KV-block pool.
* :class:`SimulatedMonitor` -- trace- or callback-driven, used by the
  cluster simulator and by every deterministic test.
"""

from __future__ import annotations

import json
import time
import zlib
from dataclasses import dataclass, asdict
from typing import Callable, Iterator, Mapping, Optional, Protocol, Sequence


class MonitorFault(RuntimeError):
    """A monitor failed to produce a sample (dropout / crash / timeout).

    The health layer in :mod:`repro.core.plane` catches this (and any
    other exception from ``sample()``) and degrades to the last-good
    holdover instead of letting one dead sensor take the interval down.
    ``repro.runtime.chaos`` raises it from injected fault proxies.
    """


@dataclass(frozen=True)
class MemorySample:
    """One observation of a node's memory state (bytes)."""

    node: str
    timestamp: float
    used: float           # v_i: total used incl. in-memory storage
    total: float          # M
    storage_used: float = 0.0   # portion attributable to managed stores
    swap_used: float = 0.0

    @property
    def utilization(self) -> float:
        return self.used / self.total if self.total else 0.0

    @property
    def compute_used(self) -> float:
        """Usage attributable to the priority (compute) tenant."""
        return max(self.used - self.storage_used, 0.0)

    def to_json(self) -> str:
        return json.dumps(asdict(self))

    @staticmethod
    def from_json(payload: str) -> "MemorySample":
        return MemorySample(**json.loads(payload))


class MemoryMonitor(Protocol):
    def sample(self) -> MemorySample: ...


def _read_proc_meminfo() -> Optional[dict]:
    try:
        with open("/proc/meminfo") as fh:
            fields = {}
            for line in fh:
                key, _, rest = line.partition(":")
                fields[key.strip()] = int(rest.strip().split()[0]) * 1024
            return fields
    except (OSError, ValueError, IndexError):
        return None


class HostMemoryMonitor:
    """Samples host RAM from /proc/meminfo (psutil fallback)."""

    def __init__(self, node: str = "localhost",
                 storage_used_fn: Optional[Callable[[], float]] = None):
        self.node = node
        self._storage_used_fn = storage_used_fn or (lambda: 0.0)

    def sample(self) -> MemorySample:
        info = _read_proc_meminfo()
        if info is not None:
            total = float(info["MemTotal"])
            avail = float(info.get("MemAvailable", info.get("MemFree", 0)))
            swap = float(info.get("SwapTotal", 0) - info.get("SwapFree", 0))
            used = total - avail
        else:  # pragma: no cover - psutil fallback path
            import psutil
            vm = psutil.virtual_memory()
            total, used = float(vm.total), float(vm.total - vm.available)
            swap = float(psutil.swap_memory().used)
        return MemorySample(
            node=self.node, timestamp=time.time(), used=used, total=total,
            storage_used=float(self._storage_used_fn()), swap_used=swap,
        )


class DeviceMemoryMonitor:
    """Samples one accelerator's HBM via ``device.memory_stats()``.

    On CPU backends memory_stats() is unavailable; ``total`` falls back to
    the configured ``assumed_total`` so control logic stays exercisable.
    """

    def __init__(self, device, node: Optional[str] = None,
                 assumed_total: float = 16 * 2**30,
                 storage_used_fn: Optional[Callable[[], float]] = None):
        self.device = device
        self.node = node or f"{device.platform}:{device.id}"
        self.assumed_total = assumed_total
        self._storage_used_fn = storage_used_fn or (lambda: 0.0)

    def sample(self) -> MemorySample:
        stats = {}
        try:
            stats = self.device.memory_stats() or {}
        except Exception:
            stats = {}
        total = float(stats.get("bytes_limit", self.assumed_total))
        used = float(stats.get("bytes_in_use", 0.0))
        return MemorySample(
            node=self.node, timestamp=time.time(), used=used, total=total,
            storage_used=float(self._storage_used_fn()),
        )


#: Fault modes a SimulatedMonitor can deterministically inject.
SIM_FAULT_KINDS = ("dropout", "freeze", "nan")


class SimulatedMonitor:
    """Trace- or callback-driven monitor for simulation and tests.

    ``faults`` turns on deterministic fault injection: a mapping from
    fault kind (``"dropout"`` raises :class:`MonitorFault`,
    ``"freeze"`` re-delivers the previous sample verbatim, ``"nan"``
    corrupts ``used``) to a per-tick probability.  Whether tick ``i``
    faults -- and which kind fires -- is a pure function of
    ``(fault_seed, node, i)``, so chaos tests replay bit-identically
    with no wall-clock timing involved.
    """

    def __init__(
        self,
        node: str,
        total: float,
        usage: Sequence[float] | Callable[[int], float],
        storage_used_fn: Optional[Callable[[], float]] = None,
        dt: float = 0.1,
        faults: Optional[Mapping[str, float]] = None,
        fault_seed: int = 0,
    ):
        self.node = node
        self.total = float(total)
        self._usage = usage
        self._storage_used_fn = storage_used_fn or (lambda: 0.0)
        self._dt = dt
        self._i = 0
        if faults:
            unknown = set(faults) - set(SIM_FAULT_KINDS)
            if unknown:
                raise ValueError(
                    f"unknown fault kinds {sorted(unknown)}; "
                    f"choose from {SIM_FAULT_KINDS}")
        self._faults = dict(faults or {})
        self._fault_seed = int(fault_seed)
        self._last: Optional[MemorySample] = None

    def _fault_at(self, i: int) -> Optional[str]:
        """Which fault (if any) fires at tick ``i`` -- pure, seeded."""
        if not self._faults:
            return None
        import numpy as np
        rng = np.random.default_rng(
            [self._fault_seed, zlib.crc32(self.node.encode()), i])
        for kind in SIM_FAULT_KINDS:          # fixed order: deterministic
            p = self._faults.get(kind, 0.0)
            if p > 0.0 and rng.random() < p:
                return kind
        return None

    def sample(self) -> MemorySample:
        i = self._i
        self._i += 1
        if callable(self._usage):
            used = float(self._usage(i))
        else:
            used = float(self._usage[min(i, len(self._usage) - 1)])
        s = MemorySample(
            node=self.node, timestamp=i * self._dt,
            used=used + self._storage_used_fn(),
            total=self.total, storage_used=float(self._storage_used_fn()),
            swap_used=max(0.0, used + self._storage_used_fn() - self.total),
        )
        kind = self._fault_at(i)
        if kind == "dropout":
            raise MonitorFault(f"{self.node}: simulated dropout at tick {i}")
        if kind == "freeze" and self._last is not None:
            return self._last                  # stuck sensor: stale repeat
        if kind == "nan":
            s = MemorySample(
                node=s.node, timestamp=s.timestamp, used=float("nan"),
                total=s.total, storage_used=s.storage_used,
                swap_used=s.swap_used)
            return s                           # corrupt: not cached as good
        self._last = s
        return s

    def __iter__(self) -> Iterator[MemorySample]:
        while True:
            yield self.sample()
