"""The DynIMS controller (the paper's Vert.x component).

Event-driven: subscribes to aggregated metrics on the bus, runs the
control law per node, and actuates the node's registered stores through
a :class:`~repro.core.store.StoreRegistry`.  Also usable synchronously
(``step``) by the trainer/serving loop and the cluster simulator.

The paper's controller is a separate service receiving Kafka messages;
ours runs in-process per host (sub-ms actuation) but keeps the same
observe -> aggregate -> decide -> actuate pipeline and message schema, so
a multi-host deployment only swaps the bus transport.
"""

from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional

from .bus import MessageBus
from .control import ControllerParams, control_step
from .monitor import MemoryMonitor, MemorySample
from .store import EvictionReport, StoreRegistry
from .stream import AGG_TOPIC, RAW_TOPIC, AggregatedMetrics, MetricAggregator

CONTROL_TOPIC = "control.actions"


@dataclass
class ControlAction:
    """One capacity decision, published to the bus for observability."""

    node: str
    timestamp: float
    u_prev: float
    u_next: float
    utilization: float
    reports: List[EvictionReport] = field(default_factory=list)

    @property
    def delta(self) -> float:
        return self.u_next - self.u_prev


@dataclass
class _NodeState:
    registry: StoreRegistry
    u: float
    v_prev: Optional[float] = None


class DynIMSController:
    """Per-node feedback control of registered in-memory stores."""

    def __init__(
        self,
        params: ControllerParams,
        bus: Optional[MessageBus] = None,
        signal: str = "latest",          # latest|ewma|max -- which aggregate drives Eq.1
    ) -> None:
        if signal not in ("latest", "ewma", "max"):
            raise ValueError("signal must be latest|ewma|max")
        self.params = params
        self.signal = signal
        self._nodes: Dict[str, _NodeState] = {}
        self._bus = bus
        self._lock = threading.RLock()
        self.actions: List[ControlAction] = []
        if bus is not None:
            bus.subscribe(AGG_TOPIC, self._on_agg)

    # -- wiring -------------------------------------------------------------
    def attach_node(self, node: str, registry: StoreRegistry,
                    u0: Optional[float] = None) -> None:
        with self._lock:
            u = registry.total_capacity() if u0 is None else float(u0)
            self._nodes[node] = _NodeState(registry=registry, u=u)

    def node_capacity(self, node: str) -> float:
        with self._lock:
            return self._nodes[node].u

    # -- control ------------------------------------------------------------
    def _on_agg(self, agg: AggregatedMetrics) -> None:
        self.step(agg)

    def step(self, agg: AggregatedMetrics) -> Optional[ControlAction]:
        """Run Eq. 1 for one node from one aggregated observation."""
        with self._lock:
            state = self._nodes.get(agg.node)
            if state is None:
                return None
            v = {
                "latest": agg.used_latest,
                "ewma": agg.used_ewma,
                "max": agg.used_max,
            }[self.signal]
            params = self.params
            if params.total_memory != agg.total and agg.total > 0:
                params = params.replace(total_memory=agg.total)
            u_next = control_step(state.u, v, params, v_prev=state.v_prev)
            reports = state.registry.apply_capacity(u_next)
            action = ControlAction(
                node=agg.node, timestamp=agg.timestamp, u_prev=state.u,
                u_next=u_next, utilization=v / agg.total if agg.total else 0.0,
                reports=reports)
            state.u = u_next
            state.v_prev = v
            self.actions.append(action)
        if self._bus is not None:
            self._bus.publish(CONTROL_TOPIC, action)
        return action


class ControlPlane:
    """Full monitoring/control pipeline for a set of local nodes.

    Wires monitor -> bus(RAW) -> aggregator -> bus(AGG) -> controller for
    every attached node and drives them from one ``tick`` (the control
    interval T).  ``run`` ticks in real time; ``tick`` is used by tests,
    the simulator, and the trainer (which ticks from its step loop).
    """

    def __init__(
        self,
        params: ControllerParams,
        window: int = 8,
        ewma_alpha: float = 0.5,
        signal: str = "latest",
    ) -> None:
        self.bus = MessageBus()
        self.aggregator = MetricAggregator(window=window,
                                           ewma_alpha=ewma_alpha, bus=self.bus)
        self.controller = DynIMSController(params, bus=self.bus, signal=signal)
        self._monitors: Dict[str, MemoryMonitor] = {}
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None

    def attach(self, node: str, monitor: MemoryMonitor,
               registry: StoreRegistry, u0: Optional[float] = None) -> None:
        self._monitors[node] = monitor
        self.controller.attach_node(node, registry, u0=u0)

    def tick(self) -> List[ControlAction]:
        """One control interval: sample every node, let control fire."""
        n_before = len(self.controller.actions)
        for monitor in self._monitors.values():
            self.bus.publish(RAW_TOPIC, monitor.sample())
        return self.controller.actions[n_before:]

    # -- real-time loop -------------------------------------------------------
    def run(self, duration_s: Optional[float] = None) -> None:
        deadline = None if duration_s is None else time.time() + duration_s
        while not self._stop.is_set():
            t0 = time.time()
            self.tick()
            if deadline is not None and time.time() >= deadline:
                break
            sleep = self.controller.params.interval_s - (time.time() - t0)
            if sleep > 0:
                self._stop.wait(sleep)

    def start(self) -> None:
        self._stop.clear()
        self._thread = threading.Thread(target=self.run, daemon=True)
        self._thread.start()

    def stop(self) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=5.0)
            self._thread = None
