"""The DynIMS memory-controller service (the paper's Vert.x component).

Event-driven: subscribes to aggregated metrics on the bus, runs the
control law, and actuates each node's registered stores through a
:class:`~repro.core.store.StoreRegistry`.  Also usable synchronously by
the trainer/serving loop and the cluster simulator.

Two backends implement the same observe -> decide -> actuate contract
(see :mod:`repro.core.plane` for the facade that wires them):

* :class:`DynIMSController` -- the scalar *reference* backend.  Steps
  each node's Eq. 1 in Python the moment its aggregate arrives, exactly
  as the paper's per-node controller would.  Authoritative for
  semantics; the parity test pins the batched backend to it.
* :class:`~repro.core.plane.ArrayController` -- the *batched* backend.
  Packs all attached nodes' ``(u, v, v_prev, M, u_min, u_max)`` into
  arrays and runs one fused, jitted ``vectorized_step`` per control
  interval, the shape a 1000+-node central controller needs.

Both keep a bounded, thread-safe :class:`ActionHistory` instead of an
unbounded action list -- the memory controller must not itself grow
without bound.

The paper's controller is a separate service receiving Kafka messages;
ours runs in-process per host (sub-ms actuation) but keeps the same
observe -> aggregate -> decide -> actuate pipeline and message schema, so
a multi-host deployment only swaps the bus transport.
"""

from __future__ import annotations

import threading
from collections import deque
from dataclasses import dataclass, field
from typing import Dict, List, Optional

from .bus import MessageBus
from .control import ControllerParams, Signal, control_step
from .store import EvictionReport, StoreRegistry
from .stream import AGG_TOPIC, AggregatedMetrics

CONTROL_TOPIC = "control.actions"

#: Default bound on retained control actions (per controller).
DEFAULT_HISTORY = 1024


@dataclass
class ControlAction:
    """One capacity decision, published to the bus for observability.

    ``epoch`` counts the controller's parameter generations: 0 until the
    first :meth:`~DynIMSController.swap_params`, then incremented by
    every hot-swap.  Actions from one control interval always share one
    epoch (swaps land at interval boundaries), so a reader can verify a
    swap dropped or duplicated no interval by checking the history is
    epoch-monotone with no gaps per node.
    """

    node: str
    timestamp: float
    u_prev: float
    u_next: float
    utilization: float
    reports: List[EvictionReport] = field(default_factory=list)
    epoch: int = 0

    @property
    def delta(self) -> float:
        return self.u_next - self.u_prev


class ActionHistory:
    """Bounded, thread-safe log of control actions.

    Keeps the last ``maxlen`` actions for observability.  With
    ``track_fresh=True`` it additionally buffers every action since the
    last :meth:`drain` so a driver (``MemoryPlane.tick``) can return a
    complete interval even when the fleet is larger than ``maxlen``;
    the buffer is a plain list emptied on each drain, so only a driver
    that actually drains should enable it (a standalone event-driven
    controller would otherwise grow it without bound).
    """

    def __init__(self, maxlen: int = DEFAULT_HISTORY,
                 track_fresh: bool = False):
        if maxlen < 1:
            raise ValueError("history bound must be >= 1")
        self.maxlen = maxlen
        self._lock = threading.Lock()
        self._log: deque = deque(maxlen=maxlen)     # guarded-by: _lock
        self._track_fresh = track_fresh
        self._fresh: List[ControlAction] = []       # guarded-by: _lock

    def append(self, action: ControlAction) -> None:
        with self._lock:
            self._log.append(action)
            if self._track_fresh:
                self._fresh.append(action)

    def snapshot(self, node: Optional[str] = None,
                 limit: Optional[int] = None) -> List[ControlAction]:
        with self._lock:
            out = list(self._log)
        if node is not None:
            out = [a for a in out if a.node == node]
        if limit is not None:
            out = out[-limit:]
        return out

    def drain(self) -> List[ControlAction]:
        """All actions appended since the last drain (requires
        ``track_fresh``; empty otherwise)."""
        with self._lock:
            out, self._fresh = self._fresh, []
        return out

    def __len__(self) -> int:
        with self._lock:
            return len(self._log)


@dataclass
class _NodeState:
    registry: StoreRegistry
    u: float
    v_prev: Optional[float] = None
    params: Optional[ControllerParams] = None   # per-node override


class DynIMSController:
    """Per-node feedback control of registered in-memory stores.

    The scalar reference backend: one float64 Python ``control_step``
    per node per observation, exactly the paper's per-node law.
    """

    def __init__(
        self,
        params: ControllerParams,
        bus: Optional[MessageBus] = None,
        signal: Signal | str = Signal.LATEST,
        max_history: int = DEFAULT_HISTORY,
        track_fresh: bool = False,
    ) -> None:
        self.params = params                        # guarded-by: _lock
        self.signal = Signal.coerce(signal)
        self._nodes: Dict[str, _NodeState] = {}     # guarded-by: _lock
        self._bus = bus
        self._lock = threading.RLock()
        self._epoch = 0                             # guarded-by: _lock
        self._history = ActionHistory(max_history, track_fresh=track_fresh)
        if bus is not None:
            bus.subscribe(AGG_TOPIC, self._on_agg)

    # -- wiring -------------------------------------------------------------
    def attach_node(self, node: str, registry: StoreRegistry,
                    u0: Optional[float] = None,
                    params: Optional[ControllerParams] = None) -> None:
        """Register one node.  ``params`` overrides the plane-level law
        parameters for this node (heterogeneous M / u_min / u_max)."""
        with self._lock:
            u = registry.total_capacity() if u0 is None else float(u0)
            self._nodes[node] = _NodeState(registry=registry, u=u,
                                           params=params)

    def node_capacity(self, node: str) -> float:
        with self._lock:
            return self._nodes[node].u

    def nodes(self) -> List[str]:
        with self._lock:
            return list(self._nodes)

    # -- online re-parameterization -----------------------------------------
    @property
    def epoch(self) -> int:
        """Parameter generation: 0 at construction, +1 per swap."""
        with self._lock:
            return self._epoch

    def swap_params(self, params: ControllerParams) -> int:
        """Atomically replace the plane-level law parameters.

        Control state (``u``, ``v_prev``) carries over -- the new law
        continues the old trajectory from the next observation, so no
        interval is dropped or replayed.  Nodes with a per-node
        ``params`` override keep it (their operator pinned it
        deliberately).  Returns the new parameter epoch, which every
        subsequent :class:`ControlAction` is stamped with.
        """
        with self._lock:
            self.params = params
            self._epoch += 1
            return self._epoch

    # -- bounded action history ---------------------------------------------
    @property
    def actions(self) -> List[ControlAction]:
        """Snapshot of the bounded action history (thread-safe)."""
        return self._history.snapshot()

    def recent(self, n: Optional[int] = None,
               node: Optional[str] = None) -> List[ControlAction]:
        return self._history.snapshot(node=node, limit=n)

    # -- control ------------------------------------------------------------
    def _on_agg(self, agg: AggregatedMetrics) -> None:
        self.step(agg)

    def observe(self, agg: AggregatedMetrics) -> None:
        """Backend interface: the scalar backend acts immediately."""
        self.step(agg)

    def flush(self) -> List[ControlAction]:
        """Backend interface: actions produced since the last flush.

        Complete only when constructed with ``track_fresh=True`` (as
        :class:`~repro.core.plane.MemoryPlane` does)."""
        return self._history.drain()

    def step(self, agg: AggregatedMetrics) -> Optional[ControlAction]:
        """Run Eq. 1 for one node from one aggregated observation."""
        with self._lock:
            state = self._nodes.get(agg.node)
            if state is None:
                return None
            v = self.signal.pick(agg)
            params = state.params or self.params
            if params.total_memory != agg.total and agg.total > 0:
                params = params.replace(total_memory=agg.total)
            u_next = control_step(state.u, v, params, v_prev=state.v_prev)
            reports = state.registry.apply_capacity(u_next)
            action = ControlAction(
                node=agg.node, timestamp=agg.timestamp, u_prev=state.u,
                u_next=u_next, utilization=v / agg.total if agg.total else 0.0,
                reports=reports, epoch=self._epoch)
            state.u = u_next
            state.v_prev = v
            self._history.append(action)
        if self._bus is not None:
            self._bus.publish(CONTROL_TOPIC, action)
        return action

    def reset_node(self, node: str, u: float) -> bool:
        """Re-seed one node's control state at capacity ``u``.

        The quarantine-rejoin hook (see ``MemoryPlane.health``): the
        law resumes from the fail-static grant with slope history
        cleared instead of jumping back to the pre-quarantine state."""
        with self._lock:
            state = self._nodes.get(node)
            if state is None:
                return False
            state.u = float(u)
            state.v_prev = None
            return True

    def squeeze(self, node: str, factor: float) -> bool:
        """Transiently clamp a node's stores to ``factor * u`` without
        moving the control state -- the controller re-grants on the next
        interval once pressure clears (straggler mitigation hook)."""
        with self._lock:
            state = self._nodes.get(node)
            if state is None:
                return False
            state.registry.apply_capacity(state.u * float(factor))
            return True


def __getattr__(name: str):
    # Legacy import path: the ControlPlane shim now lives in plane.py
    # (importing it here eagerly would be circular).
    if name == "ControlPlane":
        from .plane import ControlPlane
        return ControlPlane
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
