"""Streaming metric aggregation (the paper's Flink analogue).

Consumes raw :class:`~repro.core.monitor.MemorySample` messages from the
bus topic ``metrics``, maintains a per-node sliding window, and publishes
an :class:`AggregatedMetrics` record to topic ``metrics.agg`` for the
controller.  The paper's stream job computes "the optimized in-memory
storage space for each node online"; here the aggregation (smoothing,
slope) is separated from the control law so either can be swapped.

Aggregations per node over a window of the last ``window`` samples:
latest / mean / max / EWMA (alpha) / slope (d usage / d interval, by
least-squares over the window) -- the slope feeds the beyond-paper
feedforward term of the control law.
"""

from __future__ import annotations

from collections import defaultdict, deque
from dataclasses import dataclass
from typing import Deque, Dict, Optional

import numpy as np

from .bus import MessageBus
from .monitor import MemorySample

RAW_TOPIC = "metrics"
AGG_TOPIC = "metrics.agg"


@dataclass(frozen=True)
class AggregatedMetrics:
    node: str
    timestamp: float
    total: float
    used_latest: float
    used_ewma: float
    used_mean: float
    used_max: float
    slope_per_interval: float     # least-squares d(used)/d(sample)
    storage_used: float
    swap_used: float
    n_samples: int

    @property
    def utilization(self) -> float:
        return self.used_latest / self.total if self.total else 0.0


class MetricAggregator:
    """Per-node sliding-window aggregation; bus-attached or standalone."""

    def __init__(self, window: int = 8, ewma_alpha: float = 0.5,
                 bus: Optional[MessageBus] = None):
        if window < 1:
            raise ValueError("window must be >= 1")
        self.window = window
        self.alpha = ewma_alpha
        self._samples: Dict[str, Deque[MemorySample]] = defaultdict(
            lambda: deque(maxlen=window))
        self._ewma: Dict[str, float] = {}
        self._bus = bus
        if bus is not None:
            bus.subscribe(RAW_TOPIC, self._on_message)

    def _on_message(self, msg) -> None:
        sample = msg if isinstance(msg, MemorySample) else MemorySample.from_json(msg)
        agg = self.update(sample)
        if self._bus is not None:
            self._bus.publish(AGG_TOPIC, agg)

    def update(self, sample: MemorySample) -> AggregatedMetrics:
        q = self._samples[sample.node]
        q.append(sample)
        prev = self._ewma.get(sample.node, sample.used)
        ewma = self.alpha * sample.used + (1 - self.alpha) * prev
        self._ewma[sample.node] = ewma

        used = np.array([s.used for s in q], dtype=np.float64)
        if len(used) >= 2:
            x = np.arange(len(used), dtype=np.float64)
            slope = float(np.polyfit(x, used, 1)[0])
        else:
            slope = 0.0
        return AggregatedMetrics(
            node=sample.node,
            timestamp=sample.timestamp,
            total=sample.total,
            used_latest=sample.used,
            used_ewma=float(ewma),
            used_mean=float(used.mean()),
            used_max=float(used.max()),
            slope_per_interval=slope,
            storage_used=sample.storage_used,
            swap_used=sample.swap_used,
            n_samples=len(used),
        )

    def latest(self, node: str) -> Optional[MemorySample]:
        q = self._samples.get(node)
        return q[-1] if q else None
