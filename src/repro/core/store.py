"""Managed in-memory stores (the paper's Alluxio analogue).

A :class:`ManagedStore` is any memory consumer whose capacity DynIMS may
resize at runtime.  The paper controls one Alluxio worker per node via an
RPC "free space" interface; here the actuation is an in-process call that
triggers immediate eviction, so the full control cycle (observe -> decide
-> actuate) completes well inside the paper's 100 ms interval.

Two concrete stores:

* :class:`ShardCache` -- byte-addressed object cache keyed by shard id,
  used by the data pipeline to keep hot dataset shards in host RAM
  (paper's Alluxio-over-OrangeFS role).  Pluggable eviction policy
  (paper uses LFU).
* :class:`KVBlockPool` -- block-granular allocator bookkeeping for a
  paged serving KV cache.  Capacity changes translate to a usable-block
  budget; shrinking preempts whole sequences (coarsest-first) so the
  serving engine can requeue them.

Both stores report `used()`/`capacity()` so a :class:`HostMemoryMonitor`
can attribute usage to the storage tenant, closing the feedback loop.
"""

from __future__ import annotations

import threading
from dataclasses import dataclass, field
from typing import Callable, Dict, Hashable, List, Optional, Protocol, Tuple

from .eviction import EvictionPolicy, make_policy

Key = Hashable


@dataclass
class EvictionReport:
    """What a capacity change did (returned by ``set_capacity``)."""

    store: str
    requested_capacity: float
    applied_capacity: float
    evicted_keys: List[Key] = field(default_factory=list)
    evicted_bytes: float = 0.0


@dataclass
class StoreStats:
    hits: int = 0
    misses: int = 0
    insertions: int = 0
    evictions: int = 0
    rejected: int = 0              # inserts too large for current capacity
    bytes_evicted: float = 0.0
    bytes_read_remote: float = 0.0

    @property
    def hit_ratio(self) -> float:
        n = self.hits + self.misses
        return self.hits / n if n else 0.0


class ManagedStore(Protocol):
    """Anything DynIMS can resize."""

    name: str
    priority: int                  # higher = keep memory longer

    def capacity(self) -> float: ...
    def used(self) -> float: ...
    def set_capacity(self, capacity: float) -> EvictionReport: ...


class ShardCache:
    """In-memory object cache with controller-adjustable capacity.

    Thread-safe.  ``get`` takes an optional ``loader`` so a miss can be
    transparently filled from the backing tier (OrangeFS in the paper,
    the on-disk shard store here); loader bytes are accounted in
    ``stats.bytes_read_remote`` -- the quantity the paper's Fig. 5
    hit-ratio argument is about.
    """

    def __init__(
        self,
        name: str = "shard-cache",
        capacity: float = 0.0,
        policy: str | EvictionPolicy = "lfu",
        priority: int = 0,
        sizeof: Callable[[object], float] = None,
        admission: bool = False,
    ) -> None:
        self.name = name
        self.priority = priority
        self._capacity = float(capacity)
        self._policy = make_policy(policy) if isinstance(policy, str) else policy
        self._data: Dict[Key, object] = {}
        self._sizes: Dict[Key, float] = {}
        self._used = 0.0
        self._sizeof = sizeof or _default_sizeof
        self._lock = threading.RLock()
        self.stats = StoreStats()
        # TinyLFU-style admission: a global access-frequency doorkeeper.
        # On a full cache a newcomer is admitted only if it has been seen
        # strictly more often than the eviction victim.  This is what
        # keeps a cyclic scan (the paper's iterative Spark apps) from
        # thrashing LFU and is how the static-Alluxio configuration
        # sustains a stable ~cache/partition hit ratio (Sec. IV.B).
        self._admission = admission
        self._seen: Dict[Key, int] = {}

    # -- ManagedStore interface -------------------------------------------
    def capacity(self) -> float:
        return self._capacity

    def used(self) -> float:
        return self._used

    def set_capacity(self, capacity: float) -> EvictionReport:
        """Resize; evict (policy order) until usage fits the new budget."""
        with self._lock:
            capacity = max(float(capacity), 0.0)
            report = EvictionReport(
                store=self.name, requested_capacity=capacity,
                applied_capacity=capacity)
            self._capacity = capacity
            self._evict_to(capacity, report)
            return report

    # -- cache interface ---------------------------------------------------
    def get(self, key: Key, loader: Optional[Callable[[], object]] = None):
        with self._lock:
            if self._admission:
                self._seen[key] = self._seen.get(key, 0) + 1
            if key in self._data:
                self.stats.hits += 1
                self._policy.on_access(key)
                return self._data[key]
            self.stats.misses += 1
        if loader is None:
            return None
        value = loader()
        self.stats.bytes_read_remote += self._sizeof(value)
        self.put(key, value)
        return value

    def put(self, key: Key, value: object) -> bool:
        """Insert; returns False if the object cannot fit at all."""
        size = self._sizeof(value)
        with self._lock:
            if key in self._data:
                self._used -= self._sizes[key]
                self._policy.remove(key)
            if size > self._capacity:
                self.stats.rejected += 1
                self._data.pop(key, None)
                self._sizes.pop(key, None)
                return False
            if self._admission and self._used + size > self._capacity:
                victim = self._policy.victim()
                if victim is not None and (
                        self._seen.get(key, 0) <= self._seen.get(victim, 0)):
                    self.stats.rejected += 1
                    return False
            report = EvictionReport(self.name, self._capacity, self._capacity)
            self._evict_to(self._capacity - size, report)
            self._data[key] = value
            self._sizes[key] = size
            self._used += size
            self._policy.on_insert(key)
            self.stats.insertions += 1
            return True

    def drop(self, key: Key) -> None:
        with self._lock:
            if key in self._data:
                self._used -= self._sizes.pop(key)
                del self._data[key]
                self._policy.remove(key)

    def __contains__(self, key: Key) -> bool:
        with self._lock:
            return key in self._data

    def __len__(self) -> int:
        return len(self._data)

    def keys(self) -> List[Key]:
        with self._lock:
            return list(self._data)

    def _evict_to(self, budget: float, report: EvictionReport) -> None:
        while self._used > budget:
            victim = self._policy.victim()
            if victim is None:
                break
            size = self._sizes.pop(victim, 0.0)
            self._data.pop(victim, None)
            self._policy.remove(victim)
            self._used -= size
            self.stats.evictions += 1
            self.stats.bytes_evicted += size
            report.evicted_keys.append(victim)
            report.evicted_bytes += size


def _default_sizeof(value: object) -> float:
    nbytes = getattr(value, "nbytes", None)
    if nbytes is not None:
        return float(nbytes)
    if isinstance(value, (bytes, bytearray, memoryview)):
        return float(len(value))
    if isinstance(value, str):
        return float(len(value.encode()))
    raise TypeError(
        f"cannot size object of type {type(value).__name__}; "
        "pass sizeof= to ShardCache")


@dataclass
class SeqAllocation:
    seq_id: Key
    blocks: List[int] = field(default_factory=list)
    last_touch: int = 0


class KVBlockPool:
    """Paged-KV block bookkeeping with controller-adjustable capacity.

    The serving engine owns the actual ``(num_blocks, block_tokens, ...)``
    device arrays; this pool hands out block indices, maintains per-
    sequence block tables, and -- when DynIMS shrinks it -- preempts
    whole sequences (largest-allocation-first, then least-recently-
    touched) and reports them so the engine can requeue their requests.
    Preemption over partial-block eviction keeps KV pages consistent,
    which is the TPU analogue of Alluxio evicting whole blocks.
    """

    def __init__(self, name: str, num_blocks: int, block_bytes: float,
                 priority: int = 1) -> None:
        if num_blocks <= 0:
            raise ValueError("num_blocks must be positive")
        self.name = name
        self.priority = priority
        self.total_blocks = int(num_blocks)
        self.block_bytes = float(block_bytes)
        self._usable = int(num_blocks)
        self._free: List[int] = list(range(num_blocks - 1, -1, -1))
        self._seqs: Dict[Key, SeqAllocation] = {}
        self._clock = 0
        self._lock = threading.RLock()
        self.preempted: List[Key] = []     # drained by the serving engine
        self.stats = StoreStats()

    # -- ManagedStore interface -------------------------------------------
    def capacity(self) -> float:
        return self._usable * self.block_bytes

    def used(self) -> float:
        with self._lock:
            n = sum(len(s.blocks) for s in self._seqs.values())
        return n * self.block_bytes

    def set_capacity(self, capacity: float) -> EvictionReport:
        with self._lock:
            usable = int(max(capacity, 0.0) // self.block_bytes)
            usable = min(usable, self.total_blocks)
            report = EvictionReport(
                store=self.name, requested_capacity=capacity,
                applied_capacity=usable * self.block_bytes)
            self._usable = usable
            # Preempt sequences until allocation fits the usable budget.
            while self._allocated_blocks() > self._usable:
                victim = self._preemption_victim()
                if victim is None:
                    break
                freed = self._release(victim)
                self.preempted.append(victim)
                self.stats.evictions += 1
                self.stats.bytes_evicted += freed * self.block_bytes
                report.evicted_keys.append(victim)
                report.evicted_bytes += freed * self.block_bytes
            return report

    # -- allocator interface -----------------------------------------------
    def alloc_block(self, seq_id: Key) -> Optional[int]:
        """Allocate one block to ``seq_id``; None if at budget."""
        with self._lock:
            self._clock += 1
            if self._allocated_blocks() >= self._usable or not self._free:
                self.stats.rejected += 1
                return None
            blk = self._free.pop()
            alloc = self._seqs.setdefault(seq_id, SeqAllocation(seq_id))
            alloc.blocks.append(blk)
            alloc.last_touch = self._clock
            self.stats.insertions += 1
            return blk

    def touch(self, seq_id: Key) -> None:
        with self._lock:
            self._clock += 1
            if seq_id in self._seqs:
                self._seqs[seq_id].last_touch = self._clock

    def free_seq(self, seq_id: Key) -> int:
        with self._lock:
            return self._release(seq_id)

    def block_table(self, seq_id: Key) -> List[int]:
        with self._lock:
            alloc = self._seqs.get(seq_id)
            return list(alloc.blocks) if alloc else []

    def num_free_blocks(self) -> int:
        with self._lock:
            return self._usable - self._allocated_blocks()

    def drain_preempted(self) -> List[Key]:
        with self._lock:
            out, self.preempted = self.preempted, []
            return out

    def live_sequences(self) -> List[Key]:
        with self._lock:
            return list(self._seqs)

    # -- internals ----------------------------------------------------------
    def _allocated_blocks(self) -> int:
        return sum(len(s.blocks) for s in self._seqs.values())

    def _release(self, seq_id: Key) -> int:
        alloc = self._seqs.pop(seq_id, None)
        if alloc is None:
            return 0
        for blk in alloc.blocks:
            self._free.append(blk)
        return len(alloc.blocks)

    def _preemption_victim(self) -> Optional[Key]:
        if not self._seqs:
            return None
        # Largest allocation first (frees most per preemption), then LRU.
        return max(
            self._seqs.values(),
            key=lambda s: (len(s.blocks), -s.last_touch),
        ).seq_id


class StoreRegistry:
    """Per-node registry splitting one capacity signal across N stores.

    The paper controls a single Alluxio worker per node; a JAX worker has
    several resizable tenants (dataset cache, KV pool, checkpoint staging
    buffers).  The registry applies the controller's node-level capacity
    ``u`` with a priority waterfall: stores are filled highest-priority
    first, each up to its own ``max_bytes``.
    """

    def __init__(self) -> None:
        self._stores: List[Tuple[ManagedStore, float]] = []   # (store, max)

    def register(self, store: ManagedStore, max_bytes: float) -> None:
        self._stores.append((store, float(max_bytes)))
        self._stores.sort(key=lambda t: -t[0].priority)

    def stores(self) -> List[ManagedStore]:
        return [s for s, _ in self._stores]

    def total_used(self) -> float:
        return sum(s.used() for s, _ in self._stores)

    def total_capacity(self) -> float:
        return sum(s.capacity() for s, _ in self._stores)

    def apply_capacity(self, u: float) -> List[EvictionReport]:
        remaining = max(float(u), 0.0)
        reports = []
        for store, max_bytes in self._stores:
            grant = min(remaining, max_bytes)
            reports.append(store.set_capacity(grant))
            remaining -= grant
        return reports
