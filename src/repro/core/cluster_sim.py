"""Discrete-event cluster simulator reproducing the paper's experiments.

The paper evaluates DynIMS on 5 compute nodes + a 2-node OrangeFS
cluster, running HPCC (the priority compute tenant) concurrently with
Spark iterative analytics whose input is cached in Alluxio (the
opportunistic storage tenant).  This module models that testbed:

* per compute node: 125 GB RAM; a Spark executor (20 GB pinned, or
  45 GB for the Spark-only config with an RDD cache); an HPCC job whose
  usage follows :func:`~repro.core.traces.hpcc_trace`; an in-memory
  block cache (the Alluxio worker) whose capacity is either static or
  driven by a real :class:`~repro.core.plane.MemoryPlane` at the
  paper's 100 ms interval (scalar reference backend: bit-exact float64
  reproduction of the paper's per-node law),
* a 2-node data tier: shared disk + network bandwidth (readers divide
  it) and a 160 GB aggregate LRU OS buffer cache,
* the iterative app: each iteration every node scans its partition
  block-by-block; a block read costs local-RAM / remote-buffer-cache /
  remote-disk time depending on where it lives; compute follows,
* memory-pressure coupling: when a node's utilization approaches 100%
  the HPL-calibrated slowdown (:func:`~repro.core.traces.hpl_slowdown`)
  stretches both tenants' progress -- the paper's Fig. 2 penalty.

The four memory configurations of Sec. IV.A map to
:func:`make_paper_config`(1..4), and :func:`run_paper_experiment`
returns everything needed for Figs 5-8.

The simulator is fully deterministic given a seed.  For 1000+-node
studies, :func:`simulate_fleet` runs the vectorized JAX control law over
thousands of node controllers in one fused update per interval.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

from .control import ControllerParams, control_step
from .eviction import LFUPolicy
from .plane import CapturedTrace, MemoryPlane, NodeSpec, PlaneSpec
from .monitor import SimulatedMonitor
from .store import ShardCache, StoreRegistry
from .traces import (GiB, IterativeAppSpec, TierSpec, hpcc_trace,
                     hpl_slowdown, RDD_DESERIALIZATION_BLOAT)


@dataclass
class SimConfig:
    """One experimental configuration (Sec. IV.A)."""

    name: str
    n_compute: int = 5
    node_memory_gib: float = 125.0
    ramdisk_gib: float = 60.0                 # Alluxio U_max (Table I)
    spark_exec_gib: float = 20.0
    os_reserved_gib: float = 5.0              # slack the operators keep free
    os_base_gib: float = 2.0                  # kernel/daemon baseline usage
    data_cache_gib: float = 160.0             # aggregate OS buffer cache
    agg_disk_gibps: float = 0.45              # 2 nodes x ~0.22 GiB/s RAID read
    agg_net_gibps: float = 2.20               # 2 x 10 GbE wire-rate
    tier: TierSpec = field(default_factory=TierSpec)
    app: IterativeAppSpec = field(default_factory=IterativeAppSpec)
    interval_s: float = 0.1                   # control interval T
    controller: Optional[ControllerParams] = None   # None -> static
    static_cache_gib: float = 25.0
    rdd_cache_gib: float = 0.0                # config 1: Spark RDD cache
    run_hpcc: bool = True
    hpcc_duration_s: float = 420.0
    warm_data_cache: bool = True              # dataset gen leaves buffer cache warm
    seed: int = 0
    max_sim_s: float = 3600.0 * 4
    # ReplayLoop: keep the last trace_capacity control intervals of the
    # plane's telemetry and return them as SimResult.trace, so a
    # simulated deployment's own workload becomes a sweepable scenario
    # (ScenarioSpec.from_capture).  Only meaningful with a controller.
    record_trace: bool = False
    trace_capacity: int = 4096


@dataclass
class SimResult:
    config: str
    app_runtime_s: float
    iteration_times_s: List[float]
    hit_ratio: float                          # compute-node in-memory hit ratio
    remote_bytes_gib: float
    disk_reads_gib: float
    hpcc_runtime_s: Optional[float]
    # Fig. 7 timelines (per tick, node-0): execution / storage / free, GiB
    t_s: np.ndarray = field(default_factory=lambda: np.empty(0))
    exec_gib: np.ndarray = field(default_factory=lambda: np.empty(0))
    storage_gib: np.ndarray = field(default_factory=lambda: np.empty(0))
    free_gib: np.ndarray = field(default_factory=lambda: np.empty(0))
    cap_gib: np.ndarray = field(default_factory=lambda: np.empty(0))
    peak_utilization: float = 0.0
    mean_cap_gib: float = 0.0
    trace: Optional[CapturedTrace] = None     # cfg.record_trace capture


class _DataTier:
    """2-node data cluster: LRU OS buffer cache over shared disk."""

    def __init__(self, cache_gib: float, block_gib: float):
        self.capacity = cache_gib
        self.block = block_gib
        self._lru: "Dict[int, None]" = {}
        self.disk_reads = 0
        self.cache_reads = 0

    def warm(self, blocks: List[int]) -> None:
        for b in blocks:
            self._touch(b)

    def read_tier(self, block_id: int) -> str:
        """Returns which remote tier serves the block, updating LRU."""
        if block_id in self._lru:
            self.cache_reads += 1
            self._touch(block_id)
            return "remote_cache"
        self.disk_reads += 1
        self._touch(block_id)
        return "disk"

    def _touch(self, block_id: int) -> None:
        self._lru.pop(block_id, None)
        self._lru[block_id] = None
        while len(self._lru) * self.block > self.capacity:
            self._lru.pop(next(iter(self._lru)))


@dataclass
class _BlockJob:
    """Progress state of the block a node is currently processing."""

    block_id: int
    read_left_s: float
    compute_left_s: float
    tier: str


class _Node:
    """One compute node: HPCC tenant + Spark tenant + block cache."""

    def __init__(self, idx: int, cfg: SimConfig, partition: List[int],
                 cache_gib: float):
        self.idx = idx
        self.cfg = cfg
        self.partition = partition
        # Scan-resistant LFU (MRU tie-break) + frequency admission: keeps
        # the resident set stable under cyclic scans and keeps eviction
        # victims inclusive with the data-node buffer cache (Sec. IV.B).
        self.cache = ShardCache(
            name=f"alluxio-{idx}", capacity=cache_gib * GiB,
            policy=LFUPolicy(tie="mru"), admission=True)
        self.registry = StoreRegistry()
        self.registry.register(self.cache, max_bytes=cfg.ramdisk_gib * GiB)
        self.iteration = 0
        self.block_pos = 0
        self.job: Optional[_BlockJob] = None
        self.waiting_barrier = False
        self.done = False
        self.hpcc_clock = 0.0
        self.hpcc_done = not cfg.run_hpcc
        self.hpcc_finish_s: Optional[float] = None
        # effective RDD-cached blocks (config 1): pinned, immune to eviction
        bloat = RDD_DESERIALIZATION_BLOAT
        n_pinned = int((cfg.rdd_cache_gib / bloat) // cfg.app.block_gib)
        self.pinned = set(partition[:n_pinned])
        self.pinned_gib = len(self.pinned) * cfg.app.block_gib
        self.local_reads = 0
        self.remote_reads = 0

    # -- memory accounting -------------------------------------------------
    def hpcc_usage_gib(self, trace: np.ndarray) -> float:
        if self.hpcc_done:
            return 0.0
        i = min(int(self.hpcc_clock / self.cfg.interval_s), len(trace) - 1)
        return trace[i] / GiB

    def spark_usage_gib(self) -> float:
        # Config 1 allocates the full RDD-cache region in the JVM heap
        # regardless of how many (bloated) blocks actually fit in it.
        return self.cfg.spark_exec_gib + self.cfg.rdd_cache_gib

    def used_gib(self, trace: np.ndarray) -> float:
        # The paper's 5 GB "reserved space" is slack (kept free), not
        # usage; only the kernel/daemon baseline counts as used.
        return (self.hpcc_usage_gib(trace) + self.spark_usage_gib()
                + self.cfg.os_base_gib + self.cache.used() / GiB)


def _partition_blocks(n_blocks: int, n_nodes: int) -> List[List[int]]:
    """Contiguous partitions (Spark locality-preserving split)."""
    out, start = [], 0
    for i in range(n_nodes):
        size = n_blocks // n_nodes + (1 if i < n_blocks % n_nodes else 0)
        out.append(list(range(start, start + size)))
        start += size
    return out


def simulate(cfg: SimConfig) -> SimResult:
    app, tier = cfg.app, cfg.tier
    partitions = _partition_blocks(app.n_blocks, cfg.n_compute)
    static_cap = 0.0 if cfg.rdd_cache_gib else cfg.static_cache_gib
    init_cap = cfg.ramdisk_gib if cfg.controller is not None else static_cap
    nodes = [_Node(i, cfg, partitions[i], init_cap)
             for i in range(cfg.n_compute)]
    data_tier = _DataTier(cfg.data_cache_gib, app.block_gib)
    if cfg.warm_data_cache:
        # Dataset generation streams blocks through the data nodes; the
        # OS buffer cache retains the most recent cache_gib worth.
        data_tier.warm(list(range(app.n_blocks)))

    trace = (hpcc_trace(cfg.hpcc_duration_s, cfg.interval_s, seed=cfg.seed)
             if cfg.run_hpcc else np.zeros(1))

    plane: Optional[MemoryPlane] = None
    if cfg.controller is not None:
        plane = MemoryPlane(PlaneSpec(
            params=cfg.controller,
            backend="scalar",    # float64 reference law, paper-faithful
            record=cfg.trace_capacity if cfg.record_trace else 0,
            nodes=tuple(
                NodeSpec(
                    name=f"node{node.idx}",
                    monitor=SimulatedMonitor(
                        node=f"node{node.idx}",
                        total=cfg.node_memory_gib * GiB,
                        usage=_UsageProbe(node, trace),
                        storage_used_fn=node.cache.used,
                        dt=cfg.interval_s),
                    registry=node.registry,
                    u0=cfg.ramdisk_gib * GiB)
                for node in nodes)))

    dt = cfg.interval_s
    t = 0.0
    iter_start = [0.0]
    iteration_times: List[float] = []
    tl_t, tl_exec, tl_stor, tl_free, tl_cap = [], [], [], [], []
    peak_util = 0.0
    cap_samples: List[float] = []
    n_ticks = 0

    while t < cfg.max_sim_s:
        n_ticks += 1
        # ---- control interval: DynIMS observes and actuates ---------------
        if plane is not None:
            plane.tick()

        # ---- shared remote bandwidth this tick -----------------------------
        disk_readers = sum(1 for n in nodes if n.job and n.job.tier == "disk"
                           and n.job.read_left_s > 0)
        net_readers = sum(1 for n in nodes
                          if n.job and n.job.tier == "remote_cache"
                          and n.job.read_left_s > 0)
        disk_share = cfg.agg_disk_gibps / max(disk_readers, 1)
        net_share = cfg.agg_net_gibps / max(net_readers, 1)

        all_done = True
        barrier_count = 0
        for node in nodes:
            util = node.used_gib(trace) / cfg.node_memory_gib
            peak_util = max(peak_util, util)
            slowdown = hpl_slowdown(util)
            progress = dt / slowdown

            # HPCC tenant advances on its own clock, stretched by pressure.
            if not node.hpcc_done:
                node.hpcc_clock += progress
                if node.hpcc_clock >= cfg.hpcc_duration_s:
                    node.hpcc_done = True
                    node.hpcc_finish_s = t

            # Spark tenant
            if node.done:
                continue
            all_done = False
            if node.waiting_barrier:
                barrier_count += 1
                continue
            if node.job is None:
                node.job = _start_block(node, data_tier, tier)
            job = node.job
            if job.read_left_s > 0:
                # Remote read times are priced at the tier's *aggregate*
                # bandwidth; concurrent readers divide it evenly.
                consume = progress
                if job.tier == "disk" and disk_readers > 1:
                    consume = progress / disk_readers
                elif job.tier == "remote_cache" and net_readers > 1:
                    consume = progress / net_readers
                job.read_left_s -= consume
                if job.read_left_s > 0:
                    continue
            if job.compute_left_s > 0:
                job.compute_left_s -= progress
                if job.compute_left_s > 0:
                    continue
            # block finished
            node.block_pos += 1
            node.job = None
            if node.block_pos >= len(node.partition):
                node.block_pos = 0
                node.waiting_barrier = True
                barrier_count += 1

        # ---- iteration barrier (Spark stage boundary) ----------------------
        active = [n for n in nodes if not n.done]
        if active and all(n.waiting_barrier for n in active):
            iteration_times.append(t + dt - iter_start[0])
            iter_start[0] = t + dt
            for n in active:
                n.iteration += 1
                n.waiting_barrier = False
                if n.iteration >= app.iterations:
                    n.done = True

        # ---- timelines (node 0) --------------------------------------------
        n0 = nodes[0]
        exec_g = n0.hpcc_usage_gib(trace) + n0.spark_usage_gib() \
            + cfg.os_base_gib
        stor_g = n0.cache.used() / GiB
        tl_t.append(t)
        tl_exec.append(exec_g)
        tl_stor.append(stor_g)
        tl_free.append(max(cfg.node_memory_gib - exec_g - stor_g, 0.0))
        tl_cap.append(n0.cache.capacity() / GiB)
        cap_samples.append(n0.cache.capacity() / GiB)

        t += dt
        if all_done:
            break

    hits = sum(n.cache.stats.hits for n in nodes)
    misses = sum(n.cache.stats.misses for n in nodes)
    pinned_hits = sum(n.local_reads for n in nodes)
    total_local = hits + pinned_hits
    total_reads = hits + misses + pinned_hits
    hpcc_fin = None
    if cfg.run_hpcc:
        fins = [n.hpcc_finish_s for n in nodes if n.hpcc_finish_s is not None]
        hpcc_fin = max(fins) if fins else None
    captured = (plane.capture()
                if plane is not None and cfg.record_trace and n_ticks
                else None)
    return SimResult(
        config=cfg.name,
        app_runtime_s=float(sum(iteration_times)),
        iteration_times_s=[float(x) for x in iteration_times],
        hit_ratio=total_local / total_reads if total_reads else 0.0,
        remote_bytes_gib=sum(n.cache.stats.bytes_read_remote
                             for n in nodes) / GiB,
        disk_reads_gib=data_tier.disk_reads * app.block_gib,
        hpcc_runtime_s=hpcc_fin,
        t_s=np.asarray(tl_t),
        exec_gib=np.asarray(tl_exec),
        storage_gib=np.asarray(tl_stor),
        free_gib=np.asarray(tl_free),
        cap_gib=np.asarray(tl_cap),
        peak_utilization=peak_util,
        mean_cap_gib=float(np.mean(cap_samples)) if cap_samples else 0.0,
        trace=captured,
    )


class _UsageProbe:
    """Callable feeding SimulatedMonitor the node's *compute* usage."""

    def __init__(self, node: _Node, trace: np.ndarray):
        self._node = node
        self._trace = trace

    def __call__(self, i: int) -> float:
        n = self._node
        return (n.hpcc_usage_gib(self._trace) + n.spark_usage_gib()
                + n.cfg.os_base_gib) * GiB


def _start_block(node: _Node, data_tier: _DataTier,
                 tier: TierSpec) -> _BlockJob:
    cfg = node.cfg
    block_id = node.partition[node.block_pos]
    block_gib = cfg.app.block_gib
    compute_s = cfg.app.compute_s_per_gib * block_gib

    if block_id in node.pinned:
        node.local_reads += 1
        return _BlockJob(block_id, tier.read_time_s(block_gib, "local"),
                         compute_s, "local")

    cached = node.cache.get(block_id)
    if cached is not None:
        return _BlockJob(block_id, tier.read_time_s(block_gib, "local"),
                         compute_s, "local")

    node.remote_reads += 1
    remote = data_tier.read_tier(block_id)
    if remote == "remote_cache":
        read_s = block_gib / cfg.agg_net_gibps       # share applied per-tick
    else:
        read_s = block_gib / cfg.agg_disk_gibps
    node.cache.stats.bytes_read_remote += block_gib * GiB
    # Insert into the node cache (admission may reject under scan).
    node.cache.put(block_id, _SizedBlock(block_gib * GiB))
    return _BlockJob(block_id, read_s, compute_s, remote)


class _SizedBlock:
    __slots__ = ("nbytes",)

    def __init__(self, nbytes: float):
        self.nbytes = nbytes


# ---------------------------------------------------------------------------
# The paper's four configurations (Sec. IV.A)
# ---------------------------------------------------------------------------

def make_paper_config(configuration: int, *, app: Optional[IterativeAppSpec]
                      = None, seed: int = 0, **overrides) -> SimConfig:
    app = app or IterativeAppSpec()
    base = dict(app=app, seed=seed)
    base.update(overrides)
    if configuration == 1:      # Spark(45GB), no Alluxio caching
        return SimConfig(name="spark45", spark_exec_gib=20.0,
                         rdd_cache_gib=25.0, static_cache_gib=0.0,
                         controller=None, run_hpcc=True, **base)
    if configuration == 2:      # Spark(20)/Alluxio(25) static
        return SimConfig(name="spark20_alluxio25", static_cache_gib=25.0,
                         controller=None, run_hpcc=True, **base)
    if configuration == 3:      # Spark(20)/DynIMS(60)
        return SimConfig(name="spark20_dynims60",
                         controller=paper_controller_params(), run_hpcc=True,
                         **base)
    if configuration == 4:      # Spark(20)/Alluxio(60), no HPCC: upper bound
        return SimConfig(name="spark20_alluxio60_nohpcc",
                         static_cache_gib=60.0, controller=None,
                         run_hpcc=False, **base)
    raise ValueError("configuration must be 1..4")


def make_cache_parity_config(*, n_compute: int = 2, cache_gib: float = 32.0,
                             dataset_gib: float = 128.0, iterations: int = 25,
                             seed: int = 0, **overrides) -> SimConfig:
    """The CacheLoop oracle configuration: a pure cache-dynamics run.

    A small static-capacity, no-HPCC setup whose discrete-event hit
    ratio the analytic cache model in the scanned sweep must reproduce:
    each node cyclically scans a ``dataset_gib / n_compute`` partition
    through a ``cache_gib`` LFU cache, so after the cold first pass the
    admission-stabilized resident prefix yields exactly
    ``(iterations - 1) * cache_gib`` block hits out of
    ``iterations * partition`` reads.  The network bandwidth is raised
    so the run stays compute-shaped (fewer ticks); hit counting is
    bandwidth-independent.  ``tests/test_cacheloop.py`` asserts the
    sweep engine's ``hit_ratio`` lands within 0.02 of this oracle.
    """
    app = IterativeAppSpec(name="parity-scan", dataset_gib=dataset_gib,
                           block_gib=1.0, iterations=iterations,
                           compute_s_per_gib=0.2)
    kw = dict(name="cache-parity", n_compute=n_compute,
              static_cache_gib=cache_gib, controller=None, run_hpcc=False,
              app=app, agg_net_gibps=8.0, seed=seed)
    kw.update(overrides)
    return SimConfig(**kw)


def paper_controller_params(**overrides) -> ControllerParams:
    """Table I parameters."""
    kw = dict(total_memory=125.0 * GiB, r0=0.95, lam=0.5,
              u_min=0.0, u_max=60.0 * GiB, interval_s=0.1)
    kw.update(overrides)
    return ControllerParams(**kw)


def run_paper_experiment(app: Optional[IterativeAppSpec] = None,
                         seed: int = 0, configs: Tuple[int, ...] = (1, 2, 3, 4),
                         **overrides) -> Dict[int, SimResult]:
    return {c: simulate(make_paper_config(c, app=app, seed=seed, **overrides))
            for c in configs}


# ---------------------------------------------------------------------------
# AppGraph oracle: float64 discrete-event makespan reference
# ---------------------------------------------------------------------------

def simulate_app_graph(graph, demand: np.ndarray, *,
                       node_memory: float,
                       interval_s: float = 1.0,
                       params: Optional[ControllerParams] = None,
                       static_grant: float = 25.0 * GiB,
                       cache=None) -> Dict[str, object]:
    """Float64 discrete-event oracle for the AppGraph makespan.

    An independent implementation of the stage-DAG co-simulation the
    sweep engine streams through its scan
    (:mod:`repro.lab.appgraph`): per node, one scalar Eq.-1 controller
    (:func:`~repro.core.control.control_step`, the float64 reference
    law) observes external demand plus the active stage's held memory,
    and the node's task queue drains at ``compute_gibps`` stretched by
    the Fig.-2 curve (and, with a :class:`~repro.lab.scenarios.CacheSpec`,
    by the same analytic miss/eviction stalls, mirrored here in f64).

    Where the scan quantizes the queue to whole control intervals, this
    oracle **splits events sub-interval**: within an interval the drain
    rate is piecewise constant, a node finishing a stage row mid-
    interval promotes (non-barrier) or blocks (barrier) at the exact
    event time, a barrier releases every blocked node at the instant
    the fleet's slowest finishes, and rates are re-derived at each
    split from the new row's held demand.  The parity tests pin the
    streamed f32 interval-quantized makespan against this to a
    relative tolerance that brackets the quantization gap.

    Args:
      graph: a :class:`repro.lab.appgraph.AppGraphSpec`.
      demand: ``(N, T)`` external (HPCC) demand in **bytes** per node
        per control interval -- the same array the sweep consumes
        (transposed).
      node_memory: per-node total memory M, bytes.
      interval_s: control interval T.
      params: controller parameters; ``None`` runs the static baseline
        with the grant pinned at ``static_grant`` bytes.
      cache: optional ``CacheSpec``; mirrors CacheLoop's analytic
        resident/hit/refill dynamics in float64 (interval-quantized,
        as in the scan -- only the *queue* is event-split).

    Returns a dict: ``makespan_s`` (finished -> exact event time,
    else the sweep's work-linear extrapolation), ``finished``,
    ``t_done_s``, ``stage_finish_s`` (per compiled row: the wall clock
    at which the row cleared fleet-wide, -1 if never), and
    ``work_done_gib`` per node.
    """
    from ..lab.appgraph import compile_graph   # lazy: core must not
    # import the lab at module scope (the lab imports core)

    g = compile_graph(graph, demand.shape[0])
    n_nodes, t_steps = demand.shape
    demand = np.asarray(demand, np.float64)
    m = float(node_memory)
    w = g.work_gib.astype(np.float64)              # (S+1, N) GiB
    stage_demand = g.demand_bytes.astype(np.float64)
    barrier = g.barrier.astype(np.float64)
    s_tot = g.n_rows
    comp = float(graph.compute_gibps)              # GiB/s nominal

    u0 = float(params.u_max) if params is not None else float(static_grant)
    u = np.full(n_nodes, u0, np.float64)
    v_prev: List[Optional[float]] = [None] * n_nodes

    if cache is not None:
        from .eviction import policy_model
        conc = float(policy_model(cache.policy).concentration)
        hit_exp = 1.0 - float(cache.reuse_skew)
        wset = float(cache.working_set_frac) * m   # bytes
        access_g = float(cache.access_gibps) * interval_s   # GiB/interval
        refill_b = float(cache.refill_gibps) * GiB * interval_s
        access_b = access_g * GiB
        cold_mix = float(cache.reuse_skew)
        res0 = float(cache.warm_frac) * min(u0, wset)
        wf0 = res0 / wset
        resident = np.full(n_nodes, res0, np.float64)

    sidx = np.zeros(n_nodes, np.int64)
    wleft = w[0].copy()
    wdone = np.zeros(n_nodes, np.float64)
    blocked = np.zeros(n_nodes, bool)
    stage_finish = np.full(s_tot, -1.0, np.float64)
    t_done_s = -1.0

    def slowdown_at(n_i: int, store: np.ndarray, t: int) -> float:
        d_i = demand[n_i, t] + stage_demand[sidx[n_i]]
        return hpl_slowdown((d_i + store[n_i]) / m)

    for t in range(t_steps):
        d = demand[:, t] + stage_demand[sidx]
        store = resident if cache is not None else u
        v = d + store
        r = v / m
        if params is not None:
            u_next = np.array([control_step(u[i], v[i], params,
                                            v_prev=v_prev[i])
                               for i in range(n_nodes)])
        else:
            u_next = u
        stall = np.zeros(n_nodes, np.float64)
        if cache is not None:
            res_ev = np.minimum(resident, u_next)
            ev_g = (resident - res_ev) / GiB
            f = np.minimum(res_ev / wset, 1.0)
            hit = conc * f ** hit_exp + (1.0 - conc) * f
            if t * access_b < wset:                # cold-scan window
                wf = np.minimum(wf0, f)
                hit = wf + cold_mix * (hit - wf)
            miss_g = (1.0 - hit) * access_g
            resident = np.minimum(np.minimum(u_next, wset),
                                  res_ev + np.minimum(miss_g * GiB,
                                                      refill_b))
            stall = (miss_g * cache.miss_penalty_s_per_gib
                     + ev_g * cache.evict_penalty_s_per_gib)

        # --- event-split queue advance over [t, t+1) * interval_s ----
        # Rate is piecewise constant between events; miss/eviction
        # stalls stretch the whole interval uniformly (cache state is
        # interval-level), the Fig.-2 term re-derives at each split.
        # ``store`` still holds the pre-update values -- the scan's
        # dt_app uses the same pre-eviction observation.
        rate = np.array([comp * interval_s
                         / (interval_s * slowdown_at(i, store, t)
                            + stall[i]) for i in range(n_nodes)])
        elapsed = np.zeros(n_nodes, np.float64)
        while t_done_s < 0.0:
            eta = np.full(n_nodes, np.inf)
            act = (~blocked) & (sidx < s_tot)
            eta[act] = elapsed[act] + wleft[act] / rate[act]
            i = int(np.argmin(eta))
            if eta[i] > interval_s:
                break
            t_ev = float(eta[i])
            abs_t = t * interval_s + t_ev
            wdone[i] += wleft[i]
            wleft[i] = 0.0
            elapsed[i] = t_ev
            s = int(sidx[i])
            if barrier[s] > 0.0:
                blocked[i] = True
                if bool(np.all(blocked & (sidx == s))):
                    stage_finish[s] = abs_t
                    blocked[:] = False
                    sidx[:] = s + 1
                    if s + 1 >= s_tot:
                        t_done_s = abs_t
                        break
                    wleft = w[s + 1].copy()
                    elapsed[:] = t_ev
                    rate = np.array([
                        comp * interval_s
                        / (interval_s * slowdown_at(j, store, t)
                           + stall[j]) for j in range(n_nodes)])
            else:
                stage_finish[s] = max(stage_finish[s], abs_t)
                sidx[i] = s + 1
                if int(np.min(sidx)) >= s_tot:
                    t_done_s = abs_t
                    break
                if sidx[i] < s_tot:
                    wleft[i] = w[sidx[i], i]
                    rate[i] = (comp * interval_s
                               / (interval_s * slowdown_at(i, store, t)
                                  + stall[i]))
        if t_done_s >= 0.0:
            break
        act = (~blocked) & (sidx < s_tot)
        prog = rate * (interval_s - elapsed)
        wdone[act] += np.minimum(prog, wleft)[act]
        wleft[act] = np.maximum(wleft - prog, 0.0)[act]
        v_prev = list(v)
        u = u_next

    horizon_s = t_steps * interval_s
    if t_done_s >= 0.0:
        makespan = t_done_s
    else:
        makespan = max(horizon_s * float(w.sum())
                       / max(float(wdone.sum()), 1e-6), horizon_s)
    return {"makespan_s": makespan, "finished": t_done_s >= 0.0,
            "t_done_s": t_done_s, "stage_finish_s": stage_finish,
            "work_done_gib": wdone}


# ---------------------------------------------------------------------------
# Fleet-scale control simulation (1000+ nodes) via the vectorized law
# ---------------------------------------------------------------------------

def simulate_fleet(n_nodes: int = 4096, n_intervals: int = 1000,
                   seed: int = 0,
                   params: Optional[ControllerParams] = None,
                   engine: str = "lab") -> dict:
    """Vectorized closed-loop sim of ``n_nodes`` controllers in JAX.

    Each node gets a phase-shifted, amplitude-jittered HPCC trace
    (:func:`~repro.core.traces.fleet_demand_traces`) and the whole
    fleet's Eq. 1 updates run batched.  Two engines:

    * ``engine="lab"`` (default) -- delegate to the device-resident
      ScenarioLab sweep: the entire horizon is one jitted ``lax.scan``
      whose statistics stream through the scan carry (p99 via the
      fixed-bin streaming quantile), so the closed loop costs a single
      XLA dispatch end to end and O(1) bytes back to the host.
    * ``engine="python"`` -- the historical loop: one fused jitted step
      per interval, re-entering Python 10x per simulated second.  Kept
      as the baseline ``benchmarks/lab_bench.py`` measures against;
      a parity test pins both engines' metrics together.

    Returns stability metrics the fleet-scale test asserts on.
    """
    from .traces import fleet_demand_traces

    p = params or paper_controller_params()
    demand = fleet_demand_traces(n_nodes, n_intervals, p.interval_s,
                                 seed=seed)

    if engine == "lab":
        from ..lab.score import stats_to_dict
        from ..lab.sweep import GainSet, sweep_demand
        stats = sweep_demand(
            demand, GainSet.from_params(p), node_memory=p.total_memory,
            interval_s=p.interval_s)
        out = stats_to_dict(stats, 0)
        out["n_nodes"] = n_nodes
        return out
    if engine != "python":
        raise ValueError("engine must be lab|python")

    import jax
    import jax.numpy as jnp

    from .control import vectorized_step

    m = p.total_memory
    u = jnp.full((n_nodes,), p.u_max, dtype=jnp.float32)
    # First interval runs without a previous observation: seeding v_prev
    # with that interval's own usage zeroes the slope term exactly (the
    # lab engine uses the same convention, keeping the engines in parity
    # for feedforward params too).
    v_prev = jnp.asarray(demand[:, 0], jnp.float32) + u

    @jax.jit
    def step(u, v_prev, d):
        v = d + u                                        # saturated store
        u_next = vectorized_step(u, v, total_memory=m, r0=p.r0, lam=p.lam,
                                 u_min=p.u_min, u_max=p.u_max,
                                 lam_grant=p.lam_grant, deadband=p.deadband,
                                 v_prev=v_prev, feedforward=p.feedforward)
        return u_next, (v / m, u_next, v)

    utils, caps = [], []
    for i in range(n_intervals):
        u, (r, u_now, v_prev) = step(u, v_prev,
                                     jnp.asarray(demand[:, i], jnp.float32))
        utils.append(r)
        caps.append(u_now)
    utils = np.stack([np.asarray(x) for x in utils])     # (T, N)
    caps = np.stack([np.asarray(x) for x in caps])
    # overshoot: utilization above r0 one interval after the law engages
    over = np.clip(utils - p.r0 / 1.0, 0.0, None)
    return {
        "n_nodes": n_nodes,
        "mean_utilization": float(utils.mean()),
        "p99_utilization": float(np.quantile(utils, 0.99)),
        "max_utilization": float(utils.max()),
        "mean_capacity_gib": float(caps.mean() / GiB),
        "capacity_std_gib": float(caps.std() / GiB),
        "frac_intervals_over_r0": float((utils > p.r0 + 1e-3).mean()),
        "max_over_r0": float(over.max()),
    }
