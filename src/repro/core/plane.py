"""MemoryPlane: the declarative DynIMS control-plane API.

The paper's DynIMS is *one* controller service adapting in-memory
storage for all nodes from a single feedback loop (Eq. 1).  This module
is that service's API surface: consumers declare *what* they manage --
nodes, monitors, stores, eviction policy, signal, transport -- in a
:class:`PlaneSpec` and hand it to a :class:`MemoryPlane`; they never
touch bus/aggregator/controller internals.

    spec = PlaneSpec(
        params=paper_controller_params(),
        nodes=(NodeSpec("node0", monitor=mon,
                        stores=(StoreSpec(cache, max_bytes=60 * GiB),)),),
    )
    with MemoryPlane(spec) as plane:      # start()s the 100 ms loop
        ...                               # or: plane.tick() per interval
    print(plane.actions(node="node0", limit=8))

Two controller backends sit behind the facade:

* ``backend="scalar"`` -- :class:`~repro.core.controller.DynIMSController`,
  the float64 per-node reference implementation.
* ``backend="array"`` (default) -- :class:`ArrayController`, which packs
  every attached node's ``(u, v, v_prev, M, u_min, u_max)`` into arrays
  and runs **one fused, jitted** ``vectorized_step`` per control
  interval.  This is the backend that scales to 1000+ nodes: per tick it
  costs one XLA dispatch instead of N Python control-law evaluations
  (see ``benchmarks/controller_bench.py``).

A parity test (``tests/test_plane.py``) pins the two backends together
within 1e-4 relative tolerance across heterogeneous fleets.

**ReplayLoop** hooks live here too: a plane built with
``PlaneSpec(record=N)`` (or ``plane.record()``) keeps the last ``N``
control intervals of per-node ``(demand, utilization, grant, cache
residency)`` in a bounded :class:`TraceRecorder` ring; ``capture()``
snapshots it as a :class:`CapturedTrace` (dense numpy, ``.npz``
round-trippable) that ``ScenarioSpec.from_capture`` turns into a
sweepable replay scenario, and :meth:`MemoryPlane.swap_params`
hot-swaps re-tuned :class:`ControllerParams` into the *running* plane
at an interval boundary -- both backends re-specialize without
dropping a tick, and every action is stamped with the parameter epoch.

``ControlPlane`` remains importable (also via its historical home
``repro.core.controller``) as a deprecated shim over the scalar backend.
"""

from __future__ import annotations

import dataclasses
import threading
import time
import warnings
from collections import deque
from typing import Callable, Dict, Iterable, List, Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from ..analysis.runtime import record_trace
from .bus import MessageBus
from .control import ControllerParams, Signal, vectorized_step
from .controller import (ActionHistory, CONTROL_TOPIC, ControlAction,
                         DEFAULT_HISTORY, DynIMSController)
from .monitor import MemoryMonitor
from .monitor import MemorySample
from .store import ManagedStore, ShardCache, StoreRegistry
from .stream import AGG_TOPIC, RAW_TOPIC, AggregatedMetrics, MetricAggregator

BACKENDS = ("array", "scalar")

#: Default ring-buffer capacity (control intervals) of a TraceRecorder.
DEFAULT_TRACE_CAPACITY = 4096


# ---------------------------------------------------------------------------
# ReplayLoop: live-trace capture
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True, eq=False)
class CapturedTrace:
    """A dense snapshot of what a running plane observed and decided.

    All arrays are numpy, node-major: ``(N, T)`` over the captured
    control intervals (``total_memory`` is ``(N,)``).  ``demand`` is the
    compute tenant's usage (``used - storage_used``, bytes) -- the
    quantity a replay scenario feeds back through the sweep engine;
    ``utilization`` is the observed ``v / M``; ``grant`` the
    controller's post-decision capacity ``u``; ``residency`` the bytes
    the managed stores actually held (the CacheLoop observable).

    Serializable: :meth:`save` writes one compressed ``.npz``,
    :meth:`load` restores it bit-for-bit.
    """

    nodes: Tuple[str, ...]
    interval_s: float
    demand: np.ndarray
    utilization: np.ndarray
    grant: np.ndarray
    residency: np.ndarray
    total_memory: np.ndarray

    @property
    def n_nodes(self) -> int:
        return self.demand.shape[0]

    @property
    def n_intervals(self) -> int:
        return self.demand.shape[1]

    @property
    def duration_s(self) -> float:
        return self.n_intervals * self.interval_s

    def utilization_p99(self) -> float:
        """Observed fleet p99 utilization (replay-fidelity yardstick)."""
        return float(np.quantile(self.utilization, 0.99))

    def has_residency(self) -> bool:
        """Did the managed stores ever hold bytes during the capture?"""
        return bool(np.nanmax(self.residency, initial=0.0) > 0.0)

    def save(self, path) -> None:
        np.savez_compressed(
            path, nodes=np.asarray(self.nodes, dtype=np.str_),
            interval_s=np.float64(self.interval_s), demand=self.demand,
            utilization=self.utilization, grant=self.grant,
            residency=self.residency, total_memory=self.total_memory)

    @classmethod
    def load(cls, path) -> "CapturedTrace":
        with np.load(path, allow_pickle=False) as z:
            return cls(nodes=tuple(str(n) for n in z["nodes"]),
                       interval_s=float(z["interval_s"]),
                       demand=z["demand"], utilization=z["utilization"],
                       grant=z["grant"], residency=z["residency"],
                       total_memory=z["total_memory"])


class TraceRecorder:
    """Bounded, thread-safe ring buffer of per-tick fleet snapshots.

    :meth:`MemoryPlane.tick` feeds it one record per control interval
    (the interval's monitor samples plus the actions the controller
    produced); the ring retains the last ``capacity`` intervals, so a
    long-running deployment pays O(capacity * fleet) memory however
    long it runs.  :meth:`snapshot` densifies the ring into a
    :class:`CapturedTrace`; nodes that joined late or skipped an
    interval are forward/backward-filled so the arrays stay rectangular.
    """

    def __init__(self, capacity: int = DEFAULT_TRACE_CAPACITY):
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        self.capacity = capacity
        self._lock = threading.Lock()
        self._ring: deque = deque(maxlen=capacity)  # guarded-by: _lock

    def __len__(self) -> int:
        with self._lock:
            return len(self._ring)

    def clear(self) -> None:
        with self._lock:
            self._ring.clear()

    def record(self, samples: Dict[str, MemorySample],
               actions: List[ControlAction]) -> None:
        """Append one control interval's observations and decisions."""
        grant = {a.node: a.u_next for a in actions}
        tick = {
            node: (max(s.used - s.storage_used, 0.0), s.used, s.total,
                   grant.get(node, np.nan), s.storage_used)
            for node, s in samples.items()}
        with self._lock:
            self._ring.append(tick)

    def snapshot(self, interval_s: float = 0.1) -> CapturedTrace:
        """Densify the ring into a :class:`CapturedTrace` (numpy)."""
        with self._lock:
            ring = list(self._ring)
        if not ring:
            raise ValueError("nothing recorded yet")
        names = sorted({n for tick in ring for n in tick})
        n, t = len(names), len(ring)
        idx = {name: i for i, name in enumerate(names)}
        demand = np.full((n, t), np.nan)
        usage = np.full((n, t), np.nan)
        total = np.full((n, t), np.nan)
        grant = np.full((n, t), np.nan)
        residency = np.full((n, t), np.nan)
        for j, tick in enumerate(ring):
            for name, (d, v, m, u, res) in tick.items():
                i = idx[name]
                demand[i, j] = d
                usage[i, j] = v
                total[i, j] = m
                grant[i, j] = u
                residency[i, j] = res
        for arr in (demand, usage, total, grant, residency):
            _fill_gaps(arr)
        with np.errstate(invalid="ignore", divide="ignore"):
            utilization = np.where(total > 0, usage / total, 0.0)
        return CapturedTrace(
            nodes=tuple(names), interval_s=float(interval_s),
            demand=demand, utilization=utilization, grant=grant,
            residency=residency, total_memory=total[:, -1].copy())


def _fill_gaps(arr: np.ndarray) -> None:
    """In-place forward- then backward-fill NaN runs along axis 1."""
    n, t = arr.shape
    for i in range(n):
        row = arr[i]
        mask = np.isnan(row)
        if not mask.any():
            continue
        if mask.all():
            row[:] = 0.0
            continue
        valid = np.flatnonzero(~mask)
        # forward fill from the previous valid sample, backward fill the
        # leading gap from the first one
        fill_idx = np.clip(
            np.maximum.accumulate(np.where(mask, -1, np.arange(t))),
            valid[0], None)
        row[:] = row[fill_idx]


# ---------------------------------------------------------------------------
# Declarative spec
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class StoreSpec:
    """One managed store and the most memory it may ever be granted."""

    store: ManagedStore
    max_bytes: float


@dataclasses.dataclass(frozen=True)
class NodeSpec:
    """One controlled node: who observes it and what gets resized.

    ``stores`` builds a priority-waterfall :class:`StoreRegistry`;
    alternatively pass a pre-built ``registry``.  ``u0`` seeds the
    capacity state (default: the registry's current total capacity).
    ``params`` overrides the plane-level law parameters for this node --
    heterogeneous ``total_memory`` / ``u_min`` / ``u_max`` fleets.
    """

    name: str
    monitor: MemoryMonitor
    stores: Tuple[StoreSpec, ...] = ()
    registry: Optional[StoreRegistry] = None
    u0: Optional[float] = None
    params: Optional[ControllerParams] = None

    def replace(self, **kw) -> "NodeSpec":
        """A modified copy -- e.g. the same node under a wrapped monitor."""
        return dataclasses.replace(self, **kw)

    def build_registry(self) -> StoreRegistry:
        if self.registry is not None:
            if self.stores:
                raise ValueError(
                    "pass either stores or a pre-built registry, not both "
                    "(stores would be silently unmanaged)")
            return self.registry
        registry = StoreRegistry()
        for spec in self.stores:
            store, max_bytes = (
                (spec.store, spec.max_bytes) if isinstance(spec, StoreSpec)
                else (spec[0], spec[1]))
            registry.register(store, max_bytes=float(max_bytes))
        return registry


@dataclasses.dataclass(frozen=True)
class PlaneSpec:
    """Everything a control plane needs, declared up front.

    Fields:
      params:     plane-level Eq. 1 parameters (per-node overridable).
      nodes:      nodes managed from construction (more can ``attach``).
      signal:     which window aggregate drives the law (:class:`Signal`).
      window:     sliding-window length of the aggregator.
      ewma_alpha: EWMA smoothing factor of the aggregator.
      backend:    "array" (fused batched law) or "scalar" (reference).
      history:    bound on retained :class:`ControlAction` records.
      eviction:   default eviction policy for caches built through
                  :meth:`MemoryPlane.build_cache`.
      transport:  the message bus, or a factory for one (swap point for
                  a multi-host deployment); None -> in-process bus.
      record:     ReplayLoop capture: retain the last ``record`` control
                  intervals in a :class:`TraceRecorder` ring (0 = off;
                  enable later with :meth:`MemoryPlane.record`).
    """

    params: ControllerParams
    nodes: Tuple[NodeSpec, ...] = ()
    signal: Union[Signal, str] = Signal.LATEST
    window: int = 8
    ewma_alpha: float = 0.5
    backend: str = "array"
    history: int = DEFAULT_HISTORY
    eviction: str = "lfu"
    transport: Union[MessageBus, Callable[[], MessageBus], None] = None
    record: int = 0

    def __post_init__(self) -> None:
        if self.backend not in BACKENDS:
            raise ValueError(f"backend must be one of {BACKENDS}")
        if self.record < 0:
            raise ValueError("record must be >= 0 (ring capacity)")
        object.__setattr__(self, "nodes", tuple(self.nodes))
        object.__setattr__(self, "signal", Signal.coerce(self.signal))

    def replace(self, **kw) -> "PlaneSpec":
        """A modified copy -- the composition hook for nestable planes.

        ``repro.fleet`` derives each tenant's *inner* spec from the
        declared one: budget-sized ``params`` (the tenant's grant plays
        the role of ``total_memory``) and budget-reporting monitors
        wrapped around the declared ones, with everything else -- nodes,
        stores, signal, transport -- carried over unchanged.
        """
        return dataclasses.replace(self, **kw)

    def make_bus(self) -> MessageBus:
        if self.transport is None:
            return MessageBus()
        if isinstance(self.transport, MessageBus):
            return self.transport
        return self.transport()


# ---------------------------------------------------------------------------
# Batched controller backend
# ---------------------------------------------------------------------------

def make_fused_step(params: ControllerParams):
    """Build the jitted fleet update for one set of law gains.

    Gains (``r0``/``lam``/``lam_grant``/``deadband``/``feedforward``)
    are baked in as trace-time constants; capacities ``(u, v, v_prev,
    M, u_min, u_max)`` are per-node ``(N,)`` arrays.  ``mask`` selects
    the nodes observed this interval -- unobserved nodes pass through
    unchanged, matching the event-driven scalar backend.
    """
    ff = params.feedforward

    def fused(u, v, v_prev, has_prev, mask, m, u_min, u_max):
        # Trace-time recompile counter: fires once per XLA compile, so
        # the sanitizer fixtures can assert the fleet shape is stable.
        record_trace("plane.fused_step", nodes=int(u.shape[0]))
        # A node with no previous observation runs without feedforward:
        # substituting v for v_prev zeroes the slope term exactly.
        vp = jnp.where(has_prev, v_prev, v) if ff > 0.0 else None
        u_next = vectorized_step(
            u, v, total_memory=m, r0=params.r0, lam=params.lam,
            u_min=u_min, u_max=u_max, lam_grant=params.lam_grant,
            deadband=params.deadband, v_prev=vp, feedforward=ff)
        return jnp.where(mask, u_next, u)

    return jax.jit(fused)


_CAPACITY_FIELDS = ("total_memory", "u_min", "u_max")


class ArrayController:
    """Batched controller: all nodes' Eq. 1 in one fused jitted update.

    State lives in packed per-node arrays; ``observe`` only buffers the
    interval's aggregates (coalescing to the latest per node) and
    ``flush`` runs the whole fleet's control law as a single XLA call,
    then actuates each observed node's registry.  Decision cost per
    interval is one dispatch regardless of fleet size -- the scaling
    property the scalar per-node Python loop cannot deliver.

    Per-node ``params`` overrides may vary only capacity fields
    (``total_memory``/``u_min``/``u_max``); gains are trace-time
    constants shared by the fleet.
    """

    def __init__(
        self,
        params: ControllerParams,
        bus: Optional[MessageBus] = None,
        signal: Signal | str = Signal.LATEST,
        max_history: int = DEFAULT_HISTORY,
    ) -> None:
        self.params = params                      # guarded-by: _lock
        self.signal = Signal.coerce(signal)
        self._bus = bus
        self._lock = threading.RLock()
        self._epoch = 0                           # guarded-by: _lock
        self._history = ActionHistory(max_history)
        self._names: List[str] = []               # guarded-by: _lock
        self._index: Dict[str, int] = {}          # guarded-by: _lock
        self._registries: List[StoreRegistry] = []  # guarded-by: _lock
        self._u = np.zeros(0, np.float64)         # guarded-by: _lock
        self._v_prev = np.zeros(0, np.float64)    # guarded-by: _lock
        self._has_prev = np.zeros(0, bool)        # guarded-by: _lock
        self._m = np.zeros(0, np.float64)         # guarded-by: _lock
        self._u_min = np.zeros(0, np.float64)     # guarded-by: _lock
        self._u_max = np.zeros(0, np.float64)     # guarded-by: _lock
        self._pending: Dict[str, AggregatedMetrics] = {}  # guarded-by: _lock
        self._fused = make_fused_step(params)     # guarded-by: _lock
        if bus is not None:
            bus.subscribe(AGG_TOPIC, self.observe)

    # -- wiring -------------------------------------------------------------
    def attach_node(self, node: str, registry: StoreRegistry,
                    u0: Optional[float] = None,
                    params: Optional[ControllerParams] = None) -> None:
        p = params or self.params
        if params is not None:
            for f in dataclasses.fields(params):
                if f.name in _CAPACITY_FIELDS:
                    continue
                if getattr(params, f.name) != getattr(self.params, f.name):
                    raise ValueError(
                        "ArrayController per-node overrides may only vary "
                        f"{_CAPACITY_FIELDS}; {f.name!r} differs (gains are "
                        "fused trace-time constants)")
        with self._lock:
            if node in self._index:
                raise ValueError(f"node {node!r} already attached")
            u = registry.total_capacity() if u0 is None else float(u0)
            self._index[node] = len(self._names)
            self._names.append(node)
            self._registries.append(registry)
            self._u = np.append(self._u, u)
            self._v_prev = np.append(self._v_prev, 0.0)
            self._has_prev = np.append(self._has_prev, False)
            self._m = np.append(self._m, p.total_memory)
            self._u_min = np.append(self._u_min, p.u_min)
            self._u_max = np.append(self._u_max, p.u_max)

    def nodes(self) -> List[str]:
        with self._lock:
            return list(self._names)

    def node_capacity(self, node: str) -> float:
        with self._lock:
            return float(self._u[self._index[node]])

    # -- online re-parameterization -----------------------------------------
    @property
    def epoch(self) -> int:
        """Parameter generation: 0 at construction, +1 per swap."""
        with self._lock:
            return self._epoch

    def prewarm(self, params: ControllerParams):
        """Build + warm the fused step for ``params`` off the hot path.

        Compiles the new gains' executable against the current fleet
        shape so a subsequent :meth:`swap_params` is a pointer flip --
        the control loop never waits on XLA.  If the fleet grows
        between warm and commit, the next flush just recompiles.
        """
        fused = make_fused_step(params)
        with self._lock:
            shape_snap = (self._u.copy(), self._v_prev.copy(),
                          self._has_prev.copy(), self._m.copy(),
                          self._u_min.copy(), self._u_max.copy())
        if shape_snap[0].size:
            u, v_prev, has_prev, m, u_min, u_max = shape_snap
            jax.block_until_ready(fused(
                jnp.asarray(u, jnp.float32), jnp.asarray(v_prev, jnp.float32),
                jnp.asarray(v_prev, jnp.float32), jnp.asarray(has_prev),
                jnp.zeros(u.shape, bool), jnp.asarray(m, jnp.float32),
                jnp.asarray(u_min, jnp.float32),
                jnp.asarray(u_max, jnp.float32)))
        return fused

    def swap_params(self, params: ControllerParams, fused=None) -> int:
        """Atomically replace the fleet's law gains in a running plane.

        The swap itself is a pointer flip under the controller lock at
        an interval boundary; pass a :meth:`prewarm`-built ``fused``
        step to keep the XLA compile off the locked path (the
        ``MemoryPlane`` facade does).  Control state (``u``,
        ``v_prev``) carries over; capacity bounds (``u_min`` /
        ``u_max`` / ``M``) move with the swap for every node still on
        the old plane-level defaults, while per-node overrides
        (heterogeneous fleets) are preserved.  Returns the new
        parameter epoch; subsequent actions are stamped with it.
        """
        if fused is None:
            fused = self.prewarm(params)
        with self._lock:
            old = self.params
            for arr, prev, new in ((self._m, old.total_memory,
                                    params.total_memory),
                                   (self._u_min, old.u_min, params.u_min),
                                   (self._u_max, old.u_max, params.u_max)):
                arr[arr == prev] = new
            self.params = params
            self._fused = fused
            self._epoch += 1
            return self._epoch

    # -- bounded action history ---------------------------------------------
    @property
    def actions(self) -> List[ControlAction]:
        return self._history.snapshot()

    def recent(self, n: Optional[int] = None,
               node: Optional[str] = None) -> List[ControlAction]:
        return self._history.snapshot(node=node, limit=n)

    # -- control ------------------------------------------------------------
    def observe(self, agg: AggregatedMetrics) -> None:
        """Buffer one node's aggregate for the next ``flush``.

        Multiple observations of a node within one interval coalesce to
        the latest (the batched law steps once per interval)."""
        with self._lock:
            self._pending[agg.node] = agg

    def flush(self) -> List[ControlAction]:
        """One control interval: fused decide, then per-node actuation."""
        with self._lock:
            pending, self._pending = self._pending, {}
            observed = sorted(
                (self._index[n], n, a) for n, a in pending.items()
                if n in self._index)
            if not observed:
                return []
            n_nodes = self._u.size
            mask = np.zeros(n_nodes, bool)
            v = self._v_prev.copy()      # placeholder; masked out below
            for i, _, agg in observed:
                mask[i] = True
                v[i] = self.signal.pick(agg)
                if agg.total > 0 and agg.total != self._m[i]:
                    self._m[i] = agg.total
            u_next = np.asarray(self._fused(
                jnp.asarray(self._u, jnp.float32),
                jnp.asarray(v, jnp.float32),
                jnp.asarray(self._v_prev, jnp.float32),
                jnp.asarray(self._has_prev),
                jnp.asarray(mask),
                jnp.asarray(self._m, jnp.float32),
                jnp.asarray(self._u_min, jnp.float32),
                jnp.asarray(self._u_max, jnp.float32),
            ), np.float64)
            actions: List[ControlAction] = []
            for i, name, agg in observed:
                reports = self._registries[i].apply_capacity(u_next[i])
                action = ControlAction(
                    node=name, timestamp=agg.timestamp,
                    u_prev=float(self._u[i]), u_next=float(u_next[i]),
                    utilization=v[i] / agg.total if agg.total else 0.0,
                    reports=reports, epoch=self._epoch)
                actions.append(action)
                self._history.append(action)
                self._u[i] = u_next[i]
                self._v_prev[i] = v[i]
                self._has_prev[i] = True
        if self._bus is not None:
            for action in actions:
                self._bus.publish(CONTROL_TOPIC, action)
        return actions

    def squeeze(self, node: str, factor: float) -> bool:
        """Transient capacity clamp (see DynIMSController.squeeze)."""
        with self._lock:
            i = self._index.get(node)
            if i is None:
                return False
            self._registries[i].apply_capacity(
                float(self._u[i]) * float(factor))
            return True


# ---------------------------------------------------------------------------
# The facade
# ---------------------------------------------------------------------------

class MemoryPlane:
    """Declarative facade over the full DynIMS pipeline.

    Wires monitor -> bus(RAW) -> aggregator -> bus(AGG) -> controller
    backend for every declared/attached node and drives them all from
    one ``tick`` (the control interval T).  ``run``/``start``/``stop``
    tick in real time on a daemon thread; ``tick`` is used by tests, the
    simulator, and the trainer (which ticks from its step loop).  The
    plane is restartable and usable as a context manager.
    """

    def __init__(self, spec: PlaneSpec) -> None:
        self.spec = spec
        self.signal = spec.signal
        self.bus = spec.make_bus()
        self.aggregator = MetricAggregator(
            window=spec.window, ewma_alpha=spec.ewma_alpha, bus=self.bus)
        if spec.backend == "scalar":
            self.controller: Union[DynIMSController, ArrayController] = \
                DynIMSController(spec.params, bus=self.bus,
                                 signal=spec.signal,
                                 max_history=spec.history,
                                 track_fresh=True)   # tick() drains
        else:
            self.controller = ArrayController(
                spec.params, bus=self.bus, signal=spec.signal,
                max_history=spec.history)
        self._monitors: Dict[str, MemoryMonitor] = {}  # guarded-by: _lock
        self._lock = threading.RLock()
        # Serializes whole control intervals against hot-swaps: tick()
        # holds it for the full sample -> decide -> actuate pipeline, so
        # swap_params always lands at an interval boundary (never a
        # half-updated fleet).
        self._tick_lock = threading.Lock()
        self.recorder: Optional[TraceRecorder] = (  # guarded-by: _tick_lock
            TraceRecorder(spec.record) if spec.record else None)
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        for node_spec in spec.nodes:
            self._attach_spec(node_spec)

    @classmethod
    def for_scenario(cls, scenario: str, *,
                     nodes: Iterable[NodeSpec] = (),
                     **spec_kw) -> "MemoryPlane":
        """A plane running the ScenarioLab-tuned gains for ``scenario``.

        Looks the named scenario up in the checked-in preset registry
        (``repro.configs.dynims.tuned_params``; ``paper-*`` names map
        to Table I) and builds a :class:`PlaneSpec` around it --
        remaining keywords pass through to the spec::

            plane = MemoryPlane.for_scenario("bursty-serving",
                                             nodes=(NodeSpec(...),))
        """
        from ..configs.dynims import tuned_params
        return cls(PlaneSpec(params=tuned_params(scenario),
                             nodes=tuple(nodes), **spec_kw))

    # -- wiring -------------------------------------------------------------
    def _attach_spec(self, ns: NodeSpec) -> StoreRegistry:
        return self.attach(ns.name, ns.monitor, ns.registry,
                           stores=ns.stores, u0=ns.u0, params=ns.params)

    def attach(
        self,
        node: str,
        monitor: MemoryMonitor,
        registry: Optional[StoreRegistry] = None,
        *,
        stores: Iterable[Union[StoreSpec, Tuple[ManagedStore, float]]] = (),
        u0: Optional[float] = None,
        params: Optional[ControllerParams] = None,
    ) -> StoreRegistry:
        """Bring one node under control; returns its registry.

        Either pass a pre-built ``registry`` or an iterable of
        :class:`StoreSpec` / ``(store, max_bytes)`` pairs (not both)."""
        registry = NodeSpec(node, monitor, stores=tuple(stores),
                            registry=registry).build_registry()
        with self._lock:
            self._monitors[node] = monitor
            self.controller.attach_node(node, registry, u0=u0, params=params)
        return registry

    def build_cache(self, name: str, capacity: float, *,
                    policy: Optional[str] = None, priority: int = 0,
                    **kw) -> ShardCache:
        """A ShardCache with the plane's declared eviction default."""
        return ShardCache(name, capacity=capacity,
                          policy=policy or self.spec.eviction,
                          priority=priority, **kw)

    # -- introspection ------------------------------------------------------
    def nodes(self) -> List[str]:
        return self.controller.nodes()

    def capacity(self, node: str) -> float:
        """Current granted storage capacity ``u`` for ``node`` (bytes)."""
        return self.controller.node_capacity(node)

    def actions(self, node: Optional[str] = None,
                limit: Optional[int] = None) -> List[ControlAction]:
        """Bounded, thread-safe view of recent control actions."""
        return self.controller.recent(n=limit, node=node)

    def squeeze(self, node: str, factor: float) -> bool:
        """Transiently clamp a node's stores to ``factor`` of its grant
        (straggler/burst mitigation); the law re-grants next interval."""
        return self.controller.squeeze(node, factor)

    # -- ReplayLoop: capture and hot-swap ------------------------------------
    @property
    def params(self) -> ControllerParams:
        """The plane-level law parameters currently in force."""
        return self.controller.params

    @property
    def epoch(self) -> int:
        """Current parameter epoch (0 until the first hot-swap)."""
        return self.controller.epoch

    def record(self, capacity: int = DEFAULT_TRACE_CAPACITY) -> TraceRecorder:
        """Start (or restart) trace capture; returns the live recorder.

        Swaps under the tick lock so a concurrently running interval
        never records half to the old ring and half to the new one.
        """
        with self._tick_lock:
            self.recorder = TraceRecorder(capacity)
            return self.recorder

    def capture(self) -> CapturedTrace:
        """Snapshot the recorded ring as a :class:`CapturedTrace`.

        Raises if the plane was never recording (``PlaneSpec(record=N)``
        or :meth:`record`) or no interval has been ticked yet.
        """
        if self.recorder is None:
            raise ValueError(
                "plane is not recording; build it with PlaneSpec(record=N) "
                "or call plane.record() first")
        return self.recorder.snapshot(
            interval_s=self.controller.params.interval_s)

    def swap_params(self, params: ControllerParams) -> int:
        """Hot-swap the control-law parameters of a *running* plane.

        Delegates to the backend's atomic ``swap_params`` while holding
        the tick lock, so the swap always lands between control
        intervals: every interval runs wholly under one parameter
        epoch, and the :class:`ControlAction` history stays
        epoch-monotone with no dropped or duplicated interval.  The
        array backend's new executable is compiled and warmed *before*
        the lock is taken, so a concurrently ticking loop never waits
        on XLA.  The ``retune_online`` loop (``repro.lab.tune``) calls
        this from its tuning thread.
        """
        prewarm = getattr(self.controller, "prewarm", None)
        fused = prewarm(params) if prewarm is not None else None
        with self._tick_lock:
            if fused is not None:
                return self.controller.swap_params(params, fused=fused)
            return self.controller.swap_params(params)

    # -- control loop -------------------------------------------------------
    def tick(self) -> List[ControlAction]:
        """One control interval: sample every node, run the law once."""
        with self._tick_lock:
            with self._lock:
                monitors = dict(self._monitors)
            samples = {name: mon.sample() for name, mon in monitors.items()}
            for sample in samples.values():
                self.bus.publish(RAW_TOPIC, sample)
            actions = self.controller.flush()
            if self.recorder is not None:
                self.recorder.record(samples, actions)
            return actions

    def run(self, duration_s: Optional[float] = None) -> None:
        """Tick in real time at ``params.interval_s`` until stopped."""
        deadline = (None if duration_s is None
                    else time.time() + duration_s)
        while not self._stop.is_set():
            t0 = time.time()
            self.tick()
            if deadline is not None and time.time() >= deadline:
                break
            sleep = self.controller.params.interval_s - (time.time() - t0)
            if sleep > 0:
                self._stop.wait(sleep)

    def start(self) -> None:
        """Start (or restart) the real-time loop on a daemon thread."""
        self.stop()
        self._stop.clear()
        self._thread = threading.Thread(target=self.run, daemon=True)
        self._thread.start()

    def stop(self) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=5.0)
            self._thread = None

    @property
    def running(self) -> bool:
        return self._thread is not None and self._thread.is_alive()

    def __enter__(self) -> "MemoryPlane":
        self.start()
        return self

    def __exit__(self, *exc) -> None:
        self.stop()


# ---------------------------------------------------------------------------
# Legacy shim
# ---------------------------------------------------------------------------

class ControlPlane(MemoryPlane):
    """Deprecated: imperative predecessor of :class:`MemoryPlane`.

    Kept as a thin shim (scalar backend, old constructor signature) so
    existing callers keep working; new code should declare a
    :class:`PlaneSpec` and use :class:`MemoryPlane`.
    """

    def __init__(
        self,
        params: ControllerParams,
        window: int = 8,
        ewma_alpha: float = 0.5,
        signal: Signal | str = "latest",
        max_history: int = DEFAULT_HISTORY,
    ) -> None:
        warnings.warn(
            "ControlPlane is deprecated; declare a PlaneSpec and use "
            "MemoryPlane instead", DeprecationWarning, stacklevel=2)
        super().__init__(PlaneSpec(
            params=params, window=window, ewma_alpha=ewma_alpha,
            signal=signal, backend="scalar", history=max_history))
