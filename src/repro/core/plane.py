"""MemoryPlane: the declarative DynIMS control-plane API.

The paper's DynIMS is *one* controller service adapting in-memory
storage for all nodes from a single feedback loop (Eq. 1).  This module
is that service's API surface: consumers declare *what* they manage --
nodes, monitors, stores, eviction policy, signal, transport -- in a
:class:`PlaneSpec` and hand it to a :class:`MemoryPlane`; they never
touch bus/aggregator/controller internals.

    spec = PlaneSpec(
        params=paper_controller_params(),
        nodes=(NodeSpec("node0", monitor=mon,
                        stores=(StoreSpec(cache, max_bytes=60 * GiB),)),),
    )
    with MemoryPlane(spec) as plane:      # start()s the 100 ms loop
        ...                               # or: plane.tick() per interval
    print(plane.actions(node="node0", limit=8))

Two controller backends sit behind the facade:

* ``backend="scalar"`` -- :class:`~repro.core.controller.DynIMSController`,
  the float64 per-node reference implementation.
* ``backend="array"`` (default) -- :class:`ArrayController`, which packs
  every attached node's ``(u, v, v_prev, M, u_min, u_max)`` into arrays
  and runs **one fused, jitted** ``vectorized_step`` per control
  interval.  This is the backend that scales to 1000+ nodes: per tick it
  costs one XLA dispatch instead of N Python control-law evaluations
  (see ``benchmarks/controller_bench.py``).

A parity test (``tests/test_plane.py``) pins the two backends together
within 1e-4 relative tolerance across heterogeneous fleets.

``ControlPlane`` remains importable (also via its historical home
``repro.core.controller``) as a deprecated shim over the scalar backend.
"""

from __future__ import annotations

import dataclasses
import threading
import time
import warnings
from typing import Callable, Dict, Iterable, List, Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from .bus import MessageBus
from .control import ControllerParams, Signal, vectorized_step
from .controller import (ActionHistory, CONTROL_TOPIC, ControlAction,
                         DEFAULT_HISTORY, DynIMSController)
from .monitor import MemoryMonitor
from .store import ManagedStore, ShardCache, StoreRegistry
from .stream import AGG_TOPIC, RAW_TOPIC, AggregatedMetrics, MetricAggregator

BACKENDS = ("array", "scalar")


# ---------------------------------------------------------------------------
# Declarative spec
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class StoreSpec:
    """One managed store and the most memory it may ever be granted."""

    store: ManagedStore
    max_bytes: float


@dataclasses.dataclass(frozen=True)
class NodeSpec:
    """One controlled node: who observes it and what gets resized.

    ``stores`` builds a priority-waterfall :class:`StoreRegistry`;
    alternatively pass a pre-built ``registry``.  ``u0`` seeds the
    capacity state (default: the registry's current total capacity).
    ``params`` overrides the plane-level law parameters for this node --
    heterogeneous ``total_memory`` / ``u_min`` / ``u_max`` fleets.
    """

    name: str
    monitor: MemoryMonitor
    stores: Tuple[StoreSpec, ...] = ()
    registry: Optional[StoreRegistry] = None
    u0: Optional[float] = None
    params: Optional[ControllerParams] = None

    def build_registry(self) -> StoreRegistry:
        if self.registry is not None:
            if self.stores:
                raise ValueError(
                    "pass either stores or a pre-built registry, not both "
                    "(stores would be silently unmanaged)")
            return self.registry
        registry = StoreRegistry()
        for spec in self.stores:
            store, max_bytes = (
                (spec.store, spec.max_bytes) if isinstance(spec, StoreSpec)
                else (spec[0], spec[1]))
            registry.register(store, max_bytes=float(max_bytes))
        return registry


@dataclasses.dataclass(frozen=True)
class PlaneSpec:
    """Everything a control plane needs, declared up front.

    Fields:
      params:     plane-level Eq. 1 parameters (per-node overridable).
      nodes:      nodes managed from construction (more can ``attach``).
      signal:     which window aggregate drives the law (:class:`Signal`).
      window:     sliding-window length of the aggregator.
      ewma_alpha: EWMA smoothing factor of the aggregator.
      backend:    "array" (fused batched law) or "scalar" (reference).
      history:    bound on retained :class:`ControlAction` records.
      eviction:   default eviction policy for caches built through
                  :meth:`MemoryPlane.build_cache`.
      transport:  the message bus, or a factory for one (swap point for
                  a multi-host deployment); None -> in-process bus.
    """

    params: ControllerParams
    nodes: Tuple[NodeSpec, ...] = ()
    signal: Union[Signal, str] = Signal.LATEST
    window: int = 8
    ewma_alpha: float = 0.5
    backend: str = "array"
    history: int = DEFAULT_HISTORY
    eviction: str = "lfu"
    transport: Union[MessageBus, Callable[[], MessageBus], None] = None

    def __post_init__(self) -> None:
        if self.backend not in BACKENDS:
            raise ValueError(f"backend must be one of {BACKENDS}")
        object.__setattr__(self, "nodes", tuple(self.nodes))
        object.__setattr__(self, "signal", Signal.coerce(self.signal))

    def make_bus(self) -> MessageBus:
        if self.transport is None:
            return MessageBus()
        if isinstance(self.transport, MessageBus):
            return self.transport
        return self.transport()


# ---------------------------------------------------------------------------
# Batched controller backend
# ---------------------------------------------------------------------------

def make_fused_step(params: ControllerParams):
    """Build the jitted fleet update for one set of law gains.

    Gains (``r0``/``lam``/``lam_grant``/``deadband``/``feedforward``)
    are baked in as trace-time constants; capacities ``(u, v, v_prev,
    M, u_min, u_max)`` are per-node ``(N,)`` arrays.  ``mask`` selects
    the nodes observed this interval -- unobserved nodes pass through
    unchanged, matching the event-driven scalar backend.
    """
    ff = params.feedforward

    def fused(u, v, v_prev, has_prev, mask, m, u_min, u_max):
        # A node with no previous observation runs without feedforward:
        # substituting v for v_prev zeroes the slope term exactly.
        vp = jnp.where(has_prev, v_prev, v) if ff > 0.0 else None
        u_next = vectorized_step(
            u, v, total_memory=m, r0=params.r0, lam=params.lam,
            u_min=u_min, u_max=u_max, lam_grant=params.lam_grant,
            deadband=params.deadband, v_prev=vp, feedforward=ff)
        return jnp.where(mask, u_next, u)

    return jax.jit(fused)


_CAPACITY_FIELDS = ("total_memory", "u_min", "u_max")


class ArrayController:
    """Batched controller: all nodes' Eq. 1 in one fused jitted update.

    State lives in packed per-node arrays; ``observe`` only buffers the
    interval's aggregates (coalescing to the latest per node) and
    ``flush`` runs the whole fleet's control law as a single XLA call,
    then actuates each observed node's registry.  Decision cost per
    interval is one dispatch regardless of fleet size -- the scaling
    property the scalar per-node Python loop cannot deliver.

    Per-node ``params`` overrides may vary only capacity fields
    (``total_memory``/``u_min``/``u_max``); gains are trace-time
    constants shared by the fleet.
    """

    def __init__(
        self,
        params: ControllerParams,
        bus: Optional[MessageBus] = None,
        signal: Signal | str = Signal.LATEST,
        max_history: int = DEFAULT_HISTORY,
    ) -> None:
        self.params = params
        self.signal = Signal.coerce(signal)
        self._bus = bus
        self._lock = threading.RLock()
        self._history = ActionHistory(max_history)
        self._names: List[str] = []
        self._index: Dict[str, int] = {}
        self._registries: List[StoreRegistry] = []
        self._u = np.zeros(0, np.float64)
        self._v_prev = np.zeros(0, np.float64)
        self._has_prev = np.zeros(0, bool)
        self._m = np.zeros(0, np.float64)
        self._u_min = np.zeros(0, np.float64)
        self._u_max = np.zeros(0, np.float64)
        self._pending: Dict[str, AggregatedMetrics] = {}
        self._fused = make_fused_step(params)
        if bus is not None:
            bus.subscribe(AGG_TOPIC, self.observe)

    # -- wiring -------------------------------------------------------------
    def attach_node(self, node: str, registry: StoreRegistry,
                    u0: Optional[float] = None,
                    params: Optional[ControllerParams] = None) -> None:
        p = params or self.params
        if params is not None:
            for f in dataclasses.fields(params):
                if f.name in _CAPACITY_FIELDS:
                    continue
                if getattr(params, f.name) != getattr(self.params, f.name):
                    raise ValueError(
                        "ArrayController per-node overrides may only vary "
                        f"{_CAPACITY_FIELDS}; {f.name!r} differs (gains are "
                        "fused trace-time constants)")
        with self._lock:
            if node in self._index:
                raise ValueError(f"node {node!r} already attached")
            u = registry.total_capacity() if u0 is None else float(u0)
            self._index[node] = len(self._names)
            self._names.append(node)
            self._registries.append(registry)
            self._u = np.append(self._u, u)
            self._v_prev = np.append(self._v_prev, 0.0)
            self._has_prev = np.append(self._has_prev, False)
            self._m = np.append(self._m, p.total_memory)
            self._u_min = np.append(self._u_min, p.u_min)
            self._u_max = np.append(self._u_max, p.u_max)

    def nodes(self) -> List[str]:
        with self._lock:
            return list(self._names)

    def node_capacity(self, node: str) -> float:
        with self._lock:
            return float(self._u[self._index[node]])

    # -- bounded action history ---------------------------------------------
    @property
    def actions(self) -> List[ControlAction]:
        return self._history.snapshot()

    def recent(self, n: Optional[int] = None,
               node: Optional[str] = None) -> List[ControlAction]:
        return self._history.snapshot(node=node, limit=n)

    # -- control ------------------------------------------------------------
    def observe(self, agg: AggregatedMetrics) -> None:
        """Buffer one node's aggregate for the next ``flush``.

        Multiple observations of a node within one interval coalesce to
        the latest (the batched law steps once per interval)."""
        with self._lock:
            self._pending[agg.node] = agg

    def flush(self) -> List[ControlAction]:
        """One control interval: fused decide, then per-node actuation."""
        with self._lock:
            pending, self._pending = self._pending, {}
            observed = sorted(
                (self._index[n], n, a) for n, a in pending.items()
                if n in self._index)
            if not observed:
                return []
            n_nodes = self._u.size
            mask = np.zeros(n_nodes, bool)
            v = self._v_prev.copy()      # placeholder; masked out below
            for i, _, agg in observed:
                mask[i] = True
                v[i] = self.signal.pick(agg)
                if agg.total > 0 and agg.total != self._m[i]:
                    self._m[i] = agg.total
            u_next = np.asarray(self._fused(
                jnp.asarray(self._u, jnp.float32),
                jnp.asarray(v, jnp.float32),
                jnp.asarray(self._v_prev, jnp.float32),
                jnp.asarray(self._has_prev),
                jnp.asarray(mask),
                jnp.asarray(self._m, jnp.float32),
                jnp.asarray(self._u_min, jnp.float32),
                jnp.asarray(self._u_max, jnp.float32),
            ), np.float64)
            actions: List[ControlAction] = []
            for i, name, agg in observed:
                reports = self._registries[i].apply_capacity(u_next[i])
                action = ControlAction(
                    node=name, timestamp=agg.timestamp,
                    u_prev=float(self._u[i]), u_next=float(u_next[i]),
                    utilization=v[i] / agg.total if agg.total else 0.0,
                    reports=reports)
                actions.append(action)
                self._history.append(action)
                self._u[i] = u_next[i]
                self._v_prev[i] = v[i]
                self._has_prev[i] = True
        if self._bus is not None:
            for action in actions:
                self._bus.publish(CONTROL_TOPIC, action)
        return actions

    def squeeze(self, node: str, factor: float) -> bool:
        """Transient capacity clamp (see DynIMSController.squeeze)."""
        with self._lock:
            i = self._index.get(node)
            if i is None:
                return False
            self._registries[i].apply_capacity(
                float(self._u[i]) * float(factor))
            return True


# ---------------------------------------------------------------------------
# The facade
# ---------------------------------------------------------------------------

class MemoryPlane:
    """Declarative facade over the full DynIMS pipeline.

    Wires monitor -> bus(RAW) -> aggregator -> bus(AGG) -> controller
    backend for every declared/attached node and drives them all from
    one ``tick`` (the control interval T).  ``run``/``start``/``stop``
    tick in real time on a daemon thread; ``tick`` is used by tests, the
    simulator, and the trainer (which ticks from its step loop).  The
    plane is restartable and usable as a context manager.
    """

    def __init__(self, spec: PlaneSpec) -> None:
        self.spec = spec
        self.signal = spec.signal
        self.bus = spec.make_bus()
        self.aggregator = MetricAggregator(
            window=spec.window, ewma_alpha=spec.ewma_alpha, bus=self.bus)
        if spec.backend == "scalar":
            self.controller: Union[DynIMSController, ArrayController] = \
                DynIMSController(spec.params, bus=self.bus,
                                 signal=spec.signal,
                                 max_history=spec.history,
                                 track_fresh=True)   # tick() drains
        else:
            self.controller = ArrayController(
                spec.params, bus=self.bus, signal=spec.signal,
                max_history=spec.history)
        self._monitors: Dict[str, MemoryMonitor] = {}
        self._lock = threading.RLock()
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        for node_spec in spec.nodes:
            self._attach_spec(node_spec)

    @classmethod
    def for_scenario(cls, scenario: str, *,
                     nodes: Iterable[NodeSpec] = (),
                     **spec_kw) -> "MemoryPlane":
        """A plane running the ScenarioLab-tuned gains for ``scenario``.

        Looks the named scenario up in the checked-in preset registry
        (``repro.configs.dynims.tuned_params``; ``paper-*`` names map
        to Table I) and builds a :class:`PlaneSpec` around it --
        remaining keywords pass through to the spec::

            plane = MemoryPlane.for_scenario("bursty-serving",
                                             nodes=(NodeSpec(...),))
        """
        from ..configs.dynims import tuned_params
        return cls(PlaneSpec(params=tuned_params(scenario),
                             nodes=tuple(nodes), **spec_kw))

    # -- wiring -------------------------------------------------------------
    def _attach_spec(self, ns: NodeSpec) -> StoreRegistry:
        return self.attach(ns.name, ns.monitor, ns.registry,
                           stores=ns.stores, u0=ns.u0, params=ns.params)

    def attach(
        self,
        node: str,
        monitor: MemoryMonitor,
        registry: Optional[StoreRegistry] = None,
        *,
        stores: Iterable[Union[StoreSpec, Tuple[ManagedStore, float]]] = (),
        u0: Optional[float] = None,
        params: Optional[ControllerParams] = None,
    ) -> StoreRegistry:
        """Bring one node under control; returns its registry.

        Either pass a pre-built ``registry`` or an iterable of
        :class:`StoreSpec` / ``(store, max_bytes)`` pairs (not both)."""
        registry = NodeSpec(node, monitor, stores=tuple(stores),
                            registry=registry).build_registry()
        with self._lock:
            self._monitors[node] = monitor
            self.controller.attach_node(node, registry, u0=u0, params=params)
        return registry

    def build_cache(self, name: str, capacity: float, *,
                    policy: Optional[str] = None, priority: int = 0,
                    **kw) -> ShardCache:
        """A ShardCache with the plane's declared eviction default."""
        return ShardCache(name, capacity=capacity,
                          policy=policy or self.spec.eviction,
                          priority=priority, **kw)

    # -- introspection ------------------------------------------------------
    def nodes(self) -> List[str]:
        return self.controller.nodes()

    def capacity(self, node: str) -> float:
        """Current granted storage capacity ``u`` for ``node`` (bytes)."""
        return self.controller.node_capacity(node)

    def actions(self, node: Optional[str] = None,
                limit: Optional[int] = None) -> List[ControlAction]:
        """Bounded, thread-safe view of recent control actions."""
        return self.controller.recent(n=limit, node=node)

    def squeeze(self, node: str, factor: float) -> bool:
        """Transiently clamp a node's stores to ``factor`` of its grant
        (straggler/burst mitigation); the law re-grants next interval."""
        return self.controller.squeeze(node, factor)

    # -- control loop -------------------------------------------------------
    def tick(self) -> List[ControlAction]:
        """One control interval: sample every node, run the law once."""
        with self._lock:
            monitors = list(self._monitors.values())
        for monitor in monitors:
            self.bus.publish(RAW_TOPIC, monitor.sample())
        return self.controller.flush()

    def run(self, duration_s: Optional[float] = None) -> None:
        """Tick in real time at ``params.interval_s`` until stopped."""
        deadline = (None if duration_s is None
                    else time.time() + duration_s)
        while not self._stop.is_set():
            t0 = time.time()
            self.tick()
            if deadline is not None and time.time() >= deadline:
                break
            sleep = self.spec.params.interval_s - (time.time() - t0)
            if sleep > 0:
                self._stop.wait(sleep)

    def start(self) -> None:
        """Start (or restart) the real-time loop on a daemon thread."""
        self.stop()
        self._stop.clear()
        self._thread = threading.Thread(target=self.run, daemon=True)
        self._thread.start()

    def stop(self) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=5.0)
            self._thread = None

    @property
    def running(self) -> bool:
        return self._thread is not None and self._thread.is_alive()

    def __enter__(self) -> "MemoryPlane":
        self.start()
        return self

    def __exit__(self, *exc) -> None:
        self.stop()


# ---------------------------------------------------------------------------
# Legacy shim
# ---------------------------------------------------------------------------

class ControlPlane(MemoryPlane):
    """Deprecated: imperative predecessor of :class:`MemoryPlane`.

    Kept as a thin shim (scalar backend, old constructor signature) so
    existing callers keep working; new code should declare a
    :class:`PlaneSpec` and use :class:`MemoryPlane`.
    """

    def __init__(
        self,
        params: ControllerParams,
        window: int = 8,
        ewma_alpha: float = 0.5,
        signal: Signal | str = "latest",
        max_history: int = DEFAULT_HISTORY,
    ) -> None:
        warnings.warn(
            "ControlPlane is deprecated; declare a PlaneSpec and use "
            "MemoryPlane instead", DeprecationWarning, stacklevel=2)
        super().__init__(PlaneSpec(
            params=params, window=window, ewma_alpha=ewma_alpha,
            signal=signal, backend="scalar", history=max_history))
