"""MemoryPlane: the declarative DynIMS control-plane API.

The paper's DynIMS is *one* controller service adapting in-memory
storage for all nodes from a single feedback loop (Eq. 1).  This module
is that service's API surface: consumers declare *what* they manage --
nodes, monitors, stores, eviction policy, signal, transport -- in a
:class:`PlaneSpec` and hand it to a :class:`MemoryPlane`; they never
touch bus/aggregator/controller internals.

    spec = PlaneSpec(
        params=paper_controller_params(),
        nodes=(NodeSpec("node0", monitor=mon,
                        stores=(StoreSpec(cache, max_bytes=60 * GiB),)),),
    )
    with MemoryPlane(spec) as plane:      # start()s the 100 ms loop
        ...                               # or: plane.tick() per interval
    print(plane.actions(node="node0", limit=8))

Two controller backends sit behind the facade:

* ``backend="scalar"`` -- :class:`~repro.core.controller.DynIMSController`,
  the float64 per-node reference implementation.
* ``backend="array"`` (default) -- :class:`ArrayController`, which packs
  every attached node's ``(u, v, v_prev, M, u_min, u_max)`` into arrays
  and runs **one fused, jitted** ``vectorized_step`` per control
  interval.  This is the backend that scales to 1000+ nodes: per tick it
  costs one XLA dispatch instead of N Python control-law evaluations
  (see ``benchmarks/controller_bench.py``).

A parity test (``tests/test_plane.py``) pins the two backends together
within 1e-4 relative tolerance across heterogeneous fleets.

**ReplayLoop** hooks live here too: a plane built with
``PlaneSpec(record=N)`` (or ``plane.record()``) keeps the last ``N``
control intervals of per-node ``(demand, utilization, grant, cache
residency)`` in a bounded :class:`TraceRecorder` ring; ``capture()``
snapshots it as a :class:`CapturedTrace` (dense numpy, ``.npz``
round-trippable) that ``ScenarioSpec.from_capture`` turns into a
sweepable replay scenario, and :meth:`MemoryPlane.swap_params`
hot-swaps re-tuned :class:`ControllerParams` into the *running* plane
at an interval boundary -- both backends re-specialize without
dropping a tick, and every action is stamped with the parameter epoch.

``ControlPlane`` remains importable (also via its historical home
``repro.core.controller``) as a deprecated shim over the scalar backend.
"""

from __future__ import annotations

import dataclasses
import enum
import math
import threading
import time
import warnings
import zlib
from collections import deque
from typing import Callable, Dict, Iterable, List, Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from ..analysis.runtime import record_trace
from .bus import MessageBus
from .control import ControllerParams, Signal, vectorized_step
from .controller import (ActionHistory, CONTROL_TOPIC, ControlAction,
                         DEFAULT_HISTORY, DynIMSController)
from .monitor import MemoryMonitor
from .monitor import MemorySample
from .store import ManagedStore, ShardCache, StoreRegistry
from .stream import AGG_TOPIC, RAW_TOPIC, AggregatedMetrics, MetricAggregator

BACKENDS = ("array", "scalar")

#: Default ring-buffer capacity (control intervals) of a TraceRecorder.
DEFAULT_TRACE_CAPACITY = 4096


# ---------------------------------------------------------------------------
# ReplayLoop: live-trace capture
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True, eq=False)
class CapturedTrace:
    """A dense snapshot of what a running plane observed and decided.

    All arrays are numpy, node-major: ``(N, T)`` over the captured
    control intervals (``total_memory`` is ``(N,)``).  ``demand`` is the
    compute tenant's usage (``used - storage_used``, bytes) -- the
    quantity a replay scenario feeds back through the sweep engine;
    ``utilization`` is the observed ``v / M``; ``grant`` the
    controller's post-decision capacity ``u``; ``residency`` the bytes
    the managed stores actually held (the CacheLoop observable).

    Serializable: :meth:`save` writes one compressed ``.npz``,
    :meth:`load` restores it bit-for-bit.
    """

    nodes: Tuple[str, ...]
    interval_s: float
    demand: np.ndarray
    utilization: np.ndarray
    grant: np.ndarray
    residency: np.ndarray
    total_memory: np.ndarray

    @property
    def n_nodes(self) -> int:
        return self.demand.shape[0]

    @property
    def n_intervals(self) -> int:
        return self.demand.shape[1]

    @property
    def duration_s(self) -> float:
        return self.n_intervals * self.interval_s

    def utilization_p99(self) -> float:
        """Observed fleet p99 utilization (replay-fidelity yardstick)."""
        return float(np.quantile(self.utilization, 0.99))

    def has_residency(self) -> bool:
        """Did the managed stores ever hold bytes during the capture?"""
        return bool(np.nanmax(self.residency, initial=0.0) > 0.0)

    def save(self, path) -> None:
        np.savez_compressed(
            path, nodes=np.asarray(self.nodes, dtype=np.str_),
            interval_s=np.float64(self.interval_s), demand=self.demand,
            utilization=self.utilization, grant=self.grant,
            residency=self.residency, total_memory=self.total_memory)

    @classmethod
    def load(cls, path) -> "CapturedTrace":
        with np.load(path, allow_pickle=False) as z:
            return cls(nodes=tuple(str(n) for n in z["nodes"]),
                       interval_s=float(z["interval_s"]),
                       demand=z["demand"], utilization=z["utilization"],
                       grant=z["grant"], residency=z["residency"],
                       total_memory=z["total_memory"])


class TraceRecorder:
    """Bounded, thread-safe ring buffer of per-tick fleet snapshots.

    :meth:`MemoryPlane.tick` feeds it one record per control interval
    (the interval's monitor samples plus the actions the controller
    produced); the ring retains the last ``capacity`` intervals, so a
    long-running deployment pays O(capacity * fleet) memory however
    long it runs.  :meth:`snapshot` densifies the ring into a
    :class:`CapturedTrace`; nodes that joined late or skipped an
    interval are forward/backward-filled so the arrays stay rectangular.
    """

    def __init__(self, capacity: int = DEFAULT_TRACE_CAPACITY):
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        self.capacity = capacity
        self._lock = threading.Lock()
        self._ring: deque = deque(maxlen=capacity)  # guarded-by: _lock

    def __len__(self) -> int:
        with self._lock:
            return len(self._ring)

    def clear(self) -> None:
        with self._lock:
            self._ring.clear()

    def record(self, samples: Dict[str, MemorySample],
               actions: List[ControlAction]) -> None:
        """Append one control interval's observations and decisions."""
        grant = {a.node: a.u_next for a in actions}
        tick = {
            node: (max(s.used - s.storage_used, 0.0), s.used, s.total,
                   grant.get(node, np.nan), s.storage_used)
            for node, s in samples.items()}
        with self._lock:
            self._ring.append(tick)

    def snapshot(self, interval_s: float = 0.1) -> CapturedTrace:
        """Densify the ring into a :class:`CapturedTrace` (numpy)."""
        with self._lock:
            ring = list(self._ring)
        if not ring:
            raise ValueError("nothing recorded yet")
        names = sorted({n for tick in ring for n in tick})
        n, t = len(names), len(ring)
        idx = {name: i for i, name in enumerate(names)}
        demand = np.full((n, t), np.nan)
        usage = np.full((n, t), np.nan)
        total = np.full((n, t), np.nan)
        grant = np.full((n, t), np.nan)
        residency = np.full((n, t), np.nan)
        for j, tick in enumerate(ring):
            for name, (d, v, m, u, res) in tick.items():
                i = idx[name]
                demand[i, j] = d
                usage[i, j] = v
                total[i, j] = m
                grant[i, j] = u
                residency[i, j] = res
        for arr in (demand, usage, total, grant, residency):
            _fill_gaps(arr)
        with np.errstate(invalid="ignore", divide="ignore"):
            utilization = np.where(total > 0, usage / total, 0.0)
        return CapturedTrace(
            nodes=tuple(names), interval_s=float(interval_s),
            demand=demand, utilization=utilization, grant=grant,
            residency=residency, total_memory=total[:, -1].copy())


def _fill_gaps(arr: np.ndarray) -> None:
    """In-place forward- then backward-fill NaN runs along axis 1."""
    n, t = arr.shape
    for i in range(n):
        row = arr[i]
        mask = np.isnan(row)
        if not mask.any():
            continue
        if mask.all():
            row[:] = 0.0
            continue
        valid = np.flatnonzero(~mask)
        # forward fill from the previous valid sample, backward fill the
        # leading gap from the first one
        fill_idx = np.clip(
            np.maximum.accumulate(np.where(mask, -1, np.arange(t))),
            valid[0], None)
        row[:] = row[fill_idx]


# ---------------------------------------------------------------------------
# ChaosPlane: telemetry health, fault log, fail-static degradation
# ---------------------------------------------------------------------------
#
# DynIMS's contract is that dynamic control must never be *worse* than
# the static allocation it replaces (PAPER.md Sec. III): a late, frozen,
# or non-finite observation acted on verbatim is exactly the
# swap-storming failure the feedback model exists to prevent.  The
# health layer below sits between the monitors and the law:
#
#     healthy --bad sample--> stale (publish last-good holdover)
#     stale   --stale_budget exceeded--> quarantined (fail-static pin)
#     quarantined --rejoin_intervals consecutive good--> healthy
#
# A quarantined node is pinned to the conservative fail-static grant
# derived from ``u_min`` (the paper's most compute-protective static
# configuration; Liang et al. arxiv 1712.05554 make the same move when
# the workload model is unreliable) and its telemetry stops feeding the
# law until the rejoin hysteresis clears.  Actuation failures never
# abort an interval: they degrade to bounded, jittered exponential
# backoff in *intervals* (no sleeping under any lock).

#: Default bound on retained fault events (per plane).
DEFAULT_FAULT_LOG = 256


class NodeHealth(enum.Enum):
    """Per-node telemetry health state."""

    HEALTHY = "healthy"
    STALE = "stale"
    QUARANTINED = "quarantined"


@dataclasses.dataclass(frozen=True)
class HealthPolicy:
    """Degradation policy of a :class:`MemoryPlane`.

    Fields:
      stale_budget:     consecutive bad intervals a node may ride on its
                        last-good holdover before quarantine.
      rejoin_intervals: consecutive good samples a quarantined node must
                        deliver before re-entering closed-loop control
                        (rejoin hysteresis -- a flapping sensor stays
                        quarantined).
      fail_static_fraction: where the fail-static pin sits in
                        ``[u_min, u_max]``; 0.0 (default) pins to
                        ``u_min``, the most conservative static grant.
      actuation_retries: consecutive actuation failures before the node
                        is reported actuation-degraded (retries continue
                        at the capped backoff).
      retry_backoff_cap: max backoff between actuation retries, in
                        control intervals (base 1, doubling, jittered).
      sample_deadline_s: monitor sample slower than this is treated as
                        stale -- a late observation is a wrong one
                        (paper Sec. II.B).  None disables.
      tick_deadline_s:  whole-tick watchdog; a slower interval is logged
                        as a ``tick-deadline`` fault.  None disables.
      fault_log:        bound on retained :class:`FaultEvent` records.
      seed:             seeds the retry jitter (deterministic tests).
    """

    stale_budget: int = 3
    rejoin_intervals: int = 5
    fail_static_fraction: float = 0.0
    actuation_retries: int = 3
    retry_backoff_cap: int = 16
    sample_deadline_s: Optional[float] = None
    tick_deadline_s: Optional[float] = None
    fault_log: int = DEFAULT_FAULT_LOG
    seed: int = 0

    def __post_init__(self) -> None:
        if self.stale_budget < 1:
            raise ValueError("stale_budget must be >= 1")
        if self.rejoin_intervals < 1:
            raise ValueError("rejoin_intervals must be >= 1")
        if not 0.0 <= self.fail_static_fraction <= 1.0:
            raise ValueError("fail_static_fraction must be in [0, 1]")
        if self.actuation_retries < 1:
            raise ValueError("actuation_retries must be >= 1")
        if self.retry_backoff_cap < 1:
            raise ValueError("retry_backoff_cap must be >= 1")
        if self.fault_log < 1:
            raise ValueError("fault_log must be >= 1")

    def fail_static_grant(self, u_min: float, u_max: float) -> float:
        """The static capacity a quarantined node is pinned to."""
        return u_min + self.fail_static_fraction * (u_max - u_min)

    def replace(self, **kw) -> "HealthPolicy":
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    """One observed fault, mirrored after :class:`ControlAction`."""

    kind: str                 # sample-error | telemetry-invalid | ...
    node: Optional[str]
    tick: int                 # plane tick index when observed
    timestamp: float
    detail: str = ""


class FaultLog:
    """Bounded, thread-safe log of fault events (cf. ActionHistory)."""

    def __init__(self, maxlen: int = DEFAULT_FAULT_LOG):
        if maxlen < 1:
            raise ValueError("fault log bound must be >= 1")
        self.maxlen = maxlen
        self._lock = threading.Lock()
        self._log: deque = deque(maxlen=maxlen)     # guarded-by: _lock
        self._counts: Dict[str, int] = {}           # guarded-by: _lock

    def append(self, event: FaultEvent) -> None:
        with self._lock:
            self._log.append(event)
            self._counts[event.kind] = self._counts.get(event.kind, 0) + 1

    def snapshot(self, kind: Optional[str] = None,
                 node: Optional[str] = None,
                 limit: Optional[int] = None) -> List[FaultEvent]:
        with self._lock:
            out = list(self._log)
        if kind is not None:
            out = [e for e in out if e.kind == kind]
        if node is not None:
            out = [e for e in out if e.node == node]
        if limit is not None:
            out = out[-limit:]
        return out

    def counts(self) -> Dict[str, int]:
        """Total events seen per kind (including evicted ones)."""
        with self._lock:
            return dict(self._counts)

    def __len__(self) -> int:
        with self._lock:
            return len(self._log)


def validate_sample(s: MemorySample) -> Optional[str]:
    """Why ``s`` must not reach the control law, or None if it may.

    Rejects non-finite, non-positive-total, and negative telemetry --
    the law divides by ``total`` and feeds ``used`` straight into the
    grant, so any of these would poison the fleet state arrays.
    """
    for name in ("used", "total", "storage_used", "swap_used"):
        v = getattr(s, name)
        if not isinstance(v, (int, float)) or not math.isfinite(v):
            return f"non-finite {name}={v!r}"
    if s.total <= 0:
        return f"non-positive total={s.total!r}"
    if s.used < 0 or s.storage_used < 0 or s.swap_used < 0:
        return (f"negative telemetry used={s.used} "
                f"storage={s.storage_used} swap={s.swap_used}")
    return None


class _NodeHealthState:
    """Mutable per-node health bookkeeping (guarded by the plane)."""

    __slots__ = ("state", "last_good", "stale_ticks", "good_streak",
                 "faults", "pin_grant")

    def __init__(self, pin_grant: float):
        self.state = NodeHealth.HEALTHY
        self.last_good: Optional[MemorySample] = None
        self.stale_ticks = 0
        self.good_streak = 0
        self.faults = 0
        self.pin_grant = float(pin_grant)


class _ResilientRegistry:
    """Actuation shield: a StoreRegistry whose failures never escape.

    A raising ``set_capacity`` (hung store, injected chaos, dead
    transport) must not abort the whole fleet's interval, and must not
    be hammered every tick while it is down.  Failures degrade to
    bounded retry with exponential backoff *measured in apply calls*
    (one per control interval) plus deterministic jitter -- nothing
    ever sleeps, so the plane's tick path stays lock-discipline clean.
    After ``actuation_retries`` consecutive failures the registry is
    reported degraded and keeps retrying at the capped backoff.
    """

    def __init__(self, inner: StoreRegistry, node: str,
                 policy: HealthPolicy, fault_log: FaultLog,
                 clock: Optional[Callable[[], int]] = None):
        self._inner = inner          # swapped by chaos injection proxies
        self._node = node
        self._policy = policy
        self._fault_log = fault_log
        self._clock = clock or (lambda: -1)
        self._lock = threading.Lock()
        self._failures = 0           # guarded-by: _lock (consecutive)
        self._skip = 0               # guarded-by: _lock (backoff budget)
        self._pending: Optional[float] = None   # guarded-by: _lock
        self._degraded = False       # guarded-by: _lock
        self._rng = np.random.default_rng(
            [policy.seed, zlib.crc32(node.encode())])  # guarded-by: _lock

    # -- delegation ---------------------------------------------------------
    def register(self, store: ManagedStore, max_bytes: float) -> None:
        self._inner.register(store, max_bytes)

    def stores(self) -> List[ManagedStore]:
        return self._inner.stores()

    def total_used(self) -> float:
        return self._inner.total_used()

    def total_capacity(self) -> float:
        return self._inner.total_capacity()

    # -- resilient actuation ------------------------------------------------
    def apply_capacity(self, u: float) -> list:
        with self._lock:
            if self._skip > 0:
                self._skip -= 1
                self._pending = float(u)
                return []
            inner = self._inner
        try:
            reports = inner.apply_capacity(u)
        except Exception as exc:
            self._on_failure(u, exc)
            return []
        with self._lock:
            recovered = self._failures > 0
            self._failures = 0
            self._skip = 0
            self._pending = None
            self._degraded = False
        if recovered:
            self._fault_log.append(FaultEvent(
                kind="actuation-recovered", node=self._node,
                tick=self._clock(), timestamp=time.time()))
        return reports

    def _on_failure(self, u: float, exc: BaseException) -> None:
        with self._lock:
            self._failures += 1
            backoff = min(2 ** (self._failures - 1),
                          self._policy.retry_backoff_cap)
            # jitter in [0, backoff): desynchronizes a fleet of nodes
            # whose stores all died in the same interval
            self._skip = backoff - 1 + int(self._rng.integers(0, backoff))
            self._pending = float(u)
            newly_degraded = (not self._degraded and
                              self._failures > self._policy.actuation_retries)
            if newly_degraded:
                self._degraded = True
            failures = self._failures
        self._fault_log.append(FaultEvent(
            kind="actuation-error", node=self._node, tick=self._clock(),
            timestamp=time.time(),
            detail=f"{type(exc).__name__}: {exc} (failure #{failures})"))
        if newly_degraded:
            self._fault_log.append(FaultEvent(
                kind="actuation-degraded", node=self._node,
                tick=self._clock(), timestamp=time.time(),
                detail=f"{failures} consecutive failures; retrying at "
                       f"<= {self._policy.retry_backoff_cap}-interval "
                       "backoff"))

    def status(self) -> Tuple[int, bool]:
        """(consecutive failures, degraded?) for the health report."""
        with self._lock:
            return self._failures, self._degraded


@dataclasses.dataclass(frozen=True)
class NodeHealthInfo:
    """One node's health as reported by :meth:`MemoryPlane.health`."""

    node: str
    state: NodeHealth
    stale_ticks: int
    good_streak: int
    faults: int
    pin_grant: float
    actuation_failures: int = 0
    actuation_degraded: bool = False


@dataclasses.dataclass(frozen=True)
class HealthReport:
    """Plane-wide degradation report (:meth:`MemoryPlane.health`)."""

    ticks: int
    deadline_misses: int
    nodes: Dict[str, NodeHealthInfo]
    fault_counts: Dict[str, int]

    def quarantined(self) -> List[str]:
        return [n for n, i in self.nodes.items()
                if i.state is NodeHealth.QUARANTINED]

    def degraded(self) -> List[str]:
        """Nodes not in closed-loop control or with failing actuation."""
        return [n for n, i in self.nodes.items()
                if i.state is not NodeHealth.HEALTHY or i.actuation_degraded]

    @property
    def healthy(self) -> bool:
        return not self.degraded() and self.deadline_misses == 0

    def summary(self) -> str:
        states = {s: 0 for s in NodeHealth}
        for info in self.nodes.values():
            states[info.state] += 1
        faults = sum(self.fault_counts.values())
        return (f"health: {states[NodeHealth.HEALTHY]} healthy / "
                f"{states[NodeHealth.STALE]} stale / "
                f"{states[NodeHealth.QUARANTINED]} quarantined of "
                f"{len(self.nodes)} nodes; {faults} faults, "
                f"{self.deadline_misses} deadline misses over "
                f"{self.ticks} ticks")


# ---------------------------------------------------------------------------
# Declarative spec
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class StoreSpec:
    """One managed store and the most memory it may ever be granted."""

    store: ManagedStore
    max_bytes: float


@dataclasses.dataclass(frozen=True)
class NodeSpec:
    """One controlled node: who observes it and what gets resized.

    ``stores`` builds a priority-waterfall :class:`StoreRegistry`;
    alternatively pass a pre-built ``registry``.  ``u0`` seeds the
    capacity state (default: the registry's current total capacity).
    ``params`` overrides the plane-level law parameters for this node --
    heterogeneous ``total_memory`` / ``u_min`` / ``u_max`` fleets.
    """

    name: str
    monitor: MemoryMonitor
    stores: Tuple[StoreSpec, ...] = ()
    registry: Optional[StoreRegistry] = None
    u0: Optional[float] = None
    params: Optional[ControllerParams] = None

    def replace(self, **kw) -> "NodeSpec":
        """A modified copy -- e.g. the same node under a wrapped monitor."""
        return dataclasses.replace(self, **kw)

    def build_registry(self) -> StoreRegistry:
        if self.registry is not None:
            if self.stores:
                raise ValueError(
                    "pass either stores or a pre-built registry, not both "
                    "(stores would be silently unmanaged)")
            return self.registry
        registry = StoreRegistry()
        for spec in self.stores:
            store, max_bytes = (
                (spec.store, spec.max_bytes) if isinstance(spec, StoreSpec)
                else (spec[0], spec[1]))
            registry.register(store, max_bytes=float(max_bytes))
        return registry


@dataclasses.dataclass(frozen=True)
class PlaneSpec:
    """Everything a control plane needs, declared up front.

    Fields:
      params:     plane-level Eq. 1 parameters (per-node overridable).
      nodes:      nodes managed from construction (more can ``attach``).
      signal:     which window aggregate drives the law (:class:`Signal`).
      window:     sliding-window length of the aggregator.
      ewma_alpha: EWMA smoothing factor of the aggregator.
      backend:    "array" (fused batched law) or "scalar" (reference).
      history:    bound on retained :class:`ControlAction` records.
      eviction:   default eviction policy for caches built through
                  :meth:`MemoryPlane.build_cache`.
      transport:  the message bus, or a factory for one (swap point for
                  a multi-host deployment); None -> in-process bus.
      record:     ReplayLoop capture: retain the last ``record`` control
                  intervals in a :class:`TraceRecorder` ring (0 = off;
                  enable later with :meth:`MemoryPlane.record`).
      health:     degradation policy (:class:`HealthPolicy`); None uses
                  the defaults (validation + holdover + quarantine on,
                  deadlines off).
    """

    params: ControllerParams
    nodes: Tuple[NodeSpec, ...] = ()
    signal: Union[Signal, str] = Signal.LATEST
    window: int = 8
    ewma_alpha: float = 0.5
    backend: str = "array"
    history: int = DEFAULT_HISTORY
    eviction: str = "lfu"
    transport: Union[MessageBus, Callable[[], MessageBus], None] = None
    record: int = 0
    health: Optional[HealthPolicy] = None

    def __post_init__(self) -> None:
        if self.backend not in BACKENDS:
            raise ValueError(f"backend must be one of {BACKENDS}")
        if self.record < 0:
            raise ValueError("record must be >= 0 (ring capacity)")
        object.__setattr__(self, "nodes", tuple(self.nodes))
        object.__setattr__(self, "signal", Signal.coerce(self.signal))

    def replace(self, **kw) -> "PlaneSpec":
        """A modified copy -- the composition hook for nestable planes.

        ``repro.fleet`` derives each tenant's *inner* spec from the
        declared one: budget-sized ``params`` (the tenant's grant plays
        the role of ``total_memory``) and budget-reporting monitors
        wrapped around the declared ones, with everything else -- nodes,
        stores, signal, transport -- carried over unchanged.
        """
        return dataclasses.replace(self, **kw)

    def make_bus(self) -> MessageBus:
        if self.transport is None:
            return MessageBus()
        if isinstance(self.transport, MessageBus):
            return self.transport
        return self.transport()


# ---------------------------------------------------------------------------
# Batched controller backend
# ---------------------------------------------------------------------------

def make_fused_step(params: ControllerParams):
    """Build the jitted fleet update for one set of law gains.

    Gains (``r0``/``lam``/``lam_grant``/``deadband``/``feedforward``)
    are baked in as trace-time constants; capacities ``(u, v, v_prev,
    M, u_min, u_max)`` are per-node ``(N,)`` arrays.  ``mask`` selects
    the nodes observed this interval -- unobserved nodes pass through
    unchanged, matching the event-driven scalar backend.
    """
    ff = params.feedforward

    def fused(u, v, v_prev, has_prev, mask, m, u_min, u_max):
        # Trace-time recompile counter: fires once per XLA compile, so
        # the sanitizer fixtures can assert the fleet shape is stable.
        record_trace("plane.fused_step", nodes=int(u.shape[0]))
        # A node with no previous observation runs without feedforward:
        # substituting v for v_prev zeroes the slope term exactly.
        vp = jnp.where(has_prev, v_prev, v) if ff > 0.0 else None
        u_next = vectorized_step(
            u, v, total_memory=m, r0=params.r0, lam=params.lam,
            u_min=u_min, u_max=u_max, lam_grant=params.lam_grant,
            deadband=params.deadband, v_prev=vp, feedforward=ff)
        return jnp.where(mask, u_next, u)

    return jax.jit(fused)


_CAPACITY_FIELDS = ("total_memory", "u_min", "u_max")


class ArrayController:
    """Batched controller: all nodes' Eq. 1 in one fused jitted update.

    State lives in packed per-node arrays; ``observe`` only buffers the
    interval's aggregates (coalescing to the latest per node) and
    ``flush`` runs the whole fleet's control law as a single XLA call,
    then actuates each observed node's registry.  Decision cost per
    interval is one dispatch regardless of fleet size -- the scaling
    property the scalar per-node Python loop cannot deliver.

    Per-node ``params`` overrides may vary only capacity fields
    (``total_memory``/``u_min``/``u_max``); gains are trace-time
    constants shared by the fleet.
    """

    def __init__(
        self,
        params: ControllerParams,
        bus: Optional[MessageBus] = None,
        signal: Signal | str = Signal.LATEST,
        max_history: int = DEFAULT_HISTORY,
    ) -> None:
        self.params = params                      # guarded-by: _lock
        self.signal = Signal.coerce(signal)
        self._bus = bus
        self._lock = threading.RLock()
        self._epoch = 0                           # guarded-by: _lock
        self._history = ActionHistory(max_history)
        self._names: List[str] = []               # guarded-by: _lock
        self._index: Dict[str, int] = {}          # guarded-by: _lock
        self._registries: List[StoreRegistry] = []  # guarded-by: _lock
        self._u = np.zeros(0, np.float64)         # guarded-by: _lock
        self._v_prev = np.zeros(0, np.float64)    # guarded-by: _lock
        self._has_prev = np.zeros(0, bool)        # guarded-by: _lock
        self._m = np.zeros(0, np.float64)         # guarded-by: _lock
        self._u_min = np.zeros(0, np.float64)     # guarded-by: _lock
        self._u_max = np.zeros(0, np.float64)     # guarded-by: _lock
        self._pending: Dict[str, AggregatedMetrics] = {}  # guarded-by: _lock
        self._fused = make_fused_step(params)     # guarded-by: _lock
        if bus is not None:
            bus.subscribe(AGG_TOPIC, self.observe)

    # -- wiring -------------------------------------------------------------
    def attach_node(self, node: str, registry: StoreRegistry,
                    u0: Optional[float] = None,
                    params: Optional[ControllerParams] = None) -> None:
        p = params or self.params
        if params is not None:
            for f in dataclasses.fields(params):
                if f.name in _CAPACITY_FIELDS:
                    continue
                if getattr(params, f.name) != getattr(self.params, f.name):
                    raise ValueError(
                        "ArrayController per-node overrides may only vary "
                        f"{_CAPACITY_FIELDS}; {f.name!r} differs (gains are "
                        "fused trace-time constants)")
        with self._lock:
            if node in self._index:
                raise ValueError(f"node {node!r} already attached")
            u = registry.total_capacity() if u0 is None else float(u0)
            self._index[node] = len(self._names)
            self._names.append(node)
            self._registries.append(registry)
            self._u = np.append(self._u, u)
            self._v_prev = np.append(self._v_prev, 0.0)
            self._has_prev = np.append(self._has_prev, False)
            self._m = np.append(self._m, p.total_memory)
            self._u_min = np.append(self._u_min, p.u_min)
            self._u_max = np.append(self._u_max, p.u_max)

    def nodes(self) -> List[str]:
        with self._lock:
            return list(self._names)

    def node_capacity(self, node: str) -> float:
        with self._lock:
            return float(self._u[self._index[node]])

    # -- online re-parameterization -----------------------------------------
    @property
    def epoch(self) -> int:
        """Parameter generation: 0 at construction, +1 per swap."""
        with self._lock:
            return self._epoch

    def prewarm(self, params: ControllerParams):
        """Build + warm the fused step for ``params`` off the hot path.

        Compiles the new gains' executable against the current fleet
        shape so a subsequent :meth:`swap_params` is a pointer flip --
        the control loop never waits on XLA.  If the fleet grows
        between warm and commit, the next flush just recompiles.
        """
        fused = make_fused_step(params)
        with self._lock:
            shape_snap = (self._u.copy(), self._v_prev.copy(),
                          self._has_prev.copy(), self._m.copy(),
                          self._u_min.copy(), self._u_max.copy())
        if shape_snap[0].size:
            u, v_prev, has_prev, m, u_min, u_max = shape_snap
            jax.block_until_ready(fused(
                jnp.asarray(u, jnp.float32), jnp.asarray(v_prev, jnp.float32),
                jnp.asarray(v_prev, jnp.float32), jnp.asarray(has_prev),
                jnp.zeros(u.shape, bool), jnp.asarray(m, jnp.float32),
                jnp.asarray(u_min, jnp.float32),
                jnp.asarray(u_max, jnp.float32)))
        return fused

    def swap_params(self, params: ControllerParams, fused=None) -> int:
        """Atomically replace the fleet's law gains in a running plane.

        The swap itself is a pointer flip under the controller lock at
        an interval boundary; pass a :meth:`prewarm`-built ``fused``
        step to keep the XLA compile off the locked path (the
        ``MemoryPlane`` facade does).  Control state (``u``,
        ``v_prev``) carries over; capacity bounds (``u_min`` /
        ``u_max`` / ``M``) move with the swap for every node still on
        the old plane-level defaults, while per-node overrides
        (heterogeneous fleets) are preserved.  Returns the new
        parameter epoch; subsequent actions are stamped with it.
        """
        if fused is None:
            fused = self.prewarm(params)
        with self._lock:
            old = self.params
            for arr, prev, new in ((self._m, old.total_memory,
                                    params.total_memory),
                                   (self._u_min, old.u_min, params.u_min),
                                   (self._u_max, old.u_max, params.u_max)):
                arr[arr == prev] = new
            self.params = params
            self._fused = fused
            self._epoch += 1
            return self._epoch

    # -- bounded action history ---------------------------------------------
    @property
    def actions(self) -> List[ControlAction]:
        return self._history.snapshot()

    def recent(self, n: Optional[int] = None,
               node: Optional[str] = None) -> List[ControlAction]:
        return self._history.snapshot(node=node, limit=n)

    # -- control ------------------------------------------------------------
    def observe(self, agg: AggregatedMetrics) -> None:
        """Buffer one node's aggregate for the next ``flush``.

        Multiple observations of a node within one interval coalesce to
        the latest (the batched law steps once per interval)."""
        with self._lock:
            self._pending[agg.node] = agg

    def flush(self) -> List[ControlAction]:
        """One control interval: fused decide, then per-node actuation."""
        with self._lock:
            pending, self._pending = self._pending, {}
            observed = sorted(
                (self._index[n], n, a) for n, a in pending.items()
                if n in self._index)
            if not observed:
                return []
            n_nodes = self._u.size
            mask = np.zeros(n_nodes, bool)
            v = self._v_prev.copy()      # placeholder; masked out below
            for i, _, agg in observed:
                mask[i] = True
                v[i] = self.signal.pick(agg)
                if agg.total > 0 and agg.total != self._m[i]:
                    self._m[i] = agg.total
            u_next = np.asarray(self._fused(
                jnp.asarray(self._u, jnp.float32),
                jnp.asarray(v, jnp.float32),
                jnp.asarray(self._v_prev, jnp.float32),
                jnp.asarray(self._has_prev),
                jnp.asarray(mask),
                jnp.asarray(self._m, jnp.float32),
                jnp.asarray(self._u_min, jnp.float32),
                jnp.asarray(self._u_max, jnp.float32),
            ), np.float64)
            actions: List[ControlAction] = []
            for i, name, agg in observed:
                reports = self._registries[i].apply_capacity(u_next[i])
                action = ControlAction(
                    node=name, timestamp=agg.timestamp,
                    u_prev=float(self._u[i]), u_next=float(u_next[i]),
                    utilization=v[i] / agg.total if agg.total else 0.0,
                    reports=reports, epoch=self._epoch)
                actions.append(action)
                self._history.append(action)
                self._u[i] = u_next[i]
                self._v_prev[i] = v[i]
                self._has_prev[i] = True
        if self._bus is not None:
            for action in actions:
                self._bus.publish(CONTROL_TOPIC, action)
        return actions

    def squeeze(self, node: str, factor: float) -> bool:
        """Transient capacity clamp (see DynIMSController.squeeze)."""
        with self._lock:
            i = self._index.get(node)
            if i is None:
                return False
            self._registries[i].apply_capacity(
                float(self._u[i]) * float(factor))
            return True

    def reset_node(self, node: str, u: float) -> bool:
        """Re-seed one node's control state at capacity ``u``.

        The quarantine-rejoin hook: the law resumes from the
        fail-static grant (feedforward history cleared) instead of
        jumping back to the pre-quarantine capacity."""
        with self._lock:
            i = self._index.get(node)
            if i is None:
                return False
            self._u[i] = float(u)
            self._v_prev[i] = 0.0
            self._has_prev[i] = False
            return True


# ---------------------------------------------------------------------------
# The facade
# ---------------------------------------------------------------------------

class MemoryPlane:
    """Declarative facade over the full DynIMS pipeline.

    Wires monitor -> bus(RAW) -> aggregator -> bus(AGG) -> controller
    backend for every declared/attached node and drives them all from
    one ``tick`` (the control interval T).  ``run``/``start``/``stop``
    tick in real time on a daemon thread; ``tick`` is used by tests, the
    simulator, and the trainer (which ticks from its step loop).  The
    plane is restartable and usable as a context manager.
    """

    def __init__(self, spec: PlaneSpec) -> None:
        self.spec = spec
        self.signal = spec.signal
        self.bus = spec.make_bus()
        self.aggregator = MetricAggregator(
            window=spec.window, ewma_alpha=spec.ewma_alpha, bus=self.bus)
        if spec.backend == "scalar":
            self.controller: Union[DynIMSController, ArrayController] = \
                DynIMSController(spec.params, bus=self.bus,
                                 signal=spec.signal,
                                 max_history=spec.history,
                                 track_fresh=True)   # tick() drains
        else:
            self.controller = ArrayController(
                spec.params, bus=self.bus, signal=spec.signal,
                max_history=spec.history)
        self._monitors: Dict[str, MemoryMonitor] = {}  # guarded-by: _lock
        self._registries: Dict[str, _ResilientRegistry] = {}  # guarded-by: _lock
        self._lock = threading.RLock()
        # Serializes whole control intervals against hot-swaps: tick()
        # holds it for the full sample -> decide -> actuate pipeline, so
        # swap_params always lands at an interval boundary (never a
        # half-updated fleet).
        self._tick_lock = threading.Lock()
        self.recorder: Optional[TraceRecorder] = (  # guarded-by: _tick_lock
            TraceRecorder(spec.record) if spec.record else None)
        # ChaosPlane degradation state.  _health_lock is a leaf under
        # _tick_lock: tick() mutates the states while holding both,
        # health() snapshots under _health_lock alone so a report never
        # waits out a whole control interval.
        self.health_policy = spec.health or HealthPolicy()
        self.fault_log = FaultLog(self.health_policy.fault_log)
        self._health_lock = threading.Lock()
        self._health: Dict[str, _NodeHealthState] = {}  # guarded-by: _health_lock
        self._ticks = 0                       # guarded-by: _health_lock
        self._deadline_misses = 0             # guarded-by: _health_lock
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        for node_spec in spec.nodes:
            self._attach_spec(node_spec)

    @classmethod
    def for_scenario(cls, scenario: str, *,
                     nodes: Iterable[NodeSpec] = (),
                     **spec_kw) -> "MemoryPlane":
        """A plane running the ScenarioLab-tuned gains for ``scenario``.

        Looks the named scenario up in the checked-in preset registry
        (``repro.configs.dynims.tuned_params``; ``paper-*`` names map
        to Table I) and builds a :class:`PlaneSpec` around it --
        remaining keywords pass through to the spec::

            plane = MemoryPlane.for_scenario("bursty-serving",
                                             nodes=(NodeSpec(...),))
        """
        from ..configs.dynims import tuned_params
        return cls(PlaneSpec(params=tuned_params(scenario),
                             nodes=tuple(nodes), **spec_kw))

    # -- wiring -------------------------------------------------------------
    def _attach_spec(self, ns: NodeSpec) -> StoreRegistry:
        return self.attach(ns.name, ns.monitor, ns.registry,
                           stores=ns.stores, u0=ns.u0, params=ns.params)

    def attach(
        self,
        node: str,
        monitor: MemoryMonitor,
        registry: Optional[StoreRegistry] = None,
        *,
        stores: Iterable[Union[StoreSpec, Tuple[ManagedStore, float]]] = (),
        u0: Optional[float] = None,
        params: Optional[ControllerParams] = None,
    ) -> StoreRegistry:
        """Bring one node under control; returns its registry.

        Either pass a pre-built ``registry`` or an iterable of
        :class:`StoreSpec` / ``(store, max_bytes)`` pairs (not both).
        The returned registry is wrapped in the plane's actuation
        shield: a raising store degrades to bounded backoff-retried
        actuation instead of aborting the fleet's interval."""
        registry = NodeSpec(node, monitor, stores=tuple(stores),
                            registry=registry).build_registry()
        shielded = _ResilientRegistry(
            registry, node, self.health_policy, self.fault_log,
            clock=self._tick_index)
        effective = params or self.spec.params
        pin = self.health_policy.fail_static_grant(
            effective.u_min, effective.u_max)
        with self._lock:
            self._monitors[node] = monitor
            self._registries[node] = shielded
            self.controller.attach_node(node, shielded, u0=u0, params=params)
        with self._health_lock:
            self._health[node] = _NodeHealthState(pin)
        return shielded

    def build_cache(self, name: str, capacity: float, *,
                    policy: Optional[str] = None, priority: int = 0,
                    **kw) -> ShardCache:
        """A ShardCache with the plane's declared eviction default."""
        return ShardCache(name, capacity=capacity,
                          policy=policy or self.spec.eviction,
                          priority=priority, **kw)

    # -- introspection ------------------------------------------------------
    def nodes(self) -> List[str]:
        return self.controller.nodes()

    def capacity(self, node: str) -> float:
        """Current granted storage capacity ``u`` for ``node`` (bytes)."""
        return self.controller.node_capacity(node)

    def actions(self, node: Optional[str] = None,
                limit: Optional[int] = None) -> List[ControlAction]:
        """Bounded, thread-safe view of recent control actions."""
        return self.controller.recent(n=limit, node=node)

    def squeeze(self, node: str, factor: float) -> bool:
        """Transiently clamp a node's stores to ``factor`` of its grant
        (straggler/burst mitigation); the law re-grants next interval."""
        return self.controller.squeeze(node, factor)

    # -- ReplayLoop: capture and hot-swap ------------------------------------
    @property
    def params(self) -> ControllerParams:
        """The plane-level law parameters currently in force."""
        return self.controller.params

    @property
    def epoch(self) -> int:
        """Current parameter epoch (0 until the first hot-swap)."""
        return self.controller.epoch

    def record(self, capacity: int = DEFAULT_TRACE_CAPACITY) -> TraceRecorder:
        """Start (or restart) trace capture; returns the live recorder.

        Swaps under the tick lock so a concurrently running interval
        never records half to the old ring and half to the new one.
        """
        with self._tick_lock:
            self.recorder = TraceRecorder(capacity)
            return self.recorder

    def capture(self) -> CapturedTrace:
        """Snapshot the recorded ring as a :class:`CapturedTrace`.

        Raises if the plane was never recording (``PlaneSpec(record=N)``
        or :meth:`record`) or no interval has been ticked yet.
        """
        if self.recorder is None:
            raise ValueError(
                "plane is not recording; build it with PlaneSpec(record=N) "
                "or call plane.record() first")
        return self.recorder.snapshot(
            interval_s=self.controller.params.interval_s)

    def swap_params(self, params: ControllerParams) -> int:
        """Hot-swap the control-law parameters of a *running* plane.

        Delegates to the backend's atomic ``swap_params`` while holding
        the tick lock, so the swap always lands between control
        intervals: every interval runs wholly under one parameter
        epoch, and the :class:`ControlAction` history stays
        epoch-monotone with no dropped or duplicated interval.  The
        array backend's new executable is compiled and warmed *before*
        the lock is taken, so a concurrently ticking loop never waits
        on XLA.  The ``retune_online`` loop (``repro.lab.tune``) calls
        this from its tuning thread.
        """
        prewarm = getattr(self.controller, "prewarm", None)
        fused = prewarm(params) if prewarm is not None else None
        with self._tick_lock:
            if fused is not None:
                return self.controller.swap_params(params, fused=fused)
            return self.controller.swap_params(params)

    # -- degradation / health -----------------------------------------------
    def _tick_index(self) -> int:
        with self._health_lock:
            return self._ticks

    def log_fault(self, kind: str, node: Optional[str] = None,
                  detail: str = "") -> None:
        """Record an externally observed fault (retune supervisor,
        fleet rebalance rollback, ...) in the plane's bounded log."""
        self.fault_log.append(FaultEvent(
            kind=kind, node=node, tick=self._tick_index(),
            timestamp=time.time(), detail=detail))

    def health(self) -> HealthReport:
        """Structured degradation report: per-node health state machine
        position, actuation shield status, and fault counts.  Safe to
        call from any thread; never waits out a control interval."""
        with self._health_lock:
            states = {n: (st.state, st.stale_ticks, st.good_streak,
                          st.faults, st.pin_grant)
                      for n, st in self._health.items()}
            ticks = self._ticks
            misses = self._deadline_misses
        with self._lock:
            registries = dict(self._registries)
        nodes = {}
        for name, (state, stale, streak, faults, pin) in states.items():
            failures, degraded = (registries[name].status()
                                  if name in registries else (0, False))
            nodes[name] = NodeHealthInfo(
                node=name, state=state, stale_ticks=stale,
                good_streak=streak, faults=faults, pin_grant=pin,
                actuation_failures=failures, actuation_degraded=degraded)
        return HealthReport(ticks=ticks, deadline_misses=misses,
                            nodes=nodes,
                            fault_counts=self.fault_log.counts())

    def _observe_node(self, name: str, monitor: MemoryMonitor,
                      registry: Optional[_ResilientRegistry],
                      tick: int) -> Optional[MemorySample]:
        """Sample one node through the health state machine.

        Returns the sample the law may act on this interval (fresh, or
        the last-good holdover while stale), or None while the node is
        quarantined / has no good sample yet.  Called under _tick_lock.
        """
        policy = self.health_policy
        t0 = time.monotonic()
        sample: Optional[MemorySample] = None
        fault: Optional[Tuple[str, str]] = None
        try:
            sample = monitor.sample()
        except Exception as exc:
            fault = ("sample-error", f"{type(exc).__name__}: {exc}")
        else:
            reason = validate_sample(sample)
            if reason is not None:
                fault = ("telemetry-invalid", reason)
            elif (policy.sample_deadline_s is not None
                  and time.monotonic() - t0 > policy.sample_deadline_s):
                # A sample that arrives after its deadline is as stale
                # as one that never arrived (paper Sec. II.B).
                fault = ("sample-slow",
                         f"{time.monotonic() - t0:.3f}s "
                         f"> {policy.sample_deadline_s}s")
        events: List[FaultEvent] = []
        with self._health_lock:
            st = self._health.get(name)
            if st is None:       # attached behind our back; adopt it
                effective = self.spec.params
                st = _NodeHealthState(policy.fail_static_grant(
                    effective.u_min, effective.u_max))
                self._health[name] = st
            out, pin = self._transition(name, st, sample, fault,
                                        tick, events)
        for e in events:
            self.fault_log.append(e)
        if pin and registry is not None:
            # (Re-)pin the fail-static grant outside _health_lock; the
            # shield absorbs and backs off actuation failures.
            registry.apply_capacity(st.pin_grant)
        return out

    def _transition(self, name: str, st: _NodeHealthState,
                    sample: Optional[MemorySample],
                    fault: Optional[Tuple[str, str]], tick: int,
                    events: List[FaultEvent]) -> Tuple[
                        Optional[MemorySample], bool]:
        """Advance one node's health state machine by one interval.

        Returns ``(sample_to_publish, pin_fail_static_now)``.  Called
        with _health_lock held; appends pending events to ``events``
        (logged by the caller after the lock is dropped).
        """
        policy = self.health_policy
        now = time.time()
        if fault is None:
            assert sample is not None
            if st.state is NodeHealth.QUARANTINED:
                # Rejoin hysteresis: demand a sustained good streak, and
                # ramp back up from the fail-static grant rather than
                # jumping to the pre-quarantine capacity.
                st.good_streak += 1
                st.last_good = sample
                if st.good_streak >= policy.rejoin_intervals:
                    st.state = NodeHealth.HEALTHY
                    st.stale_ticks = 0
                    st.good_streak = 0
                    self.controller.reset_node(name, st.pin_grant)
                    events.append(FaultEvent(
                        kind="rejoin", node=name, tick=tick, timestamp=now,
                        detail=f"closed-loop control resumed from "
                               f"fail-static grant {st.pin_grant:.3e}"))
                    return sample, False
                return None, True
            if st.state is NodeHealth.STALE:
                events.append(FaultEvent(
                    kind="stale-recover", node=name, tick=tick,
                    timestamp=now,
                    detail=f"fresh sample after {st.stale_ticks} "
                           "holdover intervals"))
            st.state = NodeHealth.HEALTHY
            st.stale_ticks = 0
            st.good_streak = 0
            st.last_good = sample
            return sample, False
        # -- faulted interval ------------------------------------------------
        kind, detail = fault
        st.faults += 1
        events.append(FaultEvent(kind=kind, node=name, tick=tick,
                                 timestamp=now, detail=detail))
        if st.state is NodeHealth.QUARANTINED:
            st.good_streak = 0
            return None, True
        st.stale_ticks += 1
        st.state = NodeHealth.STALE
        if st.stale_ticks >= policy.stale_budget or st.last_good is None:
            # Sustained loss (or never a good sample): fail static.
            st.state = NodeHealth.QUARANTINED
            st.good_streak = 0
            events.append(FaultEvent(
                kind="quarantine", node=name, tick=tick, timestamp=now,
                detail=f"{st.stale_ticks} bad intervals "
                       f"(stale_budget={policy.stale_budget}); pinned to "
                       f"fail-static grant {st.pin_grant:.3e}"))
            return None, True
        # Stale holdover: act on the last-good observation.
        return st.last_good, False

    # -- control loop -------------------------------------------------------
    def tick(self) -> List[ControlAction]:
        """One control interval: sample every node, run the law once.

        Every sample passes telemetry validation and the per-node
        health state machine first -- a faulty monitor degrades that
        node (holdover, then fail-static quarantine) instead of feeding
        the law garbage or taking the interval down with an exception.
        """
        t0 = time.monotonic()
        with self._tick_lock:
            with self._lock:
                monitors = dict(self._monitors)
                registries = dict(self._registries)
            tick = self._tick_index()
            samples: Dict[str, MemorySample] = {}
            for name, mon in monitors.items():
                s = self._observe_node(name, mon, registries.get(name),
                                       tick)
                if s is not None:
                    samples[name] = s
            for sample in samples.values():
                self.bus.publish(RAW_TOPIC, sample)
            actions = self.controller.flush()
            if self.recorder is not None:
                self.recorder.record(samples, actions)
            deadline = self.health_policy.tick_deadline_s
            elapsed = time.monotonic() - t0
            missed = deadline is not None and elapsed > deadline
            with self._health_lock:
                self._ticks += 1
                if missed:
                    self._deadline_misses += 1
            if missed:
                self.fault_log.append(FaultEvent(
                    kind="tick-deadline", node=None, tick=tick,
                    timestamp=time.time(),
                    detail=f"interval took {elapsed:.3f}s "
                           f"> {deadline}s"))
            return actions

    def run(self, duration_s: Optional[float] = None) -> None:
        """Tick in real time at ``params.interval_s`` until stopped."""
        deadline = (None if duration_s is None
                    else time.time() + duration_s)
        while not self._stop.is_set():
            t0 = time.time()
            self.tick()
            if deadline is not None and time.time() >= deadline:
                break
            sleep = self.controller.params.interval_s - (time.time() - t0)
            if sleep > 0:
                self._stop.wait(sleep)

    def start(self) -> None:
        """Start (or restart) the real-time loop on a daemon thread."""
        self.stop()
        self._stop.clear()
        self._thread = threading.Thread(target=self.run, daemon=True)
        self._thread.start()

    def stop(self) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=5.0)
            self._thread = None

    @property
    def running(self) -> bool:
        return self._thread is not None and self._thread.is_alive()

    def __enter__(self) -> "MemoryPlane":
        self.start()
        return self

    def __exit__(self, *exc) -> None:
        self.stop()


# ---------------------------------------------------------------------------
# Legacy shim
# ---------------------------------------------------------------------------

class ControlPlane(MemoryPlane):
    """Deprecated: imperative predecessor of :class:`MemoryPlane`.

    Kept as a thin shim (scalar backend, old constructor signature) so
    existing callers keep working; new code should declare a
    :class:`PlaneSpec` and use :class:`MemoryPlane`.
    """

    def __init__(
        self,
        params: ControllerParams,
        window: int = 8,
        ewma_alpha: float = 0.5,
        signal: Signal | str = "latest",
        max_history: int = DEFAULT_HISTORY,
    ) -> None:
        warnings.warn(
            "ControlPlane is deprecated; declare a PlaneSpec and use "
            "MemoryPlane instead", DeprecationWarning, stacklevel=2)
        super().__init__(PlaneSpec(
            params=params, window=window, ewma_alpha=ewma_alpha,
            signal=signal, backend="scalar", history=max_history))
