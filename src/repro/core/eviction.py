"""Eviction policies for managed in-memory stores.

The paper runs Alluxio with LFU; LRU and FIFO are provided both as
baselines and because the paper's related-work section (AFA, Sec. V)
motivates swapping policies adaptively -- :class:`AdaptivePolicy` does a
simple regret-based switch between LFU and LRU using ghost lists, the
closest practical analogue of that suggestion.

All policies expose the same interface::

    on_insert(key) / on_access(key) / remove(key) / victim() -> key | None
"""

from __future__ import annotations

import dataclasses
import heapq
import itertools
from collections import OrderedDict, deque
from typing import Dict, Hashable, Optional, Protocol

Key = Hashable


class EvictionPolicy(Protocol):
    def on_insert(self, key: Key) -> None: ...
    def on_access(self, key: Key) -> None: ...
    def remove(self, key: Key) -> None: ...
    def victim(self) -> Optional[Key]: ...
    def __len__(self) -> int: ...


class LFUPolicy:
    """Least-frequently-used (lazy heap) with a configurable tie-break.

    ``tie="lru"`` (classic) evicts the least-recently-touched block among
    the minimum-frequency set.  ``tie="mru"`` evicts the most-recently-
    admitted one instead -- the scan-resistant variant: during a cold
    sequential scan (every block freq==1) it preserves the resident
    prefix and drops the block that was fetched last, which is also the
    block most likely still present in a lower cache tier (keeps the
    two-level hierarchy inclusive, Sec. IV.B of the paper).
    """

    def __init__(self, tie: str = "lru") -> None:
        if tie not in ("lru", "mru"):
            raise ValueError("tie must be 'lru' or 'mru'")
        self._freq: Dict[Key, int] = {}
        self._heap: list = []          # (freq, +/-seq, key) lazy entries
        self._seq = itertools.count()
        self._sign = 1 if tie == "lru" else -1
        self.tie = tie

    def on_insert(self, key: Key) -> None:
        self._freq[key] = 1
        heapq.heappush(self._heap, (1, self._sign * next(self._seq), key))

    def on_access(self, key: Key) -> None:
        if key not in self._freq:
            raise KeyError(key)
        self._freq[key] += 1
        heapq.heappush(
            self._heap,
            (self._freq[key], self._sign * next(self._seq), key))

    def remove(self, key: Key) -> None:
        self._freq.pop(key, None)   # heap entries invalidated lazily

    def victim(self) -> Optional[Key]:
        while self._heap:
            freq, _, key = self._heap[0]
            if self._freq.get(key) != freq:
                heapq.heappop(self._heap)   # stale entry
                continue
            return key
        return None

    def __len__(self) -> int:
        return len(self._freq)


class LRUPolicy:
    def __init__(self) -> None:
        self._order: "OrderedDict[Key, None]" = OrderedDict()

    def on_insert(self, key: Key) -> None:
        self._order[key] = None
        self._order.move_to_end(key)

    def on_access(self, key: Key) -> None:
        if key not in self._order:
            raise KeyError(key)
        self._order.move_to_end(key)

    def remove(self, key: Key) -> None:
        self._order.pop(key, None)

    def victim(self) -> Optional[Key]:
        return next(iter(self._order)) if self._order else None

    def __len__(self) -> int:
        return len(self._order)


class FIFOPolicy:
    def __init__(self) -> None:
        self._order: "OrderedDict[Key, None]" = OrderedDict()

    def on_insert(self, key: Key) -> None:
        self._order[key] = None

    def on_access(self, key: Key) -> None:
        if key not in self._order:
            raise KeyError(key)

    def remove(self, key: Key) -> None:
        self._order.pop(key, None)

    def victim(self) -> Optional[Key]:
        return next(iter(self._order)) if self._order else None

    def __len__(self) -> int:
        return len(self._order)


class AdaptivePolicy:
    """Regret-switching LFU<->LRU via ghost lists (AFA-inspired).

    Tracks recently evicted keys per inner policy in bounded ghost lists;
    a hit on a ghost entry means that policy's eviction was a mistake.
    When one policy accumulates ``switch_margin`` more mistakes than the
    other, switch to the other.
    """

    def __init__(self, ghost_size: int = 512, switch_margin: int = 8) -> None:
        self._lfu, self._lru = LFUPolicy(), LRUPolicy()
        self._active: EvictionPolicy = self._lfu
        self._ghost_lfu: deque = deque(maxlen=ghost_size)
        self._ghost_lru: deque = deque(maxlen=ghost_size)
        self._regret = {"lfu": 0, "lru": 0}
        self._margin = switch_margin

    @property
    def active_name(self) -> str:
        return "lfu" if self._active is self._lfu else "lru"

    def on_insert(self, key: Key) -> None:
        if key in self._ghost_lfu:
            self._regret["lfu"] += 1
        if key in self._ghost_lru:
            self._regret["lru"] += 1
        self._maybe_switch()
        self._lfu.on_insert(key)
        self._lru.on_insert(key)

    def on_access(self, key: Key) -> None:
        self._lfu.on_access(key)
        self._lru.on_access(key)

    def remove(self, key: Key) -> None:
        # Record what each policy would have evicted into its ghost list.
        if self._lfu.victim() == key:
            self._ghost_lfu.append(key)
        if self._lru.victim() == key:
            self._ghost_lru.append(key)
        self._lfu.remove(key)
        self._lru.remove(key)

    def victim(self) -> Optional[Key]:
        return self._active.victim()

    def _maybe_switch(self) -> None:
        if self._regret["lfu"] - self._regret["lru"] >= self._margin:
            self._active = self._lru
        elif self._regret["lru"] - self._regret["lfu"] >= self._margin:
            self._active = self._lfu

    def __len__(self) -> int:
        return len(self._lfu)


POLICIES = {
    "lfu": LFUPolicy,
    "lru": LRUPolicy,
    "fifo": FIFOPolicy,
    "adaptive": AdaptivePolicy,
}


def make_policy(name: str) -> EvictionPolicy:
    try:
        return POLICIES[name]()
    except KeyError:
        raise ValueError(f"unknown eviction policy {name!r}; "
                         f"available: {sorted(POLICIES)}") from None


# ---------------------------------------------------------------------------
# Analytic policy models (the sweep engine's reuse-distance abstraction)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class PolicyModel:
    """Analytic stand-in for a policy inside the vectorized sweep.

    The discrete policies above run per-key; the ScenarioLab sweep
    engine (``repro.lab.sweep``) cannot, so it models a cache holding a
    fraction ``f`` of the working set under Zipf(``alpha``)-skewed
    reuse with the hit curve

        h(f) = c * f**(1 - alpha) + (1 - c) * f

    ``concentration`` ``c`` is how closely the policy approximates
    keeping exactly the hottest ``f`` fraction resident (the
    frequency-ideal mass of the top-``f`` slice is ``f**(1-alpha)``):
    LFU with the scan-resistant admission filter tracks it, LRU mixes
    recency in and captures less of the skew, FIFO barely exploits it.
    At ``alpha == 0`` (uniform / cyclic-scan reuse) every policy
    degrades to ``h = f``, matching the admission-stabilized resident
    prefix :class:`~repro.core.store.ShardCache` sustains under cyclic
    scans (Sec. IV.B).
    """

    concentration: float

    def __post_init__(self) -> None:
        if not (0.0 <= self.concentration <= 1.0):
            raise ValueError("concentration must be in [0, 1]")


POLICY_MODELS: Dict[str, PolicyModel] = {
    "lfu": PolicyModel(concentration=1.0),
    "adaptive": PolicyModel(concentration=0.9),
    "lru": PolicyModel(concentration=0.65),
    "fifo": PolicyModel(concentration=0.35),
}


def policy_model(name: str) -> PolicyModel:
    """The analytic :class:`PolicyModel` behind a named policy."""
    try:
        return POLICY_MODELS[name]
    except KeyError:
        raise ValueError(f"no analytic model for policy {name!r}; "
                         f"available: {sorted(POLICY_MODELS)}") from None
