"""Workload models calibrated to the paper's measurements.

Two empirical facts ground DynIMS (Sec. II):

* **Fig. 1** -- HPCC's per-node memory usage over time: long low-usage
  phases (~5-35 GB) punctuated by bursts peaking ~75 GB (HPL/PTRANS),
  with >=40 GB unused most of the time.  :func:`hpcc_trace` generates a
  phase-structured trace with those statistics.
* **Fig. 2** -- HPL throughput vs system memory utilization: flat until
  ~95%, collapsing near 100%, catastrophic once swapping.
  :func:`hpl_slowdown` is that response curve; the simulator uses it to
  price un-relieved memory pressure.

Both are deterministic given a seed, so every experiment is replayable.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple

import numpy as np

GiB = float(2**30)


@dataclass(frozen=True)
class Phase:
    """One HPCC sub-benchmark phase."""

    name: str
    duration_s: float
    base_gib: float          # plateau usage
    peak_gib: float          # burst peak (== base for flat phases)
    burst_frac: float = 0.0  # fraction of the phase spent at/near peak


# Phase structure shaped after Fig. 1: usage plateaus with two big bursts
# (HPL and PTRANS regions) peaking near 75 GB; >=40 GB unused most of the
# run.  Durations are relative weights, scaled by ``duration_s``.
HPCC_PHASES: Tuple[Phase, ...] = (
    Phase("startup",      0.05,  5.0,  5.0),
    Phase("hpl",          0.30, 20.0, 75.0, burst_frac=0.45),
    Phase("dgemm",        0.10, 18.0, 30.0, burst_frac=0.30),
    Phase("stream",       0.10, 28.0, 32.0, burst_frac=0.50),
    Phase("ptrans",       0.15, 25.0, 73.0, burst_frac=0.35),
    Phase("randomaccess", 0.10, 15.0, 22.0, burst_frac=0.30),
    Phase("fft",          0.12, 20.0, 42.0, burst_frac=0.35),
    Phase("network",      0.08,  8.0, 10.0),
)


def hpcc_trace(
    duration_s: float = 600.0,
    interval_s: float = 0.1,
    seed: int = 0,
    noise_gib: float = 0.5,
    phases: Sequence[Phase] = HPCC_PHASES,
) -> np.ndarray:
    """Per-interval compute-tenant memory usage (bytes), Fig.-1-shaped.

    Bursts ramp up over ~2 s (the paper's motivation for sub-second
    control response: usage can climb tens of GB in seconds).
    """
    rng = np.random.default_rng(seed)
    n = int(round(duration_s / interval_s))
    total_weight = sum(p.duration_s for p in phases)
    out = np.empty(n, dtype=np.float64)
    i = 0
    for phase in phases:
        steps = max(int(round(n * phase.duration_s / total_weight)), 1)
        steps = min(steps, n - i)
        if steps <= 0:
            break
        seg = np.full(steps, phase.base_gib)
        if phase.peak_gib > phase.base_gib and phase.burst_frac > 0:
            burst_len = max(int(steps * phase.burst_frac), 1)
            start = (steps - burst_len) // 2
            ramp = max(int(2.0 / interval_s), 1)          # ~2 s ramp
            ramp = min(ramp, max(burst_len // 2, 1))
            prof = np.full(burst_len, phase.peak_gib)
            prof[:ramp] = np.linspace(phase.base_gib, phase.peak_gib, ramp)
            prof[-ramp:] = np.linspace(phase.peak_gib, phase.base_gib, ramp)
            seg[start:start + burst_len] = prof[: steps - start]
        out[i:i + steps] = seg
        i += steps
    if i < n:
        out[i:] = phases[-1].base_gib
    out += rng.normal(0.0, noise_gib, size=n)
    peak = max(p.peak_gib for p in phases)
    return np.clip(out, 1.0, peak) * GiB


def constant_trace(duration_s: float, interval_s: float,
                   usage_gib: float) -> np.ndarray:
    n = int(round(duration_s / interval_s))
    return np.full(n, usage_gib * GiB)


def fleet_demand_traces(
    n_nodes: int,
    n_intervals: int,
    interval_s: float = 0.1,
    seed: int = 0,
    amp_range: Tuple[float, float] = (0.8, 1.2),
    phase_shift: bool = True,
    base: Optional[np.ndarray] = None,
) -> np.ndarray:
    """Batched multi-node demand: ``(n_nodes, n_intervals)`` in bytes.

    Every node replays the same base trace (Fig.-1-shaped HPCC by
    default) phase-shifted by a random offset and amplitude-jittered
    within ``amp_range`` -- the fleet-scale workload model
    :func:`~repro.core.cluster_sim.simulate_fleet` and the ScenarioLab
    sweep engine share.  Deterministic given ``seed``; the RNG draw
    order (base trace, then shifts, then amplitudes) is part of the
    contract so both consumers see identical fleets.
    """
    rng = np.random.default_rng(seed)
    if base is None:
        base = hpcc_trace(float(n_intervals) * interval_s, interval_s,
                          seed=seed)
    base = np.asarray(base, dtype=np.float64)
    if phase_shift:
        shifts = rng.integers(0, len(base), size=n_nodes)
    else:
        shifts = np.zeros(n_nodes, dtype=np.int64)
    amp = rng.uniform(amp_range[0], amp_range[1], size=n_nodes)
    demand = np.stack([np.roll(base, s) * a for s, a in zip(shifts, amp)])
    if demand.shape[1] < n_intervals:
        reps = -(-n_intervals // demand.shape[1])
        demand = np.tile(demand, (1, reps))
    return demand[:, :n_intervals]


def bursty_trace(
    n_intervals: int,
    interval_s: float = 0.1,
    base_gib: float = 40.0,
    burst_gib: float = 40.0,
    burst_every_s: float = 20.0,
    burst_len_s: float = 2.0,
    ramp_s: float = 0.5,
    noise_gib: float = 0.5,
    seed: int = 0,
) -> np.ndarray:
    """Periodic load spikes over a plateau (bytes).

    Models bursty serving pressure (KV-cache admission waves): every
    ``burst_every_s`` the demand ramps from ``base_gib`` up to
    ``base_gib + burst_gib`` over ``ramp_s`` seconds, holds for
    ``burst_len_s``, and ramps back down.
    """
    rng = np.random.default_rng(seed)
    out = np.full(n_intervals, base_gib, dtype=np.float64)
    period = max(int(round(burst_every_s / interval_s)), 1)
    blen = max(int(round(burst_len_s / interval_s)), 1)
    ramp = max(int(round(ramp_s / interval_s)), 1)
    for start in range(period // 2, n_intervals, period):
        up = np.linspace(base_gib, base_gib + burst_gib, ramp)
        hold = np.full(blen, base_gib + burst_gib)
        down = np.linspace(base_gib + burst_gib, base_gib, ramp)
        prof = np.concatenate([up, hold, down])
        end = min(start + len(prof), n_intervals)
        out[start:end] = prof[: end - start]
    out += rng.normal(0.0, noise_gib, size=n_intervals)
    return np.clip(out, 0.5, None) * GiB


def hpl_slowdown(utilization: float, swap_frac: float = 0.0) -> float:
    """Relative HPL execution-time multiplier at a memory utilization.

    Fig. 2 digitized: performance is flat to ~92%, loses ~25% by 98%,
    collapses approaching 100%, and degrades by an order of magnitude
    once swap is engaged (the paper controls swap at 0.5% / 1% of RAM
    and observes severe drops).

    Returns a multiplier >= 1 on execution time (1 == full speed).
    """
    u = float(np.clip(utilization, 0.0, 1.5))
    if u <= 0.92:
        slowdown = 1.0
    elif u <= 0.98:
        slowdown = 1.0 + (u - 0.92) / 0.06 * 0.35          # -> 1.35x @ 98%
    elif u <= 1.0:
        slowdown = 1.35 + (u - 0.98) / 0.02 * 2.65         # -> 4x @ 100%
    else:
        slowdown = 4.0 + (u - 1.0) * 300.0                 # deep swap
    if swap_frac > 0.0:
        slowdown *= 1.0 + 12.0 * min(swap_frac / 0.01, 4.0)
    return float(slowdown)


@dataclass(frozen=True)
class IterativeAppSpec:
    """A Spark-like iterative analytics job (K-means & friends, Sec. IV).

    The app makes ``iterations`` passes over ``dataset_gib`` of input
    split into ``block_gib`` blocks, with ``compute_s_per_gib`` of CPU
    work per block per pass.  Reads hit one of three tiers (Fig. 5's
    analysis): compute-node cache, data-node OS buffer cache, or disk.
    """

    name: str = "kmeans"
    dataset_gib: float = 320.0
    block_gib: float = 1.0
    iterations: int = 10
    compute_s_per_gib: float = 0.55

    @property
    def n_blocks(self) -> int:
        return int(round(self.dataset_gib / self.block_gib))


@dataclass(frozen=True)
class TierSpec:
    """Read bandwidths of the three storage tiers (paper Table II era).

    Values are effective per-node GiB/s: local RAM copy, 10 GbE remote
    buffer-cache read, and remote 7200rpm-RAID disk read (incl. network).
    """

    local_mem_gibps: float = 6.0
    remote_cache_gibps: float = 1.05     # 10 GbE wire ~ 1.16 GiB/s raw
    remote_disk_gibps: float = 0.35

    def read_time_s(self, gib: float, tier: str) -> float:
        bw = {
            "local": self.local_mem_gibps,
            "remote_cache": self.remote_cache_gibps,
            "disk": self.remote_disk_gibps,
        }[tier]
        return gib / bw


# Spark-level RDD-cache penalty (Sec. IV.B): deserialized SequenceFile
# objects are larger than their on-disk bytes, so a Spark-RDD cache of
# equal capacity holds fewer input blocks.  Fig. 5 reports 1.3x.
RDD_DESERIALIZATION_BLOAT = 1.9
