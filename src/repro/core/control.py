"""The DynIMS feedback control law (paper Eq. 1) and its analysis tools.

The controller arbitrates a single contended memory resource of size ``M``
between a priority tenant (compute) and an opportunistic tenant (in-memory
storage of capacity ``u``).  Each control interval it observes total system
usage ``v`` and utilization ratio ``r = v / M`` and updates the storage
capacity:

    u_{i+1} = clamp(u_i - lam * v_i * (r_i - r0) / r0,  u_min, u_max)

Paper parameters (Table I): M = 125 GB, r0 = 0.95, lam = 0.5, u_min = 0,
u_max = 60 GB, T = 100 ms.

Stability (derived here, consistent with the paper's empirical 0 < lam <= 2
sweep): with a saturated store (occupancy == capacity) and constant compute
demand ``d``, the closed loop is u' = f(u) with fixed point
u* = r0*M - d and f'(u*) = 1 - lam, hence

    asymptotically stable    iff 0 < lam < 2
    monotone (no overshoot)  iff 0 < lam <= 1   (linearized; the true
    loop's step grows with distance from u*, so monotone convergence
    from far away empirically needs lam <~ 0.85)

``control_step`` is the scalar, paper-faithful law.  ``vectorized_step`` is
the jit/vmap-friendly JAX form used to run thousands of node controllers in
one fused update (the form a 1000+-node deployment's central controller, or
the cluster simulator, uses).

Beyond-paper extensions (all default to the paper-faithful behaviour):

* asymmetric gains -- reclaim (pressure) faster than grant (slack),
* hysteresis deadband around ``r0`` to suppress jitter from metric noise,
* slope feedforward -- act on a one-interval-ahead usage forecast, buying
  back the monitoring delay the paper calls out as critical (Sec. II.B).
"""

from __future__ import annotations

import dataclasses
import enum
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

GiB = float(2**30)


class Signal(enum.Enum):
    """Which aggregate of the usage window drives Eq. 1.

    Replaces the stringly-typed ``signal="latest"`` knob; plain strings
    are still accepted anywhere a :class:`Signal` is expected via
    :meth:`coerce`.
    """

    LATEST = "latest"
    EWMA = "ewma"
    MAX = "max"

    @classmethod
    def coerce(cls, value: "Signal | str") -> "Signal":
        if isinstance(value, Signal):
            return value
        try:
            return cls(str(value).lower())
        except ValueError:
            raise ValueError("signal must be latest|ewma|max") from None

    def pick(self, agg) -> float:
        """Extract this signal's value from an ``AggregatedMetrics``."""
        return float(getattr(agg, f"used_{self.value}"))


@dataclasses.dataclass(frozen=True)
class ControllerParams:
    """Parameters of the DynIMS control law (paper Table I).

    All capacities are in bytes.
    """

    total_memory: float                 # M
    r0: float = 0.95                    # utilization threshold
    lam: float = 0.5                    # aggressiveness
    u_min: float = 0.0
    u_max: float = 60.0 * GiB
    interval_s: float = 0.1             # T

    # --- beyond-paper knobs (paper-faithful defaults) -------------------
    lam_grant: Optional[float] = None   # gain when r < r0 (None -> lam)
    deadband: float = 0.0               # |r - r0| <= deadband -> hold
    feedforward: float = 0.0            # 0 = off; else weight on dv/dt * T

    def __post_init__(self) -> None:
        if self.total_memory <= 0:
            raise ValueError("total_memory must be positive")
        if not (0.0 < self.r0 <= 1.0):
            raise ValueError("r0 must be in (0, 1]")
        if self.u_min < 0 or self.u_max < self.u_min:
            raise ValueError("need 0 <= u_min <= u_max")
        if self.interval_s <= 0:
            raise ValueError("interval_s must be positive")

    @property
    def is_paper_faithful(self) -> bool:
        return (
            self.lam_grant is None
            and self.deadband == 0.0
            and self.feedforward == 0.0
        )

    def replace(self, **kw) -> "ControllerParams":
        return dataclasses.replace(self, **kw)


def control_step(
    u: float,
    v: float,
    params: ControllerParams,
    *,
    v_prev: Optional[float] = None,
) -> float:
    """One scalar update of the paper's Eq. 1 with clamping.

    Args:
      u: current in-memory-storage capacity (bytes).
      v: observed total system memory usage this interval (bytes).
      params: control-law parameters.
      v_prev: previous interval's usage; only used when
        ``params.feedforward > 0`` (slope feedforward extension).

    Returns:
      The capacity for the next interval, clamped to [u_min, u_max].
    """
    m = params.total_memory
    v_eff = v
    if params.feedforward > 0.0 and v_prev is not None:
        v_eff = v + params.feedforward * (v - v_prev)
    r = v_eff / m
    err = r - params.r0
    if abs(err) <= params.deadband:
        return float(np.clip(u, params.u_min, params.u_max))
    lam = params.lam
    if err < 0 and params.lam_grant is not None:
        lam = params.lam_grant
    u_next = u - lam * v_eff * err / params.r0
    return float(np.clip(u_next, params.u_min, params.u_max))


def vectorized_step(
    u: jax.Array,
    v: jax.Array,
    *,
    total_memory: jax.Array | float,
    r0: float = 0.95,
    lam: float = 0.5,
    u_min: jax.Array | float = 0.0,
    u_max: jax.Array | float = 60.0 * GiB,
    lam_grant: Optional[float] = None,
    deadband: float = 0.0,
    v_prev: Optional[jax.Array] = None,
    feedforward: float = 0.0,
    inv_total_memory: Optional[jax.Array] = None,
    inv_r0: Optional[jax.Array] = None,
) -> jax.Array:
    """Eq. 1 applied to ``N`` node controllers at once (jit/vmap friendly).

    Shapes: ``u``, ``v`` (and optional ``v_prev``) are ``(N,)``;
    ``total_memory`` / ``u_min`` / ``u_max`` broadcast against them.

    ``inv_total_memory`` / ``inv_r0`` are optional precomputed
    reciprocals for hot loops that step the law thousands of times per
    trace (the sweep engine's scan): two divisions per interval become
    multiplies by loop-invariant values.  Results differ from the
    division path by at most 1 ulp; omit them anywhere latency doesn't
    matter.
    """
    u = jnp.asarray(u, jnp.float32)
    v = jnp.asarray(v, jnp.float32)
    v_eff = v
    if feedforward > 0.0 and v_prev is not None:
        v_eff = v + feedforward * (v - jnp.asarray(v_prev, jnp.float32))
    r = (v_eff * inv_total_memory if inv_total_memory is not None
         else v_eff / total_memory)
    err = r - r0
    # Gain selection is resolved at trace time: ``lam_grant`` is a Python
    # constant, so the symmetric case jits to a single multiply and the
    # asymmetric case to one select on the sign of the error.
    if lam_grant is None:
        lam_eff = lam
    else:
        lam_eff = jnp.where(err < 0, lam_grant, lam)
    scaled_err = err * inv_r0 if inv_r0 is not None else err / r0
    delta = lam_eff * v_eff * scaled_err
    if isinstance(deadband, (int, float)) and deadband == 0.0:
        # Trace-time skip: with no deadband the hold branch can only
        # trigger at err == 0, where delta is 0 anyway -- identical
        # result, three fewer ops in the hot loop.
        u_next = u - delta
    else:
        u_next = jnp.where(jnp.abs(err) <= deadband, u, u - delta)
    return jnp.clip(u_next, u_min, u_max)


# ----------------------------------------------------------------------
# Analysis helpers (used by tests and the lambda-sweep benchmark)
# ----------------------------------------------------------------------

def fixed_point_capacity(params: ControllerParams, compute_demand: float) -> float:
    """Equilibrium storage capacity under constant compute demand.

    With a saturated store, v = d + u, so r = r0  <=>  u* = r0*M - d,
    clamped to the admissible range.
    """
    u_star = params.r0 * params.total_memory - compute_demand
    return float(np.clip(u_star, params.u_min, params.u_max))


def closed_loop_eigenvalue(params: ControllerParams) -> float:
    """f'(u*) of the saturated-store closed loop: 1 - lam."""
    return 1.0 - params.lam


def is_stable(params: ControllerParams) -> bool:
    """Asymptotic stability of the saturated-store closed loop."""
    return abs(closed_loop_eigenvalue(params)) < 1.0


def simulate_saturated_loop(
    params: ControllerParams,
    compute_demand: np.ndarray,
    u0: float,
    occupancy: float = 1.0,
) -> np.ndarray:
    """Roll the scalar loop forward against a compute-demand trace.

    The store is modelled as ``occupancy``-full (paper's experiments run
    with a hot cache, occupancy == 1).  Returns the capacity trace
    ``u[t]`` with ``u[0] == u0``, one entry per demand sample.
    """
    demand = np.asarray(compute_demand, dtype=np.float64)
    out = np.empty(demand.shape[0], dtype=np.float64)
    u = float(u0)
    v_prev: Optional[float] = None
    for i, d in enumerate(demand):
        out[i] = u
        v = d + occupancy * u
        u = control_step(u, v, params, v_prev=v_prev)
        v_prev = v
    return out


def settling_time(
    trace: np.ndarray, target: float, tol_frac: float = 0.02
) -> Optional[int]:
    """First index after which the trace stays within tol_frac of target."""
    tol = max(abs(target) * tol_frac, 1e-9)
    ok = np.abs(np.asarray(trace) - target) <= tol
    for i in range(len(ok)):
        if ok[i:].all():
            return i
    return None
