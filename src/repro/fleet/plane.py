"""FleetPlane: the live two-level multi-tenant control plane.

Level two of the hierarchy declared in :mod:`repro.fleet.specs`: a
:class:`FleetPlane` nests one :class:`~repro.core.plane.MemoryPlane`
per tenant inside the budgets a :class:`~repro.fleet.arbiter.FleetArbiter`
grants.  Nesting is pure spec composition -- each tenant's declared
``PlaneSpec`` is re-derived with budget-sized ``params`` (the tenant's
grant plays the role of ``total_memory``) and with its monitors wrapped
in :class:`TenantMonitor` so the nested loop observes utilization
*of the grant*, not of the physical node.  The tenant's Eq. 1 loop is
otherwise exactly the standalone one; a tenant spec runs unmodified
inside or outside a fleet.

Budget changes ride the existing epoch-stamped hot-swap machinery:
:meth:`FleetPlane.rebalance` pushes each tenant's new budget through
``MemoryPlane.swap_params`` (prewarmed off-lock, committed at an
interval boundary), so **no tenant interval ever runs under a torn
budget** -- every :class:`~repro.core.controller.ControlAction` is
stamped with the parameter epoch of the budget it was decided under.
Shrinking tenants commit before growing ones, so the instantaneous sum
of live budgets never exceeds the physical node memory even mid-swap.

Lock hierarchy (acyclic, leaf-to-root; PlaneCheck PC-L001)::

    FleetPlane._tick_lock
      -> MemoryPlane._tick_lock (per tenant)
           -> ArrayController._lock
    FleetPlane._lock            (budget/telemetry snapshot state; leaf)
    FleetArbiter._lock          (leaf; never held around plane calls)
    _BudgetRef._lock            (leaf; single float)
"""

from __future__ import annotations

import threading
import time
from typing import Dict, List, Optional, Tuple

from ..core.controller import ControlAction
from ..core.monitor import MemoryMonitor, MemorySample
from ..core.plane import (DEFAULT_FAULT_LOG, FaultEvent, FaultLog,
                          HealthReport, MemoryPlane, NodeSpec, PlaneSpec)
from .arbiter import (FleetArbiter, FleetGrant, MIN_TENANT_BUDGET,
                      TenantTelemetry)
from .specs import FleetSpec, TenantSpec


class _BudgetRef:
    """A thread-safe mutable float: one tenant's live budget (bytes)."""

    __slots__ = ("_lock", "_value")

    def __init__(self, value: float) -> None:
        self._lock = threading.Lock()
        self._value = float(value)     # guarded-by: _lock

    def get(self) -> float:
        with self._lock:
            return self._value

    def set(self, value: float) -> None:
        with self._lock:
            self._value = float(value)


class TenantMonitor:
    """Budget-scoped view of a node: the composition shim.

    Wraps the tenant's declared monitor so the nested plane's
    aggregator and controller see the *grant* as the node total -- the
    tenant's utilization ratio is usage-of-budget, and the array
    backend's per-node ``M`` self-heals to the live budget on the very
    next flush after a rebalance (``agg.total`` drives it).  ``used``
    and ``storage_used`` pass through untouched: what the tenant does
    inside its grant is its own business.
    """

    def __init__(self, base: MemoryMonitor, budget: _BudgetRef) -> None:
        self._base = base
        self._budget = budget

    def sample(self) -> MemorySample:
        s = self._base.sample()
        return MemorySample(
            node=s.node, timestamp=s.timestamp, used=s.used,
            total=self._budget.get(), storage_used=s.storage_used,
            swap_used=s.swap_used)


class _TenantRuntime:
    """One tenant's nested plane plus its telemetry accumulators."""

    __slots__ = ("spec", "budget", "plane", "u_max0", "u_min0", "stores",
                 "util_sum", "util_n", "hits0", "misses0", "last_telemetry")

    def __init__(self, spec: TenantSpec, budget: _BudgetRef,
                 plane: MemoryPlane) -> None:
        self.spec = spec
        self.budget = budget
        self.plane = plane
        self.u_max0 = spec.plane.params.u_max
        self.u_min0 = spec.plane.params.u_min
        self.stores = [s.store if hasattr(s, "store") else s[0]
                       for ns in spec.plane.nodes for s in ns.stores]
        # epoch accumulators -- guarded-by: FleetPlane._lock
        self.util_sum = 0.0
        self.util_n = 0
        self.hits0 = 0
        self.misses0 = 0
        # last telemetry from a *non-quarantined* epoch; what operators
        # see for a dark tenant -- guarded-by: FleetPlane._lock
        self.last_telemetry: Optional[TenantTelemetry] = None

    def budget_params(self, budget: float):
        """The tenant's law params re-sized to ``budget`` bytes."""
        u_max = min(self.u_max0, budget)
        return self.spec.plane.params.replace(
            total_memory=max(budget, MIN_TENANT_BUDGET),
            u_max=u_max, u_min=min(self.u_min0, u_max))

    def hit_counts(self) -> Tuple[int, int]:
        hits = misses = 0
        for store in self.stores:
            stats = getattr(store, "stats", None)
            if stats is not None:
                hits += stats.hits
                misses += stats.misses
        return hits, misses


class FleetPlane:
    """N tenants' DynIMS loops arbitrated over one physical fleet.

    Drive it like a :class:`~repro.core.plane.MemoryPlane`: one
    :meth:`tick` per control interval runs *every* tenant's nested
    loop; every ``spec.epoch_intervals`` ticks the closing epoch's
    telemetry is folded through the arbiter and the new budgets are
    hot-swapped in.  ``tick`` returns the tenants' actions keyed by
    tenant name.
    """

    def __init__(self, spec: FleetSpec,
                 node_memory: Optional[float] = None) -> None:
        self.spec = spec
        self.node_memory = float(node_memory if node_memory is not None
                                 else spec.fleet_memory_bytes)
        self.arbiter = FleetArbiter(spec)
        self._lock = threading.Lock()
        # Serializes whole fleet intervals against budget commits, the
        # same boundary discipline MemoryPlane._tick_lock gives one
        # plane: an interval never observes half-old, half-new budgets.
        self._tick_lock = threading.Lock()
        self._intervals = 0                 # guarded-by: _tick_lock
        self._last_grant: Optional[FleetGrant] = None  # guarded-by: _lock
        # Fleet-level degradation log (tenant quarantines, rebalance
        # rollbacks); tenant-internal faults live in each nested
        # plane's own fault_log.
        self.fault_log = FaultLog(DEFAULT_FAULT_LOG)
        self._quarantined: set = set()      # guarded-by: _lock
        budgets0 = self.arbiter.initial_budgets(self.node_memory)
        self._tenants: Dict[str, _TenantRuntime] = {}
        for t in spec.tenants:
            ref = _BudgetRef(budgets0[t.name])
            runtime = _TenantRuntime(
                t, ref, MemoryPlane(self._nest(t, ref, budgets0[t.name])))
            h, m = runtime.hit_counts()
            runtime.hits0, runtime.misses0 = h, m
            self._tenants[t.name] = runtime

    @staticmethod
    def _nest(tenant: TenantSpec, ref: _BudgetRef,
              budget: float) -> PlaneSpec:
        """Derive the tenant's inner spec: budget-sized, budget-scoped.

        Per-node ``params`` overrides are rejected -- the nested
        plane's capacity fields *are* the budget, and a node pinned to
        its own ``total_memory`` would silently escape arbitration.
        """
        for ns in tenant.plane.nodes:
            if ns.params is not None:
                raise ValueError(
                    f"tenant {tenant.name!r} node {ns.name!r} carries a "
                    "per-node params override; tenant planes must leave "
                    "capacity sizing to the fleet arbiter")
        p = tenant.plane.params
        u_max = min(p.u_max, budget)
        params = p.replace(total_memory=max(budget, MIN_TENANT_BUDGET),
                           u_max=u_max, u_min=min(p.u_min, u_max))
        nodes = tuple(
            ns.replace(monitor=TenantMonitor(ns.monitor, ref))
            for ns in tenant.plane.nodes)
        return tenant.plane.replace(params=params, nodes=nodes)

    # -- introspection -------------------------------------------------------
    def tenants(self) -> List[str]:
        return list(self._tenants)

    def plane(self, name: str) -> MemoryPlane:
        """The named tenant's live nested plane."""
        return self._tenants[name].plane

    def budgets(self) -> Dict[str, float]:
        """Live per-tenant budgets (bytes).  Always conserving: the
        shrink-first commit order keeps the sum <= node memory even
        when read mid-rebalance."""
        return {name: rt.budget.get() for name, rt in self._tenants.items()}

    @property
    def epoch(self) -> int:
        """Arbitration epochs closed so far."""
        return self.arbiter.epoch

    def last_grant(self) -> Optional[FleetGrant]:
        with self._lock:
            return self._last_grant

    # -- degradation / health ------------------------------------------------
    def log_fault(self, kind: str, node: Optional[str] = None,
                  detail: str = "") -> None:
        """Record a fleet-level fault (quarantine edge, rollback, ...).

        ``_intervals`` is read without the tick lock: a report one
        interval off is fine, a health probe stalling a control
        interval is not.
        """
        self.fault_log.append(FaultEvent(
            kind=kind, node=node, tick=self._intervals,
            timestamp=time.time(), detail=detail))

    def health(self) -> Dict[str, HealthReport]:
        """Per-tenant degradation reports from the nested planes."""
        return {name: rt.plane.health()
                for name, rt in self._tenants.items()}

    def quarantined_tenants(self) -> List[str]:
        """Tenants currently dark: every node quarantined.  These bid
        floors-only at the next rebalance (fail-static at fleet level)."""
        with self._lock:
            return sorted(self._quarantined)

    @staticmethod
    def _tenant_dark(report: HealthReport) -> bool:
        return bool(report.nodes) and (
            len(report.quarantined()) == len(report.nodes))

    def fleet_utilization(self) -> float:
        """Instantaneous fleet-level usage over physical memory."""
        used = 0.0
        nodes = 0
        for rt in self._tenants.values():
            for ns in rt.spec.plane.nodes:
                s = ns.monitor.sample()
                used += s.used
                nodes += 1
        n_phys = max(max(len(rt.spec.plane.nodes)
                         for rt in self._tenants.values()), 1)
        return used / (self.node_memory * n_phys) if nodes else 0.0

    # -- control loop --------------------------------------------------------
    def tick(self) -> Dict[str, List[ControlAction]]:
        """One fleet control interval: every tenant's loop, once.

        On an epoch boundary the closing epoch's telemetry snapshot is
        taken under the tick lock, then :meth:`rebalance` runs *after*
        the lock is released -- arbitration and XLA prewarms never
        stall a concurrent interval.
        """
        telemetry: Optional[Dict[str, TenantTelemetry]] = None
        with self._tick_lock:
            actions: Dict[str, List[ControlAction]] = {}
            for name, rt in self._tenants.items():
                acts = rt.plane.tick()
                actions[name] = acts
                if acts:
                    util = sum(a.utilization for a in acts) / len(acts)
                    with self._lock:
                        rt.util_sum += util
                        rt.util_n += 1
            self._intervals += 1
            if self._intervals % self.spec.epoch_intervals == 0:
                telemetry = self._snapshot_telemetry()
        if telemetry is not None:
            self.rebalance(telemetry)
        return actions

    def _snapshot_telemetry(self) -> Dict[str, TenantTelemetry]:
        """Close the epoch's accumulators into per-tenant telemetry.

        A *dark* tenant -- every node quarantined by its nested plane's
        health state machine -- is not trusted to bid: its accumulators
        were fed by holdover/garbage telemetry.  It bids zero usage, so
        the arbiter grants exactly its effective floor (fail-static at
        fleet level), and its last non-quarantined telemetry is kept on
        the runtime for operators.  Quarantine/rejoin edges land in the
        fleet fault log.
        """
        # Health probes take the nested planes' locks; do them before
        # taking self._lock so fleet _lock stays a leaf.
        dark = {name for name, rt in self._tenants.items()
                if self._tenant_dark(rt.plane.health())}
        events: List[Tuple[str, str]] = []
        out: Dict[str, TenantTelemetry] = {}
        with self._lock:
            for name, rt in self._tenants.items():
                budget = rt.budget.get()
                mean_util = (rt.util_sum / rt.util_n) if rt.util_n else 0.0
                hits, misses = rt.hit_counts()
                dh, dm = hits - rt.hits0, misses - rt.misses0
                hit_ratio = dh / (dh + dm) if (dh + dm) > 0 else 1.0
                tel = TenantTelemetry(
                    usage_bytes=mean_util * budget, budget_bytes=budget,
                    hit_ratio=hit_ratio)
                if name in dark:
                    out[name] = TenantTelemetry(
                        usage_bytes=0.0, budget_bytes=budget, hit_ratio=1.0)
                else:
                    out[name] = tel
                    rt.last_telemetry = tel
                rt.util_sum = 0.0
                rt.util_n = 0
                rt.hits0, rt.misses0 = hits, misses
            for name in dark - self._quarantined:
                events.append(("tenant-quarantine", name))
            for name in self._quarantined - dark:
                events.append(("tenant-rejoin", name))
            self._quarantined = dark
        for kind, name in events:
            self.log_fault(kind, node=name,
                           detail="all nodes quarantined; bidding floor"
                           if kind == "tenant-quarantine"
                           else "nodes healthy again; bidding normally")
        return out

    def rebalance(self, telemetry: Dict[str, TenantTelemetry]) -> FleetGrant:
        """Arbitrate one epoch and hot-swap the new budgets in.

        Tenants commit in shrink-first order (most-shrinking first), so
        the instantaneous sum of live budgets stays conserving at every
        point of the transition.  Each tenant's swap goes through
        ``MemoryPlane.swap_params`` -- compiled and warmed off-lock,
        committed at that tenant's next interval boundary, actions
        epoch-stamped -- which is exactly the torn-budget guarantee the
        single-plane retune loop already has.

        **Partial-failure rollback**: if any tenant's budget swap
        raises mid-commit, every already-committed tenant is restored
        to its pre-rebalance budget in *reverse commit order* -- the
        unwind retraces exactly the intermediate states the commit
        passed through, each of which conserved ``sum(budgets) <=
        node_memory``, so conservation holds at every instant of the
        rollback too.  The fleet then keeps running on the old budgets
        (fail-static) and a ``rebalance-rollback`` event is logged;
        the failed grant is never published as ``last_grant``.
        """
        grant = self.arbiter.allocate(telemetry, self.node_memory)
        deltas = sorted(
            ((grant.budgets[name] - rt.budget.get(), name)
             for name, rt in self._tenants.items()))
        committed: List[Tuple[str, float]] = []   # (tenant, old budget)
        try:
            for _, name in deltas:
                rt = self._tenants[name]
                b = grant.budgets[name]
                old = rt.budget.get()
                rt.budget.set(b)
                rt.plane.swap_params(rt.budget_params(b))
                committed.append((name, old))
        except Exception as exc:
            # The failing tenant's budget ref may already hold the new
            # value with no swap behind it: restore it first (deepest
            # state), then unwind the committed prefix in reverse.
            failed_rt = self._tenants[name]
            failed_rt.budget.set(old)
            for tname, told in reversed(committed):
                trt = self._tenants[tname]
                trt.budget.set(told)
                try:
                    trt.plane.swap_params(trt.budget_params(told))
                except Exception:
                    # Budget ref is restored either way; the nested
                    # plane self-heals its M from agg.total next flush.
                    pass
            self.log_fault(
                "rebalance-rollback", node=name,
                detail=f"swap failed after {len(committed)} commits: "
                       f"{type(exc).__name__}: {exc}")
            with self._lock:
                return self._last_grant if self._last_grant is not None \
                    else FleetGrant(epoch=grant.epoch,
                                    timestamp=grant.timestamp,
                                    budgets=self.budgets(), policy="rollback")
        with self._lock:
            self._last_grant = grant
        return grant

    def __enter__(self) -> "FleetPlane":
        return self

    def __exit__(self, *exc) -> None:
        for rt in self._tenants.values():
            rt.plane.stop()
