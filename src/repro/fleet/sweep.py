"""The fused (tenants x nodes) fleet sweep: two-level control, batched.

The fleet analogue of :mod:`repro.lab.sweep`: one compiled program
rolls the *composed* two-level system forward -- every tenant's Eq. 1
loop every interval, the global arbiter every ``epoch_intervals``
intervals -- as a nested ``lax.scan`` (epochs outer, intervals inner),
``vmap``'d over a :class:`~repro.lab.sweep.GainSet`, sharded over the
same 1-D ``("gains",)`` or 2-D ``("gains", "nodes")`` device mesh the
lab engine uses.  The arbitration policy compiles in as a trace-time
constant through :func:`~repro.fleet.arbiter.arbitrate` -- pure one-hot
array math, no host syncs, so the whole epoch loop fuses.

Stats are the lab's :class:`~repro.lab.score.FleetStats` computed on
the *fleet-level* closed loop -- utilization is all tenants' usage over
physical node memory, capacity is the summed storage grant -- so fleet
sweeps score with the same objectives single-plane sweeps do.  On top
of those, :class:`FleetExtras` streams the arbitration invariants
(conservation slack, floor slack, per-tenant budget statistics) out of
the scan so tests assert them over *every* epoch of every gain point
without materializing a history.

Parity: :func:`fleet_reference` is the float64 numpy oracle -- scalar
per-node loops, the exact runtime arbitration semantics
(:func:`~repro.fleet.arbiter.arbitrate_reference` each epoch) -- and
the test suite pins the fused path against it, mirroring the
``ArrayController`` / ``DynIMSController`` contract one level up.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Dict, NamedTuple, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from ..analysis.runtime import (dispatch_guard, record_trace,
                                sanitizers_enabled)
from ..core.control import vectorized_step
from ..core.traces import GiB
from ..lab.score import (FleetStats, OVER_R0_EPS, SETTLE_TOL,
                         compute_fleet_stats, finalize_fleet_stats,
                         kahan_add, quantile_from_codes, utilization_codes)
from ..lab._compat import warn_once
from ..lab.sweep import (GainSet, _resolve_engine, _shard_map,
                         resolve_devices)
from .arbiter import MIN_TENANT_BUDGET, arbitrate, arbitrate_reference
from .specs import FleetSpec

Array = Union[np.ndarray, "jnp.ndarray"]

# Gains per compiled fleet chunk: the code history is the same
# chunk x T x N uint16 budget as the lab engine's, but the carry is K
# times wider, so default to a smaller chunk.
FLEET_CHUNK = 8


class FleetExtras(NamedTuple):
    """Arbitration invariants streamed out of the fleet scan.

    Each field is per gain point; slacks are worst-case over every
    (epoch, node) -- non-negative iff the invariant held at every
    arbitration the sweep performed.
    """

    conservation_slack_gib: Array    # (G,) min of M - sum_k B[k]
    floor_slack_gib: Array           # (G,) min of B[k] - effective floor
    tenant_budget_mean_gib: Array    # (G, K) mean budget per tenant
    tenant_budget_min_gib: Array     # (G, K) min budget per tenant


def _effective_floors(floors, m, xp):
    """Floors as granted: raised to the minimum budget, admissible."""
    f = xp.maximum(floors[:, None], MIN_TENANT_BUDGET)
    scale = xp.minimum(1.0, m / xp.maximum(f.sum(0), 1.0))
    return f * scale                                   # (K, N)


def _initial_budgets(weights, floors, m, xp):
    """Pre-telemetry budgets: floors + weight share of the remainder.

    Matches :meth:`~repro.fleet.arbiter.FleetArbiter.initial_budgets`
    broadcast over nodes.
    """
    f_eff = _effective_floors(floors, m, xp)
    rem = xp.maximum(m - f_eff.sum(0), 0.0)
    share = (weights / weights.sum())[:, None]
    return f_eff + share * rem                         # (K, N)


def _one_fleet_gain(demand, m, inv_m, w, fl, r0_g, lam_g, lam_grant_g,
                    u_min_g, u_max_g, db_g, ff_g, interval_s, *,
                    policy: str, priority_order: Tuple[int, ...],
                    axis_name: Optional[str] = None,
                    node_shards: int = 1):
    """The composed closed loop for one gain point, fully streamed.

    ``demand`` is ``(n_epochs, E, K, N)`` bytes (tenant compute demand,
    epoch-major); ``m`` the ``(N,)`` physical node memory; ``w``/``fl``
    the ``(K,)`` tenant weights and floors.  The carry holds per-tenant
    capacities and budgets plus the same O(N) stat accumulators the lab
    engine streams; the only scan output is the fleet-utilization code
    history for the quantile bisection.

    Epoch semantics mirror the live :class:`~repro.fleet.plane.FleetPlane`:
    epoch 0 runs under the weight-share initial budgets; at the top of
    epoch ``e >= 1`` the arbiter folds epoch ``e-1``'s mean usage into
    new budgets (``desired = usage / r0``, hit ratio 1 -- the saturated
    store misses nothing), shrunk tenants evict down to their grant
    immediately (``u = min(u, B)``), and every tenant then runs Eq. 1
    inside its grant for the epoch's ``E`` intervals.
    """
    n_epochs, ep_len, k, n_nodes = demand.shape
    f_eff = _effective_floors(fl, m, jnp)
    b0 = _initial_budgets(w, fl, m, jnp)
    inv_r0_g = 1.0 / r0_g
    thr_over = r0_g + OVER_R0_EPS
    thr_settle = r0_g + SETTLE_TOL
    inv_gib = jnp.float32(1.0 / GiB)
    inv_ep = jnp.float32(1.0 / ep_len)
    zeros = jnp.zeros((n_nodes,), jnp.float32)
    cnt_dtype = jnp.int16 if n_epochs * ep_len < 2**15 else jnp.int32
    izeros = jnp.zeros((n_nodes,), cnt_dtype)
    u0 = jnp.minimum(u_max_g, b0)

    def interval_step(carry, d):
        u, b, v_prev, usage, acc = carry
        (us, us_c, cs, cs_c, c2, mx, n_r0, n_viol, last_bad, t) = acc
        v = d + u                                      # saturated store
        # Feedforward applied to v up front (identical to the law's own
        # branch, which trace-time-resolves from a Python float a
        # vmapped gain axis cannot feed).
        v_eff = v + ff_g * (v - v_prev)
        u_max_eff = jnp.minimum(u_max_g, b)
        u_next = vectorized_step(
            u, v_eff, total_memory=b, r0=r0_g, lam=lam_g,
            u_min=jnp.minimum(u_min_g, u_max_eff), u_max=u_max_eff,
            lam_grant=lam_grant_g, deadband=db_g, inv_r0=inv_r0_g)
        r = v.sum(0) * inv_m                           # fleet-level (N,)
        us, us_c = kahan_add(us, us_c, r)
        cap_gib = u_next.sum(0) * inv_gib
        cs, cs_c = kahan_add(cs, cs_c, cap_gib)
        c2 = c2 + cap_gib * cap_gib
        mx = jnp.maximum(mx, r)
        n_r0 = n_r0 + (r > thr_over)
        n_viol = n_viol + (r > 1.0)
        last_bad = jnp.where(r > thr_settle, t, last_bad)
        acc = (us, us_c, cs, cs_c, c2, mx, n_r0, n_viol, last_bad, t + 1)
        return (u_next, b, v, usage + v, acc), utilization_codes(r)

    def epoch_step(carry, xs):
        e, d_ep = xs
        u, b, v_prev, usage, acc, ext = carry
        desired = usage * (inv_ep * inv_r0_g)
        b_new = arbitrate(desired, m, weights=w, floors=fl,
                          priority_order=priority_order, policy=policy,
                          rr_offset=e - 1)
        b = jnp.where(e > 0, b_new, b)
        # Shrunk tenants evict down to the new grant at the boundary --
        # the plane's apply_capacity semantics; grown tenants let the
        # law climb.
        u = jnp.minimum(u, b)
        (u, b, v_prev, usage, acc), codes = jax.lax.scan(
            interval_step, (u, b, v_prev, jnp.zeros_like(usage), acc),
            d_ep, unroll=2)
        cons_min, floor_min, b_sum, b_min = ext
        ext = (jnp.minimum(cons_min, (m - b.sum(0)).min()),
               jnp.minimum(floor_min, (b - f_eff).min()),
               b_sum + b.sum(1),
               jnp.minimum(b_min, b.min(1)))
        return (u, b, v_prev, usage, acc, ext), codes

    acc0 = (zeros, zeros, zeros, zeros, zeros, zeros, izeros, izeros,
            jnp.full((n_nodes,), -1, jnp.int32), jnp.int32(0))
    ext0 = (jnp.float32(jnp.inf), jnp.float32(jnp.inf),
            jnp.zeros((k,), jnp.float32), jnp.full((k,), jnp.inf,
                                                   jnp.float32))
    # Seed v_prev with the first interval's usage so the slope term is
    # exactly zero before there is a previous observation.
    v_prev0 = demand[0, 0] + u0
    usage0 = jnp.zeros((k, n_nodes), jnp.float32)
    carry, codes = jax.lax.scan(
        epoch_step, (u0, b0, v_prev0, usage0, acc0, ext0),
        (jnp.arange(n_epochs, dtype=jnp.int32), demand))
    _, _, _, _, acc, ext = carry
    (us, _, cs, _, c2, mx, n_r0, n_viol, last_bad, _) = acc
    n_global = n_nodes * node_shards
    n_steps = n_epochs * ep_len
    p99 = quantile_from_codes(codes, 0.99, n_steps * n_global,
                              axis_name=axis_name)
    stats = finalize_fleet_stats(
        util_sum=us, util_max=mx, caps_sum_gib=cs, caps_sumsq_gib=c2,
        over_r0_count=n_r0, violation_count=n_viol, last_bad=last_bad,
        p99_utilization=p99, r0=r0_g, n_intervals=n_steps,
        interval_s=interval_s, axis_name=axis_name, n_nodes=n_global)
    cons_min, floor_min, b_sum, b_min = ext
    if axis_name is not None:
        cons_min = jax.lax.pmin(cons_min, axis_name)
        floor_min = jax.lax.pmin(floor_min, axis_name)
        b_sum = jax.lax.psum(b_sum, axis_name)
        b_min = jax.lax.pmin(b_min, axis_name)
    extras = FleetExtras(
        conservation_slack_gib=cons_min * inv_gib,
        floor_slack_gib=floor_min * inv_gib,
        tenant_budget_mean_gib=b_sum * inv_gib / (n_epochs * n_global),
        tenant_budget_min_gib=b_min * inv_gib)
    return stats, extras


def _fleet_chunk_stats(demand, m, w, fl, r0, lam, lam_grant, u_min, u_max,
                       deadband, feedforward, interval_s, *, policy: str,
                       priority_order: Tuple[int, ...], spec: str = "",
                       axis_name: Optional[str] = None,
                       node_shards: int = 1):
    """One gain chunk of the fleet sweep: vmap over the gain arrays."""
    record_trace("fleet.sweep.chunk", chunk=int(r0.shape[0]),
                 epochs=int(demand.shape[0]),
                 ep_len=int(demand.shape[1]),
                 tenants=int(demand.shape[2]),
                 nodes=int(demand.shape[3]), policy=policy, spec=spec)
    demand = jnp.asarray(demand, jnp.float32)
    m = jnp.asarray(m, jnp.float32)
    inv_m = 1.0 / m
    w = jnp.asarray(w, jnp.float32)
    fl = jnp.asarray(fl, jnp.float32)

    def one_gain(r0_g, lam_g, lam_grant_g, u_min_g, u_max_g, db_g, ff_g):
        return _one_fleet_gain(demand, m, inv_m, w, fl, r0_g, lam_g,
                               lam_grant_g, u_min_g, u_max_g, db_g, ff_g,
                               interval_s, policy=policy,
                               priority_order=priority_order,
                               axis_name=axis_name, node_shards=node_shards)

    return jax.vmap(one_gain)(
        jnp.asarray(r0, jnp.float32), jnp.asarray(lam, jnp.float32),
        jnp.asarray(lam_grant, jnp.float32),
        jnp.asarray(u_min, jnp.float32), jnp.asarray(u_max, jnp.float32),
        jnp.asarray(deadband, jnp.float32),
        jnp.asarray(feedforward, jnp.float32))


@functools.lru_cache(maxsize=None)
def _compiled_fleet_sweep(devices: Tuple, policy: str,
                          priority_order: Tuple[int, ...],
                          node_shards: int = 1):
    """Jitted fleet-chunk program for a device tuple (see lab engine).

    Same mesh layouts as ``repro.lab.sweep._compiled_sweep``: one
    device -> plain jit (the bit-exact reference placement);
    ``node_shards == 1`` -> 1-D ``("gains",)`` mesh with demand and
    node memory replicated; otherwise the 2-D ``("gains", "nodes")``
    mesh with the node axis of demand / memory split and the stat folds
    running collectives.
    """
    spec = repr((tuple(str(d) for d in devices), policy, priority_order,
                 node_shards))
    fn = functools.partial(_fleet_chunk_stats, policy=policy,
                           priority_order=priority_order, spec=spec,
                           axis_name="nodes" if node_shards > 1 else None,
                           node_shards=node_shards)
    if len(devices) <= 1:
        return jax.jit(fn)
    gains_specs = (P("gains"),) * 7
    if node_shards == 1:
        mesh = Mesh(np.asarray(devices), ("gains",))
        in_specs = ((P(None, None, None, None), P(None), P(None), P(None))
                    + gains_specs + (P(),))
    else:
        grid = np.asarray(devices).reshape(
            len(devices) // node_shards, node_shards)
        mesh = Mesh(grid, ("gains", "nodes"))
        in_specs = ((P(None, None, None, "nodes"), P("nodes"), P(None),
                     P(None)) + gains_specs + (P(),))
    mapped = _shard_map(fn, mesh=mesh, in_specs=in_specs,
                        out_specs=P("gains"), check_rep=False)
    return jax.jit(mapped)


def fleet_sweep_demand(
    demand: np.ndarray,
    gains: GainSet,
    *,
    node_memory: Union[float, np.ndarray],
    weights: np.ndarray,
    floors: np.ndarray,
    policy: str = "proportional",
    priority_order: Optional[Tuple[int, ...]] = None,
    epoch_intervals: int = 50,
    interval_s: float = 0.1,
    chunk: Optional[int] = None,
    devices: Union[None, int, Sequence] = None,
    node_shards: int = 1,
    horizon: Optional[int] = None,
    engine: str = "xla",
) -> Tuple[FleetStats, FleetExtras]:
    """Sweep a ``(K, N, T)`` per-tenant demand tensor over every gain.

    The fleet analogue of :func:`repro.lab.sweep.sweep_demand`:
    ``demand[k, n, t]`` is tenant ``k``'s compute demand on node ``n``
    at interval ``t`` (bytes), ``T`` must divide into
    ``epoch_intervals``-sized arbitration epochs, and every gain point
    runs the full two-level loop.  Returns ``(G,)``-field
    :class:`~repro.lab.score.FleetStats` over the *fleet-level* closed
    loop plus :class:`FleetExtras` with the arbitration invariants.

    The unified sweep kwargs apply here too: ``horizon`` truncates to
    the first ``horizon`` intervals (still a whole number of epochs),
    and ``engine`` is accepted for API uniformity -- the fleet carry is
    not kernelized yet, so ``engine="pallas"`` falls back to the XLA
    path with a one-time warning.

    Sharding matches the lab engine: gains across devices, optionally
    nodes too (``node_shards``), single device bit-exact.
    """
    if _resolve_engine(engine, "fleet_sweep_demand") == "pallas":
        warn_once("fleet_sweep_demand:pallas",
                  "fleet_sweep_demand(engine='pallas'): the two-level "
                  "fleet carry is not kernelized yet; falling back to "
                  "the XLA engine", RuntimeWarning)
    demand = np.asarray(demand)
    if demand.ndim != 3:
        raise ValueError("demand must be (tenants, nodes, intervals)")
    if horizon is not None:
        if not 1 <= horizon <= demand.shape[2]:
            raise ValueError(f"horizon must be in [1, {demand.shape[2]}]")
        demand = demand[:, :, :horizon]
    k, n_nodes, n_steps = demand.shape
    if epoch_intervals < 1 or n_steps % epoch_intervals:
        raise ValueError(
            f"n_intervals ({n_steps}) must divide into whole epochs of "
            f"{epoch_intervals}")
    weights = np.asarray(weights, np.float64)
    floors = np.asarray(floors, np.float64)
    if weights.shape != (k,) or floors.shape != (k,):
        raise ValueError("weights and floors must be (tenants,)")
    if priority_order is None:
        priority_order = tuple(range(k))
    if sorted(priority_order) != list(range(k)):
        raise ValueError("priority_order must be a permutation of tenants")
    if node_shards < 1:
        raise ValueError("node_shards must be >= 1")
    n_epochs = n_steps // epoch_intervals
    # epoch-major (n_epochs, E, K, N): the outer scan's xs
    demand_e = np.ascontiguousarray(
        demand.transpose(2, 0, 1).reshape(n_epochs, epoch_intervals, k,
                                          n_nodes), dtype=np.float32)
    m = np.broadcast_to(np.asarray(node_memory, np.float64),
                        (n_nodes,)).astype(np.float32)
    devs = resolve_devices(devices)
    if len(devs) <= 1:
        node_shards = 1
    else:
        if len(devs) % node_shards:
            raise ValueError(f"devices ({len(devs)}) must divide evenly "
                             f"into node_shards={node_shards}")
        if n_nodes % node_shards:
            raise ValueError(f"n_nodes ({n_nodes}) must be divisible by "
                             f"node_shards={node_shards}")
    gain_shards = len(devs) // node_shards
    chunk = min(FLEET_CHUNK if chunk is None else max(int(chunk), 1),
                max(len(gains), 1))
    chunk = -(-chunk // gain_shards) * gain_shards
    n_real = len(gains)
    if n_real % chunk:
        pad = GainSet(*(np.repeat(getattr(gains, f.name)[-1:],
                                  chunk - n_real % chunk)
                        for f in dataclasses.fields(GainSet)))
        gains = gains.concat(pad)
    fn = _compiled_fleet_sweep(devs, policy, tuple(priority_order),
                               node_shards)
    demand_dev = jnp.asarray(demand_e)
    m_dev = jnp.asarray(m)
    w_dev = jnp.asarray(weights, jnp.float32)
    fl_dev = jnp.asarray(floors, jnp.float32)
    gain_dev = [jnp.asarray(getattr(gains, f.name), jnp.float32)
                for f in dataclasses.fields(GainSet)]
    iv = jnp.asarray(np.float32(interval_s))
    cols_per_chunk = [[a[lo:lo + chunk] for a in gain_dev]
                     for lo in range(0, len(gains), chunk)]
    if sanitizers_enabled():
        jax.block_until_ready(fn(
            demand_dev, m_dev, w_dev, fl_dev, *cols_per_chunk[0], iv))
    pending = []
    with dispatch_guard():
        for cols in cols_per_chunk:
            pending.append(fn(demand_dev, m_dev, w_dev, fl_dev, *cols, iv))
    chunks = [jax.tree_util.tree_map(np.asarray, pair) for pair in pending]
    stats = FleetStats(*(
        np.concatenate([getattr(st, f) for st, _ in chunks])[:n_real]
        for f in FleetStats._fields))
    extras = FleetExtras(*(
        np.concatenate([getattr(ex, f) for _, ex in chunks])[:n_real]
        for f in FleetExtras._fields))
    return stats, extras


# ---------------------------------------------------------------------------
# The float64 reference (parity oracle)
# ---------------------------------------------------------------------------

def fleet_reference(
    demand: np.ndarray,
    gains: GainSet,
    *,
    node_memory: Union[float, np.ndarray],
    weights: np.ndarray,
    floors: np.ndarray,
    policy: str = "proportional",
    priority_order: Optional[Tuple[int, ...]] = None,
    epoch_intervals: int = 50,
    interval_s: float = 0.1,
) -> Tuple[FleetStats, FleetExtras]:
    """Scalar float64 oracle for :func:`fleet_sweep_demand`.

    Dense numpy per-gain loops, arbitration via
    :func:`~repro.fleet.arbiter.arbitrate_reference` -- readable,
    exact, slow.  Stats come from
    :func:`~repro.lab.score.compute_fleet_stats` on the materialized
    fleet history, so the only expected divergence from the fused path
    is float32 accumulation and the streaming quantile's quantization.
    """
    demand = np.asarray(demand, np.float64)
    k, n_nodes, n_steps = demand.shape
    if priority_order is None:
        priority_order = tuple(range(k))
    weights = np.asarray(weights, np.float64)
    floors = np.asarray(floors, np.float64)
    m = np.broadcast_to(np.asarray(node_memory, np.float64), (n_nodes,))
    n_epochs = n_steps // epoch_intervals
    f_eff = _effective_floors(floors, m, np)
    stats_rows = []
    extras_rows = []
    for g in range(len(gains)):
        r0 = float(gains.r0[g])
        lam = float(gains.lam[g])
        lam_grant = float(gains.lam_grant[g])
        u_min = float(gains.u_min[g])
        u_max = float(gains.u_max[g])
        db = float(gains.deadband[g])
        ff = float(gains.feedforward[g])
        b = _initial_budgets(weights, floors, m, np)
        u = np.minimum(u_max, b)
        v_prev = demand[:, :, 0] + u
        utils = np.empty((n_steps, n_nodes))
        caps = np.empty((n_steps, n_nodes))
        cons_min = np.inf
        floor_min = np.inf
        b_sum = np.zeros(k)
        b_min = np.full(k, np.inf)
        for e in range(n_epochs):
            if e > 0:
                lo = (e - 1) * epoch_intervals
                usage = (demand[:, :, lo:lo + epoch_intervals]
                         + u_hist[..., :]).mean(-1)
                b = arbitrate_reference(
                    usage / r0, m, weights=weights, floors=floors,
                    priority_order=priority_order, policy=policy,
                    rr_offset=(e - 1) % k)
                u = np.minimum(u, b)
            cons_min = min(cons_min, float((m - b.sum(0)).min()))
            floor_min = min(floor_min, float((b - f_eff).min()))
            b_sum += b.sum(1)
            b_min = np.minimum(b_min, b.min(1))
            u_hist = np.empty((k, n_nodes, epoch_intervals))
            for j in range(epoch_intervals):
                t = e * epoch_intervals + j
                d = demand[:, :, t]
                v = d + u
                v_eff = v + ff * (v - v_prev)
                r_t = v_eff / b
                err = r_t - r0
                lam_eff = np.where(err < 0, lam_grant, lam)
                u_max_eff = np.minimum(u_max, b)
                u_min_eff = np.minimum(u_min, u_max_eff)
                u_next = np.where(np.abs(err) <= db, u,
                                  u - lam_eff * v_eff * err / r0)
                u_next = np.clip(u_next, u_min_eff, u_max_eff)
                u_hist[:, :, j] = u
                utils[t] = v.sum(0) / m
                caps[t] = u_next.sum(0)
                v_prev = v
                u = u_next
        stats_rows.append(jax.tree_util.tree_map(
            np.asarray, compute_fleet_stats(utils, caps, r0=r0,
                                            interval_s=interval_s)))
        extras_rows.append(FleetExtras(
            conservation_slack_gib=cons_min / GiB,
            floor_slack_gib=floor_min / GiB,
            tenant_budget_mean_gib=b_sum / GiB / (n_epochs * n_nodes),
            tenant_budget_min_gib=b_min / GiB))
    stats = FleetStats(*(np.stack([getattr(s, f) for s in stats_rows])
                         for f in FleetStats._fields))
    extras = FleetExtras(*(np.stack([np.asarray(getattr(x, f))
                                     for x in extras_rows])
                           for f in FleetExtras._fields))
    return stats, extras


def run_fleet_sweep(scenario, gains: GainSet, *, seed: int = 0,
                    chunk: Optional[int] = None,
                    devices: Union[None, int, Sequence] = None,
                    node_shards: int = 1, horizon: Optional[int] = None,
                    engine: str = "xla") -> Tuple[FleetStats, FleetExtras]:
    """Sweep a registered (or inline) :class:`FleetScenario`.

    Resolves the scenario's per-tenant demand tensor and arbitration
    shape and hands them to :func:`fleet_sweep_demand`; ``horizon`` /
    ``engine`` pass through (the unified sweep kwarg set).
    """
    from .scenario import get_fleet_scenario
    fs = get_fleet_scenario(scenario)
    demand = fs.build_demand(seed=seed)
    return fleet_sweep_demand(
        demand, gains, node_memory=fs.node_memory_gib * GiB,
        weights=fs.weights(), floors=fs.floors_bytes(),
        policy=fs.policy, priority_order=fs.priority_order(),
        epoch_intervals=fs.epoch_intervals, interval_s=fs.interval_s,
        chunk=chunk, devices=devices, node_shards=node_shards,
        horizon=horizon, engine=engine)
