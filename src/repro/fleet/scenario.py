"""FleetScenario: composed multi-tenant experiments for the lab.

A :class:`FleetScenario` stacks per-tenant
:class:`~repro.lab.scenarios.ScenarioSpec` s (by registry name or
inline) into one ``(tenants, nodes, intervals)`` demand tensor plus the
arbitration shape (policy, weights, floors, epoch length), which is
exactly what :func:`repro.fleet.sweep.fleet_sweep_demand` consumes --
the *composed* two-level system sweeps in ScenarioLab the same way a
single plane does.

A registry mirrors the lab's: :func:`register_fleet_scenario` /
:func:`get_fleet_scenario` / :func:`list_fleet_scenarios`.  Registered
out of the box:

``hpcc-spark``
    The paper's Sec. IV mix as two tenants -- an HPCC-style compute
    tenant (high priority, weighted heavy) beside a Spark-style
    storage tenant with a floor (its executor + RDD baseline).
``tenant-churn``
    Three tenants over the fault-injected ``runtime-churn`` trace
    (straggler squeezes/evictions + heartbeat failures -- see
    :mod:`repro.runtime.churn`), the scenario the arbiter's
    starvation/conservation behavior is stress-tested on.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple, Union

import numpy as np

from ..lab.scenarios import ScenarioSpec, get_scenario
from .specs import POLICIES


@dataclasses.dataclass(frozen=True)
class FleetTenant:
    """One tenant's workload plus its arbitration claim.

    ``scenario`` is a lab scenario name or an inline
    :class:`~repro.lab.scenarios.ScenarioSpec`; its demand becomes this
    tenant's compute demand.  ``weight`` / ``priority`` / ``floor_gib``
    mean what they do on :class:`~repro.fleet.specs.TenantSpec`.
    """

    name: str
    scenario: Union[str, ScenarioSpec]
    weight: float = 1.0
    priority: int = 0
    floor_gib: float = 0.0

    def __post_init__(self) -> None:
        if not self.name:
            raise ValueError("tenant name must be non-empty")
        if not self.weight > 0.0:
            raise ValueError("weight must be > 0")
        if self.floor_gib < 0.0:
            raise ValueError("floor_gib must be >= 0")

    def resolve(self) -> ScenarioSpec:
        return get_scenario(self.scenario)


@dataclasses.dataclass(frozen=True)
class FleetScenario:
    """N tenant scenarios composed over one physical fleet."""

    name: str
    tenants: Tuple[FleetTenant, ...]
    policy: str = "proportional"
    epoch_intervals: int = 50
    node_memory_gib: float = 125.0
    description: str = ""

    def __post_init__(self) -> None:
        object.__setattr__(self, "tenants", tuple(self.tenants))
        if not self.tenants:
            raise ValueError("need at least one tenant")
        names = [t.name for t in self.tenants]
        if len(set(names)) != len(names):
            raise ValueError(f"tenant names must be unique; got {names}")
        if self.policy not in POLICIES:
            raise ValueError(f"policy must be one of {POLICIES}")
        if self.epoch_intervals < 1:
            raise ValueError("epoch_intervals must be >= 1")
        specs = [t.resolve() for t in self.tenants]
        shapes = {(s.n_nodes, s.n_intervals, s.interval_s) for s in specs}
        if len(shapes) != 1:
            raise ValueError(
                "tenant scenarios must agree on (n_nodes, n_intervals, "
                f"interval_s); got {sorted(shapes)}")
        n_intervals = specs[0].n_intervals
        if n_intervals % self.epoch_intervals:
            raise ValueError(
                f"n_intervals ({n_intervals}) must divide into whole "
                f"epochs of {self.epoch_intervals}")
        floors = sum(t.floor_gib for t in self.tenants)
        if floors > self.node_memory_gib + 1e-9:
            raise ValueError(
                f"tenant floors ({floors} GiB) exceed node memory "
                f"({self.node_memory_gib} GiB)")

    # -- derived shape -------------------------------------------------------
    @property
    def n_tenants(self) -> int:
        return len(self.tenants)

    @property
    def n_nodes(self) -> int:
        return self.tenants[0].resolve().n_nodes

    @property
    def n_intervals(self) -> int:
        return self.tenants[0].resolve().n_intervals

    @property
    def interval_s(self) -> float:
        return self.tenants[0].resolve().interval_s

    def weights(self) -> np.ndarray:
        return np.array([t.weight for t in self.tenants], np.float64)

    def floors_bytes(self) -> np.ndarray:
        from ..core.traces import GiB
        return np.array([t.floor_gib * GiB for t in self.tenants],
                        np.float64)

    def priority_order(self) -> Tuple[int, ...]:
        return tuple(sorted(range(len(self.tenants)),
                            key=lambda i: (-self.tenants[i].priority, i)))

    def build_demand(self, seed: int = 0) -> np.ndarray:
        """Per-tenant demand tensor ``(K, N, T)`` bytes.

        Tenant ``k`` builds under ``seed + k * 7919`` so tenants are
        decorrelated but the whole composition stays deterministic in
        one seed.
        """
        return np.stack([t.resolve().build_demand(seed=seed + k * 7919)
                         for k, t in enumerate(self.tenants)])

    def replace(self, **kw) -> "FleetScenario":
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_FLEET_REGISTRY: Dict[str, FleetScenario] = {}


def register_fleet_scenario(spec: FleetScenario, *,
                            overwrite: bool = False) -> FleetScenario:
    if not overwrite and spec.name in _FLEET_REGISTRY:
        raise ValueError(f"fleet scenario {spec.name!r} already registered")
    _FLEET_REGISTRY[spec.name] = spec
    return spec


def get_fleet_scenario(
        scenario: Union[str, FleetScenario]) -> FleetScenario:
    if isinstance(scenario, FleetScenario):
        return scenario
    try:
        return _FLEET_REGISTRY[scenario]
    except KeyError:
        known = ", ".join(sorted(_FLEET_REGISTRY))
        raise KeyError(f"unknown fleet scenario {scenario!r}; "
                       f"known: {known}") from None


def list_fleet_scenarios() -> List[str]:
    return sorted(_FLEET_REGISTRY)


# The paper's Sec. IV mix as a two-tenant fleet: HPCC is the priority
# compute tenant (its bursts must never be squeezed by storage), Spark
# the storage-heavy analytics tenant with a floor covering its executor
# + RDD baseline.  5 nodes / 125 GB per Table I; 4200 intervals = 7
# minutes of 100 ms epochs, re-arbitrated every 5 s.
register_fleet_scenario(FleetScenario(
    name="hpcc-spark",
    tenants=(
        FleetTenant("hpcc", "paper-c3-dynims60", weight=3.0, priority=1),
        FleetTenant("spark",
                    ScenarioSpec(
                        name="spark-analytics", family="constant",
                        n_nodes=5, n_intervals=4200, base_gib=30.0,
                        amp_range=(0.9, 1.1),
                        description="Spark executor + RDD cache baseline "
                                    "with mild load jitter"),
                    weight=1.0, priority=0, floor_gib=22.0),
    ),
    policy="proportional", epoch_intervals=50,
    description="paper Sec. IV mix: HPCC compute tenant beside a "
                "Spark-style storage tenant, arbitrated every 5 s"))

# Three tenants over the fault-injected runtime trace: the churn tenant
# replays the StragglerDetector/HeartbeatMonitor-generated demand, a
# serving tenant brings periodic admission bursts, and a best-effort
# batch tenant (no floor, lowest priority) probes starvation behavior.
register_fleet_scenario(FleetScenario(
    name="tenant-churn",
    tenants=(
        FleetTenant("churny-train", "runtime-churn", weight=2.0,
                    priority=2, floor_gib=10.0),
        FleetTenant("serving",
                    ScenarioSpec(
                        name="serving-waves", family="bursty", n_nodes=24,
                        n_intervals=480, base_gib=25.0, burst_gib=20.0,
                        burst_every_s=12.0, burst_len_s=2.0,
                        amp_range=(0.9, 1.1),
                        description="KV-admission waves for the churn "
                                    "composition"),
                    weight=1.5, priority=1, floor_gib=8.0),
        FleetTenant("batch",
                    ScenarioSpec(
                        name="batch-besteffort", family="constant",
                        n_nodes=24, n_intervals=480, base_gib=15.0,
                        amp_range=(0.8, 1.2),
                        description="best-effort batch filler"),
                    weight=1.0, priority=0),
    ),
    policy="proportional", epoch_intervals=48,
    description="fault-injected 3-tenant fleet: straggler/heartbeat "
                "churn + serving bursts + best-effort batch"))
