"""FleetPlane: hierarchical multi-tenant memory arbitration.

A two-level generalization of the paper's single-tenant controller
(ROADMAP's top open item, modeled on migen's ASMI hub -- many masters
arbitrated over one memory core):

* :mod:`.specs`    -- nestable declarations: :class:`TenantSpec` wraps
  a :class:`~repro.core.plane.PlaneSpec` with weight / priority /
  floor; :class:`FleetSpec` composes N tenants over one physical fleet.
* :mod:`.arbiter`  -- the epoch-driven global allocator: priority,
  round-robin, and proportional-share (weighted max-min with floors)
  policies; a float64 numpy reference (:func:`arbitrate_reference`)
  parity-pinned against the batched jax path (:func:`arbitrate`).
* :mod:`.plane`    -- the live :class:`FleetPlane`: one nested
  :class:`~repro.core.plane.MemoryPlane` per tenant, budgets
  hot-swapped through the epoch-stamped ``swap_params`` path (no torn
  budgets).
* :mod:`.sweep`    -- the fused (tenants x nodes) lab engine:
  :func:`fleet_sweep_demand` rolls the composed system over a
  :class:`~repro.lab.sweep.GainSet`, sharded over the lab's 1-D or 2-D
  device mesh, with arbitration invariants streamed out as
  :class:`FleetExtras`; :func:`fleet_reference` is the scalar oracle.
* :mod:`.scenario` -- :class:`FleetScenario` composes per-tenant
  :class:`~repro.lab.scenarios.ScenarioSpec` s (``hpcc-spark``,
  ``tenant-churn``) for registry-driven sweeps.
"""

from .arbiter import (FleetArbiter, FleetGrant, MIN_TENANT_BUDGET,
                      TenantTelemetry, arbitrate, arbitrate_reference)
from .plane import FleetPlane, TenantMonitor
from .scenario import (FleetScenario, FleetTenant, get_fleet_scenario,
                       list_fleet_scenarios, register_fleet_scenario)
from .specs import FleetSpec, POLICIES, TenantSpec
from .sweep import (FLEET_CHUNK, FleetExtras, fleet_reference,
                    fleet_sweep_demand, run_fleet_sweep)

__all__ = [
    "FLEET_CHUNK", "FleetArbiter", "FleetExtras", "FleetGrant",
    "FleetPlane", "FleetScenario", "FleetSpec", "FleetTenant",
    "MIN_TENANT_BUDGET", "POLICIES", "TenantMonitor", "TenantSpec",
    "TenantTelemetry", "arbitrate", "arbitrate_reference",
    "fleet_reference", "fleet_sweep_demand", "get_fleet_scenario",
    "list_fleet_scenarios", "register_fleet_scenario", "run_fleet_sweep",
]
