"""The two-level arbiter: per-tenant budgets from fleet telemetry.

Level one of FleetPlane's control hierarchy.  Every arbitration epoch
the global arbiter folds each tenant's telemetry (demand pressure, hit
ratio, slack) into a *desired* budget and allocates the physical
per-node DRAM among tenants under one of three policies; level two is
each tenant's own Eq. 1 loop running inside its grant.  The split
mirrors migen's ASMI hub (many masters, one memory core) applied to the
paper's controller: the arbiter decides *how much* memory a tenant may
manage, the tenant's DynIMS loop decides *how* to use it.

Policies (all floor-respecting and conserving):

``priority``
    Strict precedence: after floors, tenants drain the remaining pool
    in priority order (ties in declaration order).  Starvation-free
    only through floors -- a low-priority tenant with no floor can be
    starved by design.
``round_robin``
    The *starting* tenant of the precedence chain rotates by one each
    epoch, so over any K consecutive epochs every tenant is first
    exactly once -- starvation-free even with zero floors.
``proportional``
    Weighted max-min fairness with floors: the above-floor remainder is
    water-filled in proportion to tenant weights, capped at each
    tenant's desire; freed capacity re-divides among still-hungry
    tenants (K rounds suffice for K tenants).

Two implementations, parity-pinned like ``ArrayController``:
:func:`arbitrate_reference` is the float64 numpy oracle (per-node
Python loops, exact semantics); :func:`arbitrate` is the batched
``jax.numpy`` form over a full ``(tenants, nodes)`` grid -- pure array
ops (one-hot selects, no scatters, no host syncs) so the fleet sweep
can fuse it into its jitted scan.

Invariants (tested in ``tests/test_fleet.py``):

* conservation -- ``sum_k alloc[k, n] <= m[n]`` for every node;
* floor respect -- ``alloc[k] >= min(floor[k], fair share of m)``;
* demand boundedness -- no tenant receives more than
  ``max(desired, effective floor)``.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from typing import Dict, Optional, Sequence, Tuple, Union

import jax.numpy as jnp
import numpy as np

from .specs import FleetSpec, POLICIES

Array = Union[np.ndarray, "jnp.ndarray"]

#: Smallest budget any tenant is ever granted (bytes).  Keeps a starved
#: tenant's nested ``ControllerParams(total_memory=...)`` valid
#: (total_memory must be positive) and its utilization ratio finite.
MIN_TENANT_BUDGET = float(1 << 20)

# A byte-scale epsilon: tenants needing less than this are "satisfied"
# for water-filling purposes, which makes the K-round unroll exact.
_NEED_EPS = 0.5


def _prepare(desired, m, floors, xp):
    """Shared pre-policy math: effective floors and the free pool.

    Floors are raised to :data:`MIN_TENANT_BUDGET` and -- should an
    undersized node make the raised floors inadmissible -- scaled down
    proportionally so they always fit.  Returns ``(alloc0, need, rem)``
    with floors pre-granted.
    """
    f = xp.maximum(floors, MIN_TENANT_BUDGET)          # (K, 1)
    fsum = f.sum(0)                                    # (1,) broadcasts
    scale = xp.minimum(1.0, m / xp.maximum(fsum, 1.0))
    f_eff = f * scale                                  # (K, N)
    rem = xp.maximum(m - (f * scale).sum(0), 0.0)      # (N,)
    need = xp.maximum(desired - f_eff, 0.0)            # (K, N)
    return f_eff, need, rem


def arbitrate(
    desired: Array,
    m: Array,
    *,
    weights: Array,
    floors: Array,
    priority_order: Tuple[int, ...],
    policy: str,
    rr_offset: Union[int, Array] = 0,
) -> Array:
    """Batched allocation over a ``(tenants, nodes)`` grid (jax).

    Args:
      desired:  ``(K, N)`` bytes each tenant wants on each node.
      m:        ``(N,)`` physical memory per node.
      weights:  ``(K,)`` proportional-share weights.
      floors:   ``(K,)`` guaranteed minima (bytes).
      priority_order: static tenant indices, highest precedence first.
      policy:   one of :data:`~repro.fleet.specs.POLICIES` (trace-time
                constant -- each policy compiles its own program).
      rr_offset: rotation of the round-robin precedence chain; may be a
                traced scalar (the sweep advances it per epoch).

    Returns ``(K, N)`` granted budgets.  Pure ``jax.numpy`` -- one-hot
    selects instead of scatters, every loop a static K-unroll -- so the
    whole thing fuses into callers' jitted scans with no host syncs.
    """
    if policy not in POLICIES:
        raise ValueError(f"policy must be one of {POLICIES}")
    desired = jnp.asarray(desired)
    k = desired.shape[0]
    m = jnp.asarray(m)
    w = jnp.asarray(weights, desired.dtype).reshape(k, 1)
    floors = jnp.asarray(floors, desired.dtype).reshape(k, 1)
    alloc, need, rem = _prepare(desired, m, floors, jnp)
    lanes = jnp.arange(k)

    def drain(alloc, need, rem, idx):
        # One-hot select: grants tenant ``idx`` its residual need out of
        # ``rem`` without a traced-index scatter (pathological on XLA
        # CPU and unsafe under vmap).
        sel = (lanes == idx)[:, None]
        take = jnp.minimum((need * sel).sum(0), rem)
        return (alloc + sel * take, need - sel * take,
                jnp.maximum(rem - take, 0.0))

    if policy == "priority":
        for idx in priority_order:                     # static unroll
            alloc, need, rem = drain(alloc, need, rem, idx)
    elif policy == "round_robin":
        off = jnp.asarray(rr_offset)
        for j in range(k):                             # static unroll
            alloc, need, rem = drain(alloc, need, rem, (off + j) % k)
    else:                                              # proportional
        # Weighted max-min water-filling: K rounds always converge for
        # K tenants (each round either satisfies a tenant or exhausts
        # the pool), so the loop is a static unroll too.
        for _ in range(k):
            active = need > _NEED_EPS
            w_act = w * active
            wsum = w_act.sum(0)
            share = jnp.where(wsum > 0.0,
                              w_act / jnp.maximum(wsum, 1e-30), 0.0)
            give = jnp.minimum(need, share * rem)
            alloc = alloc + give
            need = need - give
            rem = jnp.maximum(rem - give.sum(0), 0.0)
    return alloc


def arbitrate_reference(
    desired: np.ndarray,
    m: np.ndarray,
    *,
    weights: np.ndarray,
    floors: np.ndarray,
    priority_order: Tuple[int, ...],
    policy: str,
    rr_offset: int = 0,
) -> np.ndarray:
    """Float64 numpy oracle for :func:`arbitrate` (same contract).

    Per-node Python loops and exact water-filling -- the readable
    semantics the batched path is parity-pinned against, and the
    implementation :class:`FleetArbiter` runs live (K x 1 per epoch is
    far below jit break-even, and keeping the hot runtime numpy keeps
    the arbiter lock free of blocking compiles).
    """
    if policy not in POLICIES:
        raise ValueError(f"policy must be one of {POLICIES}")
    desired = np.asarray(desired, np.float64)
    k, n = desired.shape
    m = np.broadcast_to(np.asarray(m, np.float64), (n,))
    w = np.asarray(weights, np.float64).reshape(k, 1)
    floors = np.asarray(floors, np.float64).reshape(k, 1)
    alloc, need, rem = _prepare(desired, m, floors, np)
    alloc = alloc * np.ones((k, n))
    need = need * np.ones((k, n))
    rem = rem.copy()
    if policy == "priority":
        chain = list(priority_order)
    elif policy == "round_robin":
        chain = [(rr_offset + j) % k for j in range(k)]
    else:
        chain = None
    if chain is not None:
        for idx in chain:
            take = np.minimum(need[idx], rem)
            alloc[idx] += take
            need[idx] -= take
            rem = np.maximum(rem - take, 0.0)
        return alloc
    for _ in range(k):
        active = need > _NEED_EPS
        if not active.any():
            break
        w_act = w * active
        wsum = w_act.sum(0)
        with np.errstate(invalid="ignore", divide="ignore"):
            share = np.where(wsum > 0.0, w_act / np.maximum(wsum, 1e-30),
                             0.0)
        give = np.minimum(need, share * rem)
        alloc += give
        need -= give
        rem = np.maximum(rem - give.sum(0), 0.0)
    return alloc


# ---------------------------------------------------------------------------
# Runtime telemetry and the live arbiter
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TenantTelemetry:
    """One tenant's aggregate state over the closing epoch.

    ``usage_bytes`` is the tenant's mean observed memory usage (compute
    demand plus its storage grant) per node; ``budget_bytes`` the
    budget it ran the epoch under; ``hit_ratio`` its cache service
    quality (1.0 when the tenant models no cache).
    """

    usage_bytes: float
    budget_bytes: float
    hit_ratio: float = 1.0

    @property
    def pressure(self) -> float:
        """Demand pressure: how full the tenant ran its grant."""
        return (self.usage_bytes / self.budget_bytes
                if self.budget_bytes > 0 else 0.0)

    @property
    def slack_bytes(self) -> float:
        """Unused budget -- what the tenant could cede without pain."""
        return max(self.budget_bytes - self.usage_bytes, 0.0)

    def desired_bytes(self, r0: float = 0.95) -> float:
        """The budget that would hold this tenant at utilization r0.

        Scaled up by the miss ratio: a tenant thrashing its cache
        (``hit_ratio`` < 1) bids for headroom beyond its raw usage,
        which is how service quality feeds arbitration.
        """
        base = self.usage_bytes / max(r0, 1e-6)
        return base * (1.0 + (1.0 - self.hit_ratio))


@dataclasses.dataclass(frozen=True)
class FleetGrant:
    """One arbitration decision: per-tenant budgets for an epoch."""

    epoch: int
    timestamp: float
    budgets: Dict[str, float]          # tenant name -> bytes per node
    policy: str

    def total(self) -> float:
        return float(sum(self.budgets.values()))


class FleetArbiter:
    """The live epoch-driven allocator behind :class:`FleetPlane`.

    Thread-safe and lock-leaf: ``_lock`` guards only the arbiter's own
    epoch/rotation/history state and is never held while calling into
    planes, jax, or any other lock holder -- the fleet lock graph stays
    acyclic (PlaneCheck PC-L001) with this as a terminal node, and the
    numpy reference policy math keeps blocking compiles off the locked
    path (PC-L003).
    """

    def __init__(self, spec: FleetSpec) -> None:
        self.spec = spec
        self._names = spec.names
        self._weights = spec.weights()
        self._floors = spec.floors_bytes().reshape(-1, 1)
        self._order = spec.priority_order()
        self._lock = threading.Lock()
        self._epoch = 0                        # guarded-by: _lock
        self._rr_offset = 0                    # guarded-by: _lock
        self._last: Optional[FleetGrant] = None  # guarded-by: _lock

    @property
    def epoch(self) -> int:
        with self._lock:
            return self._epoch

    def last_grant(self) -> Optional[FleetGrant]:
        with self._lock:
            return self._last

    def initial_budgets(self, node_memory: float) -> Dict[str, float]:
        """Pre-telemetry budgets: floors plus a weight-share of the rest.

        What every tenant starts under before the first epoch closes --
        arbitration-policy-independent, so a fleet's startup transient
        does not depend on which policy it later runs.
        """
        k = len(self._names)
        f = np.maximum(self._floors[:, 0], MIN_TENANT_BUDGET)
        scale = min(1.0, node_memory / max(f.sum(), 1.0))
        f_eff = f * scale
        rem = max(node_memory - f_eff.sum(), 0.0)
        share = self._weights / self._weights.sum()
        b = f_eff + share * rem
        return {self._names[i]: float(b[i]) for i in range(k)}

    def allocate(self, telemetry: Dict[str, TenantTelemetry],
                 node_memory: float) -> FleetGrant:
        """Close one epoch: fold telemetry into next-epoch budgets.

        Missing tenants (no telemetry yet) bid their floor.  Pure numpy
        under the lock -- no jax dispatch, no I/O -- so a concurrent
        ticking fleet never blocks on arbitration for more than the
        policy arithmetic.
        """
        desired = np.array(
            [[telemetry[name].desired_bytes()
              if name in telemetry else 0.0]
             for name in self._names], np.float64)
        with self._lock:
            alloc = arbitrate_reference(
                desired, np.array([node_memory], np.float64),
                weights=self._weights, floors=self._floors[:, 0],
                priority_order=self._order, policy=self.spec.policy,
                rr_offset=self._rr_offset)
            self._rr_offset = (self._rr_offset + 1) % len(self._names)
            self._epoch += 1
            grant = FleetGrant(
                epoch=self._epoch, timestamp=time.time(),
                budgets={self._names[i]: float(alloc[i, 0])
                         for i in range(len(self._names))},
                policy=self.spec.policy)
            self._last = grant
            return grant
