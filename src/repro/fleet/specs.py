"""Nestable control-plane specs: tenants composed over one fleet.

The paper's Eq. 1 sizes *one* in-memory store against *one* compute
workload per node.  FleetPlane generalizes the declaration: a
:class:`TenantSpec` wraps an ordinary :class:`~repro.core.plane.PlaneSpec`
with arbitration metadata (weight / priority / floor), and a
:class:`FleetSpec` composes N tenants over one physical fleet whose
per-node DRAM they share.  Nothing here runs -- these are pure data, the
fleet analogue of :class:`~repro.core.plane.PlaneSpec`; the runtime
lives in :mod:`repro.fleet.plane` and the policy math in
:mod:`repro.fleet.arbiter`.

Nesting works through ``PlaneSpec.replace``: the fleet runtime derives
each tenant's *inner* plane from the declared one by re-sizing its
``params`` to the tenant's current budget and wrapping its monitors so
they report the budget as the node total.  The declared spec is never
mutated; a tenant spec is reusable across fleets.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import numpy as np

from ..core.plane import PlaneSpec
from ..core.traces import GiB

#: Arbitration policies the fleet arbiter implements (see
#: :mod:`repro.fleet.arbiter` for the exact semantics of each).
POLICIES = ("priority", "round_robin", "proportional")


@dataclasses.dataclass(frozen=True)
class TenantSpec:
    """One tenant: a plane spec plus its claim on the shared fleet.

    Fields:
      name:      unique tenant id within a :class:`FleetSpec`.
      plane:     the tenant's control plane, declared exactly as a
                 standalone :class:`~repro.core.plane.PlaneSpec` --
                 the fleet runtime nests it unchanged except for
                 budget-sized params and budget-reporting monitors.
      weight:    proportional-share weight (> 0); the share of
                 above-floor memory this tenant receives when demand
                 exceeds supply under the ``proportional`` policy.
      priority:  static rank for the ``priority`` policy (higher wins;
                 ties break in declaration order).
      floor_gib: guaranteed minimum per-node budget (GiB) honored by
                 every policy before any discretionary allocation.
    """

    name: str
    plane: PlaneSpec
    weight: float = 1.0
    priority: int = 0
    floor_gib: float = 0.0

    def __post_init__(self) -> None:
        if not self.name:
            raise ValueError("tenant name must be non-empty")
        if not self.weight > 0.0:
            raise ValueError("weight must be > 0")
        if self.floor_gib < 0.0:
            raise ValueError("floor_gib must be >= 0")

    @property
    def floor_bytes(self) -> float:
        return self.floor_gib * GiB

    def replace(self, **kw) -> "TenantSpec":
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class FleetSpec:
    """N tenants arbitrated over one physical fleet's DRAM.

    Fields:
      tenants:          the composed :class:`TenantSpec` s (unique
                        names; >= 1).
      policy:           one of :data:`POLICIES`.
      epoch_intervals:  control intervals per arbitration epoch --
                        tenants run Eq. 1 every interval, the global
                        arbiter re-budgets every ``epoch_intervals``.
      fleet_memory_gib: physical per-node DRAM M shared by all tenants
                        (Table I: 125).  Budget conservation
                        (sum of grants <= M per node) is the arbiter's
                        core invariant.
    """

    tenants: Tuple[TenantSpec, ...]
    policy: str = "proportional"
    epoch_intervals: int = 10
    fleet_memory_gib: float = 125.0

    def __post_init__(self) -> None:
        object.__setattr__(self, "tenants", tuple(self.tenants))
        if not self.tenants:
            raise ValueError("need at least one tenant")
        names = [t.name for t in self.tenants]
        if len(set(names)) != len(names):
            raise ValueError(f"tenant names must be unique; got {names}")
        if self.policy not in POLICIES:
            raise ValueError(f"policy must be one of {POLICIES}")
        if self.epoch_intervals < 1:
            raise ValueError("epoch_intervals must be >= 1")
        if self.fleet_memory_gib <= 0:
            raise ValueError("fleet_memory_gib must be positive")
        floors = sum(t.floor_gib for t in self.tenants)
        if floors > self.fleet_memory_gib + 1e-9:
            raise ValueError(
                f"tenant floors ({floors} GiB) exceed fleet memory "
                f"({self.fleet_memory_gib} GiB); floors must be "
                "admissible")

    def __len__(self) -> int:
        return len(self.tenants)

    @property
    def names(self) -> Tuple[str, ...]:
        return tuple(t.name for t in self.tenants)

    @property
    def fleet_memory_bytes(self) -> float:
        return self.fleet_memory_gib * GiB

    def weights(self) -> np.ndarray:
        """``(K,)`` float64 proportional-share weights, tenant order."""
        return np.array([t.weight for t in self.tenants], np.float64)

    def floors_bytes(self) -> np.ndarray:
        """``(K,)`` float64 per-node floors in bytes, tenant order."""
        return np.array([t.floor_bytes for t in self.tenants], np.float64)

    def priority_order(self) -> Tuple[int, ...]:
        """Tenant indices from highest to lowest priority (stable)."""
        return tuple(sorted(range(len(self.tenants)),
                            key=lambda i: (-self.tenants[i].priority, i)))

    def index(self) -> Dict[str, int]:
        return {t.name: i for i, t in enumerate(self.tenants)}

    def replace(self, **kw) -> "FleetSpec":
        return dataclasses.replace(self, **kw)
