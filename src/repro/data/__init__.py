"""Data substrate: shard store + DynIMS-managed cache + pipeline."""

from .pipeline import DataPipeline, PipelineConfig
from .shard_store import ShardStore, write_corpus

__all__ = ["DataPipeline", "PipelineConfig", "ShardStore", "write_corpus"]
