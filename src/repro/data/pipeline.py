"""Input pipeline: deterministic sampling over a DynIMS-managed cache.

This is the paper's architecture transplanted to a training job's input
path: the shard store is the backing tier (OrangeFS), the in-host-RAM
:class:`~repro.core.store.ShardCache` is the Alluxio worker, and a
:class:`~repro.core.plane.MemoryPlane` resizes it every interval so the
*training process* (the priority tenant: parameters, optimizer mirrors,
compilation workspace, staging buffers) never hits memory pressure
while the cache soaks up all remaining host RAM.  The pipeline only
declares its store/monitor to the plane (``plane.attach``); it never
touches bus or controller internals.

Sampling is a deterministic function of (seed, step): restart-safe --
after checkpoint restore the pipeline resumes exactly (no state files).
A background prefetcher warms the cache ``prefetch_depth`` steps ahead.
"""

from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Iterator, Optional

import numpy as np

from ..core.monitor import HostMemoryMonitor
from ..core.plane import MemoryPlane, StoreSpec
from ..core.store import ShardCache, StoreRegistry
from .shard_store import ShardStore


@dataclass(frozen=True)
class PipelineConfig:
    batch_size: int
    seq_len: int
    seed: int = 0
    cache_bytes: float = 256 * 2**20
    eviction: str = "lfu"
    prefetch_depth: int = 2
    dynims: bool = True          # attach the cache to a control plane


class DataPipeline:
    def __init__(self, store: ShardStore, cfg: PipelineConfig,
                 plane: Optional[MemoryPlane] = None,
                 node: str = "localhost"):
        self.store = store
        self.cfg = cfg
        self.cache = ShardCache("dataset-cache", capacity=cfg.cache_bytes,
                                policy=cfg.eviction, priority=0)
        self.plane = plane
        if plane is not None and cfg.dynims:
            self._registry = plane.attach(
                node,
                HostMemoryMonitor(node, storage_used_fn=self.cache.used),
                stores=(StoreSpec(self.cache, cfg.cache_bytes),),
                u0=cfg.cache_bytes)
        else:
            self._registry = StoreRegistry()
            self._registry.register(self.cache, max_bytes=cfg.cache_bytes)
        self._prefetch_q: "queue.Queue[int]" = queue.Queue(maxsize=64)
        self._stop = threading.Event()
        self._prefetcher: Optional[threading.Thread] = None

    # ---- deterministic addressing -----------------------------------------
    def _plan(self, step: int) -> np.ndarray:
        """(batch, 2) array of (shard_id, offset) for one step."""
        man = self.store.manifest
        rng = np.random.default_rng((self.cfg.seed, step))
        per_shard = man.tokens_per_shard - self.cfg.seq_len - 1
        shards = rng.integers(0, man.n_shards, self.cfg.batch_size)
        offsets = rng.integers(0, max(per_shard, 1), self.cfg.batch_size)
        return np.stack([shards, offsets], axis=1)

    def _shard(self, shard_id: int) -> np.ndarray:
        return self.cache.get(int(shard_id),
                              loader=lambda: self.store.read(int(shard_id)))

    def batch(self, step: int) -> dict:
        """Deterministic batch for ``step`` (restart-safe)."""
        if self._prefetcher is None and self.cfg.prefetch_depth:
            self._start_prefetcher(step)
        plan = self._plan(step)
        for future_step in range(step + 1, step + 1 + self.cfg.prefetch_depth):
            for sid in np.unique(self._plan(future_step)[:, 0]):
                try:
                    self._prefetch_q.put_nowait(int(sid))
                except queue.Full:
                    break
        rows = []
        for sid, off in plan:
            shard = self._shard(sid)
            rows.append(shard[off: off + self.cfg.seq_len + 1])
        arr = np.stack(rows)
        return {"tokens": arr[:, :-1].astype(np.int32),
                "labels": arr[:, 1:].astype(np.int32)}

    def __iter__(self) -> Iterator[dict]:
        step = 0
        while True:
            yield self.batch(step)
            step += 1

    # ---- background prefetch -------------------------------------------------
    def _start_prefetcher(self, step0: int) -> None:
        def run():
            while not self._stop.is_set():
                try:
                    sid = self._prefetch_q.get(timeout=0.2)
                except queue.Empty:
                    continue
                if sid not in self.cache:
                    self._shard(sid)
        self._prefetcher = threading.Thread(target=run, daemon=True)
        self._prefetcher.start()

    def close(self) -> None:
        self._stop.set()
        if self._prefetcher is not None:
            self._prefetcher.join(timeout=2.0)
            self._prefetcher = None

    @property
    def hit_ratio(self) -> float:
        return self.cache.stats.hit_ratio
