"""On-disk tokenized shard store (the OrangeFS role in the paper).

A corpus is a directory of fixed-size token shards (``shard-%05d.npy``)
plus ``manifest.json``.  Reads are whole-shard (the unit the DynIMS-
managed cache evicts -- matching Alluxio's block granularity).
"""

from __future__ import annotations

import json
import os
from dataclasses import dataclass
from typing import Optional

import numpy as np


@dataclass(frozen=True)
class Manifest:
    n_shards: int
    tokens_per_shard: int
    vocab_size: int
    dtype: str = "int32"

    @property
    def total_tokens(self) -> int:
        return self.n_shards * self.tokens_per_shard


def write_corpus(path: str, *, n_shards: int, tokens_per_shard: int,
                 vocab_size: int, seed: int = 0,
                 zipf_exponent: float = 1.2) -> Manifest:
    """Generate a synthetic tokenized corpus (deterministic).

    Tokens are drawn from a Zipfian unigram distribution (real corpora
    are Zipf-distributed; exponent ~1 for natural language).  A uniform
    corpus (``zipf_exponent=0``) carries no learnable signal at all, so
    a smoke-scale trainer run can't demonstrate a decreasing loss on it.
    """
    os.makedirs(path, exist_ok=True)
    rng = np.random.default_rng(seed)
    probs = 1.0 / np.arange(1, vocab_size + 1) ** zipf_exponent
    probs /= probs.sum()
    for i in range(n_shards):
        tokens = rng.choice(vocab_size, size=tokens_per_shard,
                            p=probs).astype(np.int32)
        tmp = os.path.join(path, f".tmp-shard-{i:05d}.npy")
        np.save(tmp, tokens)
        os.replace(tmp, os.path.join(path, f"shard-{i:05d}.npy"))
    man = Manifest(n_shards=n_shards, tokens_per_shard=tokens_per_shard,
                   vocab_size=vocab_size)
    with open(os.path.join(path, "manifest.json"), "w") as fh:
        json.dump(man.__dict__, fh)
    return man


class ShardStore:
    def __init__(self, path: str):
        self.path = path
        with open(os.path.join(path, "manifest.json")) as fh:
            self.manifest = Manifest(**json.load(fh))
        self.reads = 0
        self.bytes_read = 0

    def read(self, shard_id: int) -> np.ndarray:
        if not 0 <= shard_id < self.manifest.n_shards:
            raise IndexError(shard_id)
        arr = np.load(os.path.join(self.path, f"shard-{shard_id:05d}.npy"))
        self.reads += 1
        self.bytes_read += arr.nbytes
        return arr
