"""Distributed runtime: failure detection, stragglers, elastic re-mesh."""

from .elastic import ElasticMeshPlanner, MeshPlan
from .fault import HeartbeatMonitor, WorkerState
from .straggler import StragglerDetector

__all__ = ["ElasticMeshPlanner", "HeartbeatMonitor", "MeshPlan",
           "StragglerDetector", "WorkerState"]
