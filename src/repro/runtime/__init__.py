"""Distributed runtime: failure detection, stragglers, chaos, re-mesh."""

from .chaos import (ACTUATION_KINDS, ChaosError, ChaosHandle, ChaosSpec,
                    FAULT_KINDS, FaultSpec, InjectedFault, TELEMETRY_KINDS,
                    inject)
from .elastic import ElasticMeshPlanner, MeshPlan
from .fault import HeartbeatMonitor, WorkerState
from .straggler import StragglerDetector, limplock_nodes

__all__ = ["ACTUATION_KINDS", "ChaosError", "ChaosHandle", "ChaosSpec",
           "ElasticMeshPlanner", "FAULT_KINDS", "FaultSpec",
           "HeartbeatMonitor", "InjectedFault", "MeshPlan",
           "StragglerDetector", "TELEMETRY_KINDS", "WorkerState", "inject",
           "limplock_nodes"]
