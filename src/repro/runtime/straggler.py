"""Straggler detection + DynIMS-coupled mitigation.

Synchronous data-parallel training runs at the pace of the slowest
worker.  Per-step wall times are kept in a per-worker ring buffer; a
worker whose median exceeds ``threshold`` x the fleet median is flagged.

Mitigation order (the coupling is the paper's own observation -- Fig. 2:
memory pressure is a leading cause of host slowdown):

1. Squeeze the straggler's DynIMS-managed stores (set a ``pressure_factor``
   multiplier on its controller's u_max) -- reclaiming host RAM from the
   cache often un-straggles a swapping host within one control interval.
2. If still slow after ``grace`` checks, report it for eviction: the
   trainer treats it as failed (checkpoint/restart on a degraded mesh).
"""

from __future__ import annotations

from collections import defaultdict, deque
from dataclasses import dataclass
from typing import Callable, Deque, Dict, List, Optional

import numpy as np


def limplock_nodes(per_node_times: np.ndarray,
                   threshold: float = 1.5) -> List[int]:
    """Indices of nodes whose time exceeds ``threshold`` x fleet median.

    The batch (offline) form of :class:`StragglerDetector`: given one
    per-node timing vector -- per-stage drain times from an AppGraph
    run (:func:`repro.core.cluster_sim.simulate_app_graph` /
    ``FleetStats.makespan`` analysis), or any per-worker wall times --
    flag the limplock candidates.  Under barrier stages one flagged
    node bounds the *fleet's* stage time, which is exactly why it is
    worth finding.
    """
    times = np.asarray(per_node_times, np.float64).reshape(-1)
    if times.size < 2:
        return []
    fleet = float(np.median(times))
    if fleet <= 0.0:
        return []
    return [int(i) for i in np.flatnonzero(times > threshold * fleet)]


@dataclass
class StragglerReport:
    worker: str
    median_s: float
    fleet_median_s: float
    action: str                  # "squeeze" | "evict"


class StragglerDetector:
    def __init__(self, window: int = 32, threshold: float = 1.5,
                 grace: int = 3,
                 squeeze_cb: Optional[Callable[[str, float], None]] = None,
                 evict_cb: Optional[Callable[[str], None]] = None):
        self.window = window
        self.threshold = threshold
        self.grace = grace
        self._times: Dict[str, Deque[float]] = defaultdict(
            lambda: deque(maxlen=window))
        self._strikes: Dict[str, int] = defaultdict(int)
        self._squeeze_cb = squeeze_cb
        self._evict_cb = evict_cb
        self.reports: List[StragglerReport] = []

    def record(self, worker: str, step_time_s: float) -> None:
        self._times[worker].append(step_time_s)

    def check(self) -> List[StragglerReport]:
        medians = {w: float(np.median(t)) for w, t in self._times.items()
                   if len(t) >= max(4, self.window // 4)}
        if len(medians) < 2:
            return []
        fleet = float(np.median(list(medians.values())))
        out = []
        for w, med in medians.items():
            if med > self.threshold * fleet:
                self._strikes[w] += 1
                if self._strikes[w] >= self.grace:
                    action = "evict"
                    if self._evict_cb:
                        self._evict_cb(w)
                else:
                    action = "squeeze"
                    if self._squeeze_cb:
                        # squeeze proportional to the overshoot
                        self._squeeze_cb(w, fleet / med)
                rep = StragglerReport(w, med, fleet, action)
                out.append(rep)
                self.reports.append(rep)
            else:
                self._strikes[w] = 0
        return out
