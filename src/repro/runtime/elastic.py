"""Elastic re-meshing: pick a working mesh for whatever chips survive.

On failure the planner chooses the largest usable (data, model) grid
from the healthy-device count, preferring to keep the model axis intact
(changing TP width re-shards every weight; changing the data axis only
re-shards the batch and re-balances FSDP).  The trainer then re-lowers
the step for the degraded mesh and restores the last checkpoint into the
new sharding -- parameters saved as full logical arrays re-shard freely.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple

import jax


@dataclass(frozen=True)
class MeshPlan:
    shape: Tuple[int, ...]
    axis_names: Tuple[str, ...]
    n_devices: int
    dropped: int                  # healthy devices left unused

    def make(self, devices: Optional[Sequence] = None):
        devices = list(devices if devices is not None else jax.devices())
        use = devices[: self.n_devices]
        import numpy as np
        from jax.sharding import Mesh
        arr = np.asarray(use).reshape(self.shape)
        return Mesh(arr, self.axis_names)


class ElasticMeshPlanner:
    def __init__(self, model_axis: int = 16,
                 axis_names: Tuple[str, str] = ("data", "model")):
        self.model_axis = model_axis
        self.axis_names = axis_names

    def plan(self, healthy_devices: int,
             model_axis: Optional[int] = None) -> MeshPlan:
        tp = model_axis or self.model_axis
        while tp > 1 and healthy_devices < tp:
            tp //= 2                       # degrade TP only as a last resort
        data = healthy_devices // tp
        if data < 1:
            raise RuntimeError(
                f"cannot build a mesh from {healthy_devices} devices")
        used = data * tp
        return MeshPlan(shape=(data, tp), axis_names=self.axis_names,
                        n_devices=used, dropped=healthy_devices - used)

    def replan_after_failures(self, total: int, failed: int) -> MeshPlan:
        return self.plan(total - failed)
