"""Fault/straggler-driven demand synthesis: runtime churn for the lab.

Bridges the runtime's failure machinery -- :class:`StragglerDetector`
(per-worker step-time rings, squeeze-then-evict mitigation) and
:class:`HeartbeatMonitor` (timeout-based failure detection) -- into a
deterministic demand trace the ScenarioLab sweep engine can replay.
The generator actually *runs* both detectors over a simulated fleet:
straggler nodes report inflated step times, the detector's escalation
(squeeze -> evict) modulates their memory demand, workers in scripted
failure windows stop heartbeating and the monitor's ``check`` collapses
their demand until the heartbeat resumes.

The result is registered in the scenario registry as ``runtime-churn``
(a ``replay``-family :class:`~repro.lab.scenarios.ScenarioSpec`) and
composed into the multi-tenant ``tenant-churn`` fleet scenario -- the
path by which fault injection finally reaches lab sweeps.
"""

from __future__ import annotations

from typing import Dict, List, Tuple

import numpy as np

from ..core.traces import GiB, fleet_demand_traces
from .fault import HeartbeatMonitor
from .straggler import StragglerDetector

# Demand modulation the detector/monitor events map to.
SQUEEZE_DEMAND_SPIKE = 1.25    # a swapping straggler's usage inflates
EVICT_DEMAND_DRAIN = 0.6       # evicted worker restarts with a cold heap
FAILED_DEMAND = 0.05           # crashed node: OS baseline only


def churn_demand(
    n_nodes: int = 24,
    n_intervals: int = 480,
    interval_s: float = 0.1,
    *,
    seed: int = 0,
    straggler_frac: float = 0.2,
    slow_factor: float = 2.5,
    failure_frac: float = 0.15,
    failure_len: int = 60,
    check_every: int = 8,
) -> Tuple[np.ndarray, Dict[str, List[int]]]:
    """Synthesize ``(N, T)`` demand (bytes) by running the detectors.

    A fraction of nodes are stragglers: their reported step times are
    ``slow_factor`` x the fleet's, so :class:`StragglerDetector` first
    squeezes them (modeled as a demand spike -- the swap pressure that
    made them slow) and, ``grace`` strikes later, evicts them (demand
    drains to a cold restart).  A disjoint fraction get one scripted
    failure window: they stop heartbeating, :class:`HeartbeatMonitor`
    declares them failed, and their demand collapses to the OS baseline
    until the heartbeat resumes.

    Deterministic given ``seed``.  Returns the demand matrix and an
    event log (``{"squeeze": [...], "evict": [...], "fail": [...],
    "recover": [...]}``, interval indices) the tests assert against.
    """
    rng = np.random.default_rng(seed)
    base = fleet_demand_traces(n_nodes, n_intervals, interval_s, seed=seed,
                               amp_range=(0.85, 1.15))
    workers = [f"node{i}" for i in range(n_nodes)]
    n_strag = max(int(round(straggler_frac * n_nodes)), 1)
    n_fail = max(int(round(failure_frac * n_nodes)), 1)
    perm = rng.permutation(n_nodes)
    stragglers = {workers[i] for i in perm[:n_strag]}
    failers = {workers[i] for i in perm[n_strag:n_strag + n_fail]}
    fail_start = {w: int(rng.integers(n_intervals // 4,
                                      max(n_intervals - failure_len - 1,
                                          n_intervals // 4 + 1)))
                  for w in failers}

    scale = np.ones(n_nodes)
    events: Dict[str, List[int]] = {"squeeze": [], "evict": [],
                                    "fail": [], "recover": []}
    idx = {w: i for i, w in enumerate(workers)}
    tick = {"t": 0}

    def on_squeeze(worker: str, factor: float) -> None:
        # Squeezing the straggler's stores is the *mitigation*; the
        # demand trace models the pressure that triggered it.
        scale[idx[worker]] = SQUEEZE_DEMAND_SPIKE
        events["squeeze"].append(tick["t"])

    def on_evict(worker: str) -> None:
        scale[idx[worker]] = EVICT_DEMAND_DRAIN
        events["evict"].append(tick["t"])

    detector = StragglerDetector(window=16, threshold=1.5, grace=3,
                                 squeeze_cb=on_squeeze, evict_cb=on_evict)
    monitor = HeartbeatMonitor(interval_s=interval_s, timeout_intervals=5)

    def on_fail(worker: str) -> None:
        scale[idx[worker]] = FAILED_DEMAND
        events["fail"].append(tick["t"])

    def on_recover(worker: str) -> None:
        scale[idx[worker]] = 1.0
        events["recover"].append(tick["t"])

    monitor.on_failure(on_fail)
    monitor.on_recovery(on_recover)
    for w in workers:
        monitor.register(w)

    demand = np.empty_like(base)
    base_step = interval_s
    for t in range(n_intervals):
        tick["t"] = t
        now = t * interval_s
        for w in workers:
            i = idx[w]
            jitter = 1.0 + 0.05 * rng.standard_normal()
            step = base_step * max(jitter, 0.1)
            if w in stragglers:
                step *= slow_factor
            detector.record(w, step)
            in_window = (w in failers
                         and fail_start[w] <= t < fail_start[w] + failure_len)
            if not in_window:
                monitor.heartbeat(w, now=now)
        monitor.check(now=now)
        if t % check_every == 0 and t > 0:
            detector.check()
        demand[:, t] = base[:, t] * scale
    return demand, events
