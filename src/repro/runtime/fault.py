"""Heartbeat-based failure detection.

Workers report heartbeats (in production: over the control-plane bus;
here: direct calls or bus messages).  A worker missing
``timeout_intervals`` consecutive intervals is declared failed and the
registered callbacks fire -- the trainer responds by pausing, asking the
:class:`~repro.runtime.elastic.ElasticMeshPlanner` for a degraded mesh,
and restoring from the last complete checkpoint (checkpoint/restart is
the recovery path; partial state on the failed host is never trusted).
"""

from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional


@dataclass
class WorkerState:
    worker: str
    last_heartbeat: float
    healthy: bool = True
    meta: dict = field(default_factory=dict)


class HeartbeatMonitor:
    """Thread-safe: heartbeats, checks, and callback registration may
    race freely.  Callback lists are mutated only under ``_lock`` and
    snapshotted before firing, so a callback registered mid-``check``
    never mutates the list a concurrent iteration is walking; the
    callbacks themselves run *outside* the lock (they may call back
    into the monitor without deadlocking, and a slow callback never
    delays heartbeat intake)."""

    def __init__(self, interval_s: float = 1.0, timeout_intervals: int = 3):
        self.interval_s = interval_s
        self.timeout_s = interval_s * timeout_intervals
        self._workers: Dict[str, WorkerState] = {}  # guarded-by: _lock
        self._on_failure: List[Callable[[str], None]] = []   # guarded-by: _lock
        self._on_recovery: List[Callable[[str], None]] = []  # guarded-by: _lock
        self._lock = threading.Lock()

    def register(self, worker: str, **meta) -> None:
        with self._lock:
            self._workers[worker] = WorkerState(worker, time.monotonic(),
                                                meta=meta)

    def heartbeat(self, worker: str, now: Optional[float] = None) -> None:
        now = time.monotonic() if now is None else now
        with self._lock:
            st = self._workers.get(worker)
            if st is None:
                self._workers[worker] = WorkerState(worker, now)
                return
            was_healthy = st.healthy
            st.last_heartbeat = now
            st.healthy = True
            callbacks = list(self._on_recovery)   # snapshot, fire unlocked
        if not was_healthy:
            for cb in callbacks:
                cb(worker)

    def check(self, now: Optional[float] = None) -> List[str]:
        """Mark/return newly failed workers."""
        now = time.monotonic() if now is None else now
        newly_failed = []
        with self._lock:
            for st in self._workers.values():
                if st.healthy and now - st.last_heartbeat > self.timeout_s:
                    st.healthy = False
                    newly_failed.append(st.worker)
            callbacks = list(self._on_failure)    # snapshot, fire unlocked
        for w in newly_failed:
            for cb in callbacks:
                cb(w)
        return newly_failed

    def on_failure(self, cb: Callable[[str], None]) -> None:
        with self._lock:
            self._on_failure.append(cb)

    def on_recovery(self, cb: Callable[[str], None]) -> None:
        with self._lock:
            self._on_recovery.append(cb)

    def healthy_workers(self) -> List[str]:
        with self._lock:
            return [w for w, st in self._workers.items() if st.healthy]

    def failed_workers(self) -> List[str]:
        with self._lock:
            return [w for w, st in self._workers.items() if not st.healthy]
