"""ChaosPlane: declarative, seed-deterministic fault injection.

DynIMS exists because a compute burst acted on late is a swap storm
(PAPER.md Sec. II.B/III); the dual claim -- that the *controller*
degrades gracefully when its own sensors and actuators fail -- needs an
adversary to prove.  This module is that adversary: a
:class:`ChaosSpec` declares *which* faults hit *which* nodes *when*,
and :func:`inject` wires it into a live
:class:`~repro.core.plane.MemoryPlane` or
:class:`~repro.fleet.plane.FleetPlane` purely by proxying its monitors
and store registries -- the code under test is never modified, and the
health layer in ``core/plane.py`` is exercised exactly as deployed.

Determinism: whether fault ``f`` fires on node ``n`` at tick ``t`` is a
pure function of ``(spec.seed, f, n, t)``, so a chaos run replays
bit-identically -- no wall-clock coin flips, no flaky CI.

Fault catalog (``FaultSpec.kind``):

==================  ======================================================
``dropout``         monitor raises (sensor gone)
``freeze``          monitor re-delivers its last sample (sensor stuck)
``nan`` / ``inf``   monitor reports non-finite ``used``
``negative``        monitor reports negative ``used``
``slow-sample``     monitor blocks ``magnitude`` seconds before answering
``crash``           node down: monitor raises AND actuation raises
``actuate-raise``   ``set_capacity`` raises (store wedged)
``actuate-timeout`` actuation blocks ``magnitude`` seconds, then raises
``actuate-partial`` only ``magnitude`` of the capacity delta lands
``retune-kill``     ``plane.capture()`` raises, killing a retune round
==================  ======================================================

Usage::

    spec = ChaosSpec(faults=(
        FaultSpec("nan", nodes=("node0",), start=10, duration=20,
                  probability=0.5),
        FaultSpec("crash", nodes=("node3",), start=40, duration=30),
    ), seed=0)
    with inject(plane, spec) as chaos:
        for _ in range(200):
            plane.tick()
    print(chaos.counts(), plane.health().summary())
"""

from __future__ import annotations

import dataclasses
import threading
import time
import zlib
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..core.monitor import MemoryMonitor, MemorySample, MonitorFault

FAULT_KINDS = (
    "dropout", "freeze", "nan", "inf", "negative", "slow-sample", "crash",
    "actuate-raise", "actuate-timeout", "actuate-partial", "retune-kill",
)

#: Fault kinds applied on the telemetry (monitor) path.
TELEMETRY_KINDS = ("dropout", "freeze", "nan", "inf", "negative",
                   "slow-sample", "crash")
#: Fault kinds applied on the actuation (registry) path.
ACTUATION_KINDS = ("actuate-raise", "actuate-timeout", "actuate-partial",
                   "crash")

_DEFAULT_MAGNITUDE = {
    "slow-sample": 0.01,      # seconds the sample blocks
    "actuate-timeout": 0.0,   # seconds the actuation blocks (then raises)
    "actuate-partial": 0.5,   # fraction of the capacity delta applied
}


class ChaosError(MonitorFault):
    """An injected fault (monitor or actuation path)."""


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One fault family scheduled onto part of the fleet.

    Fields:
      kind:        one of :data:`FAULT_KINDS`.
      nodes:       node names hit by this fault; None = every node.
      start:       first tick (inclusive) the fault is eligible.
      duration:    ticks the window stays open; None = forever.
      probability: per-tick firing chance while the window is open
                   (1.0 = every tick in the window).
      magnitude:   kind-specific knob (seconds for ``slow-sample`` /
                   ``actuate-timeout``, applied fraction for
                   ``actuate-partial``); None uses the kind's default.
    """

    kind: str
    nodes: Optional[Tuple[str, ...]] = None
    start: int = 0
    duration: Optional[int] = None
    probability: float = 1.0
    magnitude: Optional[float] = None

    def __post_init__(self) -> None:
        if self.kind not in FAULT_KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"choose from {FAULT_KINDS}")
        if self.nodes is not None:
            object.__setattr__(self, "nodes", tuple(self.nodes))
        if self.start < 0:
            raise ValueError("start must be >= 0")
        if self.duration is not None and self.duration < 1:
            raise ValueError("duration must be >= 1 (or None for forever)")
        if not 0.0 < self.probability <= 1.0:
            raise ValueError("probability must be in (0, 1]")

    def effective_magnitude(self) -> float:
        if self.magnitude is not None:
            return float(self.magnitude)
        return _DEFAULT_MAGNITUDE.get(self.kind, 0.0)

    def covers(self, node: str) -> bool:
        return self.nodes is None or node in self.nodes

    def open_at(self, t: int) -> bool:
        if t < self.start:
            return False
        return self.duration is None or t < self.start + self.duration

    def replace(self, **kw) -> "FaultSpec":
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class ChaosSpec:
    """A full fault schedule: what the adversary throws at the plane."""

    faults: Tuple[FaultSpec, ...]
    seed: int = 0

    def __post_init__(self) -> None:
        object.__setattr__(self, "faults", tuple(self.faults))
        for f in self.faults:
            if not isinstance(f, FaultSpec):
                raise TypeError(f"faults must be FaultSpec, got {type(f)}")

    def replace(self, **kw) -> "ChaosSpec":
        return dataclasses.replace(self, **kw)

    def fires(self, fault_index: int, node: str, t: int) -> bool:
        """Does fault ``fault_index`` hit ``node`` at tick ``t``?

        Pure and order-independent: seeded per ``(seed, fault, node,
        tick)``, so the schedule replays identically however the
        queries interleave.
        """
        f = self.faults[fault_index]
        if not (f.open_at(t) and f.covers(node)):
            return False
        if f.probability >= 1.0:
            return True
        rng = np.random.default_rng(
            [self.seed, fault_index, zlib.crc32(node.encode()), t])
        return bool(rng.random() < f.probability)


@dataclasses.dataclass(frozen=True)
class InjectedFault:
    """One fault actually delivered (the injector's own audit log)."""

    kind: str
    node: str
    tick: int


class _Clock:
    """Shared tick counter: advanced once per outer ``tick()``."""

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._now = -1                 # guarded-by: _lock

    def advance(self) -> int:
        with self._lock:
            self._now += 1
            return self._now

    def now(self) -> int:
        with self._lock:
            return self._now


class _EventLog:
    """Thread-safe append-only audit log of delivered faults."""

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._events: List[InjectedFault] = []   # guarded-by: _lock

    def add(self, kind: str, node: str, tick: int) -> None:
        with self._lock:
            self._events.append(InjectedFault(kind, node, tick))

    def snapshot(self) -> List[InjectedFault]:
        with self._lock:
            return list(self._events)


class ChaosMonitor:
    """Telemetry-path fault proxy around one node's monitor.

    Always advances the underlying monitor (the *world* keeps moving;
    only the *sensor* misbehaves), then corrupts, freezes, delays, or
    drops the observation according to the schedule.
    """

    def __init__(self, base: MemoryMonitor, node: str, spec: ChaosSpec,
                 clock: _Clock, events: _EventLog):
        self._base = base
        self._node = node
        self._spec = spec
        self._clock = clock
        self._events = events
        self._indices = [i for i, f in enumerate(spec.faults)
                         if f.kind in TELEMETRY_KINDS and f.covers(node)]
        self._last: Optional[MemorySample] = None

    def _fires(self, t: int) -> Dict[str, FaultSpec]:
        out: Dict[str, FaultSpec] = {}
        for i in self._indices:
            if self._spec.fires(i, self._node, t):
                out.setdefault(self._spec.faults[i].kind,
                               self._spec.faults[i])
        return out

    def sample(self) -> MemorySample:
        t = self._clock.now()
        fired = self._fires(t)
        if "slow-sample" in fired:
            self._events.add("slow-sample", self._node, t)
            time.sleep(fired["slow-sample"].effective_magnitude())
        try:
            s = self._base.sample()
        except Exception:
            # The base monitor faulted on its own; let it through --
            # the health layer treats it like any dropout.
            raise
        if "crash" in fired or "dropout" in fired:
            kind = "crash" if "crash" in fired else "dropout"
            self._events.add(kind, self._node, t)
            raise ChaosError(f"{self._node}: injected {kind} at tick {t}")
        if "freeze" in fired and self._last is not None:
            self._events.add("freeze", self._node, t)
            return self._last
        for kind, bad in (("nan", float("nan")), ("inf", float("inf")),
                          ("negative", None)):
            if kind in fired:
                self._events.add(kind, self._node, t)
                used = -abs(s.used) - 1.0 if bad is None else bad
                return MemorySample(
                    node=s.node, timestamp=s.timestamp, used=used,
                    total=s.total, storage_used=s.storage_used,
                    swap_used=s.swap_used)
        self._last = s
        return s


class ChaosRegistry:
    """Actuation-path fault proxy around one node's store registry."""

    def __init__(self, base, node: str, spec: ChaosSpec, clock: _Clock,
                 events: _EventLog):
        self._base = base
        self._node = node
        self._spec = spec
        self._clock = clock
        self._events = events
        self._indices = [i for i, f in enumerate(spec.faults)
                         if f.kind in ACTUATION_KINDS and f.covers(node)]

    # -- delegation ---------------------------------------------------------
    def register(self, store, max_bytes: float) -> None:
        self._base.register(store, max_bytes)

    def stores(self):
        return self._base.stores()

    def total_used(self) -> float:
        return self._base.total_used()

    def total_capacity(self) -> float:
        return self._base.total_capacity()

    # -- faulted actuation --------------------------------------------------
    def apply_capacity(self, u: float) -> list:
        t = self._clock.now()
        fired = {self._spec.faults[i].kind: self._spec.faults[i]
                 for i in self._indices if self._spec.fires(i, self._node, t)}
        if "crash" in fired or "actuate-raise" in fired:
            kind = "crash" if "crash" in fired else "actuate-raise"
            self._events.add(kind, self._node, t)
            raise ChaosError(
                f"{self._node}: injected {kind} actuation at tick {t}")
        if "actuate-timeout" in fired:
            self._events.add("actuate-timeout", self._node, t)
            time.sleep(fired["actuate-timeout"].effective_magnitude())
            raise ChaosError(
                f"{self._node}: injected actuation timeout at tick {t}")
        if "actuate-partial" in fired:
            self._events.add("actuate-partial", self._node, t)
            frac = fired["actuate-partial"].effective_magnitude()
            cur = self._base.total_capacity()
            return self._base.apply_capacity(cur + frac * (u - cur))
        return self._base.apply_capacity(u)


class ChaosHandle:
    """A live injection: proxies installed, clock wired, revertible.

    Usable as a context manager; :meth:`revert` restores every proxied
    monitor, registry, and method so the plane runs clean again (the
    way a chaos drill ends: faults stop, the plane must rejoin).
    """

    def __init__(self, target, spec: ChaosSpec):
        self.spec = spec
        self.target = target
        self.clock = _Clock()
        self._events = _EventLog()
        self._undo: List = []
        self._reverted = False
        planes = self._member_planes(target)
        for plane in planes:
            self._wire_plane(plane)
        # The outer tick drives the fault schedule's clock.
        orig_tick = target.tick

        def _ticked(*a, **kw):
            self.clock.advance()
            return orig_tick(*a, **kw)

        target.tick = _ticked
        self._undo.append(lambda: setattr(target, "tick", orig_tick))
        self._wire_retune_kill(planes)

    @staticmethod
    def _member_planes(target) -> List:
        tenants = getattr(target, "_tenants", None)
        if tenants is not None:                       # FleetPlane
            return [rt.plane for rt in tenants.values()]
        return [target]                               # MemoryPlane

    def _wire_plane(self, plane) -> None:
        # Proxy monitors and the raw registries *inside* the plane's
        # actuation shield, under the plane's own wiring lock, so a
        # concurrently ticking plane never sees a half-installed proxy.
        with plane._lock:
            for node, mon in list(plane._monitors.items()):
                proxy = ChaosMonitor(mon, node, self.spec, self.clock,
                                     self._events)
                plane._monitors[node] = proxy
                self._undo.append(
                    lambda p=plane, n=node, m=mon: p._monitors
                    .__setitem__(n, m))
            for node, shield in list(plane._registries.items()):
                inner = shield._inner
                shield._inner = ChaosRegistry(inner, node, self.spec,
                                              self.clock, self._events)
                self._undo.append(
                    lambda s=shield, i=inner: setattr(s, "_inner", i))

    def _wire_retune_kill(self, planes: List) -> None:
        if not any(f.kind == "retune-kill" for f in self.spec.faults):
            return
        idx = [i for i, f in enumerate(self.spec.faults)
               if f.kind == "retune-kill"]
        for plane in planes:
            orig = getattr(plane, "capture", None)
            if orig is None:
                continue

            def _capture(*a, _orig=orig, _plane=plane, **kw):
                t = self.clock.now()
                for i in idx:
                    if self.spec.fires(i, "retune", t):
                        self._events.add("retune-kill", "retune", t)
                        raise ChaosError(
                            f"injected retune kill at tick {t}")
                return _orig(*a, **kw)

            plane.capture = _capture
            self._undo.append(
                lambda p=plane, o=orig: setattr(p, "capture", o))

    # -- audit ---------------------------------------------------------------
    def events(self) -> List[InjectedFault]:
        """Every fault actually delivered, in delivery order."""
        return self._events.snapshot()

    def counts(self) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for e in self._events.snapshot():
            out[e.kind] = out.get(e.kind, 0) + 1
        return out

    # -- lifecycle -----------------------------------------------------------
    def revert(self) -> None:
        """Uninstall every proxy; the plane runs clean afterwards."""
        if self._reverted:
            return
        self._reverted = True
        for undo in reversed(self._undo):
            undo()
        self._undo.clear()

    def __enter__(self) -> "ChaosHandle":
        return self

    def __exit__(self, *exc) -> None:
        self.revert()


def inject(target, spec: ChaosSpec) -> ChaosHandle:
    """Install ``spec``'s fault schedule into a live plane.

    ``target`` is a :class:`~repro.core.plane.MemoryPlane` or a
    :class:`~repro.fleet.plane.FleetPlane` (every tenant's nested plane
    is wired; the fleet tick drives the shared clock).  Returns a
    :class:`ChaosHandle`; ``handle.revert()`` (or leaving the context)
    uninstalls everything.
    """
    return ChaosHandle(target, spec)
