"""Warn-once helpers for the unified sweep API's deprecation shims.

The PR-9 API redesign renamed a handful of kwargs and module constants
(``score_fn`` -> ``objective``, ``DEFAULT_CHUNK`` -> ``XLA_DEFAULT_CHUNK``,
``ScoreFn`` -> ``Objective``); every old spelling keeps working through a
shim that warns exactly once per process per call site key, so a sweep
inside a tuning loop does not flood stderr.  The registry is process
global -- tests that assert on the warning call :func:`reset_warnings`
first.

Kwarg mapping (old -> new):

========================  =========================  ====================
old spelling              new spelling               where
========================  =========================  ====================
``score_fn=``             ``objective=``             ``tune_gains`` /
                                                     ``halving_tune`` /
                                                     ``tune_portfolio`` /
                                                     ``retune_online``
``lab.DEFAULT_CHUNK``     ``lab.XLA_DEFAULT_CHUNK``  ``repro.lab`` /
                                                     ``repro.lab.sweep``
``lab.tune.ScoreFn``      ``lab.tune.Objective``     type alias
========================  =========================  ====================
"""

from __future__ import annotations

import threading
import warnings

_WARNED: set = set()
_LOCK = threading.Lock()


def warn_once(key: str, message: str, category=DeprecationWarning,
              stacklevel: int = 3) -> bool:
    """Emit ``message`` the first time ``key`` is seen; no-op after.

    Returns True when the warning actually fired (tests use it).
    """
    with _LOCK:
        if key in _WARNED:
            return False
        _WARNED.add(key)
    warnings.warn(message, category, stacklevel=stacklevel)
    return True


def reset_warnings() -> None:
    """Forget every warned key (test isolation only)."""
    with _LOCK:
        _WARNED.clear()
