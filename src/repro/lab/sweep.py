"""The vectorized, device-resident scenario-sweep engine.

One compiled program runs thousands of closed-loop simulations: a
scenario's demand traces are compiled to a dense ``(N, T)`` array, the
full control loop (saturated store, Eq. 1 update, clamp) runs as a
single jitted :func:`jax.lax.scan` over time, and that scan is
``vmap``'d over a :class:`GainSet` -- a whole gain grid advances in
lockstep, one XLA dispatch per gain chunk.

Closed-loop histories never leave the device.  Every
:class:`~repro.lab.score.FleetStats` metric streams through the scan
carry as per-node accumulators (Kahan-compensated float32 sums -- see
:func:`~repro.lab.score.kahan_add`), and the p99 comes from the
streaming fixed-bin quantile (:mod:`~repro.lab.score`): utilization is
quantized to ``uint16`` codes on a 65536-bin grid and the quantile is
bisected out of the implicit histogram with 16 count reductions.  Each
chunk therefore transfers O(G) scalars to the host -- the historical
engine shipped the full ``(G, T, N)`` utilization history back for a
numpy p99 (128 MB per 8-gain chunk at fleet scale), which capped chunk
size and serialized every chunk behind a host sync.  Chunks are now
dispatched asynchronously and collected once at the end.

The gain axis also shards across devices: ``sweep_demand(...,
devices=...)`` (auto-detected by default) runs each device's slice of
the chunk under ``shard_map`` over a 1-D ``("gains",)`` mesh; demand is
replicated, gains are split, and no collectives are needed.  With a
single device the plain jitted path is taken and results are
bit-identical to the sharded one (each gain's program is unchanged).

Gain chunks bound peak *device* memory (the uint16 code history is
``chunk x T x N x 2`` bytes); ``chunk=None`` picks the largest chunk
within :data:`CODES_BUDGET_BYTES`.

**CacheLoop**: a scenario with a :class:`~repro.lab.scenarios.CacheSpec`
adds per-node cache state to the scan carry -- resident-set size, an
analytic reuse-distance hit ratio, eviction/refill flux as the
controller resizes the store, and a penalty model folding misses +
evictions + the Fig.-2 pressure curve into modeled app runtime
(:class:`~repro.lab.score.FleetStats` ``hit_ratio`` / ``evicted_bytes``
/ ``app_runtime``).  The cache knobs are trace-time constants, so
cache-off scenarios compile the exact pre-CacheLoop program, and a
mixed paper/beyond-paper gain set is partitioned by law class
(:func:`paper_law_mask`) so only the points with active beyond-paper
knobs pay for the fallback executable.

**AppGraph**: a scenario with an
:class:`~repro.lab.appgraph.AppGraphSpec` co-simulates its stage DAG in
the same scan -- per-node task queues drain at a rate stretched by the
Fig.-2 pressure curve (and cache stalls), barrier stages promote on a
fleet-wide ``pmin``, and stage transitions feed their held demand back
into the trace the controller observes.  End-to-end wall clock streams
out as ``FleetStats.makespan``; ``app_graph=None`` compiles the exact
pre-AppGraph program.
"""

from __future__ import annotations

import dataclasses
import functools
import hashlib
import time
from typing import NamedTuple, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from ..analysis.runtime import (dispatch_guard, record_trace,
                                sanitizers_enabled)
from ._compat import warn_once

try:                                    # jax >= 0.5 exposes it at top level
    _shard_map = jax.shard_map
except AttributeError:
    from jax.experimental.shard_map import shard_map as _shard_map

from ..core.control import ControllerParams, vectorized_step
from ..core.eviction import policy_model
from ..core.traces import GiB
from .appgraph import AppGraphSpec, compile_graph
from .scenarios import CacheSpec, ScenarioSpec, get_scenario
from .score import (FleetStats, OVER_R0_EPS, SETTLE_TOL, _axis_min,
                    _axis_sum, default_score, finalize_fleet_stats,
                    hpl_slowdown_curve, kahan_add, quantile_from_codes,
                    utilization_codes)

# Upper bound on gains per compiled chunk; the auto-chunk logic lowers
# it when the per-gain uint16 code history would blow the budget.
# (Named for the engine it belongs to since PR 9 -- the pallas engine
# tiles lanes by pallas_sweep.TILE_GAINS instead.  The old spelling
# ``DEFAULT_CHUNK`` still resolves through a module __getattr__ shim.)
XLA_DEFAULT_CHUNK = 32
CODES_BUDGET_BYTES = 256 << 20

ENGINES = ("xla", "pallas")


def _resolve_engine(engine: str, who: str) -> str:
    if engine not in ENGINES:
        raise ValueError(f"{who}: unknown engine {engine!r}; "
                         f"expected one of {ENGINES}")
    return engine


# ---------------------------------------------------------------------------
# Gain sets
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class GainSet:
    """``G`` candidate control-law gain points, packed as arrays.

    Every law knob the sweep engine simulates is here -- a
    :class:`ControllerParams` round-trips losslessly through
    :meth:`from_params` / :meth:`params_at`, so the loop a tune run
    scores is the loop the tuned params deploy.  ``lam_grant`` equals
    ``lam`` where the gains are symmetric (the paper-faithful case);
    capacities are bytes.  Scalar / length-1 fields broadcast to the
    set's length.
    """

    r0: np.ndarray
    lam: np.ndarray
    lam_grant: np.ndarray
    u_min: np.ndarray
    u_max: np.ndarray
    deadband: np.ndarray = 0.0
    feedforward: np.ndarray = 0.0

    def __post_init__(self) -> None:
        arrays = {f.name: np.atleast_1d(np.asarray(getattr(self, f.name),
                                                   dtype=np.float64))
                  for f in dataclasses.fields(self)}
        g = max(a.shape[0] for a in arrays.values())
        sizes = {a.shape[0] for a in arrays.values()} - {1, g}
        if sizes:
            raise ValueError(f"gain arrays must share a length or be "
                             f"scalar; got lengths {sizes | {g}}")
        for name, arr in arrays.items():
            object.__setattr__(self, name,
                               np.broadcast_to(arr, (g,)).copy()
                               if arr.shape[0] != g else arr)

    def __len__(self) -> int:
        return self.r0.shape[0]

    @classmethod
    def from_params(cls, params: ControllerParams,
                    *more: ControllerParams) -> "GainSet":
        ps = (params,) + more
        return cls(
            r0=np.array([p.r0 for p in ps]),
            lam=np.array([p.lam for p in ps]),
            lam_grant=np.array([p.lam_grant if p.lam_grant is not None
                                else p.lam for p in ps]),
            u_min=np.array([p.u_min for p in ps]),
            u_max=np.array([p.u_max for p in ps]),
            deadband=np.array([p.deadband for p in ps]),
            feedforward=np.array([p.feedforward for p in ps]),
        )

    def params_at(self, i: int, base: ControllerParams) -> ControllerParams:
        """Materialize gain point ``i`` as a :class:`ControllerParams`."""
        lam = float(self.lam[i])
        lam_grant = float(self.lam_grant[i])
        return base.replace(
            r0=float(self.r0[i]), lam=lam,
            lam_grant=None if lam_grant == lam else lam_grant,
            u_min=float(self.u_min[i]), u_max=float(self.u_max[i]),
            deadband=float(self.deadband[i]),
            feedforward=float(self.feedforward[i]))

    def concat(self, other: "GainSet") -> "GainSet":
        return GainSet(*(np.concatenate([getattr(self, f.name),
                                         getattr(other, f.name)])
                         for f in dataclasses.fields(self)))

    def slice(self, lo: int, hi: int) -> "GainSet":
        return GainSet(*(getattr(self, f.name)[lo:hi]
                         for f in dataclasses.fields(self)))

    def take(self, idx: Sequence[int]) -> "GainSet":
        """Gather gain points by index (survivor promotion in halving)."""
        idx = np.asarray(idx, dtype=np.int64)
        return GainSet(*(getattr(self, f.name)[idx]
                         for f in dataclasses.fields(self)))


# ---------------------------------------------------------------------------
# The compiled chunk: streaming closed loop, one gain
# ---------------------------------------------------------------------------

def _one_gain_stream(demand_tn, m, inv_m, r0_g, lam_g, lam_grant_g, u_min_g,
                     u_max_g, db_g, ff_g, interval_s, occupancy, *,
                     paper_law: bool, unit_occupancy: bool,
                     static_bounds: Optional[Tuple[float, float]],
                     cache: Optional[CacheSpec],
                     app_graph: Optional[AppGraphSpec] = None,
                     work_sn=None,
                     axis_name: Optional[str] = None,
                     node_shards: int = 1):
    """Closed loop for one gain point, fully streamed.

    The scan carry holds only per-node accumulators (O(N) state); the
    sole scan output is the uint16 utilization code history consumed by
    the in-program quantile bisection.  Nothing of size T x N is ever
    staged for the host.

    ``paper_law`` / ``unit_occupancy`` / ``static_bounds`` are
    trace-time specializations (set by :func:`sweep_demand` after
    inspecting the whole gain set): when every gain point is
    paper-faithful -- symmetric gains, no deadband, no feedforward --
    the slope state, the gain select and the hold branch drop out of
    the hot loop entirely, and a gain set with uniform capacity bounds
    clamps against compile-time constants instead of broadcast traced
    scalars.  All paths produce identical results for parameters the
    faster path admits.

    With the node axis sharded across devices (``axis_name`` set, the
    2-D gains x nodes mesh) the per-node lanes here are one shard's
    slice: the closed loop itself is embarrassingly node-parallel, so
    only the final stat folds and the streaming-quantile counts need
    collectives -- both take ``axis_name`` and reduce over the *global*
    fleet (``n_nodes * node_shards`` samples per interval).

    ``cache`` (CacheLoop) swaps the saturated store for per-node cache
    dynamics carried through the scan: the controller observes the
    *resident set* (``v = d + resident``, the quantity cluster_sim's
    monitor reads off the real ShardCache), shrinking the grant evicts
    down to it immediately, and misses refill a grown grant read-
    through up to the admission bandwidth.  The analytic hit curve
    ``h(f) = c * f**(1-alpha) + (1-c) * f`` (see
    :class:`~repro.core.eviction.PolicyModel`) converts the resident
    fraction of the working set into a hit ratio; misses, eviction
    churn, and the Fig.-2 pressure curve accumulate into modeled app
    runtime.  The first pass over the working set is warmup-aware: the
    resident set is seeded from ``warm_frac``, and until a node has
    scanned its working set once a strictly cyclic workload
    (``reuse_skew`` -> 0) pays compulsory misses for every block
    outside the warm prefix -- parity-pinned against the
    discrete-event simulator's cold start.  All cache knobs are
    scenario constants, so the cache branch is resolved at trace time
    -- ``cache=None`` compiles the exact pre-CacheLoop program.

    ``app_graph`` (AppGraph) co-simulates the scenario's stage DAG in
    the same scan: the carry gains per-node queue state (current stage
    row, work remaining, Kahan work-done lanes) plus a scalar finish
    time; each interval the active stage's held demand is added to the
    observed demand *before* the controller sees it, the queue then
    advances by ``compute_gibps * interval_s^2 / dt_eff`` where
    ``dt_eff`` is the interval stretched by the Fig.-2 curve (and, with
    a cache, miss/eviction stalls), and barrier rows promote only once
    a fleet-wide min says every node finished the row.  Stage demand
    constants and barrier flags bake in from the frozen spec; the
    ``(S+1, N)`` per-node work matrix arrives as the traced ``work_sn``
    operand (it depends on *global* node indices, which a node shard
    cannot reconstruct locally).  Under the 2-D mesh the barrier /
    completion folds are ``pmin`` collectives -- two scalar reductions
    per step.  ``app_graph=None`` compiles the exact pre-AppGraph
    program (the queue carry is the empty tuple).
    """
    n_steps, n_nodes = demand_tn.shape
    if static_bounds is not None:
        u_min_g, u_max_g = static_bounds
    u0 = jnp.full((n_nodes,), u_max_g, jnp.float32)
    zeros = jnp.zeros((n_nodes,), jnp.float32)
    # per-node event counters: int16 lanes (2x the SIMD width) whenever
    # the horizon cannot overflow them
    cnt_dtype = jnp.int16 if n_steps < 2**15 else jnp.int32
    izeros = jnp.zeros((n_nodes,), cnt_dtype)
    # Hoisted loop invariants: two reciprocals turn the law's divisions
    # into multiplies for the T-step scan, and the threshold sums leave
    # the hot path entirely.
    inv_r0_g = 1.0 / r0_g
    thr_over = r0_g + OVER_R0_EPS
    thr_settle = r0_g + SETTLE_TOL
    inv_gib = jnp.float32(1.0 / GiB)
    if cache is not None:
        conc = float(policy_model(cache.policy).concentration)
        hit_exp = 1.0 - float(cache.reuse_skew)
        miss_pen = jnp.float32(cache.miss_penalty_s_per_gib)
        evict_pen = jnp.float32(cache.evict_penalty_s_per_gib)
        w = jnp.float32(cache.working_set_frac) * m        # (N,) bytes
        inv_w = 1.0 / w
        access_g = jnp.float32(cache.access_gibps) * interval_s  # GiB/itv
        refill_b = jnp.float32(cache.refill_gibps * GiB) * interval_s
        # Warmup-aware cold scan: constants of the first-pass term.
        # The resident set is seeded from ``warm_frac`` of the initial
        # grant; ``wf0`` is the warm-seeded fraction of the working set
        # (the only blocks a strictly cyclic first pass can hit).
        access_b = access_g * jnp.float32(GiB)             # bytes/itv
        cold_mix = jnp.float32(cache.reuse_skew)
        res0 = jnp.float32(cache.warm_frac) * jnp.minimum(u0, w)
        wf0 = res0 * inv_w
    if app_graph is not None:
        # Node-independent graph constants bake in from the frozen
        # spec (stage-held demand, barrier flags); only the per-node
        # work matrix is traced (see the docstring).  slow_nodes is a
        # work-matrix concern, stripped so the 1-node compile passes
        # range validation.
        _cg = compile_graph(app_graph.replace(slow_nodes=()), 1)
        n_stage_rows = _cg.n_rows
        stage_demand_b = jnp.asarray(_cg.demand_bytes)     # (S+1,) bytes
        stage_barrier = jnp.asarray(_cg.barrier)           # (S+1,) flags
        comp_itv = jnp.float32(app_graph.compute_gibps) * interval_s

    def saturated_usage(u, d):
        return d + u if unit_occupancy else d + occupancy * u

    def step(carry, d):
        law, cst, ags, acc = carry
        (us, us_c, cs, cs_c, c2, mx, n_r0, n_viol, last_bad, t) = acc
        u = law[0]
        if app_graph is not None:
            # An active stage holds its declared shuffle/scratch bytes:
            # the controller observes demand *including* them, so stage
            # entry/exit feeds back into the pressure the law reacts to.
            sidx, wleft, wd, wd_c, t_done = ags
            d = d + stage_demand_b[sidx]
        if cache is None:
            v = saturated_usage(u, d)                  # saturated store
        else:
            # The monitor sees what the store actually holds, not the
            # grant: a freshly granted GiB is empty until refilled.
            v = d + cst[0]
        if paper_law:
            v_eff = v
        else:
            # ``vectorized_step``'s own feedforward branch is resolved
            # at trace time from a Python float, which a vmapped gain
            # axis cannot feed; applying it to v up front is identical
            # (the law uses v_eff everywhere v appears).
            v_eff = v + ff_g * (v - law[1])
        u_next = vectorized_step(
            u, v_eff, total_memory=m, r0=r0_g, lam=lam_g,
            u_min=u_min_g, u_max=u_max_g,
            lam_grant=None if paper_law else lam_grant_g,
            deadband=0.0 if paper_law else db_g,
            inv_total_memory=inv_m, inv_r0=inv_r0_g)
        r = v * inv_m
        us, us_c = kahan_add(us, us_c, r)
        cap_gib = u_next * inv_gib
        cs, cs_c = kahan_add(cs, cs_c, cap_gib)
        c2 = c2 + cap_gib * cap_gib
        mx = jnp.maximum(mx, r)
        n_r0 = n_r0 + (r > thr_over)
        n_viol = n_viol + (r > 1.0)
        last_bad = jnp.where(r > thr_settle, t, last_bad)
        acc = (us, us_c, cs, cs_c, c2, mx, n_r0, n_viol, last_bad, t + 1)
        if cache is not None:
            resident, hs, hs_c, es, es_c, ts, ts_c = cst
            # Actuation evicts down to the shrunk grant within the
            # interval (the paper's "free space" RPC semantics);
            # min/max forms keep the arithmetic exact when nothing
            # changes.
            res_ev = jnp.minimum(resident, u_next)
            ev_g = (resident - res_ev) * inv_gib
            f = jnp.minimum(res_ev * inv_w, 1.0)
            hit = conc * f ** hit_exp + (1.0 - conc) * f
            # Cold-scan term: until a node has scanned its working set
            # once (compulsory-miss window), blocks refilled *within*
            # the pass are not re-referenced by a cyclic scan, so at
            # reuse_skew=0 only the warm-seeded prefix can hit; as the
            # skew grows, intra-pass re-reference of hot blocks revives
            # the steady-state curve.  ``reuse_skew`` interpolates
            # between the two regimes; the warm prefix is clamped by
            # the live resident fraction (eviction shrinks it too).
            scanned = t.astype(jnp.float32) * access_b
            wf = jnp.minimum(wf0, f)
            hit = jnp.where(scanned < w,
                            wf + cold_mix * (hit - wf), hit)
            miss_g = (1.0 - hit) * access_g
            # Read-through refill: only missed bytes repopulate the
            # grant, capped by admission bandwidth, the grant itself,
            # and the working set.
            target = jnp.minimum(u_next, w)
            resident = jnp.minimum(
                target, res_ev + jnp.minimum(miss_g * jnp.float32(GiB),
                                             refill_b))
            dt_app = (interval_s * hpl_slowdown_curve(r)
                      + miss_g * miss_pen + ev_g * evict_pen)
            hs, hs_c = kahan_add(hs, hs_c, hit * access_g)
            es, es_c = kahan_add(es, es_c, ev_g)
            ts, ts_c = kahan_add(ts, ts_c, dt_app)
            cst = (resident, hs, hs_c, es, es_c, ts, ts_c)
        if app_graph is not None:
            # Queue advance: the interval's wall clock stretches to
            # dt_eff under pressure (and cache stalls), so the app
            # makes interval_s / dt_eff of its nominal progress.
            dt_eff = dt_app if cache is not None \
                else interval_s * hpl_slowdown_curve(r)
            active = sidx < n_stage_rows
            adv = jnp.where(active, comp_itv * (interval_s / dt_eff), 0.0)
            wd, wd_c = kahan_add(wd, wd_c, jnp.minimum(adv, wleft))
            wleft = jnp.maximum(wleft - adv, 0.0)
            fin = active & (wleft <= 0.0)
            # Two-level progress code: 2*row, +1 once the row's work is
            # drained.  A barrier row promotes only when the *fleet*
            # min of the code says every node finished it (limplock:
            # one slow node holds every node's code down).
            lvl = sidx * 2 + fin.astype(jnp.int32)
            fleet_lvl = _axis_min(jnp.min(lvl), axis_name)
            can = fin & ((stage_barrier[sidx] == 0.0)
                         | (fleet_lvl >= sidx * 2 + 1))
            sidx = sidx + can.astype(jnp.int32)
            wleft = jnp.where(
                can, jnp.take_along_axis(work_sn, sidx[None, :], axis=0)[0],
                wleft)
            done_all = _axis_min(jnp.min(sidx), axis_name) >= n_stage_rows
            t_done = jnp.where((t_done < 0.0) & done_all,
                               (t + 1).astype(jnp.float32), t_done)
            ags = (sidx, wleft, wd, wd_c, t_done)
        law = (u_next,) if paper_law else (u_next, v)
        return (law, cst, ags, acc), utilization_codes(r)

    acc0 = (zeros, zeros, zeros, zeros, zeros, zeros, izeros, izeros,
            jnp.full((n_nodes,), -1, jnp.int32), jnp.int32(0))
    cst0 = ()
    if cache is not None:
        cst0 = (res0, zeros, zeros, zeros, zeros, zeros, zeros)
    ags0 = ()
    if app_graph is not None:
        ags0 = (jnp.zeros((n_nodes,), jnp.int32), work_sn[0],
                zeros, zeros, jnp.float32(-1.0))
    if paper_law:
        law0 = (u0,)
    else:
        # Seed v_prev with the first interval's usage so the slope term
        # is exactly zero before there is a previous observation
        # (matching the scalar loop's v_prev=None first step).
        d0 = demand_tn[0]
        if app_graph is not None:
            d0 = d0 + stage_demand_b[0]
        v0 = (saturated_usage(u0, d0) if cache is None
              else d0 + cst0[0])
        law0 = (u0, v0)
    carry, codes = jax.lax.scan(step, (law0, cst0, ags0, acc0), demand_tn,
                                unroll=2)
    _, cst, ags, acc = carry
    (us, _, cs, _, c2, mx, n_r0, n_viol, last_bad, _) = acc
    n_global = n_nodes * node_shards
    p99 = quantile_from_codes(codes, 0.99, n_steps * n_global,
                              axis_name=axis_name)
    cache_kw = {}
    if cache is not None:
        cache_kw = dict(hits_gib=cst[1], evicted_gib=cst[3],
                        app_time_s=cst[5],
                        accesses_gib=access_g * n_steps)
    if app_graph is not None:
        # Finished: the recorded interval count.  Unfinished: the
        # work-linear extrapolation (clamped to at least the horizon)
        # so truncated runs still order by real progress.
        _, _, wd, _, t_done = ags
        total_w = _axis_sum(jnp.sum(work_sn), axis_name)
        done_w = _axis_sum(wd, axis_name)
        horizon_s = jnp.float32(n_steps) * interval_s
        cache_kw["makespan_s"] = jnp.where(
            t_done >= 0.0, t_done * interval_s,
            jnp.maximum(horizon_s * total_w / jnp.maximum(done_w, 1e-6),
                        horizon_s))
    return finalize_fleet_stats(
        util_sum=us, util_max=mx, caps_sum_gib=cs, caps_sumsq_gib=c2,
        over_r0_count=n_r0, violation_count=n_viol, last_bad=last_bad,
        p99_utilization=p99, r0=r0_g, n_intervals=n_steps,
        interval_s=interval_s, axis_name=axis_name, n_nodes=n_global,
        **cache_kw)


def _chunk_stats(demand_tn, m, r0, lam, lam_grant, u_min, u_max, deadband,
                 feedforward, interval_s, occupancy, *, paper_law: bool,
                 unit_occupancy: bool,
                 static_bounds: Optional[Tuple[float, float]],
                 cache: Optional[CacheSpec],
                 app_graph: Optional[AppGraphSpec] = None,
                 work_sn=None, spec: str = "",
                 axis_name: Optional[str] = None, node_shards: int = 1):
    """One gain chunk: scan over T, vmap over gains -> (G,)-field stats.

    ``demand_tn`` is ``(T, N)`` bytes (shared by every gain point),
    ``m`` is ``(N,)`` bytes, gain arrays are ``(G,)``; ``interval_s``
    and ``occupancy`` ride along as traced scalars so every
    (chunk, T, specialization, cache spec) tuple maps to exactly one
    executable.  ``spec`` is :func:`_spec_digest` of the enclosing
    :func:`_compiled_sweep` cache key, so the recompile-counter key
    below distinguishes every legitimately separate executable.
    Under the 2-D mesh ``demand_tn``/``m`` are one node shard and
    ``axis_name``/``node_shards`` make the stat folds collective.
    """
    # Trace-time only (Python in a jitted body runs once per compile):
    # the recompile counter the sanitizer fixtures and --smoke assert
    # on.  The key must be one-to-one with the executable cache key --
    # shapes from the operands, everything else (devices, plan, full
    # CacheSpec, mesh shape) folded into the spec digest -- or distinct
    # CacheSpecs at the same shape would false-positive the gate.
    record_trace("lab.sweep.chunk", chunk=int(r0.shape[0]),
                 horizon=int(demand_tn.shape[0]),
                 nodes=int(demand_tn.shape[1]),
                 paper_law=bool(paper_law), spec=spec)
    demand_tn = jnp.asarray(demand_tn, jnp.float32)
    m = jnp.asarray(m, jnp.float32)
    inv_m = 1.0 / m
    if work_sn is not None:
        work_sn = jnp.asarray(work_sn, jnp.float32)

    def one_gain(r0_g, lam_g, lam_grant_g, u_min_g, u_max_g, db_g, ff_g):
        return _one_gain_stream(demand_tn, m, inv_m, r0_g, lam_g,
                                lam_grant_g, u_min_g, u_max_g, db_g, ff_g,
                                interval_s, occupancy, paper_law=paper_law,
                                unit_occupancy=unit_occupancy,
                                static_bounds=static_bounds, cache=cache,
                                app_graph=app_graph, work_sn=work_sn,
                                axis_name=axis_name,
                                node_shards=node_shards)

    return jax.vmap(one_gain)(
        jnp.asarray(r0, jnp.float32), jnp.asarray(lam, jnp.float32),
        jnp.asarray(lam_grant, jnp.float32),
        jnp.asarray(u_min, jnp.float32), jnp.asarray(u_max, jnp.float32),
        jnp.asarray(deadband, jnp.float32),
        jnp.asarray(feedforward, jnp.float32))


def _spec_digest(devices: Tuple, paper_law: bool, unit_occupancy: bool,
                 static_bounds: Optional[Tuple[float, float]],
                 cache: Optional[CacheSpec], node_shards: int = 1,
                 app_graph: Optional[AppGraphSpec] = None) -> str:
    """Short stable digest of one :func:`_compiled_sweep` cache key.

    Folded into the ``lab.sweep.chunk`` recompile-counter dims so the
    counter key is one-to-one with the executables that legitimately
    exist: two :class:`CacheSpec`\\ s (or device tuples, mesh shapes,
    or bound specializations) at the same shape compile separately and
    must count separately.  ``repr`` of a frozen dataclass / device
    string is deterministic, so the digest is stable across processes
    too.
    """
    key = repr((tuple(str(d) for d in devices), paper_law,
                unit_occupancy, static_bounds, cache, node_shards,
                app_graph))
    return hashlib.sha1(key.encode()).hexdigest()[:12]


@functools.lru_cache(maxsize=None)
def _compiled_sweep(devices: Tuple, paper_law: bool, unit_occupancy: bool,
                    static_bounds: Optional[Tuple[float, float]],
                    cache: Optional[CacheSpec], node_shards: int = 1,
                    app_graph: Optional[AppGraphSpec] = None):
    """Jitted chunk program for a device tuple (sharded when > 1).

    With ``node_shards == 1`` the gain axis is split over a 1-D
    ``("gains",)`` mesh with ``shard_map``; demand and node memory
    replicate and per-gain programs are identical to the single-device
    path, so sharding changes only placement, not results.

    With ``node_shards > 1`` the devices form a 2-D
    ``("gains", "nodes")`` mesh: the gain axis splits as before and the
    node axis of demand / node memory splits ``node_shards`` ways, so
    fleets too large for one device's code-history budget shard too.
    Per-gain closed loops stay node-local; only the final stat folds
    run ``psum``/``pmax`` collectives over ``"nodes"`` (every output is
    therefore replicated along that axis).  Collective summation
    reassociates float adds, so node-sharded stats match the unsharded
    ones to reduction tolerance, not bitwise -- the single-device
    fallback below stays the bit-exact reference.
    """
    spec = _spec_digest(devices, paper_law, unit_occupancy, static_bounds,
                        cache, node_shards, app_graph)
    fn = functools.partial(_chunk_stats, paper_law=paper_law,
                           unit_occupancy=unit_occupancy,
                           static_bounds=static_bounds, cache=cache,
                           app_graph=app_graph, spec=spec,
                           axis_name="nodes" if node_shards > 1 else None,
                           node_shards=node_shards)
    if app_graph is not None:
        # The work matrix rides as a third leading positional operand
        # (node-sharded like demand); routed through a wrapper so the
        # app_graph=None program keeps its exact historical signature
        # and jaxpr.
        base = fn

        def fn(demand_tn, m, work_sn, *rest):
            return base(demand_tn, m, *rest, work_sn=work_sn)
    if len(devices) <= 1:
        return jax.jit(fn)
    gains_specs = (P("gains"),) * 7
    node_p = P(None) if node_shards == 1 else P("nodes")
    demand_p = P(None, None) if node_shards == 1 else P(None, "nodes")
    lead_specs = (demand_p, node_p)
    if app_graph is not None:
        lead_specs = lead_specs + (demand_p,)          # work_sn (S+1, N)
    if node_shards == 1:
        mesh = Mesh(np.asarray(devices), ("gains",))
    else:
        grid = np.asarray(devices).reshape(
            len(devices) // node_shards, node_shards)
        mesh = Mesh(grid, ("gains", "nodes"))
    mapped = _shard_map(
        fn, mesh=mesh,
        in_specs=lead_specs + gains_specs + (P(), P()),
        out_specs=P("gains"),
        check_rep=False)
    return jax.jit(mapped)


def resolve_devices(devices: Union[None, int, Sequence] = None) -> Tuple:
    """Normalize the ``devices`` knob to a tuple of jax devices.

    ``None`` auto-detects every local device; an int takes the first
    ``n``; an explicit sequence is used as given.
    """
    if devices is None:
        return tuple(jax.local_devices())
    if isinstance(devices, int):
        local = jax.local_devices()
        if not 1 <= devices <= len(local):
            raise ValueError(f"devices={devices} but only {len(local)} "
                             "local devices exist")
        return tuple(local[:devices])
    return tuple(devices)


def _resolve_chunk(chunk: Optional[int], n_gains: int, n_steps: int,
                   n_nodes: int, n_dev: int) -> int:
    """Gains per compiled call: memory-capped, device-divisible.

    The auto chunk never exceeds the code budget -- a huge (T, N)
    shape degrades to one gain per call rather than overshooting
    device memory.
    """
    if chunk is None:
        per_gain = max(n_steps * n_nodes * 2, 1)       # uint16 codes
        chunk = min(max(int(CODES_BUDGET_BYTES // per_gain), 1),
                    XLA_DEFAULT_CHUNK)
    chunk = max(int(chunk), 1)
    chunk = min(chunk, max(n_gains, 1))
    # round up so every device holds the same number of gain points
    return -(-chunk // n_dev) * n_dev


# ---------------------------------------------------------------------------
# The sweep driver
# ---------------------------------------------------------------------------

class SweepPlan(NamedTuple):
    """Trace-time specializations one gain set compiles under."""

    paper_law: bool
    unit_occupancy: bool
    static_bounds: Optional[Tuple[float, float]]


def paper_law_mask(gains: GainSet) -> np.ndarray:
    """Per gain point: does the specialized paper-faithful law apply?

    A point leaves the fast path only when a beyond-paper knob is
    actually active -- asymmetric grant gain, nonzero deadband, or
    slope feedforward.
    """
    return ((gains.feedforward == 0.0) & (gains.deadband == 0.0)
            & (gains.lam_grant == gains.lam))


def plan_specialization(gains: GainSet,
                        occupancy: float = 1.0) -> SweepPlan:
    """The specializations :func:`sweep_demand` compiles ``gains`` under.

    With a fully paper-faithful gain set (symmetric gains, zero
    deadband, zero feedforward) the hot loop sheds the slope state and
    both law branches -- the common case (default grids, every registry
    preset) runs ~2x faster.  Uniform capacity bounds clamp against
    compile-time constants.  Mixed gain sets are partitioned by
    :func:`paper_law_mask` first, so this expects one law class.
    """
    static_bounds = None
    if np.unique(gains.u_min).size == 1 and np.unique(gains.u_max).size == 1:
        static_bounds = (float(gains.u_min[0]), float(gains.u_max[0]))
    return SweepPlan(paper_law=bool(paper_law_mask(gains).all()),
                     unit_occupancy=float(occupancy) == 1.0,
                     static_bounds=static_bounds)


def sweep_demand(
    demand: np.ndarray,
    gains: GainSet,
    *,
    node_memory: Union[float, np.ndarray],
    interval_s: float = 0.1,
    occupancy: float = 1.0,
    chunk: Optional[int] = None,
    devices: Union[None, int, Sequence] = None,
    cache: Optional[CacheSpec] = None,
    app_graph: Optional[AppGraphSpec] = None,
    node_shards: int = 1,
    horizon: Optional[int] = None,
    engine: str = "xla",
) -> FleetStats:
    """Sweep a raw ``(N, T)`` demand matrix over every gain point.

    The low-level entry: :func:`run_sweep` compiles a scenario down to
    this, and ``cluster_sim.simulate_fleet`` feeds it the historical
    fleet workload directly.  Returns ``(G,)``-field stats as numpy.

    ``engine`` selects the backend: ``"xla"`` (this module's scan+vmap
    engine) or ``"pallas"`` (the fused kernel in
    :mod:`~repro.lab.pallas_sweep`, parity-pinned to this one; pass
    pallas-only knobs like ``precision=`` by calling
    :func:`~repro.lab.pallas_sweep.pallas_sweep_demand` directly).
    ``horizon`` truncates the loop to the first ``horizon`` intervals
    -- the same knob every sweep entry point takes since the PR-9 API
    unification.

    Every chunk is dispatched before any result is collected, so on an
    asynchronous backend chunk k+1 computes while chunk k's (G,)-scalar
    stats drain.  ``devices`` shards the gain axis (see module docs);
    ``node_shards > 1`` additionally splits the node axis, forming a
    2-D ``(gains x nodes)`` mesh -- ``len(devices)`` must be divisible
    by ``node_shards`` and ``N`` by the shard count.  Chunking and
    sharding are implementation details -- stats are independent of
    both (node-sharded float sums to reduction tolerance; with one
    device the plain-jit path is taken and results are bit-identical
    regardless of ``node_shards``).  ``cache`` enables CacheLoop (see
    :class:`~repro.lab.scenarios.CacheSpec`); a gain set mixing
    paper-faithful and beyond-paper points is partitioned by law class
    so each class runs its own specialized executable.  ``app_graph``
    attaches a stage-DAG co-simulation
    (:class:`~repro.lab.appgraph.AppGraphSpec`) scored through
    ``FleetStats.makespan``; ``None`` compiles the exact pre-AppGraph
    program.
    """
    if _resolve_engine(engine, "sweep_demand") == "pallas":
        from .pallas_sweep import pallas_sweep_demand
        return pallas_sweep_demand(
            demand, gains, node_memory=node_memory, interval_s=interval_s,
            occupancy=occupancy, chunk=chunk, devices=devices, cache=cache,
            app_graph=app_graph, node_shards=node_shards, horizon=horizon)
    demand = np.asarray(demand)
    if cache is not None and float(occupancy) != 1.0:
        raise ValueError("cache modeling replaces the occupancy "
                         "abstraction; need occupancy == 1.0")
    if node_shards < 1:
        raise ValueError("node_shards must be >= 1")
    if horizon is not None:
        if not 1 <= horizon <= demand.shape[1]:
            raise ValueError(f"horizon must be in [1, {demand.shape[1]}]")
        demand = demand[:, :horizon]
    mask = paper_law_mask(gains)
    if mask.any() and not mask.all():
        # Mixed law classes: dispatch each class at its own
        # specialization and stitch stats back in gain order, so the
        # beyond-paper points never drag the whole grid off the fast
        # path.
        sub_kw = dict(node_memory=node_memory, interval_s=interval_s,
                      occupancy=occupancy, chunk=chunk, devices=devices,
                      cache=cache, app_graph=app_graph,
                      node_shards=node_shards)
        idx_fast = np.flatnonzero(mask)
        idx_slow = np.flatnonzero(~mask)
        fast = sweep_demand(demand, gains.take(idx_fast), **sub_kw)
        slow = sweep_demand(demand, gains.take(idx_slow), **sub_kw)
        merged = []
        for f in FleetStats._fields:
            a, b = getattr(fast, f), getattr(slow, f)
            out = np.empty(len(gains), dtype=a.dtype)
            out[idx_fast] = a
            out[idx_slow] = b
            merged.append(out)
        return FleetStats(*merged)
    n_nodes, n_steps = demand.shape
    demand_tn = np.ascontiguousarray(demand.T, dtype=np.float32)
    m = np.broadcast_to(np.asarray(node_memory, np.float64),
                        (n_nodes,)).astype(np.float32)
    devs = resolve_devices(devices)
    if len(devs) <= 1:
        # The bit-exact fallback: one device always runs the plain
        # jitted program, whatever node_shards was requested.
        node_shards = 1
    else:
        if len(devs) % node_shards:
            raise ValueError(f"devices ({len(devs)}) must divide evenly "
                             f"into node_shards={node_shards}")
        if n_nodes % node_shards:
            raise ValueError(f"n_nodes ({n_nodes}) must be divisible by "
                             f"node_shards={node_shards}")
    gain_shards = len(devs) // node_shards
    chunk = _resolve_chunk(chunk, len(gains), n_steps, n_nodes, gain_shards)
    # Pad the ragged tail up to the chunk width (repeating the last gain)
    # so every call hits the same shape-specialized executable; the
    # padded rows' stats are sliced off below.
    n_real = len(gains)
    if n_real % chunk:
        pad = GainSet(*(np.repeat(getattr(gains, f.name)[-1:],
                                  chunk - n_real % chunk)
                        for f in dataclasses.fields(GainSet)))
        gains = gains.concat(pad)
    plan = plan_specialization(gains, occupancy)
    fn = _compiled_sweep(devs, plan.paper_law, plan.unit_occupancy,
                         plan.static_bounds, cache, node_shards, app_graph)
    # Stage every operand device-side (f32) exactly once.  The gain
    # columns used to go up as numpy float64 slices -- a silent
    # H2D transfer + cast per chunk per array -- so chunks are now
    # sliced on device and the loop body is transfer-free, which
    # dispatch_guard() (PLANECHECK_SANITIZERS=1) enforces with
    # jax.transfer_guard("disallow").
    demand_dev = jnp.asarray(demand_tn)
    m_dev = jnp.asarray(m)
    lead = (demand_dev, m_dev)
    if app_graph is not None:
        # The (S+1, N) work matrix compiles against the *global* fleet
        # (task round-robin and slow-node skew need true node indices)
        # and is staged once like demand; node sharding splits its
        # column axis the same way.
        lead = lead + (jnp.asarray(
            compile_graph(app_graph, n_nodes).work_gib),)
    gain_dev = [jnp.asarray(getattr(gains, f.name), jnp.float32)
                for f in dataclasses.fields(GainSet)]
    iv = jnp.asarray(np.float32(interval_s))
    occ = jnp.asarray(np.float32(occupancy))

    # Device-side chunk slices, materialized before the guard (each
    # distinct slice bound compiles its own tiny getitem executable,
    # whose constants would otherwise transfer inside the guard).
    cols_per_chunk = [[a[lo:lo + chunk] for a in gain_dev]
                     for lo in range(0, len(gains), chunk)]
    if sanitizers_enabled():
        # Compile (and its constant transfers) happen outside the guard;
        # the guarded loop below then replays only cached executables.
        jax.block_until_ready(
            fn(*lead, *cols_per_chunk[0], iv, occ))
    pending = []
    with dispatch_guard():
        for cols in cols_per_chunk:
            pending.append(fn(*lead, *cols, iv, occ))
    chunks = [jax.tree_util.tree_map(np.asarray, st) for st in pending]
    return FleetStats(*(np.concatenate([getattr(c, f)
                                        for c in chunks])[:n_real]
                        for f in FleetStats._fields))


@dataclasses.dataclass
class SweepResult:
    """Everything one sweep produced, gain-point-aligned."""

    scenario: ScenarioSpec
    gains: GainSet
    stats: FleetStats                 # (G,) numpy fields
    seed: int
    elapsed_s: float
    objective: Optional[object] = None  # score fn the sweep was run under

    @property
    def n_configs(self) -> int:
        return len(self.gains)

    @property
    def throughput(self) -> float:
        """node * interval * config closed-loop updates per second."""
        work = (self.scenario.n_nodes * self.scenario.n_intervals
                * self.n_configs)
        return work / self.elapsed_s if self.elapsed_s > 0 else float("inf")

    def _score_fn(self, score_fn):
        if score_fn is not None:
            return score_fn
        return self.objective if self.objective is not None \
            else default_score

    def scores(self, score_fn=None) -> np.ndarray:
        """Score every gain point; defaults to the stored objective."""
        return np.asarray(self._score_fn(score_fn)(self.stats))

    def best(self, score_fn=None) -> int:
        return int(np.argmax(self.scores(score_fn)))

    def top(self, k: int = 5, score_fn=None) -> Sequence[int]:
        s = self.scores(score_fn)
        return list(np.argsort(-s)[:k])


def run_sweep(
    scenario: Union[str, ScenarioSpec],
    gains: GainSet,
    *,
    seed: int = 0,
    chunk: Optional[int] = None,
    node_memory: Optional[Union[float, np.ndarray]] = None,
    devices: Union[None, int, Sequence] = None,
    horizon: Optional[int] = None,
    node_shards: int = 1,
    engine: str = "xla",
    objective=None,
) -> SweepResult:
    """Compile ``scenario`` and run its closed loop over every gain.

    ``node_memory`` overrides the scenario's per-node budget (bytes);
    by default the spec's (possibly jittered) fleet memory is used.
    ``horizon`` truncates the closed loop to the scenario's first
    ``horizon`` intervals -- the successive-halving tuner scores cheap
    prefix rounds with it while reusing the same demand compilation.
    ``node_shards`` splits the node axis across devices (2-D mesh; see
    :func:`sweep_demand`).  ``engine`` selects the sweep backend
    (``"xla"`` | ``"pallas"``); ``objective`` (a registry name or
    ``FleetStats -> scores`` callable) is stored on the result so
    ``result.scores()`` / ``result.best()`` default to it.
    """
    _resolve_engine(engine, "run_sweep")
    if objective is not None:
        from .tune import resolve_objective
        objective = resolve_objective(objective)
    spec = get_scenario(scenario)
    demand = spec.build_demand(seed=seed)
    if horizon is not None:
        if not 1 <= horizon <= spec.n_intervals:
            raise ValueError(f"horizon must be in [1, {spec.n_intervals}]")
        demand = demand[:, :horizon]
        spec = spec.replace(n_intervals=horizon)
    m = spec.build_node_memory(seed=seed) if node_memory is None \
        else node_memory
    t0 = time.perf_counter()
    stats = sweep_demand(
        demand, gains, node_memory=m, interval_s=spec.interval_s,
        occupancy=spec.occupancy, chunk=chunk, devices=devices,
        cache=spec.cache, app_graph=spec.app_graph,
        node_shards=node_shards, engine=engine)
    elapsed = time.perf_counter() - t0
    return SweepResult(scenario=spec, gains=gains, stats=stats, seed=seed,
                       elapsed_s=elapsed, objective=objective)


def __getattr__(name: str):
    if name == "DEFAULT_CHUNK":
        warn_once("sweep:DEFAULT_CHUNK",
                  "repro.lab.sweep.DEFAULT_CHUNK was renamed to "
                  "XLA_DEFAULT_CHUNK in the PR-9 engine unification; "
                  "the old name will go away")
        return XLA_DEFAULT_CHUNK
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
