"""The vectorized scenario-sweep engine.

One compiled program runs thousands of closed-loop simulations: a
scenario's demand traces are compiled to a dense ``(N, T)`` array, the
full control loop (saturated store, Eq. 1 update, clamp) runs as a
single jitted :func:`jax.lax.scan` over time, and that scan is
``vmap``'d over a :class:`GainSet` -- a whole gain grid advances in
lockstep, one XLA dispatch for the entire sweep.  Contrast with the
historical fleet sim (``cluster_sim.simulate_fleet(engine="python")``),
which re-entered Python to dispatch its jitted step once per interval;
``benchmarks/lab_bench.py`` measures the gap in
node*interval*config throughput.

Gain chunks bound peak memory: each jitted call reduces its
``(chunk, T, N)`` histories to :class:`~repro.lab.score.FleetStats`,
materializing only the utilization history (for the host-side p99
selection), so sweeping a 4096-node scenario over hundreds of gain
points stays within a few hundred MB.
"""

from __future__ import annotations

import dataclasses
import functools
import time
from typing import Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from ..core.control import ControllerParams, vectorized_step
from .scenarios import ScenarioSpec, get_scenario
from .score import FleetStats, compute_fleet_stats, default_score

DEFAULT_CHUNK = 8


# ---------------------------------------------------------------------------
# Gain sets
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class GainSet:
    """``G`` candidate control-law gain points, packed as arrays.

    Every law knob the sweep engine simulates is here -- a
    :class:`ControllerParams` round-trips losslessly through
    :meth:`from_params` / :meth:`params_at`, so the loop a tune run
    scores is the loop the tuned params deploy.  ``lam_grant`` equals
    ``lam`` where the gains are symmetric (the paper-faithful case);
    capacities are bytes.  Scalar / length-1 fields broadcast to the
    set's length.
    """

    r0: np.ndarray
    lam: np.ndarray
    lam_grant: np.ndarray
    u_min: np.ndarray
    u_max: np.ndarray
    deadband: np.ndarray = 0.0
    feedforward: np.ndarray = 0.0

    def __post_init__(self) -> None:
        arrays = {f.name: np.atleast_1d(np.asarray(getattr(self, f.name),
                                                   dtype=np.float64))
                  for f in dataclasses.fields(self)}
        g = max(a.shape[0] for a in arrays.values())
        sizes = {a.shape[0] for a in arrays.values()} - {1, g}
        if sizes:
            raise ValueError(f"gain arrays must share a length or be "
                             f"scalar; got lengths {sizes | {g}}")
        for name, arr in arrays.items():
            object.__setattr__(self, name,
                               np.broadcast_to(arr, (g,)).copy()
                               if arr.shape[0] != g else arr)

    def __len__(self) -> int:
        return self.r0.shape[0]

    @classmethod
    def from_params(cls, params: ControllerParams,
                    *more: ControllerParams) -> "GainSet":
        ps = (params,) + more
        return cls(
            r0=np.array([p.r0 for p in ps]),
            lam=np.array([p.lam for p in ps]),
            lam_grant=np.array([p.lam_grant if p.lam_grant is not None
                                else p.lam for p in ps]),
            u_min=np.array([p.u_min for p in ps]),
            u_max=np.array([p.u_max for p in ps]),
            deadband=np.array([p.deadband for p in ps]),
            feedforward=np.array([p.feedforward for p in ps]),
        )

    def params_at(self, i: int, base: ControllerParams) -> ControllerParams:
        """Materialize gain point ``i`` as a :class:`ControllerParams`."""
        lam = float(self.lam[i])
        lam_grant = float(self.lam_grant[i])
        return base.replace(
            r0=float(self.r0[i]), lam=lam,
            lam_grant=None if lam_grant == lam else lam_grant,
            u_min=float(self.u_min[i]), u_max=float(self.u_max[i]),
            deadband=float(self.deadband[i]),
            feedforward=float(self.feedforward[i]))

    def concat(self, other: "GainSet") -> "GainSet":
        return GainSet(*(np.concatenate([getattr(self, f.name),
                                         getattr(other, f.name)])
                         for f in dataclasses.fields(self)))

    def slice(self, lo: int, hi: int) -> "GainSet":
        return GainSet(*(getattr(self, f.name)[lo:hi]
                         for f in dataclasses.fields(self)))


# ---------------------------------------------------------------------------
# The compiled sweep
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("interval_s", "occupancy"))
def _sweep_chunk(demand_tn, m, r0, lam, lam_grant, u_min, u_max, deadband,
                 feedforward, *, interval_s: float, occupancy: float):
    """Closed loop for one gain chunk: scan over T, vmap over gains.

    ``demand_tn`` is ``(T, N)`` bytes (shared by every gain point),
    ``m`` is ``(N,)`` bytes, gain arrays are ``(G,)``.  Returns
    ``(stats, utils)``: :class:`FleetStats` with ``(G,)`` fields (p99
    zero-filled -- the caller computes it host-side, where numpy's
    selection beats XLA's CPU sort ~40x) plus the ``(G, T, N)``
    utilization history it needs to do so.  Capacity histories never
    leave the jitted computation.
    """
    demand_tn = jnp.asarray(demand_tn, jnp.float32)
    m = jnp.asarray(m, jnp.float32)

    def one_gain(r0_g, lam_g, lam_grant_g, u_min_g, u_max_g, db_g, ff_g):
        u0 = jnp.full(demand_tn.shape[1:], u_max_g, jnp.float32)
        # Seed v_prev with the first interval's usage so the slope term
        # is exactly zero before there is a previous observation
        # (matching the scalar loop's v_prev=None first step).
        v_prev0 = demand_tn[0] + occupancy * u0

        def step(carry, d):
            u, v_prev = carry
            v = d + occupancy * u                          # saturated store
            # ``vectorized_step``'s own feedforward branch is resolved
            # at trace time from a Python float, which a vmapped gain
            # axis cannot feed; applying it to v up front is identical
            # (the law uses v_eff everywhere v appears).
            v_eff = v + ff_g * (v - v_prev)
            u_next = vectorized_step(
                u, v_eff, total_memory=m, r0=r0_g, lam=lam_g,
                u_min=u_min_g, u_max=u_max_g, lam_grant=lam_grant_g,
                deadband=db_g)
            return (u_next, v), (v / m, u_next)

        _, (utils, caps) = jax.lax.scan(step, (u0, v_prev0), demand_tn)
        stats = compute_fleet_stats(utils, caps, r0=r0_g,
                                    interval_s=interval_s,
                                    p99_utilization=jnp.zeros(()))
        return stats, utils

    return jax.vmap(one_gain)(
        jnp.asarray(r0, jnp.float32), jnp.asarray(lam, jnp.float32),
        jnp.asarray(lam_grant, jnp.float32),
        jnp.asarray(u_min, jnp.float32), jnp.asarray(u_max, jnp.float32),
        jnp.asarray(deadband, jnp.float32),
        jnp.asarray(feedforward, jnp.float32))


def sweep_demand(
    demand: np.ndarray,
    gains: GainSet,
    *,
    node_memory: Union[float, np.ndarray],
    interval_s: float = 0.1,
    occupancy: float = 1.0,
    chunk: int = DEFAULT_CHUNK,
) -> FleetStats:
    """Sweep a raw ``(N, T)`` demand matrix over every gain point.

    The low-level entry: :func:`run_sweep` compiles a scenario down to
    this, and ``cluster_sim.simulate_fleet`` feeds it the historical
    fleet workload directly.  Returns ``(G,)``-field stats as numpy.
    """
    demand = np.asarray(demand)
    n_nodes = demand.shape[0]
    demand_tn = np.ascontiguousarray(demand.T, dtype=np.float32)
    m = np.broadcast_to(np.asarray(node_memory, np.float64),
                        (n_nodes,)).astype(np.float32)
    chunk = max(chunk, 1)
    # Pad the ragged tail up to the chunk width (repeating the last gain)
    # so every call hits the same shape-specialized jit executable; the
    # padded rows' stats are sliced off below.
    n_real = len(gains)
    if n_real > chunk and n_real % chunk:
        pad = GainSet(*(np.repeat(getattr(gains, f.name)[-1:],
                                  chunk - n_real % chunk)
                        for f in dataclasses.fields(GainSet)))
        gains = gains.concat(pad)
    chunks = []
    for lo in range(0, len(gains), chunk):
        g = gains.slice(lo, lo + chunk)
        stats, utils = _sweep_chunk(
            demand_tn, m, g.r0, g.lam, g.lam_grant, g.u_min, g.u_max,
            g.deadband, g.feedforward,
            interval_s=float(interval_s), occupancy=float(occupancy))
        stats = jax.tree_util.tree_map(np.asarray, stats)
        utils = np.asarray(utils)
        p99 = np.array([np.quantile(utils[i], 0.99)
                        for i in range(utils.shape[0])], utils.dtype)
        chunks.append(stats._replace(p99_utilization=p99))
    return FleetStats(*(np.concatenate([getattr(c, f)
                                        for c in chunks])[:n_real]
                        for f in FleetStats._fields))


@dataclasses.dataclass
class SweepResult:
    """Everything one sweep produced, gain-point-aligned."""

    scenario: ScenarioSpec
    gains: GainSet
    stats: FleetStats                 # (G,) numpy fields
    seed: int
    elapsed_s: float

    @property
    def n_configs(self) -> int:
        return len(self.gains)

    @property
    def throughput(self) -> float:
        """node * interval * config closed-loop updates per second."""
        work = (self.scenario.n_nodes * self.scenario.n_intervals
                * self.n_configs)
        return work / self.elapsed_s if self.elapsed_s > 0 else float("inf")

    def scores(self, score_fn=default_score) -> np.ndarray:
        return np.asarray(score_fn(self.stats))

    def best(self, score_fn=default_score) -> int:
        return int(np.argmax(self.scores(score_fn)))

    def top(self, k: int = 5, score_fn=default_score) -> Sequence[int]:
        s = self.scores(score_fn)
        return list(np.argsort(-s)[:k])


def run_sweep(
    scenario: Union[str, ScenarioSpec],
    gains: GainSet,
    *,
    seed: int = 0,
    chunk: int = DEFAULT_CHUNK,
    node_memory: Optional[Union[float, np.ndarray]] = None,
) -> SweepResult:
    """Compile ``scenario`` and run its closed loop over every gain.

    ``node_memory`` overrides the scenario's per-node budget (bytes);
    by default the spec's (possibly jittered) fleet memory is used.
    """
    spec = get_scenario(scenario)
    demand = spec.build_demand(seed=seed)
    m = spec.build_node_memory(seed=seed) if node_memory is None \
        else node_memory
    t0 = time.perf_counter()
    stats = sweep_demand(
        demand, gains, node_memory=m, interval_s=spec.interval_s,
        occupancy=spec.occupancy, chunk=chunk)
    elapsed = time.perf_counter() - t0
    return SweepResult(scenario=spec, gains=gains, stats=stats, seed=seed,
                       elapsed_s=elapsed)
