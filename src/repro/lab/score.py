"""Scoring: fleet-stability metrics and gain objectives (Figs. 5-8 analogues).

Pure functions of sweep output.  :func:`compute_fleet_stats` reduces a
closed-loop utilization/capacity history to the paper-evaluation
metrics -- pressure-violation rate, time over ``r0``, mean/p99
utilization, granted-capacity volume, settle time -- and is written in
``jax.numpy`` so the sweep engine can fuse it into the jitted scan
(it accepts plain numpy arrays equally, which is how the legacy
Python-loop fleet sim and the tests call it).

:func:`default_score` folds a :class:`FleetStats` into one scalar per
gain point -- higher is better -- trading granted storage against
pressure.  Tuning (``lab.tune``) maximizes it; swap in any callable
with the same signature for a different objective.
"""

from __future__ import annotations

from typing import Dict, NamedTuple, Optional, Union

import jax.numpy as jnp
import numpy as np

from ..core.traces import GiB

Array = Union[np.ndarray, jnp.ndarray]

# A few thousandths over r0 is measurement noise, not pressure (matches
# the historical simulate_fleet threshold).
OVER_R0_EPS = 1e-3
# Settle band: the fleet has settled once its max utilization stays
# within this margin above r0.
SETTLE_TOL = 0.02


class FleetStats(NamedTuple):
    """Per-gain stability metrics; each field is scalar or ``(G,)``."""

    mean_utilization: Array
    p99_utilization: Array
    max_utilization: Array
    frac_intervals_over_r0: Array    # share of (t, n) samples with r > r0
    max_over_r0: Array               # worst excursion above r0
    pressure_violation_rate: Array   # share of (t, n) samples with r > 1
    mean_capacity_gib: Array
    capacity_std_gib: Array
    granted_volume_gib_s: Array      # integral of the storage grant
    settle_intervals: Array          # first t after which max util <= r0+tol


def compute_fleet_stats(
    utils: Array,
    caps: Array,
    *,
    r0: Union[float, Array],
    interval_s: float,
    p99_utilization: Optional[Array] = None,
) -> FleetStats:
    """Reduce a ``(T, N)`` closed-loop history to :class:`FleetStats`.

    ``utils`` is the observed utilization ratio ``v / M`` per interval
    and node; ``caps`` the granted storage capacity in bytes.  ``r0``
    may be traced (the sweep engine vmaps this function over gains).

    Every statistic except p99 is a streaming reduction XLA fuses into
    the producing scan.  The quantile needs the full distribution and
    XLA's CPU sort is ~40x slower than numpy's selection, so the sweep
    engine computes it host-side on the materialized history and passes
    it in via ``p99_utilization``; left as None it is computed here.
    """
    utils = jnp.asarray(utils)
    caps = jnp.asarray(caps)
    t = utils.shape[0]
    over = jnp.clip(utils - r0, 0.0, None)
    fleet_max = utils.max(axis=1)                          # (T,)
    bad = fleet_max > r0 + SETTLE_TOL
    last_bad = jnp.where(bad.any(), t - 1 - jnp.argmax(bad[::-1]), -1)
    if p99_utilization is None:
        p99_utilization = jnp.quantile(utils, 0.99)
    return FleetStats(
        mean_utilization=utils.mean(),
        p99_utilization=p99_utilization,
        max_utilization=utils.max(),
        frac_intervals_over_r0=(utils > r0 + OVER_R0_EPS).mean(),
        max_over_r0=over.max(),
        pressure_violation_rate=(utils > 1.0).mean(),
        mean_capacity_gib=caps.mean() / GiB,
        capacity_std_gib=caps.std() / GiB,
        granted_volume_gib_s=caps.mean(axis=1).sum() * interval_s / GiB,
        settle_intervals=(last_bad + 1).astype(jnp.int32),
    )


def default_score(stats: FleetStats) -> Array:
    """Storage yield minus pressure penalties; higher is better.

    Units are GiB of mean granted capacity.  The weights price the
    paper's asymmetry: a swapping node (utilization > 1) collapses HPL
    by ~10x (Fig. 2), so violations dominate; sustained time above
    ``r0`` costs throughput; slow settling delays every burst response.
    """
    return (
        jnp.asarray(stats.mean_capacity_gib)
        - 200.0 * jnp.asarray(stats.frac_intervals_over_r0)
        - 2000.0 * jnp.asarray(stats.pressure_violation_rate)
        - 100.0 * jnp.asarray(stats.max_over_r0)
        - 0.01 * jnp.asarray(stats.settle_intervals)
    )


def stats_to_dict(stats: FleetStats,
                  index: Optional[int] = None) -> Dict[str, float]:
    """One gain point's stats as a plain-float dict (JSON-friendly)."""
    out = {}
    for name, value in stats._asdict().items():
        arr = np.asarray(value)
        out[name] = float(arr if arr.ndim == 0 else arr[index])
    return out
