"""Scoring: fleet-stability metrics and gain objectives (Figs. 5-8 analogues).

Pure functions of sweep output.  :func:`compute_fleet_stats` reduces a
closed-loop utilization/capacity history to the paper-evaluation
metrics -- pressure-violation rate, time over ``r0``, mean/p99
utilization, granted-capacity volume, settle time -- and is written in
``jax.numpy`` so the sweep engine can fuse it into the jitted scan
(it accepts plain numpy arrays equally, which is how the legacy
Python-loop fleet sim and the tests call it).

The device-resident sweep (``lab.sweep``) never materializes a history:
it streams per-node accumulators through the scan (Kahan-compensated
float32 sums -- the f32-clean reduction path) and estimates the p99
with the **streaming fixed-bin quantile** primitives here: utilization
is quantized to :data:`QUANT_BINS` fixed bins (``uint16`` codes over
:data:`QUANT_RANGE`), and :func:`quantile_from_codes` extracts any
quantile of the implicit histogram by bisecting the code space with
count reductions -- O(1) state per bin boundary probed, O(gains)
transfers, no sort and no scatter (both pathologically slow on XLA
CPU; see ROADMAP).  Worst-case quantization error is
``(hi - lo) / QUANT_BINS`` ~= 3e-5 utilization.
:func:`finalize_fleet_stats` assembles a :class:`FleetStats` from the
streamed accumulators so the metric *definitions* stay in this module.

:func:`default_score` folds a :class:`FleetStats` into one scalar per
gain point -- higher is better -- trading granted storage against
pressure.  Tuning (``lab.tune``) maximizes it; swap in any callable
with the same signature for a different objective.

CacheLoop additions: :class:`FleetStats` carries ``hit_ratio`` /
``evicted_bytes`` / ``app_runtime`` / ``app_slowdown`` (neutral when
cache modeling is off), :func:`hpl_slowdown_curve` is the vectorized
Fig.-2 pressure multiplier the scanned cache model applies, and
:func:`runtime_score` is the pure modeled-app-runtime objective.

AppGraph additions: ``FleetStats.makespan`` is the DAG co-simulation's
end-to-end wall clock (neutral when no graph is attached) and
:func:`makespan_score` the objective that makes the paper's headline
speedup emergent -- no penalty weight involved.
"""

from __future__ import annotations

from typing import Dict, NamedTuple, Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from ..core.traces import GiB

Array = Union[np.ndarray, jnp.ndarray]

# A few thousandths over r0 is measurement noise, not pressure (matches
# the historical simulate_fleet threshold).
OVER_R0_EPS = 1e-3
# Settle band: the fleet has settled once its max utilization stays
# within this margin above r0.
SETTLE_TOL = 0.02

# Streaming-quantile fixed-bin grid: utilization codes are uint16 over
# [0, 2) -- ratios beyond 2x total memory saturate into the top bin
# (far past the swap cliff; every scenario in the registry peaks well
# below it).  65536 bins -> 3.05e-5 quantization granularity.
QUANT_BINS = 65536
QUANT_RANGE: Tuple[float, float] = (0.0, 2.0)
_QUANT_SCALE = QUANT_BINS / (QUANT_RANGE[1] - QUANT_RANGE[0])


class FleetStats(NamedTuple):
    """Per-gain stability metrics; each field is scalar or ``(G,)``.

    Fields 11-14 are the CacheLoop (cache-dynamics) metrics.  With
    cache modeling off (``ScenarioSpec.cache is None``) they hold
    their neutral values -- ``hit_ratio=1``, ``evicted_bytes=0``,
    ``app_runtime`` equal to the ideal horizon wall-clock,
    ``app_slowdown=1`` -- so every objective built on them is a no-op
    for pure stability sweeps.

    ``makespan`` is the AppGraph (DAG co-simulation) metric: wall-clock
    seconds until the last node drained the last stage of the
    scenario's :class:`~repro.lab.appgraph.AppGraphSpec`.  Neutral
    (ideal horizon seconds) when no graph is attached.  A graph that
    does *not* finish within the horizon reports the work-linear
    extrapolation ``horizon * total_work / done_work`` (clamped to at
    least the horizon) so unfinished runs still order correctly.
    """

    mean_utilization: Array
    p99_utilization: Array
    max_utilization: Array
    frac_intervals_over_r0: Array    # share of (t, n) samples with r > r0
    max_over_r0: Array               # worst excursion above r0
    pressure_violation_rate: Array   # share of (t, n) samples with r > 1
    mean_capacity_gib: Array
    capacity_std_gib: Array
    granted_volume_gib_s: Array      # integral of the storage grant
    settle_intervals: Array          # first t after which max util <= r0+tol
    hit_ratio: Array                 # fleet cache hits / accesses (bytes)
    evicted_bytes: Array             # controller-forced eviction flux
    app_runtime: Array               # modeled app runtime, s (fleet barrier)
    app_slowdown: Array              # app_runtime / ideal horizon wall-clock
    makespan: Array                  # AppGraph end-to-end makespan, s


def compute_fleet_stats(
    utils: Array,
    caps: Array,
    *,
    r0: Union[float, Array],
    interval_s: float,
    p99_utilization: Optional[Array] = None,
    hit_ratio: Optional[Array] = None,
    evicted_bytes: Optional[Array] = None,
    app_runtime: Optional[Array] = None,
    makespan: Optional[Array] = None,
) -> FleetStats:
    """Reduce a ``(T, N)`` closed-loop history to :class:`FleetStats`.

    ``utils`` is the observed utilization ratio ``v / M`` per interval
    and node; ``caps`` the granted storage capacity in bytes.  ``r0``
    may be traced (the sweep engine vmaps this function over gains).

    Every statistic except p99 is a streaming reduction XLA fuses into
    the producing scan.  The quantile needs the full distribution and
    XLA's CPU sort is ~40x slower than numpy's selection, so the sweep
    engine computes it host-side on the materialized history and passes
    it in via ``p99_utilization``; left as None it is computed here.

    The CacheLoop fields (``hit_ratio`` / ``evicted_bytes`` /
    ``app_runtime``) come from a cache-dynamics simulation this dense
    path does not run; callers with cache state pass them in, everyone
    else gets the neutral values.
    """
    utils = jnp.asarray(utils)
    caps = jnp.asarray(caps)
    t = utils.shape[0]
    over = jnp.clip(utils - r0, 0.0, None)
    fleet_max = utils.max(axis=1)                          # (T,)
    bad = fleet_max > r0 + SETTLE_TOL
    last_bad = jnp.where(bad.any(), t - 1 - jnp.argmax(bad[::-1]), -1)
    if p99_utilization is None:
        p99_utilization = jnp.quantile(utils, 0.99)
    ideal_s = t * interval_s
    if app_runtime is None:
        app_runtime = jnp.float32(ideal_s)
    return FleetStats(
        mean_utilization=utils.mean(),
        p99_utilization=p99_utilization,
        max_utilization=utils.max(),
        frac_intervals_over_r0=(utils > r0 + OVER_R0_EPS).mean(),
        max_over_r0=over.max(),
        pressure_violation_rate=(utils > 1.0).mean(),
        mean_capacity_gib=caps.mean() / GiB,
        capacity_std_gib=caps.std() / GiB,
        granted_volume_gib_s=caps.mean(axis=1).sum() * interval_s / GiB,
        settle_intervals=(last_bad + 1).astype(jnp.int32),
        hit_ratio=jnp.float32(1.0) if hit_ratio is None else hit_ratio,
        evicted_bytes=(jnp.float32(0.0) if evicted_bytes is None
                       else evicted_bytes),
        app_runtime=app_runtime,
        app_slowdown=jnp.asarray(app_runtime, jnp.float32) / ideal_s,
        makespan=(jnp.float32(ideal_s) if makespan is None
                  else jnp.asarray(makespan, jnp.float32)),
    )


# ---------------------------------------------------------------------------
# Streaming (device-resident) reductions
# ---------------------------------------------------------------------------

def kahan_add(total: Array, comp: Array, x: Array) -> Tuple[Array, Array]:
    """One compensated-summation step: ``total + x`` carrying ``comp``.

    Keeps long float32 accumulations (T x N closed-loop sums) at
    O(eps) relative error instead of O(T * eps) -- the sweep engine's
    f32-clean reduction path.  Elementwise, so XLA fuses it into the
    scan body.
    """
    y = x - comp
    t = total + y
    return t, (t - total) - y


def hpl_slowdown_curve(utilization: Array) -> Array:
    """Fig.-2 execution-time multiplier, vectorized for the scan.

    The elementwise jax form of
    :func:`repro.core.traces.hpl_slowdown` (``swap_frac=0``): flat to
    92% utilization, ~1.35x at 98%, 4x at 100%, then the deep-swap
    cliff.  The CacheLoop carry applies it per node per interval to
    price un-relieved pressure into the modeled app runtime; a parity
    test pins it to the scalar reference.
    """
    u = jnp.clip(jnp.asarray(utilization, jnp.float32), 0.0, 1.5)
    return jnp.where(
        u <= 0.92, 1.0,
        jnp.where(u <= 0.98, 1.0 + (u - 0.92) / 0.06 * 0.35,
                  jnp.where(u <= 1.0, 1.35 + (u - 0.98) / 0.02 * 2.65,
                            4.0 + (u - 1.0) * 300.0)))


def utilization_codes(utils: Array) -> Array:
    """Quantize utilization ratios onto the fixed streaming-bin grid."""
    lo, _ = QUANT_RANGE
    idx = (jnp.asarray(utils, jnp.float32) - lo) * _QUANT_SCALE
    return jnp.clip(idx, 0, QUANT_BINS - 1).astype(jnp.uint16)


# Bisection depth of the streaming quantile: 12 levels resolve the
# 2^16-bin code space to a 16-bin bracket, i.e. 2^-11 of QUANT_RANGE
# (~5e-4 utilization worst case, ~2.4e-4 expected).  Each level is one
# dense count reduction over the codes, so depth trades accuracy
# against sweep throughput linearly; 16 recovers the exact (quantized)
# order statistic.
QUANT_LEVELS = 12


def quantile_from_codes(codes: Array, q: float, n_total: int,
                        levels: int = QUANT_LEVELS,
                        axis_name: Optional[str] = None) -> Array:
    """Quantile of the implicit fixed-bin histogram behind ``codes``.

    ``codes`` is any-shape ``uint16`` (one code per closed-loop sample,
    produced by :func:`utilization_codes`); the quantile is recovered
    by bisecting the 2^16 code space -- ``levels`` count reductions,
    each a dense compare-and-sum XLA fuses well (a scatter histogram or
    an on-device sort is 10-40x slower on CPU backends).  Returns the
    dequantized midpoint of the final bracket around the order
    statistic at ``floor(q * (n_total - 1))`` (``np.quantile``'s lower
    neighbour): error <= ``QUANT_RANGE`` span * 2^-(levels+1), plus
    half a bin once ``levels`` hits 16.

    Under ``shard_map`` with the node axis sharded, pass ``axis_name``
    (and the *global* ``n_total``): each bisection level's count is
    ``psum``'d across the axis, so every shard walks the identical
    bracket sequence over the global histogram -- integer counts make
    the collective exact, and the result is replicated by construction.
    """
    target = jnp.int32(int(np.floor(q * (n_total - 1))))

    # two-stage integer reduction: narrow partials along the last axis
    # (int16 when < 32768 lanes) then one int32 fold
    part_dtype = jnp.int16 if codes.shape[-1] < 2**15 else jnp.int32

    def body(_, lo_hi):
        lo, hi = lo_hi
        mid = (lo + hi) >> 1
        below = codes <= mid.astype(jnp.uint16)
        count = below.sum(axis=-1, dtype=part_dtype).astype(jnp.int32).sum()
        if axis_name is not None:
            count = jax.lax.psum(count, axis_name)
        go_left = count > target
        return (jnp.where(go_left, lo, mid + 1),
                jnp.where(go_left, mid, hi))

    lo, hi = jax.lax.fori_loop(0, min(levels, 16), body,
                               (jnp.int32(0), jnp.int32(QUANT_BINS - 1)))
    lo0, _hi0 = QUANT_RANGE
    mid_code = (lo.astype(jnp.float32) + hi.astype(jnp.float32) + 1.0) * 0.5
    return lo0 + mid_code / _QUANT_SCALE


def _axis_sum(x: Array, axis_name: Optional[str]) -> Array:
    """Fold per-node lanes, then (under shard_map) across the axis.

    ``axis_name=None`` is the exact historical expression, so unsharded
    callers stay bitwise identical.
    """
    if axis_name is None:
        return x.sum()
    return jax.lax.psum(x.sum(), axis_name)


def _axis_max(x: Array, axis_name: Optional[str]) -> Array:
    if axis_name is None:
        return x.max()
    return jax.lax.pmax(x.max(), axis_name)


def _axis_min(x: Array, axis_name: Optional[str]) -> Array:
    """Fleet-wide min (the AppGraph barrier/completion fold).

    The DAG carry asks "has *every* node reached level L?" -- a min
    over the global fleet, so under the 2-D mesh it is the one
    collective the queue/barrier state machine needs per step.
    """
    if axis_name is None:
        return x.min()
    return jax.lax.pmin(x.min(), axis_name)


def finalize_fleet_stats(
    *,
    util_sum: Array,             # (N,) Kahan-compensated sum of r over T
    util_max: Array,             # (N,) running max of r
    caps_sum_gib: Array,         # (N,) Kahan-compensated sum of u / GiB
    caps_sumsq_gib: Array,       # (N,) sum of (u / GiB)^2
    over_r0_count: Array,        # (N,) int count of r > r0 + OVER_R0_EPS
    violation_count: Array,      # (N,) int count of r > 1
    last_bad: Array,             # (N,) int last t with r > r0 + SETTLE_TOL
    p99_utilization: Array,      # scalar (from quantile_from_codes)
    r0: Array,
    n_intervals: int,
    interval_s: float,
    hits_gib: Optional[Array] = None,        # (N,) sum of hit bytes / GiB
    evicted_gib: Optional[Array] = None,     # (N,) sum of evicted bytes / GiB
    app_time_s: Optional[Array] = None,      # (N,) modeled per-node app time
    accesses_gib: Optional[Array] = None,    # scalar per-node access total
    makespan_s: Optional[Array] = None,      # scalar AppGraph makespan, s
    axis_name: Optional[str] = None,         # shard_map node axis, if sharded
    n_nodes: Optional[int] = None,           # global N when lanes are a shard
) -> FleetStats:
    """Assemble :class:`FleetStats` from streamed per-node accumulators.

    The metric definitions (thresholds, units, settle semantics) match
    :func:`compute_fleet_stats` on the dense history exactly; only the
    reduction order differs (per-node lanes folded once at the end).

    The four trailing cache arguments are the CacheLoop accumulators;
    all-None (cache modeling off) yields the neutral field values.
    ``app_runtime`` is the slowest node's modeled time -- iterative
    apps synchronize on a barrier, so the straggler sets the fleet's
    runtime (``cluster_sim``'s iteration semantics).  ``makespan_s``
    is the AppGraph co-simulation's end-to-end result, already a
    fleet-global scalar (its barrier folds run inside the scan);
    ``None`` (no graph attached) pins the neutral ideal horizon.

    When the node axis is sharded under ``shard_map`` (the 2-D
    gains x nodes mesh), the accumulators here are one shard's lanes:
    pass ``axis_name`` so the final folds become ``psum``/``pmax``
    collectives, and ``n_nodes`` as the *global* fleet size.  Every
    returned field is then replicated across the node axis.
    """
    t = n_intervals
    n = util_sum.shape[-1] if n_nodes is None else n_nodes
    samples = t * n
    caps_total = _axis_sum(caps_sum_gib, axis_name)
    caps_mean = caps_total / samples
    caps_var = jnp.maximum(_axis_sum(caps_sumsq_gib, axis_name) / samples
                           - caps_mean * caps_mean, 0.0)
    max_util = _axis_max(util_max, axis_name)
    ideal_s = t * interval_s
    if app_time_s is None:
        hit_ratio = jnp.float32(1.0)
        evicted_bytes = jnp.float32(0.0)
        app_runtime = jnp.asarray(ideal_s, jnp.float32)
    else:
        hit_ratio = _axis_sum(hits_gib, axis_name) / (n * accesses_gib)
        evicted_bytes = _axis_sum(evicted_gib, axis_name) * jnp.float32(GiB)
        app_runtime = _axis_max(app_time_s, axis_name)
    return FleetStats(
        mean_utilization=_axis_sum(util_sum, axis_name) / samples,
        p99_utilization=p99_utilization,
        max_utilization=max_util,
        frac_intervals_over_r0=_axis_sum(over_r0_count, axis_name) / samples,
        max_over_r0=jnp.clip(max_util - r0, 0.0, None),
        pressure_violation_rate=_axis_sum(violation_count,
                                          axis_name) / samples,
        mean_capacity_gib=caps_mean,
        capacity_std_gib=jnp.sqrt(caps_var),
        granted_volume_gib_s=caps_total / n * interval_s,
        settle_intervals=(_axis_max(last_bad, axis_name) + 1)
        .astype(jnp.int32),
        hit_ratio=hit_ratio,
        evicted_bytes=evicted_bytes,
        app_runtime=app_runtime,
        app_slowdown=app_runtime / ideal_s,
        makespan=(jnp.asarray(ideal_s, jnp.float32) if makespan_s is None
                  else jnp.asarray(makespan_s, jnp.float32)),
    )


# GiB-equivalents one full unit of modeled app slowdown costs in
# default_score: the paper's 5X-runtime headline is an app-level
# metric, so once a scenario models cache dynamics the objective must
# price it on par with the stability terms.
RUNTIME_WEIGHT = 50.0


def default_score(stats: FleetStats) -> Array:
    """Storage yield minus pressure penalties; higher is better.

    Units are GiB of mean granted capacity.  The weights price the
    paper's asymmetry: a swapping node (utilization > 1) collapses HPL
    by ~10x (Fig. 2), so violations dominate; sustained time above
    ``r0`` costs throughput; slow settling delays every burst response.
    The app-runtime term is zero whenever cache modeling is off
    (``app_slowdown`` is pinned at 1), so pure stability sweeps score
    exactly as before CacheLoop.
    """
    return (
        jnp.asarray(stats.mean_capacity_gib)
        - 200.0 * jnp.asarray(stats.frac_intervals_over_r0)
        - 2000.0 * jnp.asarray(stats.pressure_violation_rate)
        - 100.0 * jnp.asarray(stats.max_over_r0)
        - 0.01 * jnp.asarray(stats.settle_intervals)
        - RUNTIME_WEIGHT * (jnp.asarray(stats.app_slowdown) - 1.0)
    )


def runtime_score(stats: FleetStats) -> Array:
    """Pure modeled-app-runtime objective; higher is better.

    The negated slowdown of the fleet's straggler node: the metric the
    paper's headline result (up to 5X Spark runtime) optimizes.  Memory
    pressure needs no separate guard -- the Fig.-2 curve inside the
    CacheLoop already stretches ``app_runtime`` catastrophically once a
    node swaps.  Only meaningful on cache-enabled scenarios; with cache
    modeling off every gain scores the constant -1.
    """
    return -jnp.asarray(stats.app_slowdown)


def makespan_score(stats: FleetStats) -> Array:
    """Negated AppGraph end-to-end makespan; higher is better.

    The *emergent* runtime objective: no penalty weights, no modeled
    slowdown term -- just how fast the declared stage DAG actually
    drained under the candidate gains, with memory pressure and cache
    misses acting through the queue-advance rate inside the
    co-simulation.  A controller wins here only by keeping caches warm
    and nodes off the swap cliff *while the job runs*, which is the
    paper's headline claim stated as a measurement instead of a
    weighted objective.  Only meaningful on scenarios with an
    ``app_graph``; otherwise every gain scores the constant negated
    horizon.
    """
    return -jnp.asarray(stats.makespan)


def stats_to_dict(stats: FleetStats,
                  index: Optional[int] = None) -> Dict[str, float]:
    """One gain point's stats as a plain-float dict (JSON-friendly)."""
    out = {}
    for name, value in stats._asdict().items():
        arr = np.asarray(value)
        out[name] = float(arr if arr.ndim == 0 else arr[index])
    return out
