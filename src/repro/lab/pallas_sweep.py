"""PallasSweep: the fused (gains x nodes) sweep kernel + in-scan halving.

The ``engine="pallas"`` backend of the unified sweep API.  One tiled
pass fuses everything ``repro.lab.sweep`` runs as separate vmapped
stages -- the control law (:func:`~repro.core.control.vectorized_step`),
the CacheLoop carry, and the streamed Kahan / fixed-bin-quantile
accumulators -- over a stacked ``(S, L, N)`` state block:

* **S** state planes (law + cache + accumulator lanes, all f32),
* **L** gain lanes, tiled :data:`TILE_GAINS` at a time,
* **N** nodes as the vector axis.

Grid ``(gain_tiles, time_chunks)`` with semantics
``("parallel", "arbitrary")``: each program keeps its tile's full state
in VMEM scratch across the sequential time axis, walks
:data:`TIME_CHUNK` intervals as an unrolled vector loop, and emits the
uint16 utilization codes the quantile bisection consumes.  Nothing of
size T x N ever leaves the device; per segment the host sees O(L)
scalars.

**Backends.**  On CPU (every CI leg) ``engine="pallas"`` lowers the
*identical* fused step through one ``lax.scan`` -- same ops, same
order, so parity tests and tier-1 stay runnable and fast; the true
``pallas_call`` executes under ``interpret=True`` only when forced
(``PALLAS_SWEEP_INTERPRET=1`` or ``force_interpret=True``), because XLA
emulation of a Pallas grid is ~10x slower than the native scan.  On a
TPU backend the Mosaic kernel runs directly.  All three share
:func:`_fused_step`, which is the single source of truth for the step
math.

**Numerics.**  State and every accumulator stay f32 (the Kahan pairs
and the uint16 code stream make the f32 accumulation analysis of PR 3
carry over unchanged); ``precision="bf16"`` stores only the *demand
stream* in bf16 -- it is read once per step and upcast before use, so
no accumulator ever rounds through bf16.  The one deliberate numeric
departure from the XLA engine is the cache hit-curve power:
``f ** hit_exp`` becomes ``exp2(hit_exp * log2(f))`` (3.3x faster on
the hot path, max observed relative difference 3.4e-7 -- far inside
the 1e-4 parity bracket the tests pin).

**In-scan successive halving** (:func:`halving_sweep`): the candidate
lanes, the always-alive baseline lane, and the per-lane ``alive`` mask
live in one jitted program.  At each horizon boundary (T/8, T/2 by
default) the program finalizes prefix stats *on device*, scores them
with the tuning objective, argsorts the candidate lanes, and gathers
the survivors (plus the baseline) into a smaller lane block -- no host
round-trip, no re-dispatch.  Lanes that only pad the survivor block up
to the tile width are marked dead in the alive mask; an all-dead tile
is skipped by ``pl.when`` and writes deterministic zero codes.  Because
every lane's closed loop is independent and deterministic, the running
prefix accumulators at a boundary are bit-identical to a from-scratch
run truncated there -- which is exactly what host-side
:func:`~repro.lab.tune.halving_tune` scores -- so the in-scan survivors
match the host survivors on the same grid.
"""

from __future__ import annotations

import dataclasses
import functools
import hashlib
import os
import time
from typing import Callable, List, NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..analysis.runtime import (dispatch_guard, record_trace,
                                sanitizers_enabled)
from ..core.control import vectorized_step
from ..core.eviction import policy_model
from ..core.traces import GiB
from ._compat import warn_once
from .scenarios import CacheSpec
from .score import (FleetStats, OVER_R0_EPS, SETTLE_TOL, default_score,
                    finalize_fleet_stats, hpl_slowdown_curve, kahan_add,
                    quantile_from_codes, utilization_codes)
from .sweep import (GainSet, _resolve_chunk, paper_law_mask,
                    plan_specialization, resolve_devices)

# Gain lanes per kernel tile (the sublane axis of the VPU's 8x128
# geometry) and intervals walked per sequential grid step.  A segment
# whose length is not a TIME_CHUNK multiple uses its largest divisor.
TILE_GAINS = 8
TIME_CHUNK = 32

# f32-exact module constants, mirroring the XLA engine's
# ``jnp.float32(...)`` trace-time casts bit for bit.
_INV_GIB = float(np.float32(1.0 / GiB))
_GIB_F32 = float(np.float32(GiB))

# Rows of the packed per-lane parameter matrix (P, L).  The derived
# rows (reciprocal, thresholds) are precomputed in f32 on the host with
# the exact IEEE ops the XLA engine traces, so both engines clamp and
# count against bit-identical constants.
_R0, _LAM, _LAM_GRANT, _U_MIN, _U_MAX, _DB, _FF = range(7)
_INV_R0, _THR_OVER, _THR_SETTLE = 7, 8, 9
_N_PARAM_ROWS = 10

# Rows of the packed per-node constant matrix (R, N).
_M, _INV_M, _W, _INV_W = range(4)
_N_NODE_ROWS = 4


@dataclasses.dataclass(frozen=True)
class _EngineConsts:
    """Trace-time constants one fused executable specializes on.

    Hashable (it keys the compiled-program caches) and repr-stable (it
    feeds the ``record_trace`` spec digest).  Cache-model scalars are
    precomputed with f32 host arithmetic so the step math sees the same
    values the XLA engine's traced ``jnp.float32`` constants hold.
    """

    paper_law: bool
    unit_occupancy: bool
    occupancy: float
    interval_s: float
    precision: str
    has_cache: bool = False
    conc: float = 0.0
    hit_exp: float = 1.0
    miss_pen: float = 0.0
    evict_pen: float = 0.0
    access_g: float = 0.0
    refill_b: float = 0.0
    access_b: float = 0.0
    cold_mix: float = 0.0
    warm_frac: float = 0.0


def _engine_consts(plan, cache: Optional[CacheSpec], interval_s: float,
                   occupancy: float, precision: str) -> _EngineConsts:
    iv = np.float32(interval_s)
    base = dict(paper_law=plan.paper_law, unit_occupancy=plan.unit_occupancy,
                occupancy=float(occupancy), interval_s=float(iv),
                precision=precision)
    if cache is None:
        return _EngineConsts(**base)
    access_g = np.float32(cache.access_gibps) * iv
    return _EngineConsts(
        has_cache=True,
        conc=float(policy_model(cache.policy).concentration),
        hit_exp=1.0 - float(cache.reuse_skew),
        miss_pen=float(np.float32(cache.miss_penalty_s_per_gib)),
        evict_pen=float(np.float32(cache.evict_penalty_s_per_gib)),
        access_g=float(access_g),
        refill_b=float(np.float32(cache.refill_gibps * GiB) * iv),
        access_b=float(access_g * np.float32(GiB)),
        cold_mix=float(np.float32(cache.reuse_skew)),
        warm_frac=float(np.float32(cache.warm_frac)),
        **base)


def _state_names(paper_law: bool, has_cache: bool) -> Tuple[str, ...]:
    """Plane order of the stacked (S, L, N) state block."""
    names = ["u"]
    if not paper_law:
        names.append("v_prev")
    if has_cache:
        names.append("resident")
    names += ["us", "us_c", "cs", "cs_c", "c2", "mx",
              "n_r0", "n_viol", "last_bad"]
    if has_cache:
        names += ["hs", "hs_c", "es", "es_c", "ts", "ts_c"]
    return tuple(names)


def _fast_pow(x, e: float):
    """``x ** e`` for x in [0, 1] via exp2/log2 (3.3x the pow op).

    Exact at the trace-time-special exponents (e in {0, 1}); elsewhere
    accurate to ~4e-7 relative, with ``x == 0`` mapping to ~1e-12
    instead of 0 (the 1e-30 clamp) -- both far inside the engine parity
    bracket.
    """
    if e == 1.0:
        return x
    if e == 0.0:
        return jnp.ones_like(x)
    return jnp.exp2(e * jnp.log2(jnp.maximum(x, 1e-30)))


def _warm_fraction0(cols, rows, con: _EngineConsts):
    """Warm-seeded working-set fraction ``wf0`` per (lane, node)."""
    res0 = con.warm_frac * jnp.minimum(cols[_U_MAX], rows[_W])
    return res0, res0 * rows[_INV_W]


def _fused_step(state, d, t, cols, rows, wf0, con: _EngineConsts,
                names: Tuple[str, ...], ix):
    """One closed-loop interval on a tuple of (L, N) state rows.

    The single source of truth for the fused step: the Mosaic kernel
    body, the interpret-mode kernel, and the CPU scan lowering all call
    this function, so "parity between backends" reduces to XLA
    compiling the same jaxpr two ways.  The math mirrors
    ``repro.lab.sweep._one_gain_stream`` op for op (law via
    :func:`vectorized_step`, Kahan accumulators, cold-scan cache carry)
    with lane-column parameters ``cols[row]`` of shape (L, 1)
    broadcasting against node rows ``rows[row]`` of shape (N,); the one
    departure is :func:`_fast_pow` on the hit curve.

    ``state`` is a *tuple* of per-row (L, N) planes, not the stacked
    (S, L, N) block: a stacked scan carry forces XLA's CPU backend to
    re-materialize the whole block every interval (the per-step
    ``stack`` defeats carry aliasing, ~30x slower on the cache path),
    while tuple rows update in place.  The lowerings stack/unstack only
    at segment and chunk boundaries, which is pure data movement.
    """
    u = state[ix["u"]]
    if con.has_cache:
        resident = state[ix["resident"]]
        v = d + resident
    elif con.unit_occupancy:
        v = d + u
    else:
        v = d + con.occupancy * u
    if con.paper_law:
        v_eff = v
    else:
        # Feedforward applied to v up front, exactly as the XLA engine
        # does for a vmapped gain axis (identical to the law's own
        # trace-time branch).
        v_eff = v + cols[_FF] * (v - state[ix["v_prev"]])
    u_next = vectorized_step(
        u, v_eff, total_memory=rows[_M], r0=cols[_R0], lam=cols[_LAM],
        u_min=cols[_U_MIN], u_max=cols[_U_MAX],
        lam_grant=None if con.paper_law else cols[_LAM_GRANT],
        deadband=0.0 if con.paper_law else cols[_DB],
        inv_total_memory=rows[_INV_M], inv_r0=cols[_INV_R0])
    r = v * rows[_INV_M]
    tf = t.astype(jnp.float32)
    us, us_c = kahan_add(state[ix["us"]], state[ix["us_c"]], r)
    cap_gib = u_next * _INV_GIB
    cs, cs_c = kahan_add(state[ix["cs"]], state[ix["cs_c"]], cap_gib)
    out = {
        "u": u_next,
        "us": us, "us_c": us_c, "cs": cs, "cs_c": cs_c,
        "c2": state[ix["c2"]] + cap_gib * cap_gib,
        "mx": jnp.maximum(state[ix["mx"]], r),
        "n_r0": state[ix["n_r0"]] + (r > cols[_THR_OVER]),
        "n_viol": state[ix["n_viol"]] + (r > 1.0),
        "last_bad": jnp.where(r > cols[_THR_SETTLE], tf,
                              state[ix["last_bad"]]),
    }
    if not con.paper_law:
        out["v_prev"] = v
    if con.has_cache:
        res_ev = jnp.minimum(resident, u_next)
        ev_g = (resident - res_ev) * _INV_GIB
        f = jnp.minimum(res_ev * rows[_INV_W], 1.0)
        hit = con.conc * _fast_pow(f, con.hit_exp) + (1.0 - con.conc) * f
        scanned = tf * con.access_b
        wf = jnp.minimum(wf0, f)
        hit = jnp.where(scanned < rows[_W],
                        wf + con.cold_mix * (hit - wf), hit)
        miss_g = (1.0 - hit) * con.access_g
        target = jnp.minimum(u_next, rows[_W])
        out["resident"] = jnp.minimum(
            target, res_ev + jnp.minimum(miss_g * _GIB_F32, con.refill_b))
        dt_app = (con.interval_s * hpl_slowdown_curve(r)
                  + miss_g * con.miss_pen + ev_g * con.evict_pen)
        hs, hs_c = kahan_add(state[ix["hs"]], state[ix["hs_c"]],
                             hit * con.access_g)
        es, es_c = kahan_add(state[ix["es"]], state[ix["es_c"]], ev_g)
        ts, ts_c = kahan_add(state[ix["ts"]], state[ix["ts_c"]], dt_app)
        out.update(hs=hs, hs_c=hs_c, es=es, es_c=es_c, ts=ts, ts_c=ts_c)
    # Static-length genexp of lazily indexed rows -- no host iteration.
    return (tuple(out[n] for n in names),  # planecheck: ignore[PC-T002]
            utilization_codes(r))


def _init_state(cols, rows, d0, con: _EngineConsts,
                names: Tuple[str, ...], ix):
    """Stacked initial state for (L, N) lanes -- mirrors the XLA seeds."""
    zeros = jnp.zeros((cols.shape[1], rows.shape[-1]), jnp.float32)
    u0 = zeros + cols[_U_MAX]
    planes = {n: zeros for n in names}
    planes["u"] = u0
    planes["last_bad"] = zeros - 1.0
    if con.has_cache:
        res0, _ = _warm_fraction0(cols, rows, con)
        planes["resident"] = zeros + res0
    if not con.paper_law:
        # Seed v_prev with the first interval's usage so the slope term
        # is exactly zero before there is a previous observation.
        if con.has_cache:
            planes["v_prev"] = d0 + planes["resident"]
        elif con.unit_occupancy:
            planes["v_prev"] = d0 + u0
        else:
            planes["v_prev"] = d0 + con.occupancy * u0
    return jnp.stack([planes[n] for n in names])


# ---------------------------------------------------------------------------
# The kernel and its two lowerings
# ---------------------------------------------------------------------------

def _sweep_kernel(dem_ref, lp_ref, np_ref, alive_ref, sin_ref,
                  sout_ref, codes_ref, state_ref, *, t0: int, chunk: int,
                  n_chunks: int, con: _EngineConsts,
                  names: Tuple[str, ...], ix):
    """One (gain_tile, time_chunk) program of the fused sweep.

    The tile's stacked state lives in VMEM scratch across the
    sequential time axis; the chunk is an unrolled vector loop with
    (lane x node) dims vectorized.  A tile whose ``alive`` mask is all
    zero (pure survivor-padding lanes after an in-scan halving gather)
    skips the body entirely and writes deterministic zero codes.
    """
    ic = pl.program_id(1)

    @pl.when(ic == 0)
    def _seed():
        state_ref[...] = sin_ref[...]

    live = jnp.any(alive_ref[...] > 0.5)

    @pl.when(live)
    def _body():
        cols = lp_ref[...][:, :, None]                  # (P, TG, 1)
        rows = np_ref[...]                              # (R, N)
        wf0 = _warm_fraction0(cols, rows, con)[1] if con.has_cache else None
        stacked = state_ref[...]
        state = tuple(stacked[i] for i in range(len(names)))
        for k in range(chunk):
            d = dem_ref[k].astype(jnp.float32)          # (N,)
            t = ic * chunk + (t0 + k)
            state, codes = _fused_step(state, d, t, cols, rows, wf0,
                                       con, names, ix)
            codes_ref[k] = codes
        state_ref[...] = jnp.stack(state)

    @pl.when(jnp.logical_not(live))
    def _dead():
        codes_ref[...] = jnp.zeros(codes_ref.shape, jnp.uint16)

    @pl.when(ic == n_chunks - 1)
    def _flush():
        sout_ref[...] = state_ref[...]


def _time_chunk(t_seg: int) -> int:
    """Largest divisor of the segment length <= :data:`TIME_CHUNK`."""
    for c in range(min(TIME_CHUNK, t_seg), 0, -1):
        if t_seg % c == 0:
            return c
    return 1


def _segment(state, demand_seg, lp, np_rows, alive, *, t0: int,
             backend: str, con: _EngineConsts, names: Tuple[str, ...], ix):
    """Advance every lane over ``demand_seg``; returns (state, codes).

    ``backend`` selects the lowering: ``"mosaic"`` (real TPU kernel),
    ``"interpret"`` (the same ``pallas_call`` emulated by XLA -- the
    kernel-semantics reference on CPU), or ``"scan"`` (the production
    CPU path: one ``lax.scan`` over the identical :func:`_fused_step`).
    """
    t_seg, n_nodes = demand_seg.shape
    n_lanes = lp.shape[1]
    n_state = len(names)
    if backend == "scan":
        cols = lp[:, :, None]
        wf0 = (_warm_fraction0(cols, np_rows, con)[1]
               if con.has_cache else None)

        def body(st, xs):
            d, t = xs
            return _fused_step(st, d.astype(jnp.float32), t, cols, np_rows,
                               wf0, con, names, ix)

        ts = jnp.arange(t_seg, dtype=jnp.int32) + t0
        # Carry layout is a measured CPU-fusion knob, not a semantic
        # one (stack/unstack is pure data movement, results are
        # bit-identical).  The cache path wants tuple rows with no
        # unroll (45M upd/s vs 4M stacked at the bench shape: the
        # per-step stack re-materializes the whole block and unrolling
        # defeats buffer reuse); the shorter cache-off step fuses best
        # stacked with unroll=2 (333M vs 125M tuple).
        if con.has_cache:
            carry0 = tuple(  # planecheck: ignore[PC-T002]  static unstack
                state[i] for i in range(n_state))
            carry, codes = jax.lax.scan(body, carry0, (demand_seg, ts))
            return jnp.stack(carry), codes

        def body_stacked(st, xs):
            out, codes = body(
                tuple(  # planecheck: ignore[PC-T002]  static unstack
                    st[i] for i in range(n_state)), xs)
            return jnp.stack(out), codes

        return jax.lax.scan(body_stacked, state, (demand_seg, ts),
                            unroll=2)
    chunk = _time_chunk(t_seg)
    n_chunks = t_seg // chunk
    tile = min(TILE_GAINS, n_lanes)
    kernel = functools.partial(_sweep_kernel, t0=t0, chunk=chunk,
                               n_chunks=n_chunks, con=con, names=names,
                               ix=ix)
    return pl.pallas_call(
        kernel,
        grid=(n_lanes // tile, n_chunks),
        in_specs=[
            pl.BlockSpec((chunk, n_nodes), lambda ig, ic: (ic, 0)),
            pl.BlockSpec((_N_PARAM_ROWS, tile), lambda ig, ic: (0, ig)),
            pl.BlockSpec((_N_NODE_ROWS, n_nodes), lambda ig, ic: (0, 0)),
            pl.BlockSpec((1, tile), lambda ig, ic: (0, ig)),
            pl.BlockSpec((n_state, tile, n_nodes),
                         lambda ig, ic: (0, ig, 0)),
        ],
        out_specs=[
            pl.BlockSpec((n_state, tile, n_nodes),
                         lambda ig, ic: (0, ig, 0)),
            pl.BlockSpec((chunk, tile, n_nodes),
                         lambda ig, ic: (ic, ig, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_state, n_lanes, n_nodes), jnp.float32),
            jax.ShapeDtypeStruct((t_seg, n_lanes, n_nodes), jnp.uint16),
        ],
        scratch_shapes=[pltpu.VMEM((n_state, tile, n_nodes), jnp.float32)],
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=backend == "interpret",
    )(demand_seg, lp, np_rows, alive, state)


def _finalize_lanes(state, codes, lp, con: _EngineConsts,
                    names: Tuple[str, ...], ix, n_steps: int) -> FleetStats:
    """Per-lane :class:`FleetStats` from the stacked accumulators.

    ``codes`` is the (T, L, N) prefix code history; the quantile
    bisection and :func:`finalize_fleet_stats` are vmapped over lanes,
    so the reductions are the XLA engine's own, fold for fold.
    """
    n_nodes = state.shape[-1]
    codes_l = jnp.swapaxes(codes, 0, 1)                 # (L, T, N)

    def one(st, cl, r0_l):
        p99 = quantile_from_codes(cl, 0.99, n_steps * n_nodes)
        cache_kw = {}
        if con.has_cache:
            cache_kw = dict(hits_gib=st[ix["hs"]], evicted_gib=st[ix["es"]],
                            app_time_s=st[ix["ts"]],
                            accesses_gib=con.access_g * n_steps)
        return finalize_fleet_stats(
            util_sum=st[ix["us"]], util_max=st[ix["mx"]],
            caps_sum_gib=st[ix["cs"]], caps_sumsq_gib=st[ix["c2"]],
            over_r0_count=st[ix["n_r0"]],
            violation_count=st[ix["n_viol"]],
            last_bad=st[ix["last_bad"]], p99_utilization=p99, r0=r0_l,
            n_intervals=n_steps, interval_s=con.interval_s, **cache_kw)

    return jax.vmap(one, in_axes=(1, 0, 0))(state, codes_l, lp[_R0])


# ---------------------------------------------------------------------------
# Host-side packing + backend / fallback resolution
# ---------------------------------------------------------------------------

def _lane_pack(gains: GainSet) -> np.ndarray:
    """Gain columns + derived rows as one (P, L) f32 matrix.

    The derived rows use f32 host arithmetic (`np.float32` in, f32 ops
    out) so they equal the XLA engine's traced f32 hoists bitwise.
    """
    pack = np.zeros((_N_PARAM_ROWS, len(gains)), np.float32)
    r0 = np.asarray(gains.r0, np.float32)
    pack[_R0] = r0
    pack[_LAM] = np.asarray(gains.lam, np.float32)
    pack[_LAM_GRANT] = np.asarray(gains.lam_grant, np.float32)
    pack[_U_MIN] = np.asarray(gains.u_min, np.float32)
    pack[_U_MAX] = np.asarray(gains.u_max, np.float32)
    pack[_DB] = np.asarray(gains.deadband, np.float32)
    pack[_FF] = np.asarray(gains.feedforward, np.float32)
    pack[_INV_R0] = np.float32(1.0) / r0
    pack[_THR_OVER] = r0 + np.float32(OVER_R0_EPS)
    pack[_THR_SETTLE] = r0 + np.float32(SETTLE_TOL)
    return pack


def _node_pack(node_memory, n_nodes: int,
               cache: Optional[CacheSpec]) -> np.ndarray:
    pack = np.ones((_N_NODE_ROWS, n_nodes), np.float32)
    m = np.broadcast_to(np.asarray(node_memory, np.float64),
                        (n_nodes,)).astype(np.float32)
    pack[_M] = m
    pack[_INV_M] = np.float32(1.0) / m
    if cache is not None:
        w = np.float32(cache.working_set_frac) * m
        pack[_W] = w
        pack[_INV_W] = np.float32(1.0) / w
    return pack


def _pad_gains(gains: GainSet, multiple: int) -> GainSet:
    short = (-len(gains)) % multiple
    if not short:
        return gains
    pad = GainSet(*(np.repeat(getattr(gains, f.name)[-1:], short)
                    for f in dataclasses.fields(GainSet)))
    return gains.concat(pad)


def _backend(force_interpret: Optional[bool]) -> str:
    if force_interpret is None:
        force_interpret = os.environ.get("PALLAS_SWEEP_INTERPRET",
                                         "0") == "1"
    if jax.default_backend() == "cpu":
        return "interpret" if force_interpret else "scan"
    return "mosaic"


def _single_device(devices, node_shards: int, who: str):
    """The pallas engine owns its tiling; shard knobs fall back warned."""
    devs = resolve_devices(devices)
    if len(devs) > 1:
        warn_once(f"{who}:devices",
                  f"{who}(engine='pallas') runs single-device (the kernel "
                  "grid already tiles the gain axis); ignoring the "
                  f"{len(devs)}-device mesh", RuntimeWarning)
    if node_shards > 1:
        warn_once(f"{who}:node_shards",
                  f"{who}(engine='pallas') does not shard the node axis; "
                  f"ignoring node_shards={node_shards}", RuntimeWarning)
    return devs[:1]


def _spec_digest(*parts) -> str:
    return hashlib.sha1(repr(parts).encode()).hexdigest()[:12]


# ---------------------------------------------------------------------------
# The plain sweep driver
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def _compiled_pallas_sweep(backend: str, con: _EngineConsts,
                           names: Tuple[str, ...]):
    """Jitted fused-sweep program for one (backend, consts) key."""
    ix = {n: i for i, n in enumerate(names)}
    spec = _spec_digest("sweep", backend, con, names)

    def program(demand_tn, np_rows, lp, alive):
        # Trace-time recompile counter (see lab.sweep._chunk_stats):
        # shapes from the operands, everything else -- backend, the
        # full consts dataclass (cache knobs, interval, precision),
        # state layout -- folded into the spec digest, so the key is
        # one-to-one with the executable cache entry.
        record_trace("lab.sweep.pallas", chunk=int(lp.shape[1]),
                     horizon=int(demand_tn.shape[0]),
                     nodes=int(demand_tn.shape[1]), mode="sweep",
                     spec=spec)
        cols = lp[:, :, None]
        d0 = demand_tn[0].astype(jnp.float32)
        state0 = _init_state(cols, np_rows, d0, con, names, ix)
        state, codes = _segment(state0, demand_tn, lp, np_rows, alive,
                                t0=0, backend=backend, con=con, names=names,
                                ix=ix)
        return _finalize_lanes(state, codes, lp, con, names, ix,
                               demand_tn.shape[0])

    return jax.jit(program)


def pallas_sweep_demand(
    demand: np.ndarray,
    gains: GainSet,
    *,
    node_memory,
    interval_s: float = 0.1,
    occupancy: float = 1.0,
    chunk: Optional[int] = None,
    devices=None,
    cache: Optional[CacheSpec] = None,
    app_graph=None,
    node_shards: int = 1,
    horizon: Optional[int] = None,
    precision: str = "f32",
    force_interpret: Optional[bool] = None,
) -> FleetStats:
    """The ``engine="pallas"`` backend of ``lab.sweep.sweep_demand``.

    Same contract and kwarg set as the XLA engine (``(N, T)`` demand in
    bytes, ``(G,)``-field stats out, mixed law classes partitioned,
    gain chunks bounded by the code budget) with the pallas-specific
    knobs on top: ``precision`` (``"f32"`` | ``"bf16"`` -- bf16 stores
    only the demand stream; all state and accumulators stay f32) and
    ``force_interpret`` (run the real ``pallas_call`` under XLA
    emulation on CPU instead of the fused-scan lowering -- the
    kernel-semantics parity reference, ~10x slower).  ``interval_s`` /
    ``occupancy`` are compile-time constants here (the XLA engine
    traces them); sweeping many interval lengths compiles one
    executable each.  ``devices`` meshes and ``node_shards`` are
    accepted for API uniformity but fall back to the single-device
    kernel grid with a one-time warning.

    ``app_graph`` (the AppGraph DAG co-simulation) is accepted for API
    uniformity but the queue/barrier carry is not kernelized yet: it
    needs two cross-lane scalar folds per step inside the tile, which
    the current mosaic layout cannot express without a lane shuffle.
    Falls back to the XLA engine with a one-time warning -- the fleet
    two-level carry precedent (see ROADMAP).
    """
    if app_graph is not None:
        warn_once("pallas:app_graph",
                  "pallas_sweep_demand: the AppGraph queue/barrier "
                  "carry is not kernelized yet; falling back to the "
                  "XLA sweep engine for this call", RuntimeWarning)
        from .sweep import sweep_demand
        return sweep_demand(
            demand, gains, node_memory=node_memory, interval_s=interval_s,
            occupancy=occupancy, chunk=chunk, devices=devices, cache=cache,
            app_graph=app_graph, node_shards=node_shards, horizon=horizon,
            engine="xla")
    demand = np.asarray(demand)
    if cache is not None and float(occupancy) != 1.0:
        raise ValueError("cache modeling replaces the occupancy "
                         "abstraction; need occupancy == 1.0")
    if node_shards < 1:
        raise ValueError("node_shards must be >= 1")
    if precision not in ("f32", "bf16"):
        raise ValueError("precision must be f32|bf16")
    if horizon is not None:
        if not 1 <= horizon <= demand.shape[1]:
            raise ValueError(f"horizon must be in [1, {demand.shape[1]}]")
        demand = demand[:, :horizon]
    mask = paper_law_mask(gains)
    if mask.any() and not mask.all():
        sub_kw = dict(node_memory=node_memory, interval_s=interval_s,
                      occupancy=occupancy, chunk=chunk, devices=devices,
                      cache=cache, node_shards=node_shards,
                      precision=precision, force_interpret=force_interpret)
        idx_fast = np.flatnonzero(mask)
        idx_slow = np.flatnonzero(~mask)
        fast = pallas_sweep_demand(demand, gains.take(idx_fast), **sub_kw)
        slow = pallas_sweep_demand(demand, gains.take(idx_slow), **sub_kw)
        merged = []
        for f in FleetStats._fields:
            a, b = getattr(fast, f), getattr(slow, f)
            out = np.empty(len(gains), dtype=a.dtype)
            out[idx_fast] = a
            out[idx_slow] = b
            merged.append(out)
        return FleetStats(*merged)
    n_nodes, n_steps = demand.shape
    _single_device(devices, node_shards, "pallas_sweep_demand")
    backend = _backend(force_interpret)
    chunk = _resolve_chunk(chunk, len(gains), n_steps, n_nodes, 1)
    chunk = -(-chunk // TILE_GAINS) * TILE_GAINS
    n_real = len(gains)
    gains = _pad_gains(gains, chunk)
    plan = plan_specialization(gains, occupancy)
    con = _engine_consts(plan, cache, interval_s, occupancy, precision)
    names = _state_names(con.paper_law, con.has_cache)
    fn = _compiled_pallas_sweep(backend, con, names)
    dem_dtype = jnp.bfloat16 if precision == "bf16" else jnp.float32
    demand_dev = jnp.asarray(
        np.ascontiguousarray(demand.T, np.float32)).astype(dem_dtype)
    np_dev = jnp.asarray(_node_pack(node_memory, n_nodes, cache))
    lp_dev = jnp.asarray(_lane_pack(gains))
    alive = np.zeros((1, len(gains)), np.float32)
    alive[0, :n_real] = 1.0
    alive_dev = jnp.asarray(alive)
    cols_per_chunk = [(lp_dev[:, lo:lo + chunk],
                       alive_dev[:, lo:lo + chunk])
                      for lo in range(0, len(gains), chunk)]
    if sanitizers_enabled():
        # Compile (and its constant transfers) outside the guard.
        jax.block_until_ready(
            fn(demand_dev, np_dev, *cols_per_chunk[0]))
    pending = []
    with dispatch_guard():
        for cols in cols_per_chunk:
            pending.append(fn(demand_dev, np_dev, *cols))
    chunks = [jax.tree_util.tree_map(np.asarray, st) for st in pending]
    return FleetStats(*(np.concatenate([getattr(c, f)
                                        for c in chunks])[:n_real]
                        for f in FleetStats._fields))


# ---------------------------------------------------------------------------
# In-scan successive halving
# ---------------------------------------------------------------------------

class HalvingSweep(NamedTuple):
    """Everything one in-scan halving program returned, host-side."""

    stats: FleetStats          # final-round lanes: (k_last + B,) fields
    scores: np.ndarray         # objective over the same lanes
    survivor_idx: np.ndarray   # (k_last,) original candidate indices
    rounds: List[dict]         # {horizon, n_candidates, elapsed_s}
    elapsed_s: float


def halving_schedule(n_intervals: int, n_candidates: int,
                     rounds: Sequence[float], keep: float,
                     min_survivors: int) -> Tuple[List[int], List[int]]:
    """(horizons, survivor counts) exactly as the host tuner computes.

    The in-scan program bakes these in as static gather shapes; keeping
    the arithmetic in one place is what makes "in-scan survivors ==
    host survivors" an identity rather than a coincidence.
    """
    fracs = sorted(set(float(f) for f in rounds))
    if not fracs or fracs[0] <= 0.0 or fracs[-1] > 1.0:
        raise ValueError("rounds must be fractions in (0, 1]")
    if fracs[-1] != 1.0:
        fracs.append(1.0)
    horizons = [max(int(round(n_intervals * f)), 1) for f in fracs]
    horizons[-1] = n_intervals
    keeps = []
    n = n_candidates
    for _ in fracs[:-1]:
        k = min(max(int(np.ceil(n * keep)), min_survivors), n)
        keeps.append(k)
        n = k
    return horizons, keeps


@functools.lru_cache(maxsize=None)
def _compiled_halving(backend: str, con: _EngineConsts,
                      names: Tuple[str, ...], horizons: Tuple[int, ...],
                      keeps: Tuple[int, ...], n_cand: int, n_base: int,
                      objective: Callable):
    """One jitted program running the whole halving schedule in-scan.

    Candidate lanes ``[0, n_cand)``, baseline lanes right after, tile
    padding last.  At each boundary: finalize prefix stats -> score ->
    ``argsort`` the candidate lanes only -> gather survivors + baseline
    + alive-masked padding into the next (smaller) lane block.  The
    prefix code history rides along through the gathers so the p99 (and
    any objective built on it) is computed over the full prefix, just
    like the host tuner's from-scratch truncated runs.
    """
    ix = {n: i for i, n in enumerate(names)}
    spec = _spec_digest("halving", backend, con, names, horizons, keeps,
                        n_cand, n_base,
                        getattr(objective, "__qualname__", repr(objective)))

    def program(demand_tn, np_rows, lp, alive):
        record_trace("lab.sweep.pallas", chunk=int(lp.shape[1]),
                     horizon=int(demand_tn.shape[0]),
                     nodes=int(demand_tn.shape[1]), mode="halving",
                     spec=spec)
        cols = lp[:, :, None]
        d0 = demand_tn[0].astype(jnp.float32)
        state = _init_state(cols, np_rows, d0, con, names, ix)
        orig = jnp.arange(lp.shape[1], dtype=jnp.int32)
        parts = []
        t_prev = 0
        cand = n_cand
        for i, h in enumerate(horizons):
            final = i == len(horizons) - 1
            if h > t_prev:
                state, codes = _segment(
                    state, jax.lax.slice_in_dim(demand_tn, t_prev, h),
                    lp, np_rows, alive, t0=t_prev, backend=backend,
                    con=con, names=names, ix=ix)
                parts.append(codes)
                t_prev = h
            prefix = parts[0] if len(parts) == 1 else jnp.concatenate(
                parts, axis=0)
            stats = _finalize_lanes(state, prefix, lp, con, names, ix, h)
            scores = objective(stats)
            if final:
                n_out = cand + n_base
                out_stats = jax.tree_util.tree_map(lambda a: a[:n_out],
                                                   stats)
                return out_stats, scores[:n_out], orig[:cand]
            k = keeps[i]
            # top_k (not argsort): O(cand log k) streaming selection,
            # and descending-with-ties-by-index order matches the host
            # tuner's np.argsort(-scores) ranking for distinct scores.
            _, idx = jax.lax.top_k(scores[:cand], k)
            sel = jnp.concatenate(
                [idx, jnp.arange(cand, cand + n_base, dtype=idx.dtype)])
            pad_n = (-(k + n_base)) % TILE_GAINS
            if pad_n:
                sel = jnp.concatenate(
                    [sel, jnp.broadcast_to(sel[-1:], (pad_n,))])
            state = state[:, sel, :]
            lp = lp[:, sel]
            cols = lp[:, :, None]
            parts = [c[:, sel, :] for c in parts]
            orig = orig[sel]
            alive = jnp.asarray(
                np.concatenate([np.ones((1, k + n_base), np.float32),
                                np.zeros((1, pad_n), np.float32)], axis=1))
            cand = k
        raise AssertionError("unreachable")

    return jax.jit(program)


def halving_sweep(
    demand: np.ndarray,
    gains: GainSet,
    base: GainSet,
    *,
    node_memory,
    interval_s: float = 0.1,
    occupancy: float = 1.0,
    cache: Optional[CacheSpec] = None,
    rounds: Sequence[float] = (0.125, 0.5, 1.0),
    keep: float = 0.25,
    min_survivors: int = 4,
    objective: Callable = default_score,
    chunk: Optional[int] = None,
    devices=None,
    node_shards: int = 1,
    horizon: Optional[int] = None,
    precision: str = "f32",
    force_interpret: Optional[bool] = None,
) -> HalvingSweep:
    """Run the whole successive-halving schedule as one device program.

    ``gains`` are the candidates, ``base`` the always-alive baseline
    lanes scored at the final horizon (the "never below baseline"
    guarantee); ``objective`` must be jax-traceable (both registry
    objectives are).  Dominated candidate lanes are masked dead and
    compacted away at each ``rounds`` boundary without leaving the
    device -- a 512-gain tune executes ~26% of the grid's lane-steps.
    A mixed paper/beyond-paper gain set runs whole on the generic law
    (identical results, no partition -- the lanes must share one
    program for the in-scan gathers).

    Returns a :class:`HalvingSweep`; ``lab.tune.halving_tune`` wraps it
    into the standard :class:`~repro.lab.tune.TuneResult`.
    """
    demand = np.asarray(demand)
    if cache is not None and float(occupancy) != 1.0:
        raise ValueError("cache modeling replaces the occupancy "
                         "abstraction; need occupancy == 1.0")
    if precision not in ("f32", "bf16"):
        raise ValueError("precision must be f32|bf16")
    if horizon is not None:
        if not 1 <= horizon <= demand.shape[1]:
            raise ValueError(f"horizon must be in [1, {demand.shape[1]}]")
        demand = demand[:, :horizon]
    del chunk  # lane count is the schedule's; accepted for API uniformity
    n_nodes, n_steps = demand.shape
    _single_device(devices, node_shards, "halving_sweep")
    backend = _backend(force_interpret)
    horizons, keeps = halving_schedule(n_steps, len(gains), rounds, keep,
                                       min_survivors)
    n_cand, n_base = len(gains), len(base)
    lanes = _pad_gains(gains.concat(base), TILE_GAINS)
    # One law class for the whole lane block: any beyond-paper point
    # drops every lane to the generic (identical-result) law.
    plan = plan_specialization(lanes, occupancy)
    con = _engine_consts(plan, cache, interval_s, occupancy, precision)
    names = _state_names(con.paper_law, con.has_cache)
    fn = _compiled_halving(backend, con, names, tuple(horizons),
                           tuple(keeps), n_cand, n_base, objective)
    dem_dtype = jnp.bfloat16 if precision == "bf16" else jnp.float32
    demand_dev = jnp.asarray(
        np.ascontiguousarray(demand.T, np.float32)).astype(dem_dtype)
    np_dev = jnp.asarray(_node_pack(node_memory, n_nodes, cache))
    lp_dev = jnp.asarray(_lane_pack(lanes))
    alive = np.zeros((1, len(lanes)), np.float32)
    alive[0, :n_cand + n_base] = 1.0
    alive_dev = jnp.asarray(alive)
    if sanitizers_enabled():
        jax.block_until_ready(fn(demand_dev, np_dev, lp_dev, alive_dev))
    t0 = time.perf_counter()
    with dispatch_guard():
        out = fn(demand_dev, np_dev, lp_dev, alive_dev)
    stats_dev, scores_dev, orig_dev = out
    stats = jax.tree_util.tree_map(np.asarray, stats_dev)
    scores = np.asarray(scores_dev)
    survivor_idx = np.asarray(orig_dev)
    elapsed = time.perf_counter() - t0
    counts = [n_cand] + list(keeps)
    round_log = [{"horizon": h,
                  "n_candidates": counts[i] + (n_base if final else 0),
                  "elapsed_s": elapsed if final else 0.0}
                 for i, h in enumerate(horizons)
                 for final in [i == len(horizons) - 1]]
    return HalvingSweep(stats=stats, scores=scores,
                        survivor_idx=survivor_idx, rounds=round_log,
                        elapsed_s=elapsed)
