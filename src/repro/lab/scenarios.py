"""Declarative closed-loop scenarios for the ScenarioLab sweep engine.

A :class:`ScenarioSpec` names everything the sweep engine needs to
compile a fleet's compute-tenant demand into a dense ``(N, T)`` array:
the trace family, fleet size, per-node heterogeneity (amplitude /
phase / total-memory jitter), and burst / failure injection.  Specs are
frozen dataclasses, so a scenario is a value: hashable, replayable
(deterministic given ``seed``), and cheap to :meth:`~ScenarioSpec.replace`
into variants.

The registry ships the paper's four Sec. IV.A configurations expressed
as demand scenarios plus beyond-paper stress shapes (bursty serving
pressure, heterogeneous fleets, swap storms, phase-shifted replay).
``register_scenario`` admits new ones; ``get_scenario`` accepts either
a name or a spec everywhere the lab takes a scenario.

**ReplayLoop**: the ``"replay"`` family closes the loop with live
deployments.  :meth:`ScenarioSpec.from_capture` turns a
:class:`~repro.core.plane.CapturedTrace` (what a running ``MemoryPlane``
observed) into a scenario that carries the raw demand for *exact*
replay through the sweep engine -- interpolated to any horizon,
padded/tiled to any fleet size using the capture's fitted
amplitude/phase/heterogeneity statistics -- plus a fitted
:class:`CacheSpec` whenever cache residency was observed.  Every
captured workload is thereby a new sweepable scenario.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple, Union

import numpy as np

from ..core.eviction import POLICY_MODELS
from ..core.traces import (GiB, bursty_trace, constant_trace,
                           fleet_demand_traces, hpcc_trace)
from .appgraph import AppGraphSpec, StageSpec, compile_graph

TRACE_FAMILIES = ("hpcc", "constant", "bursty", "replay")


class ReplayTrace:
    """Immutable captured-demand payload carried by ``"replay"`` specs.

    Wraps the raw per-node demand (bytes, ``(N, T)``) and per-node
    total memory (``(N,)``) of a capture so a :class:`ScenarioSpec`
    stays a hashable value: equality and hash go through a content
    digest, and the arrays are frozen read-only.
    """

    __slots__ = ("demand", "node_memory", "interval_s", "_digest")

    def __init__(self, demand: np.ndarray, node_memory: np.ndarray,
                 interval_s: float = 0.1):
        demand = np.ascontiguousarray(demand, dtype=np.float64)
        if demand.ndim != 2 or demand.size == 0:
            raise ValueError("demand must be a non-empty (N, T) array")
        node_memory = np.ascontiguousarray(
            np.broadcast_to(np.asarray(node_memory, np.float64),
                            (demand.shape[0],)))
        if (node_memory <= 0).any():
            raise ValueError("node_memory must be positive")
        demand.setflags(write=False)
        node_memory.setflags(write=False)
        object.__setattr__(self, "demand", demand)
        object.__setattr__(self, "node_memory", node_memory)
        object.__setattr__(self, "interval_s", float(interval_s))
        object.__setattr__(self, "_digest", hash(
            (demand.shape, float(interval_s), demand.tobytes(),
             node_memory.tobytes())))

    def __setattr__(self, name, value):          # pragma: no cover - guard
        raise AttributeError("ReplayTrace is immutable")

    @property
    def n_nodes(self) -> int:
        return self.demand.shape[0]

    @property
    def n_intervals(self) -> int:
        return self.demand.shape[1]

    def __hash__(self) -> int:
        return self._digest

    def __eq__(self, other) -> bool:
        return (isinstance(other, ReplayTrace)
                and self._digest == other._digest
                and self.interval_s == other.interval_s
                and np.array_equal(self.demand, other.demand)
                and np.array_equal(self.node_memory, other.node_memory))

    def __repr__(self) -> str:
        return (f"ReplayTrace(n_nodes={self.n_nodes}, "
                f"n_intervals={self.n_intervals}, "
                f"interval_s={self.interval_s})")


@dataclasses.dataclass(frozen=True)
class CacheSpec:
    """CacheLoop workload knobs: the storage tenant's cache dynamics.

    Attached to a :class:`ScenarioSpec` this turns the sweep engine's
    saturated-store model into a per-node cache simulation carried
    through the scan: a resident set bounded by the controller's grant,
    an analytic reuse-distance hit curve (see
    :class:`~repro.core.eviction.PolicyModel`), eviction flux when the
    grant shrinks, read-through refill when misses are admitted back,
    and a penalty model converting misses + evictions + memory pressure
    into modeled app runtime.  ``None`` (the default) keeps the
    paper-faithful saturated store and its specialized fast path.

    Fields:
      policy:        eviction policy whose analytic model shapes the
                     hit curve (``lfu`` -- the paper's Alluxio setup --
                     ``lru``, ``fifo``, ``adaptive``).
      reuse_skew:    Zipf exponent alpha of block popularity in [0, 1);
                     0 = uniform / cyclic-scan reuse, ->1 = hot-spot.
      working_set_frac: app working set as a fraction of per-node total
                     memory (Sec. IV: 100-200 GB datasets on 125 GB
                     nodes -> per-node fractions around 0.2-0.5).
      access_gibps:  per-node rate at which the app reads its working
                     set (block scans per wall second).
      refill_gibps:  read-through admission bandwidth -- how fast
                     misses can repopulate a grown grant (remote-tier
                     read bandwidth in the paper's testbed).
      miss_penalty_s_per_gib: extra modeled seconds per GiB served
                     remotely instead of from the local cache (~1/remote
                     read bandwidth; Table-II-era default).
      evict_penalty_s_per_gib: churn cost per evicted GiB (invalidation
                     and re-registration overhead; small).
      warm_frac:     fraction of the initial grant resident at t=0
                     (0 = cold start, matching ``cluster_sim``).
    """

    policy: str = "lfu"
    reuse_skew: float = 0.6
    working_set_frac: float = 0.5
    access_gibps: float = 2.0
    refill_gibps: float = 1.05
    miss_penalty_s_per_gib: float = 0.95
    evict_penalty_s_per_gib: float = 0.05
    warm_frac: float = 0.0

    def __post_init__(self) -> None:
        if self.policy not in POLICY_MODELS:
            raise ValueError(f"policy must be one of "
                             f"{sorted(POLICY_MODELS)}")
        if not (0.0 <= self.reuse_skew < 1.0):
            raise ValueError("reuse_skew must be in [0, 1)")
        if self.working_set_frac <= 0.0:
            raise ValueError("working_set_frac must be positive")
        if self.access_gibps <= 0.0 or self.refill_gibps <= 0.0:
            raise ValueError("access_gibps and refill_gibps must be "
                             "positive")
        if (self.miss_penalty_s_per_gib < 0.0
                or self.evict_penalty_s_per_gib < 0.0):
            raise ValueError("penalties must be non-negative")
        if not (0.0 <= self.warm_frac <= 1.0):
            raise ValueError("warm_frac must be in [0, 1]")

    def replace(self, **kw) -> "CacheSpec":
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class ScenarioSpec:
    """One closed-loop experiment, declared as data.

    Demand is the compute tenant's memory usage; the sweep engine adds
    the (saturated) storage grant on top when it closes the loop.  All
    ``*_gib`` fields are GiB; compiled traces are bytes.

    Fields:
      family:          base trace shape -- ``hpcc`` (Fig.-1 replay),
                       ``constant``, or ``bursty`` (periodic spikes).
      n_nodes / n_intervals / interval_s: fleet size and horizon.
      node_memory_gib: per-node budget M (Table I: 125).
      offset_gib:      static demand floor added to every interval
                       (Spark executor + OS baseline in the paper
                       configs).
      base_gib:        plateau level for constant/bursty families.
      amp_range:       per-node amplitude jitter (heterogeneous load).
      phase_shift:     roll each node's trace by a random offset.
      memory_jitter:   fractional spread of per-node total memory
                       (0.2 -> M drawn from [0.8, 1.2] * node_memory).
      burst_gib / burst_every_s / burst_len_s: injected spikes on top
                       of the family trace (0 burst_gib -> off).
      failure_rate:    per-node probability of one failure event: the
                       node's demand collapses to near zero for
                       ``failure_len_s`` (crash + restart), then
                       resumes -- exercises the grant path.
      occupancy:       how full the storage tenant keeps its grant
                       (paper experiments: hot cache, 1.0).
      cache:           optional :class:`CacheSpec` enabling CacheLoop
                       (hit-ratio / eviction / app-runtime dynamics in
                       the scanned loop).  ``None`` keeps the saturated
                       store; a cache spec requires ``occupancy == 1``
                       (the resident set replaces the occupancy
                       abstraction).
      app_graph:       optional :class:`~repro.lab.appgraph.AppGraphSpec`
                       enabling the DAG co-simulation (per-node task
                       queues advancing under live memory pressure,
                       barrier stages gated on the fleet's slowest
                       node, stage-held demand fed back into the
                       trace).  Sweeps then report end-to-end
                       ``FleetStats.makespan``.  Validated against
                       ``n_nodes`` (slow-node indices must exist).
      replay:          the captured demand a ``"replay"`` scenario
                       carries (required for that family, forbidden
                       elsewhere).  Build with
                       :meth:`ScenarioSpec.from_capture`; the first
                       ``min(n_nodes, capture)`` nodes replay the raw
                       trace exactly (time-interpolated when the
                       horizon differs), extra nodes are tiled clones
                       jittered by ``amp_range`` / ``phase_shift`` /
                       ``memory_jitter``.
    """

    name: str
    family: str = "hpcc"
    n_nodes: int = 64
    n_intervals: int = 600
    interval_s: float = 0.1
    node_memory_gib: float = 125.0
    offset_gib: float = 0.0
    base_gib: float = 40.0
    amp_range: Tuple[float, float] = (0.8, 1.2)
    phase_shift: bool = True
    memory_jitter: float = 0.0
    burst_gib: float = 0.0
    burst_every_s: float = 20.0
    burst_len_s: float = 2.0
    failure_rate: float = 0.0
    failure_len_s: float = 5.0
    occupancy: float = 1.0
    cache: Optional[CacheSpec] = None
    app_graph: Optional[AppGraphSpec] = None
    replay: Optional[ReplayTrace] = None
    description: str = ""

    def __post_init__(self) -> None:
        if self.family not in TRACE_FAMILIES:
            raise ValueError(f"family must be one of {TRACE_FAMILIES}")
        if (self.family == "replay") != (self.replay is not None):
            raise ValueError(
                "family='replay' requires a ReplayTrace payload (build "
                "one with ScenarioSpec.from_capture) and other families "
                "must not carry one")
        if self.n_nodes < 1 or self.n_intervals < 1:
            raise ValueError("need n_nodes >= 1 and n_intervals >= 1")
        if not (0.0 <= self.memory_jitter < 1.0):
            raise ValueError("memory_jitter must be in [0, 1)")
        if not (0.0 <= self.failure_rate <= 1.0):
            raise ValueError("failure_rate must be in [0, 1]")
        if not (0.0 < self.occupancy <= 1.0):
            raise ValueError("occupancy must be in (0, 1]")
        if self.cache is not None and self.occupancy != 1.0:
            raise ValueError("cache modeling replaces the occupancy "
                             "abstraction; need occupancy == 1.0")
        if self.app_graph is not None:
            # Fails fast on out-of-range slow_nodes / bad DAGs; the
            # compiled arrays themselves are rebuilt (cheaply) at sweep
            # staging time.
            compile_graph(self.app_graph, self.n_nodes)

    def replace(self, **kw) -> "ScenarioSpec":
        return dataclasses.replace(self, **kw)

    @property
    def duration_s(self) -> float:
        return self.n_intervals * self.interval_s

    # -- capture -> scenario -------------------------------------------------
    @classmethod
    def from_capture(cls, capture, *, name: str = "captured",
                     n_nodes: Optional[int] = None,
                     n_intervals: Optional[int] = None,
                     fit_cache: Optional[bool] = None,
                     **overrides) -> "ScenarioSpec":
        """Fit a live :class:`~repro.core.plane.CapturedTrace` into a
        replayable scenario.

        The returned spec carries the raw captured demand
        (:class:`ReplayTrace`) for exact replay through the sweep
        engine, plus fitted summary statistics -- ``amp_range`` from
        the per-node mean-demand spread, ``phase_shift`` from how
        decorrelated nodes were from the fleet-mean trace,
        ``memory_jitter`` from the per-node total-memory spread -- that
        parameterize any clone nodes a larger ``n_nodes`` asks for.
        When the capture observed cache-like residency (the managed
        stores held bytes *and* visibly lagged the grant -- residency
        that tracks the grant exactly is the saturated-store model), a
        :class:`CacheSpec` is fitted from the residency dynamics:
        ``working_set_frac`` from the
        residency ceiling, ``warm_frac`` from the initial
        residency/grant ratio, ``refill_gibps`` from the admission
        flux (p90 of positive residency increments).  Access rate,
        skew and policy are not observable from capacity telemetry
        alone, so they keep the :class:`CacheSpec` defaults -- pass
        ``cache=`` in ``overrides`` to pin them, or ``fit_cache=False``
        to replay the saturated-store model.

        ``capture`` is duck-typed: anything exposing ``demand``,
        ``total_memory``, ``interval_s`` and optionally ``residency`` /
        ``grant`` arrays works (``CapturedTrace`` does).
        """
        demand = np.asarray(capture.demand, np.float64)
        if demand.ndim != 2 or demand.size == 0:
            raise ValueError("capture.demand must be a non-empty (N, T) "
                             "array")
        m = np.broadcast_to(np.asarray(capture.total_memory, np.float64),
                            (demand.shape[0],))
        trace = ReplayTrace(demand, m, interval_s=float(capture.interval_s))

        node_mean = demand.mean(axis=1)
        fleet_mean = float(node_mean.mean())
        if fleet_mean > 0:
            rel = node_mean / fleet_mean
            amp_range = (float(np.clip(rel.min(), 0.05, 1.0)),
                         float(max(rel.max(), 1.0)))
        else:
            amp_range = (1.0, 1.0)
        # Clones should be phase-shifted iff the captured nodes were
        # visibly desynchronized from the fleet-mean shape.
        phase_shift = True
        if demand.shape[0] > 1 and demand.shape[1] > 2:
            fleet_trace = demand.mean(axis=0)
            if fleet_trace.std() > 0:
                corr = [np.corrcoef(row, fleet_trace)[0, 1]
                        for row in demand if row.std() > 0]
                phase_shift = bool(corr and float(np.median(corr)) < 0.9)
        m_mean = float(m.mean())
        memory_jitter = float(np.clip(
            (m.max() - m.min()) / (2.0 * m_mean), 0.0, 0.5))

        cache = None
        residency = np.asarray(getattr(capture, "residency", np.zeros(())),
                               np.float64)
        grant = np.asarray(getattr(capture, "grant", residency), np.float64)
        observed = residency.size > 0 and float(residency.max()) > 0.0
        if fit_cache is None:
            # Auto-fit only when the residency behaved like a *cache*:
            # visibly below the grant somewhere (cold fill, slow
            # refill, eviction lag).  Residency that tracks the grant
            # exactly IS the saturated-store model -- fitting a cache
            # to it would re-simulate warmup that never happened.
            # Samples are observed *before* the interval's decision
            # while ``grant`` is the post-decision capacity, so
            # residency is compared against the grant in force during
            # the interval (the previous tick's decision).
            in_force = np.concatenate([grant[:, :1], grant[:, :-1]], axis=1) \
                if grant.ndim == 2 and grant.shape[1] else grant
            gap = (in_force - residency) / np.maximum(in_force, 1.0)
            fit_cache = observed and bool((gap > 0.02).mean() > 0.05)
        if fit_cache:
            if not observed:
                raise ValueError("fit_cache=True but the capture holds no "
                                 "nonzero cache residency")
            cache = _fit_cache_spec(residency, m, grant,
                                    float(capture.interval_s))

        kw = dict(
            name=name, family="replay",
            n_nodes=n_nodes or trace.n_nodes,
            n_intervals=n_intervals or trace.n_intervals,
            interval_s=trace.interval_s,
            node_memory_gib=m_mean / GiB,
            base_gib=fleet_mean / GiB,
            amp_range=amp_range, phase_shift=phase_shift,
            memory_jitter=memory_jitter, cache=cache, replay=trace,
            description=(f"replay of {trace.n_intervals} intervals x "
                         f"{trace.n_nodes} nodes captured from a live "
                         "MemoryPlane"))
        kw.update(overrides)
        return cls(**kw)

    # -- compilation ---------------------------------------------------------
    def build_demand(self, seed: int = 0) -> np.ndarray:
        """Compile the per-node demand traces: ``(N, T)`` bytes."""
        n, t = self.n_nodes, self.n_intervals
        if self.family == "replay":
            demand = self._replay_demand(seed)
            if self.burst_gib > 0.0:
                demand = demand + self._injected_bursts(seed)
            if self.failure_rate > 0.0:
                demand = demand * self._failure_mask(seed)
            return demand + self.offset_gib * GiB
        if self.family == "hpcc":
            demand = fleet_demand_traces(
                n, t, self.interval_s, seed=seed, amp_range=self.amp_range,
                phase_shift=self.phase_shift)
        elif self.family == "constant":
            base = constant_trace(self.duration_s, self.interval_s,
                                  self.base_gib)
            demand = fleet_demand_traces(
                n, t, self.interval_s, seed=seed, amp_range=self.amp_range,
                phase_shift=False, base=base)
        else:                                              # bursty
            base = bursty_trace(
                t, self.interval_s, base_gib=self.base_gib,
                burst_gib=self.burst_gib,
                burst_every_s=self.burst_every_s,
                burst_len_s=self.burst_len_s, seed=seed)
            demand = fleet_demand_traces(
                n, t, self.interval_s, seed=seed, amp_range=self.amp_range,
                phase_shift=self.phase_shift, base=base)
        if self.burst_gib > 0.0 and self.family != "bursty":
            demand = demand + self._injected_bursts(seed)
        if self.failure_rate > 0.0:
            demand = demand * self._failure_mask(seed)
        return demand + self.offset_gib * GiB

    def _replay_demand(self, seed: int) -> np.ndarray:
        """Captured demand, time-interpolated and node-tiled: (N, T).

        Rows ``0..min(n_nodes, captured)`` are the raw capture (linear
        time interpolation when the horizon differs -- the identity
        when it matches, so same-shape replay is exact).  Clone rows
        tile the captured traces cyclically with per-clone amplitude
        jitter (``amp_range``) and, under ``phase_shift``, a random
        circular roll, so a 5-node capture can drive a 500-node sweep
        without 100 perfectly synchronized copies.
        """
        tr = self.replay
        base = np.asarray(tr.demand, np.float64)
        nc, tc = base.shape
        if self.n_intervals != tc:
            x_old = np.arange(tc, dtype=np.float64)
            x_new = np.linspace(0.0, tc - 1.0, self.n_intervals)
            base = np.stack([np.interp(x_new, x_old, row) for row in base])
        out = np.empty((self.n_nodes, self.n_intervals))
        out[:min(self.n_nodes, nc)] = base[:self.n_nodes]
        if self.n_nodes > nc:
            rng = np.random.default_rng(seed)
            for i in range(nc, self.n_nodes):
                row = base[i % nc]
                amp = rng.uniform(*self.amp_range)
                roll = (int(rng.integers(0, self.n_intervals))
                        if self.phase_shift else 0)
                out[i] = np.roll(row * amp, roll)
        return out

    def build_node_memory(self, seed: int = 0) -> np.ndarray:
        """Per-node total memory M: ``(N,)`` bytes."""
        if self.family == "replay":
            src = np.asarray(self.replay.node_memory, np.float64)
            nc = src.shape[0]
            m = src[np.arange(self.n_nodes) % nc].copy()
            if self.memory_jitter > 0.0 and self.n_nodes > nc:
                # jitter only the tiled clones: captured nodes keep
                # their observed memory so same-shape replay is exact
                rng = np.random.default_rng(seed + 1)
                m[nc:] *= rng.uniform(1.0 - self.memory_jitter,
                                      1.0 + self.memory_jitter,
                                      size=self.n_nodes - nc)
            return m
        m = np.full(self.n_nodes, self.node_memory_gib * GiB)
        if self.memory_jitter > 0.0:
            rng = np.random.default_rng(seed + 1)
            m *= rng.uniform(1.0 - self.memory_jitter,
                             1.0 + self.memory_jitter, size=self.n_nodes)
        return m

    def _injected_bursts(self, seed: int) -> np.ndarray:
        rng = np.random.default_rng(seed + 2)
        n, t = self.n_nodes, self.n_intervals
        period = max(int(round(self.burst_every_s / self.interval_s)), 1)
        blen = max(int(round(self.burst_len_s / self.interval_s)), 1)
        out = np.zeros((n, t))
        starts = rng.integers(0, period, size=n)          # desynchronized
        for i in range(n):
            for s in range(int(starts[i]), t, period):
                out[i, s:s + blen] = self.burst_gib * GiB
        return out

    def _failure_mask(self, seed: int) -> np.ndarray:
        rng = np.random.default_rng(seed + 3)
        n, t = self.n_nodes, self.n_intervals
        flen = max(int(round(self.failure_len_s / self.interval_s)), 1)
        mask = np.ones((n, t))
        failed = rng.random(n) < self.failure_rate
        starts = rng.integers(0, max(t - flen, 1), size=n)
        for i in np.flatnonzero(failed):
            mask[i, starts[i]:starts[i] + flen] = 0.05    # kernel remnant
        return mask


def _fit_cache_spec(residency: np.ndarray, node_memory: np.ndarray,
                    grant: np.ndarray, interval_s: float) -> CacheSpec:
    """Fit CacheLoop knobs from observed residency/grant telemetry.

    Only capacity-visible quantities are fitted; access rate, reuse
    skew and policy are unobservable from byte counts alone and keep
    the :class:`CacheSpec` defaults.
    """
    residency = np.atleast_2d(residency)
    grant = np.atleast_2d(grant)
    ceiling = residency.max(axis=1)                      # (N,) bytes
    ws_frac = float(np.clip((ceiling / node_memory).mean(), 0.01, 1e6))
    g0 = np.maximum(grant[:, 0], 1.0)
    warm_frac = float(np.clip((residency[:, 0] / g0).mean(), 0.0, 1.0))
    flux = np.diff(residency, axis=1) / interval_s       # bytes / s
    inflow = flux[flux > 0]
    refill = (float(np.quantile(inflow, 0.9)) / GiB if inflow.size
              else CacheSpec.refill_gibps)
    refill = max(refill, 0.01)
    return CacheSpec(working_set_frac=ws_frac, warm_frac=warm_frac,
                     refill_gibps=refill,
                     access_gibps=max(2.0 * refill, CacheSpec.access_gibps))


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: Dict[str, ScenarioSpec] = {}


def register_scenario(spec: ScenarioSpec, *, overwrite: bool = False) -> ScenarioSpec:
    if not overwrite and spec.name in _REGISTRY:
        raise ValueError(f"scenario {spec.name!r} already registered")
    _REGISTRY[spec.name] = spec
    return spec


def get_scenario(scenario: Union[str, ScenarioSpec]) -> ScenarioSpec:
    if isinstance(scenario, ScenarioSpec):
        return scenario
    try:
        return _REGISTRY[scenario]
    except KeyError:
        known = ", ".join(sorted(_REGISTRY))
        raise KeyError(f"unknown scenario {scenario!r}; known: {known}") \
            from None


def list_scenarios() -> List[str]:
    return sorted(_REGISTRY)


# The paper's four Sec. IV.A memory configurations, expressed as demand
# scenarios (5 nodes, 125 GB, HPCC as the priority tenant).  What varies
# across them is the static demand floor (Spark executor + RDD cache +
# OS baseline) and whether HPCC runs at all; the storage policy under
# test is supplied by the sweep's gain set.
register_scenario(ScenarioSpec(
    name="paper-c1-spark45", family="hpcc", n_nodes=5, n_intervals=4200,
    offset_gib=47.0, amp_range=(1.0, 1.0), phase_shift=False,
    description="Sec. IV.A config 1: Spark 20G + 25G RDD cache + OS, HPCC"))
register_scenario(ScenarioSpec(
    name="paper-c2-static25", family="hpcc", n_nodes=5, n_intervals=4200,
    offset_gib=22.0, amp_range=(1.0, 1.0), phase_shift=False,
    description="Sec. IV.A config 2: Spark 20G + OS, static Alluxio 25G"))
register_scenario(ScenarioSpec(
    name="paper-c3-dynims60", family="hpcc", n_nodes=5, n_intervals=4200,
    offset_gib=22.0, amp_range=(1.0, 1.0), phase_shift=False,
    description="Sec. IV.A config 3: Spark 20G + OS, DynIMS U_max=60G"))
register_scenario(ScenarioSpec(
    name="paper-c4-nohpcc", family="constant", n_nodes=5, n_intervals=4200,
    base_gib=0.0, offset_gib=22.0, amp_range=(1.0, 1.0),
    description="Sec. IV.A config 4: no HPCC -- static upper bound"))

# Beyond-paper stress scenarios.
register_scenario(ScenarioSpec(
    name="bursty-serving", family="bursty", n_nodes=256, n_intervals=1200,
    base_gib=55.0, burst_gib=50.0, burst_every_s=15.0, burst_len_s=3.0,
    amp_range=(0.9, 1.1),
    description="KV-admission waves: 55G plateau, +50G spikes every 15 s"))
register_scenario(ScenarioSpec(
    name="hetero-fleet", family="hpcc", n_nodes=512, n_intervals=1000,
    amp_range=(0.5, 1.5), memory_jitter=0.2,
    description="mixed hardware: M in [100, 150]G, load amp in [0.5, 1.5]"))
register_scenario(ScenarioSpec(
    name="swap-storm", family="bursty", n_nodes=128, n_intervals=1000,
    base_gib=85.0, burst_gib=45.0, burst_every_s=10.0, burst_len_s=4.0,
    description="demand bursts past M: reclaim must race the swap cliff"))
register_scenario(ScenarioSpec(
    name="phase-replay", family="hpcc", n_nodes=1024, n_intervals=1000,
    amp_range=(0.8, 1.2), phase_shift=True,
    description="fleet-scale phase-shifted HPCC replay (simulate_fleet's "
                "workload)"))
register_scenario(ScenarioSpec(
    name="failover-churn", family="constant", n_nodes=256, n_intervals=1200,
    base_gib=60.0, amp_range=(0.9, 1.1), failure_rate=0.15,
    failure_len_s=10.0,
    description="15% of nodes crash-restart: grant path under churn"))

# CacheLoop scenarios: the same demand families with cache dynamics in
# the scanned loop, so sweeps score modeled app runtime (the paper's
# headline metric) and not just control-loop stability.
register_scenario(ScenarioSpec(
    name="spark-iterative-cache", family="hpcc", n_nodes=64,
    n_intervals=1500, offset_gib=22.0, amp_range=(0.9, 1.1),
    cache=CacheSpec(policy="lfu", reuse_skew=0.6, working_set_frac=0.5,
                    access_gibps=2.0, refill_gibps=1.05),
    description="Sec. IV workload with CacheLoop: iterative Spark scans a "
                "~62G working set through an LFU cache under HPCC bursts"))
register_scenario(ScenarioSpec(
    name="cache-churn", family="bursty", n_nodes=64, n_intervals=1200,
    base_gib=70.0, burst_gib=40.0, burst_every_s=12.0, burst_len_s=3.0,
    amp_range=(0.9, 1.1),
    cache=CacheSpec(policy="lru", reuse_skew=0.3, working_set_frac=0.45,
                    access_gibps=2.0, refill_gibps=0.7,
                    evict_penalty_s_per_gib=0.1),
    description="bursts force evict/refill cycles through a slow-refill "
                "LRU cache: reclaim aggression now costs reloads"))

# AppGraph scenarios: the application is a stage DAG co-simulated
# inside the sweep, scored on end-to-end makespan.  "spark-dag" is the
# paper's Sec. IV workload restated as structure -- an iterative
# map->shuffle->reduce job whose queues drain through an LFU cache
# under HPCC pressure, where the tuned dynamic controller's makespan
# gap over the static Table-I 25G grant is *emergent* (no penalty
# weight; see tests/test_appgraph.py and BENCH_appgraph.json).
# "limplock" isolates the barrier coupling: one 4x-degraded node gates
# every shuffle barrier, inflating fleet makespan ~4x.
register_scenario(ScenarioSpec(
    name="spark-dag", family="hpcc", n_nodes=16, n_intervals=1800,
    offset_gib=22.0, amp_range=(0.55, 0.65), phase_shift=False,
    cache=CacheSpec(policy="lfu", reuse_skew=0.3, working_set_frac=0.5,
                    access_gibps=6.0, refill_gibps=2.5,
                    miss_penalty_s_per_gib=0.95, warm_frac=0.25),
    app_graph=AppGraphSpec(
        stages=(
            StageSpec(name="map", tasks=64, task_gib=6.0, barrier=False,
                      demand_gib=2.0),
            StageSpec(name="shuffle", tasks=0, task_gib=24.0,
                      barrier=True, demand_gib=6.0, deps=("map",)),
            StageSpec(name="reduce", tasks=32, task_gib=12.0,
                      barrier=True, demand_gib=3.0, deps=("shuffle",)),
        ),
        iterations=4, compute_gibps=4.0),
    description="iterative Spark DAG (4 x map->shuffle->reduce, ~288G "
                "of task data per node) drained through an LFU cache "
                "under synchronized HPCC pressure (HPL phases hit every "
                "node at once); scored on emergent makespan"))
register_scenario(ScenarioSpec(
    name="limplock", family="constant", n_nodes=8, n_intervals=1200,
    base_gib=40.0, amp_range=(1.0, 1.0), phase_shift=False,
    app_graph=AppGraphSpec(
        stages=(
            StageSpec(name="map", tasks=0, task_gib=8.0, barrier=True,
                      demand_gib=4.0),
            StageSpec(name="shuffle", tasks=0, task_gib=8.0,
                      barrier=True, demand_gib=8.0, deps=("map",)),
            StageSpec(name="reduce", tasks=0, task_gib=8.0, barrier=True,
                      demand_gib=2.0, deps=("shuffle",)),
        ),
        iterations=2, compute_gibps=2.0, slow_nodes=(0,),
        slow_factor=4.0),
    description="one 4x-degraded node behind every shuffle barrier: the "
                "limplock effect -- fleet makespan tracks the straggler, "
                "not the healthy median"))

# Runtime-churn scenario: the demand is synthesized by actually
# *running* the runtime's fault machinery -- StragglerDetector's
# squeeze->evict escalation and HeartbeatMonitor's timeout detection --
# over a simulated fleet (see repro.runtime.churn), then frozen as a
# replay payload so sweeps stay deterministic and cheap.  This is the
# registration that finally routes runtime/straggler.py and
# runtime/fault.py into the lab; the multi-tenant composition lives in
# repro.fleet.scenario ("tenant-churn").


def _register_runtime_churn() -> ScenarioSpec:
    from ..runtime.churn import churn_demand
    demand, _events = churn_demand(n_nodes=24, n_intervals=480,
                                   interval_s=0.1, seed=0)
    return register_scenario(ScenarioSpec(
        name="runtime-churn", family="replay", n_nodes=24, n_intervals=480,
        replay=ReplayTrace(demand, np.full(24, 125.0 * GiB),
                           interval_s=0.1),
        description="fault-injected fleet: straggler squeeze/evict demand "
                    "swings plus heartbeat-timeout failure windows, "
                    "generated by the live runtime detectors"))


_register_runtime_churn()
