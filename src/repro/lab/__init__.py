"""ScenarioLab: vectorized scenario sweeps and gain autotuning.

The lab exploits the batched control law (PR 1's ``vectorized_step``)
to run *populations* of closed-loop simulations as one compiled
program:

* :mod:`.scenarios` -- declarative :class:`ScenarioSpec` (trace family,
  fleet size, heterogeneity, burst/failure injection, optional
  :class:`CacheSpec` cache-workload knobs) + a registry of named
  scenarios (the paper's Sec. IV.A configs and beyond-paper stress
  shapes).
* :mod:`.sweep`     -- the device-resident engine: demand compiled to
  ``(N, T)``, the loop run as one jitted ``lax.scan`` over time,
  ``vmap``'d over a :class:`GainSet`, optionally ``shard_map``'d over
  devices along the gain axis.  Histories never reach the host: every
  metric streams through the scan, and chunks transfer O(gains)
  scalars.  With a :class:`CacheSpec` attached, the scan also carries
  **CacheLoop** state per node -- resident set, analytic hit ratio,
  eviction/refill flux, modeled app runtime -- so sweeps score the
  paper's headline metric, not just stability.
* :mod:`.appgraph`  -- **AppGraph**: declarative stage DAGs
  (:class:`StageSpec` / :class:`AppGraphSpec`, map->shuffle->reduce
  with dependency edges) co-simulated *inside* the scanned sweep:
  per-node task queues drain at a rate modulated by live memory
  pressure, barrier stages wait on the fleet's slowest node (limplock),
  stage-held demand feeds back into the trace, and the end-to-end
  makespan streams out as ``FleetStats.makespan`` -- the paper's
  headline speedup as an emergent measurement.
* :mod:`.score`     -- Figs. 5-8 analogue metrics (:class:`FleetStats`)
  and scalar objectives, plus the streaming fixed-bin quantile and
  Kahan reduction primitives the engine fuses into its scan.
* :mod:`.tune`      -- gain search returning a tuned
  :class:`~repro.core.control.ControllerParams`: exhaustive grid /
  random, successive halving (:func:`halving_tune`), multi-scenario
  portfolio tuning (:func:`tune_portfolio`), and the **ReplayLoop**
  (:func:`retune_online`): capture a live ``MemoryPlane``'s telemetry,
  re-tune on the replayed workload in the background, hot-swap the
  winner into the running plane.

Since PR 9 the sweep surface is **engine-selectable**: every sweep
entry point (:func:`sweep_demand`, :func:`run_sweep`,
``repro.fleet.fleet_sweep_demand``) and every tuner
(:func:`tune_gains`, :func:`halving_tune`, :func:`tune_portfolio`,
:func:`retune_online`) takes ``engine="xla" | "pallas"`` plus the
shared kwarg set ``horizon`` / ``devices`` / ``node_shards`` /
``chunk`` / ``objective``.  ``engine="pallas"`` routes to
:mod:`.pallas_sweep` -- the fused kernel with in-scan successive
halving.  Renamed spellings (``DEFAULT_CHUNK``, ``tune.ScoreFn``, the
tuners' ``score_fn=``) keep working through warn-once deprecation
shims (:mod:`._compat` documents the mapping).

Tuned presets surface through ``repro.configs.dynims.tuned_params`` and
``MemoryPlane.for_scenario``.
"""

from .appgraph import (AppGraphSpec, CompiledGraph, StageSpec, compile_graph,
                       reference_makespan, topo_order)
from .scenarios import (CacheSpec, ReplayTrace, ScenarioSpec, TRACE_FAMILIES,
                        get_scenario, list_scenarios, register_scenario)
from .score import (FleetStats, OVER_R0_EPS, QUANT_BINS, QUANT_LEVELS,
                    QUANT_RANGE, RUNTIME_WEIGHT, SETTLE_TOL,
                    compute_fleet_stats, default_score, finalize_fleet_stats,
                    hpl_slowdown_curve, kahan_add, makespan_score,
                    quantile_from_codes, runtime_score, stats_to_dict,
                    utilization_codes)
from .sweep import (CODES_BUDGET_BYTES, ENGINES, GainSet, SweepPlan,
                    SweepResult, XLA_DEFAULT_CHUNK, paper_law_mask,
                    plan_specialization, resolve_devices, run_sweep,
                    sweep_demand)
from .tune import (OBJECTIVES, Objective, PortfolioResult, RetuneHandle,
                   RetuneResult, TuneResult, grid_gains, halving_tune,
                   random_gains, resolve_objective, retune_online,
                   tune_gains, tune_portfolio)

__all__ = [
    "AppGraphSpec", "CODES_BUDGET_BYTES", "CacheSpec", "CompiledGraph",
    "ENGINES", "FleetStats",
    "GainSet", "OBJECTIVES", "OVER_R0_EPS", "Objective",
    "PortfolioResult", "QUANT_BINS",
    "QUANT_LEVELS", "QUANT_RANGE", "RUNTIME_WEIGHT", "SETTLE_TOL",
    "ReplayTrace", "RetuneHandle", "RetuneResult", "ScenarioSpec",
    "StageSpec", "SweepPlan", "SweepResult", "TRACE_FAMILIES",
    "TuneResult", "XLA_DEFAULT_CHUNK", "compile_graph",
    "compute_fleet_stats", "default_score",
    "finalize_fleet_stats", "get_scenario", "grid_gains", "halving_tune",
    "hpl_slowdown_curve", "kahan_add", "list_scenarios", "makespan_score",
    "paper_law_mask",
    "plan_specialization", "quantile_from_codes", "random_gains",
    "reference_makespan", "register_scenario", "resolve_devices",
    "resolve_objective",
    "retune_online", "run_sweep", "runtime_score", "stats_to_dict",
    "sweep_demand", "topo_order", "tune_gains", "tune_portfolio",
    "utilization_codes",
]


def __getattr__(name: str):
    if name == "DEFAULT_CHUNK":
        from ._compat import warn_once
        warn_once("lab:DEFAULT_CHUNK",
                  "repro.lab.DEFAULT_CHUNK was renamed to "
                  "XLA_DEFAULT_CHUNK in the PR-9 engine unification; "
                  "the old name will go away")
        return XLA_DEFAULT_CHUNK
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
