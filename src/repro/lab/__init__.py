"""ScenarioLab: vectorized scenario sweeps and gain autotuning.

The lab exploits the batched control law (PR 1's ``vectorized_step``)
to run *populations* of closed-loop simulations as one compiled
program:

* :mod:`.scenarios` -- declarative :class:`ScenarioSpec` (trace family,
  fleet size, heterogeneity, burst/failure injection) + a registry of
  named scenarios (the paper's Sec. IV.A configs and beyond-paper
  stress shapes).
* :mod:`.sweep`     -- the device-resident engine: demand compiled to
  ``(N, T)``, the loop run as one jitted ``lax.scan`` over time,
  ``vmap``'d over a :class:`GainSet`, optionally ``shard_map``'d over
  devices along the gain axis.  Histories never reach the host: every
  metric streams through the scan, and chunks transfer O(gains)
  scalars.
* :mod:`.score`     -- Figs. 5-8 analogue metrics (:class:`FleetStats`)
  and scalar objectives, plus the streaming fixed-bin quantile and
  Kahan reduction primitives the engine fuses into its scan.
* :mod:`.tune`      -- gain search returning a tuned
  :class:`~repro.core.control.ControllerParams`: exhaustive grid /
  random, successive halving (:func:`halving_tune`), and
  multi-scenario portfolio tuning (:func:`tune_portfolio`).

Tuned presets surface through ``repro.configs.dynims.tuned_params`` and
``MemoryPlane.for_scenario``.
"""

from .scenarios import (ScenarioSpec, TRACE_FAMILIES, get_scenario,
                        list_scenarios, register_scenario)
from .score import (FleetStats, OVER_R0_EPS, QUANT_BINS, QUANT_LEVELS,
                    QUANT_RANGE, SETTLE_TOL, compute_fleet_stats,
                    default_score, finalize_fleet_stats, kahan_add,
                    quantile_from_codes, stats_to_dict, utilization_codes)
from .sweep import (CODES_BUDGET_BYTES, DEFAULT_CHUNK, GainSet, SweepResult,
                    resolve_devices, run_sweep, sweep_demand)
from .tune import (PortfolioResult, TuneResult, grid_gains, halving_tune,
                   random_gains, tune_gains, tune_portfolio)

__all__ = [
    "CODES_BUDGET_BYTES", "DEFAULT_CHUNK", "FleetStats", "GainSet",
    "OVER_R0_EPS", "PortfolioResult", "QUANT_BINS", "QUANT_LEVELS",
    "QUANT_RANGE", "SETTLE_TOL", "ScenarioSpec", "SweepResult",
    "TRACE_FAMILIES", "TuneResult", "compute_fleet_stats", "default_score",
    "finalize_fleet_stats", "get_scenario", "grid_gains", "halving_tune",
    "kahan_add", "list_scenarios", "quantile_from_codes", "random_gains",
    "register_scenario", "resolve_devices", "run_sweep", "stats_to_dict",
    "sweep_demand", "tune_gains", "tune_portfolio", "utilization_codes",
]
