"""ScenarioLab: vectorized scenario sweeps and gain autotuning.

The lab exploits the batched control law (PR 1's ``vectorized_step``)
to run *populations* of closed-loop simulations as one compiled
program:

* :mod:`.scenarios` -- declarative :class:`ScenarioSpec` (trace family,
  fleet size, heterogeneity, burst/failure injection) + a registry of
  named scenarios (the paper's Sec. IV.A configs and beyond-paper
  stress shapes).
* :mod:`.sweep`     -- the engine: demand compiled to ``(N, T)``, the
  loop run as one jitted ``lax.scan`` over time, ``vmap``'d over a
  :class:`GainSet` gain grid.
* :mod:`.score`     -- Figs. 5-8 analogue metrics (:class:`FleetStats`)
  and scalar objectives, pure functions of sweep output.
* :mod:`.tune`      -- grid/random gain search returning a tuned
  :class:`~repro.core.control.ControllerParams`.

Tuned presets surface through ``repro.configs.dynims.tuned_params`` and
``MemoryPlane.for_scenario``.
"""

from .scenarios import (ScenarioSpec, TRACE_FAMILIES, get_scenario,
                        list_scenarios, register_scenario)
from .score import (FleetStats, OVER_R0_EPS, SETTLE_TOL, compute_fleet_stats,
                    default_score, stats_to_dict)
from .sweep import (DEFAULT_CHUNK, GainSet, SweepResult, run_sweep,
                    sweep_demand)
from .tune import TuneResult, grid_gains, random_gains, tune_gains

__all__ = [
    "DEFAULT_CHUNK", "FleetStats", "GainSet", "OVER_R0_EPS", "SETTLE_TOL",
    "ScenarioSpec", "SweepResult", "TRACE_FAMILIES", "TuneResult",
    "compute_fleet_stats", "default_score", "get_scenario", "grid_gains",
    "list_scenarios", "random_gains", "register_scenario", "run_sweep",
    "stats_to_dict", "sweep_demand", "tune_gains",
]
