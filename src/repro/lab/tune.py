"""Gain autotuning over the sweep engine.

The paper hand-picks one gain set (Table I) for one testbed; Liang '17
and Will '22 (PAPERS.md) both show memory-capacity settings are
workload-specific.  This module closes that gap: build a gain grid
(:func:`grid_gains`) or a random cloud (:func:`random_gains`), sweep a
scenario's closed loop over all of it in one compiled program, and
materialize the argmax as a :class:`~repro.core.control.ControllerParams`
ready to hand to a ``MemoryPlane``.

Three search strategies:

* ``grid`` / ``random`` -- exhaustive scoring of every candidate on the
  full horizon (one sweep).
* ``halving`` -- successive halving: every candidate is scored on a
  cheap truncated horizon (T/8 by default), survivors promote through
  T/2 to the full horizon.  Rounds reuse one compiled executable per
  (chunk, horizon) shape, so the search costs a fraction of the grid's
  wall-clock at equal candidate count (``benchmarks/lab_bench.py``
  measures time-to-best-gain for both).
* :func:`tune_portfolio` -- multi-scenario tuning: one gain set scored
  across a scenario list, aggregated worst-case (default) or mean, for
  gains that must hold up across workloads rather than win one.

The candidate set always includes the baseline gains at the final
(full-horizon) round, so a tuned result never scores below the paper
defaults on the tuning scenario.

**ReplayLoop** closes the loop on live deployments:
:func:`retune_online` snapshots a running ``MemoryPlane``'s
:class:`~repro.core.plane.TraceRecorder`, fits the capture into a
``"replay"`` scenario (:meth:`ScenarioSpec.from_capture`), runs
:func:`halving_tune` on it in a background thread, and -- when the
winner beats the currently deployed gains on the replayed workload --
atomically hot-swaps the tuned :class:`ControllerParams` into the
still-running plane at an interval boundary.  The plane's action
history is epoch-stamped, so the swap is auditable: no interval is
dropped or duplicated.
"""

from __future__ import annotations

import dataclasses
import threading
from typing import Callable, Dict, List, Optional, Sequence, Union

import numpy as np

from ..configs.dynims import PAPER_TABLE_I
from ..core.control import ControllerParams
from ._compat import warn_once
from .scenarios import ScenarioSpec, get_scenario
from .score import (FleetStats, default_score, makespan_score,
                    runtime_score, stats_to_dict)
from .sweep import GainSet, SweepResult, run_sweep

# The canonical name since the PR-9 API unification; the old spelling
# ``ScoreFn`` still resolves through the module __getattr__ shim below.
Objective = Callable[[FleetStats], np.ndarray]

# Named objectives accepted anywhere an objective goes: ``"default"``
# is the stability/yield trade (``lab.score.default_score``);
# ``"runtime"`` optimizes modeled app runtime on CacheLoop scenarios
# (``lab.score.runtime_score``); ``"makespan"`` optimizes the AppGraph
# DAG co-simulation's emergent end-to-end wall clock
# (``lab.score.makespan_score`` -- no penalty weights involved).
OBJECTIVES: Dict[str, Objective] = {
    "default": default_score,
    "runtime": runtime_score,
    "makespan": makespan_score,
}


def resolve_objective(objective: Union[str, Objective]) -> Objective:
    """Accept a named objective or any ``FleetStats -> (G,)`` callable."""
    if callable(objective):
        return objective
    try:
        return OBJECTIVES[objective]
    except KeyError:
        raise ValueError(f"unknown objective {objective!r}; named "
                         f"objectives: {sorted(OBJECTIVES)}") from None


# Sentinel distinguishing "caller passed the deprecated score_fn="
# from "caller passed nothing".
_UNSET = object()


def _objective_kwarg(objective, score_fn, who: str) -> Objective:
    """Merge the new ``objective=`` with the deprecated ``score_fn=``."""
    if score_fn is not _UNSET:
        warn_once(f"{who}:score_fn",
                  f"{who}(score_fn=...) was renamed to objective=... in "
                  "the PR-9 API unification; the old kwarg still routes "
                  "but will go away")
        if objective is None:
            objective = score_fn
    return resolve_objective(objective if objective is not None
                             else default_score)


def grid_gains(
    base: Optional[ControllerParams] = None,
    *,
    lam: Sequence[float] = (0.1, 0.25, 0.5, 0.75, 1.0, 1.25, 1.5, 1.8),
    r0: Sequence[float] = (0.88, 0.90, 0.92, 0.94, 0.95, 0.96, 0.97, 0.98),
    lam_grant: Sequence[Optional[float]] = (None,),
    u_max: Optional[Sequence[float]] = None,
    deadband: Optional[Sequence[float]] = None,
    feedforward: Optional[Sequence[float]] = None,
) -> GainSet:
    """Cartesian product of gain axes around ``base`` (paper Table I).

    ``lam_grant=None`` entries mean symmetric gains (grant at ``lam``);
    ``u_max`` entries are bytes and default to the base cap.
    ``deadband`` / ``feedforward`` axes search the remaining
    beyond-paper knobs (default: the base values; points with any of
    the three active run on the sweep engine's fallback path -- see
    ``lab.sweep.paper_law_mask``).
    """
    base = base or PAPER_TABLE_I
    u_maxes = tuple(u_max) if u_max is not None else (base.u_max,)
    deadbands = tuple(deadband) if deadband is not None else (base.deadband,)
    feedforwards = (tuple(feedforward) if feedforward is not None
                    else (base.feedforward,))
    rows = [(r, l, l if g is None else g, um, db, ff)
            for r in r0 for l in lam for g in lam_grant for um in u_maxes
            for db in deadbands for ff in feedforwards]
    arr = np.asarray(rows, dtype=np.float64)
    return GainSet(r0=arr[:, 0], lam=arr[:, 1], lam_grant=arr[:, 2],
                   u_min=np.full(len(rows), base.u_min), u_max=arr[:, 3],
                   deadband=arr[:, 4], feedforward=arr[:, 5])


def random_gains(
    n: int,
    base: Optional[ControllerParams] = None,
    *,
    seed: int = 0,
    lam_range: Sequence[float] = (0.05, 1.9),
    r0_range: Sequence[float] = (0.85, 0.98),
    asymmetric: bool = True,
) -> GainSet:
    """``n`` random gain points inside the stable region (0 < lam < 2)."""
    base = base or PAPER_TABLE_I
    rng = np.random.default_rng(seed)
    lam = rng.uniform(*lam_range, size=n)
    r0 = rng.uniform(*r0_range, size=n)
    lam_grant = rng.uniform(*lam_range, size=n) if asymmetric else lam.copy()
    return GainSet(r0=r0, lam=lam, lam_grant=lam_grant,
                   u_min=np.full(n, base.u_min), u_max=np.full(n, base.u_max),
                   deadband=base.deadband, feedforward=base.feedforward)


@dataclasses.dataclass
class TuneResult:
    """Outcome of one autotuning run."""

    params: ControllerParams          # the tuned gains, ready to deploy
    score: float
    baseline_params: ControllerParams
    baseline_score: float
    index: int                        # argmax into ``sweep.gains``
    sweep: SweepResult
    # halving only: per-round records {horizon, n_candidates, elapsed_s}
    rounds: Optional[List[dict]] = None
    # the objective the search ranked with; summary() reuses it so the
    # leaderboard matches the returned winner under custom objectives.
    # (The field keeps its historical name -- it is data, not a kwarg.)
    score_fn: Objective = default_score

    @property
    def improvement(self) -> float:
        return self.score - self.baseline_score

    def best_stats(self) -> dict:
        return stats_to_dict(self.sweep.stats, self.index)

    def summary(self, k: int = 5) -> str:
        """Human-readable top-``k`` table for example scripts."""
        s = self.sweep.scores(self.score_fn)
        lines = [f"scenario={self.sweep.scenario.name} "
                 f"configs={self.sweep.n_configs} "
                 f"throughput={self.sweep.throughput:.2e} node*intv*cfg/s",
                 f"{'rank':>4} {'r0':>6} {'lam':>6} {'lam_g':>6} "
                 f"{'u_max_gib':>9} {'score':>9}"]
        g = self.sweep.gains
        for rank, i in enumerate(self.sweep.top(k, self.score_fn)):
            lines.append(
                f"{rank:4d} {g.r0[i]:6.3f} {g.lam[i]:6.3f} "
                f"{g.lam_grant[i]:6.3f} {g.u_max[i] / 2**30:9.1f} "
                f"{s[i]:9.3f}")
        lines.append(
            f"baseline (r0={self.baseline_params.r0}, "
            f"lam={self.baseline_params.lam}) score="
            f"{self.baseline_score:.3f}  ->  tuned +{self.improvement:.3f}")
        return "\n".join(lines)


def _default_candidates(method: str, budget: int, base: ControllerParams,
                        seed: int) -> GainSet:
    if method == "grid":
        # ~3/4 of the budget on the paper-law (lam, r0) plane -- those
        # points run the sweep engine's specialized fast path -- and
        # the rest split across the three beyond-paper law variants
        # (asymmetric grant gain, hysteresis deadband, slope
        # feedforward), which the engine partitions onto the fallback
        # executable (lab.sweep.paper_law_mask).  Ceilings keep the
        # candidate count at or above ``budget``.
        k = max(int(np.ceil(np.sqrt(budget * 0.75))), 2)
        g = grid_gains(base, lam=np.linspace(0.1, 1.8, k),
                       r0=np.linspace(0.88, 0.98, k))
        kv = max(int(np.ceil(np.sqrt(max(budget - k * k, 0) / 3.0))), 2)
        vlam = np.linspace(0.3, 1.6, kv)
        vr0 = np.linspace(0.90, 0.97, kv)
        for knob in (dict(lam_grant=(0.25,)), dict(deadband=(0.005,)),
                     dict(feedforward=(0.5,))):
            g = g.concat(grid_gains(base, lam=vlam, r0=vr0, **knob))
        return g
    if method == "random":
        return random_gains(budget, base, seed=seed + 7)
    raise ValueError("method must be grid|random|halving")


def tune_gains(
    scenario: Union[str, ScenarioSpec],
    *,
    base_params: Optional[ControllerParams] = None,
    gains: Optional[GainSet] = None,
    method: str = "grid",
    budget: int = 64,
    seed: int = 0,
    objective: Union[None, str, Objective] = None,
    chunk: Optional[int] = None,
    devices=None,
    node_shards: int = 1,
    engine: str = "xla",
    score_fn=_UNSET,
) -> TuneResult:
    """Search gains for ``scenario`` and return the winner.

    ``method`` is ``"grid"`` (a paper-law lam x r0 plane plus
    beyond-paper law variants, sized to *at least* ``budget`` -- the
    plane is ceil'd and the three variant sub-grids always ride along,
    so small budgets overshoot; ``len(result.sweep.gains)`` reports
    the real count), ``"random"`` (exactly ``budget`` points), or
    ``"halving"`` (successive halving via :func:`halving_tune`); pass
    an explicit ``gains`` set to bring your own candidates.
    ``objective`` takes a callable or a named objective (``"default"``
    / ``"runtime"`` -- the latter optimizes CacheLoop's modeled app
    runtime); the pre-PR-9 spelling ``score_fn=`` still routes with a
    one-time deprecation warning.  ``engine`` selects the sweep backend
    (``"xla"`` | ``"pallas"``).  The baseline (``base_params``, default
    paper Table I) is always scored on the full horizon alongside the
    candidates, so the returned score never falls below it.
    """
    objective = _objective_kwarg(objective, score_fn, "tune_gains")
    base = base_params or PAPER_TABLE_I
    if method == "halving":
        return halving_tune(scenario, base_params=base, gains=gains,
                            budget=budget, seed=seed, objective=objective,
                            chunk=chunk, devices=devices,
                            node_shards=node_shards, engine=engine)
    if gains is None:
        gains = _default_candidates(method, budget, base, seed)
    candidates = gains.concat(GainSet.from_params(base))
    result = run_sweep(scenario, candidates, seed=seed, chunk=chunk,
                       devices=devices, node_shards=node_shards,
                       engine=engine, objective=objective)
    scores = result.scores(objective)
    best = int(np.argmax(scores))
    baseline_score = float(scores[-1])          # base appended last
    return TuneResult(
        params=candidates.params_at(best, base),
        score=float(scores[best]),
        baseline_params=base,
        baseline_score=baseline_score,
        index=best,
        sweep=result,
        score_fn=objective,
    )


def halving_tune(
    scenario: Union[str, ScenarioSpec],
    *,
    base_params: Optional[ControllerParams] = None,
    gains: Optional[GainSet] = None,
    budget: int = 64,
    rounds: Sequence[float] = (0.125, 0.5, 1.0),
    keep: float = 0.25,
    min_survivors: int = 4,
    seed: int = 0,
    objective: Union[None, str, Objective] = None,
    chunk: Optional[int] = None,
    devices=None,
    node_shards: int = 1,
    engine: str = "xla",
    score_fn=_UNSET,
) -> TuneResult:
    """Successive-halving gain search: cheap prefix rounds, full finals.

    Every candidate is scored on the scenario's first
    ``rounds[0] * T`` intervals; the top ``keep`` fraction (at least
    ``min_survivors``) promotes to the next horizon, and only the last
    round pays for the full closed loop.  With the default schedule a
    64-point search simulates ~20 full-horizon equivalents instead of
    64.  Prefix scores are a proxy -- a gain that only misbehaves late
    in the trace can be mis-ranked early, which ``keep`` hedges
    against; the final round is always exact, and the baseline is
    scored there so the guarantee "never below baseline" holds on the
    full horizon.

    ``engine="xla"`` (default) runs the halving loop host-side: each
    round is a from-scratch truncated sweep, and rounds reuse the sweep
    engine's shape-specialized executable for their (chunk, horizon)
    pair.  ``engine="pallas"`` moves the whole schedule *in-scan*
    (:func:`~repro.lab.pallas_sweep.halving_sweep`): one device program
    pauses at each horizon, scores and compacts the survivor lanes on
    device, and never re-simulates the prefix -- same survivors (the
    lanes are deterministic, so prefix accumulators equal a truncated
    from-scratch run), a fraction of the dispatches and the work.
    """
    objective = _objective_kwarg(objective, score_fn, "halving_tune")
    spec = get_scenario(scenario)
    base = base_params or PAPER_TABLE_I
    if gains is None:
        gains = _default_candidates("grid", budget, base, seed)
    if engine == "pallas":
        if spec.app_graph is not None:
            # The in-scan halving kernel has no queue/barrier carry
            # (same gap as pallas_sweep_demand); the host-side loop
            # below scores AppGraph scenarios through the XLA engine.
            warn_once("halving_tune:app_graph",
                      "halving_tune(engine='pallas'): AppGraph "
                      "scenarios fall back to the host-side halving "
                      "loop on the XLA engine", RuntimeWarning)
            engine = "xla"
        else:
            return _halving_tune_pallas(
                spec, base, gains, rounds=rounds, keep=keep,
                min_survivors=min_survivors, seed=seed,
                objective=objective, chunk=chunk, devices=devices,
                node_shards=node_shards)
    fracs = sorted(set(float(f) for f in rounds))
    if not fracs or fracs[0] <= 0.0 or fracs[-1] > 1.0:
        raise ValueError("rounds must be fractions in (0, 1]")
    if fracs[-1] != 1.0:
        fracs.append(1.0)

    survivors = gains
    round_log: List[dict] = []
    for i, frac in enumerate(fracs):
        final = i == len(fracs) - 1
        horizon = max(int(round(spec.n_intervals * frac)), 1)
        if final:
            survivors = survivors.concat(GainSet.from_params(base))
        result = run_sweep(spec, survivors, seed=seed, chunk=chunk,
                           devices=devices, node_shards=node_shards,
                           engine=engine, objective=objective,
                           horizon=None if frac == 1.0 else horizon)
        scores = result.scores(objective)
        round_log.append({"horizon": horizon,
                          "n_candidates": len(survivors),
                          "elapsed_s": result.elapsed_s})
        if final:
            best = int(np.argmax(scores))
            return TuneResult(
                params=survivors.params_at(best, base),
                score=float(scores[best]),
                baseline_params=base,
                baseline_score=float(scores[-1]),   # base appended last
                index=best,
                sweep=result,
                rounds=round_log,
                score_fn=objective,
            )
        n_keep = max(int(np.ceil(len(survivors) * keep)), min_survivors)
        n_keep = min(n_keep, len(survivors))
        survivors = survivors.take(np.argsort(-scores)[:n_keep])
    raise AssertionError("unreachable")


def _halving_tune_pallas(spec: ScenarioSpec, base: ControllerParams,
                         gains: GainSet, *, rounds, keep, min_survivors,
                         seed, objective, chunk, devices,
                         node_shards) -> TuneResult:
    """``halving_tune(engine="pallas")``: the in-scan schedule, wrapped.

    Builds the scenario exactly like :func:`run_sweep`, hands the
    candidates + baseline to the single-dispatch
    :func:`~repro.lab.pallas_sweep.halving_sweep`, and repacks its
    final-round lanes into the standard :class:`TuneResult` --
    ``result.sweep.gains`` holds the surviving candidates with the
    baseline appended last, same as the host path's final round.
    """
    from .pallas_sweep import halving_sweep

    demand = spec.build_demand(seed=seed)
    m = spec.build_node_memory(seed=seed)
    hs = halving_sweep(
        demand, gains, GainSet.from_params(base), node_memory=m,
        interval_s=spec.interval_s, occupancy=spec.occupancy,
        cache=spec.cache, rounds=rounds, keep=keep,
        min_survivors=min_survivors, objective=objective, chunk=chunk,
        devices=devices, node_shards=node_shards)
    survivors = gains.take(hs.survivor_idx).concat(
        GainSet.from_params(base))
    sweep = SweepResult(scenario=spec, gains=survivors, stats=hs.stats,
                        seed=seed, elapsed_s=hs.elapsed_s,
                        objective=objective)
    # Final ranking recomputed host-side (float64 numpy over the final
    # lanes' stats) so it matches the host tuner's arithmetic exactly;
    # the in-scan rounds selected with the same objective in f32.
    scores = sweep.scores(objective)
    best = int(np.argmax(scores))
    return TuneResult(
        params=survivors.params_at(best, base),
        score=float(scores[best]),
        baseline_params=base,
        baseline_score=float(scores[-1]),           # base appended last
        index=best,
        sweep=sweep,
        rounds=hs.rounds,
        score_fn=objective,
    )


@dataclasses.dataclass
class PortfolioResult:
    """Outcome of one multi-scenario (portfolio) tuning run."""

    params: ControllerParams          # best aggregate gains, deployable
    score: float                      # aggregated over the portfolio
    baseline_params: ControllerParams
    baseline_score: float
    index: int
    aggregate: str                    # "worst" | "mean"
    scenario_scores: Dict[str, float]      # winner's per-scenario scores
    sweeps: Dict[str, SweepResult]         # full per-scenario results

    @property
    def improvement(self) -> float:
        return self.score - self.baseline_score


def tune_portfolio(
    scenarios: Sequence[Union[str, ScenarioSpec]],
    *,
    base_params: Optional[ControllerParams] = None,
    gains: Optional[GainSet] = None,
    method: str = "grid",
    budget: int = 64,
    aggregate: str = "worst",
    seed: int = 0,
    objective: Union[None, str, Objective] = None,
    chunk: Optional[int] = None,
    devices=None,
    node_shards: int = 1,
    engine: str = "xla",
    score_fn=_UNSET,
) -> PortfolioResult:
    """One gain set scored across a scenario portfolio.

    Sweeps the same candidates over every scenario and aggregates the
    (S, G) score matrix per gain point -- ``"worst"`` (min over
    scenarios: robust gains that degrade gracefully everywhere) or
    ``"mean"``.  ``objective`` accepts the named objectives too
    (``"runtime"`` portfolio-tunes modeled app runtime across CacheLoop
    scenarios); ``score_fn=`` is the deprecated spelling.  ``engine``
    selects the sweep backend per scenario.  The baseline rides along,
    so the winner's aggregate never falls below the paper defaults
    across the portfolio.
    """
    objective = _objective_kwarg(objective, score_fn, "tune_portfolio")
    if not scenarios:
        raise ValueError("need at least one scenario")
    if aggregate not in ("worst", "mean"):
        raise ValueError("aggregate must be worst|mean")
    base = base_params or PAPER_TABLE_I
    if gains is None:
        gains = _default_candidates(method, budget, base, seed)
    candidates = gains.concat(GainSet.from_params(base))
    sweeps: Dict[str, SweepResult] = {}
    matrix = []
    for sc in scenarios:
        spec = get_scenario(sc)
        result = run_sweep(spec, candidates, seed=seed, chunk=chunk,
                           devices=devices, node_shards=node_shards,
                           engine=engine, objective=objective)
        sweeps[spec.name] = result
        matrix.append(result.scores(objective))
    matrix = np.stack(matrix)                       # (S, G)
    agg = matrix.min(axis=0) if aggregate == "worst" else matrix.mean(axis=0)
    best = int(np.argmax(agg))
    return PortfolioResult(
        params=candidates.params_at(best, base),
        score=float(agg[best]),
        baseline_params=base,
        baseline_score=float(agg[-1]),              # base appended last
        index=best,
        aggregate=aggregate,
        scenario_scores={name: float(matrix[i, best])
                         for i, name in enumerate(sweeps)},
        sweeps=sweeps,
    )


# ---------------------------------------------------------------------------
# ReplayLoop: capture -> replay -> re-tune -> hot-swap
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class RetuneResult:
    """Outcome of one online re-tuning round."""

    scenario: ScenarioSpec            # the fitted replay scenario
    tune: TuneResult                  # full tuning outcome on the replay
    old_params: ControllerParams      # what the plane was running
    params: ControllerParams          # the replay winner (== tune.params)
    swapped: bool                     # did the plane adopt the winner?
    epoch: Optional[int]              # parameter epoch after the swap
    capture: object                   # the CapturedTrace that was tuned on

    @property
    def improvement(self) -> float:
        """Winner's score minus the deployed gains' score on the replay."""
        return self.tune.improvement

    def summary(self) -> str:
        verdict = (f"hot-swapped at epoch {self.epoch}" if self.swapped
                   else "kept deployed gains (no improvement on replay)")
        return (f"retune[{self.scenario.name}]: deployed "
                f"{self.tune.baseline_score:.3f} -> tuned "
                f"{self.tune.score:.3f} (+{self.improvement:.3f}); "
                f"{verdict}")


class RetuneHandle:
    """Join handle on a supervised background :func:`retune_online` round.

    Besides joining for the result, it exposes the supervisor's live
    counters: ``attempts`` (rounds started, including the first) and
    ``restarts`` (rounds restarted after a crashed attempt) -- the
    observable trace of the chaos drill's ``retune-kill`` fault.
    """

    def __init__(self, thread: threading.Thread, box: dict,
                 stats: Optional[dict] = None,
                 stats_lock: Optional[threading.Lock] = None):
        # The box is written only by the supervisor thread and read
        # only after join() -- synchronized by the join, not by a lock.
        self._thread = thread
        self._box = box          # guarded-by: join(_thread)
        self._stats_lock = stats_lock or threading.Lock()
        self._stats = stats if stats is not None else {
            "attempts": 1, "restarts": 0}   # guarded-by: _stats_lock

    @property
    def done(self) -> bool:
        return not self._thread.is_alive()

    @property
    def attempts(self) -> int:
        """Rounds started so far (>= 1 once the thread runs)."""
        with self._stats_lock:
            return self._stats["attempts"]

    @property
    def restarts(self) -> int:
        """Rounds restarted after a crashed attempt."""
        with self._stats_lock:
            return self._stats["restarts"]

    def result(self, timeout: Optional[float] = None) -> RetuneResult:
        """Wait for the round and return its result (re-raising errors)."""
        self._thread.join(timeout)
        if self._thread.is_alive():
            raise TimeoutError("retune round still running")
        if "error" in self._box:
            raise self._box["error"]
        return self._box["result"]


def retune_online(
    plane,
    *,
    capture=None,
    name: str = "captured",
    method: str = "halving",
    budget: int = 32,
    objective: Union[None, str, Objective] = None,
    n_intervals: Optional[int] = None,
    n_nodes: Optional[int] = None,
    fit_cache: Optional[bool] = None,
    min_improvement: float = 0.0,
    swap: bool = True,
    block: bool = True,
    seed: int = 0,
    chunk: Optional[int] = None,
    devices=None,
    node_shards: int = 1,
    engine: str = "xla",
    restarts: int = 0,
    restart_backoff_s: float = 0.05,
    score_fn=_UNSET,
    **scenario_overrides,
) -> Union[RetuneResult, "RetuneHandle"]:
    """Re-tune a running ``MemoryPlane`` on its own captured workload.

    The ReplayLoop in one call: snapshot the plane's recorded telemetry
    (``plane.capture()``, or pass an explicit ``capture``), fit it into
    a ``"replay"`` scenario, search gains on it with the sweep engine
    (``method``/``budget``/``objective``/``engine`` as in
    :func:`tune_gains`; successive halving by default, ``score_fn=``
    deprecated as everywhere), and -- if the winner improves on
    the *currently deployed* parameters by more than
    ``min_improvement`` -- hot-swap it into the plane via
    ``plane.swap_params`` (atomic, interval-boundary, epoch-stamped).

    The deployed parameters are the tuning baseline, so the returned
    ``tune.score`` never falls below what the plane is already running
    on the replayed workload, and a no-improvement round swaps nothing.

    Tuning runs on a daemon thread; the plane keeps ticking while the
    search sweeps.  ``block=True`` (default) joins and returns the
    :class:`RetuneResult`; ``block=False`` returns a
    :class:`RetuneHandle` immediately (``handle.result()`` joins).
    Extra keywords pass through to :meth:`ScenarioSpec.from_capture`
    (e.g. ``cache=`` to pin a hand-fitted :class:`CacheSpec`).

    **Supervision** (``restarts > 0``): a crashed round -- capture,
    sweep, or swap raising, e.g. under the chaos drill's
    ``retune-kill`` fault -- is restarted up to ``restarts`` times with
    exponential backoff (``restart_backoff_s * 2**attempt``, capped at
    5 s).  Each retry re-captures (when ``capture`` was not pinned) and
    re-reads the deployed params, so a restart tunes on fresh
    telemetry.  The supervisor runs entirely on its own thread and
    never holds the plane's tick lock across a round -- a wedged sweep
    cannot stall control.  Restarts are visible as ``handle.restarts``
    and, when the plane has a fault log, as ``retune-restart`` /
    ``retune-dead`` events.
    """
    objective = _objective_kwarg(objective, score_fn, "retune_online")
    if restarts < 0:
        raise ValueError("restarts must be >= 0")
    if capture is None and restarts == 0:
        # Unsupervised: capture eagerly so an empty recorder raises in
        # the caller, not the round thread (legacy behavior).
        capture = plane.capture()
    box: dict = {}
    stats = {"attempts": 0, "restarts": 0}      # guarded-by: stats_lock
    stats_lock = threading.Lock()

    def _attempt() -> RetuneResult:
        cap = capture if capture is not None else plane.capture()
        deployed = plane.params
        spec = ScenarioSpec.from_capture(
            cap, name=name, n_intervals=n_intervals, n_nodes=n_nodes,
            fit_cache=fit_cache, **scenario_overrides)
        tune = tune_gains(spec, base_params=deployed, method=method,
                          budget=budget, seed=seed, objective=objective,
                          chunk=chunk, devices=devices,
                          node_shards=node_shards, engine=engine)
        swapped, epoch = False, None
        if swap and tune.improvement > min_improvement:
            epoch = plane.swap_params(tune.params)
            swapped = True
        return RetuneResult(
            scenario=spec, tune=tune, old_params=deployed,
            params=tune.params, swapped=swapped, epoch=epoch, capture=cap)

    def _supervised() -> None:
        import time as _time
        log_fault = getattr(plane, "log_fault", None)
        for attempt in range(restarts + 1):
            with stats_lock:
                stats["attempts"] += 1
            try:
                box["result"] = _attempt()
                box.pop("error", None)           # earlier attempts' crash
                return
            except BaseException as exc:         # surfaced via result()
                box["error"] = exc
                if attempt >= restarts:
                    if log_fault is not None and restarts > 0:
                        log_fault("retune-dead",
                                  detail=f"{type(exc).__name__}: {exc}")
                    return
                with stats_lock:
                    stats["restarts"] += 1
                if log_fault is not None:
                    log_fault("retune-restart",
                              detail=f"attempt {attempt + 1} died: "
                                     f"{type(exc).__name__}: {exc}")
                _time.sleep(min(restart_backoff_s * (2 ** attempt), 5.0))

    thread = threading.Thread(target=_supervised, daemon=True,
                              name="retune-online")
    thread.start()
    handle = RetuneHandle(thread, box, stats, stats_lock)
    return handle.result() if block else handle


def __getattr__(name: str):
    if name == "ScoreFn":
        warn_once("tune:ScoreFn",
                  "repro.lab.tune.ScoreFn was renamed to Objective in "
                  "the PR-9 API unification; the old name will go away")
        return Objective
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
