"""Gain autotuning over the sweep engine.

The paper hand-picks one gain set (Table I) for one testbed; Liang '17
and Will '22 (PAPERS.md) both show memory-capacity settings are
workload-specific.  This module closes that gap: build a gain grid
(:func:`grid_gains`) or a random cloud (:func:`random_gains`), sweep a
scenario's closed loop over all of it in one compiled program, and
materialize the argmax as a :class:`~repro.core.control.ControllerParams`
ready to hand to a ``MemoryPlane``.

The candidate set always includes the baseline gains, so a tuned
result never scores below the paper defaults on the tuning scenario.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Sequence, Union

import numpy as np

from ..configs.dynims import PAPER_TABLE_I
from ..core.control import ControllerParams
from .scenarios import ScenarioSpec, get_scenario
from .score import FleetStats, default_score, stats_to_dict
from .sweep import DEFAULT_CHUNK, GainSet, SweepResult, run_sweep

ScoreFn = Callable[[FleetStats], np.ndarray]


def grid_gains(
    base: Optional[ControllerParams] = None,
    *,
    lam: Sequence[float] = (0.1, 0.25, 0.5, 0.75, 1.0, 1.25, 1.5, 1.8),
    r0: Sequence[float] = (0.88, 0.90, 0.92, 0.94, 0.95, 0.96, 0.97, 0.98),
    lam_grant: Sequence[Optional[float]] = (None,),
    u_max: Optional[Sequence[float]] = None,
) -> GainSet:
    """Cartesian product of gain axes around ``base`` (paper Table I).

    ``lam_grant=None`` entries mean symmetric gains (grant at ``lam``);
    ``u_max`` entries are bytes and default to the base cap.
    """
    base = base or PAPER_TABLE_I
    u_maxes = tuple(u_max) if u_max is not None else (base.u_max,)
    rows = [(r, l, l if g is None else g, um)
            for r in r0 for l in lam for g in lam_grant for um in u_maxes]
    arr = np.asarray(rows, dtype=np.float64)
    return GainSet(r0=arr[:, 0], lam=arr[:, 1], lam_grant=arr[:, 2],
                   u_min=np.full(len(rows), base.u_min), u_max=arr[:, 3],
                   deadband=base.deadband, feedforward=base.feedforward)


def random_gains(
    n: int,
    base: Optional[ControllerParams] = None,
    *,
    seed: int = 0,
    lam_range: Sequence[float] = (0.05, 1.9),
    r0_range: Sequence[float] = (0.85, 0.98),
    asymmetric: bool = True,
) -> GainSet:
    """``n`` random gain points inside the stable region (0 < lam < 2)."""
    base = base or PAPER_TABLE_I
    rng = np.random.default_rng(seed)
    lam = rng.uniform(*lam_range, size=n)
    r0 = rng.uniform(*r0_range, size=n)
    lam_grant = rng.uniform(*lam_range, size=n) if asymmetric else lam.copy()
    return GainSet(r0=r0, lam=lam, lam_grant=lam_grant,
                   u_min=np.full(n, base.u_min), u_max=np.full(n, base.u_max),
                   deadband=base.deadband, feedforward=base.feedforward)


@dataclasses.dataclass
class TuneResult:
    """Outcome of one autotuning run."""

    params: ControllerParams          # the tuned gains, ready to deploy
    score: float
    baseline_params: ControllerParams
    baseline_score: float
    index: int                        # argmax into ``sweep.gains``
    sweep: SweepResult

    @property
    def improvement(self) -> float:
        return self.score - self.baseline_score

    def best_stats(self) -> dict:
        return stats_to_dict(self.sweep.stats, self.index)

    def summary(self, k: int = 5) -> str:
        """Human-readable top-``k`` table for example scripts."""
        s = self.sweep.scores()
        lines = [f"scenario={self.sweep.scenario.name} "
                 f"configs={self.sweep.n_configs} "
                 f"throughput={self.sweep.throughput:.2e} node*intv*cfg/s",
                 f"{'rank':>4} {'r0':>6} {'lam':>6} {'lam_g':>6} "
                 f"{'u_max_gib':>9} {'score':>9}"]
        g = self.sweep.gains
        for rank, i in enumerate(self.sweep.top(k)):
            lines.append(
                f"{rank:4d} {g.r0[i]:6.3f} {g.lam[i]:6.3f} "
                f"{g.lam_grant[i]:6.3f} {g.u_max[i] / 2**30:9.1f} "
                f"{s[i]:9.3f}")
        lines.append(
            f"baseline (r0={self.baseline_params.r0}, "
            f"lam={self.baseline_params.lam}) score="
            f"{self.baseline_score:.3f}  ->  tuned +{self.improvement:.3f}")
        return "\n".join(lines)


def tune_gains(
    scenario: Union[str, ScenarioSpec],
    *,
    base_params: Optional[ControllerParams] = None,
    gains: Optional[GainSet] = None,
    method: str = "grid",
    budget: int = 64,
    seed: int = 0,
    score_fn: ScoreFn = default_score,
    chunk: int = DEFAULT_CHUNK,
) -> TuneResult:
    """Search gains for ``scenario`` and return the winner.

    ``method`` is ``"grid"`` (cartesian lam x r0 product sized to
    ``budget``) or ``"random"``; pass an explicit ``gains`` set to
    bring your own candidates.  The baseline (``base_params``, default
    paper Table I) is always appended as the final candidate.
    """
    base = base_params or PAPER_TABLE_I
    if gains is None:
        if method == "grid":
            k = max(int(np.sqrt(budget)), 2)
            lam = np.linspace(0.1, 1.8, k)
            r0 = np.linspace(0.88, 0.98, k)
            gains = grid_gains(base, lam=lam, r0=r0)
        elif method == "random":
            gains = random_gains(budget, base, seed=seed + 7)
        else:
            raise ValueError("method must be grid|random")
    candidates = gains.concat(GainSet.from_params(base))
    result = run_sweep(scenario, candidates, seed=seed, chunk=chunk)
    scores = result.scores(score_fn)
    best = int(np.argmax(scores))
    baseline_score = float(scores[-1])          # base appended last
    return TuneResult(
        params=candidates.params_at(best, base),
        score=float(scores[best]),
        baseline_params=base,
        baseline_score=baseline_score,
        index=best,
        sweep=result,
    )
