"""AppGraph: DAG-aware application co-simulation inside the scanned sweep.

CacheLoop (PR 4) priced memory pressure into a per-interval *penalty
model*: every interval pays ``interval * hpl_slowdown + misses *
miss_penalty`` and the sum is the modeled runtime.  That reproduces the
paper's 5X claim only as a weighted objective term.  AppGraph makes it
**emergent**: the application is declared as a stage DAG
(map -> shuffle -> reduce with dependency edges, per-stage task counts
and data sizes), and the sweep engine co-simulates per-node task queues
*inside* the same ``lax.scan`` that runs the control loop --

* each node advances its current stage's work queue at a rate modulated
  by that node's live memory state: the Fig.-2 swap curve stretches the
  interval, and (with a :class:`~repro.lab.scenarios.CacheSpec`
  attached) cache misses and eviction churn stretch it further, so a
  starved cache *slows the queue down* instead of adding a penalty;
* barrier stages wait on the slowest node -- one limplocked node
  throttles the whole stage fleet-wide (the limplock effect: one
  node at 4x work or under swap pressure sets every node's stage
  completion);
* an active stage holds its declared shuffle/scratch memory
  (``demand_gib``), *allocated when the stage starts and released when
  it completes* -- stage transitions feed demand back into the trace the
  controller observes, closing the demand <-> pressure loop.

The score is end-to-end **makespan** (:class:`~repro.lab.score.FleetStats`
``makespan``): the wall-clock at which the last node drains the last
stage.  No penalty weight is involved -- a controller that keeps caches
warm and nodes off the swap cliff finishes the DAG earlier, period.

Execution model: the declared DAG is validated and topologically
linearized at compile time (:func:`compile_graph`); per node, one stage
is active at a time, in topological order -- Spark's stage scheduling
within a job, where an executor works wave by wave.  ``barrier=True``
stages (shuffle boundaries) gate *every* node's promotion on the
fleet's slowest; ``barrier=False`` stages let each node proceed
independently (map-side pipelining).  The whole thing compiles to O(N)
carry state (stage pointer, work remaining, Kahan work-done lanes) plus
two trace-time constant vectors and one ``(S+1, N)`` work-matrix
operand, so an AppGraph sweep is still one fused XLA dispatch per gain
chunk, and ``app_graph=None`` compiles the exact pre-AppGraph program.
"""

from __future__ import annotations

import dataclasses
from typing import List, NamedTuple, Optional, Tuple

import numpy as np

from ..core.traces import GiB


@dataclasses.dataclass(frozen=True)
class StageSpec:
    """One stage of the application DAG.

    Fields:
      name:       stage identifier, unique within the graph (dependency
                  edges reference it).
      tasks:      number of tasks in the stage, distributed round-robin
                  over the fleet (node ``n`` of ``N`` gets
                  ``tasks // N + (n < tasks % N)``).  ``0`` means one
                  task per node (an embarrassingly node-parallel stage).
      task_gib:   data each task processes (GiB) -- the unit of work the
                  queue drains.
      barrier:    does the stage end in a fleet-wide barrier (a shuffle
                  boundary)?  With ``True`` no node enters the next
                  stage until *every* node finished this one -- the
                  limplock coupling.  ``False`` pipelines per node.
      demand_gib: per-node memory the stage holds while active (shuffle
                  buffers, scratch): allocated the interval the node
                  enters the stage, released the interval it leaves --
                  this is the demand the controller *sees*.
      deps:       names of stages that must precede this one (validated
                  and topologically ordered by :func:`compile_graph`;
                  an empty tuple chains onto the declaration order).
    """

    name: str
    tasks: int = 0
    task_gib: float = 1.0
    barrier: bool = True
    demand_gib: float = 0.0
    deps: Tuple[str, ...] = ()

    def __post_init__(self) -> None:
        if not self.name:
            raise ValueError("stage needs a non-empty name")
        if self.tasks < 0:
            raise ValueError("tasks must be >= 0 (0 = one per node)")
        if self.task_gib <= 0.0:
            raise ValueError("task_gib must be positive")
        if self.demand_gib < 0.0:
            raise ValueError("demand_gib must be non-negative")


@dataclasses.dataclass(frozen=True)
class AppGraphSpec:
    """A declarative application DAG co-simulated by the sweep engine.

    Attached to a :class:`~repro.lab.scenarios.ScenarioSpec` as
    ``app_graph=``, this turns every sweep over that scenario into a
    DAG co-simulation scored on end-to-end makespan (see the module
    docstring).  Frozen and hashable, so a graph is a value the
    compiled-sweep cache can key on.

    Fields:
      stages:        the stage DAG (:class:`StageSpec` tuple).  Declared
                     order is the tie-break; ``deps`` edges are
                     validated and topologically sorted.
      iterations:    how many times the whole DAG repeats (iterative
                     Spark jobs re-run map->shuffle->reduce per
                     iteration); the compiled stage sequence is the
                     topological order tiled ``iterations`` times.
      compute_gibps: per-node queue drain rate with no memory
                     interference (GiB of task data per wall second).
      slow_nodes:    global node indices with a compute skew (hardware
                     limplock: a degraded disk/NIC/CPU).
      slow_factor:   work multiplier on ``slow_nodes`` (2.0 = the node
                     needs twice the wall time per task).
    """

    stages: Tuple[StageSpec, ...]
    iterations: int = 1
    compute_gibps: float = 2.0
    slow_nodes: Tuple[int, ...] = ()
    slow_factor: float = 1.0

    def __post_init__(self) -> None:
        if not self.stages:
            raise ValueError("need at least one stage")
        names = [s.name for s in self.stages]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate stage names in {names}")
        if self.iterations < 1:
            raise ValueError("iterations must be >= 1")
        if self.compute_gibps <= 0.0:
            raise ValueError("compute_gibps must be positive")
        if self.slow_factor < 1.0:
            raise ValueError("slow_factor must be >= 1 (it multiplies "
                             "work; use demand for memory skew)")
        if any(i < 0 for i in self.slow_nodes):
            raise ValueError("slow_nodes are non-negative node indices")
        # Validate + topo-order eagerly so a bad DAG fails at spec
        # construction, not inside a traced sweep.
        topo_order(self.stages)

    def replace(self, **kw) -> "AppGraphSpec":
        return dataclasses.replace(self, **kw)

    @property
    def n_stage_rows(self) -> int:
        """Compiled stage-sequence length (stages x iterations)."""
        return len(self.stages) * self.iterations

    def total_work_gib(self, n_nodes: int) -> float:
        """Fleet-total task data over the full run (skew included)."""
        return float(compile_graph(self, n_nodes).work_gib.sum())


def topo_order(stages: Tuple[StageSpec, ...]) -> List[int]:
    """Topological order of ``stages`` (Kahn), declaration-order ties.

    Raises on unknown dependency names and on cycles.  A graph with no
    ``deps`` edges keeps its declaration order -- the implicit chain.
    """
    index = {s.name: i for i, s in enumerate(stages)}
    for s in stages:
        for d in s.deps:
            if d not in index:
                raise ValueError(f"stage {s.name!r} depends on unknown "
                                 f"stage {d!r}")
            if d == s.name:
                raise ValueError(f"stage {s.name!r} depends on itself")
    indeg = {i: len(set(s.deps)) for i, s in enumerate(stages)}
    out = []
    ready = sorted(i for i, d in indeg.items() if d == 0)
    while ready:
        i = ready.pop(0)
        out.append(i)
        for j, s in enumerate(stages):
            if stages[i].name in s.deps:
                indeg[j] -= s.deps.count(stages[i].name) and 1
                if indeg[j] == 0:
                    ready.append(j)
        ready.sort()
    if len(out) != len(stages):
        cyc = sorted(s.name for i, s in enumerate(stages) if i not in out)
        raise ValueError(f"dependency cycle through stages {cyc}")
    return out


class CompiledGraph(NamedTuple):
    """Numpy arrays one :class:`AppGraphSpec` compiles to for ``N`` nodes.

    All arrays have a trailing sentinel row/entry for the "done" state
    (index ``S``): zero work, zero demand, no barrier -- a finished
    node gathers neutral values forever.
    """

    work_gib: np.ndarray      # (S+1, N) f32: per-node work per stage row
    demand_bytes: np.ndarray  # (S+1,)  f32: held memory while row active
    barrier: np.ndarray       # (S+1,)  f32: 1.0 = fleet barrier at row end
    names: Tuple[str, ...]    # (S,) row -> "stage@iteration" labels

    @property
    def n_rows(self) -> int:
        return self.barrier.shape[0] - 1


def compile_graph(graph: AppGraphSpec, n_nodes: int) -> CompiledGraph:
    """Lower a stage DAG to the sweep engine's dense operands.

    Topologically linearizes the DAG, tiles it ``iterations`` times,
    and materializes per-node work (round-robin task placement,
    ``slow_nodes`` skew applied per *global* node index), per-row held
    demand, and per-row barrier flags.  Pure numpy -- runs once per
    (graph, fleet size) at trace staging time, never inside the scan.
    """
    if n_nodes < 1:
        raise ValueError("n_nodes must be >= 1")
    bad = [i for i in graph.slow_nodes if i >= n_nodes]
    if bad:
        raise ValueError(f"slow_nodes {bad} out of range for "
                         f"n_nodes={n_nodes}")
    order = topo_order(graph.stages)
    rows = [graph.stages[i] for i in order] * graph.iterations
    s_tot = len(rows)
    skew = np.ones(n_nodes, np.float64)
    if graph.slow_nodes:
        skew[list(graph.slow_nodes)] = graph.slow_factor
    work = np.zeros((s_tot + 1, n_nodes), np.float64)
    demand = np.zeros(s_tot + 1, np.float64)
    barrier = np.zeros(s_tot + 1, np.float64)
    n = n_nodes
    for j, st in enumerate(rows):
        tasks = st.tasks if st.tasks else n
        per_node = tasks // n + (np.arange(n) < tasks % n)
        work[j] = per_node * st.task_gib * skew
        demand[j] = st.demand_gib * GiB
        barrier[j] = 1.0 if st.barrier else 0.0
    names = tuple(f"{st.name}@{j // len(graph.stages)}"
                  for j, st in enumerate(rows))
    return CompiledGraph(work_gib=work.astype(np.float32),
                         demand_bytes=demand.astype(np.float32),
                         barrier=barrier.astype(np.float32),
                         names=names)


def reference_makespan(graph: AppGraphSpec, demand: np.ndarray,
                       node_memory: np.ndarray, grant: np.ndarray,
                       *, interval_s: float,
                       extra_dt: Optional[np.ndarray] = None) -> dict:
    """Float64 numpy mirror of the streamed queue/barrier carry.

    Replays the *exact* interval-quantized update the scan engine runs
    -- same gather/min/where sequence, float64 instead of f32 -- for a
    fixed externally supplied per-interval ``grant`` history
    ``(N, T)`` (plus, optionally, ``extra_dt`` ``(N, T)`` seconds of
    additional per-interval stall, e.g. a cache-miss mirror).  The
    parity tests pin the streamed carry against this to f32 tolerance;
    for the independent sub-interval discrete-event oracle see
    :func:`repro.core.cluster_sim.simulate_app_graph`.

    Returns ``{"makespan_s", "t_done", "stage_idx", "work_done_gib",
    "stage_finish_t"}`` -- ``stage_finish_t[j]`` is the interval at
    which stage row ``j`` cleared its barrier fleet-wide (-1 if never),
    the per-stage timeline the limplock analysis reads.
    """
    from .score import hpl_slowdown_curve   # local: keep import cheap

    g = compile_graph(graph, demand.shape[0])
    n_nodes, t_steps = demand.shape
    w = g.work_gib.astype(np.float64)
    e = g.demand_bytes.astype(np.float64)
    bar = g.barrier.astype(np.float64)
    s_tot = g.n_rows
    m = np.broadcast_to(np.asarray(node_memory, np.float64), (n_nodes,))
    sidx = np.zeros(n_nodes, np.int64)
    wleft = w[0].copy()
    done = np.zeros(n_nodes, np.float64)
    t_done = -1
    stage_finish = np.full(s_tot, -1, np.int64)
    comp = float(graph.compute_gibps)
    for t in range(t_steps):
        d_eff = demand[:, t] + e[sidx]
        v = d_eff + grant[:, t]
        r = v / m
        slow = np.asarray(hpl_slowdown_curve(r), np.float64)
        dt_eff = interval_s * slow
        if extra_dt is not None:
            dt_eff = dt_eff + extra_dt[:, t]
        active = sidx < s_tot
        adv = np.where(active, comp * interval_s * (interval_s / dt_eff),
                       0.0)
        step_done = np.minimum(adv, wleft)
        done += step_done
        wleft = np.maximum(wleft - adv, 0.0)
        fin = active & (wleft <= 0.0)
        lvl = sidx * 2 + fin
        fleet_lvl = int(lvl.min())
        can = fin & ((bar[sidx] == 0.0) | (fleet_lvl >= sidx * 2 + 1))
        newly = can & (bar[sidx] > 0.0)
        for j in np.unique(sidx[newly]):
            if stage_finish[j] < 0:
                stage_finish[j] = t
        sidx = sidx + can
        wleft = np.where(can, w[sidx, np.arange(n_nodes)], wleft)
        if t_done < 0 and int(sidx.min()) >= s_tot:
            t_done = t + 1
    horizon_s = t_steps * interval_s
    total = float(w.sum())
    if t_done >= 0:
        makespan = t_done * interval_s
    else:
        makespan = max(horizon_s * total / max(float(done.sum()), 1e-6),
                       horizon_s)
    return {"makespan_s": makespan, "t_done": t_done, "stage_idx": sidx,
            "work_done_gib": done, "stage_finish_t": stage_finish}
