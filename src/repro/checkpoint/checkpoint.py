"""Fault-tolerant checkpointing: sharded npz + manifest, atomic rename.

Layout::

    <dir>/step-000123/
        manifest.json         # tree structure, leaf shapes/dtypes
        leaf-00000.npy ...    # one file per pytree leaf
        _COMPLETE             # written last; restore requires it

Atomicity: everything is written into ``.tmp-step-...`` then renamed --
a crashed save can never be mistaken for a restorable step (the paper's
restart requirement at cluster scale: node failures mid-checkpoint are
routine).  ``CheckpointManager`` adds retention, latest-step discovery,
and an async mode that stages arrays host-side on a background thread;
its staging buffer is registered as a DynIMS-managed store so a memory
burst in the training process shrinks checkpoint staging before it
causes pressure (the paper's priority inversion, avoided).
"""

from __future__ import annotations

import json
import os
import re
import shutil
import threading
from typing import List, Optional, Tuple

import jax
import numpy as np

_STEP_RE = re.compile(r"^step-(\d{9})$")


def _leaf_paths(tree) -> Tuple[List[np.ndarray], object]:
    leaves, treedef = jax.tree.flatten(tree)
    return [np.asarray(x) for x in leaves], treedef


def save_pytree(tree, directory: str, step: int) -> str:
    """Atomic sharded save; returns the final step directory."""
    final = os.path.join(directory, f"step-{step:09d}")
    tmp = os.path.join(directory, f".tmp-step-{step:09d}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    leaves, treedef = _leaf_paths(tree)
    manifest = {
        "step": step,
        "n_leaves": len(leaves),
        "treedef": str(treedef),
        "leaves": [{"shape": list(x.shape), "dtype": str(x.dtype)}
                   for x in leaves],
    }
    for i, arr in enumerate(leaves):
        np.save(os.path.join(tmp, f"leaf-{i:05d}.npy"), arr)
    with open(os.path.join(tmp, "manifest.json"), "w") as fh:
        json.dump(manifest, fh)
    with open(os.path.join(tmp, "_COMPLETE"), "w") as fh:
        fh.write("ok")
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)
    return final


def restore_pytree(tree_like, directory: str, step: int):
    """Restore into the structure of ``tree_like`` (shape-checked)."""
    path = os.path.join(directory, f"step-{step:09d}")
    if not os.path.exists(os.path.join(path, "_COMPLETE")):
        raise FileNotFoundError(f"no complete checkpoint at {path}")
    leaves, treedef = jax.tree.flatten(tree_like)
    with open(os.path.join(path, "manifest.json")) as fh:
        manifest = json.load(fh)
    if manifest["n_leaves"] != len(leaves):
        raise ValueError(
            f"checkpoint has {manifest['n_leaves']} leaves, "
            f"model expects {len(leaves)}")
    out = []
    for i, ref in enumerate(leaves):
        arr = np.load(os.path.join(path, f"leaf-{i:05d}.npy"))
        if tuple(arr.shape) != tuple(np.shape(ref)):
            raise ValueError(
                f"leaf {i}: checkpoint shape {arr.shape} != "
                f"model shape {np.shape(ref)}")
        out.append(arr)
    return jax.tree.unflatten(treedef, out)


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = []
    for name in os.listdir(directory):
        m = _STEP_RE.match(name)
        if m and os.path.exists(os.path.join(directory, name, "_COMPLETE")):
            steps.append(int(m.group(1)))
    return max(steps) if steps else None


class CheckpointManager:
    """Retention + async host-staged saves with a managed staging buffer."""

    name = "ckpt-staging"
    priority = 5               # above dataset cache, below compute

    def __init__(self, directory: str, keep: int = 3,
                 async_save: bool = False):
        self.directory = directory
        self.keep = keep
        self.async_save = async_save
        os.makedirs(directory, exist_ok=True)
        self._staged_bytes = 0.0           # guarded-by: _lock
        self._capacity = float("inf")      # guarded-by: _lock
        self._thread: Optional[threading.Thread] = None
        self._lock = threading.Lock()

    # -- ManagedStore interface (staging buffer under DynIMS) ---------------
    def capacity(self) -> float:
        return self._capacity if self._capacity != float("inf") else 0.0

    def used(self) -> float:
        return self._staged_bytes

    def set_capacity(self, capacity: float):
        from ..core.store import EvictionReport
        with self._lock:
            self._capacity = capacity
            over = self._staged_bytes > capacity
        # A shrink below current staging forces the pending async save to
        # complete synchronously (flush) rather than grow.  The join
        # happens outside the lock: the save thread takes _lock itself
        # to clear staging, so waiting while holding it would deadlock
        # the moment the save path and set_capacity race.
        report = EvictionReport(self.name, capacity, capacity)
        if over:
            self.wait()
            with self._lock:
                report.evicted_bytes = self._staged_bytes
                self._staged_bytes = 0.0
        return report

    # -- save/restore ---------------------------------------------------------
    def save(self, tree, step: int) -> None:
        if not self.async_save:
            save_pytree(tree, self.directory, step)
            self._gc()
            return
        self.wait()
        host_tree = jax.tree.map(np.asarray, tree)    # host staging copy
        with self._lock:
            self._staged_bytes = sum(
                x.nbytes for x in jax.tree.leaves(host_tree))

        def run():
            save_pytree(host_tree, self.directory, step)
            with self._lock:
                self._staged_bytes = 0.0
            self._gc()

        self._thread = threading.Thread(target=run, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def restore_latest(self, tree_like):
        step = latest_step(self.directory)
        if step is None:
            return None, None
        return restore_pytree(tree_like, self.directory, step), step

    def _gc(self) -> None:
        steps = sorted(
            int(m.group(1)) for m in
            (_STEP_RE.match(n) for n in os.listdir(self.directory)) if m)
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.directory, f"step-{s:09d}"),
                          ignore_errors=True)
